//! Native CPU DoRA model: the forward/backward/optimizer math behind the
//! [`runtime::native`](crate::runtime::native) execution engine.
//!
//! The model mirrors the shape contract of the AOT artifacts (a
//! [`ConfigInfo`]'s vocab/d_model/n_layers/seq/rank/scale), but every hot
//! operation runs through the unified kernel-backend layer instead of
//! PJRT: row norms come from the registry's [`NormEngine`]s, the adapter
//! composition from a [`ComposeKernel`] (forward for inference, dual
//! forward + `backward_with_dmag` for training). The architecture is a
//! residual stack of DoRA-adapted square projections:
//!
//! ```text
//! h_0 = Embed[tokens]                        (frozen, [vocab, d])
//! for each layer l:
//!   base = h @ W_l^T                         (frozen, [d, d])
//!   lora = (h @ A_l^T) @ B_l^T               (trainable, [r,d] / [d,r])
//!   c    = ||W_l + s B_l A_l||_row           (NormEngine, detached)
//!   g    = m_l / max(c, eps)                 (trainable magnitude [d])
//!   y    = base + compose(base, lora, g, s)  (ComposeKernel: g*(base+s*lora))
//!   h    = h + tanh(y)                       (residual)
//! logits = h @ Embed^T                       (tied head)
//! loss   = mean cross-entropy vs next token
//! ```
//!
//! As in the reference DoRA formulation (and PEFT's implementation), the
//! weight norm `c` is detached: gradients flow to the magnitude `m`, the
//! adapter factors `A`/`B`, and through the directional component, never
//! through `c` itself. `d_mag` uses the kernels' deterministic f64 block
//! reduction, so training is bitwise reproducible at any thread count.
//!
//! Leaf order matches the manifest convention (names sorted): frozen =
//! `[embed, layers.<l>.w ...]`, trainable = `[layers.<l>.a, layers.<l>.b,
//! layers.<l>.mag ...]` per layer.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::dispatch::{ComposeCtx, DispatchEnv, Override, Tier};
use crate::dora::config::{ActShape, ModuleShape};
use crate::dora::norm_cpu::AllocTracker;
use crate::kernels::{registry, BackendKind, ComposeKernel, KernelChoice, NormEngine};
use crate::numerics::half::Dtype;
use crate::runtime::ops::{AdapterParams, AdapterVariant, MergedParams, Precision};
use crate::runtime::{ConfigInfo, Tensor};
use crate::util::rng::Rng;

/// AdamW hyper-parameters of the native trainer (fixed, matching the
/// defaults the AOT train artifacts bake in).
pub const LR: f32 = 1e-2;
pub const BETA1: f32 = 0.9;
pub const BETA2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;
pub const WEIGHT_DECAY: f32 = 0.0;

/// The kernel handles one model variant runs with: a compose choice (tier
/// + backend) and the norm engine of the matching backend family.
#[derive(Clone)]
pub struct VariantKernels {
    pub choice: KernelChoice,
    pub norm: Arc<dyn NormEngine>,
}

impl VariantKernels {
    pub fn compose(&self) -> &dyn ComposeKernel {
        self.choice.backend.as_ref()
    }
}

/// Resolve the kernel handles for a typed [`Variant`] through the
/// registry's real dispatch surface. `Fused` forces the fused tiers on
/// (the variant IS the §5.9 fused numeric path, independent of the
/// crossover); `Eager` uses the global kill switch — both are the
/// documented `DORA_*` override semantics, applied to an explicit env
/// instead of process state.
pub fn kernels_for(
    variant: crate::runtime::ops::Variant,
    info: &ConfigInfo,
    training: bool,
) -> Result<VariantKernels> {
    use crate::runtime::ops::Variant;
    let act = ActShape::new(info.train_batch * info.seq, info.d_model);
    let ctx = if training { ComposeCtx::training(act) } else { ComposeCtx::inference(act) };
    let env = match variant {
        Variant::Fused => {
            DispatchEnv { fused_backward: Override::ForceOn, ..DispatchEnv::default() }
        }
        Variant::Eager => DispatchEnv { fused_enabled: false, ..DispatchEnv::default() },
    };
    let choice = registry().select(&env, &ctx);
    let norm = registry().norm_for(&choice);
    Ok(VariantKernels { choice, norm })
}

/// String-named wrapper over [`kernels_for`] (the pre-typed-API surface;
/// callers with a parsed [`Variant`](crate::runtime::ops::Variant) should
/// use `kernels_for` directly).
pub fn variant_kernels(variant: &str, info: &ConfigInfo, training: bool) -> Result<VariantKernels> {
    kernels_for(crate::runtime::ops::Variant::parse(variant)?, info, training)
}

/// Effective LoRA scaling of an adapter variant. `Dora` returns the
/// config scale verbatim — bitwise, the committed golden traces depend
/// on it. `RsLora` applies the rank-stabilized rule: reading the config
/// scale as `alpha/r`, rsLoRA's `alpha/sqrt(r)` is `scale * sqrt(r)`.
/// `Bora` keeps the DoRA scale — its variation is the derived column
/// magnitude, not the scaling.
pub fn variant_scale(adapter: AdapterVariant, info: &ConfigInfo) -> f32 {
    match adapter {
        AdapterVariant::Dora | AdapterVariant::Bora => info.scale as f32,
        AdapterVariant::RsLora => (info.scale as f32) * (info.rank as f32).sqrt(),
    }
}

/// Frozen + trainable leaves of one native model, as host tensors in the
/// manifest leaf order.
pub struct Leaves {
    pub frozen: Vec<Tensor>,
    pub trainable: Vec<Tensor>,
}

/// Names of the frozen leaves, in flatten (sorted) order.
pub fn frozen_names(n_layers: usize) -> Vec<String> {
    let mut names = vec!["embed".to_string()];
    names.extend((0..n_layers).map(|l| format!("layers.{l}.w")));
    names
}

/// Names of the trainable leaves, in flatten (sorted) order.
pub fn trainable_names(n_layers: usize) -> Vec<String> {
    let mut names = Vec::with_capacity(3 * n_layers);
    for l in 0..n_layers {
        names.push(format!("layers.{l}.a"));
        names.push(format!("layers.{l}.b"));
        names.push(format!("layers.{l}.mag"));
    }
    names
}

/// Seeded parameter init matching the config's shapes: embedding and
/// frozen projections at 1/sqrt(d) scale, LoRA `A` random / `B` zero (so
/// the adapter starts as the identity), magnitudes at the initial row
/// norms (so `g = 1` exactly at step 0 — the paper's §3.1 near-unity
/// regime is the *starting point* of training).
pub fn init_leaves(info: &ConfigInfo, seed: u64) -> Leaves {
    let d = info.d_model;
    let r = info.rank;
    let s = info.scale as f32;
    let sigma = 1.0 / (d as f32).sqrt();
    let mut rng = Rng::new(seed ^ 0x1A17);
    let embed = Tensor::f32(vec![info.vocab, d], rng.normal_vec_f32(info.vocab * d, sigma));

    let mut frozen = vec![embed];
    let mut trainable = Vec::with_capacity(3 * info.n_layers);
    for _ in 0..info.n_layers {
        let w = rng.normal_vec_f32(d * d, sigma);
        let a = rng.normal_vec_f32(r * d, sigma);
        let b = vec![0f32; d * r];
        // mag = row norms of W + s*B@A = row norms of W (B = 0).
        let mut tracker = AllocTracker::new();
        let mag = crate::dora::norm_cpu::factored_norm(
            &w,
            &a,
            &b,
            s,
            ModuleShape::new(d, d, r),
            u64::MAX,
            &mut tracker,
        );
        frozen.push(Tensor::f32(vec![d, d], w));
        trainable.push(Tensor::f32(vec![r, d], a));
        trainable.push(Tensor::f32(vec![d, r], b));
        trainable.push(Tensor::f32(vec![d], mag));
    }
    Leaves { frozen, trainable }
}

// ---------------------------------------------------------------------------
// Merged-weight serving representation (the PEFT-style DoRA merge).
// ---------------------------------------------------------------------------

/// Build the merged serving weights for an adapter:
/// `W'_l = m_l ⊙ (W_l + s·B_l·A_l) / rownorm(W_l + s·B_l·A_l)` per layer,
/// with `s` the [`variant_scale`] of the adapter variant. For
/// [`AdapterVariant::Bora`] each column additionally folds in the derived
/// column gain, `W'_l[j,k] *= g_col[k]` — the merged matmul then equals
/// the composed path's input scaling by associativity.
///
/// The row norms come from the factored-norm kernel family
/// (`registry().norm(Fused)`) with the default chunk budget, and the
/// magnitude division uses the same dtype epsilon as the composed path's
/// `layer_g`. Against the FUSED composed path (the serving variant) the
/// merged `g` is therefore **bitwise identical** and the only
/// merged-vs-composed difference is float reassociation; against the
/// eager path `g` additionally differs by the dense-vs-factored norm's
/// f32 accumulation noise. Both gaps are bounded by the 1e-5 parity
/// property tests. Degenerate rows (`rownorm → 0`) hit the same
/// `max(c, eps)` clamp on both paths.
pub fn merge_adapter_params(
    info: &ConfigInfo,
    params: &AdapterParams,
    adapter: AdapterVariant,
    precision: Precision,
) -> Result<MergedParams> {
    params.validate(info, &format!("merge_{}", info.name))?;
    let d = info.d_model;
    let r = info.rank;
    let s = variant_scale(adapter, info);
    let dt = precision.dtype();
    let norm = registry().norm(BackendKind::Fused);
    let eps = dt.division_eps();
    let budget = DispatchEnv::default().norm_chunk_bytes;
    // Under bf16 the merge reads the SAME bf16-rounded leaf views the
    // composed forward serves from, so the merged replica reproduces the
    // composed bf16 path (to reassociation), not a mixed f32/bf16 hybrid.
    let qstore;
    let params = if precision == Precision::F32 {
        params
    } else {
        qstore = AdapterParams {
            frozen: params.frozen.iter().map(|t| quantize_tensor(t, dt)).collect(),
            trainable: params.trainable.iter().map(|t| quantize_tensor(t, dt)).collect(),
        };
        &qstore
    };
    let mut layers = Vec::with_capacity(info.n_layers);
    for l in 0..info.n_layers {
        let w = params.frozen[1 + l].as_f32()?;
        let a = params.trainable[3 * l].as_f32()?;
        let b = params.trainable[3 * l + 1].as_f32()?;
        let mag = params.trainable[3 * l + 2].as_f32()?;
        let mut tracker = AllocTracker::new();
        let shape = ModuleShape::new(d, d, r);
        let c = norm.weight_norm(w, a, b, s, shape, budget, dt, &mut tracker);
        let mut g = crate::dora::norm_cpu::magnitude_divide(mag, &c, eps);
        quantize_buf(dt, &mut g);
        let g_col = if adapter == AdapterVariant::Bora {
            // Same zero-B trick as `layer_g_col`: both column norms run
            // the identical code path, so `g_col = 1` exactly at init.
            let b0 = vec![0f32; d * r];
            let m_col = norm.weight_colnorm(w, a, &b0, s, shape, budget, dt, &mut tracker);
            let c_col = norm.weight_colnorm(w, a, b, s, shape, budget, dt, &mut tracker);
            let mut gc = crate::dora::norm_cpu::magnitude_divide(&m_col, &c_col, eps);
            quantize_buf(dt, &mut gc);
            Some(gc)
        } else {
            None
        };
        let ba = matmul_nn(b, a, d, r, d);
        let mut merged = vec![0f32; d * d];
        for j in 0..d {
            let gj = g[j];
            let wrow = &w[j * d..(j + 1) * d];
            let brow = &ba[j * d..(j + 1) * d];
            let mrow = &mut merged[j * d..(j + 1) * d];
            match &g_col {
                Some(gc) => {
                    for k in 0..d {
                        mrow[k] = gj * (wrow[k] + s * brow[k]) * gc[k];
                    }
                }
                None => {
                    for k in 0..d {
                        mrow[k] = gj * (wrow[k] + s * brow[k]);
                    }
                }
            }
        }
        // The replica is STORED at the serving precision — this is the
        // halved-bytes object the merged cache accounts dtype-aware.
        quantize_buf(dt, &mut merged);
        layers.push(Tensor::f32(vec![d, d], merged));
    }
    Ok(MergedParams { embed: params.frozen[0].clone(), layers, precision })
}

/// Merged-weight inference: last-position logits `[bs, vocab]` for a
/// token batch `[bs, seq]`. One plain matmul + residual tanh per layer —
/// no norm, no compose, no LoRA matmuls on the hot path.
pub fn merged_infer_logits(
    info: &ConfigInfo,
    merged: &MergedParams,
    tokens: &[i32],
    bs: usize,
    seq: usize,
) -> Result<Vec<f32>> {
    let d = info.d_model;
    if let Some(&t) = tokens.iter().find(|&&t| t < 0 || t as usize >= info.vocab) {
        bail!("token {t} outside vocab 0..{}", info.vocab);
    }
    let e = merged.embed.as_f32()?;
    let dt = merged.precision.dtype();
    let rows = tokens.len();
    let mut h = vec![0f32; rows * d];
    for (i, &t) in tokens.iter().enumerate() {
        let row = t as usize * d;
        h[i * d..(i + 1) * d].copy_from_slice(&e[row..row + d]);
    }
    for layer in &merged.layers {
        let wp = layer.as_f32()?;
        let mut y = matmul_nt(&h, wp, rows, d, d);
        quantize_buf(dt, &mut y);
        let mut t = vec![0f32; rows * d];
        for i in 0..rows * d {
            t[i] = y[i].tanh();
        }
        quantize_buf(dt, &mut t);
        for i in 0..rows * d {
            h[i] += t[i];
        }
        quantize_buf(dt, &mut h);
    }
    let mut last = vec![0f32; bs * d];
    for row in 0..bs {
        let src = (row * seq + seq - 1) * d;
        last[row * d..(row + 1) * d].copy_from_slice(&h[src..src + d]);
    }
    let mut logits = matmul_nt(&last, e, bs, d, info.vocab);
    quantize_buf(dt, &mut logits);
    Ok(logits)
}

/// Merged-weight decode step: next-token logits `[n, vocab]` for `n`
/// single tokens (the streaming scheduler's fast path). The model is
/// row-local, so this is exactly [`merged_infer_logits`] at `seq = 1` —
/// each row's logits are a function of its token alone, bitwise
/// independent of the co-resident rows.
pub fn merged_decode_logits(
    info: &ConfigInfo,
    merged: &MergedParams,
    tokens: &[i32],
) -> Result<Vec<f32>> {
    merged_infer_logits(info, merged, tokens, tokens.len(), 1)
}

// ---------------------------------------------------------------------------
// Dense ops (the non-adapter matmuls the AOT artifacts lower to XLA dots).
// All three route through the blocked/register-tiled cores in
// `kernels::gemm` — small-K dispatch picks the adapter fast path when the
// contraction depth is the rank. For every builtin-config shape the cores
// are bitwise-identical to the old naive loops (single k-block,
// sequential per-element k-order), so the golden trace and the NumPy
// replicas are unchanged by the reroute.
// ---------------------------------------------------------------------------

/// C[m,n] = A[m,k] @ B[n,k]^T (both operands row-major).
pub(crate) fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    crate::kernels::gemm::nt(a, b, m, k, n)
}

/// C[m,n] = A[m,k] @ B[k,n] (row-major).
pub(crate) fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    crate::kernels::gemm::nn(a, b, m, k, n)
}

/// C[n1,n2] = A[rows,n1]^T @ B[rows,n2] (gradient contractions).
pub(crate) fn matmul_tn(a: &[f32], b: &[f32], rows: usize, n1: usize, n2: usize) -> Vec<f32> {
    crate::kernels::gemm::tn(a, b, rows, n1, n2)
}

/// BoRA input scaling: `out[i,k] = h[i,k] * g_col[k]` over `[rows, d]`.
fn scale_cols(h: &[f32], g_col: &[f32], d: usize) -> Vec<f32> {
    let mut out = h.to_vec();
    for row in out.chunks_mut(d) {
        for (x, &gk) in row.iter_mut().zip(g_col) {
            *x *= gk;
        }
    }
    out
}

/// Round a buffer in place to the storage dtype — the shape-fixed-point
/// quantization of the `bf16-master-f32` scheme (DESIGN.md §3.11). A
/// no-op for f32 (the f32 path stays bitwise-untouched); elementwise RNE
/// otherwise, so the rounding itself is row-local and deterministic.
fn quantize_buf(dt: Dtype, v: &mut [f32]) {
    if dt != Dtype::F32 {
        for x in v.iter_mut() {
            *x = dt.quantize(*x);
        }
    }
}

/// Quantized copy of an f32 tensor (i32 tensors pass through unchanged).
pub(crate) fn quantize_tensor(t: &Tensor, dt: Dtype) -> Tensor {
    match t.as_f32() {
        Ok(v) => Tensor::f32(t.shape.clone(), v.iter().map(|&x| dt.quantize(x)).collect()),
        Err(_) => t.clone(),
    }
}

// ---------------------------------------------------------------------------
// The model
// ---------------------------------------------------------------------------

/// A borrowed view of one model's parameters plus its kernel handles.
///
/// Under [`Precision::Bf16`] ([`Self::with_precision`]) the model holds
/// bf16-rounded COPIES of the leaves: the borrowed tensors stay the f32
/// master weights (the optimizer updates those), while every forward
/// read — and every shape-fixed activation — goes through the rounded
/// view. That is the whole of the paper's "bf16 with f32 master weights"
/// scheme at the model level.
pub struct NativeModel<'a> {
    pub info: &'a ConfigInfo,
    frozen: &'a [Tensor],
    trainable: &'a [Tensor],
    kernels: VariantKernels,
    adapter: AdapterVariant,
    precision: Precision,
    /// bf16-rounded forward views of `frozen`/`trainable` (None for f32).
    qfrozen: Option<Vec<Tensor>>,
    qtrainable: Option<Vec<Tensor>>,
}

/// Per-layer activations saved by the training forward for the backward.
struct LayerTrace {
    /// Layer input h_l [rows, d].
    h: Vec<f32>,
    /// u = h @ A^T [rows, r].
    u: Vec<f32>,
    /// inner = base + s*lora (the dual-forward output) [rows, d].
    inner: Vec<f32>,
    /// tanh(y) [rows, d] (residual branch; also the tanh' cache).
    t: Vec<f32>,
    /// g = m / max(c, eps) [d].
    g: Vec<f32>,
    /// Detached row norms c [d].
    c: Vec<f32>,
    /// BoRA's derived column gain [d] (None for row-magnitude variants).
    /// Frozen AND detached: no gradient flows to or through it.
    g_col: Option<Vec<f32>>,
}

/// Forward outputs of one training step.
struct Trace {
    layers: Vec<LayerTrace>,
    /// Final hidden state [rows, d].
    h_final: Vec<f32>,
    /// Softmax-minus-onehot, pre-multiplied by the normalization
    /// constant (1/rows for the full-batch path, 1/total_rows for a
    /// data-parallel shard) [rows, vocab].
    d_logits: Vec<f32>,
    /// Per-row f64 cross-entropy terms (`lse - z[target]`), kept
    /// unreduced so per-sample loss sums are exportable.
    loss_terms: Vec<f64>,
    loss: f32,
}

/// Per-layer trainable gradients, in leaf order (a, b, mag).
struct LayerGrads {
    a: Vec<f32>,
    b: Vec<f32>,
    mag: Vec<f32>,
}

impl<'a> NativeModel<'a> {
    pub fn new(
        info: &'a ConfigInfo,
        frozen: &'a [Tensor],
        trainable: &'a [Tensor],
        kernels: VariantKernels,
    ) -> Result<NativeModel<'a>> {
        if frozen.len() != info.frozen.len() || trainable.len() != info.trainable.len() {
            bail!(
                "native model {}: got {}+{} leaves, config wants {}+{}",
                info.name,
                frozen.len(),
                trainable.len(),
                info.frozen.len(),
                info.trainable.len()
            );
        }
        Ok(NativeModel {
            info,
            frozen,
            trainable,
            kernels,
            adapter: AdapterVariant::Dora,
            precision: Precision::F32,
            qfrozen: None,
            qtrainable: None,
        })
    }

    /// Re-type the model as an adapter variant ([`AdapterVariant::Dora`]
    /// is the [`Self::new`] default). The leaf layout is shared across
    /// variants; only the compose math changes.
    pub fn with_adapter(mut self, adapter: AdapterVariant) -> NativeModel<'a> {
        self.adapter = adapter;
        self
    }

    /// Re-type the model's numeric operating point ([`Precision::F32`] is
    /// the [`Self::new`] default). `Bf16` snapshots bf16-rounded copies
    /// of all leaves for the forward; the borrowed masters stay f32.
    pub fn with_precision(mut self, precision: Precision) -> NativeModel<'a> {
        self.precision = precision;
        if precision == Precision::Bf16 {
            let dt = precision.dtype();
            self.qfrozen =
                Some(self.frozen.iter().map(|t| quantize_tensor(t, dt)).collect());
            self.qtrainable =
                Some(self.trainable.iter().map(|t| quantize_tensor(t, dt)).collect());
        } else {
            self.qfrozen = None;
            self.qtrainable = None;
        }
        self
    }

    /// The storage/activation dtype of this model's forward.
    fn dtype(&self) -> Dtype {
        self.precision.dtype()
    }

    /// Round a buffer at a shape-fixed point (no-op for f32).
    fn q(&self, v: &mut [f32]) {
        quantize_buf(self.dtype(), v);
    }

    /// The leaf tensor the FORWARD reads: the bf16 view when one exists,
    /// the borrowed f32 master otherwise.
    fn frozen_leaf(&self, i: usize) -> &Tensor {
        match &self.qfrozen {
            Some(v) => &v[i],
            None => &self.frozen[i],
        }
    }

    fn trainable_leaf(&self, i: usize) -> &Tensor {
        match &self.qtrainable {
            Some(v) => &v[i],
            None => &self.trainable[i],
        }
    }

    pub fn tier(&self) -> Tier {
        self.kernels.choice.tier
    }

    /// The effective LoRA scaling ([`variant_scale`]) of this model.
    fn scale(&self) -> f32 {
        variant_scale(self.adapter, self.info)
    }

    pub fn backend_name(&self) -> &'static str {
        self.kernels.choice.backend.name()
    }

    fn embed(&self) -> &[f32] {
        self.frozen_leaf(0).as_f32().expect("embed is f32")
    }

    fn layer_w(&self, l: usize) -> &[f32] {
        self.frozen_leaf(1 + l).as_f32().expect("w is f32")
    }

    fn layer_abm(&self, l: usize) -> (&[f32], &[f32], &[f32]) {
        (
            self.trainable_leaf(3 * l).as_f32().expect("a is f32"),
            self.trainable_leaf(3 * l + 1).as_f32().expect("b is f32"),
            self.trainable_leaf(3 * l + 2).as_f32().expect("mag is f32"),
        )
    }

    /// Range-check a token block (inputs AND targets — a bad target
    /// would otherwise index out of bounds in the loss, a panic the
    /// engine's error-not-panic contract forbids).
    fn check_tokens(&self, tokens: &[i32]) -> Result<()> {
        if let Some(&t) = tokens.iter().find(|&&t| t < 0 || t as usize >= self.info.vocab) {
            bail!("token {t} outside vocab 0..{}", self.info.vocab);
        }
        Ok(())
    }

    /// Embedding lookup: tokens (row-major, pre-validated) -> [rows, d].
    fn embed_lookup(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let d = self.info.d_model;
        self.check_tokens(tokens)?;
        let e = self.embed();
        let mut h = vec![0f32; tokens.len() * d];
        for (i, &t) in tokens.iter().enumerate() {
            let row = t as usize * d;
            h[i * d..(i + 1) * d].copy_from_slice(&e[row..row + d]);
        }
        Ok(h)
    }

    /// One layer's norm + magnitude division (c detached). Under bf16 the
    /// norm kernel quantizes its intermediates, the division uses the
    /// half-precision epsilon (Appendix B), and `g` is rounded — it is a
    /// stored activation of the forward.
    fn layer_g(&self, l: usize) -> (Vec<f32>, Vec<f32>) {
        let d = self.info.d_model;
        let s = self.scale();
        let dt = self.dtype();
        let (a, b, mag) = self.layer_abm(l);
        let mut tracker = AllocTracker::new();
        let c = self.kernels.norm.weight_norm(
            self.layer_w(l),
            a,
            b,
            s,
            ModuleShape::new(d, d, self.info.rank),
            DispatchEnv::default().norm_chunk_bytes,
            dt,
            &mut tracker,
        );
        let mut g = crate::dora::norm_cpu::magnitude_divide(mag, &c, dt.division_eps());
        self.q(&mut g);
        (g, c)
    }

    /// BoRA's derived column gain for layer `l`:
    /// `g_col = colnorm(W) / max(colnorm(W + s·B·A), eps)`, both norms
    /// detached, the numerator frozen at the base weights. Returns `None`
    /// for the row-magnitude variants (their input is unscaled). The
    /// numerator runs the SAME factored kernel with a zero `B` rather
    /// than `s = 0`, so at init (`B = 0`) both norms are bitwise equal
    /// and `g_col = 1` exactly — BoRA starts as the identity, like DoRA.
    fn layer_g_col(&self, l: usize) -> Option<Vec<f32>> {
        if self.adapter != AdapterVariant::Bora {
            return None;
        }
        let d = self.info.d_model;
        let r = self.info.rank;
        let s = self.scale();
        let dt = self.dtype();
        let (a, b, _) = self.layer_abm(l);
        let w = self.layer_w(l);
        let shape = ModuleShape::new(d, d, r);
        let budget = DispatchEnv::default().norm_chunk_bytes;
        let mut tracker = AllocTracker::new();
        let b0 = vec![0f32; d * r];
        let m_col =
            self.kernels.norm.weight_colnorm(w, a, &b0, s, shape, budget, dt, &mut tracker);
        let c_col =
            self.kernels.norm.weight_colnorm(w, a, b, s, shape, budget, dt, &mut tracker);
        let mut g_col =
            crate::dora::norm_cpu::magnitude_divide(&m_col, &c_col, dt.division_eps());
        self.q(&mut g_col);
        Some(g_col)
    }

    /// Inference forward: tokens [bs*seq] -> hidden states [rows, d].
    /// (`forward` only — the Tier-2 path; no trace is kept.)
    fn hidden_forward(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let d = self.info.d_model;
        let r = self.info.rank;
        let s = self.scale();
        let rows = tokens.len();
        let act = ActShape::new(rows, d);
        let mut h = self.embed_lookup(tokens)?;
        let mut delta = vec![0f32; rows * d];
        for l in 0..self.info.n_layers {
            let (a, b, _) = self.layer_abm(l);
            // BoRA scales the module INPUT by the derived column gain;
            // the residual stream itself stays unscaled.
            let hs = self.layer_g_col(l).map(|gc| {
                let mut v = scale_cols(&h, &gc, d);
                self.q(&mut v);
                v
            });
            let hin: &[f32] = hs.as_deref().unwrap_or(&h);
            let mut base = matmul_nt(hin, self.layer_w(l), rows, d, d);
            self.q(&mut base);
            let mut u = matmul_nt(hin, a, rows, d, r);
            self.q(&mut u);
            let mut lora = matmul_nt(&u, b, rows, r, d);
            self.q(&mut lora);
            let (g, _c) = self.layer_g(l);
            self.kernels.compose().forward(&base, &lora, &g, s, act, self.dtype(), &mut delta);
            let mut t = vec![0f32; rows * d];
            for i in 0..rows * d {
                t[i] = (base[i] + delta[i]).tanh();
            }
            self.q(&mut t);
            for i in 0..rows * d {
                h[i] += t[i];
            }
            self.q(&mut h);
        }
        Ok(h)
    }

    /// Next-token logits for the last position of each sequence:
    /// tokens [bs, seq] -> [bs, vocab].
    pub fn infer_logits(&self, tokens: &[i32], bs: usize, seq: usize) -> Result<Vec<f32>> {
        let d = self.info.d_model;
        let h = self.hidden_forward(tokens)?;
        // Tied head over last positions only.
        let mut last = vec![0f32; bs * d];
        for row in 0..bs {
            let src = (row * seq + seq - 1) * d;
            last[row * d..(row + 1) * d].copy_from_slice(&h[src..src + d]);
        }
        let mut logits = matmul_nt(&last, self.embed(), bs, d, self.info.vocab);
        self.q(&mut logits);
        Ok(logits)
    }

    /// Composed-path decode step: next-token logits `[n, vocab]` for `n`
    /// single tokens — [`Self::infer_logits`] at `seq = 1` (row-local
    /// model, so no per-request sequence state is needed).
    pub fn decode_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.infer_logits(tokens, tokens.len(), 1)
    }

    /// Mean cross-entropy of tokens [bs, seq+1] (inputs = [:, :seq],
    /// targets = [:, 1:]), forward only.
    pub fn eval_loss(&self, tokens: &[i32], bs: usize) -> Result<f32> {
        let seq = self.info.seq;
        self.check_tokens(tokens)?;
        let (inputs, targets) = split_tokens(tokens, bs, seq);
        let h = self.hidden_forward(&inputs)?;
        let mut logits =
            matmul_nt(&h, self.embed(), bs * seq, self.info.d_model, self.info.vocab);
        self.q(&mut logits);
        let (loss, _) = xent_forward_backward(&logits, &targets, self.info.vocab);
        Ok(loss)
    }

    /// Training forward with the Tier-1 dual-output compose; saves the
    /// per-layer trace the backward needs. The cross-entropy gradient is
    /// normalized by the forward batch itself (the full-batch path).
    fn train_forward(&self, inputs: &[i32], targets: &[i32]) -> Result<Trace> {
        let rows = inputs.len();
        self.train_forward_norm(inputs, targets, 1.0 / rows as f32)
    }

    /// [`Self::train_forward`] with an explicit gradient-normalization
    /// constant `inv` (the data-parallel shard path passes
    /// `1/total_rows` of the EFFECTIVE batch, so shard gradients reduce
    /// into the whole batch's mean-loss gradient). Every forward op is
    /// row-local, so the per-row trace is bitwise-independent of how
    /// samples were grouped into the micro-batch.
    fn train_forward_norm(&self, inputs: &[i32], targets: &[i32], inv: f32) -> Result<Trace> {
        let d = self.info.d_model;
        let r = self.info.rank;
        let s = self.scale();
        let rows = inputs.len();
        let act = ActShape::new(rows, d);
        let mut h = self.embed_lookup(inputs)?;
        let mut layers = Vec::with_capacity(self.info.n_layers);
        for l in 0..self.info.n_layers {
            let (a, b, _) = self.layer_abm(l);
            let g_col = self.layer_g_col(l);
            // BoRA scales the module INPUT by the derived column gain;
            // the trace keeps the SCALED input (the matmul operand the
            // adapter gradients contract against).
            let hs = g_col.as_ref().map(|gc| {
                let mut v = scale_cols(&h, gc, d);
                self.q(&mut v);
                v
            });
            let hin: &[f32] = hs.as_deref().unwrap_or(&h);
            let mut base = matmul_nt(hin, self.layer_w(l), rows, d, d);
            self.q(&mut base);
            let mut u = matmul_nt(hin, a, rows, d, r);
            self.q(&mut u);
            let mut lora = matmul_nt(&u, b, rows, r, d);
            self.q(&mut lora);
            let (g, c) = self.layer_g(l);
            let mut delta = vec![0f32; rows * d];
            let mut inner = vec![0f32; rows * d];
            self.kernels
                .compose()
                .forward_dual(&base, &lora, &g, s, act, self.dtype(), &mut delta, &mut inner);
            let mut t = vec![0f32; rows * d];
            for i in 0..rows * d {
                t[i] = (base[i] + delta[i]).tanh();
            }
            self.q(&mut t);
            let mut h_next = h.clone();
            for i in 0..rows * d {
                h_next[i] += t[i];
            }
            self.q(&mut h_next);
            let traced_h = match hs {
                Some(v) => v,
                None => h,
            };
            layers.push(LayerTrace { h: traced_h, u, inner, t, g, c, g_col });
            h = h_next;
        }
        let mut logits = matmul_nt(&h, self.embed(), rows, d, self.info.vocab);
        self.q(&mut logits);
        let (loss_terms, d_logits) = xent_grad(&logits, targets, self.info.vocab, inv);
        let loss = xent_mean_loss(&loss_terms, rows);
        Ok(Trace { layers, h_final: h, d_logits, loss_terms, loss })
    }

    /// Backward through the stack; returns per-layer (dA, dB, dmag).
    fn backward(&self, trace: &Trace) -> Vec<LayerGrads> {
        let rows = trace.h_final.len() / self.info.d_model;
        self.backward_range(trace, 0, rows)
    }

    /// Backward over the trace's row range `[row0, row1)` only. Every
    /// non-contracting array in the backward (dh, dy, d_lora, d_base) is
    /// row-local, so restricting to a range slices the full computation
    /// exactly: `backward_range(trace, 0, rows)` IS the historical
    /// full-batch backward bitwise, while per-sample ranges export the
    /// fixed-granularity gradients of the data-parallel reduction.
    fn backward_range(&self, trace: &Trace, row0: usize, row1: usize) -> Vec<LayerGrads> {
        let d = self.info.d_model;
        let r = self.info.rank;
        let s = self.scale();
        let rows = row1 - row0;
        let act = ActShape::new(rows, d);
        // Gradients are f32 master-weight math at EVERY precision (the
        // `bf16-master-f32` accumulate side): the kernels below run with
        // Dtype::F32 over the bf16-rounded trace. Only the magnitude
        // division epsilon follows the forward's dtype, so dmag matches
        // the clamp the forward actually applied.
        let eps = self.dtype().division_eps();
        let vocab = self.info.vocab;
        // dh = d_logits @ Embed  [rows, d].
        let d_logits = &trace.d_logits[row0 * vocab..row1 * vocab];
        let mut dh = matmul_nn(d_logits, self.embed(), rows, vocab, d);
        let mut grads: Vec<LayerGrads> = Vec::with_capacity(self.info.n_layers);
        for l in (0..self.info.n_layers).rev() {
            let tr = &trace.layers[l];
            let (a, b, _) = self.layer_abm(l);
            let t = &tr.t[row0 * d..row1 * d];
            let inner = &tr.inner[row0 * d..row1 * d];
            // Through the residual tanh branch: dy = dh * (1 - tanh^2).
            let mut dy = vec![0f32; rows * d];
            for i in 0..rows * d {
                dy[i] = dh[i] * (1.0 - t[i] * t[i]);
            }
            // Compose backward + the deterministic d_mag reduction. The
            // kernel computes d_lora = g*s*dy and d_base = (g-1)*dy; the
            // total base gradient adds the skip term dy (y = base + delta).
            let mut d_lora = vec![0f32; rows * d];
            let mut d_base = vec![0f32; rows * d];
            let dg = self.kernels.compose().backward_with_dmag(
                &dy,
                inner,
                &tr.g,
                s,
                act,
                Dtype::F32,
                &mut d_lora,
                &mut d_base,
            );
            for i in 0..rows * d {
                d_base[i] += dy[i];
            }
            // g = mag / max(c, eps), c detached -> dmag = dg / max(c, eps).
            let dmag: Vec<f32> =
                dg.iter().zip(&tr.c).map(|(&dgj, &cj)| dgj / cj.max(eps)).collect();
            // Adapter factors: lora = u @ B^T, u = h @ A^T.
            let u = &tr.u[row0 * r..row1 * r];
            let h = &tr.h[row0 * d..row1 * d];
            let db = matmul_tn(&d_lora, u, rows, d, r);
            let du = matmul_nn(&d_lora, b, rows, d, r);
            let da = matmul_tn(&du, h, rows, r, d);
            // dh_prev = dh (residual skip) + d_base @ W + du @ A. With
            // BoRA the through-module input was h ⊙ g_col, so the two
            // module contributions pick up g_col (frozen, detached —
            // this is the whole of its backward footprint).
            let dh_w = matmul_nn(&d_base, self.layer_w(l), rows, d, d);
            let dh_a = matmul_nn(&du, a, rows, r, d);
            match &tr.g_col {
                Some(gc) => {
                    for i in 0..rows * d {
                        dh[i] += (dh_w[i] + dh_a[i]) * gc[i % d];
                    }
                }
                None => {
                    for i in 0..rows * d {
                        dh[i] += dh_w[i] + dh_a[i];
                    }
                }
            }
            grads.push(LayerGrads { a: da, b: db, mag: dmag });
        }
        grads.reverse();
        grads
    }

    /// One training step's loss + flat trainable gradients (leaf order)
    /// for a [bs, seq+1] token block. The optimizer update is separate
    /// ([`adamw_step`]) so callers can drop this borrowed view before
    /// mutating the parameters it reads.
    pub fn loss_and_grads(&self, tokens: &[i32], bs: usize) -> Result<(f32, Vec<Vec<f32>>)> {
        let seq = self.info.seq;
        self.check_tokens(tokens)?;
        let (inputs, targets) = split_tokens(tokens, bs, seq);
        let trace = self.train_forward(&inputs, &targets)?;
        let grads = self.backward(&trace);
        let flat: Vec<Vec<f32>> =
            grads.into_iter().flat_map(|g| [g.a, g.b, g.mag]).collect();
        Ok((trace.loss, flat))
    }

    /// Per-sample gradient export for a `[mb, seq+1]` micro-batch — the
    /// data-parallel shard computation. One batched forward (row-local,
    /// so bitwise-independent of the batching), then an independent
    /// backward per sample over its `seq` rows. The cross-entropy
    /// gradient is normalized by `total_rows` (the EFFECTIVE batch), so
    /// samples from different shards reduce into the whole batch's
    /// mean-loss gradient. Returns, per sample in batch order, the f64
    /// loss sum and the flat trainable gradients (leaf order).
    pub fn loss_and_sample_grads(
        &self,
        tokens: &[i32],
        mb: usize,
        total_rows: usize,
    ) -> Result<Vec<(f64, Vec<Vec<f32>>)>> {
        let seq = self.info.seq;
        if total_rows < mb * seq {
            bail!(
                "effective-batch rows {total_rows} < the micro-batch's own {} rows",
                mb * seq
            );
        }
        self.check_tokens(tokens)?;
        let (inputs, targets) = split_tokens(tokens, mb, seq);
        let inv = 1.0 / total_rows as f32;
        let trace = self.train_forward_norm(&inputs, &targets, inv)?;
        let mut out = Vec::with_capacity(mb);
        for smp in 0..mb {
            let (r0, r1) = (smp * seq, (smp + 1) * seq);
            let grads = self.backward_range(&trace, r0, r1);
            let flat: Vec<Vec<f32>> =
                grads.into_iter().flat_map(|g| [g.a, g.b, g.mag]).collect();
            // Sequential f64 loss accumulation in row order within the
            // sample — the reducer continues it across samples.
            let mut loss_sum = 0f64;
            for &t in &trace.loss_terms[r0..r1] {
                loss_sum += t;
            }
            out.push((loss_sum, flat));
        }
        Ok(out)
    }
}

/// Split a [bs, seq+1] block into inputs [bs, seq] and targets [bs, seq].
fn split_tokens(tokens: &[i32], bs: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
    let stride = seq + 1;
    debug_assert_eq!(tokens.len(), bs * stride);
    let mut inputs = Vec::with_capacity(bs * seq);
    let mut targets = Vec::with_capacity(bs * seq);
    for row in 0..bs {
        let block = &tokens[row * stride..(row + 1) * stride];
        inputs.extend_from_slice(&block[..seq]);
        targets.extend_from_slice(&block[1..]);
    }
    (inputs, targets)
}

/// Cross-entropy over [rows, vocab] logits: mean loss + gradient
/// (softmax - onehot) / rows. f64 log-sum-exp accumulation.
fn xent_forward_backward(logits: &[f32], targets: &[i32], vocab: usize) -> (f32, Vec<f32>) {
    let rows = targets.len();
    let (terms, d) = xent_grad(logits, targets, vocab, 1.0 / rows as f32);
    (xent_mean_loss(&terms, rows), d)
}

/// Cross-entropy core with an explicit gradient-normalization constant:
/// per-row f64 loss terms (`lse - z[target]`, unreduced) + the gradient
/// `(softmax - onehot) * inv`. Rows are fully independent, so per-row
/// outputs are bitwise-identical under any batching of the rows.
fn xent_grad(logits: &[f32], targets: &[i32], vocab: usize, inv: f32) -> (Vec<f64>, Vec<f32>) {
    let rows = targets.len();
    debug_assert_eq!(logits.len(), rows * vocab);
    let mut d = vec![0f32; rows * vocab];
    let mut terms = vec![0f64; rows];
    for i in 0..rows {
        let zrow = &logits[i * vocab..(i + 1) * vocab];
        let max = zrow.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f64;
        for &z in zrow {
            sum += ((z - max) as f64).exp();
        }
        let lse = sum.ln() + max as f64;
        let t = targets[i] as usize;
        terms[i] = lse - zrow[t] as f64;
        let drow = &mut d[i * vocab..(i + 1) * vocab];
        for j in 0..vocab {
            drow[j] = (((zrow[j] - max) as f64).exp() / sum) as f32 * inv;
        }
        drow[t] -= inv;
    }
    (terms, d)
}

/// Mean loss from per-row terms: sequential f64 accumulation in row
/// order (bitwise-matching the historical interleaved accumulation).
fn xent_mean_loss(terms: &[f64], rows: usize) -> f32 {
    let mut loss = 0f64;
    for &t in terms {
        loss += t;
    }
    (loss / rows as f64) as f32
}

/// AdamW with bias correction, in-place over the trainable leaves.
/// `t` is the 1-based optimizer step for bias correction.
pub fn adamw_step(
    params: &mut [Tensor],
    m1: &mut [Tensor],
    m2: &mut [Tensor],
    grads: &[Vec<f32>],
    t: i32,
) {
    debug_assert_eq!(params.len(), grads.len());
    let bc1 = 1.0 - BETA1.powi(t);
    let bc2 = 1.0 - BETA2.powi(t);
    for ((p, (v1, v2)), g) in params
        .iter_mut()
        .zip(m1.iter_mut().zip(m2.iter_mut()))
        .zip(grads)
    {
        let pv = match &mut p.data {
            crate::runtime::TensorData::F32(v) => v,
            crate::runtime::TensorData::I32(_) => unreachable!("trainable leaves are f32"),
        };
        let m1v = match &mut v1.data {
            crate::runtime::TensorData::F32(v) => v,
            crate::runtime::TensorData::I32(_) => unreachable!("moments are f32"),
        };
        let m2v = match &mut v2.data {
            crate::runtime::TensorData::F32(v) => v,
            crate::runtime::TensorData::I32(_) => unreachable!("moments are f32"),
        };
        for i in 0..pv.len() {
            let gi = g[i];
            m1v[i] = BETA1 * m1v[i] + (1.0 - BETA1) * gi;
            m2v[i] = BETA2 * m2v[i] + (1.0 - BETA2) * gi * gi;
            let mhat = m1v[i] / bc1;
            let vhat = m2v[i] / bc2;
            pv[i] -= LR * (mhat / (vhat.sqrt() + ADAM_EPS) + WEIGHT_DECAY * pv[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_info() -> ConfigInfo {
        crate::runtime::native::builtin_configs()["tiny"].clone()
    }

    #[test]
    fn init_matches_config_shapes() {
        let info = tiny_info();
        let leaves = init_leaves(&info, 0);
        assert_eq!(leaves.frozen.len(), info.frozen.len());
        assert_eq!(leaves.trainable.len(), info.trainable.len());
        assert_eq!(leaves.frozen[0].shape, vec![info.vocab, info.d_model]);
        for l in 0..info.n_layers {
            assert_eq!(leaves.frozen[1 + l].shape, vec![info.d_model, info.d_model]);
            assert_eq!(leaves.trainable[3 * l].shape, vec![info.rank, info.d_model]);
            assert_eq!(leaves.trainable[3 * l + 1].shape, vec![info.d_model, info.rank]);
            assert_eq!(leaves.trainable[3 * l + 2].shape, vec![info.d_model]);
        }
        // B = 0 => g = mag / ||W|| = 1 exactly at init.
        let b = leaves.trainable[1].as_f32().unwrap();
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn matmul_variants_agree_on_small_case() {
        // A [2,3], B [4,3]: nt vs manual.
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0];
        let c = matmul_nt(&a, &b, 2, 3, 4);
        assert_eq!(c, vec![1.0, 2.0, 3.0, 6.0, 4.0, 5.0, 6.0, 15.0]);
        // tn: A[2,2]^T @ B[2,3].
        let a2 = [1.0, 2.0, 3.0, 4.0];
        let b2 = [1.0, 0.0, 1.0, 0.0, 1.0, 1.0];
        let c2 = matmul_tn(&a2, &b2, 2, 2, 3);
        assert_eq!(c2, vec![1.0, 3.0, 4.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn xent_gradient_sums_to_zero_per_row() {
        let logits = [1.0f32, 2.0, 0.5, -1.0, 0.0, 1.0];
        let targets = [1i32, 2];
        let (loss, d) = xent_forward_backward(&logits, &targets, 3);
        assert!(loss > 0.0 && loss.is_finite());
        for row in 0..2 {
            let s: f32 = d[row * 3..(row + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "row {row} grad sum {s}");
        }
    }

    #[test]
    fn variant_kernels_map_to_expected_backends() {
        let info = tiny_info();
        let fused = variant_kernels("fused", &info, true).unwrap();
        assert_eq!(fused.choice.tier, Tier::FusedBackward);
        assert!(fused.choice.is_fused());
        let eager = variant_kernels("eager", &info, true).unwrap();
        assert_eq!(eager.choice.tier, Tier::Eager);
        assert_eq!(eager.choice.backend.kind(), crate::kernels::BackendKind::Eager);
        assert!(variant_kernels("nope", &info, true).is_err());
    }

    fn set_f32(t: &mut Tensor, f: impl FnOnce(&mut Vec<f32>)) {
        match &mut t.data {
            crate::runtime::TensorData::F32(v) => f(v),
            _ => unreachable!("leaf is f32"),
        }
    }

    #[test]
    fn finite_difference_checks_adapter_gradients() {
        // Numerical gradient of the loss w.r.t. A/B/mag entries of layer
        // 0. The weight norm is DETACHED in the analytic gradient (the
        // DoRA/PEFT convention), so for A/B perturbations the numerical
        // probe rescales the magnitude by c'/c to hold g fixed — the
        // finite-difference analogue of the detachment (validated against
        // an f64 reference implementation of this model).
        let info = tiny_info();
        let leaves = init_leaves(&info, 3);
        let mut trainable = leaves.trainable.clone();
        // Move B off zero so every gradient path is active.
        {
            let mut rng = Rng::new(77);
            set_f32(&mut trainable[1], |b| {
                for x in b.iter_mut() {
                    *x = rng.normal() as f32 * 0.05;
                }
            });
        }
        let kernels = variant_kernels("fused", &info, true).unwrap();
        let mut corpus = crate::coordinator::data::MarkovCorpus::new(info.vocab, 3, 5);
        let tokens = corpus.block(1, info.train_batch, info.seq + 1);
        let (inputs, targets) = split_tokens(&tokens, info.train_batch, info.seq);

        let loss_with = |tr: &[Tensor]| -> f32 {
            let m = NativeModel::new(&info, &leaves.frozen, tr, kernels.clone()).unwrap();
            m.train_forward(&inputs, &targets).unwrap().loss
        };
        let layer0_norms = |tr: &[Tensor]| -> Vec<f32> {
            let mut tracker = AllocTracker::new();
            crate::dora::norm_cpu::factored_norm(
                leaves.frozen[1].as_f32().unwrap(),
                tr[0].as_f32().unwrap(),
                tr[1].as_f32().unwrap(),
                info.scale as f32,
                ModuleShape::new(info.d_model, info.d_model, info.rank),
                u64::MAX,
                &mut tracker,
            )
        };
        let model = NativeModel::new(&info, &leaves.frozen, &trainable, kernels.clone()).unwrap();
        let trace = model.train_forward(&inputs, &targets).unwrap();
        let grads = model.backward(&trace);
        let c0 = layer0_norms(&trainable);

        // Leaf 0 = layers.0.a, leaf 1 = layers.0.b, leaf 2 = layers.0.mag.
        for (leaf, gvec, idx) in [
            (0usize, &grads[0].a, 7usize),
            (1, &grads[0].b, 3),
            (2, &grads[0].mag, 5),
        ] {
            // eps large enough that the f32 forward's rounding noise
            // (~1e-6 absolute on the loss) stays well under the signal.
            let eps = 1e-2f32;
            let mut probes = Vec::new();
            for sign in [1.0f32, -1.0] {
                let mut t = trainable.clone();
                set_f32(&mut t[leaf], |v| v[idx] += sign * eps);
                if leaf < 2 {
                    // Detachment compensation: mag *= c'/c keeps g fixed.
                    let c1 = layer0_norms(&t);
                    set_f32(&mut t[2], |mag| {
                        for (m, (&n1, &n0)) in mag.iter_mut().zip(c1.iter().zip(&c0)) {
                            *m *= n1 / n0;
                        }
                    });
                }
                probes.push(loss_with(&t));
            }
            let num = (probes[0] - probes[1]) / (2.0 * eps);
            let ana = gvec[idx];
            assert!(
                (num - ana).abs() <= 2e-2 * ana.abs().max(0.05),
                "leaf {leaf} idx {idx}: numerical {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn merged_weights_match_composed_inference_on_tiny() {
        let info = tiny_info();
        let leaves = init_leaves(&info, 5);
        let mut trainable = leaves.trainable.clone();
        // Move B off zero so the merge actually folds a LoRA delta in.
        let mut rng = Rng::new(17);
        for l in 0..info.n_layers {
            set_f32(&mut trainable[3 * l + 1], |b| {
                for x in b.iter_mut() {
                    *x = rng.normal() as f32 * 0.08;
                }
            });
        }
        let params = AdapterParams { frozen: leaves.frozen.clone(), trainable };
        let merged =
            merge_adapter_params(&info, &params, AdapterVariant::Dora, Precision::F32).unwrap();
        assert_eq!(merged.layers.len(), info.n_layers);
        assert_eq!(merged.layers[0].shape, vec![info.d_model, info.d_model]);
        // The merge is deterministic (the hot-swap protocol relies on it).
        let again =
            merge_adapter_params(&info, &params, AdapterVariant::Dora, Precision::F32).unwrap();
        for (x, y) in merged.layers.iter().zip(&again.layers) {
            assert!(x.bitwise_eq(y));
        }

        let bs = info.train_batch;
        let seq = info.seq;
        let tokens: Vec<i32> = (0..bs * seq).map(|i| (i % info.vocab) as i32).collect();
        let kernels = kernels_for(crate::runtime::ops::Variant::Fused, &info, false).unwrap();
        let model =
            NativeModel::new(&info, &params.frozen, &params.trainable, kernels).unwrap();
        let composed = model.infer_logits(&tokens, bs, seq).unwrap();
        let fast = merged_infer_logits(&info, &merged, &tokens, bs, seq).unwrap();
        assert_eq!(fast.len(), composed.len());
        for (i, (&c, &m)) in composed.iter().zip(&fast).enumerate() {
            assert!(
                (c - m).abs() <= 1e-5 * c.abs().max(1.0),
                "logit {i}: composed {c} vs merged {m}"
            );
        }
        // Bad tokens error instead of panicking.
        assert!(merged_infer_logits(&info, &merged, &[-1], 1, 1).is_err());
        // Malformed params error out of the merge.
        assert!(merge_adapter_params(
            &info,
            &AdapterParams::default(),
            AdapterVariant::Dora,
            Precision::F32
        )
        .is_err());
    }

    #[test]
    fn variant_scales_follow_the_rank_stabilized_rule() {
        let info = tiny_info();
        let s = info.scale as f32;
        assert_eq!(variant_scale(AdapterVariant::Dora, &info), s);
        assert_eq!(variant_scale(AdapterVariant::Bora, &info), s);
        assert_eq!(
            variant_scale(AdapterVariant::RsLora, &info),
            s * (info.rank as f32).sqrt()
        );
    }

    #[test]
    fn all_variants_are_the_identity_at_init() {
        // With B = 0 the adapter contributes nothing: the rsLoRA scale
        // multiplies a zero LoRA branch (and drops out of the factored
        // row norm — the cross and Gram terms vanish with B), and BoRA's
        // column gain is numerator == denominator exactly. Every variant
        // must therefore reproduce the Dora logits BITWISE.
        let info = tiny_info();
        let leaves = init_leaves(&info, 21);
        let bs = info.train_batch;
        let seq = info.seq;
        let tokens: Vec<i32> = (0..bs * seq).map(|i| (i * 7 % info.vocab) as i32).collect();
        let mut logits = Vec::new();
        for adapter in AdapterVariant::ALL {
            let kernels = kernels_for(crate::runtime::ops::Variant::Fused, &info, false).unwrap();
            let model = NativeModel::new(&info, &leaves.frozen, &leaves.trainable, kernels)
                .unwrap()
                .with_adapter(adapter);
            logits.push(model.infer_logits(&tokens, bs, seq).unwrap());
        }
        for (v, l) in AdapterVariant::ALL.iter().zip(&logits).skip(1) {
            for (i, (&x, &y)) in logits[0].iter().zip(l).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{v:?} logit {i}: dora {x} vs {y}");
            }
        }
    }

    #[test]
    fn variant_merges_match_their_composed_inference() {
        // The Dora merged-parity test above pins the legacy path; this
        // one runs the SAME contract for the new variants, with B moved
        // off zero so the rsLoRA scale and the BoRA column gain both
        // bite (and the variants genuinely disagree with each other).
        let info = tiny_info();
        let leaves = init_leaves(&info, 29);
        let mut trainable = leaves.trainable.clone();
        let mut rng = Rng::new(23);
        for l in 0..info.n_layers {
            set_f32(&mut trainable[3 * l + 1], |b| {
                for x in b.iter_mut() {
                    *x = rng.normal() as f32 * 0.08;
                }
            });
        }
        let params = AdapterParams { frozen: leaves.frozen.clone(), trainable };
        let bs = info.train_batch;
        let seq = info.seq;
        let tokens: Vec<i32> = (0..bs * seq).map(|i| (i % info.vocab) as i32).collect();
        let mut per_variant = Vec::new();
        for adapter in [AdapterVariant::RsLora, AdapterVariant::Bora] {
            let merged =
                merge_adapter_params(&info, &params, adapter, Precision::F32).unwrap();
            let kernels = kernels_for(crate::runtime::ops::Variant::Fused, &info, false).unwrap();
            let model = NativeModel::new(&info, &params.frozen, &params.trainable, kernels)
                .unwrap()
                .with_adapter(adapter);
            let composed = model.infer_logits(&tokens, bs, seq).unwrap();
            let fast = merged_infer_logits(&info, &merged, &tokens, bs, seq).unwrap();
            for (i, (&c, &m)) in composed.iter().zip(&fast).enumerate() {
                assert!(
                    (c - m).abs() <= 1e-5 * c.abs().max(1.0),
                    "{adapter:?} logit {i}: composed {c} vs merged {m}"
                );
            }
            per_variant.push(composed);
        }
        // Off init the three variants are genuinely different models.
        let kernels = kernels_for(crate::runtime::ops::Variant::Fused, &info, false).unwrap();
        let dora = NativeModel::new(&info, &params.frozen, &params.trainable, kernels)
            .unwrap()
            .infer_logits(&tokens, bs, seq)
            .unwrap();
        for (v, l) in ["rslora", "bora"].iter().zip(&per_variant) {
            let diff = dora.iter().zip(l.iter()).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
            assert!(diff > 1e-4, "{v} should diverge from dora off init, max diff {diff}");
        }
    }

    #[test]
    fn bora_gradients_pass_the_finite_difference_probe() {
        // Finite-difference probe through the BoRA path. A/B
        // perturbations move the COLUMN norms too, and the analytic
        // gradient treats g_col as frozen — holding it fixed under a
        // probe would need a compensation knob the leaf layout doesn't
        // have. So probe the magnitude leaf, which g_col is independent
        // of: the probe is exact there and still exercises the scaled-
        // input trace end to end.
        let info = tiny_info();
        let leaves = init_leaves(&info, 13);
        let mut trainable = leaves.trainable.clone();
        {
            let mut rng = Rng::new(31);
            set_f32(&mut trainable[1], |b| {
                for x in b.iter_mut() {
                    *x = rng.normal() as f32 * 0.02;
                }
            });
        }
        let kernels = variant_kernels("fused", &info, true).unwrap();
        let mut corpus = crate::coordinator::data::MarkovCorpus::new(info.vocab, 3, 5);
        let tokens = corpus.block(1, info.train_batch, info.seq + 1);
        let (inputs, targets) = split_tokens(&tokens, info.train_batch, info.seq);
        let model = NativeModel::new(&info, &leaves.frozen, &trainable, kernels.clone())
            .unwrap()
            .with_adapter(AdapterVariant::Bora);
        let trace = model.train_forward(&inputs, &targets).unwrap();
        let grads = model.backward(&trace);

        // mag leaf: g_col does not depend on mag, so the probe is exact.
        let idx = 5;
        let eps = 1e-2f32;
        let mut probes = Vec::new();
        for sign in [1.0f32, -1.0] {
            let mut t = trainable.clone();
            set_f32(&mut t[2], |v| v[idx] += sign * eps);
            let m = NativeModel::new(&info, &leaves.frozen, &t, kernels.clone())
                .unwrap()
                .with_adapter(AdapterVariant::Bora);
            probes.push(m.train_forward(&inputs, &targets).unwrap().loss);
        }
        let num = (probes[0] - probes[1]) / (2.0 * eps);
        let ana = grads[0].mag[idx];
        assert!(
            (num - ana).abs() <= 2e-2 * ana.abs().max(0.05),
            "bora mag idx {idx}: numerical {num} vs analytic {ana}"
        );
    }

    #[test]
    fn sample_grads_are_batching_invariant_and_track_full_batch() {
        let info = tiny_info();
        let leaves = init_leaves(&info, 11);
        let mut trainable = leaves.trainable.clone();
        // Move B off zero so every gradient path is active.
        let mut rng = Rng::new(5);
        set_f32(&mut trainable[1], |b| {
            for x in b.iter_mut() {
                *x = rng.normal() as f32 * 0.05;
            }
        });
        let kernels = variant_kernels("fused", &info, true).unwrap();
        let model = NativeModel::new(&info, &leaves.frozen, &trainable, kernels).unwrap();
        let mut corpus = crate::coordinator::data::MarkovCorpus::new(info.vocab, 3, 6);
        let bs = info.train_batch;
        let seq1 = info.seq + 1;
        let tokens = corpus.block(1, bs, seq1);
        let total_rows = bs * info.seq;

        // The whole batch as one micro-batch, vs an uneven [3, 1] split
        // with the same effective-batch normalization: per-sample exports
        // must be BITWISE identical — the property the data-parallel
        // reduction's worker-count invariance rests on.
        let whole = model.loss_and_sample_grads(&tokens, bs, total_rows).unwrap();
        assert_eq!(whole.len(), bs);
        let cut = 3 * seq1;
        let first = model.loss_and_sample_grads(&tokens[..cut], 3, total_rows).unwrap();
        let second = model.loss_and_sample_grads(&tokens[cut..], 1, total_rows).unwrap();
        let split: Vec<_> = first.into_iter().chain(second).collect();
        assert_eq!(split.len(), bs);
        for (smp, (w, s)) in whole.iter().zip(&split).enumerate() {
            assert_eq!(w.0.to_bits(), s.0.to_bits(), "sample {smp} loss sum");
            for (leaf, (gw, gs)) in w.1.iter().zip(&s.1).enumerate() {
                assert_eq!(gw.len(), gs.len());
                for (i, (x, y)) in gw.iter().zip(gs).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "sample {smp} leaf {leaf} elem {i}: {x} vs {y}"
                    );
                }
            }
        }

        // Reduced over all samples (f64, sample order), the result tracks
        // the legacy full-batch gradient to reassociation noise.
        let (legacy_loss, legacy) = model.loss_and_grads(&tokens, bs).unwrap();
        let mut loss_sum = 0f64;
        let mut acc: Vec<Vec<f64>> = legacy.iter().map(|g| vec![0f64; g.len()]).collect();
        for (ls, grads) in &whole {
            loss_sum += ls;
            for (a, g) in acc.iter_mut().zip(grads) {
                for (ai, &gi) in a.iter_mut().zip(g) {
                    *ai += gi as f64;
                }
            }
        }
        let reduced_loss = (loss_sum / total_rows as f64) as f32;
        assert!(
            (reduced_loss - legacy_loss).abs() < 1e-6,
            "loss: reduced {reduced_loss} vs legacy {legacy_loss}"
        );
        for (leaf, (a, g)) in acc.iter().zip(&legacy).enumerate() {
            for (i, (&r, &l)) in a.iter().zip(g).enumerate() {
                let r = r as f32;
                assert!(
                    (r - l).abs() <= 1e-5 * l.abs().max(1e-4),
                    "leaf {leaf} elem {i}: reduced {r} vs legacy {l}"
                );
            }
        }

        // A shard claiming a smaller effective batch than itself errors.
        assert!(model.loss_and_sample_grads(&tokens, bs, info.seq).is_err());
    }

    #[test]
    fn eager_and_fused_losses_agree_on_one_step() {
        let info = tiny_info();
        let leaves = init_leaves(&info, 9);
        let mut corpus = crate::coordinator::data::MarkovCorpus::new(info.vocab, 3, 9);
        let tokens = corpus.block(1, info.train_batch, info.seq + 1);
        let (inputs, targets) = split_tokens(&tokens, info.train_batch, info.seq);
        let mut losses = Vec::new();
        for variant in ["eager", "fused"] {
            let kernels = variant_kernels(variant, &info, true).unwrap();
            let m = NativeModel::new(&info, &leaves.frozen, &leaves.trainable, kernels).unwrap();
            losses.push(m.train_forward(&inputs, &targets).unwrap().loss);
        }
        assert!(
            (losses[0] - losses[1]).abs() < 1e-5,
            "eager {} vs fused {}",
            losses[0],
            losses[1]
        );
    }
}
