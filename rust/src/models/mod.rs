//! VLM architecture registry — the six 8-32B models of the paper's
//! model-level benchmarks (§5.1, Appendix D "Model identifiers"), reduced
//! to what the cost and memory models need: the per-layer inventory of
//! adapted projections with their shapes.
//!
//! Shapes follow the public configs of each model family (hidden size,
//! GQA head layout, MLP intermediate size, layer count). The LLM decoder
//! carries the seven adapted projections per layer (q,k,v,o,gate,up,down);
//! vision towers are not adapted (PEFT's default target modules), matching
//! the paper's setup.

pub mod forward;

use crate::dora::config::ModuleShape;

/// One adapted projection kind within a decoder layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Proj {
    Q,
    K,
    V,
    O,
    Gate,
    Up,
    Down,
}

pub const PROJS: [Proj; 7] = [Proj::Q, Proj::K, Proj::V, Proj::O, Proj::Gate, Proj::Up, Proj::Down];

impl Proj {
    pub fn name(self) -> &'static str {
        match self {
            Proj::Q => "q_proj",
            Proj::K => "k_proj",
            Proj::V => "v_proj",
            Proj::O => "o_proj",
            Proj::Gate => "gate_proj",
            Proj::Up => "up_proj",
            Proj::Down => "down_proj",
        }
    }
}

/// Decoder architecture of one model.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Paper's display name (Tables 4/5/8).
    pub name: &'static str,
    /// Hugging Face model id (Appendix D).
    pub hf_id: &'static str,
    pub hidden: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub intermediate: usize,
    pub vocab: usize,
    /// Approximate total parameters (for FLOP budgeting), billions.
    pub params_b: f64,
}

impl ModelSpec {
    /// Weight shape of one adapted projection at adapter rank `r`.
    pub fn proj_shape(&self, p: Proj, r: usize) -> ModuleShape {
        let h = self.hidden;
        let q_dim = self.n_heads * self.head_dim;
        let kv_dim = self.n_kv_heads * self.head_dim;
        let f = self.intermediate;
        match p {
            Proj::Q => ModuleShape::new(q_dim, h, r),
            Proj::K | Proj::V => ModuleShape::new(kv_dim, h, r),
            Proj::O => ModuleShape::new(h, q_dim, r),
            Proj::Gate | Proj::Up => ModuleShape::new(f, h, r),
            Proj::Down => ModuleShape::new(h, f, r),
        }
    }

    /// Full adapted-module inventory: (projection, shape, count) with
    /// count = n_layers for each of the seven kinds.
    pub fn inventory(&self, r: usize) -> Vec<(Proj, ModuleShape, usize)> {
        PROJS
            .iter()
            .map(|&p| (p, self.proj_shape(p, r), self.n_layers))
            .collect()
    }

    /// Total number of adapted modules (the paper's "hundreds of adapted
    /// modules": 7 per layer).
    pub fn n_adapted_modules(&self) -> usize {
        7 * self.n_layers
    }

    /// Dense FLOPs of one decoder forward pass over `tokens` tokens
    /// (projections + attention + MLP; 2*params*tokens approximation for
    /// the matmul-dominated part, plus attention score/context terms).
    pub fn forward_flops(&self, tokens: usize, seq: usize) -> f64 {
        let proj_params: usize = self
            .inventory(1)
            .iter()
            .map(|(_, s, n)| s.d_out * s.d_in * n)
            .sum();
        let embed = self.vocab * self.hidden; // tied head
        let matmul_flops = 2.0 * (proj_params + embed) as f64 * tokens as f64;
        // attention: 2 * tokens * seq * q_dim (scores) * 2 (scores+context)
        let attn = 4.0
            * tokens as f64
            * seq as f64
            * (self.n_heads * self.head_dim * self.n_layers) as f64;
        matmul_flops + attn
    }

    /// Parameter bytes at bf16 (weights resident on device).
    pub fn weight_bytes(&self) -> u64 {
        (self.params_b * 1e9 * 2.0) as u64
    }
}

/// The six models of Table 4 (shapes from the public configs; Qwen3.5-27B
/// is pre-release at paper time — dimensioned per its reported class).
pub const MODELS: [ModelSpec; 6] = [
    ModelSpec {
        name: "Qwen2.5-VL-32B",
        hf_id: "Qwen/Qwen2.5-VL-32B-Instruct",
        hidden: 5120,
        n_layers: 64,
        n_heads: 40,
        n_kv_heads: 8,
        head_dim: 128,
        intermediate: 27648,
        vocab: 152064,
        params_b: 32.5,
    },
    ModelSpec {
        name: "Qwen3-VL-32B",
        hf_id: "Qwen/Qwen3-VL-32B-Instruct",
        hidden: 5120,
        n_layers: 64,
        n_heads: 64,
        n_kv_heads: 8,
        head_dim: 128,
        intermediate: 25600,
        vocab: 151936,
        params_b: 32.8,
    },
    ModelSpec {
        name: "Qwen3.5-27B",
        hf_id: "Qwen/Qwen3.5-27B",
        hidden: 5120,
        n_layers: 48,
        n_heads: 40,
        n_kv_heads: 8,
        head_dim: 128,
        intermediate: 25600,
        vocab: 151936,
        params_b: 27.0,
    },
    ModelSpec {
        name: "Gemma3-27B",
        hf_id: "google/gemma-3-27b-it",
        hidden: 5376,
        n_layers: 62,
        n_heads: 32,
        n_kv_heads: 16,
        head_dim: 128,
        intermediate: 21504,
        vocab: 262144,
        params_b: 27.2,
    },
    ModelSpec {
        name: "Mistral-Sm-24B",
        hf_id: "unsloth/Mistral-Small-3.2-24B-Instruct-2506",
        hidden: 5120,
        n_layers: 40,
        n_heads: 32,
        n_kv_heads: 8,
        head_dim: 128,
        intermediate: 32768,
        vocab: 131072,
        params_b: 23.6,
    },
    ModelSpec {
        name: "Qwen3-VL-8B",
        hf_id: "Qwen/Qwen3-VL-8B-Instruct",
        hidden: 4096,
        n_layers: 36,
        n_heads: 32,
        n_kv_heads: 8,
        head_dim: 128,
        intermediate: 12288,
        vocab: 151936,
        params_b: 8.8,
    },
];

/// Case-insensitive lookup by paper name or HF id fragment.
pub fn find(name: &str) -> Option<&'static ModelSpec> {
    let needle = name.to_lowercase();
    MODELS
        .iter()
        .find(|m| {
            m.name.to_lowercase().contains(&needle) || m.hf_id.to_lowercase().contains(&needle)
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_models() {
        assert_eq!(MODELS.len(), 6);
        assert!(find("mistral").is_some());
        assert!(find("qwen3-vl-8b").is_some());
        assert!(find("llama").is_none());
    }

    #[test]
    fn inventory_is_seven_kinds_per_layer() {
        for m in &MODELS {
            let inv = m.inventory(384);
            assert_eq!(inv.len(), 7);
            let total: usize = inv.iter().map(|(_, _, n)| n).sum();
            assert_eq!(total, m.n_adapted_modules());
        }
    }

    #[test]
    fn kv_projections_are_narrow() {
        // The §4 dispatch claim: KV projections (d_out as low as 512-2048)
        // fall below the d_out >= 2048 crossover while q/o/mlp sit above.
        for m in &MODELS {
            let kv = m.proj_shape(Proj::K, 384);
            let gate = m.proj_shape(Proj::Gate, 384);
            assert!(kv.d_out <= 2048, "{}: kv {}", m.name, kv.d_out);
            assert!(gate.d_out > 2048, "{}: gate {}", m.name, gate.d_out);
        }
    }

    #[test]
    fn flops_scale_with_params() {
        let big = find("Qwen2.5-VL-32B").unwrap();
        let small = find("Qwen3-VL-8B").unwrap();
        let fb = big.forward_flops(4096, 4096);
        let fs = small.forward_flops(4096, 4096);
        let ratio = fb / fs;
        assert!((2.0..6.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn proj_shapes_match_architecture() {
        let m = find("mistral").unwrap();
        assert_eq!(m.proj_shape(Proj::Q, 64), ModuleShape::new(4096, 5120, 64));
        assert_eq!(m.proj_shape(Proj::K, 64), ModuleShape::new(1024, 5120, 64));
        assert_eq!(m.proj_shape(Proj::Down, 64), ModuleShape::new(5120, 32768, 64));
    }
}
