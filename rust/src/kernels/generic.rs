//! Dtype-generic element abstraction + the shared compose loop cores.
//!
//! Every backend (eager, fused, parallel-tiled) executes the SAME
//! per-element arithmetic, monomorphized over an [`Elem`] marker that
//! injects the storage dtype's rounding after each operation:
//!
//! * [`F32`]      — identity rounding: the loops compile to exactly the
//!   arithmetic the flat f32 kernels always ran (bitwise-preserving).
//! * [`SoftBf16`] / [`SoftF16`] — software round-to-nearest-even after
//!   every op (`numerics::half`), so the paper's near-unity cancellation
//!   regime (§3.1) is exercisable end-to-end in the precision the paper
//!   ships, without vendoring a half-float crate.
//!
//! Because all backends share these cores, backend parity is *structural*:
//! eager vs fused vs parallel-tiled differ only in pass structure and
//! scheduling, never in per-element evaluation order — which is what makes
//! the §3.1 "bitwise parity across composition paths" claim hold on CPU
//! in f32 and bf16 alike.

use crate::numerics::half::{round_bf16, round_f16, Dtype};

/// Compile-time dtype marker: quantize an f32 intermediate to the storage
/// precision. `q` is the identity for f32, so the f32 instantiations are
/// exactly the historical flat kernels.
///
/// ```
/// use dorafactors::kernels::generic::{Elem, F32, SoftBf16};
///
/// // f32 is the identity; soft-bf16 rounds after every op, so the
/// // §3.1 collapse zone (g = 1 + 1e-3 rounds to exactly 1) appears in
/// // the monomorphized loops with no separate bf16 code path.
/// assert_eq!(F32::q(1.0 + 1e-3), 1.0 + 1e-3);
/// assert_eq!(SoftBf16::q(1.0 + 1e-3), 1.0);
/// ```
pub trait Elem: Send + Sync + 'static {
    /// The runtime [`Dtype`] this marker monomorphizes.
    const DTYPE: Dtype;
    /// Quantize one f32 intermediate to the storage precision.
    fn q(x: f32) -> f32;
}

/// Native f32 storage (no rounding).
pub enum F32 {}

/// Software-emulated bfloat16 storage (RNE after every op).
pub enum SoftBf16 {}

/// Software-emulated IEEE fp16 storage (RNE after every op).
pub enum SoftF16 {}

impl Elem for F32 {
    const DTYPE: Dtype = Dtype::F32;
    #[inline(always)]
    fn q(x: f32) -> f32 {
        x
    }
}

impl Elem for SoftBf16 {
    const DTYPE: Dtype = Dtype::Bf16;
    #[inline(always)]
    fn q(x: f32) -> f32 {
        round_bf16(x)
    }
}

impl Elem for SoftF16 {
    const DTYPE: Dtype = Dtype::F16;
    #[inline(always)]
    fn q(x: f32) -> f32 {
        round_f16(x)
    }
}

/// Dispatch a runtime [`Dtype`] to a monomorphized `Elem` instantiation.
macro_rules! with_elem {
    ($dt:expr, $E:ident, $body:expr) => {
        match $dt {
            $crate::numerics::half::Dtype::F32 => {
                type $E = $crate::kernels::generic::F32;
                $body
            }
            $crate::numerics::half::Dtype::Bf16 => {
                type $E = $crate::kernels::generic::SoftBf16;
                $body
            }
            $crate::numerics::half::Dtype::F16 => {
                type $E = $crate::kernels::generic::SoftF16;
                $body
            }
        }
    };
}
pub(crate) use with_elem;

// ---------------------------------------------------------------------------
// Fused (single-pass) cores. All operate on whole rows: callers hand in any
// contiguous row range, which is how the tiled backend reuses them.
// ---------------------------------------------------------------------------

/// Single-pass compose over `out.len() / d` rows:
/// `delta = (g-1)*base + g*(s*lora)` in the canonical order (`s*lora`
/// first, then `g*(.)` — §3.1).
#[inline]
pub(crate) fn forward_rows<E: Elem>(
    base: &[f32],
    lora: &[f32],
    g: &[f32],
    s: f32,
    d: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(base.len(), out.len());
    debug_assert_eq!(lora.len(), out.len());
    for ((orow, brow), lrow) in out
        .chunks_exact_mut(d)
        .zip(base.chunks_exact(d))
        .zip(lora.chunks_exact(d))
    {
        for j in 0..d {
            let t1 = E::q(s * lrow[j]);
            let t2 = E::q(g[j] * t1);
            let t3 = E::q(E::q(g[j] - 1.0) * brow[j]);
            orow[j] = E::q(t3 + t2);
        }
    }
}

/// Tier-1 dual-output compose: one pass, two outputs
/// (`delta` + `inner = s*lora + base`, saved for the backward).
#[inline]
pub(crate) fn forward_dual_rows<E: Elem>(
    base: &[f32],
    lora: &[f32],
    g: &[f32],
    s: f32,
    d: usize,
    delta: &mut [f32],
    inner: &mut [f32],
) {
    for (((orow, irow), brow), lrow) in delta
        .chunks_exact_mut(d)
        .zip(inner.chunks_exact_mut(d))
        .zip(base.chunks_exact(d))
        .zip(lora.chunks_exact(d))
    {
        for j in 0..d {
            let sl = E::q(s * lrow[j]);
            let t2 = E::q(g[j] * sl);
            let t3 = E::q(E::q(g[j] - 1.0) * brow[j]);
            orow[j] = E::q(t3 + t2);
            irow[j] = E::q(sl + brow[j]);
        }
    }
}

/// Fused backward: one pass over `d_delta`, two outputs.
#[inline]
pub(crate) fn backward_rows<E: Elem>(
    d_delta: &[f32],
    g: &[f32],
    s: f32,
    d: usize,
    d_lora: &mut [f32],
    d_base: &mut [f32],
) {
    for ((dlrow, dbrow), ddrow) in d_lora
        .chunks_exact_mut(d)
        .zip(d_base.chunks_exact_mut(d))
        .zip(d_delta.chunks_exact(d))
    {
        for j in 0..d {
            let dd = ddrow[j];
            dlrow[j] = E::q(g[j] * E::q(s * dd));
            dbrow[j] = E::q(E::q(g[j] - 1.0) * dd);
        }
    }
}

// ---------------------------------------------------------------------------
// Eager (multi-pass) cores: the op-by-op chain with materialized
// temporaries, mirroring the separate CUDA kernels of the eager path.
// ---------------------------------------------------------------------------

/// The 4-pass eager chain into preallocated temporaries. Bitwise identical
/// to [`forward_rows`] per dtype (same per-element op sequence).
pub(crate) fn eager_chain<E: Elem>(
    base: &[f32],
    lora: &[f32],
    g: &[f32],
    s: f32,
    d: usize,
    t1: &mut [f32],
    t2: &mut [f32],
    t3: &mut [f32],
    delta: &mut [f32],
) {
    // Pass 1: t1 = s * lora.
    for (t, &l) in t1.iter_mut().zip(lora) {
        *t = E::q(s * l);
    }
    // Pass 2: t2 = g * t1 (g broadcast along rows).
    for (t2row, t1row) in t2.chunks_exact_mut(d).zip(t1.chunks_exact(d)) {
        for j in 0..d {
            t2row[j] = E::q(g[j] * t1row[j]);
        }
    }
    // Pass 3: t3 = (g - 1) * base.
    for (t3row, brow) in t3.chunks_exact_mut(d).zip(base.chunks_exact(d)) {
        for j in 0..d {
            t3row[j] = E::q(E::q(g[j] - 1.0) * brow[j]);
        }
    }
    // Pass 4: delta = t3 + t2.
    for ((o, &x), &y) in delta.iter_mut().zip(t3.iter()).zip(t2.iter()) {
        *o = E::q(x + y);
    }
}

/// Eager backward: two separate passes (two kernels).
pub(crate) fn backward_eager_rows<E: Elem>(
    d_delta: &[f32],
    g: &[f32],
    s: f32,
    d: usize,
    d_lora: &mut [f32],
    d_base: &mut [f32],
) {
    for (dlrow, ddrow) in d_lora.chunks_exact_mut(d).zip(d_delta.chunks_exact(d)) {
        for j in 0..d {
            dlrow[j] = E::q(g[j] * E::q(s * ddrow[j]));
        }
    }
    for (dbrow, ddrow) in d_base.chunks_exact_mut(d).zip(d_delta.chunks_exact(d)) {
        for j in 0..d {
            dbrow[j] = E::q(E::q(g[j] - 1.0) * ddrow[j]);
        }
    }
}

// ---------------------------------------------------------------------------
// d_mag reduction cores (dtype-independent: deterministic f64 accumulation
// in fixed order, §3.2 — never atomics).
// ---------------------------------------------------------------------------

/// Row-block size of the two-stage d_mag reduction (stage-1 partials are
/// private per block; stage 2 reduces blocks in fixed order).
pub(crate) const DMAG_ROWS_PER_BLOCK: usize = 32;

/// Sequential deterministic d_mag: `d_g[j] = sum_rows d_delta * inner`.
pub(crate) fn dmag(d_delta: &[f32], inner: &[f32], rows: usize, d: usize) -> Vec<f32> {
    let mut d_g = vec![0f64; d];
    for row in 0..rows {
        let o = row * d;
        for j in 0..d {
            d_g[j] += d_delta[o + j] as f64 * inner[o + j] as f64;
        }
    }
    d_g.into_iter().map(|x| x as f32).collect()
}

/// Stage 1 of the fused-dmag backward for one row block: writes d_lora and
/// d_base for the block and accumulates the block's f64 d_mag partials.
#[inline]
pub(crate) fn backward_dmag_block<E: Elem>(
    d_delta: &[f32],
    inner: &[f32],
    g: &[f32],
    s: f32,
    d: usize,
    d_lora: &mut [f32],
    d_base: &mut [f32],
    part: &mut [f64],
) {
    debug_assert_eq!(part.len(), d);
    for (((dlrow, dbrow), ddrow), irow) in d_lora
        .chunks_exact_mut(d)
        .zip(d_base.chunks_exact_mut(d))
        .zip(d_delta.chunks_exact(d))
        .zip(inner.chunks_exact(d))
    {
        for j in 0..d {
            let dd = ddrow[j];
            dlrow[j] = E::q(g[j] * E::q(s * dd));
            dbrow[j] = E::q(E::q(g[j] - 1.0) * dd);
            part[j] += dd as f64 * irow[j] as f64;
        }
    }
}

/// Stage 2: reduce per-block partials in fixed block order.
pub(crate) fn dmag_reduce_partials(partials: &[f64], n_blocks: usize, d: usize) -> Vec<f32> {
    let mut d_g = vec![0f64; d];
    for blk in 0..n_blocks {
        let part = &partials[blk * d..(blk + 1) * d];
        for j in 0..d {
            d_g[j] += part[j];
        }
    }
    d_g.into_iter().map(|x| x as f32).collect()
}
