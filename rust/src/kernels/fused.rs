//! `FusedCpu`: the single-pass composition path as a registry backend.
//!
//! One pass for the forward (Tier 2), one pass with two outputs for the
//! Tier-1 dual forward and for the backward pair, and the KernelAgent
//! two-stage fused-d_mag backward (paper §7) that folds the d_mag partial
//! reduction into the backward pass.

use crate::dora::config::{ActShape, ModuleShape};
use crate::dora::norm_cpu::AllocTracker;
use crate::kernels::generic::{self, with_elem, DMAG_ROWS_PER_BLOCK};
use crate::kernels::norm;
use crate::kernels::{BackendKind, ComposeKernel, NormEngine};
use crate::numerics::half::Dtype;

/// The fused (single-pass) CPU backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct FusedCpu;

impl ComposeKernel for FusedCpu {
    fn name(&self) -> &'static str {
        "fused-cpu"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Fused
    }

    fn forward(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        delta: &mut [f32],
    ) {
        with_elem!(dt, E, generic::forward_rows::<E>(base, lora, g, s, act.d_out, delta));
    }

    fn forward_dual(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        delta: &mut [f32],
        inner: &mut [f32],
    ) {
        with_elem!(dt, E, {
            generic::forward_dual_rows::<E>(base, lora, g, s, act.d_out, delta, inner)
        });
    }

    fn backward(
        &self,
        d_delta: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        d_lora: &mut [f32],
        d_base: &mut [f32],
    ) {
        with_elem!(dt, E, {
            generic::backward_rows::<E>(d_delta, g, s, act.d_out, d_lora, d_base)
        });
    }

    fn backward_with_dmag(
        &self,
        d_delta: &[f32],
        inner: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        d_lora: &mut [f32],
        d_base: &mut [f32],
    ) -> Vec<f32> {
        // Two-stage deterministic fusion: blocks of rows accumulate
        // private f64 partials; stage 2 reduces in fixed block order.
        let d = act.d_out;
        let block = DMAG_ROWS_PER_BLOCK;
        let n_blocks = act.rows.div_ceil(block);
        let mut partials = vec![0f64; n_blocks * d];
        with_elem!(dt, E, {
            for blk in 0..n_blocks {
                let r0 = blk * block;
                let r1 = (r0 + block).min(act.rows);
                generic::backward_dmag_block::<E>(
                    &d_delta[r0 * d..r1 * d],
                    &inner[r0 * d..r1 * d],
                    g,
                    s,
                    d,
                    &mut d_lora[r0 * d..r1 * d],
                    &mut d_base[r0 * d..r1 * d],
                    &mut partials[blk * d..(blk + 1) * d],
                );
            }
        });
        generic::dmag_reduce_partials(&partials, n_blocks, d)
    }
}

impl NormEngine for FusedCpu {
    fn name(&self) -> &'static str {
        "fused-cpu"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Fused
    }

    fn weight_norm(
        &self,
        w: &[f32],
        a: &[f32],
        b: &[f32],
        s: f32,
        m: ModuleShape,
        budget: u64,
        dt: Dtype,
        tracker: &mut AllocTracker,
    ) -> Vec<f32> {
        with_elem!(dt, E, norm::factored_norm_seq::<E>(w, a, b, s, m, budget, tracker))
    }

    fn weight_colnorm(
        &self,
        w: &[f32],
        a: &[f32],
        b: &[f32],
        s: f32,
        m: ModuleShape,
        budget: u64,
        dt: Dtype,
        tracker: &mut AllocTracker,
    ) -> Vec<f32> {
        with_elem!(dt, E, norm::factored_colnorm_seq::<E>(w, a, b, s, m, budget, tracker))
    }
}
