//! Unified kernel-backend layer: one dispatch surface over the
//! compose/norm hot paths.
//!
//! The flat f32 free functions (`dora::compose_cpu`, `dora::norm_cpu`)
//! grew call sites in the dispatcher, the coordinator, the benches, and
//! the report generator, which made every new execution strategy (threads,
//! bf16, future PJRT/GPU offload, EDoRA-style variant kernels) an
//! every-caller change. This module is the extensible replacement:
//!
//! * [`ComposeKernel`] / [`NormEngine`] — the backend traits (forward,
//!   dual-output, backward, deterministic d_mag; weight norm with
//!   [`AllocTracker`] accounting), dtype-generic over f32 and the
//!   software half formats via [`Dtype`].
//! * [`EagerCpu`], [`FusedCpu`], [`ParallelTiledCpu`] — the concrete
//!   backends: the 4-pass chain, the single-pass fused kernels, and
//!   row-tiled fused kernels on a scoped thread pool.
//! * [`KernelRegistry`] — owns the available backends; `select` combines
//!   the three-tier dispatch decision (`dispatch::select_tier`) with a
//!   backend choice, returning a [`KernelChoice`] handle instead of a
//!   bare enum.
//!
//! The flat functions survive as thin wrappers over the same generic
//! cores, so their f32 results are bitwise unchanged.
//!
//! [`AllocTracker`]: crate::dora::norm_cpu::AllocTracker

pub mod eager;
pub mod fused;
pub mod gemm;
pub mod generic;
pub(crate) mod norm;
pub mod tiled;

use std::sync::{Arc, OnceLock};

use crate::dispatch::{self, ComposeCtx, DispatchEnv, Tier};
use crate::dora::config::{ActShape, ModuleShape};
use crate::dora::norm_cpu::AllocTracker;
use crate::numerics::half::Dtype;

pub use eager::EagerCpu;
pub use fused::FusedCpu;
pub use generic::{Elem, SoftBf16, SoftF16, F32};
pub use tiled::{ParallelTiledCpu, DEFAULT_TILE_ROWS};

/// Execution strategy of a backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Op-by-op multi-pass chain (Tier-3 fallback, correctness baseline).
    Eager,
    /// Single-pass fused kernels.
    Fused,
    /// Fused kernels over row-tiles on a scoped thread pool.
    ParallelTiled,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Eager => "eager",
            BackendKind::Fused => "fused",
            BackendKind::ParallelTiled => "parallel-tiled",
        }
    }
}

/// A compose backend: the three kernel entry points of the paper's design
/// (forward, Tier-1 dual-output forward, backward) plus the deterministic
/// d_mag reduction. `dt` selects the storage precision; intermediates are
/// rounded to it after every op (identity for [`Dtype::F32`]).
#[allow(clippy::too_many_arguments)]
pub trait ComposeKernel: Send + Sync {
    fn name(&self) -> &'static str;
    fn kind(&self) -> BackendKind;

    /// Worker threads this backend uses (1 for sequential backends).
    fn parallelism(&self) -> usize {
        1
    }

    /// `delta = (g-1)*base + g*(s*lora)`, canonical order (§3.1).
    fn forward(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        delta: &mut [f32],
    );

    /// Tier-1 dual output: `delta` plus `inner = s*lora + base`.
    fn forward_dual(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        delta: &mut [f32],
        inner: &mut [f32],
    );

    /// Backward pair: `d_lora = g*(s*d_delta)`, `d_base = (g-1)*d_delta`.
    fn backward(
        &self,
        d_delta: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        d_lora: &mut [f32],
        d_base: &mut [f32],
    );

    /// Deterministic d_mag direction gradient (f64 row reduction in fixed
    /// order — never atomics, §3.2).
    fn dmag(&self, d_delta: &[f32], inner: &[f32], act: ActShape) -> Vec<f32> {
        generic::dmag(d_delta, inner, act.rows, act.d_out)
    }

    /// Backward with the d_mag reduction folded in (KernelAgent two-stage
    /// strategy, §7). Default: separate backward + reduction passes.
    fn backward_with_dmag(
        &self,
        d_delta: &[f32],
        inner: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        d_lora: &mut [f32],
        d_base: &mut [f32],
    ) -> Vec<f32> {
        self.backward(d_delta, g, s, act, dt, d_lora, d_base);
        self.dmag(d_delta, inner, act)
    }

    /// Allocating convenience wrapper around [`ComposeKernel::forward`].
    fn forward_alloc(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
    ) -> Vec<f32> {
        let mut delta = vec![0f32; act.elems()];
        self.forward(base, lora, g, s, act, dt, &mut delta);
        delta
    }
}

/// A weight-norm backend: row-wise `||W + s*B@A||` (Algorithm 1) with
/// exact transient-allocation accounting through an [`AllocTracker`].
#[allow(clippy::too_many_arguments)]
pub trait NormEngine: Send + Sync {
    fn name(&self) -> &'static str;
    fn kind(&self) -> BackendKind;

    fn weight_norm(
        &self,
        w: &[f32],
        a: &[f32],
        b: &[f32],
        s: f32,
        m: ModuleShape,
        budget: u64,
        dt: Dtype,
        tracker: &mut AllocTracker,
    ) -> Vec<f32>;

    /// Column-wise `||W + s*B@A||` (Algorithm 1 transposed) — the BoRA
    /// column-magnitude reduction, `[d_in]` output.
    fn weight_colnorm(
        &self,
        w: &[f32],
        a: &[f32],
        b: &[f32],
        s: f32,
        m: ModuleShape,
        budget: u64,
        dt: Dtype,
        tracker: &mut AllocTracker,
    ) -> Vec<f32>;
}

/// Approximate last-level-cache size used for the parallel-backend
/// crossover: below this working set a single core is already
/// memory-latency-bound and thread fan-out only adds overhead.
pub const LLC_BYTES: u64 = 32 << 20;

/// Bytes the fused compose streams touch (3 activation-sized f32 arrays).
pub fn compose_working_set_bytes(act: ActShape) -> u64 {
    3 * act.elems() as u64 * 4
}

/// The dispatch result: the selected tier plus a runnable backend handle.
#[derive(Clone)]
pub struct KernelChoice {
    pub tier: Tier,
    pub backend: Arc<dyn ComposeKernel>,
}

impl KernelChoice {
    /// Did dispatch pick a fused tier (1 or 2)?
    pub fn is_fused(&self) -> bool {
        self.tier != Tier::Eager
    }
}

/// Owns the available backends and maps dispatch decisions onto them.
pub struct KernelRegistry {
    compose: Vec<Arc<dyn ComposeKernel>>,
    norms: Vec<Arc<dyn NormEngine>>,
}

impl KernelRegistry {
    /// The standard CPU backend set; `threads` sizes the parallel backend
    /// (0 = all cores).
    pub fn with_defaults(threads: usize) -> KernelRegistry {
        let eager = Arc::new(EagerCpu);
        let fused = Arc::new(FusedCpu);
        let tiled = Arc::new(ParallelTiledCpu::new(threads));
        KernelRegistry {
            compose: vec![
                eager.clone() as Arc<dyn ComposeKernel>,
                fused.clone() as Arc<dyn ComposeKernel>,
                tiled as Arc<dyn ComposeKernel>,
            ],
            norms: vec![
                eager as Arc<dyn NormEngine>,
                fused as Arc<dyn NormEngine>,
                Arc::new(ParallelTiledCpu::new(threads)) as Arc<dyn NormEngine>,
            ],
        }
    }

    pub fn compose_backends(&self) -> &[Arc<dyn ComposeKernel>] {
        &self.compose
    }

    pub fn norm_engines(&self) -> &[Arc<dyn NormEngine>] {
        &self.norms
    }

    /// Backend handle by kind (the registry always carries all kinds).
    pub fn compose(&self, kind: BackendKind) -> Arc<dyn ComposeKernel> {
        self.compose
            .iter()
            .find(|b| b.kind() == kind)
            .expect("registry carries every BackendKind")
            .clone()
    }

    pub fn norm(&self, kind: BackendKind) -> Arc<dyn NormEngine> {
        self.norms
            .iter()
            .find(|b| b.kind() == kind)
            .expect("registry carries every BackendKind")
            .clone()
    }

    /// The norm engine of the same backend family as a compose choice:
    /// the factored engines (sequential / tiled) for the fused backends,
    /// the dense B@A baseline for eager — so a caller driving a whole
    /// model (e.g. the native execution engine) gets a numerically
    /// consistent compose + norm pair from one dispatch decision.
    pub fn norm_for(&self, choice: &KernelChoice) -> Arc<dyn NormEngine> {
        self.norm(choice.backend.kind())
    }

    /// The dispatch surface: combine the three-tier decision (paper §4,
    /// Figure 2) with a backend choice. Fused tiers run the parallel
    /// backend when BOTH the caller's env and the registered backend
    /// actually have threads (so selection never names a hot path the
    /// backend won't execute) and the working set exceeds LLC; Tier 3
    /// runs the eager chain.
    pub fn select(&self, env: &DispatchEnv, ctx: &ComposeCtx) -> KernelChoice {
        let tier = dispatch::select_tier(env, ctx);
        let kind = match tier {
            Tier::Eager => BackendKind::Eager,
            Tier::FusedForward | Tier::FusedBackward => {
                let tiled_workers = self.compose(BackendKind::ParallelTiled).parallelism();
                if env.threads > 1
                    && tiled_workers > 1
                    && compose_working_set_bytes(ctx.act) > LLC_BYTES
                {
                    BackendKind::ParallelTiled
                } else {
                    BackendKind::Fused
                }
            }
        };
        KernelChoice { tier, backend: self.compose(kind) }
    }
}

static REGISTRY: OnceLock<KernelRegistry> = OnceLock::new();

/// The process-wide registry, initialized once from the environment
/// (`DORA_THREADS` sizes the parallel backend).
pub fn registry() -> &'static KernelRegistry {
    REGISTRY.get_or_init(|| KernelRegistry::with_defaults(DispatchEnv::from_env().threads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, prop_assert};
    use crate::util::rng::Rng;

    fn backends() -> Vec<Box<dyn ComposeKernel>> {
        vec![
            Box::new(EagerCpu),
            Box::new(FusedCpu),
            // Tiny tiles + more workers than tiles: exercises uneven
            // tails and the worker-clamp path.
            Box::new(ParallelTiledCpu::with_tile(4, 3)),
        ]
    }

    fn inputs(seed: u64, act: ActShape, dt: Dtype) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let q = |v: Vec<f32>| v.into_iter().map(|x| dt.quantize(x)).collect::<Vec<f32>>();
        let base = q(rng.normal_vec_f32(act.elems(), 1.0));
        let lora = q(rng.normal_vec_f32(act.elems(), 0.3));
        let g: Vec<f32> = (0..act.d_out)
            .map(|_| dt.quantize(1.0 + rng.normal() as f32 * 0.002))
            .collect();
        (base, lora, g)
    }

    /// Signed integer key over the bf16 bit pattern: adjacent
    /// representable values differ by exactly 1.
    fn bf16_key(x: f32) -> i64 {
        let h = (x.to_bits() >> 16) as i64;
        if h & 0x8000 != 0 {
            -(h & 0x7FFF)
        } else {
            h
        }
    }

    fn assert_close_ulp(dt: Dtype, a: f32, b: f32, ctx: &str) -> Result<(), String> {
        match dt {
            Dtype::F32 => prop_assert(
                a.to_bits() == b.to_bits(),
                format!("{ctx}: f32 not bitwise: {a} vs {b}"),
            ),
            _ => prop_assert(
                (bf16_key(a) - bf16_key(b)).abs() <= 1,
                format!("{ctx}: more than 1 ULP apart: {a} vs {b}"),
            ),
        }
    }

    #[test]
    fn property_backend_parity_forward_f32_and_bf16() {
        // Satellite criterion: eager, fused, and parallel-tiled compose
        // agree bitwise in f32 and within 1 ULP in bf16 across randomized
        // shapes, including dims not divisible by the tile size.
        check("backend parity fwd", 40, |gen| {
            let dt = gen.pick(&[Dtype::F32, Dtype::Bf16]);
            let act = ActShape::new(gen.usize_in(1, 40), gen.usize_in(1, 97));
            let (base, lora, g) = inputs(gen.case as u64, act, dt);
            let s = dt.quantize(gen.f64_in(0.1, 3.0) as f32);
            let all = backends();
            let reference = all[0].forward_alloc(&base, &lora, &g, s, act, dt);
            for be in &all[1..] {
                let got = be.forward_alloc(&base, &lora, &g, s, act, dt);
                for i in 0..act.elems() {
                    assert_close_ulp(
                        dt,
                        reference[i],
                        got[i],
                        &format!("{} elem {i} ({act:?})", be.name()),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_backend_parity_dual_and_backward() {
        check("backend parity dual+bwd", 30, |gen| {
            let dt = gen.pick(&[Dtype::F32, Dtype::Bf16]);
            let act = ActShape::new(gen.usize_in(1, 30), gen.usize_in(1, 65));
            let (base, lora, g) = inputs(100 + gen.case as u64, act, dt);
            let s = dt.quantize(gen.f64_in(0.1, 3.0) as f32);
            let n = act.elems();
            let all = backends();
            let mut rd = vec![0f32; n];
            let mut ri = vec![0f32; n];
            all[0].forward_dual(&base, &lora, &g, s, act, dt, &mut rd, &mut ri);
            let (mut rl, mut rb) = (vec![0f32; n], vec![0f32; n]);
            all[0].backward(&base, &g, s, act, dt, &mut rl, &mut rb);
            let r_dmag = all[0].dmag(&base, &lora, act);
            for be in &all[1..] {
                let mut dd = vec![0f32; n];
                let mut ii = vec![0f32; n];
                be.forward_dual(&base, &lora, &g, s, act, dt, &mut dd, &mut ii);
                let (mut dl, mut db) = (vec![0f32; n], vec![0f32; n]);
                be.backward(&base, &g, s, act, dt, &mut dl, &mut db);
                let dmag = be.dmag(&base, &lora, act);
                for i in 0..n {
                    assert_close_ulp(dt, rd[i], dd[i], &format!("{} dual-delta {i}", be.name()))?;
                    assert_close_ulp(dt, ri[i], ii[i], &format!("{} dual-inner {i}", be.name()))?;
                    assert_close_ulp(dt, rl[i], dl[i], &format!("{} d_lora {i}", be.name()))?;
                    assert_close_ulp(dt, rb[i], db[i], &format!("{} d_base {i}", be.name()))?;
                }
                for j in 0..act.d_out {
                    prop_assert(
                        r_dmag[j].to_bits() == dmag[j].to_bits(),
                        format!("{} dmag {j}: {} vs {}", be.name(), r_dmag[j], dmag[j]),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fused_dmag_backward_parity_across_backends() {
        let act = ActShape::new(100, 48); // odd 32-row-block tail
        let dt = Dtype::F32;
        let (d_delta, inner, g) = inputs(7, act, dt);
        let n = act.elems();
        let all = backends();
        let (mut rl, mut rb) = (vec![0f32; n], vec![0f32; n]);
        let r_dg = all[1].backward_with_dmag(&d_delta, &inner, &g, 1.7, act, dt, &mut rl, &mut rb);
        for be in &all {
            let (mut dl, mut db) = (vec![0f32; n], vec![0f32; n]);
            let dg = be.backward_with_dmag(&d_delta, &inner, &g, 1.7, act, dt, &mut dl, &mut db);
            assert_eq!(dl, rl, "{} d_lora", be.name());
            assert_eq!(db, rb, "{} d_base", be.name());
            for j in 0..act.d_out {
                // Eager's default path reduces rows in row order; the
                // two-stage paths reduce identical block partials — both
                // f64, so they agree to f32 rounding noise.
                assert!(
                    (dg[j] - r_dg[j]).abs() <= 1e-4 * r_dg[j].abs().max(1.0),
                    "{} dmag {j}: {} vs {}",
                    be.name(),
                    dg[j],
                    r_dg[j]
                );
            }
        }
    }

    #[test]
    fn property_norm_engine_parity() {
        check("norm engine parity", 20, |gen| {
            let dt = gen.pick(&[Dtype::F32, Dtype::Bf16]);
            let d_out = gen.usize_in(3, 33);
            let d_in = gen.usize_in(4, 90);
            let r = gen.usize_in(1, 9);
            let m = ModuleShape::new(d_out, d_in, r);
            let s = gen.f64_in(0.0, 3.0) as f32;
            let mut rng = Rng::new(gen.case as u64 + 500);
            let w = rng.normal_vec_f32(d_out * d_in, 0.1);
            let a = rng.normal_vec_f32(r * d_in, 0.2);
            let b = rng.normal_vec_f32(d_out * r, 0.2);
            let budget = (d_out * 64 * 4) as u64; // force multiple chunks
            let mut t1 = AllocTracker::new();
            let seq = FusedCpu.weight_norm(&w, &a, &b, s, m, budget, dt, &mut t1);
            let tiled_engine = ParallelTiledCpu::with_tile(3, 2);
            let mut t2 = AllocTracker::new();
            let tiled = tiled_engine.weight_norm(&w, &a, &b, s, m, budget, dt, &mut t2);
            for i in 0..d_out {
                prop_assert(
                    seq[i].to_bits() == tiled[i].to_bits(),
                    format!("row {i}: {} vs {} ({m:?} {dt:?})", seq[i], tiled[i]),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn property_factored_vs_dense_norm_parity_across_dtypes() {
        // Satellite criterion: the factored norm engines (sequential +
        // tiled) agree with the dense-materialized baseline in f32,
        // soft-bf16, AND fp16 under adversarial magnitudes — rows are
        // built as W = -s·B·A + amp·noise with amp swept down to 1e-3 of
        // the row scale, i.e. the heavy-cancellation / near-unity
        // rescaling regime of the paper's §3.1. Because cancellation
        // makes the OUTPUT an invalid yardstick, tolerances are relative
        // to the row's input scale; rows without heavy cancellation get
        // a tight relative check on top. All three engines are also held
        // to an exact f64 reference over the same quantized inputs.
        check("factored vs dense norm dtypes", 36, |gen| {
            let dt = gen.pick(&[Dtype::F32, Dtype::Bf16, Dtype::F16]);
            let d_out = gen.usize_in(3, 20);
            let d_in = gen.usize_in(4, 96); // > 64 exercises chunking
            let r = gen.usize_in(1, 8);
            let m = ModuleShape::new(d_out, d_in, r);
            let s = gen.f64_in(0.1, 2.0) as f32;
            let global = 10f64.powf(gen.f64_in(-1.0, 1.0)) as f32;
            let mut rng = Rng::new(4000 + gen.case as u64);
            let a = rng.normal_vec_f32(r * d_in, 0.3 * global);
            let b = rng.normal_vec_f32(d_out * r, 0.3);
            let ba = crate::dora::norm_cpu::matmul(&b, &a, d_out, r, d_in);
            let mut w = vec![0f32; d_out * d_in];
            for i in 0..d_out {
                let row = &ba[i * d_in..(i + 1) * d_in];
                let rms = (row.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
                    / d_in as f64)
                    .sqrt()
                    .max(1e-6) as f32;
                // Per-row cancellation severity: the residual after the
                // -s·BA cancellation spans 3 orders of magnitude.
                let amp = 10f64.powf(gen.f64_in(-3.0, 0.0)) as f32;
                for j in 0..d_in {
                    w[i * d_in + j] =
                        -s * row[j] + amp * rms * (rng.normal() as f32);
                }
            }

            let budget = (d_out * 64 * 4) as u64;
            let mut t1 = AllocTracker::new();
            let dense = EagerCpu.weight_norm(&w, &a, &b, s, m, budget, dt, &mut t1);
            let mut t2 = AllocTracker::new();
            let fact = FusedCpu.weight_norm(&w, &a, &b, s, m, budget, dt, &mut t2);
            let mut t3 = AllocTracker::new();
            let tiled = ParallelTiledCpu::with_tile(3, 2)
                .weight_norm(&w, &a, &b, s, m, budget, dt, &mut t3);

            // Exact f64 reference over the quantized inputs (both engine
            // families read storage through the same per-load quantize).
            let q = |v: &[f32]| -> Vec<f64> {
                v.iter().map(|&x| dt.quantize(x) as f64).collect()
            };
            let (wq, aq, bq) = (q(&w), q(&a), q(&b));
            let sq = s as f64;
            for i in 0..d_out {
                let mut norm_sq = 0f64;
                let mut w_sq = 0f64;
                let mut ba_sq = 0f64;
                for j in 0..d_in {
                    let mut ba_ij = 0f64;
                    for l in 0..r {
                        ba_ij += bq[i * r + l] * aq[l * d_in + j];
                    }
                    let composed = wq[i * d_in + j] + sq * ba_ij;
                    norm_sq += composed * composed;
                    w_sq += wq[i * d_in + j] * wq[i * d_in + j];
                    ba_sq += ba_ij * ba_ij;
                }
                let reference = norm_sq.sqrt();
                let row_scale = (w_sq.sqrt() + sq * ba_sq.sqrt()).max(1e-6);
                // Envelope: f32 accumulation noise amplified by the sqrt
                // near total cancellation is O(sqrt(d_in * eps)) of the
                // input scale.
                let envelope = 1e-2 * row_scale;
                for (name, got) in
                    [("dense", dense[i]), ("factored", fact[i]), ("tiled", tiled[i])]
                {
                    prop_assert(
                        (got as f64 - reference).abs() <= envelope,
                        format!(
                            "{name} row {i} ({dt:?}, {m:?}, s={s}): {got} vs f64 {reference} \
                             (scale {row_scale:.3e})"
                        ),
                    )?;
                }
                // No heavy cancellation -> tight relative parity between
                // the dense baseline and the factored engines.
                if reference > 0.3 * row_scale {
                    prop_assert(
                        (dense[i] as f64 - fact[i] as f64).abs() <= 3e-4 * reference,
                        format!(
                            "dense vs factored row {i} ({dt:?}): {} vs {}",
                            dense[i], fact[i]
                        ),
                    )?;
                }
                // The two factored executors stay bitwise identical in
                // every dtype (extends the existing parity suite).
                prop_assert(
                    fact[i].to_bits() == tiled[i].to_bits(),
                    format!("factored seq vs tiled row {i} ({dt:?}): {} vs {}", fact[i], tiled[i]),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn property_factored_vs_dense_colnorm_parity_across_dtypes() {
        // Column-norm mirror of the row parity suite: the factored COLUMN
        // engines (sequential + tiled) against the dense-materialized
        // column baseline and an exact f64 reference, in f32, soft-bf16,
        // and fp16, under adversarial PER-COLUMN cancellation — columns
        // are built as W[:,k] = -s·(B·A)[:,k] + amp·noise with amp swept
        // down to 1e-3 of the column scale.
        check("factored vs dense colnorm dtypes", 36, |gen| {
            let dt = gen.pick(&[Dtype::F32, Dtype::Bf16, Dtype::F16]);
            let d_out = gen.usize_in(4, 96); // > 64 exercises row chunking
            let d_in = gen.usize_in(3, 20);
            let r = gen.usize_in(1, 8);
            let m = ModuleShape::new(d_out, d_in, r);
            let s = gen.f64_in(0.1, 2.0) as f32;
            let global = 10f64.powf(gen.f64_in(-1.0, 1.0)) as f32;
            let mut rng = Rng::new(7000 + gen.case as u64);
            let a = rng.normal_vec_f32(r * d_in, 0.3 * global);
            let b = rng.normal_vec_f32(d_out * r, 0.3);
            let ba = crate::dora::norm_cpu::matmul(&b, &a, d_out, r, d_in);
            // Per-column cancellation severity spanning 3 orders of
            // magnitude.
            let mut amps = vec![0f32; d_in];
            let mut rmss = vec![0f32; d_in];
            for k in 0..d_in {
                let col_sq: f64 =
                    (0..d_out).map(|i| (ba[i * d_in + k] as f64).powi(2)).sum();
                rmss[k] = (col_sq / d_out as f64).sqrt().max(1e-6) as f32;
                amps[k] = 10f64.powf(gen.f64_in(-3.0, 0.0)) as f32;
            }
            let mut w = vec![0f32; d_out * d_in];
            for i in 0..d_out {
                for k in 0..d_in {
                    w[i * d_in + k] =
                        -s * ba[i * d_in + k] + amps[k] * rmss[k] * (rng.normal() as f32);
                }
            }

            let budget = (d_in * 64 * 4) as u64; // force multiple row chunks
            let mut t1 = AllocTracker::new();
            let dense = EagerCpu.weight_colnorm(&w, &a, &b, s, m, budget, dt, &mut t1);
            let mut t2 = AllocTracker::new();
            let fact = FusedCpu.weight_colnorm(&w, &a, &b, s, m, budget, dt, &mut t2);
            let mut t3 = AllocTracker::new();
            let tiled = ParallelTiledCpu::with_tile(3, 2)
                .weight_colnorm(&w, &a, &b, s, m, budget, dt, &mut t3);

            // Exact f64 reference over the quantized inputs.
            let q = |v: &[f32]| -> Vec<f64> {
                v.iter().map(|&x| dt.quantize(x) as f64).collect()
            };
            let (wq, aq, bq) = (q(&w), q(&a), q(&b));
            let sq = s as f64;
            for k in 0..d_in {
                let mut norm_sq = 0f64;
                let mut w_sq = 0f64;
                let mut ba_sq = 0f64;
                for i in 0..d_out {
                    let mut ba_ik = 0f64;
                    for l in 0..r {
                        ba_ik += bq[i * r + l] * aq[l * d_in + k];
                    }
                    let composed = wq[i * d_in + k] + sq * ba_ik;
                    norm_sq += composed * composed;
                    w_sq += wq[i * d_in + k] * wq[i * d_in + k];
                    ba_sq += ba_ik * ba_ik;
                }
                let reference = norm_sq.sqrt();
                let col_scale = (w_sq.sqrt() + sq * ba_sq.sqrt()).max(1e-6);
                let envelope = 1e-2 * col_scale;
                for (name, got) in
                    [("dense", dense[k]), ("factored", fact[k]), ("tiled", tiled[k])]
                {
                    prop_assert(
                        (got as f64 - reference).abs() <= envelope,
                        format!(
                            "{name} col {k} ({dt:?}, {m:?}, s={s}): {got} vs f64 {reference} \
                             (scale {col_scale:.3e})"
                        ),
                    )?;
                }
                // No heavy cancellation -> tight relative parity.
                if reference > 0.3 * col_scale {
                    prop_assert(
                        (dense[k] as f64 - fact[k] as f64).abs() <= 3e-4 * reference,
                        format!(
                            "dense vs factored col {k} ({dt:?}): {} vs {}",
                            dense[k], fact[k]
                        ),
                    )?;
                }
                // The two factored executors stay bitwise identical in
                // every dtype.
                prop_assert(
                    fact[k].to_bits() == tiled[k].to_bits(),
                    format!(
                        "factored seq vs tiled col {k} ({dt:?}): {} vs {}",
                        fact[k], tiled[k]
                    ),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn colnorm_scale_zero_and_chunk_invariance() {
        // s == 0 fast path equals plain column norms of W; chunked and
        // unchunked runs agree.
        let m = ModuleShape::new(96, 12, 4);
        let mut rng = Rng::new(31);
        let w = rng.normal_vec_f32(m.d_out * m.d_in, 0.1);
        let a = rng.normal_vec_f32(m.rank * m.d_in, 0.2);
        let b = rng.normal_vec_f32(m.d_out * m.rank, 0.2);
        let mut t = AllocTracker::new();
        let fast = FusedCpu.weight_colnorm(&w, &a, &b, 0.0, m, u64::MAX, Dtype::F32, &mut t);
        for k in 0..m.d_in {
            let want: f64 = (0..m.d_out)
                .map(|i| (w[i * m.d_in + k] as f64).powi(2))
                .sum();
            assert!((fast[k] as f64 - want.sqrt()).abs() < 1e-5, "col {k}");
        }
        let full = FusedCpu.weight_colnorm(&w, &a, &b, 1.3, m, u64::MAX, Dtype::F32, &mut t);
        let chunked = FusedCpu.weight_colnorm(
            &w,
            &a,
            &b,
            1.3,
            m,
            (m.d_in * 64 * 4) as u64,
            Dtype::F32,
            &mut t,
        );
        for k in 0..m.d_in {
            assert!(
                (full[k] - chunked[k]).abs() < 1e-4 * full[k].abs().max(1.0),
                "col {k}: {} vs {}",
                full[k],
                chunked[k]
            );
        }
    }

    #[test]
    fn eager_norm_engine_is_the_dense_baseline() {
        // The Eager kind's NormEngine is the op-by-op dense B@A path, not
        // a relabeled factored engine: same values and tracked peak as
        // dense_ba_norm, with the factored engines using far smaller
        // transients.
        let m = ModuleShape::new(12, 30, 4);
        let mut rng = Rng::new(21);
        let w = rng.normal_vec_f32(m.d_out * m.d_in, 0.1);
        let a = rng.normal_vec_f32(m.rank * m.d_in, 0.2);
        let b = rng.normal_vec_f32(m.d_out * m.rank, 0.2);
        let mut t1 = AllocTracker::new();
        let via_engine = EagerCpu.weight_norm(&w, &a, &b, 1.5, m, u64::MAX, Dtype::F32, &mut t1);
        let mut t2 = AllocTracker::new();
        let direct = crate::dora::norm_cpu::dense_ba_norm(&w, &a, &b, 1.5, m, &mut t2);
        assert_eq!(via_engine, direct);
        assert_eq!(t1.peak(), t2.peak());
        let mut t3 = AllocTracker::new();
        let fact = FusedCpu.weight_norm(&w, &a, &b, 1.5, m, u64::MAX, Dtype::F32, &mut t3);
        assert!(t3.peak() < t1.peak(), "factored should use less transient memory");
        for i in 0..m.d_out {
            assert!(
                (fact[i] - direct[i]).abs() < 1e-3 * direct[i].abs().max(1.0),
                "row {i}: {} vs {}",
                fact[i],
                direct[i]
            );
        }
    }

    #[test]
    fn bf16_outputs_are_representable() {
        // Every value a bf16 kernel emits must be exactly representable in
        // bf16 (the quantization is applied after the final op).
        let act = ActShape::new(9, 37);
        let (base, lora, g) = inputs(3, act, Dtype::Bf16);
        for be in backends() {
            let out = be.forward_alloc(&base, &lora, &g, 1.5, act, Dtype::Bf16);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(
                    crate::numerics::half::round_bf16(v),
                    v,
                    "{} elem {i} not bf16-representable",
                    be.name()
                );
            }
        }
    }

    #[test]
    fn registry_selects_by_tier_and_working_set() {
        let reg = KernelRegistry::with_defaults(8);
        let mut env = DispatchEnv { threads: 8, ..DispatchEnv::default() };
        // Tier 3 -> eager.
        let small = ComposeCtx::training(ActShape::new(16, 256));
        let c = reg.select(&env, &small);
        assert_eq!(c.tier, Tier::Eager);
        assert_eq!(c.backend.kind(), BackendKind::Eager);
        // Tier 1, LLC-exceeding -> parallel tiled.
        let big = ComposeCtx::training(ActShape::new(8192, 8192));
        let c = reg.select(&env, &big);
        assert_eq!(c.tier, Tier::FusedBackward);
        assert_eq!(c.backend.kind(), BackendKind::ParallelTiled);
        assert!(c.is_fused());
        // Tier 2 below LLC -> fused sequential.
        let mid = ComposeCtx::inference(ActShape::new(512, 2048));
        let c = reg.select(&env, &mid);
        assert_eq!(c.tier, Tier::FusedForward);
        assert_eq!(c.backend.kind(), BackendKind::Fused);
        // Single-threaded env never picks the parallel backend.
        env.threads = 1;
        let c = reg.select(&env, &big);
        assert_eq!(c.backend.kind(), BackendKind::Fused);
    }

    #[test]
    fn registry_carries_all_kinds_for_both_traits() {
        let reg = KernelRegistry::with_defaults(2);
        for kind in [BackendKind::Eager, BackendKind::Fused, BackendKind::ParallelTiled] {
            assert_eq!(reg.compose(kind).kind(), kind);
            assert_eq!(reg.norm(kind).kind(), kind);
        }
        assert_eq!(reg.compose_backends().len(), 3);
        assert_eq!(reg.norm_engines().len(), 3);
        assert!(reg.compose(BackendKind::ParallelTiled).parallelism() >= 2);
    }

    #[test]
    fn norm_for_matches_compose_backend_family() {
        let reg = KernelRegistry::with_defaults(4);
        let env = DispatchEnv { threads: 4, ..DispatchEnv::default() };
        for ctx in [
            ComposeCtx::training(ActShape::new(16, 256)),     // tier 3
            ComposeCtx::inference(ActShape::new(512, 2048)),  // tier 2, sub-LLC
            ComposeCtx::training(ActShape::new(8192, 8192)),  // tier 1, parallel
        ] {
            let choice = reg.select(&env, &ctx);
            assert_eq!(reg.norm_for(&choice).kind(), choice.backend.kind());
        }
    }

    #[test]
    fn parallel_tiled_matches_flat_kernels_on_large_shape() {
        // A shape large enough that several workers genuinely run.
        let act = ActShape::new(531, 129); // not divisible by tile or d
        let (base, lora, g) = inputs(11, act, Dtype::F32);
        let tiled = ParallelTiledCpu::with_tile(4, 64);
        let got = tiled.forward_alloc(&base, &lora, &g, 2.0, act, Dtype::F32);
        let want = crate::dora::compose_cpu::compose_fused(&base, &lora, &g, 2.0, act);
        assert_eq!(got, want);
    }
}
