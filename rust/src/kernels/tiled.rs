//! `ParallelTiledCpu`: the fused kernels over row-tiles on a scoped
//! thread pool — the multi-core backend for LLC-exceeding shapes.
//!
//! Activations are split into tiles of `tile_rows` consecutive rows; a
//! shared queue hands tiles to `threads` scoped workers (coarse work
//! stealing, no per-element synchronization — every tile is a disjoint
//! `&mut` output slice). Per-element arithmetic is the shared fused core,
//! so results are **bitwise identical** to [`FusedCpu`] in every dtype;
//! the d_mag reduction keeps §3.2 determinism by accumulating fixed
//! per-row-block f64 partials (block boundaries independent of the thread
//! count) and reducing them in fixed order on the calling thread.
//!
//! [`FusedCpu`]: crate::kernels::FusedCpu

use std::sync::Mutex;

use crate::dora::config::{ActShape, ModuleShape};
use crate::dora::norm_cpu::AllocTracker;
use crate::kernels::generic::{self, with_elem, Elem, DMAG_ROWS_PER_BLOCK};
use crate::kernels::norm;
use crate::kernels::{BackendKind, ComposeKernel, NormEngine};
use crate::numerics::half::Dtype;

/// Rows per tile: sized so one tile's streams (3-4 rows-sized arrays at
/// d_out ~ 4-8k) stay comfortably inside a core's L2 slice while keeping
/// the queue lock cold.
pub const DEFAULT_TILE_ROWS: usize = 128;

/// The parallel row-tiled CPU backend.
#[derive(Debug, Clone, Copy)]
pub struct ParallelTiledCpu {
    threads: usize,
    tile_rows: usize,
}

impl ParallelTiledCpu {
    /// Backend with `threads` workers (0 = all available cores) and the
    /// default tile size.
    pub fn new(threads: usize) -> ParallelTiledCpu {
        Self::with_tile(threads, DEFAULT_TILE_ROWS)
    }

    /// Fully explicit construction (benches sweep both knobs).
    pub fn with_tile(threads: usize, tile_rows: usize) -> ParallelTiledCpu {
        let threads = if threads == 0 { crate::dispatch::default_threads() } else { threads };
        ParallelTiledCpu { threads, tile_rows: tile_rows.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn tile_rows(&self) -> usize {
        self.tile_rows
    }

    /// Worker count actually used for `rows` (never more workers than
    /// tiles).
    fn workers_for(&self, rows: usize) -> usize {
        self.threads.min(rows.div_ceil(self.tile_rows)).max(1)
    }

    fn par_forward<E: Elem>(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        d: usize,
        rows: usize,
        delta: &mut [f32],
    ) {
        let tile = self.tile_rows * d;
        let n = self.workers_for(rows);
        if n <= 1 {
            generic::forward_rows::<E>(base, lora, g, s, d, delta);
            return;
        }
        let queue = Mutex::new(delta.chunks_mut(tile).enumerate());
        std::thread::scope(|scope| {
            for _ in 0..n {
                scope.spawn(|| loop {
                    let item = { queue.lock().unwrap().next() };
                    let Some((ti, out)) = item else { break };
                    let lo = ti * tile;
                    let hi = lo + out.len();
                    generic::forward_rows::<E>(&base[lo..hi], &lora[lo..hi], g, s, d, out);
                });
            }
        });
    }

    fn par_forward_dual<E: Elem>(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        d: usize,
        rows: usize,
        delta: &mut [f32],
        inner: &mut [f32],
    ) {
        let tile = self.tile_rows * d;
        let n = self.workers_for(rows);
        if n <= 1 {
            generic::forward_dual_rows::<E>(base, lora, g, s, d, delta, inner);
            return;
        }
        let queue = Mutex::new(delta.chunks_mut(tile).zip(inner.chunks_mut(tile)).enumerate());
        std::thread::scope(|scope| {
            for _ in 0..n {
                scope.spawn(|| loop {
                    let item = { queue.lock().unwrap().next() };
                    let Some((ti, (dout, iout))) = item else { break };
                    let lo = ti * tile;
                    let hi = lo + dout.len();
                    generic::forward_dual_rows::<E>(
                        &base[lo..hi],
                        &lora[lo..hi],
                        g,
                        s,
                        d,
                        dout,
                        iout,
                    );
                });
            }
        });
    }

    fn par_backward<E: Elem>(
        &self,
        d_delta: &[f32],
        g: &[f32],
        s: f32,
        d: usize,
        rows: usize,
        d_lora: &mut [f32],
        d_base: &mut [f32],
    ) {
        let tile = self.tile_rows * d;
        let n = self.workers_for(rows);
        if n <= 1 {
            generic::backward_rows::<E>(d_delta, g, s, d, d_lora, d_base);
            return;
        }
        let queue = Mutex::new(d_lora.chunks_mut(tile).zip(d_base.chunks_mut(tile)).enumerate());
        std::thread::scope(|scope| {
            for _ in 0..n {
                scope.spawn(|| loop {
                    let item = { queue.lock().unwrap().next() };
                    let Some((ti, (dl, db))) = item else { break };
                    let lo = ti * tile;
                    let hi = lo + dl.len();
                    generic::backward_rows::<E>(&d_delta[lo..hi], g, s, d, dl, db);
                });
            }
        });
    }

    /// Parallel two-stage fused-d_mag backward. Stage-1 partials are per
    /// fixed 32-row block (NOT per thread), so the reduction order — and
    /// therefore the result — is independent of the worker count.
    #[allow(clippy::too_many_arguments)]
    fn par_backward_dmag<E: Elem>(
        &self,
        d_delta: &[f32],
        inner: &[f32],
        g: &[f32],
        s: f32,
        d: usize,
        rows: usize,
        d_lora: &mut [f32],
        d_base: &mut [f32],
    ) -> Vec<f32> {
        let block = DMAG_ROWS_PER_BLOCK;
        let n_blocks = rows.div_ceil(block);
        let mut partials = vec![0f64; n_blocks * d];
        let n = self.threads.min(n_blocks).max(1);
        let tile = block * d;
        if n <= 1 {
            for blk in 0..n_blocks {
                let r0 = blk * block;
                let r1 = (r0 + block).min(rows);
                generic::backward_dmag_block::<E>(
                    &d_delta[r0 * d..r1 * d],
                    &inner[r0 * d..r1 * d],
                    g,
                    s,
                    d,
                    &mut d_lora[r0 * d..r1 * d],
                    &mut d_base[r0 * d..r1 * d],
                    &mut partials[blk * d..(blk + 1) * d],
                );
            }
        } else {
            let queue = Mutex::new(
                d_lora
                    .chunks_mut(tile)
                    .zip(d_base.chunks_mut(tile))
                    .zip(partials.chunks_mut(d))
                    .enumerate(),
            );
            std::thread::scope(|scope| {
                for _ in 0..n {
                    scope.spawn(|| loop {
                        let item = { queue.lock().unwrap().next() };
                        let Some((ti, ((dl, db), part))) = item else { break };
                        let lo = ti * tile;
                        let hi = lo + dl.len();
                        generic::backward_dmag_block::<E>(
                            &d_delta[lo..hi],
                            &inner[lo..hi],
                            g,
                            s,
                            d,
                            dl,
                            db,
                            part,
                        );
                    });
                }
            });
        }
        generic::dmag_reduce_partials(&partials, n_blocks, d)
    }
}

impl ComposeKernel for ParallelTiledCpu {
    fn name(&self) -> &'static str {
        "parallel-tiled-cpu"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::ParallelTiled
    }

    fn parallelism(&self) -> usize {
        self.threads
    }

    fn forward(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        delta: &mut [f32],
    ) {
        with_elem!(dt, E, {
            self.par_forward::<E>(base, lora, g, s, act.d_out, act.rows, delta)
        });
    }

    fn forward_dual(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        delta: &mut [f32],
        inner: &mut [f32],
    ) {
        with_elem!(dt, E, {
            self.par_forward_dual::<E>(base, lora, g, s, act.d_out, act.rows, delta, inner)
        });
    }

    fn backward(
        &self,
        d_delta: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        d_lora: &mut [f32],
        d_base: &mut [f32],
    ) {
        with_elem!(dt, E, {
            self.par_backward::<E>(d_delta, g, s, act.d_out, act.rows, d_lora, d_base)
        });
    }

    fn backward_with_dmag(
        &self,
        d_delta: &[f32],
        inner: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        d_lora: &mut [f32],
        d_base: &mut [f32],
    ) -> Vec<f32> {
        with_elem!(dt, E, {
            self.par_backward_dmag::<E>(
                d_delta, inner, g, s, act.d_out, act.rows, d_lora, d_base,
            )
        })
    }
}

impl NormEngine for ParallelTiledCpu {
    fn name(&self) -> &'static str {
        "parallel-tiled-cpu"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::ParallelTiled
    }

    fn weight_norm(
        &self,
        w: &[f32],
        a: &[f32],
        b: &[f32],
        s: f32,
        m: ModuleShape,
        budget: u64,
        dt: Dtype,
        tracker: &mut AllocTracker,
    ) -> Vec<f32> {
        with_elem!(dt, E, {
            norm::factored_norm_tiled::<E>(
                w,
                a,
                b,
                s,
                m,
                budget,
                self.threads,
                self.tile_rows,
                tracker,
            )
        })
    }

    fn weight_colnorm(
        &self,
        w: &[f32],
        a: &[f32],
        b: &[f32],
        s: f32,
        m: ModuleShape,
        budget: u64,
        dt: Dtype,
        tracker: &mut AllocTracker,
    ) -> Vec<f32> {
        // `tile_rows` doubles as the column-tile width here.
        with_elem!(dt, E, {
            norm::factored_colnorm_tiled::<E>(
                w,
                a,
                b,
                s,
                m,
                budget,
                self.threads,
                self.tile_rows,
                tracker,
            )
        })
    }
}
