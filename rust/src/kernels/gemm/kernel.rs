//! The register-tiled micro-kernels. Both variants keep the same
//! arithmetic shape: per k-step, broadcast one A value per accumulator
//! row and FMA it against NR unit-stride B values — no data-dependent
//! branches, no horizontal reductions, so LLVM vectorizes the j-axis.
//! Accumulation over k is strictly sequential per element (the
//! determinism contract in the module docs).

use super::{MR, NR};

/// Packed-panel kernel: `acc[MR][NR] += Â-panel × B̂-panel` over the full
/// panel depth. `a_panel` is column-major `[kc, MR]` (MR values per
/// k-step, unit stride), `b_panel` row-major `[kc, NR]`.
#[inline]
pub(crate) fn microkernel(a_panel: &[f32], b_panel: &[f32], acc: &mut [[f32; NR]; MR]) {
    for (av, bv) in a_panel.chunks_exact(MR).zip(b_panel.chunks_exact(NR)) {
        for (&ai, row) in av.iter().zip(acc.iter_mut()) {
            for (c, &bj) in row.iter_mut().zip(bv) {
                *c += ai * bj;
            }
        }
    }
}

/// Unpacked kernel for the small-K path: reads MR rows of A in place
/// (`a[i * lda + p]`) and NR-wide row slices of B (`b[p * ldb .. +NR]`).
/// Callers guarantee `a` holds MR full rows and `b` holds `k` rows of at
/// least NR columns past its origin.
#[inline]
pub(crate) fn microkernel_direct(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    k: usize,
    acc: &mut [[f32; NR]; MR],
) {
    for p in 0..k {
        let bv = &b[p * ldb..p * ldb + NR];
        for (i, row) in acc.iter_mut().enumerate() {
            let ai = a[i * lda + p];
            for (c, &bj) in row.iter_mut().zip(bv) {
                *c += ai * bj;
            }
        }
    }
}
