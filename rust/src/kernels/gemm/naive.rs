//! Branch-free naive reference loops — the pre-PR6 engine GEMMs with the
//! data-dependent zero-skip removed. Kept public on purpose: they are
//! the measured baseline of the perf-gate GEMM rows and the bitwise
//! reference of the parity tests (per-element k-order is sequential, the
//! same fold as the register tile for a single k-block).

/// C[m,n] = A[m,k] @ B[k,n] (i-k-j loop order, unit-stride inner loop).
pub fn nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    nn_into(a, b, m, k, n, &mut c);
    c
}

/// In-place variant of [`nn`]; `c` is overwritten.
pub fn nn_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            let brow = &b[p * n..p * n + n];
            let crow = &mut c[i * n..i * n + n];
            for (cj, &bj) in crow.iter_mut().zip(brow) {
                *cj += aip * bj;
            }
        }
    }
}

/// C[m,n] = A[m,k] @ B[n,k]ᵀ (dot-product form; both rows unit-stride).
pub fn nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// C[n1,n2] = A[rows,n1]ᵀ @ B[rows,n2] (rank-1 update form).
pub fn tn(a: &[f32], b: &[f32], rows: usize, n1: usize, n2: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), rows * n1);
    debug_assert_eq!(b.len(), rows * n2);
    let mut c = vec![0f32; n1 * n2];
    for i in 0..rows {
        let arow = &a[i * n1..(i + 1) * n1];
        let brow = &b[i * n2..(i + 1) * n2];
        for (p, &ap) in arow.iter().enumerate() {
            let crow = &mut c[p * n2..(p + 1) * n2];
            for (cq, &bq) in crow.iter_mut().zip(brow) {
                *cq += ap * bq;
            }
        }
    }
    c
}
