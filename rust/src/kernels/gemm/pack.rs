//! Panel packing: copy an operand block into the contiguous layout the
//! micro-kernel streams, absorbing any transposition here so the inner
//! loop never sees a non-unit stride. Edge panels are zero-padded to a
//! full MR rows / NR columns — padding contributes exact `+0.0 * x`
//! terms only to padded C positions, which the driver never writes back.

use std::ops::Range;

use super::{MatA, MatB, MR, NR};

/// Pack the A block `rows × cols` into `rows.len().div_ceil(MR)` panels.
/// Panel `t` holds source rows `rows.start + t*MR ..` in column-major
/// order within the panel: `buf[t*MR*kc + p*MR + i]` = A(row, col) for
/// panel-local row `i` and k-offset `p`, so the micro-kernel reads MR
/// A values per k-step at unit stride.
pub(crate) fn pack_a(
    a: MatA<'_>,
    m: usize,
    k: usize,
    rows: Range<usize>,
    cols: Range<usize>,
    buf: &mut [f32],
) {
    let kc = cols.len();
    let panels = rows.len().div_ceil(MR);
    for t in 0..panels {
        let dst = &mut buf[t * MR * kc..(t + 1) * MR * kc];
        for (p, col) in cols.clone().enumerate() {
            for i in 0..MR {
                let row = rows.start + t * MR + i;
                dst[p * MR + i] = if row < rows.end {
                    match a {
                        MatA::Normal(d) => d[row * k + col],
                        MatA::Trans(d) => d[col * m + row],
                    }
                } else {
                    0.0
                };
            }
        }
    }
}

/// Pack the B block `rows(k) × cols(n)` into `cols.len().div_ceil(NR)`
/// panels. Panel `t` holds source columns `cols.start + t*NR ..` in
/// row-major order within the panel: `buf[t*kc*NR + p*NR + j]` =
/// B(krow, col), so the micro-kernel reads NR B values per k-step at
/// unit stride.
pub(crate) fn pack_b(
    b: MatB<'_>,
    k: usize,
    n: usize,
    krows: Range<usize>,
    cols: Range<usize>,
    buf: &mut [f32],
) {
    let kc = krows.len();
    let panels = cols.len().div_ceil(NR);
    for t in 0..panels {
        let dst = &mut buf[t * kc * NR..(t + 1) * kc * NR];
        for (p, krow) in krows.clone().enumerate() {
            for j in 0..NR {
                let col = cols.start + t * NR + j;
                dst[p * NR + j] = if col < cols.end {
                    match b {
                        MatB::Normal(d) => d[krow * n + col],
                        MatB::Trans(d) => d[col * k + krow],
                    }
                } else {
                    0.0
                };
            }
        }
    }
}
