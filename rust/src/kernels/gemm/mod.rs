//! Blocked, register-tiled f32 GEMM micro-kernels — the raw-speed layer
//! under every dense contraction in the native engine (DESIGN.md §2).
//!
//! Three storage variants cover all call sites (`models/forward`, the
//! dense norm baselines, the merged-weight build in the pool):
//!
//! * [`nn`] — `C[m,n] = A[m,k] @ B[k,n]`, both row-major.
//! * [`nt`] — `C[m,n] = A[m,k] @ B[n,k]ᵀ` (the forward shape: activations
//!   against row-major weights `W[d_out, d_in]`, `x@Aᵀ`, `h@Bᵀ`).
//! * [`tn`] — `C[n1,n2] = A[rows,n1]ᵀ @ B[rows,n2]` (the gradient
//!   contractions `da`, `db`).
//!
//! Design (BLIS-style, scalar Rust written to autovectorize):
//!
//! * **Register tile** MR×NR = 4×8: the micro-kernel keeps a
//!   `[[f32; NR]; MR]` accumulator whose inner loop is an unit-stride
//!   FMA over the NR axis with no data-dependent branches — the shape
//!   LLVM turns into packed mul/add without any target-feature flags
//!   (4 rows × one 8-lane vector stays inside the baseline x86-64 SSE2
//!   register budget).
//! * **Cache blocking** MC×KC×NC = 64×512×1024: A blocks are packed into
//!   MR-row panels (column-major within the panel) and B blocks into
//!   NR-column panels (row-major within the panel) so both micro-kernel
//!   operands stream at unit stride regardless of the source layout;
//!   transposition happens during packing, never in the inner loop.
//! * **Small-K fast path** (k ≤ [`SMALL_K_MAX`]): the adapter shapes
//!   `B[d_out,r] @ A[r,d_in]`, `x@Aᵀ`, `h@Bᵀ` contract over K = r ≪
//!   d_out, d_in, so the whole K extent fits one panel and blocking
//!   buys nothing — [`small_k`] skips the block loop nest (and for `nn`
//!   all packing) and runs the register tile straight over the operands.
//!
//! # Determinism contract
//!
//! The blocking schedule is a pure function of (m, k, n) — never of
//! thread count, data values, or environment — and every path accumulates
//! each output element over k **sequentially in storage order** (the
//! register tile vectorizes across output columns, not across k). Two
//! consequences the test suite pins:
//!
//! * For k ≤ KC (one k-block — every builtin-config contraction; the
//!   largest is 512, the e2e vocab and bs·seq) results are **bitwise
//!   identical** to a naive sequential-k loop, so the committed golden
//!   trace, the NumPy replicas and the merged-parity bounds are
//!   numerically unchanged by this layer.
//! * For k > KC the per-block partials reassociate the sum (still
//!   deterministically: fixed schedule, run-to-run and thread-count
//!   bitwise), which is why the golden contract is replica *tolerance*,
//!   not bitwise — see `python/golden_trace_gen.py`.

pub(crate) mod kernel;
pub mod naive;
pub(crate) mod pack;
pub(crate) mod small_k;

/// Micro-kernel rows: C register-tile height.
pub const MR: usize = 4;
/// Micro-kernel columns: C register-tile width (the vectorized axis).
pub const NR: usize = 8;
/// Row block: A panel height per inner loop (L2-resident with KC).
pub const MC: usize = 64;
/// K block: both panel depths; one block covers every builtin contraction.
pub const KC: usize = 512;
/// Column block: B panel width per outer loop (L3-resident).
pub const NC: usize = 1024;
/// Largest contraction depth routed to the small-K path. Builtin adapter
/// ranks (4/8/16, and the paper's high-rank sweep up to 64) stay under
/// it; d_model-sized contractions (≥ 128) go through the blocked core.
pub const SMALL_K_MAX: usize = 64;

/// Left operand view: logical A[m,k] in either storage order.
#[derive(Clone, Copy)]
pub(crate) enum MatA<'a> {
    /// Row-major `[m, k]`: element (i, p) at `data[i * k + p]`.
    Normal(&'a [f32]),
    /// Stored row-major `[k, m]` (the tn left operand): element (i, p)
    /// at `data[p * m + i]`.
    Trans(&'a [f32]),
}

/// Right operand view: logical B[k,n] in either storage order.
#[derive(Clone, Copy)]
pub(crate) enum MatB<'a> {
    /// Row-major `[k, n]`: element (p, j) at `data[p * n + j]`.
    Normal(&'a [f32]),
    /// Stored row-major `[n, k]` (the nt right operand): element (p, j)
    /// at `data[j * k + p]`.
    Trans(&'a [f32]),
}

// ---------------------------------------------------------------------------
// Public entry points
// ---------------------------------------------------------------------------

/// C[m,n] = A[m,k] @ B[k,n] (row-major).
pub fn nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    nn_into(a, b, m, k, n, &mut c);
    c
}

/// C[m,n] = A[m,k] @ B[k,n] (row-major), writing into `c`.
pub fn nn_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    if k <= SMALL_K_MAX {
        small_k::nn_into(a, b, m, k, n, c);
    } else {
        blocked(MatA::Normal(a), MatB::Normal(b), m, k, n, c);
    }
}

/// C[m,n] = A[m,k] @ B[n,k]ᵀ (both row-major).
pub fn nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    nt_into(a, b, m, k, n, &mut c);
    c
}

/// C[m,n] = A[m,k] @ B[n,k]ᵀ (both row-major), writing into `c`.
pub fn nt_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    if k <= SMALL_K_MAX {
        small_k::nt_into(a, b, m, k, n, c);
    } else {
        blocked(MatA::Normal(a), MatB::Trans(b), m, k, n, c);
    }
}

/// C[n1,n2] = A[rows,n1]ᵀ @ B[rows,n2] (gradient contractions). The
/// contraction depth is `rows` (can exceed KC), so this always takes the
/// blocked core; packing absorbs the transposed A access.
pub fn tn(a: &[f32], b: &[f32], rows: usize, n1: usize, n2: usize) -> Vec<f32> {
    let mut c = vec![0f32; n1 * n2];
    tn_into(a, b, rows, n1, n2, &mut c);
    c
}

/// C[n1,n2] = A[rows,n1]ᵀ @ B[rows,n2], writing into `c`.
pub fn tn_into(a: &[f32], b: &[f32], rows: usize, n1: usize, n2: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * n1);
    debug_assert_eq!(b.len(), rows * n2);
    debug_assert_eq!(c.len(), n1 * n2);
    if n1 == 0 || n2 == 0 {
        return;
    }
    if rows == 0 {
        c.fill(0.0);
        return;
    }
    blocked(MatA::Trans(a), MatB::Normal(b), n1, rows, n2, c);
}

// ---------------------------------------------------------------------------
// Bench/test hooks: run a specific nn core regardless of the dispatch
// threshold. Both are correct for any k; the perf gate uses them to
// measure the small-K dispatch crossover, and the parity tests to pin
// small-K == blocked bitwise.
// ---------------------------------------------------------------------------

/// [`nn`] through the generic blocked core, ignoring the small-K dispatch.
pub fn nn_blocked(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    if m > 0 && n > 0 && k > 0 {
        blocked(MatA::Normal(a), MatB::Normal(b), m, k, n, &mut c);
    }
    c
}

/// [`nn`] through the small-K path, ignoring the dispatch threshold.
pub fn nn_small_k(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    if m > 0 && n > 0 && k > 0 {
        small_k::nn_into(a, b, m, k, n, &mut c);
    }
    c
}

// ---------------------------------------------------------------------------
// Blocked driver
// ---------------------------------------------------------------------------

/// The MC/KC/NC loop nest over packed panels. The schedule (block sizes
/// and traversal order) depends only on (m, k, n); each C element is
/// owned by exactly one (ic, jc) block and accumulated over k-blocks in
/// increasing-p order — stored on the first k-block, added on the rest —
/// so per-element summation stays sequential within a block and
/// block-ordered across blocks.
fn blocked(a: MatA<'_>, b: MatB<'_>, m: usize, k: usize, n: usize, c: &mut [f32]) {
    let kc_max = KC.min(k);
    let mc_pad = MC.min(m).div_ceil(MR) * MR;
    let nc_pad = NC.min(n).div_ceil(NR) * NR;
    let mut abuf = vec![0f32; mc_pad * kc_max];
    let mut bbuf = vec![0f32; kc_max * nc_pad];
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let col_panels = nc.div_ceil(NR);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack::pack_b(b, k, n, pc..pc + kc, jc..jc + nc, &mut bbuf);
            let first_kblock = pc == 0;
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                pack::pack_a(a, m, k, ic..ic + mc, pc..pc + kc, &mut abuf);
                let row_panels = mc.div_ceil(MR);
                for jt in 0..col_panels {
                    let bpanel = &bbuf[jt * kc * NR..(jt + 1) * kc * NR];
                    let nj = NR.min(nc - jt * NR);
                    for it in 0..row_panels {
                        let apanel = &abuf[it * MR * kc..(it + 1) * MR * kc];
                        let mut acc = [[0f32; NR]; MR];
                        kernel::microkernel(apanel, bpanel, &mut acc);
                        let mi = MR.min(mc - it * MR);
                        let (i0, j0) = (ic + it * MR, jc + jt * NR);
                        if first_kblock {
                            store_tile(c, n, i0, j0, mi, nj, &acc);
                        } else {
                            add_tile(c, n, i0, j0, mi, nj, &acc);
                        }
                    }
                }
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

/// Write an accumulator tile into C (first k-block: plain store, so the
/// k=0 partial — including its zero signs — lands exactly).
pub(crate) fn store_tile(
    c: &mut [f32],
    ldc: usize,
    i0: usize,
    j0: usize,
    mi: usize,
    nj: usize,
    acc: &[[f32; NR]; MR],
) {
    for (i, row) in acc.iter().enumerate().take(mi) {
        let dst = &mut c[(i0 + i) * ldc + j0..(i0 + i) * ldc + j0 + nj];
        dst.copy_from_slice(&row[..nj]);
    }
}

/// Add an accumulator tile into C (later k-blocks).
fn add_tile(
    c: &mut [f32],
    ldc: usize,
    i0: usize,
    j0: usize,
    mi: usize,
    nj: usize,
    acc: &[[f32; NR]; MR],
) {
    for (i, row) in acc.iter().enumerate().take(mi) {
        let dst = &mut c[(i0 + i) * ldc + j0..(i0 + i) * ldc + j0 + nj];
        for (d, &v) in dst.iter_mut().zip(&row[..nj]) {
            *d += v;
        }
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random fill (same LCG family as the engines).
    fn fill(seed: u64, n: usize) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).max(1);
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 40) as f32 / (1u64 << 24) as f32) - 0.5
            })
            .collect()
    }

    /// Shapes that exercise tile edges: multiples of MR/NR, off-by-one,
    /// degenerate dims, and a k spanning several KC blocks.
    fn shapes() -> Vec<(usize, usize, usize)> {
        vec![
            (1, 1, 1),
            (4, 8, 8),
            (5, 3, 9),
            (7, 1, 13),
            (16, 64, 24),
            (9, 65, 17),
            (33, 128, 31),
            (12, 700, 20),
            (3, 1100, 11),
        ]
    }

    #[test]
    fn nn_matches_naive_bitwise_for_single_k_block() {
        for (m, k, n) in shapes() {
            if k > KC {
                continue; // multi-block shapes reassociate; covered below
            }
            let a = fill(1, m * k);
            let b = fill(2, k * n);
            let got = nn(&a, &b, m, k, n);
            let want = naive::nn(&a, &b, m, k, n);
            let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "nn {m}x{k}x{n} not bitwise-naive");
        }
    }

    #[test]
    fn nt_matches_naive_bitwise_for_single_k_block() {
        for (m, k, n) in shapes() {
            if k > KC {
                continue;
            }
            let a = fill(3, m * k);
            let b = fill(4, n * k);
            let got = nt(&a, &b, m, k, n);
            let want = naive::nt(&a, &b, m, k, n);
            let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "nt {m}x{k}x{n} not bitwise-naive");
        }
    }

    #[test]
    fn tn_matches_naive_bitwise_for_single_k_block() {
        for (rows, n1, n2) in shapes() {
            if rows > KC {
                continue;
            }
            let a = fill(5, rows * n1);
            let b = fill(6, rows * n2);
            let got = tn(&a, &b, rows, n1, n2);
            let want = naive::tn(&a, &b, rows, n1, n2);
            let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            assert_eq!(gb, wb, "tn rows={rows} {n1}x{n2} not bitwise-naive");
        }
    }

    #[test]
    fn multi_k_block_stays_close_and_deterministic() {
        // k > KC reassociates against naive (block partials) but must be
        // tiny-relative-error close and bitwise run-to-run stable.
        let (m, k, n) = (6, KC + 137, 10);
        let a = fill(7, m * k);
        let b = fill(8, k * n);
        let got = nn(&a, &b, m, k, n);
        let want = naive::nn(&a, &b, m, k, n);
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "elem {i}: {g} vs {w}");
        }
        let again = nn(&a, &b, m, k, n);
        let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
        let ab: Vec<u32> = again.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, ab, "multi-block nn not run-to-run bitwise");
    }

    #[test]
    fn degenerate_dims_yield_zero_or_empty() {
        assert!(nn(&[], &[], 0, 3, 4).is_empty());
        assert!(nt(&[], &[], 2, 5, 0).is_empty());
        assert_eq!(nn(&[], &[], 2, 0, 3), vec![0.0; 6]);
        assert_eq!(tn(&[], &[], 0, 2, 3), vec![0.0; 6]);
    }

    #[test]
    fn small_k_dispatch_agrees_with_blocked_bitwise() {
        // The dispatch threshold must be invisible numerically: force the
        // generic core on a small-K shape and compare bitwise.
        for (m, k, n) in [(13, 8, 21), (32, SMALL_K_MAX, 40), (5, 1, 7)] {
            let a = fill(9, m * k);
            let bn = fill(10, k * n);
            let fast = nn(&a, &bn, m, k, n);
            let mut slow = vec![0f32; m * n];
            blocked(MatA::Normal(&a), MatB::Normal(&bn), m, k, n, &mut slow);
            let fb: Vec<u32> = fast.iter().map(|x| x.to_bits()).collect();
            let sb: Vec<u32> = slow.iter().map(|x| x.to_bits()).collect();
            assert_eq!(fb, sb, "nn small-K vs blocked {m}x{k}x{n}");

            let bt = fill(11, n * k);
            let fast = nt(&a, &bt, m, k, n);
            let mut slow = vec![0f32; m * n];
            blocked(MatA::Normal(&a), MatB::Trans(&bt), m, k, n, &mut slow);
            let fb: Vec<u32> = fast.iter().map(|x| x.to_bits()).collect();
            let sb: Vec<u32> = slow.iter().map(|x| x.to_bits()).collect();
            assert_eq!(fb, sb, "nt small-K vs blocked {m}x{k}x{n}");
        }
    }
}
