//! Small-K fast path (k ≤ SMALL_K_MAX): the adapter-shape regime where
//! the contraction depth is the rank. The whole K extent fits one panel,
//! so the MC/KC/NC loop nest degenerates — this path drops it:
//!
//! * `nn` touches no scratch at all: B rows are already unit-stride, so
//!   the direct kernel streams both operands in place.
//! * `nt` packs Bᵀ once into `[k × NR]` column panels (one pass over B),
//!   then runs the same direct kernel with `ldb = NR`.
//!
//! Scalar tail rows/columns fall back to sequential dots, which keep the
//! same per-element k-order as the register tile — so the fast path is
//! bitwise-identical to the blocked core (asserted in `gemm::tests`).

use super::kernel::microkernel_direct;
use super::{pack, store_tile, MatB, MR, NR};

/// C[m,n] = A[m,k] @ B[k,n], k small; no packing.
pub(crate) fn nn_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    let m_main = m - m % MR;
    let n_main = n - n % NR;
    for i0 in (0..m_main).step_by(MR) {
        for j0 in (0..n_main).step_by(NR) {
            let mut acc = [[0f32; NR]; MR];
            microkernel_direct(&a[i0 * k..], k, &b[j0..], n, k, &mut acc);
            store_tile(c, n, i0, j0, MR, NR, &acc);
        }
        for i in i0..i0 + MR {
            for j in n_main..n {
                c[i * n + j] = dot_nn(a, i, k, b, j, n);
            }
        }
    }
    for i in m_main..m {
        for j in 0..n {
            c[i * n + j] = dot_nn(a, i, k, b, j, n);
        }
    }
}

/// C[m,n] = A[m,k] @ B[n,k]ᵀ, k small; Bᵀ packed once.
pub(crate) fn nt_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    let panels = n.div_ceil(NR);
    let mut bbuf = vec![0f32; panels * k * NR];
    pack::pack_b(MatB::Trans(b), k, n, 0..k, 0..n, &mut bbuf);
    let m_main = m - m % MR;
    for i0 in (0..m_main).step_by(MR) {
        for (t, bpanel) in bbuf.chunks_exact(k * NR).enumerate() {
            let mut acc = [[0f32; NR]; MR];
            microkernel_direct(&a[i0 * k..], k, bpanel, NR, k, &mut acc);
            let nj = NR.min(n - t * NR);
            store_tile(c, n, i0, t * NR, MR, nj, &acc);
        }
    }
    for i in m_main..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            c[i * n + j] = acc;
        }
    }
}

/// One C element of A[m,k] @ B[k,n], accumulated in storage k-order.
fn dot_nn(a: &[f32], i: usize, k: usize, b: &[f32], j: usize, n: usize) -> f32 {
    let arow = &a[i * k..(i + 1) * k];
    let mut acc = 0f32;
    for (p, &ap) in arow.iter().enumerate() {
        acc += ap * b[p * n + j];
    }
    acc
}
