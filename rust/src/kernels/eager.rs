//! `EagerCpu`: the op-by-op composition path as a registry backend.
//!
//! Forward is the 4-pass chain with materialized temporaries (allocated
//! per call — the PyTorch-eager allocation story); backward is the
//! 2-kernel pair plus the separate d_mag reduction. This backend is the
//! Tier-3 fallback and the correctness baseline the fused backends are
//! verified against.

use crate::dora::config::{ActShape, ModuleShape};
use crate::dora::norm_cpu::AllocTracker;
use crate::kernels::generic::{self, with_elem};
use crate::kernels::{BackendKind, ComposeKernel, NormEngine};
use crate::numerics::half::Dtype;

/// The eager (multi-pass) CPU backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct EagerCpu;

impl ComposeKernel for EagerCpu {
    fn name(&self) -> &'static str {
        "eager-cpu"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Eager
    }

    fn forward(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        delta: &mut [f32],
    ) {
        let n = act.elems();
        let mut t1 = vec![0f32; n];
        let mut t2 = vec![0f32; n];
        let mut t3 = vec![0f32; n];
        with_elem!(dt, E, {
            generic::eager_chain::<E>(base, lora, g, s, act.d_out, &mut t1, &mut t2, &mut t3, delta)
        });
    }

    fn forward_dual(
        &self,
        base: &[f32],
        lora: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        delta: &mut [f32],
        inner: &mut [f32],
    ) {
        let n = act.elems();
        let mut t1 = vec![0f32; n];
        let mut t2 = vec![0f32; n];
        let mut t3 = vec![0f32; n];
        with_elem!(dt, E, {
            generic::eager_chain::<E>(
                base,
                lora,
                g,
                s,
                act.d_out,
                &mut t1,
                &mut t2,
                &mut t3,
                delta,
            );
            // Extra pass for inner = s*lora + base, reusing the t1 = s*lora
            // temporary (one more kernel in the eager chain).
            for ((o, &sl), &b) in inner.iter_mut().zip(t1.iter()).zip(base.iter()) {
                *o = E::q(sl + b);
            }
        });
    }

    fn backward(
        &self,
        d_delta: &[f32],
        g: &[f32],
        s: f32,
        act: ActShape,
        dt: Dtype,
        d_lora: &mut [f32],
        d_base: &mut [f32],
    ) {
        with_elem!(dt, E, {
            generic::backward_eager_rows::<E>(d_delta, g, s, act.d_out, d_lora, d_base)
        });
    }
}

impl NormEngine for EagerCpu {
    fn name(&self) -> &'static str {
        "eager-cpu"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Eager
    }

    /// The op-by-op baseline: dense `B@A` materialization
    /// (`norm_cpu::dense_ba_norm`) — the eager path the factored engines
    /// (Fused / ParallelTiled kinds) replace, kept in the registry so
    /// dense-vs-factored memory comparisons run through one surface.
    /// The chunk `budget` does not apply to the dense path. Half
    /// dtypes read storage through a tracked fp32-cast copy (the copy
    /// the paper notes only exists for bf16 storage).
    fn weight_norm(
        &self,
        w: &[f32],
        a: &[f32],
        b: &[f32],
        s: f32,
        m: ModuleShape,
        _budget: u64,
        dt: Dtype,
        tracker: &mut AllocTracker,
    ) -> Vec<f32> {
        if dt == Dtype::F32 {
            return crate::dora::norm_cpu::dense_ba_norm(w, a, b, s, m, tracker);
        }
        let cast = |v: &[f32], tracker: &mut AllocTracker| -> Vec<f32> {
            tracker.alloc((v.len() * 4) as u64);
            v.iter().map(|&x| dt.quantize(x)).collect()
        };
        let wq = cast(w, tracker);
        let aq = cast(a, tracker);
        let bq = cast(b, tracker);
        let out = crate::dora::norm_cpu::dense_ba_norm(&wq, &aq, &bq, s, m, tracker);
        tracker.free(((wq.len() + aq.len() + bq.len()) * 4) as u64);
        out
    }

    /// Column-wise analogue of the dense baseline
    /// (`norm_cpu::dense_ba_colnorm`), with the same tracked fp32-cast
    /// copies for half storage dtypes.
    fn weight_colnorm(
        &self,
        w: &[f32],
        a: &[f32],
        b: &[f32],
        s: f32,
        m: ModuleShape,
        _budget: u64,
        dt: Dtype,
        tracker: &mut AllocTracker,
    ) -> Vec<f32> {
        if dt == Dtype::F32 {
            return crate::dora::norm_cpu::dense_ba_colnorm(w, a, b, s, m, tracker);
        }
        let cast = |v: &[f32], tracker: &mut AllocTracker| -> Vec<f32> {
            tracker.alloc((v.len() * 4) as u64);
            v.iter().map(|&x| dt.quantize(x)).collect()
        };
        let wq = cast(w, tracker);
        let aq = cast(a, tracker);
        let bq = cast(b, tracker);
        let out = crate::dora::norm_cpu::dense_ba_colnorm(&wq, &aq, &bq, s, m, tracker);
        tracker.free(((wq.len() + aq.len() + bq.len()) * 4) as u64);
        out
    }
}
