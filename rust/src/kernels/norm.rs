//! Shared factored-norm cores (Algorithm 1) behind the [`NormEngine`]
//! backends, plus the chunk accumulator the d_in-sharded norm reuses.
//!
//! Every term of Algorithm 1 is a sum over d_in column ranges, so one
//! accumulator serves three executors:
//!
//! * [`factored_norm_seq`]   — the sequential chunked engine (the flat
//!   `norm_cpu::factored_norm`, now dtype-generic);
//! * [`factored_norm_tiled`] — d_out row-tiles on a scoped thread pool
//!   (Gram first, then embarrassingly parallel rows — bitwise identical
//!   to the sequential engine because per-row accumulation order is
//!   unchanged);
//! * `sharded_norm::worker_partials` — one worker's column shard.
//!
//! Accumulation discipline matches the paper: inputs are read at storage
//! precision (`E::q` per load — the identity for f32), contractions
//! accumulate in f32, row sum-of-squares in f64, assembly constants
//! (`2s`, `s^2`) precomputed in f64 and rounded once.
//!
//! [`NormEngine`]: crate::kernels::NormEngine

use crate::dora::config::ModuleShape;
use crate::dora::norm_cpu::{chunk_size, AllocTracker};
use crate::kernels::generic::Elem;

/// NaN-propagating clamp-then-sqrt: `f32::max` in Rust returns the
/// non-NaN operand, which would silently collapse NaNs to zero — the
/// opposite of the paper's clamp_min semantics (Appendix C.3).
#[inline]
pub(crate) fn sqrt_clamp_min0(total: f32) -> f32 {
    if total.is_nan() {
        f32::NAN
    } else {
        total.max(0.0).sqrt()
    }
}

fn vec_f32(tracker: &mut AllocTracker, n: usize) -> Vec<f32> {
    tracker.alloc((n * 4) as u64);
    vec![0f32; n]
}

fn drop_vec(tracker: &mut AllocTracker, v: Vec<f32>) {
    tracker.free((v.len() * 4) as u64);
    drop(v);
}

/// Accumulate one column range `[start, stop)` of Algorithm 1's three
/// partial sums. `w_stride` / `a_stride` are the row strides of W and A
/// (`d_in` for full matrices, the shard width for d_in shards). `u_c` is
/// the reusable `[d_out, r]` chunk workspace.
#[allow(clippy::too_many_arguments)]
pub(crate) fn accumulate_columns<E: Elem>(
    w: &[f32],
    a: &[f32],
    b: &[f32],
    d_out: usize,
    r: usize,
    w_stride: usize,
    a_stride: usize,
    start: usize,
    stop: usize,
    base_sq: &mut [f32],
    cross: &mut [f32],
    gram: &mut [f32],
    u_c: &mut [f32],
) {
    let width = stop - start;
    // base_sq += rowwise sum of W_c^2 (f64 chunk accumulator).
    for i in 0..d_out {
        let row = &w[i * w_stride + start..i * w_stride + stop];
        let mut acc = 0f64;
        for &x in row {
            let x = E::q(x);
            acc += (x as f64) * (x as f64);
        }
        base_sq[i] += acc as f32;
    }
    // G += A_c @ A_c^T  [r, r]
    for i in 0..r {
        let ai = &a[i * a_stride + start..i * a_stride + stop];
        for j in i..r {
            let aj = &a[j * a_stride + start..j * a_stride + stop];
            let mut acc = 0f32;
            for t in 0..width {
                acc += E::q(ai[t]) * E::q(aj[t]);
            }
            gram[i * r + j] += acc;
            if i != j {
                gram[j * r + i] += acc;
            }
        }
    }
    // U_c = W_c @ A_c^T  [d_out, r]; cross += sum(B * U_c, dim=1).
    for i in 0..d_out {
        let wrow = &w[i * w_stride + start..i * w_stride + stop];
        for l in 0..r {
            let arow = &a[l * a_stride + start..l * a_stride + stop];
            let mut acc = 0f32;
            for t in 0..width {
                acc += E::q(wrow[t]) * E::q(arow[t]);
            }
            u_c[i * r + l] = acc;
        }
        let brow = &b[i * r..(i + 1) * r];
        let mut cacc = 0f32;
        for l in 0..r {
            cacc += E::q(brow[l]) * u_c[i * r + l];
        }
        cross[i] += cacc;
    }
}

/// `ba_sq` for one row: `(B G B^T)_ii` from the global Gram.
#[inline]
pub(crate) fn ba_sq_row<E: Elem>(brow: &[f32], gram: &[f32], r: usize) -> f32 {
    let mut acc = 0f32;
    for l in 0..r {
        let mut bg = 0f32;
        for t in 0..r {
            bg += E::q(brow[t]) * gram[t * r + l];
        }
        acc += bg * E::q(brow[l]);
    }
    acc
}

/// Gram-only chunk accumulation (used by the tiled engine, which computes
/// the shared `[r, r]` Gram before fanning rows out to threads).
fn gram_chunk<E: Elem>(
    a: &[f32],
    r: usize,
    a_stride: usize,
    start: usize,
    stop: usize,
    gram: &mut [f32],
) {
    let width = stop - start;
    for i in 0..r {
        let ai = &a[i * a_stride + start..i * a_stride + stop];
        for j in i..r {
            let aj = &a[j * a_stride + start..j * a_stride + stop];
            let mut acc = 0f32;
            for t in 0..width {
                acc += E::q(ai[t]) * E::q(aj[t]);
            }
            gram[i * r + j] += acc;
            if i != j {
                gram[j * r + i] += acc;
            }
        }
    }
}

/// Algorithm 1, sequential chunked execution with exact allocation
/// accounting — the engine behind `norm_cpu::factored_norm`.
pub(crate) fn factored_norm_seq<E: Elem>(
    w: &[f32],
    a: &[f32],
    b: &[f32],
    s: f32,
    m: ModuleShape,
    budget: u64,
    tracker: &mut AllocTracker,
) -> Vec<f32> {
    let ModuleShape { d_out, d_in, rank: r } = m;
    let cs = chunk_size(m, budget);

    let mut base_sq = vec_f32(tracker, d_out);
    // Scale-is-zero fast path (Appendix B): skip cross/ba and never
    // allocate U or G.
    if s == 0.0 {
        for i in 0..d_out {
            let row = &w[i * d_in..(i + 1) * d_in];
            // f32 square widened to f64 — matches the historical fast
            // path bit-for-bit (the chunked path below squares in f64;
            // the two paths have always differed in that last ULP).
            base_sq[i] = row
                .iter()
                .map(|&x| {
                    let x = E::q(x);
                    (x * x) as f64
                })
                .sum::<f64>() as f32;
        }
        let out = base_sq.iter().map(|&x| sqrt_clamp_min0(x)).collect();
        drop_vec(tracker, base_sq);
        return out;
    }

    let mut cross = vec_f32(tracker, d_out);
    let mut gram = vec_f32(tracker, r * r);
    // U_c chunk buffer [d_out, r], reused across chunks (never two alive).
    let mut u_c = vec_f32(tracker, d_out * r);

    let mut start = 0;
    while start < d_in {
        let stop = (start + cs).min(d_in);
        accumulate_columns::<E>(
            w, a, b, d_out, r, d_in, d_in, start, stop, &mut base_sq, &mut cross, &mut gram,
            &mut u_c,
        );
        start = stop;
    }
    drop_vec(tracker, u_c);

    // ba_sq = (B @ G * B) . 1  [d_out]
    let mut ba_sq = vec_f32(tracker, d_out);
    for i in 0..d_out {
        ba_sq[i] = ba_sq_row::<E>(&b[i * r..(i + 1) * r], &gram, r);
    }
    drop_vec(tracker, gram);

    // Assembly (Eq. 5): two_s / s2 precomputed in f64, rounded once.
    let two_s = (2.0 * s as f64) as f32;
    let s2 = (s as f64 * s as f64) as f32;
    let mut out = vec![0f32; d_out];
    for i in 0..d_out {
        let total = base_sq[i] + two_s * cross[i] + s2 * ba_sq[i];
        out[i] = sqrt_clamp_min0(total);
    }
    drop_vec(tracker, ba_sq);
    drop_vec(tracker, cross);
    drop_vec(tracker, base_sq);
    out
}

/// Algorithm 1, d_out row-tiles on a scoped thread pool.
///
/// The shared `[r, r]` Gram is accumulated once on the calling thread
/// (cost `r^2 * d_in`, a factor `d_out / r` below the row contractions);
/// rows are then fully independent — each worker owns a private `[r]`
/// workspace and walks ITS rows through the same d_in chunk schedule the
/// sequential engine uses, so results are bitwise identical to
/// [`factored_norm_seq`]. Tracked transients are smaller than the
/// sequential engine's (`threads * r` instead of `d_out * r` workspace).
pub(crate) fn factored_norm_tiled<E: Elem>(
    w: &[f32],
    a: &[f32],
    b: &[f32],
    s: f32,
    m: ModuleShape,
    budget: u64,
    threads: usize,
    tile_rows: usize,
    tracker: &mut AllocTracker,
) -> Vec<f32> {
    let ModuleShape { d_out, d_in, rank: r } = m;
    let cs = chunk_size(m, budget);
    let tile = tile_rows.max(1);
    let n_threads = threads.max(1).min(d_out.div_ceil(tile)).max(1);

    let mut out = vec![0f32; d_out];

    // Scale-is-zero fast path: row sums only, still row-parallel. No
    // transient allocations (rows write straight into the output), so
    // nothing is tracked.
    if s == 0.0 {
        run_row_tiles(&mut out, tile, n_threads, |r0, orow| {
            for (k, o) in orow.iter_mut().enumerate() {
                let i = r0 + k;
                let row = &w[i * d_in..(i + 1) * d_in];
                // f32 square widened to f64: bitwise-matches the
                // sequential fast path above.
                let total = row
                    .iter()
                    .map(|&x| {
                        let x = E::q(x);
                        (x * x) as f64
                    })
                    .sum::<f64>() as f32;
                *o = sqrt_clamp_min0(total);
            }
        });
        return out;
    }

    // Shared Gram, same chunk schedule as the sequential engine.
    let mut gram = vec_f32(tracker, r * r);
    let mut start = 0;
    while start < d_in {
        let stop = (start + cs).min(d_in);
        gram_chunk::<E>(a, r, d_in, start, stop, &mut gram);
        start = stop;
    }

    let two_s = (2.0 * s as f64) as f32;
    let s2 = (s as f64 * s as f64) as f32;

    // Per-worker U-row workspace: threads * [r].
    tracker.alloc((n_threads * r * 4) as u64);
    let gram_ref = &gram;
    run_row_tiles(&mut out, tile, n_threads, |r0, orow| {
        let mut u_row = vec![0f32; r];
        for (k, o) in orow.iter_mut().enumerate() {
            let i = r0 + k;
            let brow = &b[i * r..(i + 1) * r];
            let mut base_sq = 0f32;
            let mut cross = 0f32;
            // Same per-row chunk schedule and accumulation order as the
            // sequential engine -> bitwise-identical partials.
            let mut c0 = 0;
            while c0 < d_in {
                let c1 = (c0 + cs).min(d_in);
                let wrow = &w[i * d_in + c0..i * d_in + c1];
                let mut acc = 0f64;
                for &x in wrow {
                    let x = E::q(x);
                    acc += (x as f64) * (x as f64);
                }
                base_sq += acc as f32;
                for (l, u) in u_row.iter_mut().enumerate() {
                    let arow = &a[l * d_in + c0..l * d_in + c1];
                    let mut dot = 0f32;
                    for t in 0..wrow.len() {
                        dot += E::q(wrow[t]) * E::q(arow[t]);
                    }
                    *u = dot;
                }
                let mut cacc = 0f32;
                for l in 0..r {
                    cacc += E::q(brow[l]) * u_row[l];
                }
                cross += cacc;
                c0 = c1;
            }
            let ba = ba_sq_row::<E>(brow, gram_ref, r);
            let total = base_sq + two_s * cross + s2 * ba;
            *o = sqrt_clamp_min0(total);
        }
    });
    tracker.free((n_threads * r * 4) as u64);
    drop_vec(tracker, gram);
    out
}

/// One row-chunk's contribution to the B-Gram `G += B_c^T @ B_c` `[r, r]`
/// (the column-norm analogue of [`gram_chunk`]): per entry, a full-chunk
/// f32 dot added once — the same per-chunk discipline as the A-Gram.
fn gram_b_chunk<E: Elem>(b: &[f32], r: usize, start: usize, stop: usize, gram: &mut [f32]) {
    for l in 0..r {
        for t in l..r {
            let mut acc = 0f32;
            for i in start..stop {
                acc += E::q(b[i * r + l]) * E::q(b[i * r + t]);
            }
            gram[l * r + t] += acc;
            if l != t {
                gram[t * r + l] += acc;
            }
        }
    }
}

/// `ba_sq` for one COLUMN: `(A^T G_B A)_kk` from the B-Gram. Mirrors
/// [`ba_sq_row`] with A read down column `k` (stride `a_stride`).
#[inline]
pub(crate) fn ba_sq_col<E: Elem>(
    a: &[f32],
    k: usize,
    a_stride: usize,
    gram: &[f32],
    r: usize,
) -> f32 {
    let mut acc = 0f32;
    for l in 0..r {
        let mut ag = 0f32;
        for t in 0..r {
            ag += E::q(a[t * a_stride + k]) * gram[t * r + l];
        }
        acc += ag * E::q(a[l * a_stride + k]);
    }
    acc
}

/// Rows-per-chunk for the column norm: the transpose of the row norm's
/// [`chunk_size`] knob — the chunk workspace is `[d_in, r]` + the `[d_in]`
/// f64 accumulator, so rows are budgeted against `d_in`.
pub(crate) fn colnorm_chunk_rows(m: ModuleShape, budget: u64) -> usize {
    chunk_size(ModuleShape::new(m.d_in, m.d_out, m.rank), budget)
}

/// Algorithm 1 transposed: factored COLUMN-wise norm
/// `||W + s*B@A||_col` in `O(d_in*r + r^2)` intermediates — the BoRA
/// column-magnitude decomposition. Per column `k`:
///
/// ```text
/// ||W + sBA||^2_col[k] = base_sq[k] + 2s*cross[k] + s^2*ba_sq[k]
///   base_sq[k] = sum_i W[i,k]^2                 (f64 per row-chunk)
///   cross[k]   = sum_l (W^T B)[k,l] * A[l,k]    (f32 chunk partials)
///   ba_sq[k]   = (A^T (B^T B) A)_kk             (B-Gram, [r, r])
/// ```
///
/// Accumulation discipline matches [`factored_norm_seq`] with the axes
/// swapped: d_out is chunked instead of d_in, the chunk workspace is
/// `U_c = W_c^T @ B_c` `[d_in, r]`, and assembly reuses the same
/// `two_s`/`s2`/[`sqrt_clamp_min0`] constants.
pub(crate) fn factored_colnorm_seq<E: Elem>(
    w: &[f32],
    a: &[f32],
    b: &[f32],
    s: f32,
    m: ModuleShape,
    budget: u64,
    tracker: &mut AllocTracker,
) -> Vec<f32> {
    let ModuleShape { d_out, d_in, rank: r } = m;
    let cs = colnorm_chunk_rows(m, budget);

    // Scale-is-zero fast path: column sums of W^2 only (f32 square
    // widened to f64, matching the row fast path's discipline).
    if s == 0.0 {
        tracker.alloc((d_in * 8) as u64);
        let mut acc = vec![0f64; d_in];
        for i in 0..d_out {
            let row = &w[i * d_in..(i + 1) * d_in];
            for (k, &x) in row.iter().enumerate() {
                let x = E::q(x);
                acc[k] += (x * x) as f64;
            }
        }
        let out = acc.iter().map(|&x| sqrt_clamp_min0(x as f32)).collect();
        tracker.free((d_in * 8) as u64);
        drop(acc);
        return out;
    }

    let mut base_sq = vec_f32(tracker, d_in);
    let mut cross = vec_f32(tracker, d_in);
    let mut gram = vec_f32(tracker, r * r);
    // U_c chunk buffer [d_in, r] + f64 column accumulator, reused across
    // chunks.
    let mut u_c = vec_f32(tracker, d_in * r);
    tracker.alloc((d_in * 8) as u64);
    let mut acc64 = vec![0f64; d_in];

    let mut start = 0;
    while start < d_out {
        let stop = (start + cs).min(d_out);
        // base_sq += columnwise sum of W_c^2 (f64 chunk accumulator).
        for a64 in acc64.iter_mut() {
            *a64 = 0.0;
        }
        for i in start..stop {
            let row = &w[i * d_in..(i + 1) * d_in];
            for (k, &x) in row.iter().enumerate() {
                let x = E::q(x);
                acc64[k] += (x as f64) * (x as f64);
            }
        }
        for (bs, &a64) in base_sq.iter_mut().zip(acc64.iter()) {
            *bs += a64 as f32;
        }
        // G += B_c^T @ B_c  [r, r]
        gram_b_chunk::<E>(b, r, start, stop, &mut gram);
        // U_c = W_c^T @ B_c  [d_in, r]; cross += sum(U_c[k,:] * A[:,k]).
        for u in u_c.iter_mut() {
            *u = 0.0;
        }
        for i in start..stop {
            let wrow = &w[i * d_in..(i + 1) * d_in];
            let brow = &b[i * r..(i + 1) * r];
            for (k, &wv) in wrow.iter().enumerate() {
                let wq = E::q(wv);
                let dst = &mut u_c[k * r..(k + 1) * r];
                for (l, u) in dst.iter_mut().enumerate() {
                    *u += wq * E::q(brow[l]);
                }
            }
        }
        for k in 0..d_in {
            let urow = &u_c[k * r..(k + 1) * r];
            let mut cacc = 0f32;
            for (l, &u) in urow.iter().enumerate() {
                cacc += u * E::q(a[l * d_in + k]);
            }
            cross[k] += cacc;
        }
        start = stop;
    }
    tracker.free((d_in * 8) as u64);
    drop(acc64);
    drop_vec(tracker, u_c);

    // ba_sq = diag(A^T G A)  [d_in]
    let mut ba_sq = vec_f32(tracker, d_in);
    for (k, slot) in ba_sq.iter_mut().enumerate() {
        *slot = ba_sq_col::<E>(a, k, d_in, &gram, r);
    }
    drop_vec(tracker, gram);

    let two_s = (2.0 * s as f64) as f32;
    let s2 = (s as f64 * s as f64) as f32;
    let mut out = vec![0f32; d_in];
    for k in 0..d_in {
        let total = base_sq[k] + two_s * cross[k] + s2 * ba_sq[k];
        out[k] = sqrt_clamp_min0(total);
    }
    drop_vec(tracker, ba_sq);
    drop_vec(tracker, cross);
    drop_vec(tracker, base_sq);
    out
}

/// Factored column norm over d_in column-tiles on a scoped thread pool.
///
/// The shared `[r, r]` B-Gram is accumulated once on the calling thread
/// through the same row-chunk schedule as [`factored_colnorm_seq`];
/// columns are then fully independent — each worker walks ITS columns
/// through the identical chunk schedule with a private `[r]` workspace,
/// so results are bitwise identical to the sequential engine.
#[allow(clippy::too_many_arguments)]
pub(crate) fn factored_colnorm_tiled<E: Elem>(
    w: &[f32],
    a: &[f32],
    b: &[f32],
    s: f32,
    m: ModuleShape,
    budget: u64,
    threads: usize,
    tile_cols: usize,
    tracker: &mut AllocTracker,
) -> Vec<f32> {
    let ModuleShape { d_out, d_in, rank: r } = m;
    let cs = colnorm_chunk_rows(m, budget);
    let tile = tile_cols.max(1);
    let n_threads = threads.max(1).min(d_in.div_ceil(tile)).max(1);

    let mut out = vec![0f32; d_in];

    // Scale-is-zero fast path: per-column f64 row sums (ascending-row
    // order — bitwise-matches the sequential fast path), column-parallel.
    if s == 0.0 {
        run_row_tiles(&mut out, tile, n_threads, |c0, ocol| {
            for (kk, o) in ocol.iter_mut().enumerate() {
                let k = c0 + kk;
                let mut acc = 0f64;
                for i in 0..d_out {
                    let x = E::q(w[i * d_in + k]);
                    acc += (x * x) as f64;
                }
                *o = sqrt_clamp_min0(acc as f32);
            }
        });
        return out;
    }

    // Shared B-Gram, same row-chunk schedule as the sequential engine.
    let mut gram = vec_f32(tracker, r * r);
    let mut start = 0;
    while start < d_out {
        let stop = (start + cs).min(d_out);
        gram_b_chunk::<E>(b, r, start, stop, &mut gram);
        start = stop;
    }

    let two_s = (2.0 * s as f64) as f32;
    let s2 = (s as f64 * s as f64) as f32;

    // Per-worker U-column workspace: threads * [r].
    tracker.alloc((n_threads * r * 4) as u64);
    let gram_ref = &gram;
    run_row_tiles(&mut out, tile, n_threads, |c0, ocol| {
        let mut u_col = vec![0f32; r];
        for (kk, o) in ocol.iter_mut().enumerate() {
            let k = c0 + kk;
            let mut base_sq = 0f32;
            let mut cross = 0f32;
            // Same per-column chunk schedule and accumulation order as
            // the sequential engine -> bitwise-identical partials.
            let mut r0 = 0;
            while r0 < d_out {
                let r1 = (r0 + cs).min(d_out);
                let mut acc = 0f64;
                for i in r0..r1 {
                    let x = E::q(w[i * d_in + k]);
                    acc += (x as f64) * (x as f64);
                }
                base_sq += acc as f32;
                for u in u_col.iter_mut() {
                    *u = 0.0;
                }
                for i in r0..r1 {
                    let wq = E::q(w[i * d_in + k]);
                    let brow = &b[i * r..(i + 1) * r];
                    for (l, u) in u_col.iter_mut().enumerate() {
                        *u += wq * E::q(brow[l]);
                    }
                }
                let mut cacc = 0f32;
                for (l, &u) in u_col.iter().enumerate() {
                    cacc += u * E::q(a[l * d_in + k]);
                }
                cross += cacc;
                r0 = r1;
            }
            let ba = ba_sq_col::<E>(a, k, d_in, gram_ref, r);
            let total = base_sq + two_s * cross + s2 * ba;
            *o = sqrt_clamp_min0(total);
        }
    });
    tracker.free((n_threads * r * 4) as u64);
    drop_vec(tracker, gram);
    out
}

/// Run `job(first_row, out_tile)` over row tiles of `out` on a scoped
/// thread pool. Tiles are handed out through a shared queue (coarse
/// work-stealing); each tile is a disjoint `&mut` slice, so the only
/// synchronization is the queue lock.
fn run_row_tiles<F>(out: &mut [f32], tile: usize, n_threads: usize, job: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if n_threads <= 1 {
        for (ti, orow) in out.chunks_mut(tile).enumerate() {
            job(ti * tile, orow);
        }
        return;
    }
    let queue = std::sync::Mutex::new(out.chunks_mut(tile).enumerate());
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| loop {
                let item = { queue.lock().unwrap().next() };
                let Some((ti, orow)) = item else { break };
                job(ti * tile, orow);
            });
        }
    });
}
