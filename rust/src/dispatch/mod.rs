//! Three-tier runtime dispatch (paper §4, Figure 2, Table 2).
//!
//! `select_tier` is the Rust port of `_compose_with_dispatch`: given the
//! execution context (training vs inference, device, activation shape,
//! contiguity, magnitude broadcast layout) and the environment-variable
//! overrides, it picks:
//!
//! * **Tier 1 — FusedBackward**: training, accelerator present, above the
//!   crossover, auto/force-on. Dual-output kernel saves `inner` for the
//!   backward (skipped when the magnitude is frozen).
//! * **Tier 2 — FusedForward**: inference on an accelerator.
//! * **Tier 3 — Eager**: CPU, kernels unavailable, force-off, or
//!   sub-crossover shapes where launch latency dominates.
//!
//! Environment variables (paper Appendix B), read ONCE at [`DispatchEnv`]
//! construction — the decision path itself is pure and testable, and
//! malformed values fall back to the defaults instead of erroring:
//!
//! * `DORA_FUSED`           (0/false/off = force eager everywhere)
//! * `DORA_FUSED_BACKWARD`  (1 = force fused bwd, 0 = disable, unset/other = auto)
//! * `DORA_NORM_CHUNK_MB` / `DORA_FWD_CHUNK_MB` (256 MB defaults)
//! * `DORA_THREADS`         (worker count for the parallel-tiled backend;
//!   default = available cores)
//!
//! (The upstream names are `PEFT_DORA_*`; this runtime drops the prefix.)
//!
//! Since the kernel-backend refactor the canonical dispatch surface is
//! [`select_kernel`], which returns a runnable backend handle from the
//! [`KernelRegistry`](crate::kernels::KernelRegistry); [`select_tier`]
//! remains the pure tier decision it wraps.

use crate::dora::config::ActShape;

/// Default auto-mode crossover (paper §4): `d_out >= 2048` AND
/// `rows * d_out >= 2048 * 6144`.
pub const CROSSOVER_DOUT: usize = 2048;
pub const CROSSOVER_ELEMS: usize = 2048 * 6144;

/// The execution tier selected for one compose call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    FusedBackward,
    FusedForward,
    Eager,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::FusedBackward => "tier1-fused-backward",
            Tier::FusedForward => "tier2-fused-forward",
            Tier::Eager => "tier3-eager",
        }
    }
}

/// Tri-state env override.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Override {
    ForceOn,
    ForceOff,
    #[default]
    Auto,
}

impl Override {
    /// Parse a tri-state override variable: `1`/`true`/`on` force on,
    /// `0`/`false`/`off` force off; unset or malformed values fall back
    /// to [`Override::Auto`] (case-insensitive, whitespace-tolerant).
    pub fn parse(v: Option<&str>) -> Override {
        match v.map(|s| s.trim().to_ascii_lowercase()).as_deref() {
            Some("1") | Some("true") | Some("on") => Override::ForceOn,
            Some("0") | Some("false") | Some("off") => Override::ForceOff,
            _ => Override::Auto,
        }
    }
}

/// Boolean env parse with the same token set as [`Override::parse`];
/// malformed values fall back to `default`.
fn parse_bool(v: Option<&str>, default: bool) -> bool {
    match Override::parse(v) {
        Override::ForceOn => true,
        Override::ForceOff => false,
        Override::Auto => default,
    }
}

/// Megabyte budget parse; malformed or overflowing values fall back to
/// `default_bytes`.
fn parse_mb(v: Option<&str>, default_bytes: u64) -> u64 {
    v.and_then(|s| s.trim().parse::<u64>().ok())
        .and_then(|mb| mb.checked_mul(1 << 20))
        .unwrap_or(default_bytes)
}

/// Thread-count parse; zero or malformed values fall back to `default`.
fn parse_threads(v: Option<&str>, default: usize) -> usize {
    v.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

/// Available cores with a single-core fallback — the one source of truth
/// for thread-count defaults (DispatchEnv, the parallel backend's `0 =
/// all cores` sizing, and the benches' core gating all use it).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Environment-variable configuration (Appendix B).
#[derive(Debug, Clone)]
pub struct DispatchEnv {
    /// DORA_FUSED=0 forces eager everywhere.
    pub fused_enabled: bool,
    /// DORA_FUSED_BACKWARD: force/disable/auto for Tier 1.
    pub fused_backward: Override,
    /// Norm chunk budget in bytes (DORA_NORM_CHUNK_MB, default 256 MB).
    pub norm_chunk_bytes: u64,
    /// Forward compose chunk budget (DORA_FWD_CHUNK_MB, dropout path).
    pub fwd_chunk_bytes: u64,
    /// Worker count for the parallel-tiled backend (DORA_THREADS,
    /// default = available cores; 1 disables the parallel backend).
    pub threads: usize,
}

impl Default for DispatchEnv {
    fn default() -> Self {
        DispatchEnv {
            fused_enabled: true,
            fused_backward: Override::Auto,
            norm_chunk_bytes: 256 << 20,
            fwd_chunk_bytes: 256 << 20,
            threads: default_threads(),
        }
    }
}

impl DispatchEnv {
    /// Read every `DORA_*` variable once, with malformed-value fallbacks
    /// to the defaults (defaults require no config).
    pub fn from_env() -> Self {
        let get = |key: &str| std::env::var(key).ok();
        DispatchEnv {
            fused_enabled: parse_bool(get("DORA_FUSED").as_deref(), true),
            fused_backward: Override::parse(get("DORA_FUSED_BACKWARD").as_deref()),
            norm_chunk_bytes: parse_mb(get("DORA_NORM_CHUNK_MB").as_deref(), 256 << 20),
            fwd_chunk_bytes: parse_mb(get("DORA_FWD_CHUNK_MB").as_deref(), 256 << 20),
            threads: parse_threads(get("DORA_THREADS").as_deref(), default_threads()),
        }
    }
}

/// Everything the dispatch decision depends on, for one compose call.
#[derive(Debug, Clone, Copy)]
pub struct ComposeCtx {
    pub act: ActShape,
    /// Training (autograd active) vs inference.
    pub training: bool,
    /// An accelerator backend with the fused kernels available. On this
    /// CPU-PJRT testbed this means "the fused AOT artifact is loaded";
    /// on CUDA it means device.is_cuda and Triton importable.
    pub accelerator: bool,
    /// Contiguous activation layout (non-contiguous routes to Tier 3).
    pub contiguous: bool,
    /// Magnitude broadcasts exclusively along the last dim (the Appendix-B
    /// shape guard: [1, C, 1, 1]-style conv broadcasts route to Tier 3).
    pub mag_last_dim: bool,
    /// Dropout probability (p > 0 uses the chunked path, Tier 3).
    pub dropout_p: f32,
}

impl ComposeCtx {
    pub fn inference(act: ActShape) -> Self {
        ComposeCtx {
            act,
            training: false,
            accelerator: true,
            contiguous: true,
            mag_last_dim: true,
            dropout_p: 0.0,
        }
    }

    pub fn training(act: ActShape) -> Self {
        ComposeCtx { training: true, ..Self::inference(act) }
    }
}

/// Is the activation above the auto-mode crossover?
pub fn above_crossover(act: ActShape) -> bool {
    act.d_out >= CROSSOVER_DOUT && act.elems() >= CROSSOVER_ELEMS
}

/// The dispatch decision (paper Figure 2).
pub fn select_tier(env: &DispatchEnv, ctx: &ComposeCtx) -> Tier {
    // Universal Tier-3 gates: kernels unavailable, disabled, layout.
    if !env.fused_enabled
        || !ctx.accelerator
        || !ctx.contiguous
        || !ctx.mag_last_dim
        || ctx.dropout_p > 0.0
    {
        return Tier::Eager;
    }
    if !ctx.training {
        return Tier::FusedForward;
    }
    match env.fused_backward {
        Override::ForceOn => Tier::FusedBackward,
        Override::ForceOff => Tier::Eager,
        Override::Auto => {
            if above_crossover(ctx.act) {
                Tier::FusedBackward
            } else {
                Tier::Eager
            }
        }
    }
}

/// The dispatch surface of the kernel-backend layer: the tier decision of
/// [`select_tier`] plus a runnable backend handle from the process-wide
/// [`KernelRegistry`](crate::kernels::KernelRegistry) (fused tiers map to
/// the single-pass or parallel-tiled backend depending on threads and
/// working-set size; Tier 3 maps to the eager chain).
pub fn select_kernel(env: &DispatchEnv, ctx: &ComposeCtx) -> crate::kernels::KernelChoice {
    crate::kernels::registry().select(env, ctx)
}

/// Per-module dispatch statistics over a model's inventory — reproduces
/// the paper's "~71% of adapted modules dispatch to Tier 1" measurement.
#[derive(Debug, Clone, Default)]
pub struct TierStats {
    pub tier1: usize,
    pub tier2: usize,
    pub tier3: usize,
}

impl TierStats {
    pub fn record(&mut self, t: Tier) {
        match t {
            Tier::FusedBackward => self.tier1 += 1,
            Tier::FusedForward => self.tier2 += 1,
            Tier::Eager => self.tier3 += 1,
        }
    }

    pub fn total(&self) -> usize {
        self.tier1 + self.tier2 + self.tier3
    }

    pub fn frac_tier1(&self) -> f64 {
        self.tier1 as f64 / self.total().max(1) as f64
    }
}

/// Dispatch every adapted module of a model at the given batch*seq rows,
/// in training mode (the §4 per-layer statistic).
pub fn model_tier_stats(
    env: &DispatchEnv,
    spec: &crate::models::ModelSpec,
    rank: usize,
    rows: usize,
) -> TierStats {
    let mut stats = TierStats::default();
    for (_, shape, count) in spec.inventory(rank) {
        let ctx = ComposeCtx::training(ActShape::new(rows, shape.d_out));
        let tier = select_tier(env, &ctx);
        for _ in 0..count {
            stats.record(tier);
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, prop_assert};

    fn env() -> DispatchEnv {
        DispatchEnv::default()
    }

    #[test]
    fn training_above_crossover_is_tier1() {
        let ctx = ComposeCtx::training(ActShape::new(4096, 4096));
        assert_eq!(select_tier(&env(), &ctx), Tier::FusedBackward);
    }

    #[test]
    fn inference_is_tier2_regardless_of_size() {
        // Tier 2 has no crossover gate in the paper's Figure 2.
        let small = ComposeCtx::inference(ActShape::new(8, 64));
        assert_eq!(select_tier(&env(), &small), Tier::FusedForward);
    }

    #[test]
    fn sub_crossover_training_falls_back() {
        // KV projection: d_out = 1024 < 2048 -> Tier 3 even with huge rows.
        let ctx = ComposeCtx::training(ActShape::new(65536, 1024));
        assert_eq!(select_tier(&env(), &ctx), Tier::Eager);
        // Large d_out but tiny batch: below the elems gate.
        let ctx = ComposeCtx::training(ActShape::new(16, 4096));
        assert_eq!(select_tier(&env(), &ctx), Tier::Eager);
    }

    #[test]
    fn force_flags_override_crossover() {
        let mut e = env();
        e.fused_backward = Override::ForceOn;
        let small = ComposeCtx::training(ActShape::new(16, 256));
        assert_eq!(select_tier(&e, &small), Tier::FusedBackward);
        e.fused_backward = Override::ForceOff;
        let big = ComposeCtx::training(ActShape::new(8192, 8192));
        assert_eq!(select_tier(&e, &big), Tier::Eager);
    }

    #[test]
    fn global_kill_switch_beats_everything() {
        let mut e = env();
        e.fused_enabled = false;
        e.fused_backward = Override::ForceOn;
        let ctx = ComposeCtx::training(ActShape::new(8192, 8192));
        assert_eq!(select_tier(&e, &ctx), Tier::Eager);
        let ctx = ComposeCtx::inference(ActShape::new(8192, 8192));
        assert_eq!(select_tier(&e, &ctx), Tier::Eager);
    }

    #[test]
    fn shape_guard_and_layout_gates() {
        let mut ctx = ComposeCtx::inference(ActShape::new(8192, 8192));
        ctx.mag_last_dim = false; // conv-style [1,C,1,1] broadcast
        assert_eq!(select_tier(&env(), &ctx), Tier::Eager);
        let mut ctx = ComposeCtx::inference(ActShape::new(8192, 8192));
        ctx.contiguous = false;
        assert_eq!(select_tier(&env(), &ctx), Tier::Eager);
        let mut ctx = ComposeCtx::training(ActShape::new(8192, 8192));
        ctx.dropout_p = 0.1; // chunked dropout path
        assert_eq!(select_tier(&env(), &ctx), Tier::Eager);
    }

    #[test]
    fn cpu_only_is_always_eager() {
        let mut ctx = ComposeCtx::training(ActShape::new(8192, 8192));
        ctx.accelerator = false;
        assert_eq!(select_tier(&env(), &ctx), Tier::Eager);
    }

    #[test]
    fn paper_71_percent_tier1() {
        // §4: in the evaluated VLMs, KV projections fall below the
        // crossover -> 5 of 7 module kinds (~71%) dispatch to Tier 1.
        let rows = 4096; // bs=1, seq=4096
        for spec in crate::models::MODELS.iter() {
            let stats = model_tier_stats(&env(), spec, 384, rows);
            let frac = stats.frac_tier1();
            assert!(
                (0.70..0.72).contains(&frac),
                "{}: tier1 fraction {frac}",
                spec.name
            );
        }
    }

    #[test]
    fn property_dispatch_total_and_deterministic() {
        check("dispatch is total + deterministic", 300, |g| {
            let ctx = ComposeCtx {
                act: ActShape::new(g.usize_in(1, 1 << 16), g.usize_in(1, 1 << 14)),
                training: g.bool(),
                accelerator: g.bool(),
                contiguous: g.bool(),
                mag_last_dim: g.bool(),
                dropout_p: if g.bool() { 0.0 } else { 0.1 },
            };
            let e = DispatchEnv {
                fused_enabled: g.bool(),
                fused_backward: g.pick(&[Override::Auto, Override::ForceOn, Override::ForceOff]),
                ..DispatchEnv::default()
            };
            let t1 = select_tier(&e, &ctx);
            let t2 = select_tier(&e, &ctx);
            prop_assert(t1 == t2, "nondeterministic dispatch")?;
            // Soundness: fused tiers only ever run with kernels available,
            // contiguous last-dim-broadcast activations, p=0.
            if t1 != Tier::Eager {
                prop_assert(
                    e.fused_enabled && ctx.accelerator && ctx.contiguous
                        && ctx.mag_last_dim && ctx.dropout_p == 0.0,
                    format!("unsound fused dispatch: {ctx:?}"),
                )?;
            }
            // Tier 1 only in training; Tier 2 only in inference.
            match t1 {
                Tier::FusedBackward => prop_assert(ctx.training, "t1 outside training")?,
                Tier::FusedForward => prop_assert(!ctx.training, "t2 in training")?,
                Tier::Eager => {}
            }
            Ok(())
        });
    }

    #[test]
    fn env_parsing_roundtrip() {
        // Uses real env vars; serialize through a lock-free single test.
        std::env::set_var("DORA_FUSED", "0");
        std::env::set_var("DORA_FUSED_BACKWARD", "1");
        std::env::set_var("DORA_NORM_CHUNK_MB", "64");
        std::env::set_var("DORA_THREADS", "3");
        let e = DispatchEnv::from_env();
        assert!(!e.fused_enabled);
        assert_eq!(e.fused_backward, Override::ForceOn);
        assert_eq!(e.norm_chunk_bytes, 64 << 20);
        assert_eq!(e.threads, 3);
        // Malformed values fall back to defaults rather than erroring.
        std::env::set_var("DORA_FUSED", "maybe");
        std::env::set_var("DORA_FUSED_BACKWARD", "2");
        std::env::set_var("DORA_NORM_CHUNK_MB", "lots");
        std::env::set_var("DORA_THREADS", "0");
        let e = DispatchEnv::from_env();
        assert!(e.fused_enabled);
        assert_eq!(e.fused_backward, Override::Auto);
        assert_eq!(e.norm_chunk_bytes, 256 << 20);
        assert!(e.threads >= 1);
        std::env::remove_var("DORA_FUSED");
        std::env::remove_var("DORA_FUSED_BACKWARD");
        std::env::remove_var("DORA_NORM_CHUNK_MB");
        std::env::remove_var("DORA_THREADS");
        let e = DispatchEnv::from_env();
        assert!(e.fused_enabled);
        assert_eq!(e.fused_backward, Override::Auto);
        assert_eq!(e.norm_chunk_bytes, 256 << 20);
    }

    #[test]
    fn override_parse_tristate() {
        for v in ["1", "true", "on", "ON", " 1 ", "True"] {
            assert_eq!(Override::parse(Some(v)), Override::ForceOn, "{v:?}");
        }
        for v in ["0", "false", "off", "OFF", " 0 ", "False"] {
            assert_eq!(Override::parse(Some(v)), Override::ForceOff, "{v:?}");
        }
        // Unset and malformed both resolve to Auto.
        for v in [None, Some("2"), Some("yes"), Some(""), Some("auto"), Some("-1")] {
            assert_eq!(Override::parse(v), Override::Auto, "{v:?}");
        }
    }

    #[test]
    fn numeric_env_parsers_fall_back_on_garbage() {
        assert_eq!(parse_mb(Some("64"), 256 << 20), 64 << 20);
        assert_eq!(parse_mb(Some(" 8 "), 256 << 20), 8 << 20);
        for bad in [None, Some("lots"), Some("-3"), Some("1.5"), Some("")] {
            assert_eq!(parse_mb(bad, 256 << 20), 256 << 20, "{bad:?}");
        }
        // Overflowing-but-numeric megabyte counts also fall back instead
        // of wrapping to a nonsense budget.
        assert_eq!(parse_mb(Some("17592186044416"), 256 << 20), 256 << 20);
        assert_eq!(parse_threads(Some("4"), 2), 4);
        for bad in [None, Some("0"), Some("-1"), Some("many"), Some("")] {
            assert_eq!(parse_threads(bad, 2), 2, "{bad:?}");
        }
        assert!(!parse_bool(Some("off"), true));
        assert!(parse_bool(Some("junk"), true));
        assert!(!parse_bool(Some("junk"), false));
    }

    #[test]
    fn select_kernel_returns_registry_handles() {
        let e = DispatchEnv { threads: 4, ..DispatchEnv::default() };
        // Tier 3 shape -> the eager backend handle.
        let small = ComposeCtx::training(ActShape::new(16, 256));
        let c = select_kernel(&e, &small);
        assert_eq!(c.tier, Tier::Eager);
        assert_eq!(c.backend.kind(), crate::kernels::BackendKind::Eager);
        // Tier selection agrees with the bare-enum path for any ctx.
        let big = ComposeCtx::training(ActShape::new(8192, 8192));
        assert_eq!(select_kernel(&e, &big).tier, select_tier(&e, &big));
        assert!(select_kernel(&e, &big).is_fused());
    }
}
