//! Named DoRA adapters and their on-disk checkpoint store.
//!
//! The paper's cost story is hundreds of adapted modules per base model;
//! the serving story that follows is *many adapters per server*. An
//! [`Adapter`] is one named, self-describing unit: the config it was
//! trained against, its `ConfigInfo`-derived rank/scale, the init/data
//! seed, the optimizer step it was checkpointed at, and the parameter
//! leaves themselves. An [`AdapterStore`] persists adapters as versioned
//! binary checkpoints with integrity checks and guarantees a
//! **bitwise-identical** round trip (raw little-endian leaf payloads —
//! no float formatting anywhere near the parameters).
//!
//! Checkpoint format (version 1):
//!
//! ```text
//! [0..8)    magic  b"DORACKPT"
//! [8..12)   format version, u32 LE
//! [12..16)  header length H, u32 LE
//! [16..16+H) header JSON: name/config/rank/scale/seed/step +
//!            per-leaf {name, shape, dtype} for frozen and trainable
//! [..]      payload: leaf data, frozen then trainable, raw LE bytes
//! [-8..]    FNV-1a 64 checksum over every preceding byte, u64 LE
//! ```
//!
//! Writes go through a same-directory temp file + rename, so a crashed
//! writer never leaves a half checkpoint under the adapter's name — the
//! hot-swap protocol (server reloads a named adapter while serving)
//! relies on this.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::ops::{AdapterParams, AdapterVariant, Precision};
use crate::runtime::{ConfigInfo, Tensor, TensorData};
use crate::util::json::{self, Json};

const MAGIC: &[u8; 8] = b"DORACKPT";
pub const FORMAT_VERSION: u32 = 1;
const CKPT_EXT: &str = "ckpt";

/// Typed checkpoint-integrity failure. Every structural fault a stored
/// checkpoint can have maps to one variant, carried inside the
/// `anyhow` chain [`AdapterStore::load`] returns — callers that need to
/// distinguish fault classes (retry vs quarantine vs refuse) use
/// `err.downcast_ref::<CkptError>()` instead of string-matching.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CkptError {
    /// The file is not a DORACKPT checkpoint at all.
    BadMagic,
    /// A format version this build does not read.
    WrongVersion { found: u32 },
    /// The file ends before the declared header/payload/checksum.
    Truncated { expected: usize, got: usize },
    /// The FNV-1a64 over the body disagrees with the stored checksum.
    ChecksumMismatch { stored: u64, computed: u64 },
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::BadMagic => write!(f, "not a DoRA checkpoint (bad magic)"),
            CkptError::WrongVersion { found } => write!(
                f,
                "checkpoint format version {found} (this build reads {FORMAT_VERSION})"
            ),
            CkptError::Truncated { expected, got } => {
                write!(f, "checkpoint truncated: {got} bytes of an expected {expected}")
            }
            CkptError::ChecksumMismatch { stored, computed } => write!(
                f,
                "checkpoint checksum mismatch: stored {stored:#018x}, computed {computed:#018x}"
            ),
        }
    }
}

impl std::error::Error for CkptError {}

/// One named adapter: identity + provenance + parameter leaves.
#[derive(Debug, Clone)]
pub struct Adapter {
    /// Store key (validated: `[A-Za-z0-9_-]{1,64}`).
    pub name: String,
    /// Model config the leaves are shaped for ("tiny"/"small"/"e2e").
    pub config: String,
    /// Adapter rank (from the config at creation).
    pub rank: usize,
    /// Compose scale `s` (from the config at creation).
    pub scale: f64,
    /// Parameter-init + data seed the adapter was trained from.
    pub seed: u64,
    /// Optimizer step the leaves were captured at.
    pub step: i32,
    /// Effective-batch provenance: data-parallel gradient workers the
    /// training run used (1 = single-engine path).
    pub train_workers: u32,
    /// Effective-batch provenance: micro-steps accumulated per optimizer
    /// update (effective batch = `grad_accum * train_batch`).
    pub grad_accum: u32,
    /// Effective batch size (sequences per optimizer update) the leaves
    /// were trained with. 0 = unrecorded (a pre-provenance checkpoint).
    pub effective_batch: u32,
    /// Adapter variant the leaves were trained as. Additive header key:
    /// checkpoints written before the variant axis decode as `Dora`.
    pub variant: AdapterVariant,
    /// Precision the adapter was trained under. Additive header key:
    /// pre-precision checkpoints decode as `F32`. The leaves themselves
    /// are ALWAYS stored as f32 master weights — precision records the
    /// operating point (how forward/serving rounds), not the payload
    /// encoding, so the bitwise round-trip guarantee is unchanged.
    pub precision: Precision,
    /// Frozen + trainable leaves, manifest flatten order.
    pub params: AdapterParams,
}

impl Adapter {
    /// Build an adapter from a config and its parameter leaves,
    /// validating the name and the leaf counts.
    pub fn new(
        name: impl Into<String>,
        info: &ConfigInfo,
        seed: u64,
        step: i32,
        params: AdapterParams,
    ) -> Result<Adapter> {
        let name = name.into();
        validate_name(&name)?;
        if !params.matches(info) {
            bail!(
                "adapter {name:?}: got {}+{} leaves, config {} wants {}+{}",
                params.frozen.len(),
                params.trainable.len(),
                info.name,
                info.frozen.len(),
                info.trainable.len()
            );
        }
        Ok(Adapter {
            name,
            config: info.name.clone(),
            rank: info.rank,
            scale: info.scale,
            seed,
            step,
            train_workers: 1,
            grad_accum: 1,
            effective_batch: info.train_batch as u32,
            variant: AdapterVariant::Dora,
            precision: Precision::F32,
            params,
        })
    }

    /// Record the training run's effective-batch provenance (the
    /// data-parallel trainer calls this when snapshotting).
    pub fn with_provenance(
        mut self,
        train_workers: u32,
        grad_accum: u32,
        effective_batch: u32,
    ) -> Adapter {
        self.train_workers = train_workers;
        self.grad_accum = grad_accum;
        self.effective_batch = effective_batch;
        self
    }

    /// Record the adapter variant the leaves were trained as.
    pub fn with_variant(mut self, variant: AdapterVariant) -> Adapter {
        self.variant = variant;
        self
    }

    /// Record the precision the adapter was trained under.
    pub fn with_precision(mut self, precision: Precision) -> Adapter {
        self.precision = precision;
        self
    }

    /// Total parameter elements across all leaves.
    pub fn n_elems(&self) -> usize {
        self.params
            .frozen
            .iter()
            .chain(&self.params.trainable)
            .map(Tensor::elems)
            .sum()
    }

    // ---- binary encoding ---------------------------------------------------

    /// Serialize to the versioned checkpoint format.
    pub fn encode(&self) -> Vec<u8> {
        let leaf_meta = |ts: &[Tensor]| {
            Json::Arr(
                ts.iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("dtype", Json::Str(t.dtype_str().to_string())),
                            (
                                "shape",
                                Json::Arr(
                                    t.shape.iter().map(|&d| Json::Num(d as f64)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            )
        };
        let header = Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("config", Json::Str(self.config.clone())),
            ("rank", Json::Num(self.rank as f64)),
            ("scale", Json::Num(self.scale)),
            // Stored as a string: u64 seeds above 2^53 would lose bits
            // through the JSON f64 number model.
            ("seed", Json::Str(self.seed.to_string())),
            ("step", Json::Num(self.step as f64)),
            ("train_workers", Json::Num(self.train_workers as f64)),
            ("grad_accum", Json::Num(self.grad_accum as f64)),
            ("effective_batch", Json::Num(self.effective_batch as f64)),
            ("variant", Json::Str(self.variant.as_str().to_string())),
            ("precision", Json::Str(self.precision.as_str().to_string())),
            ("frozen", leaf_meta(&self.params.frozen)),
            ("trainable", leaf_meta(&self.params.trainable)),
        ])
        .to_string();

        let mut out = Vec::with_capacity(16 + header.len() + 4 * self.n_elems() + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for t in self.params.frozen.iter().chain(&self.params.trainable) {
            match &t.data {
                TensorData::F32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::I32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        let sum = fnv1a64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Deserialize and verify a checkpoint. Every integrity failure
    /// (bad magic, unknown version, truncation, checksum mismatch,
    /// header/payload disagreement) is a contextful `Err`.
    pub fn decode(bytes: &[u8]) -> Result<Adapter> {
        let (header, payload_off) = decode_header(bytes)?;
        // Expected total size from the header's leaf metadata — checked
        // BEFORE the checksum so a cut-off file reports the typed
        // truncation fault, not a checksum mismatch.
        // Checked arithmetic throughout: the dims come from the (possibly
        // corrupt) header, and an overflowing product must be "unreadable
        // checkpoint", never a debug-build panic.
        let leaf_bytes = |key: &str| -> Result<usize> {
            let mut total = 0usize;
            for meta in header.get(key)?.as_arr()? {
                let bytes = meta
                    .get("shape")?
                    .as_shape()?
                    .iter()
                    .try_fold(4usize, |acc, &d| acc.checked_mul(d))
                    .context("checkpoint header declares an impossibly large leaf")?;
                total = total
                    .checked_add(bytes)
                    .context("checkpoint header declares an impossibly large payload")?;
            }
            Ok(total)
        };
        let frozen_bytes = leaf_bytes("frozen")?;
        let trainable_bytes = leaf_bytes("trainable")?;
        let expected = payload_off
            .checked_add(frozen_bytes)
            .and_then(|n| n.checked_add(trainable_bytes))
            .and_then(|n| n.checked_add(8))
            .context("checkpoint header declares an impossibly large payload")?;
        if bytes.len() < expected {
            return Err(anyhow::Error::new(CkptError::Truncated {
                expected,
                got: bytes.len(),
            }));
        }
        if bytes.len() > expected {
            bail!(
                "checkpoint has {} trailing bytes after the checksum",
                bytes.len() - expected
            );
        }
        let body = &bytes[..bytes.len() - 8];
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        let computed = fnv1a64(body);
        if stored != computed {
            return Err(anyhow::Error::new(CkptError::ChecksumMismatch { stored, computed }));
        }

        let mut pos = payload_off;
        let payload_end = bytes.len() - 8;
        let mut read_leaves = |metas: &[Json]| -> Result<Vec<Tensor>> {
            let mut out = Vec::with_capacity(metas.len());
            for meta in metas {
                let shape = meta.get("shape")?.as_shape()?;
                let dtype = meta.get("dtype")?.as_str()?.to_string();
                let elems: usize = shape.iter().product();
                let nbytes = 4 * elems;
                if pos + nbytes > payload_end {
                    bail!("checkpoint payload truncated at leaf with shape {shape:?}");
                }
                let raw = &bytes[pos..pos + nbytes];
                pos += nbytes;
                let t = match dtype.as_str() {
                    "f32" => Tensor::f32(
                        shape,
                        raw.chunks_exact(4)
                            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    ),
                    "i32" => Tensor::i32(
                        shape,
                        raw.chunks_exact(4)
                            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    ),
                    other => bail!("checkpoint leaf has unknown dtype {other:?}"),
                };
                out.push(t);
            }
            Ok(out)
        };
        let frozen = read_leaves(header.get("frozen")?.as_arr()?)?;
        let trainable = read_leaves(header.get("trainable")?.as_arr()?)?;
        if pos != payload_end {
            bail!(
                "checkpoint payload has {} trailing bytes after the last leaf",
                payload_end - pos
            );
        }

        let name = header.get("name")?.as_str()?.to_string();
        validate_name(&name)?;
        let seed_s = header.get("seed")?.as_str()?;
        let seed = seed_s
            .parse::<u64>()
            .with_context(|| format!("checkpoint seed {seed_s:?} is not a u64"))?;
        // Provenance keys are additive (format version unchanged):
        // checkpoints written before the data-parallel trainer default to
        // the single-engine provenance, with effective_batch 0 =
        // "unrecorded".
        let prov = |key: &str, default: u32| -> u32 {
            header
                .opt(key)
                .and_then(|v| v.as_f64().ok())
                .map(|v| v as u32)
                .unwrap_or(default)
        };
        // The variant key is additive too: pre-variant checkpoints are
        // DoRA by construction. An unknown variant string is an error —
        // silently treating it as DoRA would serve the wrong math.
        let variant = match header.opt("variant") {
            Some(v) => AdapterVariant::parse(v.as_str()?)
                .context("parsing checkpoint adapter variant")?,
            None => AdapterVariant::Dora,
        };
        // Precision follows the same additive contract: absent = f32
        // (every pre-precision checkpoint trained at f32), unknown = an
        // error — silently serving at the wrong operating point would
        // break the bf16 determinism story.
        let precision = match header.opt("precision") {
            Some(v) => {
                Precision::parse(v.as_str()?).context("parsing checkpoint precision")?
            }
            None => Precision::F32,
        };
        Ok(Adapter {
            name,
            config: header.get("config")?.as_str()?.to_string(),
            rank: header.get("rank")?.as_usize()?,
            scale: header.get("scale")?.as_f64()?,
            seed,
            step: header.get("step")?.as_i64()? as i32,
            train_workers: prov("train_workers", 1),
            grad_accum: prov("grad_accum", 1),
            effective_batch: prov("effective_batch", 0),
            variant,
            precision,
            params: AdapterParams { frozen, trainable },
        })
    }
}

/// Parse + validate the fixed-size prefix and the JSON header; returns
/// the header value and the payload offset.
fn decode_header(bytes: &[u8]) -> Result<(Json, usize)> {
    if bytes.len() < 16 {
        return Err(anyhow::Error::new(CkptError::Truncated {
            expected: 16,
            got: bytes.len(),
        }));
    }
    if &bytes[..8] != MAGIC {
        return Err(anyhow::Error::new(CkptError::BadMagic));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != FORMAT_VERSION {
        return Err(anyhow::Error::new(CkptError::WrongVersion { found: version }));
    }
    let hlen = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    if bytes.len() < 16 + hlen {
        return Err(anyhow::Error::new(CkptError::Truncated {
            expected: 16 + hlen,
            got: bytes.len(),
        }));
    }
    let text = std::str::from_utf8(&bytes[16..16 + hlen]).context("checkpoint header utf-8")?;
    let header = json::parse(text).context("parsing checkpoint header")?;
    Ok((header, 16 + hlen))
}

/// FNV-1a 64-bit — the checkpoint integrity hash (not cryptographic;
/// guards against truncation and bit rot, not adversaries).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Adapter names become file names: restrict to a safe charset so a name
/// can never traverse out of the store directory.
pub fn validate_name(name: &str) -> Result<()> {
    if name.is_empty() || name.len() > 64 {
        bail!("adapter name must be 1..=64 chars, got {:?}", name.len());
    }
    if !name
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
    {
        bail!("adapter name {name:?} may only contain [A-Za-z0-9_-]");
    }
    Ok(())
}

/// Header-level summary of a stored checkpoint (no payload decode).
#[derive(Debug, Clone)]
pub struct AdapterSummary {
    pub name: String,
    pub config: String,
    pub rank: usize,
    pub step: i32,
    /// Effective batch size the checkpoint was trained with
    /// (0 = unrecorded pre-provenance checkpoint).
    pub effective_batch: u32,
    /// Adapter variant (pre-variant checkpoints list as `Dora`).
    pub variant: AdapterVariant,
    /// Precision the adapter was trained under (pre-precision
    /// checkpoints list as `F32`).
    pub precision: Precision,
    pub file_bytes: u64,
}

/// A directory of named adapter checkpoints.
#[derive(Debug, Clone)]
pub struct AdapterStore {
    dir: PathBuf,
}

impl AdapterStore {
    /// Open (creating if needed) a store rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<AdapterStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating adapter store directory {dir:?}"))?;
        Ok(AdapterStore { dir })
    }

    /// Open an explicit directory when one was given (e.g. a `--store`
    /// flag), the default store otherwise — the one resolution rule for
    /// every CLI/example call site.
    pub fn open_or_default(dir: Option<&str>) -> Result<AdapterStore> {
        match dir {
            Some(dir) => Self::open(dir),
            None => Self::open(Self::default_dir()),
        }
    }

    /// Default store directory: `$DORA_ADAPTERS` or `<repo>/adapters`.
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("DORA_ADAPTERS") {
            return PathBuf::from(dir);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("adapters")
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Checkpoint path for a (validated) adapter name.
    pub fn path_for(&self, name: &str) -> Result<PathBuf> {
        validate_name(name)?;
        Ok(self.dir.join(format!("{name}.{CKPT_EXT}")))
    }

    pub fn exists(&self, name: &str) -> bool {
        self.path_for(name).map(|p| p.exists()).unwrap_or(false)
    }

    /// Persist an adapter under its name (atomic: temp file + rename, so
    /// a concurrent hot-loader never observes a partial checkpoint). The
    /// temp name carries a process-wide counter as well as the pid, so
    /// two threads saving the same adapter concurrently (checkpointing
    /// trainer + explicit save) never share a temp file.
    pub fn save(&self, adapter: &Adapter) -> Result<PathBuf> {
        static SAVE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = SAVE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let path = self.path_for(&adapter.name)?;
        let tmp = self.dir.join(format!(
            "{}.{CKPT_EXT}.tmp{}-{seq}",
            adapter.name,
            std::process::id()
        ));
        let bytes = adapter.encode();
        std::fs::write(&tmp, &bytes).with_context(|| format!("writing {tmp:?}"))?;
        if let Err(e) = std::fs::rename(&tmp, &path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(anyhow::Error::from(e)
                .context(format!("renaming {tmp:?} into place")));
        }
        Ok(path)
    }

    /// Load and integrity-check a named adapter.
    pub fn load(&self, name: &str) -> Result<Adapter> {
        let path = self.path_for(name)?;
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading adapter checkpoint {path:?}"))?;
        let adapter =
            Adapter::decode(&bytes).with_context(|| format!("decoding {path:?}"))?;
        if adapter.name != name {
            bail!(
                "checkpoint {path:?} is named {:?} inside, expected {name:?}",
                adapter.name
            );
        }
        Ok(adapter)
    }

    /// Delete a named checkpoint.
    pub fn remove(&self, name: &str) -> Result<()> {
        let path = self.path_for(name)?;
        std::fs::remove_file(&path).with_context(|| format!("removing {path:?}"))
    }

    /// Header-level summaries of every checkpoint in the store, sorted
    /// by name. Only the fixed prefix + JSON header are read from each
    /// file — never the leaf payload, so listing a store of multi-MB
    /// checkpoints stays cheap. Unreadable/foreign files are skipped,
    /// not fatal.
    pub fn list(&self) -> Result<Vec<AdapterSummary>> {
        let mut out = Vec::new();
        let entries = std::fs::read_dir(&self.dir)
            .with_context(|| format!("listing adapter store {:?}", self.dir))?;
        for entry in entries {
            let entry = entry?;
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some(CKPT_EXT) {
                continue;
            }
            let Ok(file_bytes) = entry.metadata().map(|m| m.len()) else { continue };
            // Unreadable/foreign entries are skipped WITH a warning, not
            // silently and never fatally: one corrupt checkpoint must not
            // hide the rest of the store.
            let header_bytes = match read_header_bytes(&path, file_bytes) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("adapter store: skipping unreadable {path:?}: {e:#}");
                    continue;
                }
            };
            let header = match decode_header(&header_bytes) {
                Ok((header, _)) => header,
                Err(e) => {
                    eprintln!("adapter store: skipping unreadable {path:?}: {e:#}");
                    continue;
                }
            };
            let field_str = |k: &str| {
                header.get(k).ok().and_then(|v| v.as_str().ok().map(String::from))
            };
            let (Some(name), Some(config)) = (field_str("name"), field_str("config")) else {
                continue;
            };
            out.push(AdapterSummary {
                name,
                config,
                rank: header
                    .get("rank")
                    .ok()
                    .and_then(|v| v.as_usize().ok())
                    .unwrap_or(0),
                step: header
                    .get("step")
                    .ok()
                    .and_then(|v| v.as_i64().ok())
                    .unwrap_or(0) as i32,
                effective_batch: header
                    .opt("effective_batch")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u32,
                variant: header
                    .opt("variant")
                    .and_then(|v| v.as_str().ok())
                    .and_then(|s| AdapterVariant::parse(s).ok())
                    .unwrap_or_default(),
                precision: header
                    .opt("precision")
                    .and_then(|v| v.as_str().ok())
                    .and_then(|s| Precision::parse(s).ok())
                    .unwrap_or_default(),
                file_bytes,
            });
        }
        out.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(out)
    }
}

/// Read just the fixed prefix + JSON header of a checkpoint file (the
/// `list()` fast path — payloads are never touched).
fn read_header_bytes(path: &Path, file_bytes: u64) -> Result<Vec<u8>> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)?;
    let mut prefix = [0u8; 16];
    f.read_exact(&mut prefix)?;
    // Sanity-check magic before trusting anything else.
    if &prefix[..8] != MAGIC {
        bail!("bad magic");
    }
    let hlen = u32::from_le_bytes(prefix[12..16].try_into().unwrap()) as u64;
    // A corrupt length field must not drive the allocation: the header
    // can never extend past the file itself, so a lying field makes the
    // file "unreadable, skipped", not a multi-GiB resize.
    if 16 + hlen > file_bytes {
        bail!("header length {hlen} exceeds file size {file_bytes}");
    }
    let mut buf = prefix.to_vec();
    buf.resize(16 + hlen as usize, 0);
    f.read_exact(&mut buf[16..])?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngine;

    /// Per-test scratch store (unique dir, removed on drop).
    struct TestStore {
        store: AdapterStore,
        dir: PathBuf,
    }

    impl TestStore {
        fn new(tag: &str) -> TestStore {
            let dir = std::env::temp_dir()
                .join(format!("dora_adapter_store_{}_{tag}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            TestStore { store: AdapterStore::open(&dir).unwrap(), dir }
        }
    }

    impl Drop for TestStore {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }

    fn tiny_adapter(name: &str, seed: i32) -> Adapter {
        let eng = NativeEngine::new();
        let info = eng.config("tiny").unwrap();
        let leaves = eng
            .run("init_tiny", &[crate::runtime::Tensor::scalar_i32(seed)])
            .unwrap();
        let params = AdapterParams::from_flat(info, leaves).unwrap();
        Adapter::new(name, info, seed as u64, 0, params).unwrap()
    }

    #[test]
    fn provenance_roundtrips_and_defaults_for_pre_provenance_headers() {
        let ts = TestStore::new("prov");
        // Fresh adapters carry the single-engine provenance by default.
        let fresh = tiny_adapter("fresh", 1);
        assert_eq!((fresh.train_workers, fresh.grad_accum), (1, 1));
        let info = NativeEngine::new().config("tiny").unwrap();
        assert_eq!(fresh.effective_batch as usize, info.train_batch);
        // Recorded provenance survives the checkpoint round trip.
        let a = tiny_adapter("prov", 3).with_provenance(4, 2, 8);
        ts.store.save(&a).unwrap();
        let back = ts.store.load("prov").unwrap();
        assert_eq!(back.train_workers, 4);
        assert_eq!(back.grad_accum, 2);
        assert_eq!(back.effective_batch, 8);

        // A checkpoint written before the provenance keys existed decodes
        // with the defaults (workers/accum 1, effective batch unrecorded).
        let header = Json::obj(vec![
            ("name", Json::Str("old".into())),
            ("config", Json::Str("tiny".into())),
            ("rank", Json::Num(4.0)),
            ("scale", Json::Num(2.0)),
            ("seed", Json::Str("0".into())),
            ("step", Json::Num(0.0)),
            ("frozen", Json::Arr(vec![])),
            ("trainable", Json::Arr(vec![])),
        ])
        .to_string();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        let sum = fnv1a64(&bytes);
        bytes.extend_from_slice(&sum.to_le_bytes());
        let old = Adapter::decode(&bytes).unwrap();
        assert_eq!(old.train_workers, 1);
        assert_eq!(old.grad_accum, 1);
        assert_eq!(old.effective_batch, 0);
        // The variant key is additive the same way: no key = DoRA.
        assert_eq!(old.variant, AdapterVariant::Dora);
        // And precision: pre-precision checkpoints decode as f32.
        assert_eq!(old.precision, Precision::F32);
    }

    #[test]
    fn variant_roundtrips_and_lists() {
        let ts = TestStore::new("variant");
        // Fresh adapters are DoRA unless tagged.
        assert_eq!(tiny_adapter("fresh", 1).variant, AdapterVariant::Dora);
        let a = tiny_adapter("rs", 5).with_variant(AdapterVariant::RsLora);
        ts.store.save(&a).unwrap();
        let back = ts.store.load("rs").unwrap();
        assert_eq!(back.variant, AdapterVariant::RsLora);
        // Stable encoding holds with the new header key present.
        assert_eq!(a.encode(), back.encode());
        // The header-level listing surfaces the variant without a payload
        // decode.
        ts.store.save(&tiny_adapter("plain", 6)).unwrap();
        let listed = ts.store.list().unwrap();
        assert_eq!(listed.len(), 2);
        assert_eq!(listed[0].name, "plain");
        assert_eq!(listed[0].variant, AdapterVariant::Dora);
        assert_eq!(listed[1].name, "rs");
        assert_eq!(listed[1].variant, AdapterVariant::RsLora);
        // An unknown variant string in the header is a decode error, not
        // a silent DoRA fallback.
        let mut bytes = a.encode();
        let pos = bytes
            .windows(8)
            .position(|w| w == b"\"rslora\"")
            .expect("variant value in header");
        bytes[pos + 1..pos + 7].copy_from_slice(b"rslorb");
        let body_end = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..body_end]);
        bytes[body_end..].copy_from_slice(&sum.to_le_bytes());
        let err = Adapter::decode(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("adapter variant"), "{err:#}");
    }

    #[test]
    fn precision_roundtrips_and_lists() {
        let ts = TestStore::new("precision");
        // Fresh adapters are f32 unless tagged.
        assert_eq!(tiny_adapter("fresh", 1).precision, Precision::F32);
        let a = tiny_adapter("half", 9).with_precision(Precision::Bf16);
        ts.store.save(&a).unwrap();
        let back = ts.store.load("half").unwrap();
        assert_eq!(back.precision, Precision::Bf16);
        // The payload is still f32 master weights regardless of the
        // operating precision: the bitwise round trip is unchanged.
        assert_bitwise_eq(&a, &back);
        assert_eq!(a.encode(), back.encode());
        // Header-level listing surfaces the precision without a payload
        // decode.
        ts.store.save(&tiny_adapter("plain", 2)).unwrap();
        let listed = ts.store.list().unwrap();
        assert_eq!(listed.len(), 2);
        assert_eq!(listed[0].name, "half");
        assert_eq!(listed[0].precision, Precision::Bf16);
        assert_eq!(listed[1].name, "plain");
        assert_eq!(listed[1].precision, Precision::F32);
        // An unknown precision string in the header is a decode error,
        // not a silent f32 fallback.
        let mut bytes = a.encode();
        let pos = bytes
            .windows(18)
            .position(|w| w == b"\"precision\":\"bf16\"")
            .expect("precision value in header");
        bytes[pos + 13..pos + 17].copy_from_slice(b"bf17");
        let body_end = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..body_end]);
        bytes[body_end..].copy_from_slice(&sum.to_le_bytes());
        let err = Adapter::decode(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("precision"), "{err:#}");
    }

    fn assert_bitwise_eq(a: &Adapter, b: &Adapter) {
        assert_eq!(a.params.frozen.len(), b.params.frozen.len());
        assert_eq!(a.params.trainable.len(), b.params.trainable.len());
        for (x, y) in a
            .params
            .frozen
            .iter()
            .chain(&a.params.trainable)
            .zip(b.params.frozen.iter().chain(&b.params.trainable))
        {
            assert!(x.bitwise_eq(y), "leaf differs: {:?} vs {:?}", x.shape, y.shape);
        }
    }

    #[test]
    fn roundtrip_is_bitwise_identical() {
        let ts = TestStore::new("roundtrip");
        let mut adapter = tiny_adapter("round-trip_1", 7);
        // Plant awkward values: subnormal, negative zero, exact bit
        // patterns that any text formatting would mangle.
        if let crate::runtime::TensorData::F32(v) = &mut adapter.params.trainable[0].data {
            v[0] = f32::from_bits(0x0000_0001); // smallest subnormal
            v[1] = -0.0;
            v[2] = 0.1 + 0.2;
        }
        adapter.step = 12;
        let path = ts.store.save(&adapter).unwrap();
        assert!(path.exists());
        let back = ts.store.load("round-trip_1").unwrap();
        assert_eq!(back.name, adapter.name);
        assert_eq!(back.config, "tiny");
        assert_eq!(back.rank, adapter.rank);
        assert_eq!(back.scale, adapter.scale);
        assert_eq!(back.seed, adapter.seed);
        assert_eq!(back.step, 12);
        assert_bitwise_eq(&adapter, &back);
        // Save → load → save produces identical bytes (stable encoding).
        assert_eq!(adapter.encode(), back.encode());
    }

    #[test]
    fn integrity_checks_catch_corruption() {
        let adapter = tiny_adapter("victim", 1);
        let good = adapter.encode();
        assert!(Adapter::decode(&good).is_ok());

        // Flipped payload byte -> checksum mismatch.
        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        let err = Adapter::decode(&flipped).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");

        // Truncation.
        let err = Adapter::decode(&good[..good.len() - 16]).unwrap_err();
        assert!(!format!("{err:#}").is_empty());
        assert!(Adapter::decode(&good[..4]).is_err());

        // Bad magic.
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        let err = Adapter::decode(&bad_magic).unwrap_err();
        assert!(format!("{err:#}").contains("magic"), "{err:#}");

        // Unknown future version.
        let mut bad_version = good.clone();
        bad_version[8..12].copy_from_slice(&99u32.to_le_bytes());
        let err = Adapter::decode(&bad_version).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
    }

    #[test]
    fn store_load_faults_are_typed_errors_not_panics() {
        // Satellite criterion: truncated file, corrupted checksum, and
        // wrong-version header each yield a TYPED error from
        // `AdapterStore::load` — distinguishable via downcast, no panic.
        let ts = TestStore::new("faults");
        let good = tiny_adapter("victim", 1).encode();
        let path = ts.store.path_for("victim").unwrap();

        // Truncated mid-payload (the header parses; the payload is cut).
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        let err = ts.store.load("victim").unwrap_err();
        assert!(
            matches!(err.downcast_ref::<CkptError>(), Some(CkptError::Truncated { .. })),
            "{err:#}"
        );

        // Corrupted payload byte: length intact, checksum disagrees.
        let mut corrupt = good.clone();
        let mid = corrupt.len() / 2;
        corrupt[mid] ^= 0x20;
        std::fs::write(&path, &corrupt).unwrap();
        let err = ts.store.load("victim").unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<CkptError>(),
                Some(CkptError::ChecksumMismatch { .. })
            ),
            "{err:#}"
        );

        // Wrong format version.
        let mut versioned = good.clone();
        versioned[8..12].copy_from_slice(&9u32.to_le_bytes());
        std::fs::write(&path, &versioned).unwrap();
        let err = ts.store.load("victim").unwrap_err();
        assert_eq!(
            err.downcast_ref::<CkptError>(),
            Some(&CkptError::WrongVersion { found: 9 }),
            "{err:#}"
        );

        // Not a checkpoint at all.
        std::fs::write(&path, b"PNG... definitely not a checkpoint").unwrap();
        let err = ts.store.load("victim").unwrap_err();
        assert_eq!(err.downcast_ref::<CkptError>(), Some(&CkptError::BadMagic), "{err:#}");

        // A missing file is an IO error, not a CkptError.
        let err = ts.store.load("never-saved").unwrap_err();
        assert!(err.downcast_ref::<CkptError>().is_none(), "{err:#}");

        // A header declaring an impossibly large leaf (usize-overflowing
        // shape product) is an error, never a debug-build panic.
        let huge_header = br#"{"config":"tiny","frozen":[{"dtype":"f32","shape":[1000000000000000000,1000000000000000000]}],"name":"victim","rank":4,"scale":2,"seed":"1","step":0,"trainable":[]}"#;
        let mut huge = Vec::new();
        huge.extend_from_slice(b"DORACKPT");
        huge.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        huge.extend_from_slice(&(huge_header.len() as u32).to_le_bytes());
        huge.extend_from_slice(huge_header);
        huge.extend_from_slice(&[0u8; 8]); // bogus checksum: unreachable
        std::fs::write(&path, &huge).unwrap();
        let err = ts.store.load("victim").unwrap_err();
        assert!(format!("{err:#}").contains("impossibly large"), "{err:#}");
    }

    #[test]
    fn list_skips_unreadable_entries_and_keeps_the_rest() {
        let ts = TestStore::new("list_faults");
        ts.store.save(&tiny_adapter("healthy", 4)).unwrap();
        // A file cut inside the fixed 16-byte prefix and a garbage file:
        // both unreadable at header level -> skipped (with a warning).
        let good = tiny_adapter("cut", 5).encode();
        std::fs::write(ts.dir.join("cut.ckpt"), &good[..10]).unwrap();
        std::fs::write(ts.dir.join("junk.ckpt"), b"junk").unwrap();
        let listed = ts.store.list().unwrap();
        assert_eq!(listed.len(), 1, "{listed:?}");
        assert_eq!(listed[0].name, "healthy");
    }

    #[test]
    fn names_are_path_safe() {
        assert!(validate_name("default").is_ok());
        assert!(validate_name("user-7_v2").is_ok());
        assert!(validate_name("").is_err());
        assert!(validate_name("../evil").is_err());
        assert!(validate_name("a/b").is_err());
        assert!(validate_name("dot.dot").is_err());
        assert!(validate_name(&"x".repeat(65)).is_err());
        let ts = TestStore::new("names");
        assert!(ts.store.path_for("../evil").is_err());
        assert!(!ts.store.exists("../evil"));
    }

    #[test]
    fn list_summarizes_and_skips_foreign_files() {
        let ts = TestStore::new("list");
        ts.store.save(&tiny_adapter("beta", 2)).unwrap();
        let mut trained = tiny_adapter("alpha", 1);
        trained.step = 20;
        ts.store.save(&trained).unwrap();
        // Foreign/garbage files are skipped.
        std::fs::write(ts.dir.join("notes.txt"), b"hello").unwrap();
        std::fs::write(ts.dir.join("garbage.ckpt"), b"not a checkpoint").unwrap();
        let listed = ts.store.list().unwrap();
        assert_eq!(listed.len(), 2);
        assert_eq!(listed[0].name, "alpha");
        assert_eq!(listed[0].step, 20);
        assert_eq!(listed[1].name, "beta");
        assert_eq!(listed[1].config, "tiny");
        assert!(listed[0].file_bytes > 0);
    }

    #[test]
    fn save_overwrites_and_remove_removes() {
        let ts = TestStore::new("overwrite");
        let a0 = tiny_adapter("live", 3);
        ts.store.save(&a0).unwrap();
        let mut a1 = tiny_adapter("live", 3);
        a1.step = 44;
        ts.store.save(&a1).unwrap();
        assert_eq!(ts.store.load("live").unwrap().step, 44);
        // No temp droppings.
        let stray: Vec<_> = std::fs::read_dir(&ts.dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp"))
            .collect();
        assert!(stray.is_empty(), "temp files left behind: {stray:?}");
        ts.store.remove("live").unwrap();
        assert!(!ts.store.exists("live"));
        assert!(ts.store.load("live").is_err());
    }

    #[test]
    fn adapter_new_validates_counts() {
        let eng = NativeEngine::new();
        let info = eng.config("tiny").unwrap();
        let err = Adapter::new("x", info, 0, 0, AdapterParams::default()).unwrap_err();
        assert!(format!("{err:#}").contains("leaves"), "{err:#}");
    }
}
