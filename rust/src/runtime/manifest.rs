//! Typed view of `artifacts/manifest.json` — the contract between the
//! Python AOT compiler (`python/compile/aot.py`) and this runtime.
//!
//! The manifest describes every artifact's I/O signature (names, shapes,
//! dtypes, roles) plus the model configurations (leaf names in flatten
//! order, optimizer hyper-parameters), so the Rust side can construct and
//! interpret PJRT literals without a pytree library.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Element dtype of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoDtype {
    F32,
    S32,
}

impl IoDtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(IoDtype::F32),
            "s32" => Ok(IoDtype::S32),
            other => bail!("unknown dtype {other:?} in manifest"),
        }
    }
}

/// One input or output slot of an artifact.
#[derive(Debug, Clone)]
pub struct IoSlot {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: IoDtype,
    /// Role tag: frozen / trainable / opt / step / data / out.
    pub role: String,
}

impl IoSlot {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> Result<IoSlot> {
        Ok(IoSlot {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v.get("shape")?.as_shape()?,
            dtype: IoDtype::parse(v.get("dtype")?.as_str()?)?,
            role: v.get("role")?.as_str()?.to_string(),
        })
    }
}

/// One AOT artifact (an HLO text file plus its signature).
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSlot>,
    pub outputs: Vec<IoSlot>,
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactInfo {
    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(|v| v.as_f64().ok())
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str().ok())
    }
}

/// One exported model configuration.
#[derive(Debug, Clone)]
pub struct ConfigInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub seq: usize,
    pub rank: usize,
    pub scale: f64,
    pub n_params: usize,
    pub train_batch: usize,
    pub chunk_steps: usize,
    /// Frozen / trainable leaf names, in flatten (sorted) order.
    pub frozen: Vec<String>,
    pub trainable: Vec<String>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub configs: BTreeMap<String, ConfigInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = json::parse(&text).context("parsing manifest.json")?;

        let mut artifacts = BTreeMap::new();
        for (name, v) in root.get("artifacts")?.as_obj()? {
            let inputs = v
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(IoSlot::parse)
                .collect::<Result<_>>()?;
            let outputs = v
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(IoSlot::parse)
                .collect::<Result<_>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: v.get("file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                    meta: v.get("meta")?.as_obj()?.clone(),
                },
            );
        }

        let mut configs = BTreeMap::new();
        for (name, v) in root.get("configs")?.as_obj()? {
            let names = |key: &str| -> Result<Vec<String>> {
                v.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|x| Ok(x.as_str()?.to_string()))
                    .collect()
            };
            configs.insert(
                name.clone(),
                ConfigInfo {
                    name: name.clone(),
                    vocab: v.get("vocab")?.as_usize()?,
                    d_model: v.get("d_model")?.as_usize()?,
                    n_layers: v.get("n_layers")?.as_usize()?,
                    seq: v.get("seq")?.as_usize()?,
                    rank: v.get("rank")?.as_usize()?,
                    scale: v.get("scale")?.as_f64()?,
                    n_params: v.get("n_params")?.as_usize()?,
                    train_batch: v.get("train_batch")?.as_usize()?,
                    chunk_steps: v.get("chunk_steps")?.as_usize()?,
                    frozen: names("frozen")?,
                    trainable: names("trainable")?,
                },
            );
        }

        Ok(Manifest { dir: dir.to_path_buf(), artifacts, configs })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    pub fn config(&self, name: &str) -> Result<&ConfigInfo> {
        self.configs
            .get(name)
            .with_context(|| format!("config {name:?} not in manifest"))
    }

    pub fn hlo_path(&self, art: &ArtifactInfo) -> PathBuf {
        self.dir.join(&art.file)
    }
}

/// Default artifacts directory: `$DORA_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("DORA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).expect("manifest parses"))
        } else {
            None
        }
    }

    #[test]
    fn parses_real_manifest() {
        let Some(m) = manifest() else { return };
        assert!(m.artifacts.len() >= 30, "{}", m.artifacts.len());
        assert!(m.configs.contains_key("tiny"));
        assert!(m.configs.contains_key("small"));
        assert!(m.configs.contains_key("e2e"));
    }

    #[test]
    fn train_artifact_signature() {
        let Some(m) = manifest() else { return };
        let cfg = m.config("tiny").unwrap();
        let art = m.artifact("train_tiny_fused").unwrap();
        let nf = cfg.frozen.len();
        let nt = cfg.trainable.len();
        assert_eq!(art.inputs.len(), nf + 3 * nt + 2);
        assert_eq!(art.outputs.len(), 3 * nt + 2);
        assert_eq!(art.inputs.last().unwrap().name, "tokens");
        assert_eq!(art.inputs.last().unwrap().dtype, IoDtype::S32);
        assert_eq!(art.outputs.last().unwrap().name, "losses");
        // tokens shape [k, bs, seq+1]
        let t = &art.inputs.last().unwrap().shape;
        assert_eq!(t.len(), 3);
        assert_eq!(t[0], cfg.chunk_steps);
        assert_eq!(t[1], cfg.train_batch);
        assert_eq!(t[2], cfg.seq + 1);
    }

    #[test]
    fn every_artifact_file_exists() {
        let Some(m) = manifest() else { return };
        for art in m.artifacts.values() {
            assert!(m.hlo_path(art).exists(), "{} missing", art.file);
        }
    }

    #[test]
    fn compose_artifact_meta() {
        let Some(m) = manifest() else { return };
        let art = m.artifact("compose_fused_512x2048").unwrap();
        assert_eq!(art.meta_f64("rows"), Some(512.0));
        assert_eq!(art.meta_f64("d_out"), Some(2048.0));
        assert_eq!(art.meta_str("variant"), Some("fused"));
    }
}
