//! Native CPU execution engine: the typed-op surface, served by the
//! in-process kernel registry instead of compiled HLO.
//!
//! [`NativeEngine::execute`] is the primary entrypoint: it takes a typed
//! [`EngineOp`] (Init / TrainStep / Eval / Infer / DoraLinear / Compose)
//! and returns the matching typed [`EngineOut`] — no artifact-name
//! parsing, no positional tensor packing. The model math lives in
//! [`models::forward`](crate::models::forward); every compose/norm hot
//! path goes through `kernels::registry().select(...)`.
//!
//! [`NativeEngine::run`] remains as the string-name compatibility shim:
//! it accepts the same artifact names and I/O conventions the AOT
//! manifest defines — `init_<cfg>`, `train_<cfg>_<variant>`,
//! `eval_<cfg>_<variant>`, `infer_<cfg>_<variant>`, the streaming
//! `decode_step_<cfg>_<variant>` / `decode_step_merged_<cfg>` steps,
//! plus the single-module `dora_linear_<variant>` and
//! `compose_<variant>_<rows>x<dout>` units — parses them into typed ops,
//! and flattens the typed response back to the positional output list.
//! PJRT artifact naming therefore still resolves against this engine.
//!
//! Configs are built in (`tiny`/`small`/`e2e`), dimensioned like the AOT
//! manifest's but sized for a CPU testbed; the leaf naming and flatten
//! order follow the manifest convention exactly, so parameters can be
//! handed between a native trainer and a PJRT server (or vice versa) when
//! the shapes line up.

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Context, Result};

use crate::dora::config::{ActShape, ModuleShape};
use crate::dora::norm_cpu::{self, AllocTracker};
use crate::kernels::{registry, BackendKind};
use crate::models::forward::{self, init_leaves, kernels_for, NativeModel};
use crate::numerics::half::Dtype;
use crate::runtime::ops::{
    parse_variant_spec, variant_token, AdapterParams, AdapterVariant, ApplyUpdateReq,
    ApplyUpdateResp, ComposeReq, ComposeResp, DecodeStepMergedReq, DecodeStepReq, DecodeStepResp,
    DoraLinearReq, DoraLinearResp, EngineOp, EngineOut, EvalReq, EvalResp, InferMergedReq,
    InferReq, InferResp, InitReq, InitResp, LinearVariant, LossAndGradsReq, LossAndGradsResp,
    MergedParams, OptState, Precision, SampleGrads, TrainStepReq, TrainStepResp, Variant,
};
use crate::runtime::{ConfigInfo, Tensor};

/// The built-in native model configurations. Shapes follow the AOT
/// manifest's tiny/small/e2e ladder, scaled to interactive CPU budgets
/// (the `tiny` config must train in debug-mode unit tests).
pub fn builtin_configs() -> &'static BTreeMap<String, ConfigInfo> {
    static CONFIGS: OnceLock<BTreeMap<String, ConfigInfo>> = OnceLock::new();
    CONFIGS.get_or_init(|| {
        let mut m = BTreeMap::new();
        for (name, vocab, d_model, n_layers, seq, rank, train_batch, chunk_steps) in [
            ("tiny", 64usize, 32usize, 2usize, 16usize, 4usize, 4usize, 4usize),
            ("small", 256, 64, 3, 32, 8, 8, 4),
            ("e2e", 512, 128, 4, 64, 16, 8, 8),
        ] {
            let n_params = vocab * d_model
                + n_layers * (d_model * d_model + rank * d_model + d_model * rank + d_model);
            m.insert(
                name.to_string(),
                ConfigInfo {
                    name: name.to_string(),
                    vocab,
                    d_model,
                    n_layers,
                    seq,
                    rank,
                    scale: 2.0,
                    n_params,
                    train_batch,
                    chunk_steps,
                    frozen: forward::frozen_names(n_layers),
                    trainable: forward::trainable_names(n_layers),
                },
            );
        }
        m
    })
}

/// Scale used by the native `dora_linear` ops (matching the AOT
/// lowering's `alpha/sqrt(r)` with alpha = 16).
fn dora_linear_scale(rank: usize) -> f32 {
    16.0 / (rank as f32).sqrt()
}

/// The native execution engine. Cheap to clone; stateless between calls
/// (parameters cross the call boundary as host tensors, exactly like the
/// PJRT engine's literals).
#[derive(Clone, Default)]
pub struct NativeEngine {
    _priv: (),
}

impl NativeEngine {
    pub fn new() -> NativeEngine {
        NativeEngine { _priv: () }
    }

    pub fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    pub fn config(&self, name: &str) -> Result<&'static ConfigInfo> {
        builtin_configs()
            .get(name)
            .with_context(|| format!("config {name:?} not in the native engine's builtin set"))
    }

    pub fn configs(&self) -> &'static BTreeMap<String, ConfigInfo> {
        builtin_configs()
    }

    /// Execute a typed op — the primary native entrypoint. Inputs are
    /// validated (an `Err`, never a panic) before any model math runs.
    pub fn execute(&self, op: &EngineOp) -> Result<EngineOut> {
        match op {
            EngineOp::Init(r) => run_init(self.config(&r.config)?, r).map(EngineOut::Init),
            EngineOp::TrainStep(r) => {
                run_train(self.config(&r.config)?, r).map(EngineOut::TrainStep)
            }
            EngineOp::LossAndGrads(r) => {
                run_loss_and_grads(self.config(&r.config)?, r).map(EngineOut::LossAndGrads)
            }
            EngineOp::ApplyUpdate(r) => {
                run_apply_update(self.config(&r.config)?, r).map(EngineOut::ApplyUpdate)
            }
            EngineOp::Eval(r) => run_eval(self.config(&r.config)?, r).map(EngineOut::Eval),
            EngineOp::Infer(r) => run_infer(self.config(&r.config)?, r).map(EngineOut::Infer),
            EngineOp::InferMerged(r) => {
                run_infer_merged(self.config(&r.config)?, r).map(EngineOut::Infer)
            }
            EngineOp::DecodeStep(r) => {
                run_decode_step(self.config(&r.config)?, r).map(EngineOut::DecodeStep)
            }
            EngineOp::DecodeStepMerged(r) => {
                run_decode_step_merged(self.config(&r.config)?, r).map(EngineOut::DecodeStep)
            }
            EngineOp::DoraLinear(r) => run_dora_linear(r).map(EngineOut::DoraLinear),
            EngineOp::Compose(r) => run_compose(r).map(EngineOut::Compose),
        }
    }

    /// Does this engine implement the named artifact? (Shim-level probe:
    /// checks the name grammar and config, not the input tensors.)
    pub fn supports(&self, name: &str) -> bool {
        self.parse_artifact(name).is_ok()
    }

    /// Execute an artifact by manifest name with positional host tensors
    /// — the string-name compatibility shim over [`Self::execute`], the
    /// same contract as [`Engine::run`](crate::runtime::Engine::run).
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let op = self.op_from_artifact(name, inputs)?;
        Ok(self.execute(&op)?.into_tensors())
    }

    /// Parse an artifact name into its op descriptor (no tensors yet).
    fn parse_artifact(&self, name: &str) -> Result<ArtifactKind> {
        if let Some(cfg) = name.strip_prefix("init_") {
            return Ok(ArtifactKind::Init(self.config(cfg)?));
        }
        for (prefix, train) in [("train_", true), ("eval_", false)] {
            if let Some(rest) = name.strip_prefix(prefix) {
                let (cfg, variant) = rest.rsplit_once('_').with_context(|| {
                    format!("artifact {name:?}: expected {prefix}<cfg>_<variant>")
                })?;
                // The token is either a bare kernel variant ("fused" —
                // the Dora names, unchanged) or "<kernel>-<adapter>",
                // optionally with a trailing "-bf16" precision suffix.
                let (precision, variant) = Precision::split_token(variant);
                let (variant, adapter) =
                    parse_variant_spec(variant).with_context(|| format!("artifact {name:?}"))?;
                let info = self.config(cfg)?;
                return Ok(if train {
                    ArtifactKind::Train(info, variant, adapter, precision)
                } else {
                    ArtifactKind::Eval(info, variant, adapter, precision)
                });
            }
        }
        if let Some(rest) = name.strip_prefix("loss_and_grads_") {
            let (cfg, variant) = rest.rsplit_once('_').with_context(|| {
                format!("artifact {name:?}: expected loss_and_grads_<cfg>_<variant>")
            })?;
            let (precision, variant) = Precision::split_token(variant);
            let (variant, adapter) =
                parse_variant_spec(variant).with_context(|| format!("artifact {name:?}"))?;
            return Ok(ArtifactKind::LossAndGrads(self.config(cfg)?, variant, adapter, precision));
        }
        if let Some(cfg) = name.strip_prefix("apply_update_") {
            return Ok(ArtifactKind::ApplyUpdate(self.config(cfg)?));
        }
        // Checked before the generic infer grammar: "infer_merged_tiny"
        // would otherwise parse as config "merged" + variant "tiny". The
        // merged ops carry the precision suffix on the config segment
        // ("infer_merged_tiny-bf16") — there is no variant token.
        if let Some(cfg) = name.strip_prefix("infer_merged_") {
            let (precision, cfg) = Precision::split_token(cfg);
            return Ok(ArtifactKind::InferMerged(self.config(cfg)?, precision));
        }
        if let Some(rest) = name.strip_prefix("infer_") {
            let (cfg, variant) = rest
                .rsplit_once('_')
                .with_context(|| format!("artifact {name:?}: expected infer_<cfg>_<variant>"))?;
            let (precision, variant) = Precision::split_token(variant);
            let (variant, adapter) =
                parse_variant_spec(variant).with_context(|| format!("artifact {name:?}"))?;
            return Ok(ArtifactKind::Infer(self.config(cfg)?, variant, adapter, precision));
        }
        // Same ordering hazard as infer: "decode_step_merged_tiny" would
        // otherwise parse as config "merged" + variant "tiny".
        if let Some(cfg) = name.strip_prefix("decode_step_merged_") {
            let (precision, cfg) = Precision::split_token(cfg);
            return Ok(ArtifactKind::DecodeStepMerged(self.config(cfg)?, precision));
        }
        if let Some(rest) = name.strip_prefix("decode_step_") {
            let (cfg, variant) = rest.rsplit_once('_').with_context(|| {
                format!("artifact {name:?}: expected decode_step_<cfg>_<variant>")
            })?;
            let (precision, variant) = Precision::split_token(variant);
            let (variant, adapter) =
                parse_variant_spec(variant).with_context(|| format!("artifact {name:?}"))?;
            return Ok(ArtifactKind::DecodeStep(self.config(cfg)?, variant, adapter, precision));
        }
        if let Some(variant) = name.strip_prefix("dora_linear_") {
            let variant = LinearVariant::parse(variant)
                .with_context(|| format!("artifact {name:?}"))?;
            return Ok(ArtifactKind::DoraLinear(variant));
        }
        if let Some(rest) = name.strip_prefix("compose_") {
            let (variant, shape) = rest
                .split_once('_')
                .with_context(|| format!("artifact {name:?}: expected compose_<variant>_<RxD>"))?;
            let variant =
                Variant::parse(variant).with_context(|| format!("artifact {name:?}"))?;
            let bad = || format!("artifact {name:?}: bad <rows>x<d_out> suffix");
            let (rows_s, d_s) = shape.split_once('x').with_context(bad)?;
            let rows = rows_s.parse::<usize>().ok().with_context(bad)?;
            let d_out = d_s.parse::<usize>().ok().with_context(bad)?;
            return Ok(ArtifactKind::Compose(variant, rows, d_out));
        }
        bail!("artifact {name:?} is not implemented by the native engine")
    }

    /// Build a typed op from an artifact name plus positional inputs —
    /// the inbound half of the compatibility shim. Input counts are
    /// checked here; shapes/dtypes are checked by `execute`.
    fn op_from_artifact(&self, name: &str, inputs: &[Tensor]) -> Result<EngineOp> {
        match self.parse_artifact(name)? {
            ArtifactKind::Init(info) => {
                expect_inputs(name, inputs, 1)?;
                expect_shape(name, "seed", &inputs[0], &[])?;
                let seed = inputs[0].as_i32().context("init seed must be i32")?[0];
                Ok(EngineOp::Init(InitReq {
                    config: info.name.clone(),
                    seed,
                    precision: Precision::F32,
                }))
            }
            ArtifactKind::Train(info, variant, adapter, precision) => {
                let nf = info.frozen.len();
                let nt = info.trainable.len();
                expect_inputs(name, inputs, nf + 3 * nt + 2)?;
                let step_t = &inputs[nf + 3 * nt];
                expect_shape(name, "step", step_t, &[])?;
                let step = step_t.as_i32().context("step must be i32")?[0];
                Ok(EngineOp::TrainStep(TrainStepReq {
                    config: info.name.clone(),
                    variant,
                    adapter,
                    precision,
                    params: Arc::new(AdapterParams {
                        frozen: inputs[..nf].to_vec(),
                        trainable: inputs[nf..nf + nt].to_vec(),
                    }),
                    opt: OptState {
                        m1: inputs[nf + nt..nf + 2 * nt].to_vec(),
                        m2: inputs[nf + 2 * nt..nf + 3 * nt].to_vec(),
                        step,
                    },
                    tokens: inputs[nf + 3 * nt + 1].clone(),
                }))
            }
            ArtifactKind::LossAndGrads(info, variant, adapter, precision) => {
                let nf = info.frozen.len();
                let nt = info.trainable.len();
                expect_inputs(name, inputs, nf + nt + 2)?;
                let rows_t = &inputs[nf + nt + 1];
                expect_shape(name, "total_rows", rows_t, &[])?;
                let total_rows = rows_t.as_i32().context("total_rows must be i32")?[0];
                if total_rows <= 0 {
                    bail!("op {name:?}: total_rows {total_rows} must be positive");
                }
                Ok(EngineOp::LossAndGrads(LossAndGradsReq {
                    config: info.name.clone(),
                    variant,
                    adapter,
                    precision,
                    params: Arc::new(AdapterParams {
                        frozen: inputs[..nf].to_vec(),
                        trainable: inputs[nf..nf + nt].to_vec(),
                    }),
                    tokens: inputs[nf + nt].clone(),
                    total_rows: total_rows as usize,
                }))
            }
            ArtifactKind::ApplyUpdate(info) => {
                let nt = info.trainable.len();
                expect_inputs(name, inputs, 4 * nt + 1)?;
                let step_t = &inputs[3 * nt];
                expect_shape(name, "step", step_t, &[])?;
                let step = step_t.as_i32().context("step must be i32")?[0];
                Ok(EngineOp::ApplyUpdate(ApplyUpdateReq {
                    config: info.name.clone(),
                    trainable: inputs[..nt].to_vec(),
                    opt: OptState {
                        m1: inputs[nt..2 * nt].to_vec(),
                        m2: inputs[2 * nt..3 * nt].to_vec(),
                        step,
                    },
                    grads: inputs[3 * nt + 1..].to_vec(),
                }))
            }
            ArtifactKind::Eval(info, variant, adapter, precision) => {
                let (params, tokens) = split_params_tokens(info, name, inputs)?;
                Ok(EngineOp::Eval(EvalReq {
                    config: info.name.clone(),
                    variant,
                    adapter,
                    precision,
                    params,
                    tokens,
                }))
            }
            ArtifactKind::Infer(info, variant, adapter, precision) => {
                let (params, tokens) = split_params_tokens(info, name, inputs)?;
                Ok(EngineOp::Infer(InferReq {
                    config: info.name.clone(),
                    variant,
                    adapter,
                    precision,
                    params,
                    tokens,
                }))
            }
            ArtifactKind::InferMerged(info, precision) => {
                let nl = info.n_layers;
                expect_inputs(name, inputs, nl + 2)?;
                Ok(EngineOp::InferMerged(InferMergedReq {
                    config: info.name.clone(),
                    params: Arc::new(MergedParams {
                        embed: inputs[0].clone(),
                        layers: inputs[1..1 + nl].to_vec(),
                        precision,
                    }),
                    tokens: inputs[nl + 1].clone(),
                }))
            }
            ArtifactKind::DecodeStep(info, variant, adapter, precision) => {
                let (params, tokens) = split_params_tokens(info, name, inputs)?;
                Ok(EngineOp::DecodeStep(DecodeStepReq {
                    config: info.name.clone(),
                    variant,
                    adapter,
                    precision,
                    params,
                    tokens,
                }))
            }
            ArtifactKind::DecodeStepMerged(info, precision) => {
                let nl = info.n_layers;
                expect_inputs(name, inputs, nl + 2)?;
                Ok(EngineOp::DecodeStepMerged(DecodeStepMergedReq {
                    config: info.name.clone(),
                    params: Arc::new(MergedParams {
                        embed: inputs[0].clone(),
                        layers: inputs[1..1 + nl].to_vec(),
                        precision,
                    }),
                    tokens: inputs[nl + 1].clone(),
                }))
            }
            ArtifactKind::DoraLinear(variant) => {
                expect_inputs(name, inputs, 5)?;
                Ok(EngineOp::DoraLinear(DoraLinearReq {
                    variant,
                    x: inputs[0].clone(),
                    w: inputs[1].clone(),
                    a: inputs[2].clone(),
                    b: inputs[3].clone(),
                    mag: inputs[4].clone(),
                }))
            }
            ArtifactKind::Compose(variant, rows, d_out) => {
                expect_inputs(name, inputs, 3)?;
                expect_shape(name, "base", &inputs[0], &[rows, d_out])?;
                Ok(EngineOp::Compose(ComposeReq {
                    variant,
                    base: inputs[0].clone(),
                    lora: inputs[1].clone(),
                    g: inputs[2].clone(),
                }))
            }
        }
    }
}

/// Parsed artifact-name descriptor (the shim's grammar).
enum ArtifactKind {
    Init(&'static ConfigInfo),
    Train(&'static ConfigInfo, Variant, AdapterVariant, Precision),
    LossAndGrads(&'static ConfigInfo, Variant, AdapterVariant, Precision),
    ApplyUpdate(&'static ConfigInfo),
    Eval(&'static ConfigInfo, Variant, AdapterVariant, Precision),
    Infer(&'static ConfigInfo, Variant, AdapterVariant, Precision),
    InferMerged(&'static ConfigInfo, Precision),
    DecodeStep(&'static ConfigInfo, Variant, AdapterVariant, Precision),
    DecodeStepMerged(&'static ConfigInfo, Precision),
    DoraLinear(LinearVariant),
    Compose(Variant, usize, usize),
}

/// Split `frozen + trainable + tokens` positional inputs (the eval/infer
/// artifact layout) into typed parts.
fn split_params_tokens(
    info: &ConfigInfo,
    name: &str,
    inputs: &[Tensor],
) -> Result<(Arc<AdapterParams>, Tensor)> {
    let nf = info.frozen.len();
    let nt = info.trainable.len();
    expect_inputs(name, inputs, nf + nt + 1)?;
    Ok((
        Arc::new(AdapterParams {
            frozen: inputs[..nf].to_vec(),
            trainable: inputs[nf..nf + nt].to_vec(),
        }),
        inputs[nf + nt].clone(),
    ))
}

fn expect_inputs(label: &str, inputs: &[Tensor], want: usize) -> Result<()> {
    if inputs.len() != want {
        bail!("op {label:?} expects {want} inputs, got {}", inputs.len());
    }
    Ok(())
}

fn expect_shape(label: &str, what: &str, t: &Tensor, shape: &[usize]) -> Result<()> {
    if t.shape != shape {
        bail!(
            "op {label:?} input {what:?}: shape {:?} != expected {shape:?}",
            t.shape
        );
    }
    Ok(())
}

/// Shape AND dtype check for an f32 parameter leaf — a wrong-dtype leaf
/// must surface as an `Err` here, never as a downstream panic.
fn expect_f32(label: &str, what: &str, t: &Tensor, shape: &[usize]) -> Result<()> {
    expect_shape(label, what, t, shape)?;
    t.as_f32()
        .with_context(|| format!("op {label:?} input {what:?}"))?;
    Ok(())
}

/// Validate an adapter's leaf set against the config's shapes: counts,
/// per-leaf shape, and f32 dtype (the shared [`AdapterParams::validate`]).
fn validate_params(info: &ConfigInfo, label: &str, params: &AdapterParams) -> Result<()> {
    params.validate(info, label)
}

/// Validate a merged parameter set: embedding shape, layer count, and
/// per-layer `[d, d]` f32 weights.
fn validate_merged(info: &ConfigInfo, label: &str, merged: &MergedParams) -> Result<()> {
    let d = info.d_model;
    expect_f32(label, "embed", &merged.embed, &[info.vocab, d])?;
    if !merged.matches(info) {
        bail!(
            "op {label:?}: merged layer count {} != config {}'s {}",
            merged.layers.len(),
            info.name,
            info.n_layers
        );
    }
    for (l, layer) in merged.layers.iter().enumerate() {
        expect_f32(label, &format!("layers.{l}.merged"), layer, &[d, d])?;
    }
    Ok(())
}

fn run_init(info: &'static ConfigInfo, req: &InitReq) -> Result<InitResp> {
    let leaves = init_leaves(info, req.seed as u64);
    Ok(InitResp {
        params: AdapterParams { frozen: leaves.frozen, trainable: leaves.trainable },
    })
}

/// TrainStep: `chunk_steps` optimizer steps over one packed token block
/// `[k, bs, seq+1]` — the scan-over-steps contract, executed as k native
/// steps.
fn run_train(info: &'static ConfigInfo, req: &TrainStepReq) -> Result<TrainStepResp> {
    let label = format!(
        "train_{}_{}{}",
        info.name,
        variant_token(req.variant, req.adapter),
        req.precision.token_suffix()
    );
    validate_params(info, &label, &req.params)?;
    let k = info.chunk_steps;
    let bs = info.train_batch;
    let seq1 = info.seq + 1;
    expect_shape(&label, "tokens", &req.tokens, &[k, bs, seq1])?;
    let tokens = req.tokens.as_i32().context("tokens must be i32")?;
    let trainable = &req.params.trainable;
    // Moments must mirror the trainable leaf shapes and dtype (the
    // optimizer iterates them in lockstep).
    let nt = trainable.len();
    for (which, moments) in [("m1", &req.opt.m1), ("m2", &req.opt.m2)] {
        if moments.len() != nt {
            bail!("op {label:?}: {which} has {} leaves, expected {nt}", moments.len());
        }
        for (slot, (m, t)) in moments.iter().zip(trainable).enumerate() {
            expect_f32(&label, &format!("{which}[{slot}]"), m, &t.shape)?;
        }
    }

    // A negative step would hand adamw_step a t <= 0 bias-correction
    // exponent (1 - beta^0 = 0 divides by zero) and silently NaN-poison
    // every parameter — reject it like any other malformed input.
    let step0 = req.opt.step;
    if step0 < 0 {
        bail!("op {label:?}: step counter {step0} is negative");
    }
    let mut params = trainable.to_vec();
    let mut m1 = req.opt.m1.clone();
    let mut m2 = req.opt.m2.clone();
    let kernels = kernels_for(req.variant, info, true)?;
    let mut losses = Vec::with_capacity(k);
    for i in 0..k {
        let block = &tokens[i * bs * seq1..(i + 1) * bs * seq1];
        // The model is a borrowed view over `params`; grads are computed
        // with the view alive, the update after it drops.
        let (loss, grads) = {
            let model = NativeModel::new(info, &req.params.frozen, &params, kernels.clone())?
                .with_adapter(req.adapter)
                .with_precision(req.precision);
            model.loss_and_grads(block, bs)?
        };
        forward::adamw_step(&mut params, &mut m1, &mut m2, &grads, step0 + i as i32 + 1);
        losses.push(loss);
    }
    Ok(TrainStepResp {
        trainable: params,
        opt: OptState { m1, m2, step: step0 + k as i32 },
        losses,
    })
}

/// LossAndGrads: per-sample gradients for one `[mb, seq+1]` micro-batch
/// shard of an effective batch with `total_rows` rows — the data-parallel
/// gradient op. No optimizer state touched; the update runs centrally
/// through [`run_apply_update`] after the reduction.
fn run_loss_and_grads(
    info: &'static ConfigInfo,
    req: &LossAndGradsReq,
) -> Result<LossAndGradsResp> {
    let label = format!(
        "loss_and_grads_{}_{}{}",
        info.name,
        variant_token(req.variant, req.adapter),
        req.precision.token_suffix()
    );
    validate_params(info, &label, &req.params)?;
    let seq1 = info.seq + 1;
    if req.tokens.shape.len() != 2 || req.tokens.shape[1] != seq1 || req.tokens.shape[0] == 0 {
        bail!(
            "op {label:?} input \"tokens\": shape {:?} != expected [mb >= 1, {seq1}]",
            req.tokens.shape
        );
    }
    let mb = req.tokens.shape[0];
    let tokens = req.tokens.as_i32().context("tokens must be i32")?;
    let kernels = kernels_for(req.variant, info, true)?;
    let model = NativeModel::new(info, &req.params.frozen, &req.params.trainable, kernels)?
        .with_adapter(req.adapter)
        .with_precision(req.precision);
    let per_sample = model.loss_and_sample_grads(tokens, mb, req.total_rows)?;
    let samples = per_sample
        .into_iter()
        .map(|(loss_sum, grads)| SampleGrads {
            loss_sum,
            grads: grads
                .into_iter()
                .zip(&req.params.trainable)
                .map(|(g, t)| Tensor::f32(t.shape.clone(), g))
                .collect(),
        })
        .collect();
    Ok(LossAndGradsResp { samples })
}

/// ApplyUpdate: ONE central AdamW step over pre-reduced gradients — the
/// optimizer half of the split train step.
fn run_apply_update(info: &'static ConfigInfo, req: &ApplyUpdateReq) -> Result<ApplyUpdateResp> {
    let label = format!("apply_update_{}", info.name);
    let nt = info.trainable.len();
    for (which, leaves) in [
        ("trainable", &req.trainable),
        ("m1", &req.opt.m1),
        ("m2", &req.opt.m2),
        ("grads", &req.grads),
    ] {
        if leaves.len() != nt {
            bail!("op {label:?}: {which} has {} leaves, expected {nt}", leaves.len());
        }
        for (slot, (l, t)) in leaves.iter().zip(&req.trainable).enumerate() {
            expect_f32(&label, &format!("{which}[{slot}]"), l, &t.shape)?;
        }
    }
    // Trainable shapes themselves must match the config (the zip above
    // only checks internal consistency).
    let d = info.d_model;
    let r = info.rank;
    for l in 0..info.n_layers {
        expect_f32(&label, &info.trainable[3 * l], &req.trainable[3 * l], &[r, d])?;
        expect_f32(&label, &info.trainable[3 * l + 1], &req.trainable[3 * l + 1], &[d, r])?;
        expect_f32(&label, &info.trainable[3 * l + 2], &req.trainable[3 * l + 2], &[d])?;
    }
    let step0 = req.opt.step;
    if step0 < 0 {
        bail!("op {label:?}: step counter {step0} is negative");
    }
    let mut params = req.trainable.clone();
    let mut m1 = req.opt.m1.clone();
    let mut m2 = req.opt.m2.clone();
    let grads: Vec<Vec<f32>> = req
        .grads
        .iter()
        .map(|t| t.as_f32().map(<[f32]>::to_vec))
        .collect::<Result<_>>()?;
    forward::adamw_step(&mut params, &mut m1, &mut m2, &grads, step0 + 1);
    Ok(ApplyUpdateResp {
        trainable: params,
        opt: OptState { m1, m2, step: step0 + 1 },
    })
}

/// Eval: mean loss over one held-out token block `[bs, seq+1]`.
fn run_eval(info: &'static ConfigInfo, req: &EvalReq) -> Result<EvalResp> {
    let label = format!(
        "eval_{}_{}{}",
        info.name,
        variant_token(req.variant, req.adapter),
        req.precision.token_suffix()
    );
    validate_params(info, &label, &req.params)?;
    let bs = info.train_batch;
    expect_shape(&label, "tokens", &req.tokens, &[bs, info.seq + 1])?;
    let tokens = req.tokens.as_i32().context("tokens must be i32")?;
    let kernels = kernels_for(req.variant, info, false)?;
    let model = NativeModel::new(info, &req.params.frozen, &req.params.trainable, kernels)?
        .with_adapter(req.adapter)
        .with_precision(req.precision);
    let loss = model.eval_loss(tokens, bs)?;
    Ok(EvalResp { loss })
}

/// Infer: last-position logits `[bs, vocab]` for a token batch
/// `[bs, seq]` (the Tier-2 serving path).
fn run_infer(info: &'static ConfigInfo, req: &InferReq) -> Result<InferResp> {
    let label = format!(
        "infer_{}_{}{}",
        info.name,
        variant_token(req.variant, req.adapter),
        req.precision.token_suffix()
    );
    validate_params(info, &label, &req.params)?;
    let bs = info.train_batch;
    let seq = info.seq;
    expect_shape(&label, "tokens", &req.tokens, &[bs, seq])?;
    let tokens = req.tokens.as_i32().context("tokens must be i32")?;
    let kernels = kernels_for(req.variant, info, false)?;
    let model = NativeModel::new(info, &req.params.frozen, &req.params.trainable, kernels)?
        .with_adapter(req.adapter)
        .with_precision(req.precision);
    let logits = model.infer_logits(tokens, bs, seq)?;
    Ok(InferResp { logits: Tensor::f32(vec![bs, info.vocab], logits) })
}

/// InferMerged: last-position logits over precomputed merged weights —
/// the serving fast path (one matmul per layer, no norm/compose).
fn run_infer_merged(info: &'static ConfigInfo, req: &InferMergedReq) -> Result<InferResp> {
    let label = format!("infer_merged_{}{}", info.name, req.params.precision.token_suffix());
    validate_merged(info, &label, &req.params)?;
    let bs = info.train_batch;
    let seq = info.seq;
    expect_shape(&label, "tokens", &req.tokens, &[bs, seq])?;
    let tokens = req.tokens.as_i32().context("tokens must be i32")?;
    let logits = forward::merged_infer_logits(info, &req.params, tokens, bs, seq)?;
    Ok(InferResp { logits: Tensor::f32(vec![bs, info.vocab], logits) })
}

/// Shared token validation for the decode-step ops: rank-1 `[n]`,
/// n >= 1, n <= train_batch (the scheduler's slot capacity — one row per
/// co-resident streaming request).
fn decode_tokens<'a>(
    info: &ConfigInfo,
    label: &str,
    tokens: &'a Tensor,
) -> Result<&'a [i32]> {
    if tokens.shape.len() != 1 {
        bail!(
            "op {label:?} input \"tokens\": expected rank-1 [n], got {:?}",
            tokens.shape
        );
    }
    let n = tokens.shape[0];
    if n == 0 || n > info.train_batch {
        bail!(
            "op {label:?}: decode batch size {n} outside 1..={}",
            info.train_batch
        );
    }
    tokens.as_i32().context("tokens must be i32")
}

/// DecodeStep: next-token logits `[n, vocab]` for the newest token of
/// each of `n` active streaming requests (the composed path — full DoRA
/// composition per step). The model is row-local, so each row's logits
/// are bitwise-independent of the co-resident rows: the continuous
/// batcher's determinism contract rests on this op.
fn run_decode_step(info: &'static ConfigInfo, req: &DecodeStepReq) -> Result<DecodeStepResp> {
    let label = format!(
        "decode_step_{}_{}{}",
        info.name,
        variant_token(req.variant, req.adapter),
        req.precision.token_suffix()
    );
    validate_params(info, &label, &req.params)?;
    let tokens = decode_tokens(info, &label, &req.tokens)?;
    let n = tokens.len();
    let kernels = kernels_for(req.variant, info, false)?;
    let model = NativeModel::new(info, &req.params.frozen, &req.params.trainable, kernels)?
        .with_adapter(req.adapter)
        .with_precision(req.precision);
    let logits = model.decode_logits(tokens)?;
    Ok(DecodeStepResp { logits: Tensor::f32(vec![n, info.vocab], logits) })
}

/// DecodeStepMerged: the decode step over precomputed merged weights —
/// the streaming fast path (one matmul per layer per token).
fn run_decode_step_merged(
    info: &'static ConfigInfo,
    req: &DecodeStepMergedReq,
) -> Result<DecodeStepResp> {
    let label =
        format!("decode_step_merged_{}{}", info.name, req.params.precision.token_suffix());
    validate_merged(info, &label, &req.params)?;
    let tokens = decode_tokens(info, &label, &req.tokens)?;
    let n = tokens.len();
    let logits = forward::merged_decode_logits(info, &req.params, tokens)?;
    Ok(DecodeStepResp { logits: Tensor::f32(vec![n, info.vocab], logits) })
}

/// DoraLinear: x [bs, sq, d] + w [d, d] + a [r, d] + b [d, r] + mag [d]
/// -> y [bs, sq, d]. The four norm/compose configurations of the paper's
/// §1 table, over the registry kernels.
fn run_dora_linear(req: &DoraLinearReq) -> Result<DoraLinearResp> {
    let label = format!("dora_linear_{}", req.variant.as_str());
    if req.x.shape.len() != 3 {
        bail!(
            "op {label:?} input \"x\": expected rank-3 [bs, sq, d], got {:?}",
            req.x.shape
        );
    }
    let (bs, sq, d) = (req.x.shape[0], req.x.shape[1], req.x.shape[2]);
    let r = req.a.shape.first().copied().unwrap_or(0);
    if r == 0 {
        bail!("op {label:?} input \"a\": empty rank dimension");
    }
    expect_shape(&label, "w", &req.w, &[d, d])?;
    expect_shape(&label, "a", &req.a, &[r, d])?;
    expect_shape(&label, "b", &req.b, &[d, r])?;
    expect_shape(&label, "mag", &req.mag, &[d])?;
    let x = req.x.as_f32()?;
    let w = req.w.as_f32()?;
    let a = req.a.as_f32()?;
    let b = req.b.as_f32()?;
    let mag = req.mag.as_f32()?;

    let s = dora_linear_scale(r);
    let m = ModuleShape::new(d, d, r);
    let mut tracker = AllocTracker::new();
    let c = match req.variant {
        LinearVariant::Peft => norm_cpu::peft_norm(w, a, b, s, m, &mut tracker),
        LinearVariant::DenseBa => norm_cpu::dense_ba_norm(w, a, b, s, m, &mut tracker),
        LinearVariant::Eager | LinearVariant::Fused => {
            norm_cpu::factored_norm(w, a, b, s, m, norm_cpu::DEFAULT_CHUNK_BUDGET, &mut tracker)
        }
    };
    let g = norm_cpu::magnitude_divide(mag, &c, Dtype::F32.division_eps());

    let rows = bs * sq;
    let act = ActShape::new(rows, d);
    let base = forward::matmul_nt(x, w, rows, d, d);
    let u = forward::matmul_nt(x, a, rows, d, r);
    let lora = forward::matmul_nt(&u, b, rows, r, d);
    let kind = match req.variant {
        LinearVariant::Fused => BackendKind::Fused,
        _ => BackendKind::Eager,
    };
    let kernel = registry().compose(kind);
    let mut delta = vec![0f32; rows * d];
    kernel.forward(&base, &lora, &g, s, act, Dtype::F32, &mut delta);
    let y: Vec<f32> = base.iter().zip(&delta).map(|(&b0, &dl)| b0 + dl).collect();
    Ok(DoraLinearResp { y: Tensor::f32(vec![bs, sq, d], y) })
}

/// Compose: base + lora + g -> delta, s = 2.0 (the AOT compose units'
/// baked-in scale).
fn run_compose(req: &ComposeReq) -> Result<ComposeResp> {
    let label = format!("compose_{}", req.variant.as_str());
    if req.base.shape.len() != 2 {
        bail!(
            "op {label:?} input \"base\": expected rank-2 [rows, d_out], got {:?}",
            req.base.shape
        );
    }
    let (rows, d_out) = (req.base.shape[0], req.base.shape[1]);
    expect_shape(&label, "lora", &req.lora, &[rows, d_out])?;
    expect_shape(&label, "g", &req.g, &[d_out])?;
    let kind = match req.variant {
        Variant::Fused => BackendKind::Fused,
        Variant::Eager => BackendKind::Eager,
    };
    let kernel: Arc<dyn crate::kernels::ComposeKernel> = registry().compose(kind);
    let act = ActShape::new(rows, d_out);
    let delta = kernel.forward_alloc(
        req.base.as_f32()?,
        req.lora.as_f32()?,
        req.g.as_f32()?,
        2.0,
        act,
        Dtype::F32,
    );
    Ok(ComposeResp { delta: Tensor::f32(vec![rows, d_out], delta) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn builtin_configs_have_manifest_shape_invariants() {
        let cfgs = builtin_configs();
        for name in ["tiny", "small", "e2e"] {
            let c = &cfgs[name];
            assert_eq!(c.frozen.len(), 1 + c.n_layers, "{name}");
            assert_eq!(c.trainable.len(), 3 * c.n_layers, "{name}");
            // Leaf names are in flatten (sorted) order — the manifest
            // contract the coordinator relies on.
            let mut sorted = c.frozen.clone();
            sorted.sort();
            assert_eq!(sorted, c.frozen, "{name} frozen order");
            let mut sorted = c.trainable.clone();
            sorted.sort();
            assert_eq!(sorted, c.trainable, "{name} trainable order");
            assert!(c.n_params > 0);
        }
    }

    #[test]
    fn init_is_seeded_and_shaped() {
        let eng = NativeEngine::new();
        let a = eng.run("init_tiny", &[Tensor::scalar_i32(1)]).unwrap();
        let b = eng.run("init_tiny", &[Tensor::scalar_i32(1)]).unwrap();
        let c = eng.run("init_tiny", &[Tensor::scalar_i32(2)]).unwrap();
        let info = eng.config("tiny").unwrap();
        assert_eq!(a.len(), info.frozen.len() + info.trainable.len());
        assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
        assert_ne!(a[0].as_f32().unwrap(), c[0].as_f32().unwrap());
    }

    #[test]
    fn typed_init_matches_string_shim() {
        let eng = NativeEngine::new();
        let via_shim = eng.run("init_tiny", &[Tensor::scalar_i32(3)]).unwrap();
        let via_typed = match eng
            .execute(&EngineOp::Init(InitReq {
                config: "tiny".into(),
                seed: 3,
                precision: Precision::F32,
            }))
            .unwrap()
        {
            EngineOut::Init(r) => r,
            other => panic!("wrong response kind: {other:?}"),
        };
        let info = eng.config("tiny").unwrap();
        assert_eq!(via_typed.params.frozen.len(), info.frozen.len());
        assert_eq!(
            via_typed.params.frozen[0].as_f32().unwrap(),
            via_shim[0].as_f32().unwrap()
        );
        let nf = info.frozen.len();
        assert_eq!(
            via_typed.params.trainable[0].as_f32().unwrap(),
            via_shim[nf].as_f32().unwrap()
        );
    }

    #[test]
    fn train_chunk_contract_roundtrip() {
        let eng = NativeEngine::new();
        let info = eng.config("tiny").unwrap();
        let nf = info.frozen.len();
        let nt = info.trainable.len();
        let leaves = eng.run("init_tiny", &[Tensor::scalar_i32(0)]).unwrap();
        let zeros: Vec<Tensor> = leaves[nf..]
            .iter()
            .map(|t| Tensor::f32(t.shape.clone(), vec![0.0; t.elems()]))
            .collect();
        let mut corpus =
            crate::coordinator::data::MarkovCorpus::new(info.vocab, 3, 7);
        let k = info.chunk_steps;
        let tokens = Tensor::i32(
            vec![k, info.train_batch, info.seq + 1],
            corpus.block(k, info.train_batch, info.seq + 1),
        );
        let mut inputs = leaves.clone();
        inputs.extend(zeros.clone());
        inputs.extend(zeros.clone());
        inputs.push(Tensor::scalar_i32(0));
        inputs.push(tokens);
        let outs = eng.run("train_tiny_fused", &inputs).unwrap();
        assert_eq!(outs.len(), 3 * nt + 2);
        assert_eq!(outs[3 * nt].as_i32().unwrap()[0], k as i32);
        let losses = outs[3 * nt + 1].as_f32().unwrap();
        assert_eq!(losses.len(), k);
        assert!(losses.iter().all(|l| l.is_finite() && *l > 0.0));
        // Parameters actually moved.
        assert_ne!(outs[0].as_f32().unwrap(), leaves[nf].as_f32().unwrap());
    }

    #[test]
    fn typed_train_step_matches_string_shim() {
        let eng = NativeEngine::new();
        let info = eng.config("tiny").unwrap();
        let nf = info.frozen.len();
        let nt = info.trainable.len();
        let leaves = eng.run("init_tiny", &[Tensor::scalar_i32(5)]).unwrap();
        let params = AdapterParams {
            frozen: leaves[..nf].to_vec(),
            trainable: leaves[nf..].to_vec(),
        };
        let opt = OptState::zeros_like(&params.trainable);
        let mut corpus = crate::coordinator::data::MarkovCorpus::new(info.vocab, 3, 9);
        let k = info.chunk_steps;
        let tokens = Tensor::i32(
            vec![k, info.train_batch, info.seq + 1],
            corpus.block(k, info.train_batch, info.seq + 1),
        );
        // Typed path.
        let resp = match eng
            .execute(&EngineOp::TrainStep(TrainStepReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter: AdapterVariant::Dora,
                precision: Precision::F32,
                params: Arc::new(params.clone()),
                opt: opt.clone(),
                tokens: tokens.clone(),
            }))
            .unwrap()
        {
            EngineOut::TrainStep(r) => r,
            other => panic!("wrong response kind: {other:?}"),
        };
        // String-shim path with the identical inputs.
        let mut inputs = leaves.clone();
        inputs.extend(opt.m1.iter().cloned());
        inputs.extend(opt.m2.iter().cloned());
        inputs.push(Tensor::scalar_i32(0));
        inputs.push(tokens);
        let outs = eng.run("train_tiny_fused", &inputs).unwrap();
        assert_eq!(resp.opt.step, k as i32);
        assert_eq!(resp.losses.len(), k);
        for (i, t) in resp.trainable.iter().enumerate() {
            assert_eq!(t.as_f32().unwrap(), outs[i].as_f32().unwrap(), "leaf {i}");
        }
        assert_eq!(
            resp.losses.as_slice(),
            outs[3 * nt + 1].as_f32().unwrap(),
            "losses"
        );
    }

    #[test]
    fn split_grad_path_tracks_the_fused_train_step() {
        use crate::runtime::ops::reduce_sample_grads;
        let eng = NativeEngine::new();
        let info = eng.config("tiny").unwrap();
        let nf = info.frozen.len();
        let leaves = eng.run("init_tiny", &[Tensor::scalar_i32(3)]).unwrap();
        let params = AdapterParams {
            frozen: leaves[..nf].to_vec(),
            trainable: leaves[nf..].to_vec(),
        };
        let k = info.chunk_steps;
        let bs = info.train_batch;
        let seq1 = info.seq + 1;
        let total_rows = bs * info.seq;
        let mut corpus = crate::coordinator::data::MarkovCorpus::new(info.vocab, 3, 13);
        let block = corpus.block(k, bs, seq1);

        // Legacy chunk: k in-graph optimizer steps.
        let legacy = match eng
            .execute(&EngineOp::TrainStep(TrainStepReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter: AdapterVariant::Dora,
                precision: Precision::F32,
                params: Arc::new(params.clone()),
                opt: OptState::zeros_like(&params.trainable),
                tokens: Tensor::i32(vec![k, bs, seq1], block.clone()),
            }))
            .unwrap()
        {
            EngineOut::TrainStep(r) => r,
            other => panic!("wrong response kind: {other:?}"),
        };

        // Split path: per step, LossAndGrads over the full batch as one
        // shard, deterministic reduce, one central ApplyUpdate.
        let mut trainable = params.trainable.clone();
        let mut opt = OptState::zeros_like(&trainable);
        let mut losses = Vec::new();
        for i in 0..k {
            let step_params = AdapterParams {
                frozen: params.frozen.clone(),
                trainable: trainable.clone(),
            };
            let resp = match eng
                .execute(&EngineOp::LossAndGrads(LossAndGradsReq {
                    config: "tiny".into(),
                    variant: Variant::Fused,
                    adapter: AdapterVariant::Dora,
                    precision: Precision::F32,
                    params: Arc::new(step_params),
                    tokens: Tensor::i32(
                        vec![bs, seq1],
                        block[i * bs * seq1..(i + 1) * bs * seq1].to_vec(),
                    ),
                    total_rows,
                }))
                .unwrap()
            {
                EngineOut::LossAndGrads(r) => r,
                other => panic!("wrong response kind: {other:?}"),
            };
            assert_eq!(resp.samples.len(), bs);
            let (loss, grads) = reduce_sample_grads(&resp.samples, total_rows).unwrap();
            losses.push(loss);
            let upd = match eng
                .execute(&EngineOp::ApplyUpdate(ApplyUpdateReq {
                    config: "tiny".into(),
                    trainable,
                    opt,
                    grads,
                }))
                .unwrap()
            {
                EngineOut::ApplyUpdate(r) => r,
                other => panic!("wrong response kind: {other:?}"),
            };
            trainable = upd.trainable;
            opt = upd.opt;
        }
        assert_eq!(opt.step, k as i32);
        // The split path differs from the in-graph chunk only by the
        // per-sample f64 reduction's reassociation — per-step losses and
        // final leaves track to well under test tolerance.
        for (i, (&l, &tl)) in losses.iter().zip(&legacy.losses).enumerate() {
            assert!((l - tl).abs() < 1e-5, "step {i}: split {l} vs chunk {tl}");
        }
        for (slot, (a, b)) in trainable.iter().zip(&legacy.trainable).enumerate() {
            let (av, bv) = (a.as_f32().unwrap(), b.as_f32().unwrap());
            for (i, (&x, &y)) in av.iter().zip(bv).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-5 * y.abs().max(1e-3),
                    "leaf {slot} elem {i}: split {x} vs chunk {y}"
                );
            }
        }
    }

    #[test]
    fn loss_and_grads_shim_matches_typed_and_validates() {
        use crate::runtime::ops::decode_loss_sums;
        let eng = NativeEngine::new();
        assert!(eng.supports("loss_and_grads_tiny_fused"));
        assert!(eng.supports("apply_update_tiny"));
        assert!(!eng.supports("loss_and_grads_tiny_nope"));
        assert!(!eng.supports("apply_update_missingcfg"));

        let info = eng.config("tiny").unwrap();
        let nf = info.frozen.len();
        let nt = info.trainable.len();
        let leaves = eng.run("init_tiny", &[Tensor::scalar_i32(1)]).unwrap();
        let bs = 2usize; // a shard smaller than train_batch
        let seq1 = info.seq + 1;
        let mut corpus = crate::coordinator::data::MarkovCorpus::new(info.vocab, 3, 4);
        let tokens = Tensor::i32(vec![bs, seq1], corpus.block(1, bs, seq1));
        let total_rows = info.train_batch * info.seq;

        let typed = match eng
            .execute(&EngineOp::LossAndGrads(LossAndGradsReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter: AdapterVariant::Dora,
                precision: Precision::F32,
                params: Arc::new(AdapterParams {
                    frozen: leaves[..nf].to_vec(),
                    trainable: leaves[nf..].to_vec(),
                }),
                tokens: tokens.clone(),
                total_rows,
            }))
            .unwrap()
        {
            EngineOut::LossAndGrads(r) => r,
            other => panic!("wrong response kind: {other:?}"),
        };
        assert_eq!(typed.samples.len(), bs);
        assert_eq!(typed.samples[0].grads.len(), nt);

        // The string shim on identical inputs produces the identical
        // flattened outputs (grads sample-major, loss sums bit-packed).
        let mut inputs = leaves.clone();
        inputs.push(tokens);
        inputs.push(Tensor::scalar_i32(total_rows as i32));
        let outs = eng.run("loss_and_grads_tiny_fused", &inputs).unwrap();
        assert_eq!(outs.len(), bs * nt + 1);
        let sums = decode_loss_sums(&outs[bs * nt]).unwrap();
        for (smp, s) in typed.samples.iter().enumerate() {
            assert_eq!(s.loss_sum.to_bits(), sums[smp].to_bits(), "sample {smp}");
            for (leaf, g) in s.grads.iter().enumerate() {
                assert!(g.bitwise_eq(&outs[smp * nt + leaf]), "sample {smp} leaf {leaf}");
            }
        }

        // Validation: wrong tokens rank, zero total_rows, negative step.
        let mut bad = leaves.clone();
        bad.push(Tensor::i32(vec![4], vec![1; 4]));
        bad.push(Tensor::scalar_i32(total_rows as i32));
        assert!(eng.run("loss_and_grads_tiny_fused", &bad).is_err());
        let mut bad = leaves.clone();
        bad.push(Tensor::i32(vec![1, seq1], vec![1; seq1]));
        bad.push(Tensor::scalar_i32(0));
        assert!(eng.run("loss_and_grads_tiny_fused", &bad).is_err());
        let zeros: Vec<Tensor> = leaves[nf..]
            .iter()
            .map(|t| Tensor::f32(t.shape.clone(), vec![0.0; t.elems()]))
            .collect();
        let err = eng
            .execute(&EngineOp::ApplyUpdate(ApplyUpdateReq {
                config: "tiny".into(),
                trainable: leaves[nf..].to_vec(),
                opt: OptState { m1: zeros.clone(), m2: zeros.clone(), step: -1 },
                grads: zeros.clone(),
            }))
            .unwrap_err();
        assert!(format!("{err:#}").contains("negative"), "{err:#}");
        let err = eng
            .execute(&EngineOp::ApplyUpdate(ApplyUpdateReq {
                config: "tiny".into(),
                trainable: leaves[nf..].to_vec(),
                opt: OptState { m1: zeros.clone(), m2: zeros.clone(), step: 0 },
                grads: zeros[..nt - 1].to_vec(),
            }))
            .unwrap_err();
        assert!(format!("{err:#}").contains("leaves"), "{err:#}");
    }

    #[test]
    fn infer_contract_and_validation() {
        let eng = NativeEngine::new();
        let info = eng.config("tiny").unwrap();
        let leaves = eng.run("init_tiny", &[Tensor::scalar_i32(0)]).unwrap();
        let mut inputs = leaves.clone();
        inputs.push(Tensor::i32(
            vec![info.train_batch, info.seq],
            vec![1; info.train_batch * info.seq],
        ));
        let outs = eng.run("infer_tiny_fused", &inputs).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].shape, vec![info.train_batch, info.vocab]);
        assert!(outs[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
        // Wrong token shape errors instead of panicking.
        let mut bad = leaves;
        bad.push(Tensor::i32(vec![1, 3], vec![1, 2, 3]));
        let err = eng.run("infer_tiny_fused", &bad).unwrap_err();
        assert!(err.to_string().contains("shape"), "{err:#}");
    }

    #[test]
    fn unknown_artifacts_error() {
        let eng = NativeEngine::new();
        assert!(eng.run("no_such_artifact", &[]).is_err());
        assert!(eng.run("train_tiny_nope", &[]).is_err());
        assert!(eng.run("init_unknowncfg", &[]).is_err());
        assert!(!eng.supports("norm_dense_ba_1024x1024r64"));
        assert!(eng.supports("init_small"));
        assert!(eng.supports("infer_tiny_fused"));
        // Adapter-variant artifact names: <kernel>-<adapter> tokens.
        assert!(eng.supports("train_tiny_fused-rslora"));
        assert!(eng.supports("infer_tiny_eager-bora"));
        assert!(eng.supports("loss_and_grads_tiny_fused-rslora"));
        assert!(!eng.supports("train_tiny_fused-nope"));
        assert!(!eng.supports("eval_tiny_nope-rslora"));
        assert!(eng.supports("infer_merged_tiny"));
        assert!(!eng.supports("infer_merged_nocfg"));
        assert!(eng.supports("decode_step_tiny_fused"));
        assert!(eng.supports("decode_step_tiny_fused-bora"));
        assert!(eng.supports("decode_step_merged_tiny"));
        assert!(!eng.supports("decode_step_tiny_nope"));
        assert!(!eng.supports("decode_step_merged_nocfg"));
        assert!(eng.supports("compose_fused_512x2048"));
        // Precision-suffixed names: "-bf16" rides on the variant token
        // (or the merged ops' config segment) and composes with the
        // adapter-variant grammar.
        assert!(eng.supports("train_tiny_fused-bf16"));
        assert!(eng.supports("infer_tiny_fused-rslora-bf16"));
        assert!(eng.supports("loss_and_grads_tiny_eager-bora-bf16"));
        assert!(eng.supports("infer_merged_tiny-bf16"));
        assert!(eng.supports("decode_step_merged_tiny-bf16"));
        assert!(eng.supports("decode_step_tiny_fused-bf16"));
        assert!(!eng.supports("init_tiny-bf16")); // init is always f32 masters
        assert!(!eng.supports("train_tiny_bf16")); // precision is a suffix, not a variant
        // Input-count mismatch is an error, not a panic.
        assert!(eng.run("init_tiny", &[]).is_err());
    }

    #[test]
    fn adapter_variant_train_steps_are_finite_and_distinct() {
        let eng = NativeEngine::new();
        let info = eng.config("tiny").unwrap();
        let nt = info.trainable.len();
        let leaves = eng.run("init_tiny", &[Tensor::scalar_i32(4)]).unwrap();
        let zeros: Vec<Tensor> = leaves[info.frozen.len()..]
            .iter()
            .map(|t| Tensor::f32(t.shape.clone(), vec![0.0; t.elems()]))
            .collect();
        let mut corpus = crate::coordinator::data::MarkovCorpus::new(info.vocab, 3, 11);
        let k = info.chunk_steps;
        let tokens = Tensor::i32(
            vec![k, info.train_batch, info.seq + 1],
            corpus.block(k, info.train_batch, info.seq + 1),
        );
        let mut inputs = leaves.clone();
        inputs.extend(zeros.clone());
        inputs.extend(zeros.clone());
        inputs.push(Tensor::scalar_i32(0));
        inputs.push(tokens);
        let mut trained_a = Vec::new();
        for name in ["train_tiny_fused", "train_tiny_fused-rslora", "train_tiny_fused-bora"] {
            let outs = eng.run(name, &inputs).unwrap();
            let losses = outs[3 * nt + 1].as_f32().unwrap();
            assert!(losses.iter().all(|l| l.is_finite() && *l > 0.0), "{name}: {losses:?}");
            trained_a.push(outs[0].as_f32().unwrap().to_vec());
        }
        // The variants optimize genuinely different objectives: once B
        // moves off zero their trajectories separate from Dora's.
        assert_ne!(trained_a[0], trained_a[1], "rslora tracked dora exactly");
        assert_ne!(trained_a[0], trained_a[2], "bora tracked dora exactly");
    }

    #[test]
    fn malformed_params_and_tokens_error_not_panic() {
        let eng = NativeEngine::new();
        let info = eng.config("tiny").unwrap();
        let leaves = eng.run("init_tiny", &[Tensor::scalar_i32(0)]).unwrap();
        // Out-of-range TARGET token (last column — past the embed-lookup
        // range check) must be an Err, not an index panic in the loss.
        let bs = info.train_batch;
        let seq1 = info.seq + 1;
        let mut toks = vec![1i32; bs * seq1];
        toks[seq1 - 1] = info.vocab as i32 + 5; // row 0's final (target-only) slot
        let mut inputs = leaves.clone();
        inputs.push(Tensor::i32(vec![bs, seq1], toks));
        let err = eng.run("eval_tiny_fused", &inputs).unwrap_err();
        assert!(format!("{err:#}").contains("vocab"), "{err:#}");
        // Wrong-dtype parameter leaf must be an Err, not an expect panic.
        let mut bad = leaves.clone();
        let a_shape = bad[info.frozen.len()].shape.clone();
        let n: usize = a_shape.iter().product();
        bad[info.frozen.len()] = Tensor::i32(a_shape, vec![0; n]);
        bad.push(Tensor::i32(vec![bs, info.seq], vec![1; bs * info.seq]));
        let err = eng.run("infer_tiny_fused", &bad).unwrap_err();
        assert!(format!("{err:#}").contains("i32"), "{err:#}");
        // Typed path: param-count mismatch is an Err too.
        let err = eng
            .execute(&EngineOp::Infer(InferReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter: AdapterVariant::Dora,
                precision: Precision::F32,
                params: Arc::new(AdapterParams::default()),
                tokens: Tensor::i32(vec![bs, info.seq], vec![1; bs * info.seq]),
            }))
            .unwrap_err();
        assert!(format!("{err:#}").contains("param count"), "{err:#}");
    }

    #[test]
    fn infer_merged_matches_composed_infer() {
        let eng = NativeEngine::new();
        let info = eng.config("tiny").unwrap();
        let leaves = eng.run("init_tiny", &[Tensor::scalar_i32(2)]).unwrap();
        let params = AdapterParams::from_flat(info, leaves).unwrap();
        let bs = info.train_batch;
        let tokens = Tensor::i32(
            vec![bs, info.seq],
            (0..bs * info.seq).map(|i| (i % info.vocab) as i32).collect(),
        );
        let composed = match eng
            .execute(&EngineOp::Infer(InferReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter: AdapterVariant::Dora,
                precision: Precision::F32,
                params: Arc::new(params.clone()),
                tokens: tokens.clone(),
            }))
            .unwrap()
        {
            EngineOut::Infer(r) => r,
            other => panic!("wrong response kind: {other:?}"),
        };
        let merged = crate::models::forward::merge_adapter_params(
            info,
            &params,
            AdapterVariant::Dora,
            Precision::F32,
        )
        .unwrap();
        let fast = match eng
            .execute(&EngineOp::InferMerged(InferMergedReq {
                config: "tiny".into(),
                params: Arc::new(merged.clone()),
                tokens: tokens.clone(),
            }))
            .unwrap()
        {
            EngineOut::Infer(r) => r,
            other => panic!("wrong response kind: {other:?}"),
        };
        assert_eq!(fast.logits.shape, vec![bs, info.vocab]);
        let (c, m) = (composed.logits.as_f32().unwrap(), fast.logits.as_f32().unwrap());
        for i in 0..c.len() {
            assert!(
                (c[i] - m[i]).abs() <= 1e-5 * c[i].abs().max(1.0),
                "logit {i}: composed {} vs merged {}",
                c[i],
                m[i]
            );
        }
        // Malformed merged params error, never panic: wrong layer count...
        let short = MergedParams {
            embed: merged.embed.clone(),
            layers: merged.layers[..1].to_vec(),
            precision: Precision::F32,
        };
        let err = eng
            .execute(&EngineOp::InferMerged(InferMergedReq {
                config: "tiny".into(),
                params: Arc::new(short),
                tokens: tokens.clone(),
            }))
            .unwrap_err();
        assert!(format!("{err:#}").contains("layer count"), "{err:#}");
        // ...and wrong tokens shape.
        let err = eng
            .execute(&EngineOp::InferMerged(InferMergedReq {
                config: "tiny".into(),
                params: Arc::new(merged),
                tokens: Tensor::i32(vec![1, 2], vec![0, 1]),
            }))
            .unwrap_err();
        assert!(format!("{err:#}").contains("shape"), "{err:#}");
    }

    #[test]
    fn decode_step_is_row_local_and_matches_infer() {
        // The property the continuous batcher's determinism contract
        // rests on: a request's decode-step logits row is bitwise the
        // same whether the request runs alone, shares the step with
        // other requests, or runs through the full-prompt infer path
        // (the last position of infer depends only on its own token).
        let eng = NativeEngine::new();
        let info = eng.config("tiny").unwrap();
        let leaves = eng.run("init_tiny", &[Tensor::scalar_i32(2)]).unwrap();
        let params = Arc::new(AdapterParams::from_flat(info, leaves).unwrap());
        let decode = |toks: Vec<i32>| -> Vec<f32> {
            let n = toks.len();
            match eng
                .execute(&EngineOp::DecodeStep(DecodeStepReq {
                    config: "tiny".into(),
                    variant: Variant::Fused,
                    adapter: AdapterVariant::Dora,
                    precision: Precision::F32,
                    params: params.clone(),
                    tokens: Tensor::i32(vec![n], toks),
                }))
                .unwrap()
            {
                EngineOut::DecodeStep(r) => {
                    assert_eq!(r.logits.shape, vec![n, info.vocab]);
                    r.logits.as_f32().unwrap().to_vec()
                }
                other => panic!("wrong response kind: {other:?}"),
            }
        };
        let solo_a = decode(vec![7]);
        let solo_b = decode(vec![13]);
        let batched = decode(vec![7, 13, 21]);
        assert_eq!(&batched[..info.vocab], &solo_a[..], "row 0 depends on co-resident rows");
        assert_eq!(
            &batched[info.vocab..2 * info.vocab],
            &solo_b[..],
            "row 1 depends on co-resident rows"
        );
        // Full-prompt infer's last-position logits == decoding the
        // prompt's final token alone (the row-local prefill shortcut).
        let bs = info.train_batch;
        let mut prompt = vec![0i32; bs * info.seq];
        prompt[info.seq - 1] = 7; // row 0 ends in token 7
        let infer = match eng
            .execute(&EngineOp::Infer(InferReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter: AdapterVariant::Dora,
                precision: Precision::F32,
                params: params.clone(),
                tokens: Tensor::i32(vec![bs, info.seq], prompt),
            }))
            .unwrap()
        {
            EngineOut::Infer(r) => r.logits.as_f32().unwrap().to_vec(),
            other => panic!("wrong response kind: {other:?}"),
        };
        assert_eq!(&infer[..info.vocab], &solo_a[..], "infer vs decode_step diverge");

        // Merged decode agrees with composed decode at merge tolerance,
        // through both the typed path and the artifact-name shim.
        let merged = Arc::new(
            crate::models::forward::merge_adapter_params(
                info,
                &params,
                AdapterVariant::Dora,
                Precision::F32,
            )
            .unwrap(),
        );
        let fast = match eng
            .execute(&EngineOp::DecodeStepMerged(DecodeStepMergedReq {
                config: "tiny".into(),
                params: merged.clone(),
                tokens: Tensor::i32(vec![2], vec![7, 13]),
            }))
            .unwrap()
        {
            EngineOut::DecodeStep(r) => r.logits.as_f32().unwrap().to_vec(),
            other => panic!("wrong response kind: {other:?}"),
        };
        for (i, (&m, &c)) in fast.iter().zip(batched[..2 * info.vocab].iter()).enumerate() {
            assert!(
                (m - c).abs() <= 1e-5 * c.abs().max(1.0),
                "logit {i}: merged {m} vs composed {c}"
            );
        }
        let mut shim_inputs = vec![merged.embed.clone()];
        shim_inputs.extend(merged.layers.iter().cloned());
        shim_inputs.push(Tensor::i32(vec![2], vec![7, 13]));
        let outs = eng.run("decode_step_merged_tiny", &shim_inputs).unwrap();
        assert_eq!(outs[0].as_f32().unwrap(), &fast[..]);

        // Validation: wrong tokens rank, empty batch, oversized batch,
        // out-of-vocab token — all Err, never a panic.
        let step = |tokens: Tensor| {
            eng.execute(&EngineOp::DecodeStep(DecodeStepReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter: AdapterVariant::Dora,
                precision: Precision::F32,
                params: params.clone(),
                tokens,
            }))
        };
        assert!(step(Tensor::i32(vec![1, 2], vec![1, 2])).is_err());
        assert!(step(Tensor::i32(vec![0], vec![])).is_err());
        assert!(step(Tensor::i32(vec![bs + 1], vec![1; bs + 1])).is_err());
        assert!(step(Tensor::i32(vec![1], vec![info.vocab as i32])).is_err());
    }

    #[test]
    fn dora_linear_variants_agree() {
        // The quickstart invariant: all four configurations compute the
        // same function to ~1e-3.
        let eng = NativeEngine::new();
        let (bs, sq, d, r) = (2usize, 8usize, 32usize, 4usize);
        let mut rng = Rng::new(42);
        let x = rng.normal_vec_f32(bs * sq * d, 1.0);
        let w = rng.normal_vec_f32(d * d, 0.05);
        let a = rng.normal_vec_f32(r * d, 0.06);
        let b = rng.normal_vec_f32(d * r, 0.06);
        let s = dora_linear_scale(r);
        let mut tracker = AllocTracker::new();
        let mag = norm_cpu::factored_norm(
            &w,
            &a,
            &b,
            s,
            ModuleShape::new(d, d, r),
            u64::MAX,
            &mut tracker,
        );
        let inputs = [
            Tensor::f32(vec![bs, sq, d], x),
            Tensor::f32(vec![d, d], w),
            Tensor::f32(vec![r, d], a),
            Tensor::f32(vec![d, r], b),
            Tensor::f32(vec![d], mag),
        ];
        let mut reference: Option<Vec<f32>> = None;
        for variant in ["peft", "dense_ba", "eager", "fused"] {
            let y = eng.run(&format!("dora_linear_{variant}"), &inputs).unwrap();
            let y = y[0].as_f32().unwrap().to_vec();
            if let Some(r0) = &reference {
                let max_diff =
                    y.iter().zip(r0).map(|(p, q)| (p - q).abs()).fold(0f32, f32::max);
                assert!(max_diff < 1e-3, "{variant}: max diff {max_diff}");
            } else {
                reference = Some(y);
            }
        }
    }

    #[test]
    fn compose_units_match_flat_kernels() {
        let eng = NativeEngine::new();
        let (rows, d_out) = (64usize, 96usize);
        let mut rng = Rng::new(8);
        let base = rng.normal_vec_f32(rows * d_out, 1.0);
        let lora = rng.normal_vec_f32(rows * d_out, 0.3);
        let g: Vec<f32> =
            (0..d_out).map(|_| 1.0 + rng.normal() as f32 * 0.002).collect();
        let inputs = [
            Tensor::f32(vec![rows, d_out], base.clone()),
            Tensor::f32(vec![rows, d_out], lora.clone()),
            Tensor::f32(vec![d_out], g.clone()),
        ];
        let out = eng.run(&format!("compose_fused_{rows}x{d_out}"), &inputs).unwrap();
        let want = crate::dora::compose_cpu::compose_fused(
            &base,
            &lora,
            &g,
            2.0,
            ActShape::new(rows, d_out),
        );
        assert_eq!(out[0].as_f32().unwrap(), want.as_slice());
        let eager = eng.run(&format!("compose_eager_{rows}x{d_out}"), &inputs).unwrap();
        assert_eq!(eager[0].as_f32().unwrap(), want.as_slice());
    }
}
