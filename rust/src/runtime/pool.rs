//! Sharded execution pool: N worker engines behind per-key affinity
//! routing — the runtime substrate of the multi-worker serving path.
//!
//! The serving coordinator used to funnel every batch through ONE engine
//! behind the batcher thread, so batches for different adapters
//! serialized even though their parameter sets are independent. An
//! [`EnginePool`] owns `n` worker threads, each with its own connected
//! [`ExecBackend`] (engines are reconnected per thread from a
//! [`BackendSpec`] — PJRT clients are not `Send`), and routes jobs by an
//! affinity key:
//!
//! * **Affinity** — the first time a key is seen it is assigned the next
//!   worker round-robin; afterwards the same key always routes to the
//!   same worker. Per-key FIFO ordering is therefore preserved (the
//!   hot-swap protocol's "in-flight batches keep their snapshot" story
//!   needs jobs for one adapter to never race each other), while
//!   distinct keys spread across workers and execute concurrently.
//! * **Startup is synchronous** — every worker handshakes its engine
//!   connection back to `start`, so a backend that cannot connect fails
//!   the pool (and the server) immediately instead of leaving clients to
//!   time out against a dead thread.
//! * **Shutdown drains** — dropping the pool closes the job channels;
//!   workers finish their queued jobs, then exit, and `Drop` joins them.
//!   Nothing submitted before the drop is lost.
//!
//! Jobs are closures over `(worker_index, &ExecBackend)` so callers (the
//! server's batcher) can fan replies and record per-worker metrics from
//! inside the worker thread without the pool knowing about either.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::runtime::ops::{
    AdapterParams, AdapterVariant, LossAndGradsReq, Precision, SampleGrads, Variant,
};
use crate::runtime::{BackendSpec, ExecBackend, Tensor};
use crate::util::lock_unpoisoned;

/// One unit of pool work: runs on the routed worker's thread with that
/// worker's engine.
pub type PoolJob = Box<dyn FnOnce(usize, &ExecBackend) + Send + 'static>;

struct Worker {
    tx: Option<Sender<PoolJob>>,
    join: Option<std::thread::JoinHandle<()>>,
    executed: Arc<AtomicU64>,
}

/// A pool of worker engines with per-key affinity routing.
pub struct EnginePool {
    workers: Vec<Worker>,
    /// key -> worker index; first-seen keys take the next slot
    /// round-robin, so k keys spread over min(k, n) distinct workers.
    routes: Mutex<HashMap<String, usize>>,
}

impl EnginePool {
    /// Start `workers` worker engines connected from `spec`
    /// (0 = available parallelism). Fails fast if any worker's engine
    /// cannot connect.
    pub fn start(spec: &BackendSpec, workers: usize) -> Result<EnginePool> {
        let n = if workers == 0 { crate::dispatch::default_threads() } else { workers };
        let mut pool = EnginePool {
            workers: Vec::with_capacity(n),
            routes: Mutex::new(HashMap::new()),
        };
        for idx in 0..n {
            let (tx, rx): (Sender<PoolJob>, Receiver<PoolJob>) = mpsc::channel();
            let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
            let spec = spec.clone();
            let executed = Arc::new(AtomicU64::new(0));
            let counter = executed.clone();
            let join = std::thread::spawn(move || {
                // Connect on the worker thread (PJRT clients are not
                // Send) and report the outcome before serving.
                let engine = match spec.connect() {
                    Ok(engine) => {
                        let _ = ready_tx.send(Ok(()));
                        engine
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    // A panicking job must not kill the worker: that
                    // would silently blackhole every key affinitized to
                    // it. Catch, log, keep serving (shared state is
                    // poison-tolerant: metrics go through
                    // `lock_unpoisoned`, engines are reconnectable
                    // values).
                    let caught = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| job(idx, &engine)),
                    );
                    if caught.is_err() {
                        eprintln!("engine pool: worker {idx} job panicked; worker keeps serving");
                    }
                    counter.fetch_add(1, Ordering::Relaxed);
                }
            });
            // A partially started pool drops through `Drop` (joining the
            // workers already spawned) when a later worker fails.
            ready_rx
                .recv()
                .context("pool worker thread died during startup")?
                .with_context(|| format!("connecting pool worker {idx}"))?;
            pool.workers.push(Worker { tx: Some(tx), join: Some(join), executed });
        }
        Ok(pool)
    }

    /// Number of worker engines.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// The worker index `key` routes to (assigning one on first sight).
    pub fn route(&self, key: &str) -> usize {
        let mut routes = lock_unpoisoned(&self.routes);
        let next = routes.len() % self.workers.len();
        *routes.entry(key.to_string()).or_insert(next)
    }

    /// Submit a job under an affinity key; returns the worker index it
    /// was routed to. Jobs for the same key execute FIFO on one worker.
    /// Workers survive panicking jobs (caught and logged), so the only
    /// way a send can fail is a worker killed by the runtime itself; in
    /// that last-resort case the dropped job's reply channels close and
    /// callers observe an error rather than a hang.
    pub fn submit(&self, key: &str, job: PoolJob) -> usize {
        let idx = self.route(key);
        if let Some(tx) = self.workers[idx].tx.as_ref() {
            if tx.send(job).is_err() {
                eprintln!("engine pool: worker {idx} is gone; dropping a job for key {key:?}");
            }
        }
        idx
    }

    /// Jobs executed per worker (snapshot).
    pub fn executed(&self) -> Vec<u64> {
        self.workers
            .iter()
            .map(|w| w.executed.load(Ordering::Relaxed))
            .collect()
    }
}

/// Data-parallel gradient scatter/gather over an [`EnginePool`]: shards a
/// batch into contiguous per-worker micro-batches, runs the
/// `loss_and_grads` op concurrently on the pool's workers (each holding
/// the replicated adapter parameters behind the request's `Arc`), and
/// gathers the per-sample gradient exports back IN GLOBAL SAMPLE ORDER.
///
/// Determinism contract: the shard granularity is one sample, each
/// sample's export is computed from that sample alone (bitwise
/// independent of which worker ran it or how samples were grouped), and
/// the final reduction ([`reduce_sample_grads`](crate::runtime::ops::reduce_sample_grads))
/// accumulates in f64 in fixed sample order — so the reduced gradient is
/// **bitwise-identical for any worker count**, including uneven shards
/// when `batch % workers != 0`.
pub struct GradReducer {
    config: String,
    variant: Variant,
    adapter: AdapterVariant,
    precision: Precision,
}

impl GradReducer {
    pub fn new(
        config: impl Into<String>,
        variant: Variant,
        adapter: AdapterVariant,
        precision: Precision,
    ) -> GradReducer {
        GradReducer { config: config.into(), variant, adapter, precision }
    }

    /// Contiguous shard plan: `bs` samples over at most `workers` shards,
    /// remainder spread over the leading shards (`bs=4, workers=3` →
    /// `[0..2, 2..3, 3..4]`). Empty shards are never emitted.
    pub fn shards(bs: usize, workers: usize) -> Vec<Range<usize>> {
        let n = workers.min(bs).max(1);
        let base = bs / n;
        let rem = bs % n;
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        for w in 0..n {
            let len = base + usize::from(w < rem);
            out.push(start..start + len);
            start += len;
        }
        debug_assert_eq!(start, bs);
        out
    }

    /// Run one `[bs, seq+1]` micro-batch across the pool and return the
    /// per-sample gradient exports in global sample order. `total_rows`
    /// is the effective batch's row count (with gradient accumulation the
    /// effective batch spans several micro-batches, so it can exceed
    /// `bs * seq`).
    pub fn sample_grads(
        &self,
        pool: &EnginePool,
        params: &Arc<AdapterParams>,
        tokens: &Tensor,
        total_rows: usize,
    ) -> Result<Vec<SampleGrads>> {
        if tokens.shape.len() != 2 || tokens.shape[0] == 0 {
            bail!(
                "grad reducer tokens must be [bs >= 1, seq+1], got {:?}",
                tokens.shape
            );
        }
        let bs = tokens.shape[0];
        let stride = tokens.shape[1];
        let toks = tokens.as_i32().context("grad reducer tokens")?;
        let shards = Self::shards(bs, pool.size());
        let (tx, rx) = mpsc::channel::<(usize, Result<Vec<SampleGrads>>)>();
        for (idx, range) in shards.iter().enumerate() {
            let req = LossAndGradsReq {
                config: self.config.clone(),
                variant: self.variant,
                adapter: self.adapter,
                precision: self.precision,
                params: params.clone(),
                tokens: Tensor::i32(
                    vec![range.len(), stride],
                    toks[range.start * stride..range.end * stride].to_vec(),
                ),
                total_rows,
            };
            let tx = tx.clone();
            let want = range.len();
            // Shard index as the affinity key: on a dedicated training
            // pool, first-seen keys take workers round-robin, so shard i
            // lands on worker i (shards never outnumber workers).
            pool.submit(
                &format!("grad-shard-{idx}"),
                Box::new(move |_, engine| {
                    let result = engine.loss_and_grads(req).and_then(|resp| {
                        if resp.samples.len() != want {
                            bail!(
                                "shard returned {} samples, expected {want}",
                                resp.samples.len()
                            );
                        }
                        Ok(resp.samples)
                    });
                    let _ = tx.send((idx, result));
                }),
            );
        }
        drop(tx);
        let mut per_shard: Vec<Option<Vec<SampleGrads>>> = vec![None; shards.len()];
        for _ in 0..shards.len() {
            let (idx, result) = rx
                .recv()
                .context("a gradient worker died before returning its shard")?;
            per_shard[idx] = Some(result.with_context(|| format!("gradient shard {idx}"))?);
        }
        // Gather in shard order == global sample order (shards are
        // contiguous and emitted in order).
        let mut samples = Vec::with_capacity(bs);
        for shard in per_shard {
            samples.extend(shard.expect("all shards received"));
        }
        Ok(samples)
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        // Close every job channel first, then join: workers drain their
        // queues and exit, so nothing submitted before the drop is lost.
        for w in &mut self.workers {
            w.tx.take();
        }
        for w in &mut self.workers {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn affinity_is_stable_and_spreads_keys() {
        let pool = EnginePool::start(&BackendSpec::Native, 2).unwrap();
        assert_eq!(pool.size(), 2);
        let a = pool.route("alice");
        let b = pool.route("bob");
        assert_ne!(a, b, "two first-seen keys share a worker");
        for _ in 0..10 {
            assert_eq!(pool.route("alice"), a);
            assert_eq!(pool.route("bob"), b);
        }
        // A third key wraps around.
        assert!(pool.route("carol") < 2);
    }

    #[test]
    fn jobs_run_on_their_routed_worker_and_drain_on_drop() {
        let pool = EnginePool::start(&BackendSpec::Native, 2).unwrap();
        let hits = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for i in 0..8 {
            let key = if i % 2 == 0 { "even" } else { "odd" };
            let want = pool.route(key);
            let hits = hits.clone();
            let tx = tx.clone();
            pool.submit(
                key,
                Box::new(move |worker, engine| {
                    assert_eq!(worker, want, "job ran on the wrong worker");
                    // The worker's engine is live and serves configs.
                    assert!(engine.config("tiny").is_ok());
                    hits.fetch_add(1, Ordering::SeqCst);
                    let _ = tx.send(i);
                }),
            );
        }
        drop(tx);
        // Drop drains: all 8 jobs complete before the pool is gone.
        drop(pool);
        assert_eq!(hits.load(Ordering::SeqCst), 8);
        assert_eq!(rx.iter().count(), 8);
    }

    #[test]
    fn executed_counters_cover_submitted_jobs() {
        let pool = EnginePool::start(&BackendSpec::Native, 3).unwrap();
        let (tx, rx) = mpsc::channel();
        for i in 0..9 {
            let tx = tx.clone();
            pool.submit(
                &format!("k{}", i % 3),
                Box::new(move |_, _| {
                    let _ = tx.send(());
                }),
            );
        }
        drop(tx);
        assert_eq!(rx.iter().count(), 9);
        // Counters tick after each job returns; give the workers a beat.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let per_worker = pool.executed();
            if per_worker.iter().sum::<u64>() == 9 {
                // 3 keys round-robin onto 3 workers -> 3 jobs each.
                assert_eq!(per_worker, vec![3, 3, 3]);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "counters never reached 9");
            std::thread::yield_now();
        }
    }

    #[test]
    fn panicking_job_does_not_kill_the_worker() {
        let pool = EnginePool::start(&BackendSpec::Native, 1).unwrap();
        pool.submit("k", Box::new(|_, _| panic!("job bug")));
        // The worker must survive and serve the next job for the key.
        let (tx, rx) = mpsc::sync_channel(1);
        pool.submit(
            "k",
            Box::new(move |_, engine| {
                assert!(engine.config("tiny").is_ok());
                let _ = tx.send(());
            }),
        );
        rx.recv_timeout(std::time::Duration::from_secs(10))
            .expect("worker died after a panicking job");
    }

    #[test]
    fn grad_reducer_shards_are_contiguous_and_never_empty() {
        assert_eq!(GradReducer::shards(4, 1), vec![0..4]);
        assert_eq!(GradReducer::shards(4, 2), vec![0..2, 2..4]);
        assert_eq!(GradReducer::shards(4, 3), vec![0..2, 2..3, 3..4]);
        assert_eq!(GradReducer::shards(4, 4), vec![0..1, 1..2, 2..3, 3..4]);
        // More workers than samples: one shard per sample, no empties.
        assert_eq!(GradReducer::shards(2, 8), vec![0..1, 1..2]);
        assert_eq!(GradReducer::shards(5, 2), vec![0..3, 3..5]);
        for (bs, w) in [(1usize, 1usize), (7, 3), (8, 5), (3, 16)] {
            let shards = GradReducer::shards(bs, w);
            assert!(shards.iter().all(|r| !r.is_empty()));
            assert_eq!(shards.first().unwrap().start, 0);
            assert_eq!(shards.last().unwrap().end, bs);
            for pair in shards.windows(2) {
                assert_eq!(pair[0].end, pair[1].start);
            }
        }
    }

    #[test]
    fn grad_reducer_gathers_in_sample_order_across_pool_sizes() {
        use crate::runtime::ops::{reduce_sample_grads, InitReq, Variant};
        let be = ExecBackend::native();
        let info = be.config("tiny").unwrap();
        let init = be
            .init(InitReq { config: "tiny".into(), seed: 2, precision: Precision::F32 })
            .unwrap();
        let params = Arc::new(init.params);
        let bs = info.train_batch;
        let seq1 = info.seq + 1;
        let mut corpus = crate::coordinator::data::MarkovCorpus::new(info.vocab, 3, 21);
        let tokens = Tensor::i32(vec![bs, seq1], corpus.block(1, bs, seq1));
        let total_rows = bs * info.seq;
        let reducer =
            GradReducer::new("tiny", Variant::Fused, AdapterVariant::Dora, Precision::F32);

        let mut reference: Option<(f32, Vec<Tensor>)> = None;
        for workers in [1usize, 3] {
            let pool = EnginePool::start(&BackendSpec::Native, workers).unwrap();
            let samples = reducer
                .sample_grads(&pool, &params, &tokens, total_rows)
                .unwrap();
            assert_eq!(samples.len(), bs);
            let (loss, grads) = reduce_sample_grads(&samples, total_rows).unwrap();
            match &reference {
                None => reference = Some((loss, grads)),
                Some((l0, g0)) => {
                    assert_eq!(loss.to_bits(), l0.to_bits(), "{workers} workers");
                    for (i, (a, b)) in grads.iter().zip(g0).enumerate() {
                        assert!(a.bitwise_eq(b), "{workers} workers, leaf {i}");
                    }
                }
            }
        }
        // Malformed tokens error before any job is submitted.
        let pool = EnginePool::start(&BackendSpec::Native, 1).unwrap();
        let bad = Tensor::i32(vec![4], vec![1; 4]);
        assert!(reducer.sample_grads(&pool, &params, &bad, total_rows).is_err());
    }

    #[test]
    fn zero_workers_means_available_parallelism() {
        let pool = EnginePool::start(&BackendSpec::Native, 0).unwrap();
        assert_eq!(pool.size(), crate::dispatch::default_threads());
    }

    #[test]
    fn unconnectable_backend_fails_start_synchronously() {
        let spec = BackendSpec::Pjrt(std::path::PathBuf::from("/nonexistent/artifacts"));
        let err = EnginePool::start(&spec, 2).unwrap_err();
        assert!(!format!("{err:#}").is_empty());
    }
}
