//! Execution-backend selection: one surface over PJRT artifacts and the
//! native CPU engine, so the coordinator (Trainer/Server) and the
//! examples are not welded to one compiled runtime.
//!
//! * [`ExecBackend`] — a connected engine: PJRT ([`Engine`]), native
//!   ([`NativeEngine`]), or a scripted mock (test/bench instrumentation).
//! * [`BackendSpec`] — a *description* of a backend that can be connected
//!   on any thread. PJRT clients are not `Send`, so the server's batcher
//!   thread reconnects from the spec instead of moving an engine across
//!   the thread boundary.
//!
//! Fallback order (`auto`): PJRT when the artifacts directory has a
//! manifest AND the linked `xla` backend can actually parse HLO (the
//! offline stub cannot); otherwise the native engine. This is what turns
//! the artifact-gated coordinator paths into always-runnable ones.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::runtime::native::NativeEngine;
use crate::runtime::{manifest, ConfigInfo, Engine, Tensor};

/// A connected execution engine.
#[derive(Clone)]
pub enum ExecBackend {
    /// Compiled AOT artifacts through the PJRT runtime.
    Pjrt(Engine),
    /// The in-process kernel-registry engine.
    Native(NativeEngine),
    /// Scripted outputs (tests and batching-overhead benches).
    Mock(MockExec),
}

impl ExecBackend {
    /// Connect following the fallback order: PJRT if usable, else native.
    pub fn auto() -> ExecBackend {
        BackendSpec::auto()
            .connect()
            .unwrap_or_else(|_| ExecBackend::Native(NativeEngine::new()))
    }

    pub fn native() -> ExecBackend {
        ExecBackend::Native(NativeEngine::new())
    }

    /// Short backend kind name for logs/metrics.
    pub fn kind_name(&self) -> &'static str {
        match self {
            ExecBackend::Pjrt(_) => "pjrt",
            ExecBackend::Native(_) => "native",
            ExecBackend::Mock(_) => "mock",
        }
    }

    pub fn platform(&self) -> String {
        match self {
            ExecBackend::Pjrt(e) => e.platform(),
            ExecBackend::Native(e) => e.platform(),
            ExecBackend::Mock(_) => "mock".to_string(),
        }
    }

    /// Model configuration by name.
    pub fn config(&self, name: &str) -> Result<ConfigInfo> {
        match self {
            ExecBackend::Pjrt(e) => Ok(e.manifest().config(name)?.clone()),
            ExecBackend::Native(e) => Ok(e.config(name)?.clone()),
            ExecBackend::Mock(m) => {
                if m.info.name == name {
                    Ok(m.info.clone())
                } else {
                    bail!("mock backend only serves config {:?}, asked for {name:?}", m.info.name)
                }
            }
        }
    }

    /// Fail fast if the named artifact cannot run on this backend (for
    /// PJRT this compiles the executable, surfacing startup errors
    /// synchronously instead of from the batcher thread).
    pub fn ensure_artifact(&self, name: &str) -> Result<()> {
        match self {
            ExecBackend::Pjrt(e) => {
                e.executable(name)?;
                Ok(())
            }
            ExecBackend::Native(e) => {
                if e.supports(name) {
                    Ok(())
                } else {
                    bail!("native engine does not implement artifact {name:?}")
                }
            }
            ExecBackend::Mock(_) => Ok(()),
        }
    }

    /// Execute an artifact with host tensors.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match self {
            ExecBackend::Pjrt(e) => e.run(name, inputs),
            ExecBackend::Native(e) => e.run(name, inputs),
            ExecBackend::Mock(m) => m.run(name, inputs),
        }
    }
}

impl From<Engine> for ExecBackend {
    fn from(e: Engine) -> ExecBackend {
        ExecBackend::Pjrt(e)
    }
}

impl From<NativeEngine> for ExecBackend {
    fn from(e: NativeEngine) -> ExecBackend {
        ExecBackend::Native(e)
    }
}

impl From<MockExec> for ExecBackend {
    fn from(m: MockExec) -> ExecBackend {
        ExecBackend::Mock(m)
    }
}

/// A thread-portable description of a backend; `connect` builds the
/// engine on the calling thread.
#[derive(Clone)]
pub enum BackendSpec {
    /// PJRT over an artifacts directory.
    Pjrt(PathBuf),
    /// The native engine (builtin configs).
    Native,
    /// A scripted mock (shares its script across clones).
    Mock(MockExec),
}

impl BackendSpec {
    /// The fallback order over the default artifacts directory.
    pub fn auto() -> BackendSpec {
        let dir = manifest::default_dir();
        if pjrt_usable(&dir) {
            BackendSpec::Pjrt(dir)
        } else {
            BackendSpec::Native
        }
    }

    pub fn connect(&self) -> Result<ExecBackend> {
        match self {
            BackendSpec::Pjrt(dir) => Ok(ExecBackend::Pjrt(Engine::load(dir)?)),
            BackendSpec::Native => Ok(ExecBackend::Native(NativeEngine::new())),
            BackendSpec::Mock(m) => Ok(ExecBackend::Mock(m.clone())),
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            BackendSpec::Pjrt(_) => "pjrt",
            BackendSpec::Native => "native",
            BackendSpec::Mock(_) => "mock",
        }
    }
}

impl From<&Path> for BackendSpec {
    fn from(dir: &Path) -> BackendSpec {
        BackendSpec::Pjrt(dir.to_path_buf())
    }
}

impl From<&PathBuf> for BackendSpec {
    fn from(dir: &PathBuf) -> BackendSpec {
        BackendSpec::Pjrt(dir.clone())
    }
}

impl From<PathBuf> for BackendSpec {
    fn from(dir: PathBuf) -> BackendSpec {
        BackendSpec::Pjrt(dir)
    }
}

impl From<MockExec> for BackendSpec {
    fn from(m: MockExec) -> BackendSpec {
        BackendSpec::Mock(m)
    }
}

/// Can the linked `xla` backend actually execute artifacts from `dir`?
/// (The offline stub parses nothing; the check is cheap relative to an
/// engine's first compile.)
fn pjrt_usable(dir: &Path) -> bool {
    if !dir.join("manifest.json").exists() {
        return false;
    }
    let Ok(engine) = Engine::load(dir) else {
        return false;
    };
    let Some(art) = engine.manifest().artifacts.values().next() else {
        return false;
    };
    let path = engine.manifest().hlo_path(art);
    path.to_str()
        .map(|p| xla::HloModuleProto::from_text_file(p).is_ok())
        .unwrap_or(false)
}

/// One scripted mock result: outputs, or an error message.
pub type MockResult = std::result::Result<Vec<Tensor>, String>;

/// Scripted execution backend for tests and benches: pops pre-loaded
/// results in order; once the script is exhausted, `infer_*` artifacts
/// return well-formed zero logits (so "server keeps serving after a bad
/// batch" is testable) and everything else errors.
#[derive(Clone)]
pub struct MockExec {
    info: ConfigInfo,
    script: Arc<Mutex<VecDeque<MockResult>>>,
}

impl MockExec {
    pub fn new(info: ConfigInfo) -> MockExec {
        MockExec { info, script: Arc::new(Mutex::new(VecDeque::new())) }
    }

    /// Append a scripted result (FIFO across all clones).
    pub fn push(&self, result: MockResult) {
        self.script.lock().unwrap().push_back(result);
    }

    pub fn config_info(&self) -> &ConfigInfo {
        &self.info
    }

    fn run(&self, name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if let Some(scripted) = self.script.lock().unwrap().pop_front() {
            return scripted.map_err(|msg| anyhow::anyhow!(msg));
        }
        if name.starts_with("infer_") {
            let n = self.info.train_batch * self.info.vocab;
            return Ok(vec![Tensor::f32(
                vec![self.info.train_batch, self.info.vocab],
                vec![0.0; n],
            )]);
        }
        bail!("mock script exhausted for artifact {name:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_falls_back_to_native_without_pjrt() {
        // In the offline workspace the xla stub can never parse HLO, so
        // auto() must resolve to the native engine whether or not an
        // artifacts directory exists.
        let be = ExecBackend::auto();
        match be {
            ExecBackend::Native(_) | ExecBackend::Pjrt(_) => {}
            ExecBackend::Mock(_) => panic!("auto never yields a mock"),
        }
        // The spec-level probe agrees with the connected backend.
        assert_eq!(BackendSpec::auto().kind_name(), be.kind_name());
    }

    #[test]
    fn native_backend_serves_configs_and_artifacts() {
        let be = ExecBackend::native();
        let info = be.config("tiny").unwrap();
        assert_eq!(info.name, "tiny");
        assert!(be.config("nonexistent").is_err());
        assert!(be.ensure_artifact("infer_tiny_fused").is_ok());
        assert!(be.ensure_artifact("no_such_artifact").is_err());
        assert_eq!(be.platform(), "native-cpu");
    }

    #[test]
    fn mock_scripts_pop_in_order_then_default() {
        let info = ExecBackend::native().config("tiny").unwrap();
        let mock = MockExec::new(info.clone());
        mock.push(Err("boom".into()));
        mock.push(Ok(vec![Tensor::f32(vec![1], vec![42.0])]));
        let be: ExecBackend = mock.clone().into();
        assert!(be.run("infer_tiny_fused", &[]).is_err());
        let out = be.run("infer_tiny_fused", &[]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[42.0]);
        // Script exhausted: infer falls back to well-formed zero logits.
        let out = be.run("infer_tiny_fused", &[]).unwrap();
        assert_eq!(out[0].shape, vec![info.train_batch, info.vocab]);
        // Non-infer artifacts error once the script is gone.
        assert!(be.run("train_tiny_fused", &[]).is_err());
    }
}
