//! Execution-backend selection: one surface over PJRT artifacts and the
//! native CPU engine, so the coordinator (Trainer/Server) and the
//! examples are not welded to one compiled runtime.
//!
//! * [`ExecBackend`] — a connected engine: PJRT ([`Engine`]), native
//!   ([`NativeEngine`]), or a scripted mock (test/bench instrumentation).
//! * [`BackendSpec`] — a *description* of a backend that can be connected
//!   on any thread. PJRT clients are not `Send`, so the server's batcher
//!   thread reconnects from the spec instead of moving an engine across
//!   the thread boundary.
//!
//! Fallback order (`auto`): PJRT when the artifacts directory has a
//! manifest AND the linked `xla` backend can actually parse HLO (the
//! offline stub cannot); otherwise the native engine. This is what turns
//! the artifact-gated coordinator paths into always-runnable ones.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::runtime::native::NativeEngine;
use crate::runtime::ops::{
    ApplyUpdateReq, ApplyUpdateResp, ComposeReq, ComposeResp, DecodeStepMergedReq, DecodeStepReq,
    DecodeStepResp, DoraLinearReq, DoraLinearResp, EngineOp, EngineOut, EvalReq, EvalResp,
    InferMergedReq, InferReq, InferResp, InitReq, InitResp, LossAndGradsReq, LossAndGradsResp,
    TrainStepReq, TrainStepResp,
};
use crate::runtime::{manifest, ConfigInfo, Engine, Tensor};
use crate::util::lock_unpoisoned;

/// A connected execution engine.
#[derive(Clone)]
pub enum ExecBackend {
    /// Compiled AOT artifacts through the PJRT runtime.
    Pjrt(Engine),
    /// The in-process kernel-registry engine.
    Native(NativeEngine),
    /// Scripted outputs (tests and batching-overhead benches).
    Mock(MockExec),
}

impl ExecBackend {
    /// Connect following the fallback order: PJRT if usable, else native.
    pub fn auto() -> ExecBackend {
        BackendSpec::auto()
            .connect()
            .unwrap_or_else(|_| ExecBackend::Native(NativeEngine::new()))
    }

    pub fn native() -> ExecBackend {
        ExecBackend::Native(NativeEngine::new())
    }

    /// Short backend kind name for logs/metrics.
    pub fn kind_name(&self) -> &'static str {
        match self {
            ExecBackend::Pjrt(_) => "pjrt",
            ExecBackend::Native(_) => "native",
            ExecBackend::Mock(_) => "mock",
        }
    }

    pub fn platform(&self) -> String {
        match self {
            ExecBackend::Pjrt(e) => e.platform(),
            ExecBackend::Native(e) => e.platform(),
            ExecBackend::Mock(_) => "mock".to_string(),
        }
    }

    /// Model configuration by name.
    pub fn config(&self, name: &str) -> Result<ConfigInfo> {
        match self {
            ExecBackend::Pjrt(e) => Ok(e.manifest().config(name)?.clone()),
            ExecBackend::Native(e) => Ok(e.config(name)?.clone()),
            ExecBackend::Mock(m) => {
                if m.info.name == name {
                    Ok(m.info.clone())
                } else {
                    bail!("mock backend only serves config {:?}, asked for {name:?}", m.info.name)
                }
            }
        }
    }

    /// Fail fast if the named artifact cannot run on this backend (for
    /// PJRT this compiles the executable, surfacing startup errors
    /// synchronously instead of from the batcher thread).
    pub fn ensure_artifact(&self, name: &str) -> Result<()> {
        match self {
            ExecBackend::Pjrt(e) => {
                e.executable(name)?;
                Ok(())
            }
            ExecBackend::Native(e) => {
                if e.supports(name) {
                    Ok(())
                } else {
                    bail!("native engine does not implement artifact {name:?}")
                }
            }
            ExecBackend::Mock(_) => Ok(()),
        }
    }

    /// Execute an artifact with host tensors (the string-name surface;
    /// typed call sites use [`ExecBackend::execute`] or the per-op
    /// wrappers below).
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match self {
            ExecBackend::Pjrt(e) => e.run(name, inputs),
            ExecBackend::Native(e) => e.run(name, inputs),
            ExecBackend::Mock(m) => m.run(name, inputs),
        }
    }

    /// Execute a typed op. The native engine takes the op directly; PJRT
    /// and mock backends go through the artifact-name compatibility shim
    /// (`op.artifact_name()` + positional pack/unpack) — so a typed call
    /// site runs identically against compiled HLO, the native kernels,
    /// or a scripted mock.
    pub fn execute(&self, op: &EngineOp) -> Result<EngineOut> {
        if let ExecBackend::Native(e) = self {
            return e.execute(op);
        }
        let name = op.artifact_name()?;
        let outs = self.run(&name, &op.pack_inputs())?;
        self.unpack(op, outs)
    }

    /// Typed-response construction for the shim path.
    fn unpack(&self, op: &EngineOp, outs: Vec<Tensor>) -> Result<EngineOut> {
        Ok(match op {
            EngineOp::Init(r) => {
                let info = self.config(&r.config)?;
                EngineOut::Init(InitResp::unpack(&info, outs)?)
            }
            EngineOp::TrainStep(r) => {
                let info = self.config(&r.config)?;
                EngineOut::TrainStep(TrainStepResp::unpack(&info, outs)?)
            }
            EngineOp::LossAndGrads(r) => {
                let info = self.config(&r.config)?;
                EngineOut::LossAndGrads(LossAndGradsResp::unpack(&info, outs)?)
            }
            EngineOp::ApplyUpdate(r) => {
                let info = self.config(&r.config)?;
                EngineOut::ApplyUpdate(ApplyUpdateResp::unpack(&info, outs)?)
            }
            EngineOp::Eval(_) => EngineOut::Eval(EvalResp::unpack(outs)?),
            EngineOp::Infer(r) => {
                let info = self.config(&r.config)?;
                EngineOut::Infer(InferResp::unpack(info.train_batch, info.vocab, outs)?)
            }
            EngineOp::InferMerged(r) => {
                let info = self.config(&r.config)?;
                EngineOut::Infer(InferResp::unpack(info.train_batch, info.vocab, outs)?)
            }
            EngineOp::DecodeStep(r) => {
                let info = self.config(&r.config)?;
                EngineOut::DecodeStep(DecodeStepResp::unpack(r.tokens.elems(), info.vocab, outs)?)
            }
            EngineOp::DecodeStepMerged(r) => {
                let info = self.config(&r.config)?;
                EngineOut::DecodeStep(DecodeStepResp::unpack(r.tokens.elems(), info.vocab, outs)?)
            }
            EngineOp::DoraLinear(_) => EngineOut::DoraLinear(DoraLinearResp::unpack(outs)?),
            EngineOp::Compose(_) => EngineOut::Compose(ComposeResp::unpack(outs)?),
        })
    }

    /// Seeded in-graph parameter init.
    pub fn init(&self, req: InitReq) -> Result<InitResp> {
        match self.execute(&EngineOp::Init(req))? {
            EngineOut::Init(r) => Ok(r),
            other => bail!("engine returned {other:?} for an init op"),
        }
    }

    /// One chunk of optimizer steps.
    pub fn train_step(&self, req: TrainStepReq) -> Result<TrainStepResp> {
        match self.execute(&EngineOp::TrainStep(req))? {
            EngineOut::TrainStep(r) => Ok(r),
            other => bail!("engine returned {other:?} for a train op"),
        }
    }

    /// One data-parallel gradient shard (no optimizer step).
    pub fn loss_and_grads(&self, req: LossAndGradsReq) -> Result<LossAndGradsResp> {
        match self.execute(&EngineOp::LossAndGrads(req))? {
            EngineOut::LossAndGrads(r) => Ok(r),
            other => bail!("engine returned {other:?} for a loss_and_grads op"),
        }
    }

    /// One central AdamW step over pre-reduced gradients.
    pub fn apply_update(&self, req: ApplyUpdateReq) -> Result<ApplyUpdateResp> {
        match self.execute(&EngineOp::ApplyUpdate(req))? {
            EngineOut::ApplyUpdate(r) => Ok(r),
            other => bail!("engine returned {other:?} for an apply_update op"),
        }
    }

    /// Held-out eval loss.
    pub fn eval(&self, req: EvalReq) -> Result<EvalResp> {
        match self.execute(&EngineOp::Eval(req))? {
            EngineOut::Eval(r) => Ok(r),
            other => bail!("engine returned {other:?} for an eval op"),
        }
    }

    /// Last-position logits (the serving path). The response is fully
    /// validated — shape, dtype, element count — so callers never panic
    /// on malformed engine output.
    pub fn infer(&self, req: InferReq) -> Result<InferResp> {
        match self.execute(&EngineOp::Infer(req))? {
            EngineOut::Infer(r) => Ok(r),
            other => bail!("engine returned {other:?} for an infer op"),
        }
    }

    /// Merged-weight logits (the serving fast path). Same validated
    /// response contract as [`ExecBackend::infer`].
    pub fn infer_merged(&self, req: InferMergedReq) -> Result<InferResp> {
        match self.execute(&EngineOp::InferMerged(req))? {
            EngineOut::Infer(r) => Ok(r),
            other => bail!("engine returned {other:?} for an infer_merged op"),
        }
    }

    /// One continuous-batching decode step (composed path): next-token
    /// logits for the newest token of each active streaming request.
    /// Same validated response contract as [`ExecBackend::infer`].
    pub fn decode_step(&self, req: DecodeStepReq) -> Result<DecodeStepResp> {
        match self.execute(&EngineOp::DecodeStep(req))? {
            EngineOut::DecodeStep(r) => Ok(r),
            other => bail!("engine returned {other:?} for a decode_step op"),
        }
    }

    /// Merged-weight decode step (the streaming fast path).
    pub fn decode_step_merged(&self, req: DecodeStepMergedReq) -> Result<DecodeStepResp> {
        match self.execute(&EngineOp::DecodeStepMerged(req))? {
            EngineOut::DecodeStep(r) => Ok(r),
            other => bail!("engine returned {other:?} for a decode_step_merged op"),
        }
    }

    /// One DoRA-adapted linear module.
    pub fn dora_linear(&self, req: DoraLinearReq) -> Result<DoraLinearResp> {
        match self.execute(&EngineOp::DoraLinear(req))? {
            EngineOut::DoraLinear(r) => Ok(r),
            other => bail!("engine returned {other:?} for a dora_linear op"),
        }
    }

    /// One compose unit.
    pub fn compose(&self, req: ComposeReq) -> Result<ComposeResp> {
        match self.execute(&EngineOp::Compose(req))? {
            EngineOut::Compose(r) => Ok(r),
            other => bail!("engine returned {other:?} for a compose op"),
        }
    }
}

impl From<Engine> for ExecBackend {
    fn from(e: Engine) -> ExecBackend {
        ExecBackend::Pjrt(e)
    }
}

impl From<NativeEngine> for ExecBackend {
    fn from(e: NativeEngine) -> ExecBackend {
        ExecBackend::Native(e)
    }
}

impl From<MockExec> for ExecBackend {
    fn from(m: MockExec) -> ExecBackend {
        ExecBackend::Mock(m)
    }
}

/// A thread-portable description of a backend; `connect` builds the
/// engine on the calling thread.
#[derive(Clone)]
pub enum BackendSpec {
    /// PJRT over an artifacts directory.
    Pjrt(PathBuf),
    /// The native engine (builtin configs).
    Native,
    /// A scripted mock (shares its script across clones).
    Mock(MockExec),
}

impl BackendSpec {
    /// The fallback order over the default artifacts directory.
    pub fn auto() -> BackendSpec {
        Self::auto_for(&manifest::default_dir())
    }

    /// The fallback order over an explicit artifacts directory: PJRT
    /// when the directory has a manifest AND the linked `xla` backend
    /// can parse HLO, native otherwise. (Separated from [`Self::auto`]
    /// so the selection policy is testable without mutating the
    /// process-wide `DORA_ARTIFACTS` environment.)
    pub fn auto_for(dir: &Path) -> BackendSpec {
        if pjrt_usable(dir) {
            BackendSpec::Pjrt(dir.to_path_buf())
        } else {
            BackendSpec::Native
        }
    }

    pub fn connect(&self) -> Result<ExecBackend> {
        match self {
            BackendSpec::Pjrt(dir) => Ok(ExecBackend::Pjrt(Engine::load(dir)?)),
            BackendSpec::Native => Ok(ExecBackend::Native(NativeEngine::new())),
            BackendSpec::Mock(m) => Ok(ExecBackend::Mock(m.clone())),
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            BackendSpec::Pjrt(_) => "pjrt",
            BackendSpec::Native => "native",
            BackendSpec::Mock(_) => "mock",
        }
    }
}

impl From<&Path> for BackendSpec {
    fn from(dir: &Path) -> BackendSpec {
        BackendSpec::Pjrt(dir.to_path_buf())
    }
}

impl From<&PathBuf> for BackendSpec {
    fn from(dir: &PathBuf) -> BackendSpec {
        BackendSpec::Pjrt(dir.clone())
    }
}

impl From<PathBuf> for BackendSpec {
    fn from(dir: PathBuf) -> BackendSpec {
        BackendSpec::Pjrt(dir)
    }
}

impl From<MockExec> for BackendSpec {
    fn from(m: MockExec) -> BackendSpec {
        BackendSpec::Mock(m)
    }
}

/// Can the linked `xla` backend actually execute artifacts from `dir`?
/// (The offline stub parses nothing; the check is cheap relative to an
/// engine's first compile.)
fn pjrt_usable(dir: &Path) -> bool {
    if !dir.join("manifest.json").exists() {
        return false;
    }
    let Ok(engine) = Engine::load(dir) else {
        return false;
    };
    let Some(art) = engine.manifest().artifacts.values().next() else {
        return false;
    };
    let path = engine.manifest().hlo_path(art);
    path.to_str()
        .map(|p| xla::HloModuleProto::from_text_file(p).is_ok())
        .unwrap_or(false)
}

/// One scripted mock result: outputs, or an error message.
pub type MockResult = std::result::Result<Vec<Tensor>, String>;

/// Scripted execution backend for tests and benches: pops pre-loaded
/// results in order; once the script is exhausted, `infer_*` and
/// `decode_step_*` artifacts return well-formed zero logits (so "server
/// keeps serving after a bad batch" is testable) and everything else
/// errors.
#[derive(Clone)]
pub struct MockExec {
    info: ConfigInfo,
    script: Arc<Mutex<VecDeque<MockResult>>>,
}

impl MockExec {
    pub fn new(info: ConfigInfo) -> MockExec {
        MockExec { info, script: Arc::new(Mutex::new(VecDeque::new())) }
    }

    /// Append a scripted result (FIFO across all clones).
    pub fn push(&self, result: MockResult) {
        lock_unpoisoned(&self.script).push_back(result);
    }

    pub fn config_info(&self) -> &ConfigInfo {
        &self.info
    }

    fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if let Some(scripted) = lock_unpoisoned(&self.script).pop_front() {
            return scripted.map_err(|msg| anyhow::anyhow!(msg));
        }
        if name.starts_with("infer_") {
            let n = self.info.train_batch * self.info.vocab;
            return Ok(vec![Tensor::f32(
                vec![self.info.train_batch, self.info.vocab],
                vec![0.0; n],
            )]);
        }
        if name.starts_with("decode_step_") {
            // Decode-step batches are variably sized: derive n from the
            // trailing `[n]` token tensor so the zero-logit fallback
            // stays well-formed for any occupancy.
            let n = inputs.last().map(Tensor::elems).unwrap_or(self.info.train_batch);
            return Ok(vec![Tensor::f32(
                vec![n, self.info.vocab],
                vec![0.0; n * self.info.vocab],
            )]);
        }
        bail!("mock script exhausted for artifact {name:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_falls_back_to_native_without_pjrt() {
        // In the offline workspace the xla stub can never parse HLO, so
        // auto() must resolve to the native engine whether or not an
        // artifacts directory exists.
        let be = ExecBackend::auto();
        match be {
            ExecBackend::Native(_) | ExecBackend::Pjrt(_) => {}
            ExecBackend::Mock(_) => panic!("auto never yields a mock"),
        }
        // The spec-level probe agrees with the connected backend.
        assert_eq!(BackendSpec::auto().kind_name(), be.kind_name());
    }

    #[test]
    fn native_backend_serves_configs_and_artifacts() {
        let be = ExecBackend::native();
        let info = be.config("tiny").unwrap();
        assert_eq!(info.name, "tiny");
        assert!(be.config("nonexistent").is_err());
        assert!(be.ensure_artifact("infer_tiny_fused").is_ok());
        assert!(be.ensure_artifact("infer_merged_tiny").is_ok());
        assert!(be.ensure_artifact("no_such_artifact").is_err());
        assert_eq!(be.platform(), "native-cpu");
    }

    #[test]
    fn fallback_order_selects_native_when_pjrt_unusable() {
        // No manifest at all -> native.
        let spec = BackendSpec::auto_for(Path::new("/nonexistent/artifacts"));
        assert_eq!(spec.kind_name(), "native");
        assert_eq!(spec.connect().unwrap().kind_name(), "native");
        // A directory that exists but has no manifest -> native too.
        let empty = std::env::temp_dir()
            .join(format!("dora_backend_test_{}", std::process::id()));
        std::fs::create_dir_all(&empty).unwrap();
        assert_eq!(BackendSpec::auto_for(&empty).kind_name(), "native");
        // A directory with a manifest the xla stub can't execute ->
        // native as well (the pjrt_usable probe, not mere existence,
        // gates the PJRT branch).
        std::fs::write(
            empty.join("manifest.json"),
            r#"{"artifacts": {}, "configs": {}}"#,
        )
        .unwrap();
        assert_eq!(BackendSpec::auto_for(&empty).kind_name(), "native");
        let _ = std::fs::remove_dir_all(&empty);
    }

    #[test]
    fn explicit_pjrt_spec_fails_to_connect_without_artifacts() {
        // An explicit (non-auto) PJRT spec keeps its kind — and surfaces
        // a connect error instead of silently degrading.
        let spec = BackendSpec::Pjrt(PathBuf::from("/nonexistent/artifacts"));
        assert_eq!(spec.kind_name(), "pjrt");
        assert!(spec.connect().is_err());
        let from_path: BackendSpec = Path::new("/also/nonexistent").into();
        assert_eq!(from_path.kind_name(), "pjrt");
    }

    #[test]
    fn mock_scripted_failures_surface_through_spec_and_kind() {
        let info = ExecBackend::native().config("tiny").unwrap();
        let mock = MockExec::new(info.clone());
        mock.push(Err("scripted device loss".into()));
        let spec: BackendSpec = mock.into();
        assert_eq!(spec.kind_name(), "mock");
        let be = spec.connect().unwrap();
        assert_eq!(be.kind_name(), "mock");
        // Scripted failure pops first...
        let err = be.run("infer_tiny_fused", &[]).unwrap_err();
        assert!(format!("{err:#}").contains("scripted device loss"), "{err:#}");
        // ...then the exhausted script falls back to zero logits for
        // infer and errors for everything else.
        assert!(be.run("infer_tiny_fused", &[]).is_ok());
        assert!(be.run("train_tiny_fused", &[]).is_err());
    }

    #[test]
    fn typed_ops_run_against_native_and_mock() {
        use crate::runtime::ops::{AdapterVariant, InferReq, InitReq, Precision, Variant};
        let be = ExecBackend::native();
        let info = be.config("tiny").unwrap();
        let init = be
            .init(InitReq { config: "tiny".into(), seed: 0, precision: Precision::F32 })
            .unwrap();
        assert_eq!(init.params.frozen.len(), info.frozen.len());
        let tokens = Tensor::i32(
            vec![info.train_batch, info.seq],
            vec![1; info.train_batch * info.seq],
        );
        let params = std::sync::Arc::new(init.params);
        let resp = be
            .infer(InferReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter: AdapterVariant::Dora,
                precision: Precision::F32,
                params: params.clone(),
                tokens: tokens.clone(),
            })
            .unwrap();
        assert_eq!(resp.logits.shape, vec![info.train_batch, info.vocab]);

        // The same typed call through a mock resolves via the name shim.
        let mock = MockExec::new(info.clone());
        mock.push(Ok(vec![Tensor::f32(
            vec![info.train_batch, info.vocab],
            vec![0.25; info.train_batch * info.vocab],
        )]));
        let be: ExecBackend = mock.into();
        let resp = be
            .infer(InferReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter: AdapterVariant::Dora,
                precision: Precision::F32,
                params,
                tokens,
            })
            .unwrap();
        assert_eq!(resp.logits.as_f32().unwrap()[0], 0.25);
    }

    #[test]
    fn mock_scripts_pop_in_order_then_default() {
        let info = ExecBackend::native().config("tiny").unwrap();
        let mock = MockExec::new(info.clone());
        mock.push(Err("boom".into()));
        mock.push(Ok(vec![Tensor::f32(vec![1], vec![42.0])]));
        let be: ExecBackend = mock.clone().into();
        assert!(be.run("infer_tiny_fused", &[]).is_err());
        let out = be.run("infer_tiny_fused", &[]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[42.0]);
        // Script exhausted: infer falls back to well-formed zero logits.
        let out = be.run("infer_tiny_fused", &[]).unwrap();
        assert_eq!(out[0].shape, vec![info.train_batch, info.vocab]);
        // Non-infer artifacts error once the script is gone.
        assert!(be.run("train_tiny_fused", &[]).is_err());
    }
}
