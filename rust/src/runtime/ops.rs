//! Typed execution ops: the session-level request/response surface over
//! the execution backends.
//!
//! Historically the runtime was driven through stringly-typed artifact
//! names (`"train_tiny_fused"`) with hand-packed positional tensor lists
//! — every call site had to know the flatten order (frozen + trainable +
//! m1 + m2 + step + tokens) by heart, and a packing mistake surfaced as a
//! shape error deep inside the engine. This module replaces that surface
//! with an [`EngineOp`] enum of typed requests and typed responses:
//!
//! * [`InitReq`] / [`InitResp`] — seeded in-graph parameter init.
//! * [`TrainStepReq`] / [`TrainStepResp`] — one chunk of optimizer steps.
//! * [`LossAndGradsReq`] / [`LossAndGradsResp`] — one micro-batch's
//!   per-sample gradients, no optimizer step (the data-parallel shard op).
//! * [`ApplyUpdateReq`] / [`ApplyUpdateResp`] — one central AdamW step
//!   over pre-reduced gradients.
//! * [`EvalReq`] / [`EvalResp`] — held-out mean loss.
//! * [`InferReq`] / [`InferResp`] — last-position logits (serving).
//! * [`DecodeStepReq`] / [`DecodeStepResp`] — one continuous-batching
//!   decode step: next-token logits for the newest token of each active
//!   streaming request ([`DecodeStepMergedReq`] is its merged-weight
//!   fast path).
//! * [`DoraLinearReq`] / [`DoraLinearResp`] — one adapted module.
//! * [`ComposeReq`] / [`ComposeResp`] — one compose unit.
//!
//! The PJRT engine still speaks artifact names and positional literals,
//! so every op renders to its artifact name ([`EngineOp::artifact_name`])
//! and packs/unpacks the positional convention ([`EngineOp::pack_inputs`]
//! and the per-response `unpack`) — a thin compatibility shim that keeps
//! AOT manifest naming resolvable while every call site above the
//! backend layer is typed.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::{ConfigInfo, Tensor};

/// Numeric-path variant of the train/eval/infer ops (the paper's §5.9
/// eager-vs-fused axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Variant {
    Eager,
    #[default]
    Fused,
}

impl Variant {
    pub fn as_str(self) -> &'static str {
        match self {
            Variant::Eager => "eager",
            Variant::Fused => "fused",
        }
    }

    pub fn parse(s: &str) -> Result<Variant> {
        match s {
            "eager" => Ok(Variant::Eager),
            "fused" => Ok(Variant::Fused),
            other => bail!("variant must be eager|fused, got {other:?}"),
        }
    }
}

/// The adapter-method axis: which compose/norm math a run uses. This is
/// orthogonal to [`Variant`] (the eager-vs-fused NUMERIC path): every
/// adapter variant can run on either kernel path.
///
/// * `Dora` — the paper's row-norm DoRA. The default; bitwise-identical
///   to the pre-variant code (committed golden traces pin this).
/// * `RsLora` — rank-stabilized scaling (Kalajdzievski 2023): identical
///   compose math with the effective scale `s·√r` instead of `s`.
/// * `Bora` — bi-dimensional normalization (Wang et al. 2024): a frozen
///   derived column-magnitude `g_col = colnorm(W)/colnorm(W+sBA)` scales
///   the module INPUT, composed with the trainable row-norm DoRA path.
///
/// Future init-time variants (`Doran`, `Edora`) slot in as new arms; the
/// checkpoint header key and artifact grammar are already additive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdapterVariant {
    #[default]
    Dora,
    RsLora,
    Bora,
}

impl AdapterVariant {
    pub const ALL: [AdapterVariant; 3] =
        [AdapterVariant::Dora, AdapterVariant::RsLora, AdapterVariant::Bora];

    pub fn as_str(self) -> &'static str {
        match self {
            AdapterVariant::Dora => "dora",
            AdapterVariant::RsLora => "rslora",
            AdapterVariant::Bora => "bora",
        }
    }

    pub fn parse(s: &str) -> Result<AdapterVariant> {
        match s {
            "dora" => Ok(AdapterVariant::Dora),
            "rslora" => Ok(AdapterVariant::RsLora),
            "bora" => Ok(AdapterVariant::Bora),
            other => bail!("adapter variant must be dora|rslora|bora, got {other:?}"),
        }
    }
}

/// Render the combined artifact variant token: `Dora` keeps the historic
/// bare kernel-variant token (`fused`), non-Dora adapters append their
/// name (`fused-rslora`) so PJRT manifest names stay collision-free per
/// (kernel, adapter) pair.
pub fn variant_token(variant: Variant, adapter: AdapterVariant) -> String {
    match adapter {
        AdapterVariant::Dora => variant.as_str().to_string(),
        other => format!("{}-{}", variant.as_str(), other.as_str()),
    }
}

/// Parse a CLI `--variant` spec into the (kernel, adapter) pair. Accepts
/// the historic kernel tokens (`eager`/`fused`, implying `Dora`), bare
/// adapter tokens (`dora`/`rslora`/`bora`, implying the default `Fused`
/// kernel path), or the combined `<kernel>-<adapter>` form
/// (`eager-rslora`).
pub fn parse_variant_spec(s: &str) -> Result<(Variant, AdapterVariant)> {
    if let Ok(v) = Variant::parse(s) {
        return Ok((v, AdapterVariant::default()));
    }
    if let Ok(a) = AdapterVariant::parse(s) {
        return Ok((Variant::default(), a));
    }
    if let Some((kv, av)) = s.split_once('-') {
        if let (Ok(v), Ok(a)) = (Variant::parse(kv), AdapterVariant::parse(av)) {
            return Ok((v, a));
        }
    }
    bail!(
        "variant must be eager|fused, dora|rslora|bora, or <kernel>-<adapter> \
         (e.g. eager-rslora), got {s:?}"
    )
}

/// The end-to-end numeric operating point of a run (ROADMAP open item 2;
/// the paper's §eval bf16 measurement setting). Orthogonal to both
/// [`Variant`] (eager/fused kernel path) and [`AdapterVariant`] (compose
/// math): every (kernel, adapter) pair runs at either precision.
///
/// * `F32` — everything f32. The default; bitwise-identical to the
///   pre-precision code (committed golden fixtures pin this path).
/// * `Bf16` — the paper's "bf16 with f32 master weights" scheme: weights
///   and activations round to soft-bf16 (round-to-nearest-even via
///   `numerics::half`) at every shape-fixed point of the forward, while
///   gradients, AdamW moments, and the trainable master leaves stay f32
///   and the f64 fixed-order loss/grad reduction is unchanged. Rounding
///   is elementwise on shape-fixed tensors, so bf16 runs inherit the f32
///   path's bitwise run-to-run reproducibility and worker-count
///   invariance (DESIGN.md §3.11).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    F32,
    Bf16,
}

impl Precision {
    pub const ALL: [Precision; 2] = [Precision::F32, Precision::Bf16];

    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        }
    }

    /// Parse a CLI `--precision` spec. `bf16-master-f32` is accepted as
    /// an explicit alias for `bf16` (there is no bf16 mode WITHOUT f32
    /// master weights — the alias just names the scheme).
    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "f32" => Ok(Precision::F32),
            "bf16" | "bf16-master-f32" => Ok(Precision::Bf16),
            other => bail!("precision must be f32|bf16, got {other:?}"),
        }
    }

    /// The storage/activation dtype the forward quantizes to.
    pub fn dtype(self) -> crate::numerics::half::Dtype {
        match self {
            Precision::F32 => crate::numerics::half::Dtype::F32,
            Precision::Bf16 => crate::numerics::half::Dtype::Bf16,
        }
    }

    /// Bytes per element a merged-weight replica is accounted at (the
    /// cache/memsim byte model): f32 = 4, bf16 = 2 — a bf16 fleet fits
    /// ~2x the adapters under the same cache budget.
    pub fn bytes_per_elem(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 => 2,
        }
    }

    /// Additive artifact-name suffix: f32 renders the historic names
    /// unchanged (golden fixtures and pinned manifests stay valid), bf16
    /// appends `-bf16` to the variant token (`train_tiny_fused-bf16`) or,
    /// for merged ops, to the config segment (`infer_merged_tiny-bf16`).
    /// `-` cannot appear in a config name, so the suffix never collides.
    pub fn token_suffix(self) -> &'static str {
        match self {
            Precision::F32 => "",
            Precision::Bf16 => "-bf16",
        }
    }

    /// Strip the optional precision suffix off an artifact token — the
    /// parse-side inverse of [`Precision::token_suffix`].
    pub fn split_token(token: &str) -> (Precision, &str) {
        match token.strip_suffix("-bf16") {
            Some(rest) => (Precision::Bf16, rest),
            None => (Precision::F32, token),
        }
    }
}

/// The four single-module configurations of the paper's §1 table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinearVariant {
    Peft,
    DenseBa,
    Eager,
    Fused,
}

impl LinearVariant {
    pub const ALL: [LinearVariant; 4] = [
        LinearVariant::Peft,
        LinearVariant::DenseBa,
        LinearVariant::Eager,
        LinearVariant::Fused,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            LinearVariant::Peft => "peft",
            LinearVariant::DenseBa => "dense_ba",
            LinearVariant::Eager => "eager",
            LinearVariant::Fused => "fused",
        }
    }

    pub fn parse(s: &str) -> Result<LinearVariant> {
        match s {
            "peft" => Ok(LinearVariant::Peft),
            "dense_ba" => Ok(LinearVariant::DenseBa),
            "eager" => Ok(LinearVariant::Eager),
            "fused" => Ok(LinearVariant::Fused),
            other => bail!("dora_linear variant must be peft|dense_ba|eager|fused, got {other:?}"),
        }
    }
}

/// One adapter's parameter leaves, in the manifest's flatten order.
#[derive(Debug, Clone, Default)]
pub struct AdapterParams {
    pub frozen: Vec<Tensor>,
    pub trainable: Vec<Tensor>,
}

impl AdapterParams {
    /// Split a flat init-order leaf list (frozen then trainable).
    pub fn from_flat(info: &ConfigInfo, mut leaves: Vec<Tensor>) -> Result<AdapterParams> {
        let nf = info.frozen.len();
        let nt = info.trainable.len();
        if leaves.len() != nf + nt {
            bail!(
                "config {}: got {} leaves, expected {} frozen + {} trainable",
                info.name,
                leaves.len(),
                nf,
                nt
            );
        }
        let trainable = leaves.split_off(nf);
        Ok(AdapterParams { frozen: leaves, trainable })
    }

    /// Leaf counts match the config's?
    pub fn matches(&self, info: &ConfigInfo) -> bool {
        self.frozen.len() == info.frozen.len() && self.trainable.len() == info.trainable.len()
    }

    /// Full structural validation against a config: leaf counts, per-leaf
    /// shapes, and f32 dtype. Every mismatch is an `Err` (never a panic) —
    /// the engines and the merged-weight builder share this check.
    pub fn validate(&self, info: &ConfigInfo, label: &str) -> Result<()> {
        if !self.matches(info) {
            bail!(
                "op {label:?}: param count mismatch — got {}+{}, config {} wants {}+{}",
                self.frozen.len(),
                self.trainable.len(),
                info.name,
                info.frozen.len(),
                info.trainable.len()
            );
        }
        let check = |what: &str, t: &Tensor, shape: &[usize]| -> Result<()> {
            if t.shape != shape {
                bail!(
                    "op {label:?} input {what:?}: shape {:?} != expected {shape:?}",
                    t.shape
                );
            }
            t.as_f32()
                .with_context(|| format!("op {label:?} input {what:?}"))?;
            Ok(())
        };
        let d = info.d_model;
        let r = info.rank;
        check("embed", &self.frozen[0], &[info.vocab, d])?;
        for l in 0..info.n_layers {
            check(&info.frozen[1 + l], &self.frozen[1 + l], &[d, d])?;
            check(&info.trainable[3 * l], &self.trainable[3 * l], &[r, d])?;
            check(&info.trainable[3 * l + 1], &self.trainable[3 * l + 1], &[d, r])?;
            check(&info.trainable[3 * l + 2], &self.trainable[3 * l + 2], &[d])?;
        }
        Ok(())
    }
}

/// A merged-weight adapter: the serving fast path's precomputed
/// representation. Per layer, `W' = m ⊙ (W + s·B·A) / rownorm(W + s·B·A)`
/// (the PEFT-style DoRA merge), so steady-state inference is one plain
/// matmul per layer — no per-request norm, no compose kernel, no LoRA
/// matmuls. Built once by the server's adapter-load path
/// (`Server::load_adapter` / `Server::hot_load`) via the factored-norm
/// kernels and invalidated on swap.
#[derive(Debug, Clone)]
pub struct MergedParams {
    /// `[vocab, d]` embedding (shared with the source adapter — the
    /// embedding is not adapted).
    pub embed: Tensor,
    /// Per-layer `[d, d]` merged projection weights, layer order.
    pub layers: Vec<Tensor>,
    /// Numeric operating point the replica was merged AT: `Bf16` replicas
    /// hold bf16-rounded values (in f32 containers) and are accounted at
    /// 2 bytes/elem by the merged cache; serving rounds their activations
    /// at the same shape-fixed points as the composed bf16 path.
    pub precision: Precision,
}

impl MergedParams {
    /// Layer count matches the config's?
    pub fn matches(&self, info: &ConfigInfo) -> bool {
        self.layers.len() == info.n_layers
    }
}

/// AdamW optimizer state: first/second moments mirroring the trainable
/// leaves, plus the step counter.
#[derive(Debug, Clone, Default)]
pub struct OptState {
    pub m1: Vec<Tensor>,
    pub m2: Vec<Tensor>,
    pub step: i32,
}

impl OptState {
    /// Fresh (zeroed) state for a trainable leaf set.
    pub fn zeros_like(trainable: &[Tensor]) -> OptState {
        let zeros = |ts: &[Tensor]| -> Vec<Tensor> {
            ts.iter()
                .map(|t| Tensor::f32(t.shape.clone(), vec![0.0; t.elems()]))
                .collect()
        };
        OptState { m1: zeros(trainable), m2: zeros(trainable), step: 0 }
    }
}

/// Seeded in-graph parameter init for a named config.
///
/// `precision` rides along for provenance (the trainer stamps it into
/// checkpoints), but init emits f32 MASTER leaves at every precision —
/// under `bf16-master-f32` the rounding happens at forward time, never
/// in the stored masters — so one `init_<cfg>` artifact serves both.
#[derive(Debug, Clone)]
pub struct InitReq {
    pub config: String,
    pub seed: i32,
    pub precision: Precision,
}

#[derive(Debug, Clone)]
pub struct InitResp {
    pub params: AdapterParams,
}

impl InitResp {
    pub fn unpack(info: &ConfigInfo, outs: Vec<Tensor>) -> Result<InitResp> {
        Ok(InitResp { params: AdapterParams::from_flat(info, outs)? })
    }
}

/// One chunk of `chunk_steps` optimizer steps (the scan-over-steps
/// artifact contract). `tokens` is `[chunk_steps, train_batch, seq+1]`.
///
/// Parameters ride behind an `Arc` in every op that carries them: a
/// caller holding a parameter snapshot (the multi-adapter server's slot
/// table) builds the request with a refcount bump, not a whole-model
/// copy.
#[derive(Debug, Clone)]
pub struct TrainStepReq {
    pub config: String,
    pub variant: Variant,
    pub adapter: AdapterVariant,
    pub precision: Precision,
    pub params: Arc<AdapterParams>,
    pub opt: OptState,
    pub tokens: Tensor,
}

#[derive(Debug, Clone)]
pub struct TrainStepResp {
    pub trainable: Vec<Tensor>,
    pub opt: OptState,
    pub losses: Vec<f32>,
}

impl TrainStepResp {
    pub fn unpack(info: &ConfigInfo, outs: Vec<Tensor>) -> Result<TrainStepResp> {
        let nt = info.trainable.len();
        if outs.len() != 3 * nt + 2 {
            bail!("train op returned {} outputs, expected {}", outs.len(), 3 * nt + 2);
        }
        let step = *outs[3 * nt]
            .as_i32()
            .context("train op step counter")?
            .first()
            .context("train op returned an empty step counter")?;
        let losses = outs[3 * nt + 1].as_f32().context("train op losses")?.to_vec();
        Ok(TrainStepResp {
            trainable: outs[..nt].to_vec(),
            opt: OptState {
                m1: outs[nt..2 * nt].to_vec(),
                m2: outs[2 * nt..3 * nt].to_vec(),
                step,
            },
            losses,
        })
    }
}

/// One data-parallel gradient shard: loss + per-sample gradients for a
/// `[mb, seq+1]` micro-batch, WITHOUT the optimizer step (that runs
/// centrally via [`ApplyUpdateReq`] after the reduction). `mb` may be any
/// size >= 1 — shards of an unevenly divided batch are first-class.
///
/// `total_rows` is the row count (`effective_batch * seq`) of the
/// EFFECTIVE batch this shard belongs to: the cross-entropy gradient is
/// normalized by the effective batch, not the shard, so per-sample
/// gradients from different shards reduce into exactly the mean-loss
/// gradient of the whole batch.
#[derive(Debug, Clone)]
pub struct LossAndGradsReq {
    pub config: String,
    pub variant: Variant,
    pub adapter: AdapterVariant,
    pub precision: Precision,
    pub params: Arc<AdapterParams>,
    /// `[mb, seq+1]` micro-batch token block.
    pub tokens: Tensor,
    /// Effective-batch row count (the gradient normalization divisor).
    pub total_rows: usize,
}

/// One sample's (sequence's) gradient export: the fixed shard granularity
/// of the deterministic reduction. The f32 gradients and the f64 loss sum
/// are computed from this sample alone, so they are bitwise-independent
/// of how samples were grouped into micro-batches or spread over workers.
#[derive(Debug, Clone)]
pub struct SampleGrads {
    /// f64 sum of the sample's per-row cross-entropy terms (the reducer
    /// divides by `total_rows` once, centrally).
    pub loss_sum: f64,
    /// Per-leaf f32 gradients, trainable leaf order.
    pub grads: Vec<Tensor>,
}

#[derive(Debug, Clone)]
pub struct LossAndGradsResp {
    /// One entry per sample of the micro-batch, in batch order.
    pub samples: Vec<SampleGrads>,
}

impl LossAndGradsResp {
    pub fn unpack(info: &ConfigInfo, mut outs: Vec<Tensor>) -> Result<LossAndGradsResp> {
        let nt = info.trainable.len();
        if nt == 0 || outs.is_empty() || (outs.len() - 1) % nt != 0 {
            bail!(
                "loss_and_grads op returned {} outputs, expected mb*{nt} + 1",
                outs.len()
            );
        }
        let sums = decode_loss_sums(&outs.pop().expect("non-empty"))?;
        let mb = outs.len() / nt;
        if sums.len() != mb {
            bail!(
                "loss_and_grads op returned {} loss sums for {mb} samples",
                sums.len()
            );
        }
        let mut samples = Vec::with_capacity(mb);
        for (smp, sum) in sums.into_iter().enumerate() {
            let grads = outs[smp * nt..(smp + 1) * nt].to_vec();
            for (slot, g) in grads.iter().enumerate() {
                g.as_f32()
                    .with_context(|| format!("sample {smp} gradient leaf {slot}"))?;
            }
            samples.push(SampleGrads { loss_sum: sum, grads });
        }
        Ok(LossAndGradsResp { samples })
    }
}

/// Encode per-sample f64 loss sums as an `[n, 2]` i32 tensor of raw bit
/// halves (hi, lo) — the string-shim transport for f64 values over the
/// f32/i32 tensor boundary. Bit-exact round trip.
pub fn encode_loss_sums(sums: &[f64]) -> Tensor {
    let mut data = Vec::with_capacity(2 * sums.len());
    for &s in sums {
        let bits = s.to_bits();
        data.push((bits >> 32) as i32);
        data.push(bits as u32 as i32);
    }
    Tensor::i32(vec![sums.len(), 2], data)
}

/// Inverse of [`encode_loss_sums`].
pub fn decode_loss_sums(t: &Tensor) -> Result<Vec<f64>> {
    if t.shape.len() != 2 || t.shape[1] != 2 {
        bail!("loss-sum tensor has shape {:?}, expected [n, 2]", t.shape);
    }
    let v = t.as_i32().context("loss-sum tensor")?;
    Ok(v
        .chunks_exact(2)
        .map(|c| f64::from_bits(((c[0] as u32 as u64) << 32) | c[1] as u32 as u64))
        .collect())
}

/// The deterministic gradient reduction: for each trainable leaf, an f64
/// accumulator sums the per-sample f32 gradients IN GLOBAL SAMPLE ORDER
/// and rounds to f32 once; the per-sample f64 loss sums reduce the same
/// way and normalize by `total_rows`. Because every per-sample export is
/// bitwise-independent of sharding and the accumulation order is fixed,
/// the reduced result is bitwise-identical for ANY worker count and any
/// contiguous shard plan — the invariant `tests/train_parallel.rs` pins.
pub fn reduce_sample_grads(
    samples: &[SampleGrads],
    total_rows: usize,
) -> Result<(f32, Vec<Tensor>)> {
    let first = match samples.first() {
        Some(s) => s,
        None => bail!("gradient reduction over zero samples"),
    };
    if total_rows == 0 {
        bail!("gradient reduction with total_rows = 0");
    }
    let mut acc: Vec<Vec<f64>> = first
        .grads
        .iter()
        .map(|t| vec![0f64; t.elems()])
        .collect();
    let mut loss_sum = 0f64;
    for (smp, s) in samples.iter().enumerate() {
        loss_sum += s.loss_sum;
        if s.grads.len() != acc.len() {
            bail!(
                "sample {smp} has {} gradient leaves, sample 0 has {}",
                s.grads.len(),
                acc.len()
            );
        }
        for (slot, (a, g)) in acc.iter_mut().zip(&s.grads).enumerate() {
            if g.shape != first.grads[slot].shape {
                bail!(
                    "sample {smp} gradient leaf {slot} has shape {:?}, sample 0 has {:?}",
                    g.shape,
                    first.grads[slot].shape
                );
            }
            let gv = g
                .as_f32()
                .with_context(|| format!("sample {smp} gradient leaf {slot}"))?;
            for (ai, &gi) in a.iter_mut().zip(gv) {
                *ai += gi as f64;
            }
        }
    }
    let grads = acc
        .into_iter()
        .zip(&first.grads)
        .map(|(a, t)| {
            Tensor::f32(t.shape.clone(), a.into_iter().map(|x| x as f32).collect())
        })
        .collect();
    Ok(((loss_sum / total_rows as f64) as f32, grads))
}

/// One central AdamW step over pre-reduced gradients — the update half
/// of the split [`LossAndGradsReq`] introduced. Advances `opt.step` by 1.
#[derive(Debug, Clone)]
pub struct ApplyUpdateReq {
    pub config: String,
    /// Current trainable leaves.
    pub trainable: Vec<Tensor>,
    pub opt: OptState,
    /// Reduced f32 gradients, trainable leaf order.
    pub grads: Vec<Tensor>,
}

#[derive(Debug, Clone)]
pub struct ApplyUpdateResp {
    pub trainable: Vec<Tensor>,
    pub opt: OptState,
}

impl ApplyUpdateResp {
    pub fn unpack(info: &ConfigInfo, outs: Vec<Tensor>) -> Result<ApplyUpdateResp> {
        let nt = info.trainable.len();
        if outs.len() != 3 * nt + 1 {
            bail!(
                "apply_update op returned {} outputs, expected {}",
                outs.len(),
                3 * nt + 1
            );
        }
        let step = *outs[3 * nt]
            .as_i32()
            .context("apply_update step counter")?
            .first()
            .context("apply_update returned an empty step counter")?;
        Ok(ApplyUpdateResp {
            trainable: outs[..nt].to_vec(),
            opt: OptState {
                m1: outs[nt..2 * nt].to_vec(),
                m2: outs[2 * nt..3 * nt].to_vec(),
                step,
            },
        })
    }
}

/// Held-out eval loss. `tokens` is `[train_batch, seq+1]`.
#[derive(Debug, Clone)]
pub struct EvalReq {
    pub config: String,
    pub variant: Variant,
    pub adapter: AdapterVariant,
    pub precision: Precision,
    pub params: Arc<AdapterParams>,
    pub tokens: Tensor,
}

#[derive(Debug, Clone, Copy)]
pub struct EvalResp {
    pub loss: f32,
}

impl EvalResp {
    pub fn unpack(outs: Vec<Tensor>) -> Result<EvalResp> {
        let loss = outs
            .first()
            .context("eval op returned no outputs")?
            .scalar_f32()
            .context("eval op loss")?;
        Ok(EvalResp { loss })
    }
}

/// Last-position logits for a token batch (the Tier-2 serving path).
/// `tokens` is `[train_batch, seq]`.
#[derive(Debug, Clone)]
pub struct InferReq {
    pub config: String,
    pub variant: Variant,
    pub adapter: AdapterVariant,
    pub precision: Precision,
    pub params: Arc<AdapterParams>,
    pub tokens: Tensor,
}

#[derive(Debug, Clone)]
pub struct InferResp {
    /// `[train_batch, vocab]` f32 logits.
    pub logits: Tensor,
}

impl InferResp {
    /// Validate engine outputs down to a well-formed logits tensor. Any
    /// mismatch (missing output, wrong shape, wrong dtype) is an `Err`
    /// the serving batcher fans to its batch — never a panic.
    pub fn unpack(bs: usize, vocab: usize, mut outs: Vec<Tensor>) -> Result<InferResp> {
        if outs.is_empty() {
            bail!("engine returned no outputs for the infer op");
        }
        let first = outs.swap_remove(0);
        if first.shape != [bs, vocab] {
            bail!("infer output shape {:?} != expected [{bs}, {vocab}]", first.shape);
        }
        let logits = first
            .as_f32()
            .context("infer output has wrong dtype (expected f32 logits)")?;
        if logits.len() != bs * vocab {
            bail!("infer output has {} elements, expected {}", logits.len(), bs * vocab);
        }
        Ok(InferResp { logits: first })
    }
}

/// Merged-weight last-position logits: the serving fast path. Same
/// output contract as [`InferReq`] (`[train_batch, vocab]` f32 logits),
/// but the engine runs the precomputed [`MergedParams`] — one matmul per
/// layer instead of the full DoRA composition.
#[derive(Debug, Clone)]
pub struct InferMergedReq {
    pub config: String,
    pub params: Arc<MergedParams>,
    pub tokens: Tensor,
}

/// One continuous-batching decode step: next-token logits for `n`
/// co-resident streaming requests, each contributing its single newest
/// token. `tokens` is rank-1 `[n]` (one token per active request; `n` is
/// the current decode-batch occupancy, 1..=train_batch).
///
/// The model is row-local (no cross-position attention), so a request's
/// logits row is a function of ITS token only — bitwise-independent of
/// which other requests share the step. That property is what makes the
/// scheduler's determinism contract (DESIGN.md §3.9) hold without any
/// per-request sequence cache in the engine.
#[derive(Debug, Clone)]
pub struct DecodeStepReq {
    pub config: String,
    pub variant: Variant,
    pub adapter: AdapterVariant,
    pub precision: Precision,
    pub params: Arc<AdapterParams>,
    /// `[n]` i32 — the newest token of each active request.
    pub tokens: Tensor,
}

/// Merged-weight decode step: same contract as [`DecodeStepReq`] over the
/// precomputed [`MergedParams`] (the steady-state streaming fast path —
/// one matmul per layer per token).
#[derive(Debug, Clone)]
pub struct DecodeStepMergedReq {
    pub config: String,
    pub params: Arc<MergedParams>,
    /// `[n]` i32 — the newest token of each active request.
    pub tokens: Tensor,
}

#[derive(Debug, Clone)]
pub struct DecodeStepResp {
    /// `[n, vocab]` f32 next-token logits, request order preserved.
    pub logits: Tensor,
}

impl DecodeStepResp {
    /// Validate engine outputs down to a well-formed `[n, vocab]` logits
    /// tensor. Any mismatch is an `Err` the scheduler fans to the step's
    /// requests — never a panic.
    pub fn unpack(n: usize, vocab: usize, mut outs: Vec<Tensor>) -> Result<DecodeStepResp> {
        if outs.is_empty() {
            bail!("engine returned no outputs for the decode_step op");
        }
        let first = outs.swap_remove(0);
        if first.shape != [n, vocab] {
            bail!("decode_step output shape {:?} != expected [{n}, {vocab}]", first.shape);
        }
        let logits = first
            .as_f32()
            .context("decode_step output has wrong dtype (expected f32 logits)")?;
        if logits.len() != n * vocab {
            bail!(
                "decode_step output has {} elements, expected {}",
                logits.len(),
                n * vocab
            );
        }
        Ok(DecodeStepResp { logits: first })
    }
}

/// One DoRA-adapted linear module: `y = base + compose(base, lora, g, s)`
/// with `g` derived from the supplied magnitude vector.
#[derive(Debug, Clone)]
pub struct DoraLinearReq {
    pub variant: LinearVariant,
    /// `[bs, sq, d]` activations.
    pub x: Tensor,
    /// `[d, d]` frozen projection.
    pub w: Tensor,
    /// `[r, d]` adapter down-projection.
    pub a: Tensor,
    /// `[d, r]` adapter up-projection.
    pub b: Tensor,
    /// `[d]` magnitude vector.
    pub mag: Tensor,
}

#[derive(Debug, Clone)]
pub struct DoraLinearResp {
    /// `[bs, sq, d]` module output.
    pub y: Tensor,
}

impl DoraLinearResp {
    pub fn unpack(mut outs: Vec<Tensor>) -> Result<DoraLinearResp> {
        if outs.is_empty() {
            bail!("engine returned no outputs for the dora_linear op");
        }
        Ok(DoraLinearResp { y: outs.swap_remove(0) })
    }
}

/// One compose unit: `delta = g * (base + s*lora) - base` over the fixed
/// AOT scale. `base`/`lora` are `[rows, d_out]`, `g` is `[d_out]`.
#[derive(Debug, Clone)]
pub struct ComposeReq {
    pub variant: Variant,
    pub base: Tensor,
    pub lora: Tensor,
    pub g: Tensor,
}

#[derive(Debug, Clone)]
pub struct ComposeResp {
    /// `[rows, d_out]` delta.
    pub delta: Tensor,
}

impl ComposeResp {
    pub fn unpack(mut outs: Vec<Tensor>) -> Result<ComposeResp> {
        if outs.is_empty() {
            bail!("engine returned no outputs for the compose op");
        }
        Ok(ComposeResp { delta: outs.swap_remove(0) })
    }
}

/// A typed execution op: the request side of one engine call.
#[derive(Debug, Clone)]
pub enum EngineOp {
    Init(InitReq),
    TrainStep(TrainStepReq),
    LossAndGrads(LossAndGradsReq),
    ApplyUpdate(ApplyUpdateReq),
    Eval(EvalReq),
    Infer(InferReq),
    InferMerged(InferMergedReq),
    DecodeStep(DecodeStepReq),
    DecodeStepMerged(DecodeStepMergedReq),
    DoraLinear(DoraLinearReq),
    Compose(ComposeReq),
}

/// The typed response matching an [`EngineOp`] variant.
#[derive(Debug, Clone)]
pub enum EngineOut {
    Init(InitResp),
    TrainStep(TrainStepResp),
    LossAndGrads(LossAndGradsResp),
    ApplyUpdate(ApplyUpdateResp),
    Eval(EvalResp),
    Infer(InferResp),
    DecodeStep(DecodeStepResp),
    DoraLinear(DoraLinearResp),
    Compose(ComposeResp),
}

impl EngineOp {
    /// Render the op to its AOT artifact name — the compatibility shim
    /// that keeps PJRT manifest naming resolvable from the typed surface.
    pub fn artifact_name(&self) -> Result<String> {
        Ok(match self {
            EngineOp::Init(r) => format!("init_{}", r.config),
            EngineOp::TrainStep(r) => format!(
                "train_{}_{}{}",
                r.config,
                variant_token(r.variant, r.adapter),
                r.precision.token_suffix()
            ),
            EngineOp::LossAndGrads(r) => format!(
                "loss_and_grads_{}_{}{}",
                r.config,
                variant_token(r.variant, r.adapter),
                r.precision.token_suffix()
            ),
            EngineOp::ApplyUpdate(r) => format!("apply_update_{}", r.config),
            EngineOp::Eval(r) => format!(
                "eval_{}_{}{}",
                r.config,
                variant_token(r.variant, r.adapter),
                r.precision.token_suffix()
            ),
            EngineOp::Infer(r) => format!(
                "infer_{}_{}{}",
                r.config,
                variant_token(r.variant, r.adapter),
                r.precision.token_suffix()
            ),
            EngineOp::InferMerged(r) => {
                format!("infer_merged_{}{}", r.config, r.params.precision.token_suffix())
            }
            EngineOp::DecodeStep(r) => format!(
                "decode_step_{}_{}{}",
                r.config,
                variant_token(r.variant, r.adapter),
                r.precision.token_suffix()
            ),
            EngineOp::DecodeStepMerged(r) => {
                format!("decode_step_merged_{}{}", r.config, r.params.precision.token_suffix())
            }
            EngineOp::DoraLinear(r) => format!("dora_linear_{}", r.variant.as_str()),
            EngineOp::Compose(r) => {
                if r.base.shape.len() != 2 {
                    bail!(
                        "compose op base must be rank-2 [rows, d_out], got {:?}",
                        r.base.shape
                    );
                }
                format!(
                    "compose_{}_{}x{}",
                    r.variant.as_str(),
                    r.base.shape[0],
                    r.base.shape[1]
                )
            }
        })
    }

    /// Pack the request into the artifact's positional tensor list (the
    /// PJRT literal convention).
    pub fn pack_inputs(&self) -> Vec<Tensor> {
        match self {
            EngineOp::Init(r) => vec![Tensor::scalar_i32(r.seed)],
            EngineOp::TrainStep(r) => {
                let mut v = Vec::with_capacity(
                    r.params.frozen.len() + 3 * r.params.trainable.len() + 2,
                );
                v.extend(r.params.frozen.iter().cloned());
                v.extend(r.params.trainable.iter().cloned());
                v.extend(r.opt.m1.iter().cloned());
                v.extend(r.opt.m2.iter().cloned());
                v.push(Tensor::scalar_i32(r.opt.step));
                v.push(r.tokens.clone());
                v
            }
            EngineOp::LossAndGrads(r) => {
                let mut v = Vec::with_capacity(
                    r.params.frozen.len() + r.params.trainable.len() + 2,
                );
                v.extend(r.params.frozen.iter().cloned());
                v.extend(r.params.trainable.iter().cloned());
                v.push(r.tokens.clone());
                v.push(Tensor::scalar_i32(r.total_rows as i32));
                v
            }
            EngineOp::ApplyUpdate(r) => {
                let mut v = Vec::with_capacity(4 * r.trainable.len() + 1);
                v.extend(r.trainable.iter().cloned());
                v.extend(r.opt.m1.iter().cloned());
                v.extend(r.opt.m2.iter().cloned());
                v.push(Tensor::scalar_i32(r.opt.step));
                v.extend(r.grads.iter().cloned());
                v
            }
            EngineOp::Eval(r) => {
                let mut v = Vec::with_capacity(
                    r.params.frozen.len() + r.params.trainable.len() + 1,
                );
                v.extend(r.params.frozen.iter().cloned());
                v.extend(r.params.trainable.iter().cloned());
                v.push(r.tokens.clone());
                v
            }
            EngineOp::Infer(r) => {
                let mut v = Vec::with_capacity(
                    r.params.frozen.len() + r.params.trainable.len() + 1,
                );
                v.extend(r.params.frozen.iter().cloned());
                v.extend(r.params.trainable.iter().cloned());
                v.push(r.tokens.clone());
                v
            }
            EngineOp::InferMerged(r) => {
                let mut v = Vec::with_capacity(r.params.layers.len() + 2);
                v.push(r.params.embed.clone());
                v.extend(r.params.layers.iter().cloned());
                v.push(r.tokens.clone());
                v
            }
            EngineOp::DecodeStep(r) => {
                let mut v = Vec::with_capacity(
                    r.params.frozen.len() + r.params.trainable.len() + 1,
                );
                v.extend(r.params.frozen.iter().cloned());
                v.extend(r.params.trainable.iter().cloned());
                v.push(r.tokens.clone());
                v
            }
            EngineOp::DecodeStepMerged(r) => {
                let mut v = Vec::with_capacity(r.params.layers.len() + 2);
                v.push(r.params.embed.clone());
                v.extend(r.params.layers.iter().cloned());
                v.push(r.tokens.clone());
                v
            }
            EngineOp::DoraLinear(r) => vec![
                r.x.clone(),
                r.w.clone(),
                r.a.clone(),
                r.b.clone(),
                r.mag.clone(),
            ],
            EngineOp::Compose(r) => vec![r.base.clone(), r.lora.clone(), r.g.clone()],
        }
    }

    /// Short op kind name for logs/errors.
    pub fn kind(&self) -> &'static str {
        match self {
            EngineOp::Init(_) => "init",
            EngineOp::TrainStep(_) => "train",
            EngineOp::LossAndGrads(_) => "loss_and_grads",
            EngineOp::ApplyUpdate(_) => "apply_update",
            EngineOp::Eval(_) => "eval",
            EngineOp::Infer(_) => "infer",
            EngineOp::InferMerged(_) => "infer_merged",
            EngineOp::DecodeStep(_) => "decode_step",
            EngineOp::DecodeStepMerged(_) => "decode_step_merged",
            EngineOp::DoraLinear(_) => "dora_linear",
            EngineOp::Compose(_) => "compose",
        }
    }
}

impl EngineOut {
    /// Flatten a typed response back into the artifact's positional
    /// output list (the string-name shim's return convention).
    pub fn into_tensors(self) -> Vec<Tensor> {
        match self {
            EngineOut::Init(r) => {
                let mut v = r.params.frozen;
                v.extend(r.params.trainable);
                v
            }
            EngineOut::TrainStep(r) => {
                let mut v = r.trainable;
                v.extend(r.opt.m1);
                v.extend(r.opt.m2);
                v.push(Tensor::scalar_i32(r.opt.step));
                let k = r.losses.len();
                v.push(Tensor::f32(vec![k], r.losses));
                v
            }
            EngineOut::LossAndGrads(r) => {
                let sums: Vec<f64> = r.samples.iter().map(|s| s.loss_sum).collect();
                let mut v = Vec::with_capacity(
                    r.samples.iter().map(|s| s.grads.len()).sum::<usize>() + 1,
                );
                for s in r.samples {
                    v.extend(s.grads);
                }
                v.push(encode_loss_sums(&sums));
                v
            }
            EngineOut::ApplyUpdate(r) => {
                let mut v = r.trainable;
                v.extend(r.opt.m1);
                v.extend(r.opt.m2);
                v.push(Tensor::scalar_i32(r.opt.step));
                v
            }
            EngineOut::Eval(r) => vec![Tensor::f32(vec![], vec![r.loss])],
            EngineOut::Infer(r) => vec![r.logits],
            EngineOut::DecodeStep(r) => vec![r.logits],
            EngineOut::DoraLinear(r) => vec![r.y],
            EngineOut::Compose(r) => vec![r.delta],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_roundtrip_and_rejects() {
        assert_eq!(Variant::parse("eager").unwrap(), Variant::Eager);
        assert_eq!(Variant::parse("fused").unwrap(), Variant::Fused);
        assert!(Variant::parse("nope").is_err());
        for v in [Variant::Eager, Variant::Fused] {
            assert_eq!(Variant::parse(v.as_str()).unwrap(), v);
        }
        for v in LinearVariant::ALL {
            assert_eq!(LinearVariant::parse(v.as_str()).unwrap(), v);
        }
        assert!(LinearVariant::parse("norm").is_err());
    }

    #[test]
    fn adapter_variant_roundtrip_and_rejects() {
        for a in AdapterVariant::ALL {
            assert_eq!(AdapterVariant::parse(a.as_str()).unwrap(), a);
        }
        assert_eq!(AdapterVariant::default(), AdapterVariant::Dora);
        assert!(AdapterVariant::parse("lora").is_err());
        assert!(AdapterVariant::parse("").is_err());
    }

    #[test]
    fn variant_spec_parses_kernel_adapter_and_combined_forms() {
        // Historic kernel tokens imply Dora.
        assert_eq!(
            parse_variant_spec("fused").unwrap(),
            (Variant::Fused, AdapterVariant::Dora)
        );
        assert_eq!(
            parse_variant_spec("eager").unwrap(),
            (Variant::Eager, AdapterVariant::Dora)
        );
        // Bare adapter tokens imply the default Fused kernel path.
        assert_eq!(
            parse_variant_spec("rslora").unwrap(),
            (Variant::Fused, AdapterVariant::RsLora)
        );
        assert_eq!(
            parse_variant_spec("bora").unwrap(),
            (Variant::Fused, AdapterVariant::Bora)
        );
        assert_eq!(
            parse_variant_spec("dora").unwrap(),
            (Variant::Fused, AdapterVariant::Dora)
        );
        // Combined <kernel>-<adapter> form.
        assert_eq!(
            parse_variant_spec("eager-rslora").unwrap(),
            (Variant::Eager, AdapterVariant::RsLora)
        );
        assert_eq!(
            parse_variant_spec("fused-bora").unwrap(),
            (Variant::Fused, AdapterVariant::Bora)
        );
        assert!(parse_variant_spec("nope").is_err());
        assert!(parse_variant_spec("fused-nope").is_err());
        assert!(parse_variant_spec("nope-rslora").is_err());
    }

    #[test]
    fn variant_token_keeps_dora_names_and_extends_others() {
        // Dora renders the historic bare token — PJRT manifests and
        // golden artifacts keep their names.
        assert_eq!(variant_token(Variant::Fused, AdapterVariant::Dora), "fused");
        assert_eq!(variant_token(Variant::Eager, AdapterVariant::Dora), "eager");
        assert_eq!(variant_token(Variant::Fused, AdapterVariant::RsLora), "fused-rslora");
        assert_eq!(variant_token(Variant::Eager, AdapterVariant::Bora), "eager-bora");
    }

    #[test]
    fn artifact_names_carry_the_adapter_variant() {
        let t = |n: usize| Tensor::f32(vec![n], vec![0.0; n]);
        let params = Arc::new(AdapterParams { frozen: vec![t(2)], trainable: vec![t(3)] });
        let infer = |adapter: AdapterVariant| {
            EngineOp::Infer(InferReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter,
                precision: Precision::F32,
                params: params.clone(),
                tokens: Tensor::i32(vec![1, 2], vec![0, 1]),
            })
        };
        assert_eq!(infer(AdapterVariant::Dora).artifact_name().unwrap(), "infer_tiny_fused");
        assert_eq!(
            infer(AdapterVariant::RsLora).artifact_name().unwrap(),
            "infer_tiny_fused-rslora"
        );
        let train = EngineOp::TrainStep(TrainStepReq {
            config: "tiny".into(),
            variant: Variant::Fused,
            adapter: AdapterVariant::Bora,
            precision: Precision::F32,
            params: params.clone(),
            opt: OptState::default(),
            tokens: Tensor::i32(vec![1, 1, 2], vec![0, 1]),
        });
        assert_eq!(train.artifact_name().unwrap(), "train_tiny_fused-bora");
        let lag = EngineOp::LossAndGrads(LossAndGradsReq {
            config: "tiny".into(),
            variant: Variant::Fused,
            adapter: AdapterVariant::RsLora,
            precision: Precision::F32,
            params,
            tokens: Tensor::i32(vec![2, 3], vec![0; 6]),
            total_rows: 64,
        });
        assert_eq!(lag.artifact_name().unwrap(), "loss_and_grads_tiny_fused-rslora");
    }

    #[test]
    fn precision_parses_and_suffixes_artifact_names() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("bf16").unwrap(), Precision::Bf16);
        assert_eq!(Precision::parse("bf16-master-f32").unwrap(), Precision::Bf16);
        assert!(Precision::parse("fp16").is_err());
        assert_eq!(Precision::default(), Precision::F32);
        assert_eq!(Precision::F32.bytes_per_elem(), 4);
        assert_eq!(Precision::Bf16.bytes_per_elem(), 2);
        // Suffix/split round-trips for every (token, precision) pair.
        for p in Precision::ALL {
            for tok in ["fused", "eager-rslora", "fused-bora"] {
                let rendered = format!("{tok}{}", p.token_suffix());
                assert_eq!(Precision::split_token(&rendered), (p, tok));
            }
        }

        let t = |n: usize| Tensor::f32(vec![n], vec![0.0; n]);
        let params = Arc::new(AdapterParams { frozen: vec![t(2)], trainable: vec![t(3)] });
        let infer = |precision: Precision, adapter: AdapterVariant| {
            EngineOp::Infer(InferReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter,
                precision,
                params: params.clone(),
                tokens: Tensor::i32(vec![1, 2], vec![0, 1]),
            })
        };
        // f32 renders the historic names; bf16 appends the suffix.
        assert_eq!(
            infer(Precision::F32, AdapterVariant::Dora).artifact_name().unwrap(),
            "infer_tiny_fused"
        );
        assert_eq!(
            infer(Precision::Bf16, AdapterVariant::Dora).artifact_name().unwrap(),
            "infer_tiny_fused-bf16"
        );
        assert_eq!(
            infer(Precision::Bf16, AdapterVariant::RsLora).artifact_name().unwrap(),
            "infer_tiny_fused-rslora-bf16"
        );
        let train = EngineOp::TrainStep(TrainStepReq {
            config: "tiny".into(),
            variant: Variant::Fused,
            adapter: AdapterVariant::Dora,
            precision: Precision::Bf16,
            params: params.clone(),
            opt: OptState::default(),
            tokens: Tensor::i32(vec![1, 1, 2], vec![0, 1]),
        });
        assert_eq!(train.artifact_name().unwrap(), "train_tiny_fused-bf16");
        // Init never carries a precision suffix: masters are f32 at every
        // precision, so one artifact serves both.
        let init =
            EngineOp::Init(InitReq { config: "tiny".into(), seed: 0, precision: Precision::Bf16 });
        assert_eq!(init.artifact_name().unwrap(), "init_tiny");
        // Merged ops suffix the config segment.
        let merged = |precision: Precision| {
            EngineOp::InferMerged(InferMergedReq {
                config: "tiny".into(),
                params: Arc::new(MergedParams {
                    embed: Tensor::f32(vec![8, 4], vec![0.0; 32]),
                    layers: vec![Tensor::f32(vec![4, 4], vec![0.0; 16])],
                    precision,
                }),
                tokens: Tensor::i32(vec![1, 3], vec![0, 1, 2]),
            })
        };
        assert_eq!(merged(Precision::F32).artifact_name().unwrap(), "infer_merged_tiny");
        assert_eq!(merged(Precision::Bf16).artifact_name().unwrap(), "infer_merged_tiny-bf16");
    }

    #[test]
    fn artifact_names_render_the_manifest_convention() {
        let init =
            EngineOp::Init(InitReq { config: "tiny".into(), seed: 0, precision: Precision::F32 });
        assert_eq!(init.artifact_name().unwrap(), "init_tiny");
        let compose = EngineOp::Compose(ComposeReq {
            variant: Variant::Fused,
            base: Tensor::f32(vec![512, 2048], vec![0.0; 512 * 2048]),
            lora: Tensor::f32(vec![512, 2048], vec![0.0; 512 * 2048]),
            g: Tensor::f32(vec![2048], vec![1.0; 2048]),
        });
        assert_eq!(compose.artifact_name().unwrap(), "compose_fused_512x2048");
        let bad = EngineOp::Compose(ComposeReq {
            variant: Variant::Eager,
            base: Tensor::f32(vec![8], vec![0.0; 8]),
            lora: Tensor::f32(vec![8], vec![0.0; 8]),
            g: Tensor::f32(vec![8], vec![1.0; 8]),
        });
        assert!(bad.artifact_name().is_err());
        let lin = EngineOp::DoraLinear(DoraLinearReq {
            variant: LinearVariant::DenseBa,
            x: Tensor::f32(vec![1, 1, 1], vec![0.0]),
            w: Tensor::f32(vec![1, 1], vec![0.0]),
            a: Tensor::f32(vec![1, 1], vec![0.0]),
            b: Tensor::f32(vec![1, 1], vec![0.0]),
            mag: Tensor::f32(vec![1], vec![0.0]),
        });
        assert_eq!(lin.artifact_name().unwrap(), "dora_linear_dense_ba");
    }

    #[test]
    fn infer_merged_op_renders_and_packs() {
        let d = 4usize;
        let merged = MergedParams {
            embed: Tensor::f32(vec![8, d], vec![0.0; 8 * d]),
            layers: vec![
                Tensor::f32(vec![d, d], vec![0.0; d * d]),
                Tensor::f32(vec![d, d], vec![0.0; d * d]),
            ],
            precision: Precision::F32,
        };
        let op = EngineOp::InferMerged(InferMergedReq {
            config: "tiny".into(),
            params: Arc::new(merged),
            tokens: Tensor::i32(vec![1, 3], vec![0, 1, 2]),
        });
        assert_eq!(op.artifact_name().unwrap(), "infer_merged_tiny");
        assert_eq!(op.kind(), "infer_merged");
        let packed = op.pack_inputs();
        // embed + 2 layers + tokens.
        assert_eq!(packed.len(), 4);
        assert_eq!(packed[0].shape, vec![8, d]);
        assert_eq!(packed[3].shape, vec![1, 3]);
    }

    #[test]
    fn decode_step_ops_render_pack_and_unpack() {
        let t = |n: usize| Tensor::f32(vec![n], vec![0.0; n]);
        let params = Arc::new(AdapterParams { frozen: vec![t(2)], trainable: vec![t(3)] });
        let step = |adapter: AdapterVariant| {
            EngineOp::DecodeStep(DecodeStepReq {
                config: "tiny".into(),
                variant: Variant::Fused,
                adapter,
                precision: Precision::F32,
                params: params.clone(),
                tokens: Tensor::i32(vec![3], vec![1, 2, 3]),
            })
        };
        assert_eq!(
            step(AdapterVariant::Dora).artifact_name().unwrap(),
            "decode_step_tiny_fused"
        );
        assert_eq!(
            step(AdapterVariant::Bora).artifact_name().unwrap(),
            "decode_step_tiny_fused-bora"
        );
        assert_eq!(step(AdapterVariant::Dora).kind(), "decode_step");
        // frozen(1) + trainable(1) + tokens.
        let packed = step(AdapterVariant::Dora).pack_inputs();
        assert_eq!(packed.len(), 3);
        assert_eq!(packed[2].shape, vec![3]);

        let d = 4usize;
        let merged = EngineOp::DecodeStepMerged(DecodeStepMergedReq {
            config: "tiny".into(),
            params: Arc::new(MergedParams {
                embed: Tensor::f32(vec![8, d], vec![0.0; 8 * d]),
                layers: vec![Tensor::f32(vec![d, d], vec![0.0; d * d])],
                precision: Precision::F32,
            }),
            tokens: Tensor::i32(vec![2], vec![0, 1]),
        });
        assert_eq!(merged.artifact_name().unwrap(), "decode_step_merged_tiny");
        assert_eq!(merged.kind(), "decode_step_merged");
        // embed + 1 layer + tokens.
        assert_eq!(merged.pack_inputs().len(), 3);

        // Response validation mirrors InferResp::unpack.
        assert!(DecodeStepResp::unpack(2, 4, vec![]).is_err());
        assert!(
            DecodeStepResp::unpack(2, 4, vec![Tensor::f32(vec![2, 3], vec![0.0; 6])]).is_err()
        );
        assert!(
            DecodeStepResp::unpack(2, 4, vec![Tensor::i32(vec![2, 4], vec![0; 8])]).is_err()
        );
        let ok =
            DecodeStepResp::unpack(2, 4, vec![Tensor::f32(vec![2, 4], vec![0.5; 8])]).unwrap();
        assert_eq!(ok.logits.shape, vec![2, 4]);
    }

    #[test]
    fn infer_unpack_rejects_malformed_outputs() {
        assert!(InferResp::unpack(2, 4, vec![]).is_err());
        assert!(
            InferResp::unpack(2, 4, vec![Tensor::f32(vec![2, 3], vec![0.0; 6])]).is_err()
        );
        assert!(InferResp::unpack(2, 4, vec![Tensor::i32(vec![2, 4], vec![0; 8])]).is_err());
        let ok = InferResp::unpack(2, 4, vec![Tensor::f32(vec![2, 4], vec![0.5; 8])]).unwrap();
        assert_eq!(ok.logits.shape, vec![2, 4]);
    }

    #[test]
    fn opt_state_zeros_mirror_trainable_shapes() {
        let trainable = vec![
            Tensor::f32(vec![2, 3], vec![1.0; 6]),
            Tensor::f32(vec![4], vec![1.0; 4]),
        ];
        let opt = OptState::zeros_like(&trainable);
        assert_eq!(opt.step, 0);
        assert_eq!(opt.m1.len(), 2);
        assert_eq!(opt.m1[0].shape, vec![2, 3]);
        assert_eq!(opt.m2[1].shape, vec![4]);
        assert!(opt.m1[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn loss_sums_roundtrip_bit_exact() {
        let sums = [0.0f64, -0.0, 1.5, -3.25e-7, 4.243542117e2, f64::MIN_POSITIVE];
        let t = encode_loss_sums(&sums);
        assert_eq!(t.shape, vec![sums.len(), 2]);
        let back = decode_loss_sums(&t).unwrap();
        for (a, b) in sums.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Malformed shapes and dtypes error.
        assert!(decode_loss_sums(&Tensor::i32(vec![4], vec![0; 4])).is_err());
        assert!(decode_loss_sums(&Tensor::f32(vec![1, 2], vec![0.0; 2])).is_err());
    }

    #[test]
    fn loss_and_grads_op_renders_packs_and_unpacks() {
        let t = |n: usize| Tensor::f32(vec![n], vec![0.5; n]);
        let op = EngineOp::LossAndGrads(LossAndGradsReq {
            config: "tiny".into(),
            variant: Variant::Fused,
            adapter: AdapterVariant::Dora,
            precision: Precision::F32,
            params: Arc::new(AdapterParams { frozen: vec![t(2)], trainable: vec![t(3)] }),
            tokens: Tensor::i32(vec![2, 3], vec![0; 6]),
            total_rows: 64,
        });
        assert_eq!(op.artifact_name().unwrap(), "loss_and_grads_tiny_fused");
        assert_eq!(op.kind(), "loss_and_grads");
        let packed = op.pack_inputs();
        // frozen(1) + trainable(1) + tokens + total_rows = 4.
        assert_eq!(packed.len(), 4);
        assert_eq!(packed[3].as_i32().unwrap(), &[64]);

        // Response flatten/unpack roundtrip through the shim convention.
        let resp = LossAndGradsResp {
            samples: vec![
                SampleGrads { loss_sum: 1.25, grads: vec![t(3)] },
                SampleGrads { loss_sum: -0.5, grads: vec![t(3)] },
            ],
        };
        let outs = EngineOut::LossAndGrads(resp).into_tensors();
        assert_eq!(outs.len(), 3); // 2 samples x 1 leaf + loss sums.
        let info = ConfigInfo {
            name: "t".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 1,
            seq: 2,
            rank: 1,
            scale: 2.0,
            n_params: 0,
            train_batch: 2,
            chunk_steps: 1,
            frozen: vec!["embed".into()],
            trainable: vec!["layers.0.a".into()],
        };
        let back = LossAndGradsResp::unpack(&info, outs).unwrap();
        assert_eq!(back.samples.len(), 2);
        assert_eq!(back.samples[0].loss_sum, 1.25);
        assert_eq!(back.samples[1].loss_sum, -0.5);
        // Wrong output count errors.
        assert!(LossAndGradsResp::unpack(&info, vec![]).is_err());
    }

    #[test]
    fn reduce_sample_grads_is_partition_invariant_and_validates() {
        let g = |vals: Vec<f32>| Tensor::f32(vec![vals.len()], vals);
        let samples: Vec<SampleGrads> = (0..4)
            .map(|i| SampleGrads {
                loss_sum: 1.0 + i as f64 * 0.125,
                grads: vec![g(vec![0.1 * i as f32, -0.2, 1.0 + i as f32])],
            })
            .collect();
        let (loss, grads) = reduce_sample_grads(&samples, 64).unwrap();
        assert!(loss > 0.0);
        assert_eq!(grads.len(), 1);
        // The reduction is a pure function of the ordered sample list:
        // re-reducing the same list is bitwise identical (partitioning
        // across workers never reorders samples, so this IS the
        // worker-count invariance at the reducer level).
        let (loss2, grads2) = reduce_sample_grads(&samples, 64).unwrap();
        assert_eq!(loss.to_bits(), loss2.to_bits());
        assert!(grads[0].bitwise_eq(&grads2[0]));
        // Empty sample lists and zero rows are errors.
        assert!(reduce_sample_grads(&[], 64).is_err());
        assert!(reduce_sample_grads(&samples, 0).is_err());
        // Shape mismatches across samples are errors.
        let bad = vec![
            samples[0].clone(),
            SampleGrads { loss_sum: 0.0, grads: vec![g(vec![1.0])] },
        ];
        assert!(reduce_sample_grads(&bad, 64).is_err());
    }

    #[test]
    fn apply_update_op_renders_packs_and_unpacks() {
        let t = |n: usize| Tensor::f32(vec![n], vec![0.0; n]);
        let op = EngineOp::ApplyUpdate(ApplyUpdateReq {
            config: "tiny".into(),
            trainable: vec![t(3)],
            opt: OptState { m1: vec![t(3)], m2: vec![t(3)], step: 5 },
            grads: vec![t(3)],
        });
        assert_eq!(op.artifact_name().unwrap(), "apply_update_tiny");
        assert_eq!(op.kind(), "apply_update");
        let packed = op.pack_inputs();
        // trainable + m1 + m2 + step + grads = 5.
        assert_eq!(packed.len(), 5);
        assert_eq!(packed[3].as_i32().unwrap(), &[5]);

        let resp = ApplyUpdateResp {
            trainable: vec![t(3)],
            opt: OptState { m1: vec![t(3)], m2: vec![t(3)], step: 6 },
        };
        let outs = EngineOut::ApplyUpdate(resp).into_tensors();
        assert_eq!(outs.len(), 4);
        let info = ConfigInfo {
            name: "t".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 1,
            seq: 2,
            rank: 1,
            scale: 2.0,
            n_params: 0,
            train_batch: 2,
            chunk_steps: 1,
            frozen: vec!["embed".into()],
            trainable: vec!["layers.0.a".into()],
        };
        let back = ApplyUpdateResp::unpack(&info, outs).unwrap();
        assert_eq!(back.opt.step, 6);
        assert!(ApplyUpdateResp::unpack(&info, vec![]).is_err());
    }

    #[test]
    fn train_pack_order_matches_the_artifact_contract() {
        let t = |n: usize| Tensor::f32(vec![n], vec![0.0; n]);
        let req = TrainStepReq {
            config: "tiny".into(),
            variant: Variant::Fused,
            adapter: AdapterVariant::Dora,
            precision: Precision::F32,
            params: Arc::new(AdapterParams { frozen: vec![t(1), t(2)], trainable: vec![t(3)] }),
            opt: OptState { m1: vec![t(3)], m2: vec![t(3)], step: 7 },
            tokens: Tensor::i32(vec![1, 1, 2], vec![0, 1]),
        };
        let op = EngineOp::TrainStep(req);
        assert_eq!(op.artifact_name().unwrap(), "train_tiny_fused");
        let packed = op.pack_inputs();
        // frozen(2) + trainable(1) + m1(1) + m2(1) + step + tokens = 7.
        assert_eq!(packed.len(), 7);
        assert_eq!(packed[5].as_i32().unwrap(), &[7]);
        assert_eq!(packed[6].shape, vec![1, 1, 2]);
    }
}
