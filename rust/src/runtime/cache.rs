//! Budgeted merged-weight cache for multi-tenant adapter serving.
//!
//! The paper's memory argument — hundreds of adapted modules make dense
//! per-module products infeasible on one device — reappears one level up
//! in serving: a fleet hosts thousands of adapters per base model, and a
//! resident merged `W' = m ⊙ (W + s·B·A) / rownorm` for every one of them
//! is exactly the unbounded transient footprint the factored-norm kernels
//! were built to avoid. This module bounds it: merged weights live under
//! an explicit byte budget, cold adapters serve the composed path while
//! their merge builds asynchronously, and an LRU/clock policy evicts.
//!
//! Per-adapter lifecycle (DESIGN.md §3.10):
//!
//! ```text
//!   cold --miss claimed--> building --promote--> resident
//!     ^                       |                    |
//!     |        stale / rejected / build failed     | evicted (budget
//!     +-----------------------+--------------------+  pressure, unpinned)
//! ```
//!
//! **Publication.** Each adapter entry owns a [`MergeSlot`] — a mutex'd
//! `Option<Arc<MergedParams>>`. Serving paths [`MergeSlot::snapshot`] it
//! once per engine call: they either see the whole merge or none of it,
//! the same torn-weight-free exchange the hot-swap table gives parameter
//! sets. [`MergedCache::promote`] fills the slot only after accounting
//! and eviction have made room, and only if the adapter's registered
//! generation still matches the one the merge was built from (a build
//! that raced a hot-swap is discarded as stale, never published).
//!
//! **Eviction vs. replacement.** Budget eviction clears the victim's
//! slot — the entry stays in the serving table and falls back to the
//! composed path until re-promoted. Replacement ([`MergedCache::register`]
//! with a new generation) releases the old residency *without* clearing
//! the old entry's slot: the old entry is leaving the table anyway, and
//! in-flight groups that snapshotted it keep serving its merge bitwise
//! until they drain. Either way the `Arc` keeps evicted bytes alive for
//! holders; the budget governs *accounted residency*, not liveness.
//!
//! **Pinning.** A decode stream pins its adapter for its whole lifetime
//! (admission → finish/cancel). Pinned adapters are exempt from budget
//! eviction — a promotion that cannot fit without evicting pinned
//! residents is rejected (counted) and the adapter stays composed.
//! Pins do NOT block replacement: a hot-swap is a correctness event.
//!
//! **Accounting spine.** Every promotion/eviction is an alloc/free on a
//! [`CachingAllocator`] (512-byte rounded, like the CUDA allocator it
//! models) and is appended to a replayable [`Event`] stream — so
//! resident bytes, the high-water mark, and `mem_events` replay all agree
//! by construction. The property test below churns random
//! register/promote/pin/evict sequences against exactly that invariant.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{bail, Result};

use crate::memsim::{CachingAllocator, Event};
use crate::runtime::ops::MergedParams;
use crate::util::lock_unpoisoned;

/// Eviction policy over resident merged weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CachePolicy {
    /// Evict the least-recently-served resident merge.
    #[default]
    Lru,
    /// Clock (second-chance): a sweeping hand clears reference bits and
    /// evicts the first unreferenced resident it meets — LRU-approximate
    /// with O(1) bookkeeping per hit.
    Clock,
}

impl CachePolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            CachePolicy::Lru => "lru",
            CachePolicy::Clock => "clock",
        }
    }

    pub fn parse(s: &str) -> Result<CachePolicy> {
        match s {
            "lru" => Ok(CachePolicy::Lru),
            "clock" => Ok(CachePolicy::Clock),
            other => bail!("cache policy must be lru|clock, got {other:?}"),
        }
    }
}

/// Outcome of a [`MergedCache::promote`] attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Promotion {
    /// The merge is published and accounted under the budget.
    Resident,
    /// The merge did not fit (oversized, or the budget is held by pinned
    /// residents). The adapter keeps serving composed; a later miss may
    /// rebuild and retry.
    Rejected,
    /// The merge was built against a generation that has since been
    /// replaced (hot-swap raced the build). Discarded, never published.
    Stale,
}

/// Counter/gauge snapshot of a [`MergedCache`].
#[derive(Debug, Default, Clone)]
pub struct CacheStats {
    /// Engine calls served from a resident merge.
    pub hits: u64,
    /// Engine calls that found the slot cold and served composed.
    pub misses: u64,
    /// Residents evicted under budget pressure.
    pub evictions: u64,
    /// Merges published and accounted.
    pub promotions: u64,
    /// Built merges rejected at promotion (did not fit).
    pub rejected: u64,
    /// Built merges discarded because a hot-swap outran the build.
    pub stale: u64,
    /// Accounted resident bytes right now (512-byte rounded).
    pub resident_bytes: u64,
    /// Resident merge count right now.
    pub resident_count: usize,
    /// Peak accounted resident bytes over the cache's lifetime.
    pub high_water_bytes: u64,
    /// Adapter names currently holding at least one pin.
    pub pinned_count: usize,
    /// Configured budget in bytes; 0 means unbounded.
    pub budget_bytes: u64,
}

/// One adapter's merged-weight publication point: an atomically exchanged
/// `Option<Arc<MergedParams>>`. Serving paths snapshot it once per engine
/// call, so a concurrent promote/evict can never expose a torn merge —
/// only the whole previous or the whole next state.
#[derive(Default)]
pub struct MergeSlot {
    cell: Mutex<Option<Arc<MergedParams>>>,
}

impl MergeSlot {
    pub fn empty() -> MergeSlot {
        MergeSlot::default()
    }

    /// The current merge, if resident (one refcount bump; callers reuse
    /// the snapshot for the whole engine call).
    pub fn snapshot(&self) -> Option<Arc<MergedParams>> {
        lock_unpoisoned(&self.cell).clone()
    }

    fn publish(&self, m: Arc<MergedParams>) {
        *lock_unpoisoned(&self.cell) = Some(m);
    }

    fn clear(&self) {
        *lock_unpoisoned(&self.cell) = None;
    }
}

/// Bytes a merge occupies under cache accounting: the payload at the
/// merge's storage precision (4 B/elem f32, 2 B/elem bf16) rounded to
/// the allocator's granularity. Budget math done with this function
/// matches [`CacheStats::resident_bytes`] exactly — a bf16 fleet fits
/// ~2× the adapters of an f32 fleet under the same budget.
pub fn accounted_bytes(m: &MergedParams) -> u64 {
    let elems = m.embed.elems() + m.layers.iter().map(|t| t.elems()).sum::<usize>();
    CachingAllocator::round_up(elems as u64 * m.precision.bytes_per_elem() as u64)
}

/// One resident merge's bookkeeping record.
struct Resident {
    /// Entry generation the merge was built from.
    gen: u64,
    /// The owning entry's publication slot (cleared on eviction).
    slot: Arc<MergeSlot>,
    /// Accounted (rounded) bytes.
    bytes: u64,
    /// LRU recency stamp (monotonic tick).
    last_used: u64,
    /// Clock reference bit.
    referenced: bool,
}

struct Inner {
    resident: BTreeMap<String, Resident>,
    /// Clock ring: resident names in insertion order; the hand sweeps it.
    ring: Vec<String>,
    hand: usize,
    /// Current registered generation per adapter name — the authority
    /// promote and miss-claims are checked against.
    registered: BTreeMap<String, u64>,
    /// Builds claimed via `note_miss` and not yet resolved, per name.
    building: BTreeMap<String, u64>,
    /// Generations whose merge build failed — never re-claimed, so an
    /// unmergeable adapter cannot trigger a rebuild storm.
    failed: BTreeMap<String, u64>,
    /// Pin counts per adapter name (streams in flight).
    pins: BTreeMap<String, usize>,
    alloc: CachingAllocator,
    events: Vec<Event>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    promotions: u64,
    rejected: u64,
    stale: u64,
}

impl Inner {
    /// Drop a name's residency: free the accounting, log the event, fix
    /// the clock ring. Clears the entry's publication slot only for
    /// budget eviction (`clear_slot`) — replacement leaves the old slot
    /// filled for in-flight snapshot holders (module docs).
    fn remove_resident(&mut self, name: &str, clear_slot: bool) {
        let Some(r) = self.resident.remove(name) else { return };
        let key = format!("{name}#{}", r.gen);
        self.alloc.free(&key);
        self.events.push(Event::free(&key));
        if let Some(pos) = self.ring.iter().position(|n| n == name) {
            self.ring.remove(pos);
            if pos < self.hand {
                self.hand -= 1;
            }
            if self.ring.is_empty() {
                self.hand = 0;
            } else {
                self.hand %= self.ring.len();
            }
        }
        if clear_slot {
            r.slot.clear();
        }
    }
}

fn is_pinned(pins: &BTreeMap<String, usize>, name: &str) -> bool {
    pins.get(name).is_some_and(|&c| c > 0)
}

/// The budgeted merged-weight cache. One per server; shared by the
/// one-shot batcher, the decode scheduler, and the async merge builder.
pub struct MergedCache {
    budget: u64,
    policy: CachePolicy,
    inner: Mutex<Inner>,
}

impl MergedCache {
    pub fn new(budget_bytes: u64, policy: CachePolicy) -> MergedCache {
        MergedCache {
            budget: budget_bytes,
            policy,
            inner: Mutex::new(Inner {
                resident: BTreeMap::new(),
                ring: Vec::new(),
                hand: 0,
                registered: BTreeMap::new(),
                building: BTreeMap::new(),
                failed: BTreeMap::new(),
                pins: BTreeMap::new(),
                alloc: CachingAllocator::new(),
                events: Vec::new(),
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                promotions: 0,
                rejected: 0,
                stale: 0,
            }),
        }
    }

    /// A cache that never evicts (the legacy eager-merge server mode —
    /// same code path, effectively infinite budget).
    pub fn unbounded(policy: CachePolicy) -> MergedCache {
        MergedCache::new(u64::MAX, policy)
    }

    /// Configured budget in raw bytes (`u64::MAX` = unbounded).
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Declare `gen` the current generation for `name` (startup load or
    /// hot-swap). Releases any residency held by a previous generation
    /// (without clearing the old entry's slot — see module docs), drops
    /// pending build claims, and clears the failed-build latch so the new
    /// leaves get a fresh merge attempt.
    pub fn register(&self, name: &str, gen: u64) {
        let mut s = self.lock();
        s.registered.insert(name.to_string(), gen);
        s.building.remove(name);
        s.failed.remove(name);
        s.remove_resident(name, false);
    }

    /// Record a merged-path serve. Touches recency when the name is still
    /// resident (a snapshot can outlive its residency — the serve still
    /// counts as a hit: it ran on merged weights).
    pub fn note_hit(&self, name: &str) {
        let mut s = self.lock();
        s.hits += 1;
        s.tick += 1;
        let tick = s.tick;
        if let Some(r) = s.resident.get_mut(name) {
            r.last_used = tick;
            r.referenced = true;
        }
    }

    /// Record a composed-path serve of a mergeable adapter. Returns true
    /// exactly once per (name, generation): the caller should schedule an
    /// async merge build. Concurrent misses, already-resident races,
    /// stale generations, and failed builds all return false.
    pub fn note_miss(&self, name: &str, gen: u64) -> bool {
        let mut s = self.lock();
        s.misses += 1;
        if s.registered.get(name) != Some(&gen)
            || s.failed.get(name) == Some(&gen)
            || s.building.get(name) == Some(&gen)
            || s.resident.contains_key(name)
        {
            return false;
        }
        s.building.insert(name.to_string(), gen);
        true
    }

    /// Publish a built merge: verify the generation is still current,
    /// evict per policy until the accounted bytes fit the budget, account
    /// the allocation, and fill the entry's slot. The slot is filled only
    /// on [`Promotion::Resident`] — a stale or rejected build is never
    /// visible to serving paths.
    pub fn promote(
        &self,
        name: &str,
        gen: u64,
        slot: &Arc<MergeSlot>,
        merged: Arc<MergedParams>,
    ) -> Promotion {
        let bytes = accounted_bytes(&merged);
        let mut s = self.lock();
        if s.building.get(name) == Some(&gen) {
            s.building.remove(name);
        }
        if s.registered.get(name) != Some(&gen) {
            s.stale += 1;
            return Promotion::Stale;
        }
        if s.resident.contains_key(name) {
            // A duplicate build raced an earlier promotion of the same
            // generation; the slot is already published.
            return Promotion::Resident;
        }
        if bytes > self.budget {
            s.rejected += 1;
            return Promotion::Rejected;
        }
        while s.alloc.allocated().saturating_add(bytes) > self.budget {
            let Some(victim) = self.pick_victim(&mut s) else {
                // Everything resident is pinned: stay composed.
                s.rejected += 1;
                return Promotion::Rejected;
            };
            s.remove_resident(&victim, true);
            s.evictions += 1;
        }
        let key = format!("{name}#{gen}");
        s.alloc.alloc(&key, bytes);
        s.events.push(Event::alloc(&key, bytes));
        s.tick += 1;
        let last_used = s.tick;
        s.resident.insert(
            name.to_string(),
            Resident { gen, slot: slot.clone(), bytes, last_used, referenced: true },
        );
        s.ring.push(name.to_string());
        s.promotions += 1;
        slot.publish(merged);
        Promotion::Resident
    }

    /// A build for (name, gen) failed: release the claim and latch the
    /// generation as unmergeable so later misses don't re-claim it.
    pub fn build_failed(&self, name: &str, gen: u64) {
        let mut s = self.lock();
        if s.building.get(name) == Some(&gen) {
            s.building.remove(name);
        }
        if s.registered.get(name) == Some(&gen) {
            s.failed.insert(name.to_string(), gen);
        }
    }

    /// Exempt `name` from budget eviction (an in-flight decode stream).
    /// Counted: pin/unpin pairs nest.
    pub fn pin(&self, name: &str) {
        let mut s = self.lock();
        *s.pins.entry(name.to_string()).or_insert(0) += 1;
    }

    /// Release one pin. Unbalanced unpins are ignored (defensive).
    pub fn unpin(&self, name: &str) {
        let mut s = self.lock();
        if let Some(c) = s.pins.get_mut(name) {
            *c -= 1;
            if *c == 0 {
                s.pins.remove(name);
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        let s = self.lock();
        CacheStats {
            hits: s.hits,
            misses: s.misses,
            evictions: s.evictions,
            promotions: s.promotions,
            rejected: s.rejected,
            stale: s.stale,
            resident_bytes: s.alloc.allocated(),
            resident_count: s.resident.len(),
            high_water_bytes: s.alloc.max_allocated(),
            pinned_count: s.pins.len(),
            budget_bytes: if self.budget == u64::MAX { 0 } else { self.budget },
        }
    }

    /// `(name, accounted bytes)` of every resident merge, name-sorted.
    pub fn resident(&self) -> Vec<(String, u64)> {
        let s = self.lock();
        s.resident.iter().map(|(n, r)| (n.clone(), r.bytes)).collect()
    }

    /// The replayable residency event stream (one alloc per promotion,
    /// one free per eviction/replacement). Replaying it on a fresh
    /// [`CachingAllocator`] reconstructs [`CacheStats::high_water_bytes`].
    pub fn events(&self) -> Vec<Event> {
        self.lock().events.clone()
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        lock_unpoisoned(&self.inner)
    }

    /// Choose an eviction victim among unpinned residents, or None if
    /// every resident is pinned.
    fn pick_victim(&self, s: &mut Inner) -> Option<String> {
        if !s.resident.keys().any(|n| !is_pinned(&s.pins, n)) {
            return None;
        }
        match self.policy {
            CachePolicy::Lru => s
                .resident
                .iter()
                .filter(|(n, _)| !is_pinned(&s.pins, n))
                .min_by_key(|(_, r)| r.last_used)
                .map(|(n, _)| n.clone()),
            CachePolicy::Clock => {
                // Two full sweeps suffice: the first clears every
                // reference bit an unpinned resident holds.
                for _ in 0..(2 * s.ring.len() + 1) {
                    if s.ring.is_empty() {
                        return None;
                    }
                    s.hand %= s.ring.len();
                    let name = s.ring[s.hand].clone();
                    if is_pinned(&s.pins, &name) {
                        s.hand += 1;
                        continue;
                    }
                    let referenced = {
                        let r = s.resident.get_mut(&name).expect("ring entry resident");
                        std::mem::replace(&mut r.referenced, false)
                    };
                    if referenced {
                        s.hand += 1;
                    } else {
                        return Some(name);
                    }
                }
                // Unreachable with a consistent ring; keep a safe default.
                s.ring.iter().find(|n| !is_pinned(&s.pins, n)).cloned()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::peak_of_events;
    use crate::runtime::ops::Precision;
    use crate::runtime::Tensor;
    use crate::util::prop::{check, prop_assert};

    /// A synthetic merge of exactly `elems` f32 elements (no layers —
    /// the cache only measures bytes).
    fn merged(elems: usize) -> Arc<MergedParams> {
        Arc::new(MergedParams {
            embed: Tensor::f32(vec![elems], vec![0.0; elems]),
            layers: vec![],
            precision: Precision::F32,
        })
    }

    /// The same synthetic merge accounted at bf16 storage precision.
    fn merged_bf16(elems: usize) -> Arc<MergedParams> {
        Arc::new(MergedParams {
            embed: Tensor::f32(vec![elems], vec![0.0; elems]),
            layers: vec![],
            precision: Precision::Bf16,
        })
    }

    /// One 512-byte accounting unit.
    fn unit() -> Arc<MergedParams> {
        merged(128)
    }

    fn slot() -> Arc<MergeSlot> {
        Arc::new(MergeSlot::empty())
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [CachePolicy::Lru, CachePolicy::Clock] {
            assert_eq!(CachePolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(CachePolicy::parse("mru").is_err());
        assert_eq!(CachePolicy::default(), CachePolicy::Lru);
    }

    #[test]
    fn accounted_bytes_rounds_to_granularity() {
        assert_eq!(accounted_bytes(&merged(1)), 512);
        assert_eq!(accounted_bytes(&merged(128)), 512);
        assert_eq!(accounted_bytes(&merged(129)), 1024);
        let with_layers = MergedParams {
            embed: Tensor::f32(vec![128], vec![0.0; 128]),
            layers: vec![Tensor::f32(vec![128], vec![0.0; 128])],
            precision: Precision::F32,
        };
        assert_eq!(accounted_bytes(&with_layers), 1024);
    }

    #[test]
    fn bf16_merges_account_half_the_bytes_and_fit_twice_as_many() {
        // 1024 f32 elements: 4096 B at f32, 2048 B at bf16 — the ISSUE's
        // "bf16 merged-replica bytes ≈ ½ f32" serving contract.
        assert_eq!(accounted_bytes(&merged(1024)), 4096);
        assert_eq!(accounted_bytes(&merged_bf16(1024)), 2048);
        // Under one 4096 B budget: two bf16 merges are co-resident where
        // a second f32 merge would have evicted the first.
        let cache = MergedCache::new(4096, CachePolicy::Lru);
        cache.register("a", 1);
        cache.register("b", 2);
        let (sa, sb) = (slot(), slot());
        assert_eq!(cache.promote("a", 1, &sa, merged_bf16(1024)), Promotion::Resident);
        assert_eq!(cache.promote("b", 2, &sb, merged_bf16(1024)), Promotion::Resident);
        let st = cache.stats();
        assert_eq!(st.resident_count, 2, "bf16 fleet fits 2x adapters per budget");
        assert_eq!(st.resident_bytes, 4096);
        assert_eq!(st.evictions, 0);

        let f32_cache = MergedCache::new(4096, CachePolicy::Lru);
        f32_cache.register("a", 1);
        f32_cache.register("b", 2);
        let (fa, fb) = (slot(), slot());
        assert_eq!(f32_cache.promote("a", 1, &fa, merged(1024)), Promotion::Resident);
        assert_eq!(f32_cache.promote("b", 2, &fb, merged(1024)), Promotion::Resident);
        assert_eq!(f32_cache.stats().resident_count, 1, "f32 pair must evict");
        assert_eq!(f32_cache.stats().evictions, 1);
    }

    #[test]
    fn promote_publishes_and_accounts() {
        let cache = MergedCache::new(1024, CachePolicy::Lru);
        cache.register("a", 1);
        let sa = slot();
        assert!(sa.snapshot().is_none());
        assert_eq!(cache.promote("a", 1, &sa, unit()), Promotion::Resident);
        assert!(sa.snapshot().is_some());
        let st = cache.stats();
        assert_eq!(st.promotions, 1);
        assert_eq!(st.resident_bytes, 512);
        assert_eq!(st.resident_count, 1);
        assert_eq!(st.budget_bytes, 1024);
        assert_eq!(cache.resident(), vec![("a".to_string(), 512)]);
    }

    #[test]
    fn lru_evicts_least_recently_served() {
        let cache = MergedCache::new(1024, CachePolicy::Lru);
        let (sa, sb, sc) = (slot(), slot(), slot());
        for (n, g) in [("a", 1), ("b", 2), ("c", 3)] {
            cache.register(n, g);
        }
        assert_eq!(cache.promote("a", 1, &sa, unit()), Promotion::Resident);
        assert_eq!(cache.promote("b", 2, &sb, unit()), Promotion::Resident);
        cache.note_hit("a"); // a is now more recent than b
        assert_eq!(cache.promote("c", 3, &sc, unit()), Promotion::Resident);
        let names: Vec<String> = cache.resident().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a".to_string(), "c".to_string()]);
        // The victim's slot is cleared so serving falls back to composed.
        assert!(sb.snapshot().is_none());
        assert!(sa.snapshot().is_some());
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn clock_clears_reference_bits_before_evicting() {
        let cache = MergedCache::new(1024, CachePolicy::Clock);
        let (sa, sb, sc) = (slot(), slot(), slot());
        for (n, g) in [("a", 1), ("b", 2), ("c", 3)] {
            cache.register(n, g);
        }
        cache.promote("a", 1, &sa, unit());
        cache.promote("b", 2, &sb, unit());
        // Both referenced: the hand clears a then b, wraps, evicts a.
        assert_eq!(cache.promote("c", 3, &sc, unit()), Promotion::Resident);
        let names: Vec<String> = cache.resident().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["b".to_string(), "c".to_string()]);
        assert!(sa.snapshot().is_none());
    }

    #[test]
    fn pinned_residents_survive_the_squeeze() {
        let cache = MergedCache::new(512, CachePolicy::Lru);
        cache.register("a", 1);
        cache.register("b", 2);
        let (sa, sb) = (slot(), slot());
        cache.pin("a");
        assert_eq!(cache.promote("a", 1, &sa, unit()), Promotion::Resident);
        // No unpinned victim: b is rejected, a stays.
        assert_eq!(cache.promote("b", 2, &sb, unit()), Promotion::Rejected);
        assert!(sa.snapshot().is_some());
        assert!(sb.snapshot().is_none());
        let st = cache.stats();
        assert_eq!(st.rejected, 1);
        assert_eq!(st.evictions, 0);
        assert_eq!(st.pinned_count, 1);
        // Releasing the pin lets the next promotion evict a.
        cache.unpin("a");
        assert_eq!(cache.promote("b", 2, &sb, unit()), Promotion::Resident);
        assert!(sa.snapshot().is_none());
        assert!(sb.snapshot().is_some());
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn stale_promotion_is_discarded_unpublished() {
        let cache = MergedCache::new(4096, CachePolicy::Lru);
        cache.register("a", 1);
        let s1 = slot();
        cache.register("a", 2); // hot-swap outran the build
        assert_eq!(cache.promote("a", 1, &s1, unit()), Promotion::Stale);
        assert!(s1.snapshot().is_none());
        assert_eq!(cache.stats().stale, 1);
        assert_eq!(cache.stats().resident_count, 0);
        let s2 = slot();
        assert_eq!(cache.promote("a", 2, &s2, unit()), Promotion::Resident);
    }

    #[test]
    fn replacement_releases_bytes_but_keeps_old_snapshot_serving() {
        let cache = MergedCache::new(4096, CachePolicy::Lru);
        cache.register("a", 1);
        let s1 = slot();
        cache.promote("a", 1, &s1, unit());
        assert_eq!(cache.stats().resident_bytes, 512);
        // Hot-swap: residency is released immediately, but the OLD
        // entry's slot stays filled — in-flight groups that snapshotted
        // it keep serving the old merge bitwise until they drain.
        cache.register("a", 2);
        assert_eq!(cache.stats().resident_bytes, 0);
        assert!(s1.snapshot().is_some());
    }

    #[test]
    fn oversized_merge_is_rejected() {
        let cache = MergedCache::new(512, CachePolicy::Lru);
        cache.register("a", 1);
        assert_eq!(cache.promote("a", 1, &slot(), merged(256)), Promotion::Rejected);
        assert_eq!(cache.stats().rejected, 1);
        assert_eq!(cache.stats().resident_bytes, 0);
    }

    #[test]
    fn miss_claims_once_and_failed_builds_do_not_retry() {
        let cache = MergedCache::new(4096, CachePolicy::Lru);
        cache.register("a", 1);
        assert!(cache.note_miss("a", 1));
        assert!(!cache.note_miss("a", 1), "claim must dedupe");
        cache.build_failed("a", 1);
        assert!(!cache.note_miss("a", 1), "failed gen must not re-claim");
        assert!(!cache.note_miss("a", 99), "unregistered gen must not claim");
        // A hot-swap resets the latch: the new leaves deserve an attempt.
        cache.register("a", 2);
        assert!(cache.note_miss("a", 2));
        assert_eq!(cache.stats().misses, 5);
    }

    #[test]
    fn events_replay_reconstructs_high_water() {
        let cache = MergedCache::new(1024, CachePolicy::Lru);
        for (n, g) in [("a", 1), ("b", 2), ("c", 3)] {
            cache.register(n, g);
        }
        cache.promote("a", 1, &slot(), unit());
        cache.promote("b", 2, &slot(), merged(256)); // 1024 B: evicts a
        cache.register("b", 4); // replacement frees b
        cache.promote("c", 3, &slot(), unit());
        let st = cache.stats();
        assert!(st.high_water_bytes <= 1024);
        assert_eq!(peak_of_events(&cache.events()), st.high_water_bytes);
    }

    #[test]
    fn randomized_churn_preserves_accounting_invariants() {
        // The satellite property: under random register/promote/pin/
        // unpin/fail sequences, accounted resident bytes == the sum of
        // live merges, the budget is never exceeded, residency and slot
        // publication agree, and event replay reconstructs the same
        // high-water mark.
        check("cache accounting", 40, |g| {
            let policy = g.pick(&[CachePolicy::Lru, CachePolicy::Clock]);
            let budget = 512 * g.usize_in(1, 5) as u64;
            let cache = MergedCache::new(budget, policy);
            let names = ["a", "b", "c", "d"];
            let mut gens: BTreeMap<&str, u64> = BTreeMap::new();
            let mut slots: BTreeMap<&str, Arc<MergeSlot>> = BTreeMap::new();
            let mut pins: BTreeMap<&str, usize> = BTreeMap::new();
            let mut next_gen = 0u64;
            for n in names {
                next_gen += 1;
                gens.insert(n, next_gen);
                slots.insert(n, slot());
                cache.register(n, next_gen);
            }
            for _ in 0..60 {
                let n = g.pick(&names);
                match g.usize_in(0, 6) {
                    0 => {
                        // Hot-swap to a new generation.
                        next_gen += 1;
                        gens.insert(n, next_gen);
                        slots.insert(n, slot());
                        cache.register(n, next_gen);
                    }
                    1 | 2 => {
                        // Build + promote at the current generation
                        // (either storage precision — accounting must
                        // hold for mixed-precision fleets too).
                        let elems = 128 * g.usize_in(1, 3);
                        let m = if g.bool() { merged_bf16(elems * 2) } else { merged(elems) };
                        cache.promote(n, gens[n], &slots[n], m);
                    }
                    3 => {
                        // A build that lost a race to a hot-swap.
                        cache.promote(n, gens[n] + 1000, &slots[n], unit());
                    }
                    4 => {
                        cache.pin(n);
                        *pins.entry(n).or_insert(0) += 1;
                    }
                    5 => {
                        if pins.get(n).copied().unwrap_or(0) > 0 {
                            *pins.get_mut(n).unwrap() -= 1;
                            cache.unpin(n);
                        }
                    }
                    6 => {
                        if cache.note_miss(n, gens[n]) && g.bool() {
                            cache.build_failed(n, gens[n]);
                        }
                    }
                    _ => unreachable!(),
                }
                let st = cache.stats();
                let live: u64 = cache.resident().iter().map(|(_, b)| *b).sum();
                prop_assert(
                    st.resident_bytes == live,
                    format!("accounted {} != sum of live merges {live}", st.resident_bytes),
                )?;
                prop_assert(
                    st.resident_bytes <= budget,
                    format!("budget overshoot: {} > {budget}", st.resident_bytes),
                )?;
                for n in names {
                    let resident = cache.resident().iter().any(|(r, _)| r == n);
                    prop_assert(
                        resident == slots[n].snapshot().is_some(),
                        format!("{n}: residency and slot publication disagree"),
                    )?;
                }
            }
            let st = cache.stats();
            prop_assert(
                peak_of_events(&cache.events()) == st.high_water_bytes,
                "event replay reconstructs a different high-water mark",
            )
        });
    }
}
