//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! Rust hot path.
//!
//! `Engine` wraps the `xla` crate's PJRT CPU client:
//!
//! ```text
//! HloModuleProto::from_text_file -> XlaComputation -> client.compile
//!   -> PjRtLoadedExecutable (cached per artifact) -> execute(literals)
//! ```
//!
//! HLO **text** is the interchange format: jax >= 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and aot.py).
//!
//! Python never runs here — the engine + artifacts directory is the
//! entire deployable unit.
//!
//! PJRT is one of two execution strategies: [`native`] implements the
//! same artifact surface over the in-process kernel registry, and
//! [`backend`] ([`ExecBackend`] / [`BackendSpec`]) is the selection
//! layer the coordinator consumes (fallback order: PJRT when usable,
//! else native).

pub mod adapters;
pub mod backend;
pub mod cache;
pub mod manifest;
pub mod native;
pub mod ops;
pub mod pool;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

pub use adapters::{Adapter, AdapterStore, AdapterSummary, CkptError};
pub use backend::{BackendSpec, ExecBackend, MockExec};
pub use cache::{accounted_bytes, CachePolicy, CacheStats, MergeSlot, MergedCache, Promotion};
pub use manifest::{ArtifactInfo, ConfigInfo, IoDtype, IoSlot, Manifest};
pub use native::NativeEngine;
pub use ops::{
    reduce_sample_grads, AdapterParams, ApplyUpdateReq, ApplyUpdateResp, ComposeReq,
    ComposeResp, DecodeStepMergedReq, DecodeStepReq, DecodeStepResp, DoraLinearReq,
    DoraLinearResp, EngineOp, EngineOut, EvalReq, EvalResp, InferMergedReq, InferReq, InferResp,
    InitReq, InitResp, LinearVariant, LossAndGradsReq, LossAndGradsResp, MergedParams, OptState,
    Precision, SampleGrads, TrainStepReq, TrainStepResp, Variant,
};
pub use pool::{EnginePool, GradReducer, PoolJob};

/// A host tensor crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::i32(vec![], vec![v])
    }

    pub fn zeros_like_slot(slot: &IoSlot) -> Tensor {
        match slot.dtype {
            IoDtype::F32 => Tensor::f32(slot.shape.clone(), vec![0.0; slot.elems()]),
            IoDtype::S32 => Tensor::i32(slot.shape.clone(), vec![0; slot.elems()]),
        }
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Element dtype name ("f32" / "i32") — the checkpoint header tag.
    pub fn dtype_str(&self) -> &'static str {
        match &self.data {
            TensorData::F32(_) => "f32",
            TensorData::I32(_) => "i32",
        }
    }

    /// Exact bit-level equality: same shape, same dtype, and every
    /// element's bit pattern identical (distinguishes -0.0 from 0.0 and
    /// compares NaNs by payload — the checkpoint round-trip guarantee).
    pub fn bitwise_eq(&self, other: &Tensor) -> bool {
        if self.shape != other.shape {
            return false;
        }
        match (&self.data, &other.data) {
            (TensorData::F32(a), TensorData::F32(b)) => {
                a.len() == b.len()
                    && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
            }
            (TensorData::I32(a), TensorData::I32(b)) => a == b,
            _ => false,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    /// Scalar f32 accessor (loss values etc).
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        // Single-copy construction (EXPERIMENTS.md §Perf L3): vec1 +
        // reshape copies the payload twice; create_from_shape_and_
        // untyped_data copies once into the final shape.
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &self.shape,
                bytemuck_f32(v),
            )?,
            TensorData::I32(v) => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                &self.shape,
                bytemuck_i32(v),
            )?,
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            other => bail!("unsupported output element type {other:?}"),
        };
        Ok(Tensor { shape: dims, data })
    }
}

/// View a typed slice as bytes (safe: f32/i32 are plain-old-data and the
/// allocation is at least align 4).
fn bytemuck_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// The PJRT execution engine. Cheap to clone (shared compiled cache).
#[derive(Clone)]
pub struct Engine {
    inner: Arc<EngineInner>,
}

struct EngineInner {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU PJRT client over the given artifacts directory.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            inner: Arc::new(EngineInner { client, manifest, cache: Mutex::new(HashMap::new()) }),
        })
    }

    /// Engine over the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        Self::load(&manifest::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.inner.manifest
    }

    pub fn platform(&self) -> String {
        self.inner.client.platform_name()
    }

    /// Get (compiling and caching on first use) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = crate::util::lock_unpoisoned(&self.inner.cache).get(name) {
            return Ok(exe.clone());
        }
        let art = self.inner.manifest.artifact(name)?;
        let path = self.inner.manifest.hlo_path(art);
        let path_str = path.to_str().context("non-utf8 artifact path")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.inner
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name:?}"))?,
        );
        crate::util::lock_unpoisoned(&self.inner.cache).insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with host tensors, validating the signature,
    /// and return the (untupled) outputs as host tensors.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let art = self.inner.manifest.artifact(name)?.clone();
        if inputs.len() != art.inputs.len() {
            bail!(
                "artifact {name:?} expects {} inputs, got {}",
                art.inputs.len(),
                inputs.len()
            );
        }
        for (slot, t) in art.inputs.iter().zip(inputs) {
            if slot.shape != t.shape {
                bail!(
                    "artifact {name:?} input {:?}: shape {:?} != expected {:?}",
                    slot.name,
                    t.shape,
                    slot.shape
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let mut out_lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = out_lit.decompose_tuple()?;
        let mut outputs = Vec::with_capacity(parts.len());
        for part in &parts {
            outputs.push(Tensor::from_literal(part)?);
        }
        if outputs.len() != art.outputs.len() {
            bail!(
                "artifact {name:?} returned {} outputs, manifest says {}",
                outputs.len(),
                art.outputs.len()
            );
        }
        Ok(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        let dir = manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Engine::load(&dir).expect("engine loads"))
        } else {
            None
        }
    }

    #[test]
    fn tensor_bitwise_eq_is_exact() {
        let a = Tensor::f32(vec![2], vec![0.0, 1.0]);
        assert!(a.bitwise_eq(&a.clone()));
        // -0.0 == 0.0 numerically but NOT bitwise.
        let neg = Tensor::f32(vec![2], vec![-0.0, 1.0]);
        assert!(!a.bitwise_eq(&neg));
        // Shape and dtype mismatches.
        assert!(!a.bitwise_eq(&Tensor::f32(vec![1, 2], vec![0.0, 1.0])));
        assert!(!a.bitwise_eq(&Tensor::i32(vec![2], vec![0, 1])));
        assert_eq!(a.dtype_str(), "f32");
        assert_eq!(Tensor::scalar_i32(3).dtype_str(), "i32");
    }

    #[test]
    fn tensor_roundtrip_through_literal() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape, vec![2, 3]);
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn engine_runs_compose_artifact() {
        let Some(eng) = engine() else { return };
        let art = eng.manifest().artifact("compose_eager_512x2048").unwrap().clone();
        let rows = 512;
        let d_out = 2048;
        let s = art.meta_f64("scale").unwrap() as f32;
        let mut rng = crate::util::rng::Rng::new(7);
        let base = rng.normal_vec_f32(rows * d_out, 1.0);
        let lora = rng.normal_vec_f32(rows * d_out, 0.3);
        let g: Vec<f32> = (0..d_out).map(|_| 1.0 + rng.normal() as f32 * 0.01).collect();
        let out = eng
            .run(
                "compose_eager_512x2048",
                &[
                    Tensor::f32(vec![rows, d_out], base.clone()),
                    Tensor::f32(vec![rows, d_out], lora.clone()),
                    Tensor::f32(vec![d_out], g.clone()),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let delta = out[0].as_f32().unwrap();
        // Cross-layer check: XLA output == the Rust CPU fused kernel.
        let act = crate::dora::config::ActShape::new(rows, d_out);
        let want = crate::dora::compose_cpu::compose_fused(&base, &lora, &g, s, act);
        for i in (0..delta.len()).step_by(97) {
            assert!(
                (delta[i] - want[i]).abs() <= 1e-4 * want[i].abs().max(1.0),
                "elem {i}: {} vs {}",
                delta[i],
                want[i]
            );
        }
    }

    #[test]
    fn fused_and_eager_artifacts_agree() {
        let Some(eng) = engine() else { return };
        let rows = 512;
        let d_out = 2048;
        let mut rng = crate::util::rng::Rng::new(8);
        let inputs = [
            Tensor::f32(vec![rows, d_out], rng.normal_vec_f32(rows * d_out, 1.0)),
            Tensor::f32(vec![rows, d_out], rng.normal_vec_f32(rows * d_out, 0.3)),
            Tensor::f32(
                vec![d_out],
                (0..d_out).map(|_| 1.0 + rng.normal() as f32 * 0.002).collect(),
            ),
        ];
        let e = eng.run("compose_eager_512x2048", &inputs).unwrap();
        let f = eng.run("compose_fused_512x2048", &inputs).unwrap();
        let (ev, fv) = (e[0].as_f32().unwrap(), f[0].as_f32().unwrap());
        for i in (0..ev.len()).step_by(131) {
            assert!((ev[i] - fv[i]).abs() <= 1e-4 * ev[i].abs().max(1.0));
        }
    }

    #[test]
    fn norm_artifacts_agree_across_engines() {
        let Some(eng) = engine() else { return };
        let (d_out, d_in, r) = (1024, 1024, 64);
        let mut rng = crate::util::rng::Rng::new(9);
        let inputs = [
            Tensor::f32(vec![d_out, d_in], rng.normal_vec_f32(d_out * d_in, 0.05)),
            Tensor::f32(vec![r, d_in], rng.normal_vec_f32(r * d_in, 0.1)),
            Tensor::f32(vec![d_out, r], rng.normal_vec_f32(d_out * r, 0.1)),
        ];
        let dense = eng.run("norm_dense_ba_1024x1024r64", &inputs).unwrap();
        let eager = eng.run("norm_eager_1024x1024r64", &inputs).unwrap();
        let fused = eng.run("norm_fused_1024x1024r64", &inputs).unwrap();
        let (d, e, f) = (
            dense[0].as_f32().unwrap(),
            eager[0].as_f32().unwrap(),
            fused[0].as_f32().unwrap(),
        );
        for i in 0..d_out {
            assert!((d[i] - e[i]).abs() <= 2e-4 * d[i].abs().max(1e-3), "dense vs eager {i}");
            assert!((e[i] - f[i]).abs() <= 2e-4 * e[i].abs().max(1e-3), "eager vs fused {i}");
        }
        // And against the Rust CPU factored norm.
        let m = crate::dora::config::ModuleShape::new(d_out, d_in, r);
        let mut tracker = crate::dora::norm_cpu::AllocTracker::new();
        let cpu = crate::dora::norm_cpu::factored_norm(
            inputs[0].as_f32().unwrap(),
            inputs[1].as_f32().unwrap(),
            inputs[2].as_f32().unwrap(),
            0.5,
            m,
            1 << 22,
            &mut tracker,
        );
        for i in (0..d_out).step_by(37) {
            assert!((cpu[i] - f[i]).abs() <= 2e-4 * cpu[i].abs().max(1e-3), "cpu vs xla {i}");
        }
    }

    #[test]
    fn input_validation_errors() {
        let Some(eng) = engine() else { return };
        let err = eng.run("compose_eager_512x2048", &[]).unwrap_err();
        assert!(err.to_string().contains("expects"));
        let bad = [
            Tensor::f32(vec![4, 4], vec![0.0; 16]),
            Tensor::f32(vec![4, 4], vec![0.0; 16]),
            Tensor::f32(vec![4], vec![0.0; 4]),
        ];
        let err = eng.run("compose_eager_512x2048", &bad).unwrap_err();
        assert!(err.to_string().contains("shape"));
        assert!(eng.run("no_such_artifact", &[]).is_err());
    }
}
