//! dorafactors CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   report <id>        regenerate a paper table/figure (or `all`)
//!   info               manifest + device + config summary
//!   train              run a training job against the AOT artifacts
//!   serve-demo         start the batched server and fire demo traffic
//!   generate           stream an autoregressive decode token by token
//!   adapters list      list checkpoints in the adapter store
//!   adapters train     train a NAMED adapter with periodic checkpoints
//!   adapters serve     serve one or more named adapters from the store
//!   bench-diff         compare a fresh perf_gate run against the
//!                      committed bench_baselines snapshot
//!
//! The heavier end-to-end drivers (quickstart, convergence study, the
//! ~100M e2e training run, serving load test) live in `examples/`.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use dorafactors::bench::report;
use dorafactors::coordinator::{FastPath, GenOptions, Server, ServerCfg, Trainer, TrainerCfg};
use dorafactors::runtime::ops::{parse_variant_spec, variant_token};
use dorafactors::runtime::{manifest, AdapterStore, BackendSpec, CachePolicy, Engine, Precision};
use dorafactors::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("report") => cmd_report(&args),
        Some("info") => cmd_info(),
        Some("train") => cmd_train(&args),
        Some("serve-demo") => cmd_serve_demo(&args),
        Some("generate") => cmd_generate(&args),
        Some("adapters") => cmd_adapters(&args),
        Some("bench-diff") => cmd_bench_diff(&args),
        _ => {
            eprintln!(
                "usage: dorafactors <report|info|train|serve-demo|generate|adapters|bench-diff> [--flags]\n\
                 \n\
                 report <id>     one of: {}\n\
                 train           --config tiny|small|e2e \
                 --variant eager|fused|dora|rslora|bora|<kernel>-<adapter> \
                 --steps N --seed S [--eval-every N] [--precision f32|bf16] \
                 [--train-workers N (data-parallel pool)] [--grad-accum K]\n\
                 serve-demo      --config tiny|small --requests N \
                 [--workers N] [--fast-path merged|composed] [--queue-depth N] \
                 [--precision f32|bf16]\n\
                 generate        [--adapter NAME [--store DIR]] [--config tiny] \
                 [--prompt 1,2,3] [--max-tokens N] [--temperature T] [--top-k K] \
                 [--seed S] [--top-logits K] [--workers N] [--fast-path merged|composed] \
                 [--precision f32|bf16 (default: the checkpoint's)]\n\
                 adapters list   [--store DIR]\n\
                 adapters train  --adapter NAME [--config tiny] [--variant SPEC] [--steps N] \
                 [--seed S] [--checkpoint-every N] [--store DIR] [--resume] \
                 [--train-workers N] [--grad-accum K] [--precision f32|bf16]\n\
                 adapters serve  --adapter NAME[,NAME...] [--requests N] [--streams N] \
                 [--max-tokens N] [--store DIR] [--workers N (0 = all cores)] \
                 [--fast-path merged|composed] [--queue-depth N] [--metrics-every-ms N] \
                 [--merge-budget-mb MB (0 = unbounded)] [--cache-policy lru|clock] \
                 [--precision f32|bf16 (default: the checkpoints')]\n\
                 bench-diff      [--baseline bench_baselines/BENCH_pr10.json] \
                 [--fresh bench_results/BENCH_ci.json] [--allow-new-keys]",
                report::REPORT_IDS.join(" ")
            );
            std::process::exit(2);
        }
    }
}

/// Compare a fresh perf-gate BENCH JSON against the committed baseline
/// snapshot and print per-row deltas (the perf trajectory lives in git;
/// bench_results/ is gitignored).
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let baseline_path = args.get_or("baseline", "bench_baselines/BENCH_pr10.json");
    let fresh_path = args.get_or("fresh", "bench_results/BENCH_ci.json");
    let read = |path: &str| -> Result<dorafactors::util::json::Json> {
        let text = std::fs::read_to_string(path).with_context(|| {
            format!(
                "reading {path} (generate a fresh run with \
                 `cargo bench --bench perf_gate`, or point --baseline/--fresh elsewhere)"
            )
        })?;
        dorafactors::util::json::parse(&text).with_context(|| format!("parsing {path}"))
    };
    let baseline = read(baseline_path)?;
    let fresh = read(fresh_path)?;
    let rendered = dorafactors::bench::diff::render(&baseline, &fresh)
        .with_context(|| format!("diffing {baseline_path} vs {fresh_path}"))?;
    println!("{rendered}");
    // Row-identity gate: lost rows always fail; rows new to this run
    // (e.g. a PR adding bench coverage) need the explicit opt-in until
    // the baseline snapshot is re-committed.
    let d = dorafactors::bench::diff::diff(&baseline, &fresh)
        .with_context(|| format!("diffing {baseline_path} vs {fresh_path}"))?;
    if let Err(msg) = d.gate(args.has("allow-new-keys")) {
        bail!("{msg}");
    }
    Ok(())
}

fn store_from(args: &Args) -> Result<AdapterStore> {
    AdapterStore::open_or_default(args.get("store"))
}

fn cmd_adapters(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("list") => cmd_adapters_list(args),
        Some("train") => cmd_adapters_train(args),
        Some("serve") => cmd_adapters_serve(args),
        other => bail!("unknown adapters subcommand {other:?}; try list|train|serve"),
    }
}

fn cmd_adapters_list(args: &Args) -> Result<()> {
    let store = store_from(args)?;
    let listed = store.list()?;
    if listed.is_empty() {
        println!("no adapters in {:?}", store.dir());
        return Ok(());
    }
    println!(
        "{:20} {:8} {:8} {:9} {:>6} {:>8} {:>7} {:>12}",
        "name", "config", "variant", "precision", "rank", "step", "eff-bs", "bytes"
    );
    for a in listed {
        let eff = if a.effective_batch == 0 {
            "-".to_string()
        } else {
            a.effective_batch.to_string()
        };
        println!(
            "{:20} {:8} {:8} {:9} {:>6} {:>8} {:>7} {:>12}",
            a.name,
            a.config,
            a.variant.as_str(),
            a.precision.as_str(),
            a.rank,
            a.step,
            eff,
            a.file_bytes
        );
    }
    Ok(())
}

fn cmd_adapters_train(args: &Args) -> Result<()> {
    let name = args
        .get("adapter")
        .context("adapters train needs --adapter NAME")?
        .to_string();
    // Validate the name BEFORE training: with no periodic checkpoints
    // the first save happens after the full run, and an invalid name
    // would discard every step of it.
    dorafactors::runtime::adapters::validate_name(&name)?;
    let store = store_from(args)?;
    let mut cfg = TrainerCfg {
        config: args.get_or("config", "tiny").to_string(),
        variant: args.get_or("variant", "fused").to_string(),
        seed: args.get_u64("seed", 0),
        branching: args.get_usize("branching", 4),
        eval_every: args.get_usize("eval-every", 0),
        train_workers: args.get_usize("train-workers", 0),
        grad_accum: args.get_usize("grad-accum", 1),
        precision: Precision::parse(args.get_or("precision", "f32"))?,
    };
    let steps = args.get_usize("steps", 50);
    let ckpt_every = args.get_usize("checkpoint-every", 0);

    let mut tr = if args.has("resume") {
        // A missing checkpoint under --resume is an error, not a silent
        // fresh start — a typoed name/store must not masquerade as a
        // continued run.
        if !store.exists(&name) {
            bail!(
                "--resume: adapter {name:?} not found in {:?}; drop --resume to train from scratch",
                store.dir()
            );
        }
        let adapter = store.load(&name)?;
        println!(
            "resuming adapter {name:?} from step {} (seed {} from the checkpoint)",
            adapter.step, adapter.seed
        );
        // The stored seed wins: the resumed run must continue the
        // original data stream, and the re-saved checkpoint must keep
        // its seed provenance. An explicit --seed that disagrees is an
        // error, not a silent switch.
        if args.get("seed").is_some() && cfg.seed != adapter.seed {
            bail!(
                "--seed {} conflicts with checkpoint seed {}; drop --seed to resume",
                cfg.seed,
                adapter.seed
            );
        }
        cfg.seed = adapter.seed;
        // The stored adapter variant wins the same way: resuming under a
        // different variant would continue the checkpoint with the wrong
        // compose math. An explicit --variant that disagrees is an
        // error; otherwise the kernel half of the spec combines with the
        // checkpoint's variant.
        let (kernel, adapter_variant) = parse_variant_spec(&cfg.variant)?;
        if args.get("variant").is_some() && adapter_variant != adapter.variant {
            bail!(
                "--variant {} conflicts with checkpoint variant {:?}; \
                 drop --variant to resume",
                cfg.variant,
                adapter.variant.as_str()
            );
        }
        cfg.variant = variant_token(kernel, adapter.variant);
        // And the stored precision: resuming a bf16 run at f32 (or the
        // reverse) would change every subsequent step's numerics, so an
        // explicit --precision that disagrees is an error; with no flag
        // the checkpoint's precision carries forward (pre-precision
        // checkpoints resume as f32).
        if args.get("precision").is_some() && cfg.precision != adapter.precision {
            bail!(
                "--precision {} conflicts with checkpoint precision {}; \
                 drop --precision to resume",
                cfg.precision.as_str(),
                adapter.precision.as_str()
            );
        }
        cfg.precision = adapter.precision;
        Trainer::from_adapter_spec(&BackendSpec::auto(), cfg.clone(), &adapter)?
    } else {
        Trainer::auto(cfg.clone())?
    };
    if ckpt_every > 0 {
        tr.set_checkpointing(store.clone(), name.clone(), ckpt_every)?;
    }
    println!(
        "training adapter {name:?}: config={} variant={} precision={} seed={} backend={} \
         store={:?} train-workers={} grad-accum={}",
        cfg.config,
        cfg.variant,
        cfg.precision.as_str(),
        cfg.seed,
        tr.backend_kind(),
        store.dir(),
        tr.train_workers(),
        cfg.grad_accum
    );
    while tr.step_count() < steps {
        let recs: Vec<_> = tr.run_chunk()?.to_vec();
        let last = recs.last().unwrap();
        println!(
            "step {:5}  loss {:.4}  ({:.2} s wall, {} checkpoints)",
            last.step, last.loss, tr.wall_seconds, tr.checkpoints_written
        );
    }
    let path = store.save(&tr.to_adapter(&name)?)?;
    println!(
        "saved adapter {name:?} at step {} -> {path:?} ({} periodic checkpoints)",
        tr.step_count(),
        tr.checkpoints_written
    );
    Ok(())
}

fn cmd_adapters_serve(args: &Args) -> Result<()> {
    let names: Vec<String> = args
        .get("adapter")
        .context("adapters serve needs --adapter NAME[,NAME...]")?
        .split(',')
        .map(str::to_string)
        .collect();
    let store = store_from(args)?;
    let n = args.get_usize("requests", 16);
    let adapters = names
        .iter()
        .map(|name| store.load(name))
        .collect::<Result<Vec<_>>>()?;
    let config = adapters[0].config.clone();
    // The server runs ONE precision instance-wide and every adapter must
    // match it (start_with_adapters enforces this); with no flag the
    // first checkpoint's precision carries over, like --config.
    let precision = match args.get("precision") {
        Some(p) => Precision::parse(p)?,
        None => adapters[0].precision,
    };
    // --merge-budget-mb 0 (the default) keeps the legacy unbounded
    // eager-merge behavior; any positive budget switches the merged path
    // to lazy async promotion under LRU/clock eviction.
    let budget_mb = args.get_f64("merge-budget-mb", 0.0);
    let merge_budget =
        if budget_mb > 0.0 { Some((budget_mb * 1024.0 * 1024.0) as u64) } else { None };
    let server = Server::start_with_adapters(
        BackendSpec::auto(),
        ServerCfg {
            config: config.clone(),
            max_wait: Duration::from_millis(args.get_u64("max-wait-ms", 10)),
            workers: args.get_usize("workers", 0),
            fast_path: FastPath::parse(args.get_or("fast-path", "merged"))?,
            queue_depth: args.get_usize("queue-depth", 64),
            merge_budget,
            cache_policy: CachePolicy::parse(args.get_or("cache-policy", "lru"))?,
            precision,
        },
        adapters,
    )?;
    let n_streams = args.get_usize("streams", 0);
    println!(
        "serving {} adapter(s) {:?} on config {config} ({} requests + {} streams round-robin, \
         {} pool workers, {} fast path)",
        names.len(),
        server.adapter_names(),
        n,
        n_streams,
        server.metrics().workers,
        server.fast_path().as_str()
    );
    let client = server.client();
    // Periodic metrics logging: batch counters plus the streaming gauges
    // (admission-queue depth, in-flight decode slots, shed requests) so
    // saturation is visible while the server runs, not only at shutdown.
    let every = Duration::from_millis(args.get_u64("metrics-every-ms", 1000));
    let done = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| -> Result<()> {
        let logger = scope.spawn(|| {
            let mut last = std::time::Instant::now();
            while !done.load(std::sync::atomic::Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(20));
                if last.elapsed() < every {
                    continue;
                }
                last = std::time::Instant::now();
                let m = server.metrics();
                println!(
                    "[metrics] completed {:5} failed {:3} batches {:5} occupancy {:.2} | \
                     streaming: queue {:3} in-flight {:2} tokens {:6} shed {:3} | \
                     cache: hit {:5} miss {:4} evict {:3} resident {:3} ({} KiB, pinned {})",
                    m.completed,
                    m.failed,
                    m.batches,
                    m.mean_occupancy(),
                    m.decode_queue_depth,
                    m.decode_in_flight,
                    m.decode_tokens,
                    m.shed_requests,
                    m.cache_hits,
                    m.cache_misses,
                    m.cache_evictions,
                    m.cache_resident,
                    m.cache_resident_bytes / 1024,
                    m.cache_pinned
                );
            }
        });
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let c = client.clone();
                let adapter = names[i % names.len()].clone();
                std::thread::spawn(move || c.infer_with(&adapter, &[(i % 7 + 1) as i32, 2, 3, 4]))
            })
            .collect();
        let stream_handles: Vec<_> = (0..n_streams)
            .map(|i| {
                let c = client.clone();
                let adapter = names[i % names.len()].clone();
                let opts = GenOptions {
                    max_tokens: args.get_usize("max-tokens", 16),
                    temperature: args.get_f64("temperature", 0.0) as f32,
                    seed: i as u64,
                    ..GenOptions::default()
                };
                std::thread::spawn(move || {
                    let prompt = [(i % 7 + 1) as i32, 2];
                    c.generate_collect_with(&adapter, &prompt, opts)
                })
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap()?;
            println!(
                "adapter={:12} next_token={:4}  latency={:7.1?}  occupancy={}",
                r.adapter, r.next_token, r.latency, r.batch_occupancy
            );
        }
        for (i, h) in stream_handles.into_iter().enumerate() {
            let tokens = h.join().unwrap()?;
            println!(
                "stream {i:3} decoded {} tokens: {:?}...",
                tokens.len(),
                &tokens[..tokens.len().min(6)]
            );
        }
        done.store(true, std::sync::atomic::Ordering::Relaxed);
        logger.join().unwrap();
        Ok(())
    })?;
    let m = server.shutdown();
    println!(
        "served {} requests in {} engine calls ({} merged / {} composed); \
         p50 {:.0} us, p95 {:.0} us, exec backend {}",
        m.completed,
        m.batches,
        m.merged_batches,
        m.composed_batches,
        m.p50_us(),
        m.p95_us(),
        m.exec_backend
    );
    if merge_budget.is_some() {
        println!(
            "cache: {} hits / {} misses, {} promotions, {} evictions, {} rejected, \
             high water {} KiB of {} KiB budget; resident at shutdown: {:?}",
            m.cache_hits,
            m.cache_misses,
            m.cache_promotions,
            m.cache_evictions,
            m.cache_rejects,
            m.cache_high_water_bytes / 1024,
            m.merge_budget_bytes / 1024,
            m.resident_adapters
        );
    }
    if m.decode_requests > 0 {
        println!(
            "streaming: {} streams, {} tokens, {} shed; ttft p50 {:.0} us p99 {:.0} us, \
             token p50 {:.0} us p99 {:.0} us",
            m.decode_requests,
            m.decode_tokens,
            m.shed_requests,
            m.ttft_p50_us(),
            m.ttft_p99_us(),
            m.token_p50_us(),
            m.token_p99_us()
        );
    }
    for (name, am) in &m.per_adapter {
        println!(
            "  adapter {:12} completed {:4} failed {:3} batches {:4} p95 {:8.0} us occupancy {:.2}",
            name,
            am.completed,
            am.failed,
            am.batches,
            am.p95_us(),
            am.mean_occupancy()
        );
    }
    for (i, w) in m.per_worker.iter().enumerate() {
        println!(
            "  worker {:3} batches {:5} completed {:5} failed {:3}",
            i, w.batches, w.completed, w.failed
        );
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    match report::by_name(id) {
        Some(body) => {
            println!("{body}");
            Ok(())
        }
        None => bail!("unknown report id {id:?}; try one of {:?}", report::REPORT_IDS),
    }
}

fn cmd_info() -> Result<()> {
    println!("devices (simulated testbed):");
    for d in dorafactors::gpusim::DEVICES.iter() {
        println!(
            "  {:14} SM{:3}  {:5.0} GB  {:4.2} TB/s  fused {:2.0}% / eager {:2.0}%",
            d.name,
            d.sm,
            d.mem_gb,
            d.peak_bw / 1e12,
            d.fused_bw_frac * 100.0,
            d.eager_bw_frac * 100.0
        );
    }
    let dir = manifest::default_dir();
    match Engine::load(&dir) {
        Ok(eng) => {
            println!("\nartifacts: {dir:?} (platform {})", eng.platform());
            for (name, cfg) in &eng.manifest().configs {
                println!(
                    "  config {:5}  {} params, vocab {}, d_model {}, {} layers, r={}",
                    name, cfg.n_params, cfg.vocab, cfg.d_model, cfg.n_layers, cfg.rank
                );
            }
            println!("  {} artifacts", eng.manifest().artifacts.len());
        }
        Err(e) => println!("\nartifacts not available: {e:#}"),
    }
    println!("\nnative engine configs (PJRT fallback):");
    for (name, cfg) in dorafactors::runtime::native::builtin_configs() {
        println!(
            "  config {:5}  {} params, vocab {}, d_model {}, {} layers, r={}",
            name, cfg.n_params, cfg.vocab, cfg.d_model, cfg.n_layers, cfg.rank
        );
    }
    println!("selected backend: {}", BackendSpec::auto().kind_name());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = TrainerCfg {
        config: args.get_or("config", "small").to_string(),
        variant: args.get_or("variant", "fused").to_string(),
        seed: args.get_u64("seed", 0),
        branching: args.get_usize("branching", 4),
        eval_every: args.get_usize("eval-every", 0),
        train_workers: args.get_usize("train-workers", 0),
        grad_accum: args.get_usize("grad-accum", 1),
        precision: Precision::parse(args.get_or("precision", "f32"))?,
    };
    let steps = args.get_usize("steps", 50);
    let mut tr = Trainer::auto(cfg.clone())?;
    println!(
        "training config={} variant={} precision={} seed={} params={} backend={} compose={} ({}) \
         train-workers={} grad-accum={}",
        cfg.config,
        cfg.variant,
        cfg.precision.as_str(),
        cfg.seed,
        tr.config_info().n_params,
        tr.backend_kind(),
        tr.compose_backend,
        tr.compose_tier.name(),
        tr.train_workers(),
        cfg.grad_accum
    );
    while tr.step_count() < steps {
        let recs: Vec<_> = tr.run_chunk()?.to_vec();
        let last = recs.last().unwrap();
        println!(
            "step {:5}  loss {:.4}  ({:.2} s wall)",
            last.step, last.loss, tr.wall_seconds
        );
    }
    let eval = tr.eval()?;
    println!("final eval loss: {eval:.4}");
    Ok(())
}

/// Stream one autoregressive decode to stdout, token by token as each
/// lands (the CLI face of `Client::generate`). With `--adapter` the
/// request runs against a stored checkpoint; without it a fresh-init
/// adapter on `--config` serves the request, so a clean checkout can
/// stream immediately.
fn cmd_generate(args: &Args) -> Result<()> {
    let prompt: Vec<i32> = args
        .get_or("prompt", "1,2,3")
        .split(',')
        .map(|t| t.trim().parse::<i32>().with_context(|| format!("bad --prompt token {t:?}")))
        .collect::<Result<Vec<_>>>()?;
    let opts = GenOptions {
        max_tokens: args.get_usize("max-tokens", 32),
        temperature: args.get_f64("temperature", 0.0) as f32,
        top_k: args.get_usize("top-k", 0),
        seed: args.get_u64("seed", 0),
        top_logits: args.get_usize("top-logits", 0),
        ..GenOptions::default()
    };
    let cfg = |config: String, precision: Precision| ServerCfg {
        config,
        max_wait: Duration::from_millis(2),
        workers: args.get_usize("workers", 1),
        fast_path: FastPath::parse(args.get_or("fast-path", "merged"))
            .unwrap_or(FastPath::Merged),
        queue_depth: args.get_usize("queue-depth", 16),
        precision,
        ..ServerCfg::default()
    };
    // With --adapter and no --precision the checkpoint's precision wins
    // (pre-precision checkpoints serve as f32); without --adapter the
    // flag picks the fresh-init server's precision.
    let precision_flag = match args.get("precision") {
        Some(p) => Some(Precision::parse(p)?),
        None => None,
    };
    let (server, adapter_name) = match args.get("adapter") {
        Some(name) => {
            let adapter = store_from(args)?.load(name)?;
            let config = adapter.config.clone();
            let precision = precision_flag.unwrap_or(adapter.precision);
            (
                Server::start_with_adapters(
                    BackendSpec::auto(),
                    cfg(config, precision),
                    vec![adapter],
                )?,
                name.to_string(),
            )
        }
        None => {
            let config = args.get_or("config", "tiny").to_string();
            let precision = precision_flag.unwrap_or_default();
            let server = Server::start(BackendSpec::auto(), cfg(config, precision))?;
            let name = server.default_adapter().to_string();
            (server, name)
        }
    };
    println!(
        "generate: adapter {adapter_name:?}, prompt {prompt:?}, max {} tokens, \
         temperature {}, {} fast path",
        opts.max_tokens,
        opts.temperature,
        server.fast_path().as_str()
    );
    let stream = server.client().generate_with(&adapter_name, &prompt, opts)?;
    let mut finish = None;
    for ev in stream {
        let ev = ev?;
        use std::io::Write;
        print!("{} ", ev.token);
        std::io::stdout().flush().ok();
        if !ev.top.is_empty() {
            let alts: Vec<String> =
                ev.top.iter().map(|(t, l)| format!("{t}:{l:.3}")).collect();
            print!("[{}] ", alts.join(" "));
        }
        finish = ev.finish;
    }
    println!();
    let m = server.shutdown();
    println!(
        "finished ({:?}): {} tokens; ttft {:.2} ms, token p50 {:.2} ms p99 {:.2} ms",
        finish,
        m.decode_tokens,
        m.ttft_p50_us() / 1e3,
        m.token_p50_us() / 1e3,
        m.token_p99_us() / 1e3
    );
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    let config = args.get_or("config", "tiny").to_string();
    let n = args.get_usize("requests", 16);
    let server = Server::start(
        BackendSpec::auto(),
        ServerCfg {
            config,
            max_wait: Duration::from_millis(10),
            workers: args.get_usize("workers", 0),
            fast_path: FastPath::parse(args.get_or("fast-path", "merged"))?,
            queue_depth: args.get_usize("queue-depth", 64),
            precision: Precision::parse(args.get_or("precision", "f32"))?,
            ..ServerCfg::default()
        },
    )?;
    let client = server.client();
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let c = client.clone();
            std::thread::spawn(move || c.infer(&[(i % 7 + 1) as i32, 2, 3, 4]))
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap()?;
        println!(
            "next_token={:4}  latency={:7.1?}  occupancy={}",
            r.next_token, r.latency, r.batch_occupancy
        );
    }
    let m = server.shutdown();
    println!(
        "served {} requests in {} batches ({} workers, {} fast path); p50 {:.0} us, p95 {:.0} us, mean occupancy {:.1}, compose backend {}, exec backend {}",
        m.completed,
        m.batches,
        m.workers,
        m.fast_path,
        m.p50_us(),
        m.p95_us(),
        m.mean_occupancy(),
        m.compose_backend,
        m.exec_backend
    );
    Ok(())
}
