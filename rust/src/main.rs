//! dorafactors CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   report <id>        regenerate a paper table/figure (or `all`)
//!   info               manifest + device + config summary
//!   train              run a training job against the AOT artifacts
//!   serve-demo         start the batched server and fire demo traffic
//!
//! The heavier end-to-end drivers (quickstart, convergence study, the
//! ~100M e2e training run, serving load test) live in `examples/`.

use std::time::Duration;

use anyhow::{bail, Result};

use dorafactors::bench::report;
use dorafactors::coordinator::{Server, ServerCfg, Trainer, TrainerCfg};
use dorafactors::runtime::{manifest, BackendSpec, Engine};
use dorafactors::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("report") => cmd_report(&args),
        Some("info") => cmd_info(),
        Some("train") => cmd_train(&args),
        Some("serve-demo") => cmd_serve_demo(&args),
        _ => {
            eprintln!(
                "usage: dorafactors <report|info|train|serve-demo> [--flags]\n\
                 \n\
                 report <id>   one of: {}\n\
                 train         --config tiny|small|e2e --variant eager|fused \
                 --steps N --seed S [--eval-every N]\n\
                 serve-demo    --config tiny|small --requests N",
                report::REPORT_IDS.join(" ")
            );
            std::process::exit(2);
        }
    }
}

fn cmd_report(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    match report::by_name(id) {
        Some(body) => {
            println!("{body}");
            Ok(())
        }
        None => bail!("unknown report id {id:?}; try one of {:?}", report::REPORT_IDS),
    }
}

fn cmd_info() -> Result<()> {
    println!("devices (simulated testbed):");
    for d in dorafactors::gpusim::DEVICES.iter() {
        println!(
            "  {:14} SM{:3}  {:5.0} GB  {:4.2} TB/s  fused {:2.0}% / eager {:2.0}%",
            d.name,
            d.sm,
            d.mem_gb,
            d.peak_bw / 1e12,
            d.fused_bw_frac * 100.0,
            d.eager_bw_frac * 100.0
        );
    }
    let dir = manifest::default_dir();
    match Engine::load(&dir) {
        Ok(eng) => {
            println!("\nartifacts: {dir:?} (platform {})", eng.platform());
            for (name, cfg) in &eng.manifest().configs {
                println!(
                    "  config {:5}  {} params, vocab {}, d_model {}, {} layers, r={}",
                    name, cfg.n_params, cfg.vocab, cfg.d_model, cfg.n_layers, cfg.rank
                );
            }
            println!("  {} artifacts", eng.manifest().artifacts.len());
        }
        Err(e) => println!("\nartifacts not available: {e:#}"),
    }
    println!("\nnative engine configs (PJRT fallback):");
    for (name, cfg) in dorafactors::runtime::native::builtin_configs() {
        println!(
            "  config {:5}  {} params, vocab {}, d_model {}, {} layers, r={}",
            name, cfg.n_params, cfg.vocab, cfg.d_model, cfg.n_layers, cfg.rank
        );
    }
    println!("selected backend: {}", BackendSpec::auto().kind_name());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = TrainerCfg {
        config: args.get_or("config", "small").to_string(),
        variant: args.get_or("variant", "fused").to_string(),
        seed: args.get_u64("seed", 0),
        branching: args.get_usize("branching", 4),
        eval_every: args.get_usize("eval-every", 0),
    };
    let steps = args.get_usize("steps", 50);
    let mut tr = Trainer::auto(cfg.clone())?;
    println!(
        "training config={} variant={} seed={} params={} backend={} compose={} ({})",
        cfg.config,
        cfg.variant,
        cfg.seed,
        tr.config_info().n_params,
        tr.backend_kind(),
        tr.compose_backend,
        tr.compose_tier.name()
    );
    while tr.step_count() < steps {
        let recs: Vec<_> = tr.run_chunk()?.to_vec();
        let last = recs.last().unwrap();
        println!(
            "step {:5}  loss {:.4}  ({:.2} s wall)",
            last.step, last.loss, tr.wall_seconds
        );
    }
    let eval = tr.eval()?;
    println!("final eval loss: {eval:.4}");
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    let config = args.get_or("config", "tiny").to_string();
    let n = args.get_usize("requests", 16);
    let server = Server::start(
        BackendSpec::auto(),
        ServerCfg { config, max_wait: Duration::from_millis(10) },
    )?;
    let client = server.client();
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let c = client.clone();
            std::thread::spawn(move || c.infer(&[(i % 7 + 1) as i32, 2, 3, 4]))
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap()?;
        println!(
            "next_token={:4}  latency={:7.1?}  occupancy={}",
            r.next_token, r.latency, r.batch_occupancy
        );
    }
    let m = server.shutdown();
    println!(
        "served {} requests in {} batches; p50 {:.0} us, p95 {:.0} us, mean occupancy {:.1}, compose backend {}, exec backend {}",
        m.completed,
        m.batches,
        m.p50_us(),
        m.p95_us(),
        m.mean_occupancy(),
        m.compose_backend,
        m.exec_backend
    );
    Ok(())
}
