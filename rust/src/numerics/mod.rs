//! Numerics substrate: software half-precision rounding, the Figure-1
//! stability analysis, and the g-distribution (collapse zone) measurement.
//!
//! Everything here is exact: bf16/fp16 rounding phenomena do not depend on
//! hardware, so this module is the authoritative reproduction of the
//! paper's numerical claims (§3.1, Figure 1).
//!
//! It is also the substrate of the runtime's `--precision bf16` operating
//! point (DESIGN.md §3.11): [`half::round_bf16`] is the rounding primitive
//! the soft-bf16 forward applies at every shape-fixed point, and
//! [`gdist::cosine`] is the metric of the bf16-vs-f32 logit gates.

#![warn(missing_docs)]

pub mod gdist;
pub mod half;
pub mod stability;

pub use half::Dtype;
