//! Numerics substrate: software half-precision rounding, the Figure-1
//! stability analysis, and the g-distribution (collapse zone) measurement.
//!
//! Everything here is exact: bf16/fp16 rounding phenomena do not depend on
//! hardware, so this module is the authoritative reproduction of the
//! paper's numerical claims (§3.1, Figure 1).

pub mod gdist;
pub mod half;
pub mod stability;

pub use half::Dtype;
