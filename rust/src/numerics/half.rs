//! Software bf16 / fp16 rounding — exact round-to-nearest-even emulation.
//!
//! The paper's numerical claims (Figure 1, the g≈1 "collapse zone"
//! analysis in §3.1) are pure rounding phenomena, so software emulation on
//! f32/f64 reproduces them bit-exactly. No `half` crate is vendored;
//! these routines implement IEEE 754 round-to-nearest-even directly.

/// Round an f32 to bfloat16 precision (RNE), returning the value as f32.
///
/// bf16 = top 16 bits of f32 (1 sign, 8 exponent, 7 mantissa bits).
///
/// This is the rounding primitive of the `--precision bf16` operating
/// point: the soft-bf16 forward applies it elementwise at every
/// shape-fixed point (weights on snapshot, activations between ops), so
/// CPU runs model bf16 *numerics* exactly without bf16 storage or speed.
///
/// ```
/// use dorafactors::numerics::half::round_bf16;
///
/// // Exactly representable values pass through untouched...
/// assert_eq!(round_bf16(1.5), 1.5);
/// // ...while g = 1 + 1e-3 collapses to 1.0 (the §3.1 collapse zone):
/// assert_eq!(round_bf16(1.0 + 1e-3), 1.0);
/// ```
#[inline]
pub fn round_bf16(x: f32) -> f32 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Quiet the NaN in the truncated payload so it stays a NaN.
        return f32::from_bits((bits | 0x0040_0000) & 0xFFFF_0000);
    }
    // Round-to-nearest-even on the low 16 bits.
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x0000_7FFF + lsb) & 0xFFFF_0000;
    let _ = round_bit;
    f32::from_bits(rounded)
}

/// Round an f32 to IEEE fp16 precision (RNE), returning the value as f32.
///
/// Handles normals, subnormals, overflow-to-infinity, and NaN. fp16 =
/// 1 sign, 5 exponent, 10 mantissa bits, bias 15.
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// f32 -> fp16 bit pattern with round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN.
        return if man != 0 {
            sign | 0x7E00 // quiet NaN
        } else {
            sign | 0x7C00
        };
    }

    // Unbiased exponent.
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if e >= -14 {
        // Normal fp16. 13 mantissa bits are dropped.
        let man16 = (man >> 13) as u16;
        let rest = man & 0x1FFF;
        let halfway = 0x1000;
        let mut out = sign | (((e + 15) as u16) << 10) | man16;
        if rest > halfway || (rest == halfway && (man16 & 1) == 1) {
            out = out.wrapping_add(1); // may carry into exponent: correct
        }
        return out;
    }
    if e >= -25 {
        // Subnormal fp16: implicit leading 1 becomes explicit. e == -25
        // is included so values in (2^-25, 2^-24) round to the smallest
        // subnormal rather than flushing; shifts can reach 38 bits, so
        // widen to u64.
        let full = (man | 0x0080_0000) as u64;
        let shift = ((-14 - e) + 13) as u32;
        let man16 = (full >> shift) as u16;
        let rest = full & ((1u64 << shift) - 1);
        let halfway = 1u64 << (shift - 1);
        let mut out = sign | man16;
        if rest > halfway || (rest == halfway && (man16 & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    sign // underflow to zero
}

/// fp16 bit pattern -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        // Inf / NaN.
        sign | 0x7F80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // Subnormal: normalize.
            let mut m = man;
            let mut e = -14i32;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | (((e + 127) as u32) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Machine epsilon of bf16 (2^-8 between 1 and 2).
pub const BF16_EPS: f32 = 0.0078125; // 2^-7 ULP at 1.0; eps = 2^-8 rounding radius*2
/// Machine epsilon of fp16 (2^-10 ULP at 1.0).
pub const F16_EPS: f32 = 0.0009765625;

/// Supported emulated dtypes for the stability sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// IEEE 754 single precision — the identity under [`Dtype::quantize`].
    F32,
    /// bfloat16: 8 exponent / 7 mantissa bits (f32 range, coarse steps).
    Bf16,
    /// IEEE fp16: 5 exponent / 10 mantissa bits (narrow range, finer steps).
    F16,
}

impl Dtype {
    /// Round a value to this dtype's precision (identity for f32).
    #[inline]
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            Dtype::F32 => x,
            Dtype::Bf16 => round_bf16(x),
            Dtype::F16 => round_f16(x),
        }
    }

    /// The paper's dtype-dependent epsilon for the magnitude division
    /// (Appendix B): 1e-12 for fp32, 1e-6 for half types.
    pub fn division_eps(self) -> f32 {
        match self {
            Dtype::F32 => 1e-12,
            Dtype::Bf16 | Dtype::F16 => 1e-6,
        }
    }

    /// Bytes per element (for traffic accounting).
    pub fn size(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 | Dtype::F16 => 2,
        }
    }

    /// Representational epsilon: |g-1| below eps/2 collapses to 1 when g is
    /// stored in this dtype (the paper's collapse-zone threshold §3.1).
    pub fn machine_eps(self) -> f32 {
        match self {
            Dtype::F32 => f32::EPSILON,
            Dtype::Bf16 => BF16_EPS,
            Dtype::F16 => F16_EPS,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_exact_values_pass_through() {
        for x in [0.0f32, 1.0, -2.0, 0.5, 128.0, -0.0078125] {
            assert_eq!(round_bf16(x), x, "{x}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-9 is exactly halfway between 1.0 and 1.0078125 (odd LSB
        // candidate); RNE goes to even (1.0).
        let halfway = 1.0 + (0.5 * BF16_EPS);
        assert_eq!(round_bf16(halfway), 1.0);
        // Just above halfway rounds up.
        assert_eq!(round_bf16(halfway + 1e-5), 1.0 + BF16_EPS);
    }

    #[test]
    fn bf16_collapse_zone() {
        // The §3.1 phenomenon: g = 1 + 1e-3 is representable only as 1.0
        // in bf16 (|g-1| < eps/2 = 3.9e-3).
        assert_eq!(round_bf16(1.0 + 1e-3), 1.0);
        assert_ne!(round_bf16(1.0 + 5e-3), 1.0);
    }

    #[test]
    fn bf16_preserves_nan_and_inf() {
        assert!(round_bf16(f32::NAN).is_nan());
        assert_eq!(round_bf16(f32::INFINITY), f32::INFINITY);
        assert_eq!(round_bf16(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn f16_exact_and_rounding() {
        for x in [0.0f32, 1.0, -1.5, 0.25, 2048.0] {
            assert_eq!(round_f16(x), x, "{x}");
        }
        // fp16 max ~ 65504; beyond that -> inf.
        assert_eq!(round_f16(70000.0), f32::INFINITY);
        assert_eq!(round_f16(65504.0), 65504.0);
    }

    #[test]
    fn f16_subnormals() {
        let min_sub = (2f32).powi(-24); // smallest fp16 subnormal
        assert_eq!(round_f16(min_sub), min_sub);
        // Halfway below (2^-25) ties to even -> 0; just above rounds up.
        assert_eq!(round_f16((2f32).powi(-25)), 0.0);
        assert_eq!(round_f16((2f32).powi(-25) * 1.5), min_sub);
        assert_eq!(round_f16(min_sub / 8.0), 0.0);
    }

    #[test]
    fn f16_nan() {
        assert!(round_f16(f32::NAN).is_nan());
    }

    #[test]
    fn f16_collapse_zone_narrower_than_bf16() {
        // 1 + 1e-3: representable in fp16 (eps = 9.77e-4 -> 1e-3 > eps/2)
        // but NOT in bf16 — matching the paper's "100% bf16, 20% fp16"
        // asymmetry.
        assert_ne!(round_f16(1.0 + 1e-3), 1.0);
        assert_eq!(round_bf16(1.0 + 1e-3), 1.0);
    }

    #[test]
    fn rne_matches_reference_grid() {
        // Cross-check fp16 round-trip on a dense grid against the
        // definition: result must be one of the two neighbouring fp16
        // values, whichever is closer (ties to even).
        for i in 0..2000 {
            let x = -4.0 + i as f32 * 0.004;
            let r = round_f16(x);
            let up = f16_bits_to_f32(f32_to_f16_bits(x).wrapping_add(1));
            assert!(
                (r - x).abs() <= (up - x).abs() + 1e-12,
                "x={x} r={r} up={up}"
            );
        }
    }

    #[test]
    fn dtype_quantize_dispatch() {
        assert_eq!(Dtype::F32.quantize(1.0 + 1e-3), 1.0 + 1e-3);
        assert_eq!(Dtype::Bf16.quantize(1.0 + 1e-3), 1.0);
        assert_eq!(Dtype::Bf16.size(), 2);
        assert_eq!(Dtype::F32.size(), 4);
    }
}
