//! Numerical-stability analysis of the DoRA compose (paper §3.1, Figure 1).
//!
//! Three evaluation strategies of the algebraically identical composition
//! `delta = (g-1)*base + g*s*lora`:
//!
//! * **naive**  — `g*(s*lora + base) - base`, evaluated entirely in the
//!   storage dtype. Catastrophic cancellation when g ≈ 1: `g*base` rounds
//!   to `base` and the correction vanishes.
//! * **stable** — `(g-1)*base + g*(s*lora)` with fp32 intermediates,
//!   rounded to the storage dtype only at the end (the paper's eager path
//!   and both kernels).
//! * **fused**  — same algebra as stable; in this reproduction the fused
//!   CPU kernel shares the fp32 intermediate discipline, so its error sits
//!   on the stable trace (Figure 1's bottom curves).
//!
//! The fp64 evaluation is the error reference.

use super::half::Dtype;

/// One point of the Figure-1 sweep: peak absolute error of each form at a
/// given |g-1| offset.
#[derive(Debug, Clone)]
pub struct StabilityPoint {
    /// The |g-1| offset of this sweep point (log-spaced, 1e-6..1e-1).
    pub g_offset: f64,
    /// Peak absolute error of the naive (all-quantized) form vs fp64.
    pub err_naive: f64,
    /// Peak absolute error of the stable (fp32-intermediate) form vs fp64.
    pub err_stable: f64,
}

/// Evaluate the naive form in `dt`: every intermediate is quantized.
#[inline]
pub fn compose_naive_quantized(base: f32, lora: f32, g: f32, s: f32, dt: Dtype) -> f32 {
    let sl = dt.quantize(s * lora);
    let inner = dt.quantize(sl + base);
    let scaled = dt.quantize(dt.quantize(g) * inner);
    dt.quantize(scaled - base)
}

/// Evaluate the stable form: fp32 compute, one final quantization.
/// g is NOT quantized to the storage dtype (it is produced by the fp32
/// magnitude division, Eq. 6) — quantizing it is precisely the collapse
/// the paper's design avoids.
///
/// ```
/// use dorafactors::numerics::{stability, Dtype};
///
/// // g = 1 + 1e-3 with base = 100: truth is 0.1. The naive bf16 form
/// // loses the whole correction; the stable form keeps it.
/// let naive = stability::compose_naive_quantized(100.0, 0.0, 1.0 + 1e-3, 1.0, Dtype::Bf16);
/// assert_eq!(naive, 0.0);
/// let stable = stability::compose_stable_quantized(100.0, 0.0, 1.0 + 1e-3, 1.0, Dtype::Bf16);
/// assert!((stable as f64 - 0.1).abs() < 5e-4);
/// ```
#[inline]
pub fn compose_stable_quantized(base: f32, lora: f32, g: f32, s: f32, dt: Dtype) -> f32 {
    let delta = (g - 1.0) * base + g * (s * lora);
    dt.quantize(delta)
}

/// fp64 ground truth.
#[inline]
pub fn compose_f64(base: f64, lora: f64, g: f64, s: f64) -> f64 {
    (g - 1.0) * base + g * s * lora
}

/// Sweep |g-1| offsets (log-spaced) and record each form's peak absolute
/// error against fp64, over pseudo-random activations. Reproduces the
/// Figure 1 panel for the given dtype.
pub fn sweep_g_offsets(
    dt: Dtype,
    n_offsets: usize,
    n_elems: usize,
    seed: u64,
) -> Vec<StabilityPoint> {
    let mut rng = crate::util::rng::Rng::new(seed);
    // Match the figure's setup: activations at realistic scale, lora path
    // active but small relative to base (adapters start near zero).
    let base: Vec<f32> = (0..n_elems)
        .map(|_| dt.quantize((rng.normal() * 10.0) as f32))
        .collect();
    let lora: Vec<f32> = (0..n_elems)
        .map(|_| dt.quantize((rng.normal() * 0.1) as f32))
        .collect();
    let s = 2.0f32;

    let mut out = Vec::with_capacity(n_offsets);
    for i in 0..n_offsets {
        // Log-spaced offsets from 1e-6 to 1e-1 (the figure's x-axis).
        let t = i as f64 / (n_offsets - 1).max(1) as f64;
        let offset = 10f64.powf(-6.0 + 5.0 * t);
        let g = (1.0 + offset) as f32;

        let mut err_naive: f64 = 0.0;
        let mut err_stable: f64 = 0.0;
        for j in 0..n_elems {
            let truth = compose_f64(base[j] as f64, lora[j] as f64, 1.0 + offset, s as f64);
            let en = (compose_naive_quantized(base[j], lora[j], g, s, dt) as f64 - truth).abs();
            let es = (compose_stable_quantized(base[j], lora[j], g, s, dt) as f64 - truth).abs();
            err_naive = err_naive.max(en);
            err_stable = err_stable.max(es);
        }
        out.push(StabilityPoint { g_offset: offset, err_naive, err_stable });
    }
    out
}

/// Figure 1's headline: ratio of peak naive error to peak stable error
/// over the sweep (paper: 3.0x in bf16).
pub fn peak_error_ratio(points: &[StabilityPoint]) -> f64 {
    let pn = points.iter().map(|p| p.err_naive).fold(0.0, f64::max);
    let ps = points.iter().map(|p| p.err_stable).fold(0.0, f64::max);
    pn / ps.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forms_agree_in_f32_away_from_unity() {
        // At g = 1.5 there is no cancellation; both forms are accurate.
        let (b, l, g, s) = (3.0f32, 0.5, 1.5, 2.0);
        let truth = compose_f64(b as f64, l as f64, g as f64, s as f64);
        let n = compose_naive_quantized(b, l, g, s, Dtype::F32) as f64;
        let st = compose_stable_quantized(b, l, g, s, Dtype::F32) as f64;
        assert!((n - truth).abs() < 1e-6);
        assert!((st - truth).abs() < 1e-6);
    }

    #[test]
    fn naive_collapses_in_bf16_near_unity() {
        // g = 1 + 1e-3, base = 100, lora = 0: truth = 0.1. Naive in bf16:
        // g rounds to 1, delta = 0 — the full correction is lost.
        let got = compose_naive_quantized(100.0, 0.0, 1.0 + 1e-3, 1.0, Dtype::Bf16);
        assert_eq!(got, 0.0);
        let stable = compose_stable_quantized(100.0, 0.0, 1.0 + 1e-3, 1.0, Dtype::Bf16);
        assert!((stable as f64 - 0.1).abs() < 5e-4, "stable={stable}");
    }

    #[test]
    fn figure1_ratio_exceeds_three_bf16() {
        let pts = sweep_g_offsets(Dtype::Bf16, 12, 512, 42);
        let ratio = peak_error_ratio(&pts);
        assert!(ratio > 3.0, "peak error ratio {ratio} <= 3.0");
    }

    #[test]
    fn stable_error_sits_near_quantization_floor() {
        // Stable-form error should be bounded by ~1 ULP of the output,
        // independent of the g offset (the flat trace in Figure 1).
        let pts = sweep_g_offsets(Dtype::Bf16, 10, 256, 7);
        for p in &pts {
            assert!(
                p.err_stable <= p.err_naive + 1e-12,
                "stable worse than naive at offset {}",
                p.g_offset
            );
        }
    }

    #[test]
    fn fp16_cancellation_less_severe_than_bf16() {
        // fp16 has 3 more mantissa bits; its collapse zone is ~8x narrower,
        // so the same sweep yields a lower peak ratio.
        let bf = peak_error_ratio(&sweep_g_offsets(Dtype::Bf16, 12, 256, 1));
        let fp = peak_error_ratio(&sweep_g_offsets(Dtype::F16, 12, 256, 1));
        assert!(
            bf > fp,
            "expected bf16 ratio ({bf}) > fp16 ratio ({fp})"
        );
    }
}
