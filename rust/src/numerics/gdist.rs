//! g-distribution analysis: how tightly the composed scale g = m / w_norm
//! concentrates around unity (paper §3.1).
//!
//! The paper measures a Qwen2-VL-7B adapter (r=128, 326 modules, 1.77M
//! elements): mean ≈ 1.0, std ≈ 0.0015, with 100% of values inside the
//! bf16 collapse zone (|g-1| < eps_bf16/2) and 20% inside the fp16 zone.
//! This module reproduces the measurement on synthetic adapters whose
//! magnitude drift models DoRA training (m initialized to ||W||_row, then
//! tracking weight norms with small relative drift).

use super::half::Dtype;
use crate::util::rng::Rng;
use crate::util::stats;

/// Summary of a g-value population.
#[derive(Debug, Clone)]
pub struct GDistribution {
    /// Population size.
    pub n: usize,
    /// Mean of the g values (paper: ≈ 1.0).
    pub mean: f64,
    /// Standard deviation (paper: ≈ 0.0015).
    pub std: f64,
    /// Fraction with |g-1| < eps_bf16/2 (bf16 collapse zone).
    pub frac_bf16_zone: f64,
    /// Fraction with |g-1| < eps_f16/2 (fp16 collapse zone).
    pub frac_f16_zone: f64,
    /// Smallest g in the population.
    pub min: f64,
    /// Largest g in the population.
    pub max: f64,
}

/// Collapse-zone membership test (paper §3.1): (g-1)*base vanishes in `dt`
/// iff |g-1| < machine_eps(dt)/2, i.e. g rounds to exactly 1.
pub fn in_collapse_zone(g: f64, dt: Dtype) -> bool {
    (g - 1.0).abs() < (dt.machine_eps() as f64) / 2.0
}

/// Cosine similarity of two equal-length f32 vectors, accumulated in f64.
///
/// This is the metric of the precision gates (DESIGN.md §3.11): the
/// bf16-vs-f32 final logits of every config × adapter-variant × serving
/// path must keep `cosine > 0.9999`. Accumulation runs in f64 so the
/// metric itself adds no rounding noise at gate resolution.
///
/// A zero (or empty) vector on either side returns 0.0 — a dead output
/// compared against anything reads as maximally dissimilar, so a gate
/// fails loudly instead of propagating NaN.
///
/// ```
/// use dorafactors::numerics::gdist::cosine;
///
/// let a = [1.0f32, 2.0, 3.0];
/// let scaled: Vec<f32> = a.iter().map(|x| 2.0 * x).collect();
/// assert!((cosine(&a, &scaled) - 1.0).abs() < 1e-12);
/// assert!((cosine(&a, &[-1.0, -2.0, -3.0]) + 1.0).abs() < 1e-12);
/// assert_eq!(cosine(&a, &[0.0; 3]), 0.0);
/// ```
///
/// # Panics
///
/// Panics if the slices differ in length — gate inputs come from the
/// same logit shape, so a mismatch is a harness bug, not data.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "cosine: length mismatch {} vs {}", a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Analyze a population of g values.
pub fn analyze(gs: &[f64]) -> GDistribution {
    let n = gs.len();
    let bf = gs.iter().filter(|&&g| in_collapse_zone(g, Dtype::Bf16)).count();
    let fp = gs.iter().filter(|&&g| in_collapse_zone(g, Dtype::F16)).count();
    GDistribution {
        n,
        mean: stats::mean(gs),
        std: stats::std_dev(gs),
        frac_bf16_zone: bf as f64 / n.max(1) as f64,
        frac_f16_zone: fp as f64 / n.max(1) as f64,
        min: stats::min(gs),
        max: stats::max(gs),
    }
}

/// Synthesize the g population of a trained DoRA adapter.
///
/// DoRA initializes m = ||W||_row exactly (g = 1); during training the
/// magnitude tracks the (slowly moving) weight norm, so g = m / w_norm
/// stays within a small relative band. `drift_std` is the relative drift —
/// the paper's measured std is ~0.0015.
pub fn synthesize_trained_adapter(
    n_modules: usize,
    d_out: usize,
    drift_std: f64,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut gs = Vec::with_capacity(n_modules * d_out);
    for module in 0..n_modules {
        let mut mrng = rng.fork(module as u64);
        // Per-module drift scale varies (layers train at different rates).
        let module_scale = drift_std * (0.5 + mrng.next_f64());
        for _ in 0..d_out {
            gs.push(1.0 + mrng.normal() * module_scale);
        }
    }
    gs
}

/// The paper's measurement, reproduced: a 326-module adapter population
/// with the measured drift.
pub fn paper_population() -> GDistribution {
    analyze(&synthesize_trained_adapter(326, 5430, 0.0015, 2024))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_population_is_exactly_unity() {
        let gs = vec![1.0; 1000];
        let d = analyze(&gs);
        assert_eq!(d.mean, 1.0);
        assert_eq!(d.frac_bf16_zone, 1.0);
        assert_eq!(d.frac_f16_zone, 1.0);
    }

    #[test]
    fn cosine_tracks_perturbation_size() {
        // The gate metric behaves monotonically: a tiny relative
        // perturbation keeps cosine above the 0.9999 gate, a gross one
        // does not.
        let a: Vec<f32> = (0..256).map(|i| ((i * 37) % 101) as f32 - 50.0).collect();
        let tiny: Vec<f32> = a.iter().map(|x| x * 1.0001 + 1e-4).collect();
        assert!(cosine(&a, &tiny) > 0.9999);
        let gross: Vec<f32> = a.iter().map(|x| -x + 7.0).collect();
        assert!(cosine(&a, &gross) < 0.0);
        assert_eq!(cosine(&[], &[]), 0.0);
    }

    #[test]
    fn collapse_zone_thresholds() {
        // bf16 zone: |g-1| < 2^-8 = 3.9e-3.
        assert!(in_collapse_zone(1.001, Dtype::Bf16));
        assert!(!in_collapse_zone(1.01, Dtype::Bf16));
        // fp16 zone is ~8x narrower: 1.001 is OUTSIDE.
        assert!(!in_collapse_zone(1.001, Dtype::F16));
        assert!(in_collapse_zone(1.0002, Dtype::F16));
    }

    #[test]
    fn paper_measurement_shape() {
        // §3.1: mean ~ 1.0, std ~ 0.0015, 100% bf16 zone, ~20% fp16 zone.
        let d = paper_population();
        assert!((d.mean - 1.0).abs() < 1e-3, "mean {}", d.mean);
        assert!((d.std - 0.0015).abs() < 6e-4, "std {}", d.std);
        assert!(d.frac_bf16_zone > 0.95, "bf16 zone {}", d.frac_bf16_zone);
        assert!(
            d.frac_f16_zone > 0.05 && d.frac_f16_zone < 0.6,
            "fp16 zone {}",
            d.frac_f16_zone
        );
        // The asymmetry is the headline: far more values collapse in bf16.
        assert!(d.frac_bf16_zone > 2.0 * d.frac_f16_zone);
    }

    #[test]
    fn wider_drift_escapes_zone() {
        let gs = synthesize_trained_adapter(10, 1000, 0.05, 3);
        let d = analyze(&gs);
        assert!(d.frac_bf16_zone < 0.5, "drift 0.05 should leave the zone");
    }
}
