//! Minimal JSON parser + writer.
//!
//! The vendored crate set has no `serde`/`serde_json`, so the repo carries
//! its own implementation, scoped to what the stack needs: parsing the
//! machine-generated `artifacts/manifest.json` and emitting benchmark /
//! experiment result files. Full RFC 8259 value model (objects, arrays,
//! strings with escapes, numbers, booleans, null); no streaming, no
//! serde-style derive.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use BTreeMap so emission is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadEscape(usize),
    Trailing(usize),
    Type(&'static str, &'static str),
    MissingKey(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof(at) => write!(f, "unexpected end of input at byte {at}"),
            JsonError::Unexpected(c, at) => {
                write!(f, "unexpected character {c:?} at byte {at}")
            }
            JsonError::BadNumber(at) => write!(f, "invalid number at byte {at}"),
            JsonError::BadEscape(at) => write!(f, "invalid escape at byte {at}"),
            JsonError::Trailing(at) => write!(f, "trailing garbage at byte {at}"),
            JsonError::Type(want, got) => write!(f, "expected {want}, found {got}"),
            JsonError::MissingKey(key) => write!(f, "missing key {key:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(JsonError::Type("number", other.kind())),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type("string", other.kind())),
        }
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type("bool", other.kind())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Type("array", other.kind())),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Type("object", other.kind())),
        }
    }

    /// Object field access; errors with the key name when absent.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// Optional field access.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Shape helper: array of numbers -> Vec<usize>.
    pub fn as_shape(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- construction helpers ---------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Str(x.to_string())).collect())
    }

    // ---- emission ----------------------------------------------------------

    /// Compact single-line encoding.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        // JSON has no NaN/Inf; emit null like most encoders in lenient mode.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parsing.
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut pos = 0;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(JsonError::Trailing(pos));
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn peek(b: &[u8], pos: usize) -> Result<u8, JsonError> {
    b.get(pos).copied().ok_or(JsonError::Eof(pos))
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    match peek(b, *pos)? {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_num(b, pos),
        c => Err(JsonError::Unexpected(c as char, *pos)),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, JsonError> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(JsonError::Unexpected(b[*pos] as char, *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    if peek(b, *pos)? == b'-' {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or(JsonError::BadNumber(start))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        match peek(b, *pos)? {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match peek(b, *pos)? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err(JsonError::Eof(*pos));
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| JsonError::BadEscape(*pos))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError::BadEscape(*pos))?;
                        // Surrogate pairs: manifest content is ASCII, but
                        // handle BMP + pairs for completeness.
                        if (0xD800..0xDC00).contains(&cp) {
                            if b.len() < *pos + 11 || b[*pos + 5] != b'\\' || b[*pos + 6] != b'u' {
                                return Err(JsonError::BadEscape(*pos));
                            }
                            let hex2 = std::str::from_utf8(&b[*pos + 7..*pos + 11])
                                .map_err(|_| JsonError::BadEscape(*pos))?;
                            let lo = u32::from_str_radix(hex2, 16)
                                .map_err(|_| JsonError::BadEscape(*pos))?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or(JsonError::BadEscape(*pos))?);
                            *pos += 6;
                        } else {
                            out.push(char::from_u32(cp).ok_or(JsonError::BadEscape(*pos))?);
                        }
                        *pos += 4;
                    }
                    _ => return Err(JsonError::BadEscape(*pos)),
                }
                *pos += 1;
            }
            _ => {
                // Copy a UTF-8 run without escape characters wholesale.
                let run_start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(
                    std::str::from_utf8(&b[run_start..*pos])
                        .map_err(|_| JsonError::BadEscape(run_start))?,
                );
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if peek(b, *pos)? == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match peek(b, *pos)? {
            b',' => {
                *pos += 1;
            }
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            c => return Err(JsonError::Unexpected(c as char, *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if peek(b, *pos)? == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if peek(b, *pos)? != b'"' {
            return Err(JsonError::Unexpected(b[*pos] as char, *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if peek(b, *pos)? != b':' {
            return Err(JsonError::Unexpected(b[*pos] as char, *pos));
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match peek(b, *pos)? {
            b',' => {
                *pos += 1;
            }
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            c => return Err(JsonError::Unexpected(c as char, *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\n"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x\n"
        );
        assert_eq!(v.get("c").unwrap(), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("x", Json::Num(1.0)),
            ("y", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn shape_helper() {
        let v = parse("[2, 3, 4]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![2, 3, 4]);
    }

    #[test]
    fn missing_key_error_names_key() {
        let v = parse("{}").unwrap();
        let err = v.get("foo").unwrap_err();
        assert!(err.to_string().contains("foo"));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = parse(&text).unwrap();
            assert!(m.get("artifacts").unwrap().as_obj().unwrap().len() > 10);
        }
    }
}
