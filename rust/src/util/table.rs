//! Plain-text table rendering for the report generator: every paper table
//! is re-emitted as an aligned ASCII/markdown table so EXPERIMENTS.md can
//! be assembled directly from `dorafactors report` output.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// An aligned text table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            aligns: std::iter::once(Align::Left)
                .chain(std::iter::repeat(Align::Right))
                .take(header.len())
                .collect(),
            rows: Vec::new(),
        }
    }

    /// Override column alignments (default: first left, rest right).
    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as a GitHub-flavoured markdown table (with title header).
    pub fn to_markdown(&self) -> String {
        let widths = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&render_row(&self.header, &widths, &self.aligns));
        out.push('|');
        for (w, a) in widths.iter().zip(&self.aligns) {
            match a {
                Align::Left => out.push_str(&format!(" :{} |", "-".repeat(w.max(&2) - 1))),
                Align::Right => out.push_str(&format!(" {}: |", "-".repeat(w.max(&2) - 1))),
            }
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&render_row(row, &widths, &self.aligns));
        }
        out
    }

    fn widths(&self) -> Vec<usize> {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.chars().count());
            }
        }
        widths
    }
}

fn render_row(cells: &[String], widths: &[usize], aligns: &[Align]) -> String {
    let mut out = String::from("|");
    for ((cell, w), a) in cells.iter().zip(widths).zip(aligns) {
        let pad = w - cell.chars().count();
        match a {
            Align::Left => out.push_str(&format!(" {}{} |", cell, " ".repeat(pad))),
            Align::Right => out.push_str(&format!(" {}{} |", " ".repeat(pad), cell)),
        }
    }
    out.push('\n');
    out
}

/// Format a speedup ratio like the paper: "1.74x".
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format bytes with binary units.
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit + 1 < UNITS.len() {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[unit])
    }
}

/// Format seconds adaptively (ns/us/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["Model", "Speedup"]);
        t.row(vec!["Qwen3-VL-8B".into(), "1.47x".into()]);
        t.row(vec!["Mistral".into(), "1.87x".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| Qwen3-VL-8B |"));
        // all data rows same width
        let lines: Vec<&str> = md.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_speedup(1.7346), "1.73x");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(256 * 1024 * 1024), "256.0 MiB");
        assert_eq!(fmt_secs(0.25), "250.00 ms");
        assert_eq!(fmt_secs(2.5e-5), "25.00 us");
    }
}
