//! Shared substrates: PRNG, JSON, statistics, table rendering, property
//! testing, and a tiny CLI argument helper.
//!
//! These exist because the build is fully offline against the vendored
//! crate set (xla + its deps only) — no rand/serde/clap/proptest. Each
//! module is scoped to exactly what the stack needs and carries its own
//! unit tests.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

/// Lock a mutex, recovering the inner data if a previous holder panicked.
///
/// The serving metrics and adapter-store maps are plain telemetry/state:
/// a panicking worker must not convert every later `metrics()` call into
/// a second panic (the default `.lock().unwrap()` behavior on a poisoned
/// mutex). Poisoning exists to flag possibly-inconsistent invariants;
/// every use site here updates self-contained counters/maps, so
/// recovering the data is always safe.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Minimal CLI flag parsing: `--key value` and `--flag` switches.
///
/// The main binary has a handful of subcommands with simple options; this
/// covers them without a clap dependency.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                let next_is_value = iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    out.flags.insert(key.to_string(), iter.next().unwrap());
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn parses_mixed_args() {
        let a = parse("report table4 --gpu h200 --verbose --steps 100");
        assert_eq!(a.positional, vec!["report", "table4"]);
        assert_eq!(a.get("gpu"), Some("h200"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("steps", 0), 100);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("gpu", "b200"), "b200");
        assert_eq!(a.get_usize("steps", 7), 7);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("--fast");
        assert_eq!(a.get("fast"), Some("true"));
    }

    #[test]
    fn lock_unpoisoned_recovers_after_a_panicking_holder() {
        use std::sync::{Arc, Mutex};
        let m = Arc::new(Mutex::new(41u64));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        // The data is still reachable — and writable — through the helper.
        *super::lock_unpoisoned(&m) += 1;
        assert_eq!(*super::lock_unpoisoned(&m), 42);
    }
}
