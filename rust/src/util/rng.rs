//! Deterministic PRNG: xoshiro256++ with splitmix64 seeding.
//!
//! The vendored crate set has no `rand`, so the repo carries its own
//! generator. xoshiro256++ is the reference generator of Blackman &
//! Vigna (2019); splitmix64 expands a 64-bit seed into the 256-bit state,
//! which is the seeding procedure the authors recommend.
//!
//! Everything downstream (synthetic corpora, benchmark inputs, property
//! tests) derives from this type, so runs are reproducible from a single
//! seed recorded in EXPERIMENTS.md.

/// xoshiro256++ PRNG with a Box-Muller cache for normal deviates.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single u64 via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (for per-thread / per-module use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Unbiased (Lemire's multiply-shift with
    /// rejection).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller (both values used).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.cached_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Vector of N(0, sigma^2) f32 samples.
    pub fn normal_vec_f32(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() as f32) * sigma).collect()
    }

    /// Vector of uniform [lo, hi) f32 samples.
    pub fn uniform_vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n)
            .map(|_| self.range_f64(lo as f64, hi as f64) as f32)
            .collect()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let k = r.below(10);
            assert!(k < 10);
        }
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
