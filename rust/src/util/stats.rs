//! Summary statistics used by the benchmark harness and report generator:
//! median-of-trials (the paper reports medians of 200 CUDA-event trials),
//! geometric means (Table 9/14 summaries), and coefficient of variation
//! (the paper's CV < 1.7% stability criterion).

/// Arithmetic mean. Empty input -> NaN.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (std/mean), the paper's run-stability metric.
pub fn cv(xs: &[f64]) -> f64 {
    std_dev(xs) / mean(xs)
}

/// Median (interpolated for even lengths). NaN-safe: sorts by the IEEE
/// total order instead of panicking (a single NaN latency sample must
/// not take down `ServerMetrics` reporting).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile in [0, 100] with linear interpolation (for p50/p95/p99
/// latency reporting in the serving coordinator). NaN-safe via the IEEE
/// total order: positive NaNs sort to the top, so low/mid percentiles of
/// a mostly-clean sample stay meaningful and nothing panics.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Geometric mean (Table 9/14's summary statistic). Requires positives.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0), "geomean of non-positive");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Min/max without NaN panics.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118033988).abs() < 1e-6);
        assert!((cv(&xs) - 0.4472135955 / 1.0).abs() < 1e-6);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn geomean_of_ratios() {
        // geomean([2, 8]) = 4 — the Table-9 aggregation.
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.5, 1.5, 1.5]) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_nan() {
        assert!(mean(&[]).is_nan());
        assert!(median(&[]).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn order_stats_survive_nan_inputs() {
        // Regression: these panicked with `partial_cmp(..).unwrap()` —
        // one NaN latency sample killed ServerMetrics reporting.
        let with_nan = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(median(&with_nan), 2.5); // NaN sorts above 3.0
        assert_eq!(percentile(&with_nan, 0.0), 1.0);
        assert!((percentile(&with_nan, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&with_nan, 100.0).is_nan());
        let all_nan = [f64::NAN, f64::NAN];
        assert!(median(&all_nan).is_nan());
        assert!(percentile(&all_nan, 95.0).is_nan());
        // Negative NaN bit patterns sort low in the total order; still
        // no panic and a deterministic result.
        let neg_nan = [-f64::NAN, 5.0, 1.0];
        assert_eq!(percentile(&neg_nan, 100.0), 5.0);
    }
}
