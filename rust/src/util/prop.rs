//! Miniature property-testing harness (the vendored crate set has no
//! `proptest`/`quickcheck`).
//!
//! Scope: seeded random-case generation with failure reporting that prints
//! the case index + seed so any failure is reproducible by re-running the
//! same test binary. Shrinking is intentionally out of scope — cases are
//! generated from compact generators, so the failing input is printed
//! whole instead.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries do not receive the workspace's
//! // rpath link flags, so they cannot locate libxla_extension's
//! // libstdc++ at runtime; the same example runs as a unit test below.)
//! use dorafactors::util::prop::{check, prop_assert};
//! check("add commutes", 200, |g| {
//!     let (a, b) = (g.i64_in(-100, 100), g.i64_in(-100, 100));
//!     prop_assert(a + b == b + a, format!("{a} {b}"))
//! });
//! ```

use super::rng::Rng;

/// Per-case generator handle wrapping the PRNG.
pub struct Gen {
    rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn f32_normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        self.rng.normal_vec_f32(n, sigma)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    /// Pick one of the given values.
    pub fn pick<T: Clone>(&mut self, xs: &[T]) -> T {
        xs[self.rng.below(xs.len() as u64) as usize].clone()
    }
}

/// Property outcome: Ok(()) or a message describing the counterexample.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two floats are within tolerance.
pub fn prop_close(a: f64, b: f64, tol: f64, ctx: &str) -> PropResult {
    let diff = (a - b).abs();
    let scale = a.abs().max(b.abs()).max(1.0);
    if diff <= tol * scale {
        Ok(())
    } else {
        Err(format!("{ctx}: |{a} - {b}| = {diff} > {tol}*{scale}"))
    }
}

/// Run `cases` random cases of `prop`. Panics (test failure) on the first
/// counterexample, printing the case index and the base seed.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    check_seeded(name, cases, 0xD0_5E_ED, &mut prop)
}

/// As `check` but with an explicit base seed (used by tests that need
/// distinct corpora).
pub fn check_seeded<F>(name: &str, cases: usize, seed: u64, prop: &mut F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut g = Gen { rng: root.fork(case as u64), case };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (base seed {seed:#x}):\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("counts", 50, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property \"fails\" failed")]
    fn failing_property_panics_with_context() {
        check("fails", 10, |g| {
            let x = g.i64_in(0, 100);
            prop_assert(x < 90, format!("x = {x}"))
        });
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 300, |g| {
            let a = g.usize_in(3, 7);
            let b = g.f64_in(-1.0, 1.0);
            prop_assert((3..=7).contains(&a), format!("a={a}"))?;
            prop_assert((-1.0..1.0).contains(&b), format!("b={b}"))
        });
    }

    #[test]
    fn prop_close_tolerates() {
        assert!(prop_close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(prop_close(1.0, 1.1, 1e-6, "x").is_err());
    }
}
