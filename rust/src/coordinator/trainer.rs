//! Training coordinator: drives the `train_<cfg>_<variant>` artifact
//! from Rust — parameter lifecycle, data feeding, loss/eval logging.
//!
//! Python never runs here. The coordinator:
//!
//! 1. runs the `init_<cfg>` artifact once (seeded, in-graph init) to get
//!    the frozen + trainable leaves;
//! 2. materializes AdamW state as zeros host-side;
//! 3. repeatedly packs `chunk_steps` optimizer steps worth of Markov
//!    corpus into one `train` call — the scan-over-steps artifact — so
//!    the host round-trip amortizes over the chunk;
//! 4. tracks per-step losses, periodic eval losses, and wall time.
//!
//! The trainer runs over any [`ExecBackend`]: the PJRT engine when AOT
//! artifacts are available, the native kernel-registry engine otherwise
//! (`Trainer::new` accepts either via `Into<ExecBackend>`; use
//! `ExecBackend::auto()` for the fallback order). All engine calls go
//! through the typed op surface ([`TrainStepReq`]/[`EvalReq`]) — no
//! artifact-name strings, no positional tensor packing.
//!
//! **Data-parallel training** (`TrainerCfg::train_workers` >= 1): instead
//! of one in-graph [`TrainStepReq`] chunk, each optimizer step splits
//! gradient computation from the update. A [`GradReducer`] shards every
//! batch into contiguous per-worker micro-batches, runs the
//! `loss_and_grads` op concurrently on an [`EnginePool`] of worker
//! engines (adapter parameters replicated behind an `Arc` per request),
//! and reduces the per-sample gradients in fixed sample order via f64
//! accumulators — so the reduced gradient is bitwise-identical for ANY
//! worker count (`tests/train_parallel.rs` pins this; the committed
//! golden trace holds at 1e-6 for workers 1/2/4). AdamW then runs ONCE
//! centrally (`apply_update`), and the updated parameters broadcast to
//! the workers as the next step's request `Arc`.
//! `TrainerCfg::grad_accum = K` accumulates K reduced micro-steps into
//! one update (effective batch `K * train_batch`); checkpoints record
//! the workers/accum/effective-batch provenance.
//!
//! Training runs materialize as **named adapters**: [`Trainer::to_adapter`]
//! snapshots the current leaves, and [`Trainer::set_checkpointing`] writes
//! periodic checkpoints to an [`AdapterStore`] that a *running* server can
//! hot-load ([`Server::hot_load`](super::Server::hot_load)).
//! [`Trainer::from_adapter`] resumes from a stored checkpoint.
//!
//! The convergence experiment (paper §5.9, Table 10 / Figure 12) runs two
//! `Trainer`s (eager + fused variants) from the same seed and data stream
//! and compares their loss trajectories.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::data::MarkovCorpus;
use crate::runtime::ops::{
    parse_variant_spec, reduce_sample_grads, variant_token, AdapterParams, AdapterVariant,
    ApplyUpdateReq, EvalReq, InitReq, OptState, Precision, TrainStepReq, Variant,
};
use crate::runtime::{
    Adapter, AdapterStore, BackendSpec, ConfigInfo, EnginePool, ExecBackend, GradReducer,
    Tensor,
};

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerCfg {
    /// Manifest config name: "tiny" | "small" | "e2e".
    pub config: String,
    /// Variant spec: a kernel token ("eager" | "fused", implying DoRA),
    /// an adapter token ("dora" | "rslora" | "bora", implying the fused
    /// kernel path), or the combined "<kernel>-<adapter>" form
    /// ("eager-rslora"). See [`parse_variant_spec`].
    pub variant: String,
    /// Parameter-init + data seed.
    pub seed: u64,
    /// Markov branching factor (corpus difficulty).
    pub branching: usize,
    /// Evaluate every N steps (0 = never).
    pub eval_every: usize,
    /// Data-parallel gradient workers over an engine pool
    /// (0 = the single-engine in-graph TrainStep path).
    pub train_workers: usize,
    /// Micro-steps accumulated per optimizer update (data-parallel path
    /// only; effective batch = `grad_accum * train_batch`).
    pub grad_accum: usize,
    /// Operating precision: `F32` is the historical full-precision path;
    /// `Bf16` stores/serves weights and activations rounded to bf16
    /// while gradients and AdamW state stay f32 master weights (the
    /// bf16-master-f32 scheme). Init and update ops always run f32.
    pub precision: Precision,
}

impl Default for TrainerCfg {
    fn default() -> Self {
        TrainerCfg {
            config: "small".into(),
            variant: "fused".into(),
            seed: 0,
            branching: 4,
            eval_every: 0,
            train_workers: 0,
            grad_accum: 1,
            precision: Precision::F32,
        }
    }
}

/// One recorded training step.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
}

/// Periodic checkpointing policy: write the adapter to `store` under
/// `name` every `every_steps` optimizer steps.
struct Checkpointing {
    store: AdapterStore,
    name: String,
    every_steps: usize,
}

/// Training run state + history.
pub struct Trainer {
    backend: ExecBackend,
    cfg: TrainerCfg,
    variant: Variant,
    adapter: AdapterVariant,
    info: ConfigInfo,
    corpus: MarkovCorpus,
    /// Frozen + trainable leaves behind one shared handle: engine
    /// requests (train/eval/shard ops) clone the `Arc`, not the
    /// parameters, and the post-step update mutates in place via
    /// `Arc::make_mut` once the workers' request handles are dropped —
    /// so the data-parallel "broadcast" really is a refcount bump.
    params: std::sync::Arc<AdapterParams>,
    /// AdamW moments + step counter.
    opt: OptState,
    pub history: Vec<StepRecord>,
    pub eval_history: Vec<StepRecord>,
    pub wall_seconds: f64,
    /// Held-out eval block, fixed at construction.
    eval_tokens: Tensor,
    /// Worker engine pool of the data-parallel path (None = the
    /// single-engine chunked path).
    pool: Option<EnginePool>,
    ckpt: Option<Checkpointing>,
    /// Checkpoints written by the periodic policy.
    pub checkpoints_written: u64,
    /// Compose backend the kernel registry selects for this config's
    /// training shape (recorded at construction for operational logs).
    pub compose_backend: &'static str,
    pub compose_tier: crate::dispatch::Tier,
}

impl Trainer {
    /// Initialize from the backend's typed init op. Accepts a PJRT
    /// `Engine`, a `NativeEngine`, or an `ExecBackend` directly. For the
    /// data-parallel path (`train_workers` >= 1) the worker pool is
    /// derived from the backend kind; a PJRT backend cannot be
    /// re-described from a connected engine — use [`Trainer::with_spec`].
    pub fn new(backend: impl Into<ExecBackend>, cfg: TrainerCfg) -> Result<Trainer> {
        let backend = backend.into();
        // Cheap validation first: a bad variant must not cost a full
        // parameter init (or a PJRT artifact compile) before erroring.
        parse_variant_spec(&cfg.variant)?;
        let pool = Self::pool_for(&backend, &cfg)?;
        let init = backend
            .init(InitReq {
                config: cfg.config.clone(),
                seed: cfg.seed as i32,
                precision: cfg.precision,
            })
            .with_context(|| format!("initializing config {}", cfg.config))?;
        Self::with_parts(backend, pool, cfg, init.params, 0)
    }

    /// Initialize over a thread-portable backend description — the
    /// general data-parallel constructor (every pool worker reconnects
    /// its own engine from the spec).
    pub fn with_spec(spec: &BackendSpec, cfg: TrainerCfg) -> Result<Trainer> {
        parse_variant_spec(&cfg.variant)?;
        let backend = spec.connect()?;
        let pool = Self::pool_for_spec(spec, &cfg)?;
        let init = backend
            .init(InitReq {
                config: cfg.config.clone(),
                seed: cfg.seed as i32,
                precision: cfg.precision,
            })
            .with_context(|| format!("initializing config {}", cfg.config))?;
        Self::with_parts(backend, pool, cfg, init.params, 0)
    }

    /// The one place a worker pool is built: validates the parallel
    /// config, then starts `train_workers` engines from the description
    /// (None when the config is single-engine). Every constructor —
    /// spec-based or backend-based — funnels through this.
    fn pool_for_spec(spec: &BackendSpec, cfg: &TrainerCfg) -> Result<Option<EnginePool>> {
        Self::validate_parallel_cfg(cfg)?;
        if cfg.train_workers == 0 {
            return Ok(None);
        }
        Ok(Some(EnginePool::start(spec, cfg.train_workers)?))
    }

    /// Data-parallel sanity: accumulation needs at least one gradient
    /// worker, and a zero accumulation factor is meaningless.
    fn validate_parallel_cfg(cfg: &TrainerCfg) -> Result<()> {
        if cfg.grad_accum == 0 {
            bail!("grad_accum must be >= 1");
        }
        if cfg.train_workers == 0 && cfg.grad_accum > 1 {
            bail!(
                "gradient accumulation runs on the data-parallel path; \
                 set train_workers >= 1 (got grad_accum {})",
                cfg.grad_accum
            );
        }
        Ok(())
    }

    /// Derive the worker pool from a connected backend's kind.
    fn pool_for(backend: &ExecBackend, cfg: &TrainerCfg) -> Result<Option<EnginePool>> {
        if cfg.train_workers > 0 {
            let spec = match backend {
                ExecBackend::Native(_) => BackendSpec::Native,
                ExecBackend::Mock(m) => BackendSpec::Mock(m.clone()),
                ExecBackend::Pjrt(_) => bail!(
                    "data-parallel training needs a reconnectable backend description; \
                     construct the trainer with Trainer::with_spec"
                ),
            };
            return Self::pool_for_spec(&spec, cfg);
        }
        Self::pool_for_spec(&BackendSpec::Native, cfg)
    }

    /// Resume from a stored adapter checkpoint: the adapter's leaves and
    /// step counter, fresh optimizer moments (checkpoints carry the model
    /// state, not the AdamW state), and the configured data stream.
    pub fn from_adapter(
        backend: impl Into<ExecBackend>,
        cfg: TrainerCfg,
        adapter: &Adapter,
    ) -> Result<Trainer> {
        Self::check_adapter_config(&cfg, adapter)?;
        let backend = backend.into();
        let pool = Self::pool_for(&backend, &cfg)?;
        Self::with_parts(backend, pool, cfg, adapter.params.clone(), adapter.step)
    }

    /// [`Self::from_adapter`] over a thread-portable backend description
    /// — the resume counterpart of [`Self::with_spec`], so a resumed
    /// data-parallel run constructs exactly like a fresh one (the CLI
    /// `--resume` path uses this).
    pub fn from_adapter_spec(
        spec: &BackendSpec,
        cfg: TrainerCfg,
        adapter: &Adapter,
    ) -> Result<Trainer> {
        Self::check_adapter_config(&cfg, adapter)?;
        let backend = spec.connect()?;
        let pool = Self::pool_for_spec(spec, &cfg)?;
        Self::with_parts(backend, pool, cfg, adapter.params.clone(), adapter.step)
    }

    fn check_adapter_config(cfg: &TrainerCfg, adapter: &Adapter) -> Result<()> {
        if adapter.config != cfg.config {
            bail!(
                "adapter {:?} targets config {:?}, trainer is configured for {:?}",
                adapter.name,
                adapter.config,
                cfg.config
            );
        }
        // Resuming a checkpoint under a different adapter variant would
        // silently train it with the wrong compose math — hard error.
        let (_, adapter_variant) = parse_variant_spec(&cfg.variant)?;
        if adapter.variant != adapter_variant {
            bail!(
                "adapter {:?} was trained as variant {:?}, trainer is configured for {:?}",
                adapter.name,
                adapter.variant.as_str(),
                adapter_variant.as_str()
            );
        }
        // Same guard for precision: a bf16 checkpoint resumed at f32 (or
        // vice versa) would silently change the rounding scheme mid-run.
        // Pre-precision checkpoints decode as f32, so historic resumes
        // under the default config still work.
        if adapter.precision != cfg.precision {
            bail!(
                "adapter {:?} was trained at precision {:?}, trainer is configured for {:?}",
                adapter.name,
                adapter.precision.as_str(),
                cfg.precision.as_str()
            );
        }
        Ok(())
    }

    /// Shared construction tail over explicit parameters.
    fn with_parts(
        backend: ExecBackend,
        pool: Option<EnginePool>,
        cfg: TrainerCfg,
        params: AdapterParams,
        step: i32,
    ) -> Result<Trainer> {
        let (variant, adapter) = parse_variant_spec(&cfg.variant)?;
        let info = backend.config(&cfg.config)?;
        if !params.matches(&info) {
            bail!(
                "config {}: got {}+{} leaves, expected {}+{}",
                info.name,
                params.frozen.len(),
                params.trainable.len(),
                info.frozen.len(),
                info.trainable.len()
            );
        }
        let mut opt = OptState::zeros_like(&params.trainable);
        opt.step = step;
        // Data-parallel runs need the split train ops on every worker
        // (workers reconnect from the same description as `backend`).
        // A backend without them — e.g. a PJRT manifest whose artifacts
        // predate the ops — must fail HERE, not mid-training after the
        // startup cost is paid.
        if pool.is_some() {
            for artifact in [
                format!(
                    "loss_and_grads_{}_{}{}",
                    info.name,
                    variant_token(variant, adapter),
                    cfg.precision.token_suffix()
                ),
                format!("apply_update_{}", info.name),
            ] {
                backend.ensure_artifact(&artifact).with_context(|| {
                    format!(
                        "data-parallel training needs the {artifact:?} op, \
                         which this backend does not provide"
                    )
                })?;
            }
        }
        // Data stream: seeded identically across variants so eager/fused
        // see the same batches (the §5.9 controlled setup).
        let mut corpus = MarkovCorpus::new(info.vocab, cfg.branching, cfg.seed ^ 0xDA7A);
        let eval_bs = info.train_batch;
        let eval_tokens = Tensor::i32(
            vec![eval_bs, info.seq + 1],
            corpus.block(1, eval_bs, info.seq + 1),
        );
        // Resuming from step N: fast-forward the stream past the blocks
        // the original run already consumed, so a resumed run continues
        // on fresh data exactly where an uninterrupted run would be. The
        // consumption granularity differs by path: the single-engine
        // path draws one chunk per engine call, the data-parallel path
        // draws `grad_accum` micro-batches per optimizer step.
        if pool.is_some() {
            for _ in 0..step.max(0) as usize {
                let _ = corpus.block(cfg.grad_accum, info.train_batch, info.seq + 1);
            }
        } else {
            for _ in 0..(step.max(0) as usize / info.chunk_steps) {
                let _ = corpus.block(info.chunk_steps, info.train_batch, info.seq + 1);
            }
        }
        // Operational log: the compose plan actually in effect. The
        // native engine forces the variant's tiers (the variant IS the
        // numeric path); PJRT records the registry's auto plan.
        let plan = match &backend {
            ExecBackend::Pjrt(_) => super::compose_plan(&info, true),
            _ => crate::models::forward::kernels_for(variant, &info, true)?.choice,
        };
        Ok(Trainer {
            backend,
            cfg,
            variant,
            adapter,
            info,
            corpus,
            params: std::sync::Arc::new(params),
            opt,
            history: Vec::new(),
            eval_history: Vec::new(),
            wall_seconds: 0.0,
            eval_tokens,
            pool,
            ckpt: None,
            checkpoints_written: 0,
            compose_backend: plan.backend.name(),
            compose_tier: plan.tier,
        })
    }

    /// Trainer over the default execution backend (PJRT artifacts when
    /// usable, the native engine otherwise). Data-parallel configs go
    /// through the spec path so PJRT backends get a reconnectable pool.
    pub fn auto(cfg: TrainerCfg) -> Result<Trainer> {
        if cfg.train_workers > 0 {
            Self::with_spec(&BackendSpec::auto(), cfg)
        } else {
            Self::new(ExecBackend::auto(), cfg)
        }
    }

    /// Data-parallel gradient workers in use (0 = single-engine path).
    pub fn train_workers(&self) -> usize {
        self.pool.as_ref().map(|p| p.size()).unwrap_or(0)
    }

    pub fn config_info(&self) -> &ConfigInfo {
        &self.info
    }

    /// Which execution backend this trainer runs on ("pjrt"/"native").
    pub fn backend_kind(&self) -> &'static str {
        self.backend.kind_name()
    }

    pub fn step_count(&self) -> usize {
        self.opt.step as usize
    }

    /// Borrow the current trainable leaves (for the serving handoff).
    pub fn trainable(&self) -> &[Tensor] {
        &self.params.trainable
    }

    pub fn frozen(&self) -> &[Tensor] {
        &self.params.frozen
    }

    /// Snapshot the current parameters as a named adapter (the trainer →
    /// store → server unit of exchange). Checkpoints record the run's
    /// effective-batch provenance: gradient workers, accumulation factor,
    /// and the effective batch size in sequences.
    pub fn to_adapter(&self, name: &str) -> Result<Adapter> {
        let workers = self.pool.as_ref().map(|p| p.size()).unwrap_or(1) as u32;
        let accum = self.cfg.grad_accum.max(1) as u32;
        Ok(Adapter::new(
            name,
            &self.info,
            self.cfg.seed,
            self.opt.step,
            (*self.params).clone(),
        )?
        .with_provenance(workers, accum, accum * self.info.train_batch as u32)
        .with_variant(self.adapter)
        .with_precision(self.cfg.precision))
    }

    /// Write the adapter to `store` under `name` every `every_steps`
    /// optimizer steps (checked at chunk boundaries — the chunk is the
    /// engine-call granularity). A running server hot-loads these with
    /// [`Server::hot_load`](super::Server::hot_load).
    pub fn set_checkpointing(
        &mut self,
        store: AdapterStore,
        name: impl Into<String>,
        every_steps: usize,
    ) -> Result<()> {
        if every_steps == 0 {
            bail!("checkpoint interval must be > 0 steps");
        }
        let name = name.into();
        crate::runtime::adapters::validate_name(&name)?;
        self.ckpt = Some(Checkpointing { store, name, every_steps });
        Ok(())
    }

    /// Run one chunk: `chunk_steps` optimizer steps — in-graph through
    /// one TrainStep call on the single-engine path, or step by step
    /// through the pool's shard/reduce/update cycle on the data-parallel
    /// path.
    pub fn run_chunk(&mut self) -> Result<&[StepRecord]> {
        if self.pool.is_some() {
            return self.run_chunk_parallel();
        }
        let k = self.info.chunk_steps;
        let bs = self.info.train_batch;
        let seq1 = self.info.seq + 1;
        let tokens = Tensor::i32(vec![k, bs, seq1], self.corpus.block(k, bs, seq1));

        let prev_step = self.opt.step;
        let req = TrainStepReq {
            config: self.cfg.config.clone(),
            variant: self.variant,
            adapter: self.adapter,
            precision: self.cfg.precision,
            params: self.params.clone(),
            opt: self.opt.clone(),
            tokens,
        };
        let t0 = Instant::now();
        let resp = self.backend.train_step(req)?;
        self.wall_seconds += t0.elapsed().as_secs_f64();

        // The engine dropped its request handle, so this mutates the
        // shared parameters in place (no frozen-leaf copy).
        std::sync::Arc::make_mut(&mut self.params).trainable = resp.trainable;
        self.opt = resp.opt;
        let losses = resp.losses;

        let first = self.history.len();
        let base_step = self.opt.step as usize - losses.len();
        for (i, &loss) in losses.iter().enumerate() {
            self.history.push(StepRecord { step: base_step + i + 1, loss });
        }
        self.chunk_tail(prev_step)?;
        Ok(&self.history[first..])
    }

    /// The data-parallel chunk: per optimizer step, draw `grad_accum`
    /// micro-batches, shard each over the pool, reduce the per-sample
    /// gradients deterministically, and apply ONE central AdamW update.
    /// The updated parameters broadcast to the workers as the next
    /// step's request `Arc` (engines are stateless; replication is the
    /// refcount, not a copy).
    fn run_chunk_parallel(&mut self) -> Result<&[StepRecord]> {
        let k = self.info.chunk_steps;
        let bs = self.info.train_batch;
        let seq1 = self.info.seq + 1;
        let accum = self.cfg.grad_accum;
        let total_rows = accum * bs * self.info.seq;
        let reducer = GradReducer::new(
            self.cfg.config.clone(),
            self.variant,
            self.adapter,
            self.cfg.precision,
        );
        let prev_step = self.opt.step;
        let first = self.history.len();
        for _ in 0..k {
            let micro = self.corpus.block(accum, bs, seq1);
            let t0 = Instant::now();
            let mut samples = Vec::with_capacity(accum * bs);
            for a in 0..accum {
                let tokens = Tensor::i32(
                    vec![bs, seq1],
                    micro[a * bs * seq1..(a + 1) * bs * seq1].to_vec(),
                );
                let pool = self.pool.as_ref().expect("parallel chunk has a pool");
                samples.extend(reducer.sample_grads(pool, &self.params, &tokens, total_rows)?);
            }
            let (loss, grads) = reduce_sample_grads(&samples, total_rows)?;
            let resp = self.backend.apply_update(ApplyUpdateReq {
                config: self.cfg.config.clone(),
                trainable: self.params.trainable.clone(),
                opt: self.opt.clone(),
                grads,
            })?;
            self.wall_seconds += t0.elapsed().as_secs_f64();
            // Every shard request dropped its `Arc` when its job
            // finished, so the update mutates the shared parameters in
            // place — the broadcast to the next step's workers is the
            // refcount bump on `self.params`, never a frozen-leaf copy.
            std::sync::Arc::make_mut(&mut self.params).trainable = resp.trainable;
            self.opt = resp.opt;
            self.history.push(StepRecord { step: self.opt.step as usize, loss });
        }
        self.chunk_tail(prev_step)?;
        Ok(&self.history[first..])
    }

    /// Shared end-of-chunk bookkeeping: periodic eval and checkpoints
    /// (fired when this chunk crossed an interval boundary).
    fn chunk_tail(&mut self, prev_step: i32) -> Result<()> {
        if self.cfg.eval_every > 0 && self.opt.step as usize % self.cfg.eval_every == 0 {
            let loss = self.eval()?;
            self.eval_history.push(StepRecord { step: self.opt.step as usize, loss });
        }
        if let Some(c) = &self.ckpt {
            let every = c.every_steps as i32;
            if self.opt.step / every > prev_step / every {
                let adapter = self.to_adapter(&c.name)?;
                c.store
                    .save(&adapter)
                    .with_context(|| format!("checkpointing adapter {:?}", c.name))?;
                self.checkpoints_written += 1;
            }
        }
        Ok(())
    }

    /// Train until at least `steps` optimizer steps have run.
    pub fn train_steps(&mut self, steps: usize) -> Result<()> {
        while (self.opt.step as usize) < steps {
            self.run_chunk()?;
        }
        Ok(())
    }

    /// Held-out eval loss via the typed eval op.
    pub fn eval(&self) -> Result<f32> {
        let resp = self.backend.eval(EvalReq {
            config: self.cfg.config.clone(),
            variant: self.variant,
            adapter: self.adapter,
            precision: self.cfg.precision,
            params: self.params.clone(),
            tokens: self.eval_tokens.clone(),
        })?;
        Ok(resp.loss)
    }

    /// Mean |Δloss| between two runs' histories (Table 10's metric).
    pub fn loss_delta(a: &Trainer, b: &Trainer) -> (f64, f64) {
        let n = a.history.len().min(b.history.len());
        let mut sum = 0.0f64;
        let mut max = 0.0f64;
        for i in 0..n {
            let d = (a.history[i].loss as f64 - b.history[i].loss as f64).abs();
            sum += d;
            max = max.max(d);
        }
        (sum / n.max(1) as f64, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::default_dir;
    use crate::runtime::{Engine, NativeEngine};

    fn engine() -> Option<Engine> {
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            Some(Engine::load(&dir).unwrap())
        } else {
            None
        }
    }

    fn tiny(variant: &str, seed: u64) -> TrainerCfg {
        TrainerCfg {
            config: "tiny".into(),
            variant: variant.into(),
            seed,
            branching: 3,
            eval_every: 0,
            train_workers: 0,
            grad_accum: 1,
            precision: Precision::F32,
        }
    }

    fn tiny_dp(seed: u64, workers: usize, accum: usize) -> TrainerCfg {
        TrainerCfg { train_workers: workers, grad_accum: accum, ..tiny("fused", seed) }
    }

    // --- Native-engine tests: run unconditionally (no artifact gating) ---

    #[test]
    fn native_init_and_one_chunk() {
        let mut tr = Trainer::new(NativeEngine::new(), tiny("eager", 1)).unwrap();
        assert_eq!(tr.backend_kind(), "native");
        let recs = tr.run_chunk().unwrap().to_vec();
        assert_eq!(recs.len(), tr.config_info().chunk_steps);
        assert!(recs.iter().all(|r| r.loss.is_finite() && r.loss > 0.0));
        assert_eq!(tr.step_count(), tr.config_info().chunk_steps);
    }

    #[test]
    fn native_loss_decreases_over_chunks() {
        let mut tr = Trainer::new(NativeEngine::new(), tiny("fused", 2)).unwrap();
        tr.train_steps(32).unwrap();
        let first = tr.history.first().unwrap().loss;
        let last_avg: f32 =
            tr.history.iter().rev().take(4).map(|r| r.loss).sum::<f32>() / 4.0;
        assert!(last_avg < first, "no learning: first {first}, last-4 avg {last_avg}");
    }

    #[test]
    fn native_eager_fused_convergence_parity() {
        // The §5.9 acceptance criterion on the native engine: same seed
        // + data through both numeric paths, per-step losses within 1e-3.
        let mut a = Trainer::new(NativeEngine::new(), tiny("eager", 3)).unwrap();
        let mut b = Trainer::new(NativeEngine::new(), tiny("fused", 3)).unwrap();
        a.train_steps(8).unwrap();
        b.train_steps(8).unwrap();
        assert_eq!(a.history.len(), b.history.len());
        let (mean, max) = Trainer::loss_delta(&a, &b);
        assert!(mean < 1e-3, "mean |dloss| {mean}");
        assert!(max < 1e-3, "max |dloss| {max}");
        // Eval agrees across paths too.
        let ea = a.eval().unwrap();
        let eb = b.eval().unwrap();
        assert!((ea - eb).abs() < 1e-3, "eval {ea} vs {eb}");
    }

    #[test]
    fn native_seeds_produce_different_runs() {
        let mut a = Trainer::new(NativeEngine::new(), tiny("eager", 4)).unwrap();
        let mut b = Trainer::new(NativeEngine::new(), tiny("eager", 5)).unwrap();
        a.run_chunk().unwrap();
        b.run_chunk().unwrap();
        assert_ne!(a.history[0].loss, b.history[0].loss);
    }

    #[test]
    fn native_eval_runs_and_is_deterministic() {
        let tr = Trainer::new(NativeEngine::new(), tiny("fused", 6)).unwrap();
        let l1 = tr.eval().unwrap();
        let l2 = tr.eval().unwrap();
        assert!(l1.is_finite() && l1 > 0.0);
        assert_eq!(l1, l2);
    }

    #[test]
    fn native_trainer_rejects_bad_config_and_variant() {
        assert!(Trainer::new(NativeEngine::new(), tiny("nope", 0)).is_err());
        let cfg = TrainerCfg { config: "missing".into(), ..tiny("fused", 0) };
        assert!(Trainer::new(NativeEngine::new(), cfg).is_err());
    }

    #[test]
    fn periodic_checkpoints_write_and_resume() {
        use crate::runtime::AdapterStore;
        let dir = std::env::temp_dir()
            .join(format!("dora_trainer_ckpt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::open(&dir).unwrap();

        let mut tr = Trainer::new(NativeEngine::new(), tiny("fused", 17)).unwrap();
        tr.set_checkpointing(store.clone(), "job-a", 4).unwrap();
        assert!(tr.set_checkpointing(store.clone(), "bad name!", 4).is_err());
        assert!(tr.set_checkpointing(store.clone(), "x", 0).is_err());
        tr.train_steps(8).unwrap(); // tiny chunk = 4 steps -> 2 checkpoints
        assert_eq!(tr.checkpoints_written, 2);

        let stored = store.load("job-a").unwrap();
        assert_eq!(stored.config, "tiny");
        assert_eq!(stored.step, 8);
        // The stored leaves are the trainer's current leaves, bitwise.
        for (a, b) in stored.params.trainable.iter().zip(tr.trainable()) {
            assert!(a.bitwise_eq(b));
        }

        // Resume: picks up leaves + step, trains further.
        let mut resumed =
            Trainer::from_adapter(NativeEngine::new(), tiny("fused", 17), &stored).unwrap();
        assert_eq!(resumed.step_count(), 8);
        resumed.train_steps(12).unwrap();
        assert_eq!(resumed.step_count(), 12);
        // Config mismatch is rejected.
        let cfg = TrainerCfg { config: "small".into(), ..tiny("fused", 17) };
        assert!(Trainer::from_adapter(NativeEngine::new(), cfg, &stored).is_err());

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_fast_forwards_the_data_stream() {
        // A resumed run must NOT replay the corpus blocks the original
        // run already consumed. Same leaves + same kernels + different
        // data => different first-chunk losses; a resume that restarted
        // the stream would reproduce the fresh run's losses exactly.
        let fresh = Trainer::new(NativeEngine::new(), tiny("fused", 23)).unwrap();
        let mut adapter = fresh.to_adapter("ff").unwrap();
        let k = fresh.config_info().chunk_steps;
        adapter.step = k as i32; // pretend one chunk was already trained
        let mut from_start = Trainer::new(NativeEngine::new(), tiny("fused", 23)).unwrap();
        let mut resumed =
            Trainer::from_adapter(NativeEngine::new(), tiny("fused", 23), &adapter).unwrap();
        from_start.run_chunk().unwrap();
        resumed.run_chunk().unwrap();
        assert_eq!(resumed.step_count(), 2 * k);
        assert_ne!(
            from_start.history[0].loss, resumed.history[0].loss,
            "resumed run replayed the original run's first data block"
        );
    }

    #[test]
    fn adapter_variants_train_and_the_resume_guard_holds() {
        // rsLoRA through the combined "<kernel>-<adapter>" spec.
        let mut rs = Trainer::new(NativeEngine::new(), tiny("fused-rslora", 9)).unwrap();
        rs.run_chunk().unwrap();
        assert!(rs.history.iter().all(|r| r.loss.is_finite() && r.loss > 0.0));
        let a = rs.to_adapter("rs").unwrap();
        assert_eq!(a.variant, AdapterVariant::RsLora);
        // Resuming under the matching variant works; a mismatch bails
        // before any training step runs.
        assert!(
            Trainer::from_adapter(NativeEngine::new(), tiny("fused-rslora", 9), &a).is_ok()
        );
        let err =
            Trainer::from_adapter(NativeEngine::new(), tiny("fused", 9), &a).unwrap_err();
        assert!(format!("{err:#}").contains("variant"), "{err:#}");
        // A bare adapter token implies the fused kernel path; BoRA's
        // column-normalized compose trains to finite losses too.
        let mut bo = Trainer::new(NativeEngine::new(), tiny("bora", 10)).unwrap();
        bo.run_chunk().unwrap();
        assert!(bo.history.iter().all(|r| r.loss.is_finite() && r.loss > 0.0));

        // The data-parallel path threads the adapter variant through the
        // shard requests: a 2-worker rsLoRA run tracks the single-engine
        // rsLoRA run within the reduction's reassociation tolerance.
        let mut dp = Trainer::new(
            NativeEngine::new(),
            TrainerCfg { train_workers: 2, ..tiny("fused-rslora", 9) },
        )
        .unwrap();
        dp.train_steps(rs.step_count()).unwrap();
        let (mean, max) = Trainer::loss_delta(&dp, &rs);
        assert!(mean < 1e-5, "mean |dloss| {mean}");
        assert!(max < 1e-5, "max |dloss| {max}");
    }

    #[test]
    fn bf16_trains_stamps_checkpoints_and_the_resume_guard_holds() {
        let bf16 = |seed| TrainerCfg { precision: Precision::Bf16, ..tiny("fused", seed) };
        // bf16-master-f32 training runs to finite positive losses.
        let mut tr = Trainer::new(NativeEngine::new(), bf16(11)).unwrap();
        tr.train_steps(8).unwrap();
        assert!(tr.history.iter().all(|r| r.loss.is_finite() && r.loss > 0.0));
        // bf16 rounds the forward trace, so its trajectory differs from
        // f32 — but stays close (the master weights are f32).
        let mut full = Trainer::new(NativeEngine::new(), tiny("fused", 11)).unwrap();
        full.train_steps(8).unwrap();
        let (mean, _max) = Trainer::loss_delta(&tr, &full);
        assert!(mean < 0.1, "bf16 diverged from f32: mean |dloss| {mean}");
        // Checkpoints record the operating precision.
        let a = tr.to_adapter("half").unwrap();
        assert_eq!(a.precision, Precision::Bf16);
        // Resuming at the matching precision works; a mismatch bails
        // before any training step runs.
        assert!(Trainer::from_adapter(NativeEngine::new(), bf16(11), &a).is_ok());
        let err =
            Trainer::from_adapter(NativeEngine::new(), tiny("fused", 11), &a).unwrap_err();
        assert!(format!("{err:#}").contains("precision"), "{err:#}");
        // And the f32 checkpoint can't be resumed as bf16 either.
        let f = full.to_adapter("full").unwrap();
        assert!(Trainer::from_adapter(NativeEngine::new(), bf16(11), &f).is_err());
        // bf16 run-to-run determinism: same cfg, bitwise-equal leaves.
        let mut again = Trainer::new(NativeEngine::new(), bf16(11)).unwrap();
        again.train_steps(8).unwrap();
        for (x, y) in tr.trainable().iter().zip(again.trainable()) {
            assert!(x.bitwise_eq(y), "bf16 training is not run-to-run deterministic");
        }
    }

    // --- Data-parallel path (native pool; unconditional) ---

    #[test]
    fn parallel_trainer_learns_and_tracks_the_single_engine_path() {
        let mut dp = Trainer::new(NativeEngine::new(), tiny_dp(31, 2, 1)).unwrap();
        assert_eq!(dp.train_workers(), 2);
        let mut legacy = Trainer::new(NativeEngine::new(), tiny("fused", 31)).unwrap();
        assert_eq!(legacy.train_workers(), 0);
        dp.train_steps(16).unwrap();
        legacy.train_steps(16).unwrap();
        assert_eq!(dp.history.len(), legacy.history.len());
        // Same seed + same data stream: the split/reduce path differs
        // from the in-graph chunk only by the per-sample reduction's
        // reassociation.
        let (mean, max) = Trainer::loss_delta(&dp, &legacy);
        assert!(mean < 1e-5, "mean |dloss| {mean}");
        assert!(max < 1e-5, "max |dloss| {max}");
        // And it actually learns.
        let first = dp.history.first().unwrap().loss;
        let last4: f32 = dp.history.iter().rev().take(4).map(|r| r.loss).sum::<f32>() / 4.0;
        assert!(last4 < first, "no learning: first {first}, last-4 {last4}");
    }

    #[test]
    fn parallel_trainer_accumulates_large_effective_batches() {
        let mut tr = Trainer::new(NativeEngine::new(), tiny_dp(5, 2, 4)).unwrap();
        tr.train_steps(8).unwrap();
        assert_eq!(tr.step_count(), 8);
        assert_eq!(tr.history.len(), 8);
        assert!(tr.history.iter().all(|r| r.loss.is_finite() && r.loss > 0.0));
        // Checkpoints record the effective-batch provenance.
        let a = tr.to_adapter("dp").unwrap();
        assert_eq!(a.train_workers, 2);
        assert_eq!(a.grad_accum, 4);
        assert_eq!(a.effective_batch as usize, 4 * tr.config_info().train_batch);
    }

    #[test]
    fn parallel_cfg_validation() {
        // Accumulation without workers is a config error, not silence.
        let err = Trainer::new(NativeEngine::new(), tiny_dp(0, 0, 2)).unwrap_err();
        assert!(format!("{err:#}").contains("data-parallel"), "{err:#}");
        // A zero accumulation factor is rejected.
        assert!(Trainer::new(NativeEngine::new(), tiny_dp(0, 2, 0)).is_err());
        // The spec constructor enforces the same rules.
        assert!(Trainer::with_spec(&crate::runtime::BackendSpec::Native, tiny_dp(0, 1, 0))
            .is_err());
    }

    #[test]
    fn parallel_resume_fast_forwards_the_data_stream() {
        // Same protocol as the single-engine resume test: a resumed DP
        // run must continue the stream, not replay it — and the DP
        // consumption granularity (accum micro-batches per step) must be
        // what the fast-forward replays.
        let fresh = Trainer::new(NativeEngine::new(), tiny_dp(23, 2, 2)).unwrap();
        let mut adapter = fresh.to_adapter("ff-dp").unwrap();
        let k = fresh.config_info().chunk_steps;
        adapter.step = k as i32; // pretend one chunk was already trained
        let mut from_start = Trainer::new(NativeEngine::new(), tiny_dp(23, 2, 2)).unwrap();
        // The spec-based resume constructor (what the CLI --resume uses).
        let mut resumed = Trainer::from_adapter_spec(
            &crate::runtime::BackendSpec::Native,
            tiny_dp(23, 2, 2),
            &adapter,
        )
        .unwrap();
        assert_eq!(resumed.train_workers(), 2);
        from_start.run_chunk().unwrap();
        resumed.run_chunk().unwrap();
        assert_eq!(resumed.step_count(), 2 * k);
        assert_ne!(
            from_start.history[0].loss, resumed.history[0].loss,
            "resumed DP run replayed the original run's first data block"
        );
    }

    // --- PJRT-gated variants (skip without `make artifacts`) ---

    #[test]
    fn init_and_one_chunk() {
        let Some(eng) = engine() else { return };
        let mut tr = Trainer::new(eng, tiny("eager", 1)).unwrap();
        let recs = tr.run_chunk().unwrap().to_vec();
        assert_eq!(recs.len(), tr.config_info().chunk_steps);
        assert!(recs.iter().all(|r| r.loss.is_finite() && r.loss > 0.0));
        assert_eq!(tr.step_count(), tr.config_info().chunk_steps);
    }

    #[test]
    fn loss_decreases_over_chunks() {
        let Some(eng) = engine() else { return };
        let mut tr = Trainer::new(eng, tiny("eager", 2)).unwrap();
        tr.train_steps(16).unwrap();
        let first = tr.history.first().unwrap().loss;
        let last_avg: f32 = tr.history.iter().rev().take(4).map(|r| r.loss).sum::<f32>() / 4.0;
        assert!(
            last_avg < first,
            "no learning: first {first}, last-4 avg {last_avg}"
        );
    }

    #[test]
    fn eager_fused_convergence_equivalence_tiny() {
        // Table 10 in miniature: same seed + data, two numeric paths.
        let Some(eng) = engine() else { return };
        let mut a = Trainer::new(eng.clone(), tiny("eager", 3)).unwrap();
        let mut b = Trainer::new(eng, tiny("fused", 3)).unwrap();
        a.train_steps(8).unwrap();
        b.train_steps(8).unwrap();
        let (mean, max) = Trainer::loss_delta(&a, &b);
        assert!(mean < 1e-4, "mean |dloss| {mean}");
        assert!(max < 1e-3, "max |dloss| {max}");
    }

    #[test]
    fn seeds_produce_different_runs() {
        let Some(eng) = engine() else { return };
        let mut a = Trainer::new(eng.clone(), tiny("eager", 4)).unwrap();
        let mut b = Trainer::new(eng, tiny("eager", 5)).unwrap();
        a.run_chunk().unwrap();
        b.run_chunk().unwrap();
        assert_ne!(a.history[0].loss, b.history[0].loss);
    }

    #[test]
    fn eval_runs() {
        let Some(eng) = engine() else { return };
        let tr = Trainer::new(eng, tiny("fused", 6)).unwrap();
        let loss = tr.eval().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
    }
}
