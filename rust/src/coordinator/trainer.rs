//! Training coordinator: drives the `train_<cfg>_<variant>` artifact
//! from Rust — parameter lifecycle, data feeding, loss/eval logging.
//!
//! Python never runs here. The coordinator:
//!
//! 1. runs the `init_<cfg>` artifact once (seeded, in-graph init) to get
//!    the frozen + trainable leaves;
//! 2. materializes AdamW state as zeros host-side;
//! 3. repeatedly packs `chunk_steps` optimizer steps worth of Markov
//!    corpus into one `train` call — the scan-over-steps artifact — so
//!    the host round-trip amortizes over the chunk;
//! 4. tracks per-step losses, periodic eval losses, and wall time.
//!
//! The trainer runs over any [`ExecBackend`]: the PJRT engine when AOT
//! artifacts are available, the native kernel-registry engine otherwise
//! (`Trainer::new` accepts either via `Into<ExecBackend>`; use
//! `ExecBackend::auto()` for the fallback order).
//!
//! The convergence experiment (paper §5.9, Table 10 / Figure 12) runs two
//! `Trainer`s (eager + fused variants) from the same seed and data stream
//! and compares their loss trajectories.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::data::MarkovCorpus;
use crate::runtime::{ConfigInfo, ExecBackend, Tensor};

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerCfg {
    /// Manifest config name: "tiny" | "small" | "e2e".
    pub config: String,
    /// Variant: "eager" | "fused".
    pub variant: String,
    /// Parameter-init + data seed.
    pub seed: u64,
    /// Markov branching factor (corpus difficulty).
    pub branching: usize,
    /// Evaluate every N steps (0 = never).
    pub eval_every: usize,
}

impl Default for TrainerCfg {
    fn default() -> Self {
        TrainerCfg {
            config: "small".into(),
            variant: "fused".into(),
            seed: 0,
            branching: 4,
            eval_every: 0,
        }
    }
}

/// One recorded training step.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
}

/// Training run state + history.
pub struct Trainer {
    backend: ExecBackend,
    cfg: TrainerCfg,
    info: ConfigInfo,
    corpus: MarkovCorpus,
    /// Frozen leaves (constant across steps).
    frozen: Vec<Tensor>,
    /// Trainable leaves + AdamW moments.
    trainable: Vec<Tensor>,
    m1: Vec<Tensor>,
    m2: Vec<Tensor>,
    step: i32,
    pub history: Vec<StepRecord>,
    pub eval_history: Vec<StepRecord>,
    pub wall_seconds: f64,
    /// Held-out eval block, fixed at construction.
    eval_tokens: Tensor,
    /// Compose backend the kernel registry selects for this config's
    /// training shape (recorded at construction for operational logs).
    pub compose_backend: &'static str,
    pub compose_tier: crate::dispatch::Tier,
}

impl Trainer {
    /// Initialize from the backend's init artifact. Accepts a PJRT
    /// `Engine`, a `NativeEngine`, or an `ExecBackend` directly.
    pub fn new(backend: impl Into<ExecBackend>, cfg: TrainerCfg) -> Result<Trainer> {
        let backend = backend.into();
        let info = backend.config(&cfg.config)?;
        if !["eager", "fused"].contains(&cfg.variant.as_str()) {
            bail!("variant must be eager|fused, got {:?}", cfg.variant);
        }
        let init_name = format!("init_{}", cfg.config);
        let outs = backend
            .run(&init_name, &[Tensor::scalar_i32(cfg.seed as i32)])
            .with_context(|| format!("running {init_name}"))?;
        let nf = info.frozen.len();
        let nt = info.trainable.len();
        if outs.len() != nf + nt {
            bail!("init returned {} leaves, expected {}", outs.len(), nf + nt);
        }
        let frozen = outs[..nf].to_vec();
        let trainable = outs[nf..].to_vec();
        let zeros = |ts: &[Tensor]| -> Vec<Tensor> {
            ts.iter()
                .map(|t| Tensor::f32(t.shape.clone(), vec![0.0; t.elems()]))
                .collect()
        };
        let m1 = zeros(&trainable);
        let m2 = zeros(&trainable);
        // Data stream: seeded identically across variants so eager/fused
        // see the same batches (the §5.9 controlled setup).
        let mut corpus = MarkovCorpus::new(info.vocab, cfg.branching, cfg.seed ^ 0xDA7A);
        let eval_bs = info.train_batch;
        let eval_tokens = Tensor::i32(
            vec![eval_bs, info.seq + 1],
            corpus.block(1, eval_bs, info.seq + 1),
        );
        // Operational log: the compose plan actually in effect. The
        // native engine forces the variant's tiers (the variant IS the
        // numeric path); PJRT records the registry's auto plan.
        let plan = match &backend {
            ExecBackend::Pjrt(_) => super::compose_plan(&info, true),
            _ => crate::models::forward::variant_kernels(&cfg.variant, &info, true)?.choice,
        };
        Ok(Trainer {
            backend,
            cfg,
            info,
            corpus,
            frozen,
            trainable,
            m1,
            m2,
            step: 0,
            history: Vec::new(),
            eval_history: Vec::new(),
            wall_seconds: 0.0,
            eval_tokens,
            compose_backend: plan.backend.name(),
            compose_tier: plan.tier,
        })
    }

    /// Trainer over the default execution backend (PJRT artifacts when
    /// usable, the native engine otherwise).
    pub fn auto(cfg: TrainerCfg) -> Result<Trainer> {
        Self::new(ExecBackend::auto(), cfg)
    }

    pub fn config_info(&self) -> &ConfigInfo {
        &self.info
    }

    /// Which execution backend this trainer runs on ("pjrt"/"native").
    pub fn backend_kind(&self) -> &'static str {
        self.backend.kind_name()
    }

    pub fn step_count(&self) -> usize {
        self.step as usize
    }

    /// Borrow the current trainable leaves (for the serving handoff).
    pub fn trainable(&self) -> &[Tensor] {
        &self.trainable
    }

    pub fn frozen(&self) -> &[Tensor] {
        &self.frozen
    }

    fn train_artifact(&self) -> String {
        format!("train_{}_{}", self.cfg.config, self.cfg.variant)
    }

    /// Run one chunk (`chunk_steps` optimizer steps in-graph).
    pub fn run_chunk(&mut self) -> Result<&[StepRecord]> {
        let k = self.info.chunk_steps;
        let bs = self.info.train_batch;
        let seq1 = self.info.seq + 1;
        let tokens = Tensor::i32(vec![k, bs, seq1], self.corpus.block(k, bs, seq1));

        let mut inputs = Vec::with_capacity(
            self.frozen.len() + 3 * self.trainable.len() + 2,
        );
        inputs.extend(self.frozen.iter().cloned());
        inputs.extend(self.trainable.iter().cloned());
        inputs.extend(self.m1.iter().cloned());
        inputs.extend(self.m2.iter().cloned());
        inputs.push(Tensor::scalar_i32(self.step));
        inputs.push(tokens);

        let t0 = Instant::now();
        let outs = self.backend.run(&self.train_artifact(), &inputs)?;
        self.wall_seconds += t0.elapsed().as_secs_f64();

        let nt = self.trainable.len();
        if outs.len() != 3 * nt + 2 {
            bail!(
                "train artifact returned {} outputs, expected {}",
                outs.len(),
                3 * nt + 2
            );
        }
        self.trainable = outs[..nt].to_vec();
        self.m1 = outs[nt..2 * nt].to_vec();
        self.m2 = outs[2 * nt..3 * nt].to_vec();
        self.step = *outs[3 * nt]
            .as_i32()?
            .first()
            .context("train artifact returned an empty step counter")?;
        let losses = outs[3 * nt + 1].as_f32()?;

        let first = self.history.len();
        let base_step = self.step as usize - losses.len();
        for (i, &loss) in losses.iter().enumerate() {
            self.history.push(StepRecord { step: base_step + i + 1, loss });
        }
        if self.cfg.eval_every > 0 && self.step as usize % self.cfg.eval_every == 0 {
            let loss = self.eval()?;
            self.eval_history.push(StepRecord { step: self.step as usize, loss });
        }
        Ok(&self.history[first..])
    }

    /// Train until at least `steps` optimizer steps have run.
    pub fn train_steps(&mut self, steps: usize) -> Result<()> {
        while (self.step as usize) < steps {
            self.run_chunk()?;
        }
        Ok(())
    }

    /// Held-out eval loss via the eval artifact.
    pub fn eval(&self) -> Result<f32> {
        let name = format!("eval_{}_{}", self.cfg.config, self.cfg.variant);
        let mut inputs: Vec<Tensor> = Vec::new();
        inputs.extend(self.frozen.iter().cloned());
        inputs.extend(self.trainable.iter().cloned());
        inputs.push(self.eval_tokens.clone());
        let outs = self.backend.run(&name, &inputs)?;
        outs.first()
            .context("eval artifact returned no outputs")?
            .scalar_f32()
    }

    /// Mean |Δloss| between two runs' histories (Table 10's metric).
    pub fn loss_delta(a: &Trainer, b: &Trainer) -> (f64, f64) {
        let n = a.history.len().min(b.history.len());
        let mut sum = 0.0f64;
        let mut max = 0.0f64;
        for i in 0..n {
            let d = (a.history[i].loss as f64 - b.history[i].loss as f64).abs();
            sum += d;
            max = max.max(d);
        }
        (sum / n.max(1) as f64, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::default_dir;
    use crate::runtime::{Engine, NativeEngine};

    fn engine() -> Option<Engine> {
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            Some(Engine::load(&dir).unwrap())
        } else {
            None
        }
    }

    fn tiny(variant: &str, seed: u64) -> TrainerCfg {
        TrainerCfg {
            config: "tiny".into(),
            variant: variant.into(),
            seed,
            branching: 3,
            eval_every: 0,
        }
    }

    // --- Native-engine tests: run unconditionally (no artifact gating) ---

    #[test]
    fn native_init_and_one_chunk() {
        let mut tr = Trainer::new(NativeEngine::new(), tiny("eager", 1)).unwrap();
        assert_eq!(tr.backend_kind(), "native");
        let recs = tr.run_chunk().unwrap().to_vec();
        assert_eq!(recs.len(), tr.config_info().chunk_steps);
        assert!(recs.iter().all(|r| r.loss.is_finite() && r.loss > 0.0));
        assert_eq!(tr.step_count(), tr.config_info().chunk_steps);
    }

    #[test]
    fn native_loss_decreases_over_chunks() {
        let mut tr = Trainer::new(NativeEngine::new(), tiny("fused", 2)).unwrap();
        tr.train_steps(32).unwrap();
        let first = tr.history.first().unwrap().loss;
        let last_avg: f32 =
            tr.history.iter().rev().take(4).map(|r| r.loss).sum::<f32>() / 4.0;
        assert!(last_avg < first, "no learning: first {first}, last-4 avg {last_avg}");
    }

    #[test]
    fn native_eager_fused_convergence_parity() {
        // The §5.9 acceptance criterion on the native engine: same seed
        // + data through both numeric paths, per-step losses within 1e-3.
        let mut a = Trainer::new(NativeEngine::new(), tiny("eager", 3)).unwrap();
        let mut b = Trainer::new(NativeEngine::new(), tiny("fused", 3)).unwrap();
        a.train_steps(8).unwrap();
        b.train_steps(8).unwrap();
        assert_eq!(a.history.len(), b.history.len());
        let (mean, max) = Trainer::loss_delta(&a, &b);
        assert!(mean < 1e-3, "mean |dloss| {mean}");
        assert!(max < 1e-3, "max |dloss| {max}");
        // Eval agrees across paths too.
        let ea = a.eval().unwrap();
        let eb = b.eval().unwrap();
        assert!((ea - eb).abs() < 1e-3, "eval {ea} vs {eb}");
    }

    #[test]
    fn native_seeds_produce_different_runs() {
        let mut a = Trainer::new(NativeEngine::new(), tiny("eager", 4)).unwrap();
        let mut b = Trainer::new(NativeEngine::new(), tiny("eager", 5)).unwrap();
        a.run_chunk().unwrap();
        b.run_chunk().unwrap();
        assert_ne!(a.history[0].loss, b.history[0].loss);
    }

    #[test]
    fn native_eval_runs_and_is_deterministic() {
        let tr = Trainer::new(NativeEngine::new(), tiny("fused", 6)).unwrap();
        let l1 = tr.eval().unwrap();
        let l2 = tr.eval().unwrap();
        assert!(l1.is_finite() && l1 > 0.0);
        assert_eq!(l1, l2);
    }

    #[test]
    fn native_trainer_rejects_bad_config_and_variant() {
        assert!(Trainer::new(NativeEngine::new(), tiny("nope", 0)).is_err());
        let cfg = TrainerCfg { config: "missing".into(), ..tiny("fused", 0) };
        assert!(Trainer::new(NativeEngine::new(), cfg).is_err());
    }

    // --- PJRT-gated variants (skip without `make artifacts`) ---

    #[test]
    fn init_and_one_chunk() {
        let Some(eng) = engine() else { return };
        let mut tr = Trainer::new(eng, tiny("eager", 1)).unwrap();
        let recs = tr.run_chunk().unwrap().to_vec();
        assert_eq!(recs.len(), tr.config_info().chunk_steps);
        assert!(recs.iter().all(|r| r.loss.is_finite() && r.loss > 0.0));
        assert_eq!(tr.step_count(), tr.config_info().chunk_steps);
    }

    #[test]
    fn loss_decreases_over_chunks() {
        let Some(eng) = engine() else { return };
        let mut tr = Trainer::new(eng, tiny("eager", 2)).unwrap();
        tr.train_steps(16).unwrap();
        let first = tr.history.first().unwrap().loss;
        let last_avg: f32 = tr.history.iter().rev().take(4).map(|r| r.loss).sum::<f32>() / 4.0;
        assert!(
            last_avg < first,
            "no learning: first {first}, last-4 avg {last_avg}"
        );
    }

    #[test]
    fn eager_fused_convergence_equivalence_tiny() {
        // Table 10 in miniature: same seed + data, two numeric paths.
        let Some(eng) = engine() else { return };
        let mut a = Trainer::new(eng.clone(), tiny("eager", 3)).unwrap();
        let mut b = Trainer::new(eng, tiny("fused", 3)).unwrap();
        a.train_steps(8).unwrap();
        b.train_steps(8).unwrap();
        let (mean, max) = Trainer::loss_delta(&a, &b);
        assert!(mean < 1e-4, "mean |dloss| {mean}");
        assert!(max < 1e-3, "max |dloss| {max}");
    }

    #[test]
    fn seeds_produce_different_runs() {
        let Some(eng) = engine() else { return };
        let mut a = Trainer::new(eng.clone(), tiny("eager", 4)).unwrap();
        let mut b = Trainer::new(eng, tiny("eager", 5)).unwrap();
        a.run_chunk().unwrap();
        b.run_chunk().unwrap();
        assert_ne!(a.history[0].loss, b.history[0].loss);
    }

    #[test]
    fn eval_runs() {
        let Some(eng) = engine() else { return };
        let tr = Trainer::new(eng, tiny("fused", 6)).unwrap();
        let loss = tr.eval().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
    }
}
