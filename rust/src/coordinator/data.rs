//! Synthetic corpus generation for training and convergence experiments.
//!
//! Substitutes for MMFineReason-SFT-123K (DESIGN.md §1): a sparse
//! first-order Markov chain over the model's vocabulary. Each token has a
//! small set of likely successors, giving the corpus a controllable
//! entropy floor well below `ln(vocab)` — so adapter training produces a
//! visibly decreasing loss curve, while uniform-random tokens would
//! already sit at their optimum.

use crate::util::rng::Rng;

/// Sparse Markov-chain corpus generator.
#[derive(Debug, Clone)]
pub struct MarkovCorpus {
    vocab: usize,
    /// Per-token successor sets (uniform over `branching` choices).
    successors: Vec<Vec<u32>>,
    rng: Rng,
}

impl MarkovCorpus {
    /// Build a chain over `vocab` tokens with `branching` successors each.
    /// The transition structure is a function of `seed` only; sampling
    /// state evolves as sequences are drawn.
    pub fn new(vocab: usize, branching: usize, seed: u64) -> Self {
        assert!(vocab >= 2 && branching >= 1);
        let mut structure_rng = Rng::new(seed ^ 0x5EED_5EED);
        let successors = (0..vocab)
            .map(|_| {
                (0..branching)
                    .map(|_| structure_rng.below(vocab as u64) as u32)
                    .collect()
            })
            .collect();
        MarkovCorpus { vocab, successors, rng: Rng::new(seed) }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The corpus' entropy floor in nats (mean over states of ln of the
    /// number of *distinct* successors) — the loss an ideal model reaches.
    pub fn entropy_floor(&self) -> f64 {
        let total: f64 = self
            .successors
            .iter()
            .map(|s| {
                let mut d = s.clone();
                d.sort_unstable();
                d.dedup();
                (d.len() as f64).ln()
            })
            .sum();
        total / self.vocab as f64
    }

    /// Sample one sequence of `len` tokens.
    pub fn sequence(&mut self, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut state = self.rng.below(self.vocab as u64) as u32;
        for _ in 0..len {
            out.push(state as i32);
            let succ = &self.successors[state as usize];
            state = succ[self.rng.below(succ.len() as u64) as usize];
        }
        out
    }

    /// Sample a [k, bs, len] token block, flattened row-major — the train
    /// artifact's `tokens` input layout.
    pub fn block(&mut self, k: usize, bs: usize, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(k * bs * len);
        for _ in 0..k * bs {
            out.extend(self.sequence(len));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range() {
        let mut c = MarkovCorpus::new(512, 4, 1);
        let seq = c.sequence(1000);
        assert!(seq.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = MarkovCorpus::new(64, 4, 9);
        let mut b = MarkovCorpus::new(64, 4, 9);
        assert_eq!(a.sequence(100), b.sequence(100));
        let mut c = MarkovCorpus::new(64, 4, 10);
        assert_ne!(a.sequence(100), c.sequence(100));
    }

    #[test]
    fn entropy_floor_below_uniform() {
        let c = MarkovCorpus::new(512, 4, 2);
        let floor = c.entropy_floor();
        assert!(floor < (512f64).ln() * 0.5, "floor {floor}");
        assert!(floor > 0.5, "floor {floor}"); // branching 4 -> ~ln 4
    }

    #[test]
    fn transitions_respected() {
        let mut c = MarkovCorpus::new(32, 2, 3);
        let succ = c.successors.clone();
        let seq = c.sequence(500);
        for w in seq.windows(2) {
            assert!(succ[w[0] as usize].contains(&(w[1] as u32)));
        }
    }

    #[test]
    fn block_layout() {
        let mut c = MarkovCorpus::new(64, 4, 5);
        let b = c.block(2, 3, 10);
        assert_eq!(b.len(), 60);
    }
}
