//! Streaming autoregressive decode with a continuous-batching scheduler.
//!
//! This is the serving layer's second data path, next to the one-shot
//! batcher in [`super::server`]: a client calls [`Client::generate`]
//! (`Client` lives in the server module) and receives a [`GenStream`]
//! that yields one [`TokenEvent`] per decoded token **as it lands**,
//! instead of one bulk reply. Internally a dedicated scheduler thread
//! owns every request lifecycle:
//!
//! ```text
//!   queued --admit--> prefill --step--> decoding --EOS/max--> done
//!     |                                   |
//!     +-- Overloaded (queue full)         +-- cancelled (client gone)
//! ```
//!
//! **Continuous batching.** The scheduler keeps a set of active slots
//! (capacity = the config's `train_batch`). Between every decode step it
//! admits queued requests into free slots and retires finished ones — a
//! new request joins the *running* batch without waiting for the batch
//! to drain, and a finished request frees its slot within one step.
//! Each step groups the active slots by `(adapter, entry snapshot)` and
//! submits one [`EngineOp::DecodeStep`](crate::runtime::EngineOp) per
//! group to the shared [`EnginePool`], keyed by adapter affinity, then
//! barriers on the group replies before sampling.
//!
//! **Prefill.** The model family served here is row-local (no
//! cross-position attention; see DESIGN.md §3.9): next-token logits
//! depend only on the newest token, so prefill degenerates to seeding
//! the slot's decode state with the prompt's last token. The native
//! engine test `decode_step_is_row_local_and_matches_infer` pins this
//! equivalence bitwise against the full-prompt infer path.
//!
//! **Determinism contract.** Sampling happens here, not in the engine:
//! the engine returns logits, and each slot owns a private
//! [`Rng`] seeded from [`GenOptions::seed`]. Because the GEMM core
//! accumulates row-locally, a request's logits are bitwise identical
//! regardless of which other requests share its batch rows — so the
//! decoded token sequence is a pure function of
//! `(seed, prompt, adapter, variant)`, no matter when the request joined
//! the running batch or how the pool is sized. Batch *composition*
//! (which requests share an engine call) is explicitly NOT deterministic.
//!
//! **Backpressure.** Admission is a bounded queue
//! ([`ServerCfg::queue_depth`](super::ServerCfg)): when it is full the
//! submit fails fast with a typed [`Overloaded`] error (downcastable
//! from the `anyhow::Error`), counted in
//! [`ServerMetrics::shed_requests`](super::ServerMetrics) — the server
//! sheds load explicitly instead of hanging clients. SLO metrics record
//! per-request time-to-first-token and per-token latency histograms
//! (p50/p99) plus queue-depth and in-flight gauges.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::ops::{DecodeStepMergedReq, DecodeStepReq, MergedParams, Precision, Variant};
use crate::runtime::{EnginePool, MergedCache, Tensor};
use crate::util::lock_unpoisoned;
use crate::util::rng::Rng;

use super::server::{argmax, AdapterEntry, BuildReq, ServerMetrics};

/// Typed load-shed rejection: the streaming admission queue was full.
/// Carried inside the `anyhow::Error` returned by
/// [`Client::generate`](super::Client::generate) — callers distinguish
/// overload from validation errors with
/// `err.downcast_ref::<Overloaded>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Overloaded {
    /// Queue depth observed at rejection time (== the configured cap).
    pub queue_depth: usize,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "server overloaded: streaming queue full ({} requests queued)",
            self.queue_depth
        )
    }
}

impl std::error::Error for Overloaded {}

/// Why a stream finished. Reported on the FINAL [`TokenEvent`] of a
/// stream; every earlier event carries `finish: None`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// The sampled token matched [`GenOptions::eos`]. The EOS token
    /// itself IS emitted (callers that want to hide it drop the final
    /// event's token).
    Eos,
    /// [`GenOptions::max_tokens`] tokens were produced.
    MaxTokens,
}

/// Per-request decode options.
#[derive(Debug, Clone, Copy)]
pub struct GenOptions {
    /// Decode budget; the stream finishes with [`FinishReason::MaxTokens`]
    /// after this many tokens (must be >= 1).
    pub max_tokens: usize,
    /// Softmax temperature. `<= 0.0` selects greedy decoding (NaN-safe
    /// argmax, ties keep the lowest token id) and consumes NO randomness.
    pub temperature: f32,
    /// Restrict sampling to the `top_k` highest logits (0 = full vocab).
    /// Ignored under greedy decoding.
    pub top_k: usize,
    /// Seed for the request-private PRNG. The decoded sequence is a pure
    /// function of `(seed, prompt, adapter, variant)` — see the module
    /// docs' determinism contract.
    pub seed: u64,
    /// Optional end-of-sequence token: sampling it finishes the stream
    /// with [`FinishReason::Eos`]. The synthetic Markov corpus has no
    /// natural EOS, so this defaults to `None`.
    pub eos: Option<i32>,
    /// How many `(token, logit)` pairs of the step's top logits to attach
    /// to each [`TokenEvent`] (0 = none). Streaming replies deliberately
    /// never carry the full `[vocab]` logits row — use
    /// [`Client::infer`](super::Client::infer) for that.
    pub top_logits: usize,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions {
            max_tokens: 16,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            eos: None,
            top_logits: 0,
        }
    }
}

/// One decoded token, streamed to the client as it lands.
#[derive(Debug, Clone)]
pub struct TokenEvent {
    /// Position in the decoded sequence (0-based).
    pub index: usize,
    pub token: i32,
    /// The chosen token's logit.
    pub logit: f32,
    /// The step's `top_logits` highest `(token, logit)` pairs (logit
    /// descending, token id ascending on ties); empty when
    /// [`GenOptions::top_logits`] is 0.
    pub top: Vec<(i32, f32)>,
    /// `Some` on the stream's final event.
    pub finish: Option<FinishReason>,
}

/// Receiving half of a streaming generation: yields one
/// `Result<TokenEvent>` per decoded token. Dropping the stream cancels
/// the request — the scheduler notices the closed channel at its next
/// send and frees the slot without poisoning the batch.
pub struct GenStream {
    rx: Receiver<Result<TokenEvent>>,
}

impl GenStream {
    pub(crate) fn new(rx: Receiver<Result<TokenEvent>>) -> GenStream {
        GenStream { rx }
    }

    /// Block for the next token event; `None` once the stream is done.
    pub fn next_event(&self) -> Option<Result<TokenEvent>> {
        self.rx.recv().ok()
    }

    /// Drain the stream into the full decoded token sequence (including
    /// the EOS token, when one finished the stream). The first engine or
    /// shutdown error aborts the collect.
    pub fn collect(self) -> Result<Vec<i32>> {
        let mut out = Vec::new();
        for ev in self.rx.iter() {
            let ev = ev?;
            out.push(ev.token);
            if ev.finish.is_some() {
                break;
            }
        }
        Ok(out)
    }
}

impl Iterator for GenStream {
    type Item = Result<TokenEvent>;

    fn next(&mut self) -> Option<Result<TokenEvent>> {
        self.rx.recv().ok()
    }
}

/// A queued (not yet admitted) generation request. The adapter entry is
/// snapshotted at submit time, so a request streams against ONE
/// consistent parameter set even if the adapter is hot-swapped
/// mid-decode.
pub(crate) struct GenRequest {
    pub(crate) adapter: String,
    pub(crate) entry: Arc<AdapterEntry>,
    pub(crate) prompt: Vec<i32>,
    pub(crate) opts: GenOptions,
    pub(crate) tx: Sender<Result<TokenEvent>>,
    pub(crate) enqueued: Instant,
}

/// State shared between clients (submit side) and the scheduler thread:
/// the bounded admission queue plus the load/backpressure gauges.
pub(crate) struct DecodeShared {
    queue: Mutex<VecDeque<GenRequest>>,
    cv: Condvar,
    cap: usize,
    pub(crate) shed: AtomicU64,
    pub(crate) in_flight: AtomicUsize,
    pub(crate) stopped: AtomicBool,
}

impl DecodeShared {
    pub(crate) fn new(cap: usize) -> DecodeShared {
        DecodeShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            cap: cap.max(1),
            shed: AtomicU64::new(0),
            in_flight: AtomicUsize::new(0),
            stopped: AtomicBool::new(false),
        }
    }

    /// Admission control: enqueue or shed. A full queue returns the typed
    /// [`Overloaded`] error immediately — clients never block here.
    pub(crate) fn try_push(&self, req: GenRequest) -> Result<()> {
        if self.stopped.load(Ordering::SeqCst) {
            anyhow::bail!("server stopped");
        }
        let mut q = lock_unpoisoned(&self.queue);
        if q.len() >= self.cap {
            drop(q);
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(Overloaded { queue_depth: self.cap }));
        }
        q.push_back(req);
        drop(q);
        self.cv.notify_one();
        Ok(())
    }

    /// Current admission-queue depth (gauge).
    pub(crate) fn queue_depth(&self) -> usize {
        lock_unpoisoned(&self.queue).len()
    }
}

/// One active decode slot: a request mid-stream.
struct Slot {
    adapter: String,
    entry: Arc<AdapterEntry>,
    /// Merged weights snapshotted ONCE at admission. A stream must not
    /// flip between the merged and composed paths mid-decode (their
    /// logits differ by float reassociation), so this fixes the path —
    /// and with it the whole token sequence — for the stream's life,
    /// even across a concurrent promotion or eviction.
    merged: Option<Arc<MergedParams>>,
    /// Newest token — the model is row-local, so this IS the decode
    /// state (no KV cache; see module docs).
    last: i32,
    produced: usize,
    opts: GenOptions,
    rng: Rng,
    tx: Sender<Result<TokenEvent>>,
    enqueued: Instant,
    /// Completion time of the previous step (TTFT base = `enqueued`).
    prev_step: Instant,
}

/// Why a slot left the active set after a step.
enum Retire {
    Finished,
    Cancelled,
    Failed,
}

/// The continuous-batching scheduler: owned by its own server thread,
/// sharing the [`EnginePool`] with the one-shot batcher.
pub(crate) struct DecodeScheduler {
    pub(crate) config: String,
    /// Serving precision threaded into every composed decode step (the
    /// merged path carries it inside [`MergedParams`]).
    pub(crate) precision: Precision,
    pub(crate) vocab: usize,
    /// Active-slot capacity (the config's `train_batch`; decode-step
    /// tokens tensors are validated against it by the engine).
    pub(crate) slots: usize,
    pub(crate) shared: Arc<DecodeShared>,
    pub(crate) pool: Arc<EnginePool>,
    pub(crate) metrics: Arc<Mutex<ServerMetrics>>,
    /// The server's merged-weight cache: admission pins a stream's
    /// adapter (evict-exempt until the stream retires) and records the
    /// hit/miss.
    pub(crate) cache: Arc<MergedCache>,
    /// Builder-thread submit side; `None` outside budgeted mode.
    pub(crate) merge_tx: Option<Sender<BuildReq>>,
    pub(crate) stop: Arc<AtomicBool>,
}

impl DecodeScheduler {
    /// Scheduler main loop: admit -> step -> sample/emit -> retire, until
    /// the server stops. On exit every queued and active request is
    /// answered with an error (no client is left hanging).
    pub(crate) fn run(&self) {
        let mut active: Vec<Slot> = Vec::new();
        while !self.stop.load(Ordering::SeqCst) {
            self.admit(&mut active);
            self.shared.in_flight.store(active.len(), Ordering::SeqCst);
            if active.is_empty() {
                // Idle: park on the condvar until a submit arrives (the
                // timeout bounds shutdown latency).
                let q = lock_unpoisoned(&self.shared.queue);
                if q.is_empty() {
                    let _ = self
                        .shared
                        .cv
                        .wait_timeout(q, Duration::from_millis(20))
                        .map(|(g, _)| g)
                        .unwrap_or_else(|p| p.into_inner().0);
                }
                continue;
            }
            self.step(&mut active);
        }
        self.shared.stopped.store(true, Ordering::SeqCst);
        self.shared.in_flight.store(0, Ordering::SeqCst);
        for slot in active.drain(..) {
            // Queued (never-admitted) requests below hold no pin.
            self.cache.unpin(&slot.adapter);
            let _ = slot.tx.send(Err(anyhow::anyhow!("server stopped")));
        }
        let mut q = lock_unpoisoned(&self.shared.queue);
        for req in q.drain(..) {
            let _ = req.tx.send(Err(anyhow::anyhow!("server stopped")));
        }
    }

    /// Move queued requests into free slots (continuous batching: this
    /// runs between every step, so arrivals join the running batch).
    fn admit(&self, active: &mut Vec<Slot>) {
        let mut admitted = 0u64;
        {
            let mut q = lock_unpoisoned(&self.shared.queue);
            while active.len() < self.slots {
                let Some(req) = q.pop_front() else { break };
                let now = Instant::now();
                // One merge-slot snapshot per stream (see [`Slot::merged`]).
                // A cold adapter under budgeted mode schedules its async
                // build and streams composed.
                let merged = req.entry.merged.snapshot();
                match &merged {
                    Some(_) => self.cache.note_hit(&req.adapter),
                    None => {
                        if let Some(btx) = &self.merge_tx {
                            if self.cache.note_miss(&req.adapter, req.entry.gen) {
                                let _ = btx.send(BuildReq {
                                    name: req.adapter.clone(),
                                    entry: req.entry.clone(),
                                });
                            }
                        }
                    }
                }
                // Pin for the stream's whole life (admission to retire):
                // budget eviction must not churn the merge an active
                // stream's adapter holds resident.
                self.cache.pin(&req.adapter);
                // Row-local prefill: the prompt's last token seeds the
                // decode state (validated non-empty by the client).
                let last = *req.prompt.last().unwrap_or(&0);
                active.push(Slot {
                    adapter: req.adapter,
                    entry: req.entry,
                    merged,
                    last,
                    produced: 0,
                    opts: req.opts,
                    rng: Rng::new(req.opts.seed),
                    tx: req.tx,
                    enqueued: req.enqueued,
                    prev_step: now,
                });
                admitted += 1;
            }
        }
        if admitted > 0 {
            lock_unpoisoned(&self.metrics).decode_requests += admitted;
        }
    }

    /// One decode step over the whole active set: group slots by adapter
    /// entry, submit one batched `decode_step` per group to the pool,
    /// barrier on the replies, then sample/emit/retire per slot.
    fn step(&self, active: &mut Vec<Slot>) {
        // Group by (adapter, entry identity, merge identity): two
        // requests share an engine call only if they decode against the
        // SAME snapshot on the SAME path (a hot-swapped adapter must not
        // mix old and new weights in one batch, and a composed stream
        // must not ride a merged group's engine call).
        let mut groups: BTreeMap<(String, usize, usize), Vec<usize>> = BTreeMap::new();
        for (i, slot) in active.iter().enumerate() {
            let key = (
                slot.adapter.clone(),
                Arc::as_ptr(&slot.entry) as usize,
                slot.merged.as_ref().map_or(0, |m| Arc::as_ptr(m) as usize),
            );
            groups.entry(key).or_default().push(i);
        }

        let (tx, rx) = mpsc::channel::<(Vec<usize>, Result<Vec<f32>>)>();
        let mut jobs = 0usize;
        for ((adapter, _, _), idxs) in groups {
            let entry = active[idxs[0]].entry.clone();
            let merged = active[idxs[0]].merged.clone();
            let tokens: Vec<i32> = idxs.iter().map(|&i| active[i].last).collect();
            let config = self.config.clone();
            let precision = self.precision;
            let tx = tx.clone();
            self.pool.submit(
                &adapter,
                Box::new(move |_worker, engine| {
                    let n = tokens.len();
                    let t = Tensor::i32(vec![n], tokens);
                    let result = match &merged {
                        Some(m) => engine.decode_step_merged(DecodeStepMergedReq {
                            config,
                            params: m.clone(),
                            tokens: t,
                        }),
                        None => engine.decode_step(DecodeStepReq {
                            config,
                            variant: Variant::Fused,
                            adapter: entry.variant,
                            precision,
                            params: entry.params.clone(),
                            tokens: t,
                        }),
                    };
                    // The typed wrapper validated shape/dtype/len.
                    let _ = tx.send((
                        idxs,
                        result.map(|r| r.logits.as_f32().expect("validated f32 logits").to_vec()),
                    ));
                }),
            );
            jobs += 1;
        }
        drop(tx);

        // Step barrier: sampling needs every group's logits before the
        // next step can form (slots advance in lockstep; admission
        // happens between steps).
        let mut retire: Vec<(usize, Retire)> = Vec::new();
        let mut events = 0u64;
        let mut ttft_us: Vec<f64> = Vec::new();
        let mut tok_us: Vec<f64> = Vec::new();
        for _ in 0..jobs {
            let Ok((idxs, result)) = rx.recv() else { break };
            match result {
                Ok(logits) => {
                    for (row, &i) in idxs.iter().enumerate() {
                        let slot = &mut active[i];
                        let row_logits = &logits[row * self.vocab..(row + 1) * self.vocab];
                        let (token, logit) = sample_token(
                            row_logits,
                            slot.opts.temperature,
                            slot.opts.top_k,
                            &mut slot.rng,
                        );
                        slot.last = token;
                        let index = slot.produced;
                        slot.produced += 1;
                        let finish = if slot.opts.eos == Some(token) {
                            Some(FinishReason::Eos)
                        } else if slot.produced >= slot.opts.max_tokens {
                            Some(FinishReason::MaxTokens)
                        } else {
                            None
                        };
                        let top = top_logits(row_logits, slot.opts.top_logits);
                        let now = Instant::now();
                        if index == 0 {
                            ttft_us.push((now - slot.enqueued).as_secs_f64() * 1e6);
                        } else {
                            tok_us.push((now - slot.prev_step).as_secs_f64() * 1e6);
                        }
                        slot.prev_step = now;
                        let sent = slot
                            .tx
                            .send(Ok(TokenEvent { index, token, logit, top, finish }));
                        if sent.is_err() {
                            // Client dropped its stream mid-decode:
                            // cancel cleanly, free the slot.
                            retire.push((i, Retire::Cancelled));
                        } else {
                            events += 1;
                            if finish.is_some() {
                                retire.push((i, Retire::Finished));
                            }
                        }
                    }
                }
                Err(e) => {
                    // Fan the group's failure to its own slots only; the
                    // rest of the batch keeps decoding.
                    let msg = format!("{e:#}");
                    for &i in &idxs {
                        let _ = active[i].tx.send(Err(anyhow::anyhow!(msg.clone())));
                        retire.push((i, Retire::Failed));
                    }
                }
            }
        }

        // Record SLO metrics under one short lock.
        {
            let mut m = lock_unpoisoned(&self.metrics);
            m.decode_steps += jobs as u64;
            m.decode_tokens += events;
            m.ttft_us.extend_from_slice(&ttft_us);
            m.token_latency_us.extend_from_slice(&tok_us);
            for (_, why) in &retire {
                match why {
                    Retire::Finished => m.decode_completed += 1,
                    Retire::Cancelled => m.decode_cancelled += 1,
                    Retire::Failed => m.decode_failed += 1,
                }
            }
        }

        // Retire in descending index order so swap_remove stays stable.
        // Every retirement — finish, cancel (receiver drop), or failure —
        // releases the stream's cache pin.
        retire.sort_by(|a, b| b.0.cmp(&a.0));
        for (i, _) in retire {
            let slot = active.swap_remove(i);
            self.cache.unpin(&slot.adapter);
        }
        self.shared.in_flight.store(active.len(), Ordering::SeqCst);
    }
}

/// Sample one token from a logits row. `temperature <= 0` is greedy
/// (NaN-safe argmax, no randomness consumed); otherwise restrict to the
/// `top_k` highest logits (0 = all), softmax in f64 at `temperature`,
/// and draw from the request's private PRNG. All arithmetic is
/// platform-independent f64, so a `(seed, logits)` pair reproduces the
/// same token everywhere.
fn sample_token(row: &[f32], temperature: f32, top_k: usize, rng: &mut Rng) -> (i32, f32) {
    if temperature <= 0.0 {
        return argmax(row);
    }
    let mut idx: Vec<usize> = (0..row.len()).filter(|&i| !row[i].is_nan()).collect();
    if idx.is_empty() {
        return (0, f32::NAN);
    }
    // Logit descending; token id ascending on exact ties (determinism).
    idx.sort_by(|&a, &b| {
        row[b]
            .partial_cmp(&row[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let k = if top_k == 0 { idx.len() } else { top_k.min(idx.len()) };
    idx.truncate(k);
    let maxv = row[idx[0]] as f64;
    let t = temperature as f64;
    let weights: Vec<f64> = idx.iter().map(|&i| ((row[i] as f64 - maxv) / t).exp()).collect();
    let total: f64 = weights.iter().sum();
    let draw = rng.next_f64() * total;
    let mut acc = 0.0f64;
    for (j, &i) in idx.iter().enumerate() {
        acc += weights[j];
        if draw < acc {
            return (i as i32, row[i]);
        }
    }
    let i = *idx.last().expect("non-empty candidate set");
    (i as i32, row[i])
}

/// The `k` highest `(token, logit)` pairs of a row (logit descending,
/// token id ascending on ties; NaN logits excluded).
fn top_logits(row: &[f32], k: usize) -> Vec<(i32, f32)> {
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..row.len()).filter(|&i| !row[i].is_nan()).collect();
    idx.sort_by(|&a, &b| {
        row[b]
            .partial_cmp(&row[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.into_iter().map(|i| (i as i32, row[i])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overloaded_displays_and_downcasts() {
        let err = anyhow::Error::new(Overloaded { queue_depth: 7 });
        assert!(format!("{err:#}").contains("overloaded"), "{err:#}");
        let o = err.downcast_ref::<Overloaded>().expect("downcast");
        assert_eq!(o.queue_depth, 7);
    }

    #[test]
    fn greedy_sampling_is_argmax_and_consumes_no_randomness() {
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        let (t, l) = sample_token(&[0.1, 3.0, -1.0], 0.0, 0, &mut rng);
        assert_eq!((t, l), (1, 3.0));
        assert_eq!(rng.next_u64(), before, "greedy consumed randomness");
    }

    #[test]
    fn temperature_sampling_is_seed_deterministic() {
        let row = [0.5f32, 1.5, -0.5, 2.5, 0.0];
        let run = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..20)
                .map(|_| sample_token(&row, 0.8, 0, &mut rng).0)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(42), run(42));
        // A different seed should (with these margins) diverge somewhere.
        assert_ne!(run(42), run(43));
        // All sampled tokens are valid indices.
        assert!(run(7).iter().all(|&t| (0..5).contains(&t)));
    }

    #[test]
    fn top_k_restricts_the_candidate_set() {
        let row = [0.0f32, 10.0, 9.0, -5.0];
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let (t, _) = sample_token(&row, 1.0, 2, &mut rng);
            assert!(t == 1 || t == 2, "token {t} outside top-2");
        }
    }

    #[test]
    fn sampling_is_nan_safe() {
        let mut rng = Rng::new(0);
        let (t, l) = sample_token(&[f32::NAN, f32::NAN], 1.0, 0, &mut rng);
        assert_eq!(t, 0);
        assert!(l.is_nan());
        let (t, _) = sample_token(&[f32::NAN, 1.0, f32::NAN], 0.7, 0, &mut rng);
        assert_eq!(t, 1);
    }

    #[test]
    fn top_logits_orders_and_breaks_ties_by_token_id() {
        let row = [1.0f32, 3.0, 3.0, f32::NAN, 2.0];
        assert_eq!(top_logits(&row, 3), vec![(1, 3.0), (2, 3.0), (4, 2.0)]);
        assert!(top_logits(&row, 0).is_empty());
    }

    #[test]
    fn gen_options_default_is_greedy() {
        let o = GenOptions::default();
        assert_eq!(o.max_tokens, 16);
        assert_eq!(o.temperature, 0.0);
        assert_eq!(o.top_k, 0);
        assert_eq!(o.eos, None);
        assert_eq!(o.top_logits, 0);
    }
}
