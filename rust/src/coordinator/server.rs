//! Serving coordinator: batched inference over the Tier-2 fused-forward
//! artifact (`infer_<cfg>_fused`).
//!
//! vLLM-router-style shape: clients submit token prompts to a bounded
//! queue; a batcher thread groups up to `batch` requests within a
//! `max_wait` window (batch-or-timeout policy), pads them into the fixed
//! [bs, seq] artifact shape, executes one engine call, and fans the
//! last-position logits back to per-request channels. Metrics record
//! per-request latency and batch occupancy so the bench harness can sweep
//! the batching policy.
//!
//! The server runs over any [`BackendSpec`]: PJRT over an artifacts
//! directory, the native kernel-registry engine, or a scripted mock.
//! Engines are reconnected *inside* the batcher thread (PJRT clients are
//! not `Send`); everything fallible is validated synchronously on a probe
//! connection first, so `start_with_params` fails fast instead of leaving
//! clients to time out against a dead thread.
//!
//! Robustness contract: the batcher never panics on malformed engine
//! output — a bad batch fans an `Err` to each of its requests and the
//! loop keeps serving subsequent batches.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::{BackendSpec, ExecBackend, Tensor};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// Manifest config name (must have an `infer_<cfg>_fused` artifact).
    pub config: String,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg { config: "small".into(), max_wait: Duration::from_millis(20) }
    }
}

/// One inference request: a prompt, answered with next-token logits.
struct Request {
    prompt: Vec<i32>,
    enqueued: Instant,
    reply: SyncSender<Result<Reply>>,
}

/// Response: argmax token + its logit + timing.
#[derive(Debug, Clone)]
pub struct Reply {
    pub next_token: i32,
    pub logit: f32,
    pub latency: Duration,
    /// How many real requests shared the batch.
    pub batch_occupancy: usize,
}

/// Aggregated serving metrics.
#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    pub completed: u64,
    /// Requests answered with an error (engine failure or malformed
    /// engine output). The batcher stays up; this counts what it shed.
    pub failed: u64,
    pub batches: u64,
    pub latencies_us: Vec<f64>,
    pub occupancies: Vec<f64>,
    /// Compose backend the kernel registry selects for this config's
    /// inference shape (Tier-2 path), recorded at startup.
    pub compose_backend: String,
    /// Execution backend kind ("pjrt" / "native" / "mock").
    pub exec_backend: String,
}

impl ServerMetrics {
    pub fn p50_us(&self) -> f64 {
        crate::util::stats::percentile(&self.latencies_us, 50.0)
    }

    pub fn p95_us(&self) -> f64 {
        crate::util::stats::percentile(&self.latencies_us, 95.0)
    }

    pub fn mean_occupancy(&self) -> f64 {
        crate::util::stats::mean(&self.occupancies)
    }
}

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    seq: usize,
    vocab: usize,
}

impl Client {
    /// Blocking single-shot inference: returns the next-token prediction.
    pub fn infer(&self, prompt: &[i32]) -> Result<Reply> {
        if prompt.is_empty() || prompt.len() > self.seq {
            bail!("prompt length {} outside 1..={}", prompt.len(), self.seq);
        }
        if let Some(&t) = prompt.iter().find(|&&t| t < 0 || t as usize >= self.vocab) {
            bail!("token {t} outside vocab 0..{}", self.vocab);
        }
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request { prompt: prompt.to_vec(), enqueued: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx.recv().context("server dropped request")?
    }
}

/// The running server: owns the batcher thread.
pub struct Server {
    client_tx: Sender<Request>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServerMetrics>>,
    join: Option<std::thread::JoinHandle<()>>,
    seq: usize,
    vocab: usize,
}

impl Server {
    /// Start with seed-0 initialized parameters (callers with a trained
    /// adapter use [`Server::start_with_params`]). Accepts anything that
    /// converts to a [`BackendSpec`]: an artifacts directory path (PJRT),
    /// `BackendSpec::Native`, `BackendSpec::auto()`, or a mock.
    pub fn start(spec: impl Into<BackendSpec>, cfg: ServerCfg) -> Result<Server> {
        let spec = spec.into();
        let backend = spec.connect()?;
        let info = backend.config(&cfg.config)?;
        let outs = backend.run(&format!("init_{}", cfg.config), &[Tensor::scalar_i32(0)])?;
        let nf = info.frozen.len();
        if outs.len() != nf + info.trainable.len() {
            bail!(
                "init_{} returned {} leaves, expected {}",
                cfg.config,
                outs.len(),
                nf + info.trainable.len()
            );
        }
        // Reuse the already-connected backend as the validation probe
        // (on PJRT a fresh connect would re-load the engine and
        // re-compile the infer executable for nothing).
        Self::start_with_probe(spec, backend, cfg, outs[..nf].to_vec(), outs[nf..].to_vec())
    }

    /// Start the server on the default backend (PJRT artifacts when
    /// usable, native otherwise).
    pub fn start_auto(cfg: ServerCfg) -> Result<Server> {
        Self::start(BackendSpec::auto(), cfg)
    }

    /// Start with explicit parameters (e.g. a Trainer's adapted weights).
    ///
    /// All startup failure modes surface synchronously here: unknown
    /// config, parameter-count mismatch, and a missing/uncompilable
    /// `infer_<cfg>_fused` artifact (validated on a probe connection —
    /// previously the spawned thread died silently and clients hung).
    pub fn start_with_params(
        spec: impl Into<BackendSpec>,
        cfg: ServerCfg,
        frozen: Vec<Tensor>,
        trainable: Vec<Tensor>,
    ) -> Result<Server> {
        let spec = spec.into();
        let probe = spec.connect().context("connecting execution backend")?;
        Self::start_with_probe(spec, probe, cfg, frozen, trainable)
    }

    /// Shared startup tail: validate on `probe` (an engine already
    /// connected from `spec`), then spawn the batcher thread, which
    /// reconnects from `spec` on its own thread.
    fn start_with_probe(
        spec: BackendSpec,
        probe: ExecBackend,
        cfg: ServerCfg,
        frozen: Vec<Tensor>,
        trainable: Vec<Tensor>,
    ) -> Result<Server> {
        let info = probe.config(&cfg.config)?;
        if frozen.len() != info.frozen.len() || trainable.len() != info.trainable.len() {
            bail!(
                "param count mismatch: got {}+{}, config wants {}+{}",
                frozen.len(),
                trainable.len(),
                info.frozen.len(),
                info.trainable.len()
            );
        }
        let artifact = format!("infer_{}_fused", cfg.config);
        probe
            .ensure_artifact(&artifact)
            .with_context(|| format!("validating serving artifact {artifact:?}"))?;
        drop(probe);

        let (tx, rx): (Sender<Request>, Receiver<Request>) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(ServerMetrics {
            compose_backend: super::compose_plan(&info, false).backend.name().to_string(),
            exec_backend: spec.kind_name().to_string(),
            ..ServerMetrics::default()
        }));

        let bs = info.train_batch;
        let seq = info.seq;
        let vocab = info.vocab;
        let stop2 = stop.clone();
        let metrics2 = metrics.clone();
        let max_wait = cfg.max_wait;

        let join = std::thread::spawn(move || {
            // PJRT clients are not Send: reconnect from the spec on this
            // thread. The probe validated everything, so a failure here
            // is exceptional (e.g. the artifacts dir vanished) — drain
            // requests with the cause instead of letting clients hang.
            match spec.connect() {
                Ok(engine) => batcher_loop(
                    engine, artifact, frozen, trainable, rx, stop2, metrics2, bs, seq, vocab,
                    max_wait,
                ),
                Err(e) => {
                    let msg = format!("server backend failed to start: {e:#}");
                    drain_with_error(rx, stop2, metrics2, &msg);
                }
            }
        });

        Ok(Server { client_tx: tx, stop, metrics, join: Some(join), seq, vocab })
    }

    pub fn client(&self) -> Client {
        Client { tx: self.client_tx.clone(), seq: self.seq, vocab: self.vocab }
    }

    pub fn metrics(&self) -> ServerMetrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Stop the batcher and join.
    pub fn shutdown(mut self) -> ServerMetrics {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        let m = self.metrics.lock().unwrap().clone();
        m
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Reply `Err(msg)` to every request until stopped (the batcher thread's
/// unreachable-engine fallback: clients get the cause, not a hang).
fn drain_with_error(
    rx: Receiver<Request>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServerMetrics>>,
    msg: &str,
) {
    while !stop.load(Ordering::SeqCst) {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(req) => {
                metrics.lock().unwrap().failed += 1;
                let _ = req.reply.send(Err(anyhow::anyhow!(msg.to_string())));
            }
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
}

/// Validate one batch's engine outputs down to the logits slice. Any
/// mismatch (missing output, wrong dtype, wrong shape) is an `Err` the
/// caller fans to the batch — never a panic.
fn validate_logits<'a>(outs: &'a [Tensor], bs: usize, vocab: usize) -> Result<&'a [f32]> {
    let first = outs
        .first()
        .context("engine returned no outputs for the infer artifact")?;
    if first.shape != [bs, vocab] {
        bail!(
            "infer output shape {:?} != expected [{bs}, {vocab}]",
            first.shape
        );
    }
    let logits = first
        .as_f32()
        .context("infer output has wrong dtype (expected f32 logits)")?;
    if logits.len() != bs * vocab {
        bail!(
            "infer output has {} elements, expected {}",
            logits.len(),
            bs * vocab
        );
    }
    Ok(logits)
}

/// NaN-safe argmax over one row of logits: NaN entries are skipped (the
/// old `partial_cmp(..).unwrap()` panicked on them and killed the batcher
/// thread); ties keep the first index. A fully poisoned row degrades to a
/// deterministic `(0, NaN)` reply instead of a panic.
fn argmax(row: &[f32]) -> (i32, f32) {
    let mut best: Option<usize> = None;
    for (i, v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some(b) if *v <= row[b] => {}
            _ => best = Some(i),
        }
    }
    match best {
        Some(b) => (b as i32, row[b]),
        None => (0, f32::NAN),
    }
}

#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    engine: ExecBackend,
    artifact: String,
    frozen: Vec<Tensor>,
    trainable: Vec<Tensor>,
    rx: Receiver<Request>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServerMetrics>>,
    bs: usize,
    seq: usize,
    vocab: usize,
    max_wait: Duration,
) {
    while !stop.load(Ordering::SeqCst) {
        // Collect up to `bs` requests, waiting at most `max_wait` after
        // the first arrival (batch-or-timeout).
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + max_wait;
        while batch.len() < bs {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Pad into the fixed [bs, seq] shape: left-pad each prompt with
        // token 0, unused rows are zeros (their outputs are discarded).
        let mut tokens = vec![0i32; bs * seq];
        for (row, req) in batch.iter().enumerate() {
            let p = &req.prompt;
            let start = seq - p.len();
            tokens[row * seq + start..(row + 1) * seq].copy_from_slice(p);
        }

        let mut inputs: Vec<Tensor> = Vec::new();
        inputs.extend(frozen.iter().cloned());
        inputs.extend(trainable.iter().cloned());
        inputs.push(Tensor::i32(vec![bs, seq], tokens));

        let occupancy = batch.len();
        let result = engine.run(&artifact, &inputs);
        let checked = result.and_then(|outs| {
            validate_logits(&outs, bs, vocab).map(|l| l.to_vec())
        });
        let mut m = metrics.lock().unwrap();
        m.batches += 1;
        match checked {
            Ok(logits) => {
                for (row, req) in batch.into_iter().enumerate() {
                    let (next, logit) = argmax(&logits[row * vocab..(row + 1) * vocab]);
                    let latency = req.enqueued.elapsed();
                    m.completed += 1;
                    m.latencies_us.push(latency.as_secs_f64() * 1e6);
                    m.occupancies.push(occupancy as f64);
                    let _ = req.reply.send(Ok(Reply {
                        next_token: next,
                        logit,
                        latency,
                        batch_occupancy: occupancy,
                    }));
                }
            }
            Err(e) => {
                // Fan the failure to every request in the batch; the
                // batcher itself keeps serving.
                let msg = format!("{e:#}");
                m.failed += batch.len() as u64;
                for req in batch {
                    let _ = req.reply.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::default_dir;
    use crate::runtime::MockExec;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = default_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    fn tiny_cfg() -> ServerCfg {
        ServerCfg { config: "tiny".into(), max_wait: Duration::from_millis(5) }
    }

    // --- Native-engine tests: run unconditionally (no artifact gating) ---

    #[test]
    fn native_serves_single_request() {
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        let client = server.client();
        let reply = client.infer(&[1, 2, 3, 4]).unwrap();
        assert!(reply.next_token >= 0);
        assert!(reply.logit.is_finite());
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 0);
        assert_eq!(m.batches, 1);
        assert_eq!(m.exec_backend, "native");
    }

    #[test]
    fn native_batches_concurrent_requests() {
        // The batch-occupancy criterion: with a wide window and 4
        // concurrent clients, batching packs >1 request per engine call.
        let server = Server::start(
            BackendSpec::Native,
            ServerCfg { config: "tiny".into(), max_wait: Duration::from_millis(200) },
        )
        .unwrap();
        let client = server.client();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || c.infer(&[i as i32 + 1, 2, 3]).unwrap())
            })
            .collect();
        let replies: Vec<Reply> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let m = server.shutdown();
        assert_eq!(m.completed, 4);
        assert!(m.batches < 4, "batches {}", m.batches);
        assert!(replies.iter().any(|r| r.batch_occupancy > 1));
        assert!(m.mean_occupancy() > 1.0, "occupancy {}", m.mean_occupancy());
    }

    #[test]
    fn native_rejects_invalid_prompts() {
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        let client = server.client();
        assert!(client.infer(&[]).is_err());
        assert!(client.infer(&vec![0; 10_000]).is_err());
        assert!(client.infer(&[-1]).is_err());
        assert!(client.infer(&[1_000_000]).is_err());
        drop(server);
    }

    #[test]
    fn native_deterministic_given_params() {
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        let client = server.client();
        let a = client.infer(&[5, 6, 7]).unwrap();
        let b = client.infer(&[5, 6, 7]).unwrap();
        assert_eq!(a.next_token, b.next_token);
        drop(server);
    }

    #[test]
    fn native_train_then_serve_handoff() {
        use crate::coordinator::{Trainer, TrainerCfg};
        use crate::runtime::NativeEngine;
        let mut tr = Trainer::new(
            NativeEngine::new(),
            TrainerCfg {
                config: "tiny".into(),
                variant: "fused".into(),
                seed: 11,
                branching: 3,
                eval_every: 0,
            },
        )
        .unwrap();
        tr.train_steps(4).unwrap();
        let server = Server::start_with_params(
            BackendSpec::Native,
            tiny_cfg(),
            tr.frozen().to_vec(),
            tr.trainable().to_vec(),
        )
        .unwrap();
        let r = server.client().infer(&[1, 2, 3]).unwrap();
        assert!(r.logit.is_finite());
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn startup_validates_config_params_and_artifact() {
        // Unknown config fails synchronously.
        let err = Server::start(
            BackendSpec::Native,
            ServerCfg { config: "no_such_config".into(), ..tiny_cfg() },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("no_such_config"), "{err:#}");
        // Param-count mismatch fails synchronously.
        let err = Server::start_with_params(BackendSpec::Native, tiny_cfg(), vec![], vec![])
            .unwrap_err();
        assert!(format!("{err:#}").contains("param count"), "{err:#}");
        // A PJRT spec over a directory with no artifacts fails
        // synchronously (this used to hang clients: the batcher thread
        // hit its "unreachable" return).
        let err = Server::start(
            BackendSpec::Pjrt(std::path::PathBuf::from("/nonexistent/artifacts")),
            tiny_cfg(),
        )
        .unwrap_err();
        assert!(!format!("{err:#}").is_empty());
    }

    #[test]
    fn malformed_engine_output_fans_errors_and_server_keeps_serving() {
        // The batcher-robustness criterion: a wrong-shaped output batch
        // answers every in-flight request with Err, and the NEXT batch
        // (well-formed) succeeds — the thread survives.
        let info = ExecBackend::native().config("tiny").unwrap();
        let mock = MockExec::new(info.clone());
        // Batch 1: empty output vec (the old `outs[0]` panic).
        mock.push(Ok(vec![]));
        // Batch 2: wrong shape (the old slice-out-of-range panic).
        mock.push(Ok(vec![Tensor::f32(vec![1, 3], vec![0.0; 3])]));
        // Batch 3: wrong dtype (the old `unwrap_or(&[])` silent-empty).
        mock.push(Ok(vec![Tensor::i32(
            vec![info.train_batch, info.vocab],
            vec![0; info.train_batch * info.vocab],
        )]));
        // Batch 4+: script exhausted -> mock returns valid zero logits.
        let dummy_frozen: Vec<Tensor> =
            info.frozen.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let dummy_trainable: Vec<Tensor> =
            info.trainable.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let server = Server::start_with_params(
            mock,
            tiny_cfg(),
            dummy_frozen,
            dummy_trainable,
        )
        .unwrap();
        let client = server.client();
        for expect_err in [true, true, true, false] {
            let r = client.infer(&[1, 2, 3]);
            if expect_err {
                let e = format!("{:#}", r.unwrap_err());
                assert!(
                    e.contains("output") || e.contains("dtype") || e.contains("shape"),
                    "unexpected error: {e}"
                );
            } else {
                let reply = r.unwrap();
                assert_eq!(reply.next_token, 0); // zero logits -> argmax 0
            }
        }
        let m = server.shutdown();
        assert_eq!(m.batches, 4);
        assert_eq!(m.failed, 3);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn engine_error_fans_to_batch_and_serving_continues() {
        let info = ExecBackend::native().config("tiny").unwrap();
        let mock = MockExec::new(info.clone());
        mock.push(Err("transient device loss".into()));
        let dummy: Vec<Tensor> =
            info.frozen.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let dummy_t: Vec<Tensor> =
            info.trainable.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let server = Server::start_with_params(mock, tiny_cfg(), dummy, dummy_t).unwrap();
        let client = server.client();
        let e = format!("{:#}", client.infer(&[1]).unwrap_err());
        assert!(e.contains("transient device loss"), "{e}");
        assert!(client.infer(&[1]).is_ok());
        let m = server.shutdown();
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn nan_logits_do_not_panic_the_batcher() {
        let info = ExecBackend::native().config("tiny").unwrap();
        let mock = MockExec::new(info.clone());
        let mut logits = vec![f32::NAN; info.train_batch * info.vocab];
        // One finite value in row 0: total_cmp must find it.
        logits[3] = 1.5;
        mock.push(Ok(vec![Tensor::f32(
            vec![info.train_batch, info.vocab],
            logits,
        )]));
        let dummy: Vec<Tensor> =
            info.frozen.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let dummy_t: Vec<Tensor> =
            info.trainable.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let server = Server::start_with_params(mock, tiny_cfg(), dummy, dummy_t).unwrap();
        let reply = server.client().infer(&[1, 2]).unwrap();
        assert_eq!(reply.next_token, 3);
        assert_eq!(reply.logit, 1.5);
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn argmax_is_nan_safe_and_deterministic() {
        assert_eq!(argmax(&[0.0, 2.0, 1.0]), (1, 2.0));
        assert_eq!(argmax(&[f32::NAN, 1.0, f32::NAN]), (1, 1.0));
        let (i, v) = argmax(&[f32::NAN, f32::NAN]);
        assert_eq!(i, 0); // ties (incl. all-NaN) keep the first index
        assert!(v.is_nan());
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), (1, -1.0));
    }

    #[test]
    fn validate_logits_rejects_malformed_outputs() {
        assert!(validate_logits(&[], 2, 4).is_err());
        assert!(validate_logits(&[Tensor::f32(vec![2, 3], vec![0.0; 6])], 2, 4).is_err());
        assert!(validate_logits(&[Tensor::i32(vec![2, 4], vec![0; 8])], 2, 4).is_err());
        let ok = [Tensor::f32(vec![2, 4], vec![0.0; 8])];
        assert_eq!(validate_logits(&ok, 2, 4).unwrap().len(), 8);
    }

    // --- PJRT-gated variants (skip without `make artifacts`) ---

    #[test]
    fn serves_single_request() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(&dir, tiny_cfg()).unwrap();
        let client = server.client();
        let reply = client.infer(&[1, 2, 3, 4]).unwrap();
        assert!(reply.next_token >= 0);
        assert!(reply.logit.is_finite());
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.batches, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(
            &dir,
            ServerCfg { config: "tiny".into(), max_wait: Duration::from_millis(100) },
        )
        .unwrap();
        let client = server.client();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || c.infer(&[i as i32 + 1, 2, 3]).unwrap())
            })
            .collect();
        let replies: Vec<Reply> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let m = server.shutdown();
        assert_eq!(m.completed, 4);
        // With a 100 ms window and 4 concurrent clients, batching should
        // pack more than one request per executable call.
        assert!(m.batches < 4, "batches {}", m.batches);
        assert!(replies.iter().any(|r| r.batch_occupancy > 1));
    }

    #[test]
    fn deterministic_given_params() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(&dir, tiny_cfg()).unwrap();
        let client = server.client();
        let a = client.infer(&[5, 6, 7]).unwrap();
        let b = client.infer(&[5, 6, 7]).unwrap();
        assert_eq!(a.next_token, b.next_token);
        drop(server);
    }
}
