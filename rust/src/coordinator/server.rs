//! Serving coordinator: batched inference over the Tier-2 fused-forward
//! artifact (`infer_<cfg>_fused`).
//!
//! vLLM-router-style shape: clients submit token prompts to a bounded
//! queue; a batcher thread groups up to `batch` requests within a
//! `max_wait` window (batch-or-timeout policy), pads them into the fixed
//! [bs, seq] artifact shape, executes one PJRT call, and fans the
//! last-position logits back to per-request channels. Metrics record
//! per-request latency and batch occupancy so the bench harness can sweep
//! the batching policy.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::{Engine, Tensor};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// Manifest config name (must have an `infer_<cfg>_fused` artifact).
    pub config: String,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg { config: "small".into(), max_wait: Duration::from_millis(20) }
    }
}

/// One inference request: a prompt, answered with next-token logits.
struct Request {
    prompt: Vec<i32>,
    enqueued: Instant,
    reply: SyncSender<Result<Reply>>,
}

/// Response: argmax token + its logit + timing.
#[derive(Debug, Clone)]
pub struct Reply {
    pub next_token: i32,
    pub logit: f32,
    pub latency: Duration,
    /// How many real requests shared the batch.
    pub batch_occupancy: usize,
}

/// Aggregated serving metrics.
#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    pub completed: u64,
    pub batches: u64,
    pub latencies_us: Vec<f64>,
    pub occupancies: Vec<f64>,
    /// Compose backend the kernel registry selects for this config's
    /// inference shape (Tier-2 path), recorded at startup.
    pub compose_backend: String,
}

impl ServerMetrics {
    pub fn p50_us(&self) -> f64 {
        crate::util::stats::percentile(&self.latencies_us, 50.0)
    }

    pub fn p95_us(&self) -> f64 {
        crate::util::stats::percentile(&self.latencies_us, 95.0)
    }

    pub fn mean_occupancy(&self) -> f64 {
        crate::util::stats::mean(&self.occupancies)
    }
}

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    seq: usize,
    vocab: usize,
}

impl Client {
    /// Blocking single-shot inference: returns the next-token prediction.
    pub fn infer(&self, prompt: &[i32]) -> Result<Reply> {
        if prompt.is_empty() || prompt.len() > self.seq {
            bail!("prompt length {} outside 1..={}", prompt.len(), self.seq);
        }
        if let Some(&t) = prompt.iter().find(|&&t| t < 0 || t as usize >= self.vocab) {
            bail!("token {t} outside vocab 0..{}", self.vocab);
        }
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request { prompt: prompt.to_vec(), enqueued: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx.recv().context("server dropped request")?
    }
}

/// The running server: owns the batcher thread.
pub struct Server {
    client_tx: Sender<Request>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServerMetrics>>,
    join: Option<std::thread::JoinHandle<()>>,
    seq: usize,
    vocab: usize,
}

impl Server {
    /// Start the batcher thread over the given artifacts directory.
    /// PJRT client types are not Send, so the batcher thread constructs
    /// its OWN engine from the directory; host tensors (plain data) are
    /// what crosses the thread boundary.
    pub fn start(artifacts_dir: &Path, cfg: ServerCfg) -> Result<Server> {
        // Serving needs model parameters; initialize from seed 0 by
        // default (callers with a trained adapter use `start_with_params`).
        let engine = Engine::load(artifacts_dir)?;
        let info = engine.manifest().config(&cfg.config)?.clone();
        let outs = engine.run(&format!("init_{}", cfg.config), &[Tensor::scalar_i32(0)])?;
        let nf = info.frozen.len();
        Self::start_with_params(artifacts_dir, cfg, outs[..nf].to_vec(), outs[nf..].to_vec())
    }

    /// Start with explicit parameters (e.g. a Trainer's adapted weights).
    pub fn start_with_params(
        artifacts_dir: &Path,
        cfg: ServerCfg,
        frozen: Vec<Tensor>,
        trainable: Vec<Tensor>,
    ) -> Result<Server> {
        // Validate config + shapes up front, on a throwaway engine, so
        // startup errors surface synchronously.
        let probe = Engine::load(artifacts_dir)?;
        let info = probe.manifest().config(&cfg.config)?.clone();
        if frozen.len() != info.frozen.len() || trainable.len() != info.trainable.len() {
            bail!(
                "param count mismatch: got {}+{}, config wants {}+{}",
                frozen.len(),
                trainable.len(),
                info.frozen.len(),
                info.trainable.len()
            );
        }
        drop(probe);
        let artifact = format!("infer_{}_fused", cfg.config);
        let (tx, rx): (Sender<Request>, Receiver<Request>) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(ServerMetrics {
            compose_backend: super::compose_plan(&info, false).backend.name().to_string(),
            ..ServerMetrics::default()
        }));

        let bs = info.train_batch;
        let seq = info.seq;
        let vocab = info.vocab;
        let stop2 = stop.clone();
        let metrics2 = metrics.clone();
        let max_wait = cfg.max_wait;
        let dir: PathBuf = artifacts_dir.to_path_buf();

        let join = std::thread::spawn(move || {
            let engine = match Engine::load(&dir) {
                Ok(e) => e,
                Err(_) => return, // start() already validated; unreachable
            };
            if engine.executable(&artifact).is_err() {
                return;
            }
            batcher_loop(
                engine, artifact, frozen, trainable, rx, stop2, metrics2, bs, seq, vocab, max_wait,
            );
        });

        Ok(Server { client_tx: tx, stop, metrics, join: Some(join), seq, vocab })
    }

    pub fn client(&self) -> Client {
        Client { tx: self.client_tx.clone(), seq: self.seq, vocab: self.vocab }
    }

    pub fn metrics(&self) -> ServerMetrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Stop the batcher and join.
    pub fn shutdown(mut self) -> ServerMetrics {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        let m = self.metrics.lock().unwrap().clone();
        m
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    engine: Engine,
    artifact: String,
    frozen: Vec<Tensor>,
    trainable: Vec<Tensor>,
    rx: Receiver<Request>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServerMetrics>>,
    bs: usize,
    seq: usize,
    vocab: usize,
    max_wait: Duration,
) {
    while !stop.load(Ordering::SeqCst) {
        // Collect up to `bs` requests, waiting at most `max_wait` after
        // the first arrival (batch-or-timeout).
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + max_wait;
        while batch.len() < bs {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Pad into the fixed [bs, seq] shape: left-pad each prompt with
        // token 0, unused rows are zeros (their outputs are discarded).
        let mut tokens = vec![0i32; bs * seq];
        for (row, req) in batch.iter().enumerate() {
            let p = &req.prompt;
            let start = seq - p.len();
            tokens[row * seq + start..(row + 1) * seq].copy_from_slice(p);
        }

        let mut inputs: Vec<Tensor> = Vec::new();
        inputs.extend(frozen.iter().cloned());
        inputs.extend(trainable.iter().cloned());
        inputs.push(Tensor::i32(vec![bs, seq], tokens));

        let occupancy = batch.len();
        let result = engine.run(&artifact, &inputs);
        let mut m = metrics.lock().unwrap();
        m.batches += 1;
        match result {
            Ok(outs) => {
                let logits = outs[0].as_f32().unwrap_or(&[]);
                for (row, req) in batch.into_iter().enumerate() {
                    let row_logits = &logits[row * vocab..(row + 1) * vocab];
                    let (next, &logit) = row_logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, v)| (i as i32, v))
                        .unwrap_or((0, &0.0));
                    let latency = req.enqueued.elapsed();
                    m.completed += 1;
                    m.latencies_us.push(latency.as_secs_f64() * 1e6);
                    m.occupancies.push(occupancy as f64);
                    let _ = req.reply.send(Ok(Reply {
                        next_token: next,
                        logit,
                        latency,
                        batch_occupancy: occupancy,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for req in batch {
                    let _ = req.reply.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::default_dir;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = default_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    fn tiny_cfg() -> ServerCfg {
        ServerCfg { config: "tiny".into(), max_wait: Duration::from_millis(5) }
    }

    #[test]
    fn serves_single_request() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(&dir, tiny_cfg()).unwrap();
        let client = server.client();
        let reply = client.infer(&[1, 2, 3, 4]).unwrap();
        assert!(reply.next_token >= 0);
        assert!(reply.logit.is_finite());
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.batches, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(
            &dir,
            ServerCfg { config: "tiny".into(), max_wait: Duration::from_millis(100) },
        )
        .unwrap();
        let client = server.client();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || c.infer(&[i as i32 + 1, 2, 3]).unwrap())
            })
            .collect();
        let replies: Vec<Reply> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let m = server.shutdown();
        assert_eq!(m.completed, 4);
        // With a 100 ms window and 4 concurrent clients, batching should
        // pack more than one request per executable call.
        assert!(m.batches < 4, "batches {}", m.batches);
        assert!(replies.iter().any(|r| r.batch_occupancy > 1));
    }

    #[test]
    fn rejects_invalid_prompts() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(&dir, tiny_cfg()).unwrap();
        let client = server.client();
        assert!(client.infer(&[]).is_err());
        assert!(client.infer(&vec![0; 10_000]).is_err());
        assert!(client.infer(&[-1]).is_err());
        assert!(client.infer(&[1_000_000]).is_err());
        drop(server);
    }

    #[test]
    fn deterministic_given_params() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(&dir, tiny_cfg()).unwrap();
        let client = server.client();
        let a = client.infer(&[5, 6, 7]).unwrap();
        let b = client.infer(&[5, 6, 7]).unwrap();
        assert_eq!(a.next_token, b.next_token);
        drop(server);
    }
}
