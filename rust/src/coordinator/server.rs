//! Serving coordinator: batched inference over the typed infer ops,
//! hosting **many named adapters** across a **pool of worker engines**.
//!
//! vLLM-router-style shape: clients submit token prompts — optionally
//! routed to a named adapter ([`Client::infer_with`]) — to a bounded
//! queue; a batcher thread collects up to `batch` requests within a
//! `max_wait` window (batch-or-timeout policy), groups them **by
//! adapter**, and dispatches each group as a job to an
//! [`EnginePool`](crate::runtime::EnginePool) worker chosen by adapter
//! affinity — so batches for different adapters execute concurrently on
//! different engines instead of serializing behind one engine lock. The
//! worker pads its group into the fixed [bs, seq] shape, executes one
//! typed infer per group, and fans the last-position logits back to
//! per-request channels. Metrics record per-request latency and batch
//! occupancy globally, per adapter, and per worker.
//!
//! Two inference paths serve a group ([`FastPath`], policy in
//! [`ServerCfg`], effective path in [`ServerMetrics`]):
//!
//! * **Merged** (default): the adapter's precomputed
//!   [`MergedParams`] — `W' = m ⊙ (W + s·B·A) / rownorm(W + s·B·A)`,
//!   built ONCE at [`Server::load_adapter`] / [`Server::hot_load`] time
//!   via the factored-norm kernels — turn steady-state inference into
//!   one plain matmul per layer. Falls back per adapter to Composed when
//!   the merge is impossible (malformed leaves) and globally when the
//!   backend has no merged artifact (PJRT manifests).
//! * **Composed**: the full DoRA composition per request (norm + four
//!   kernels), exactly the Tier-2 path training validates against.
//!
//! Invalidation protocol: an adapter's table slot holds ONE immutable
//! entry (`Arc<{params, merged}>`) — the merged weights are built before
//! the slot swap, and [`Server::load_adapter`] replaces the whole entry
//! atomically under the table lock. A group job snapshots the entry once,
//! so it either serves the old parameters+merge or the new
//! parameters+merge, never a torn mix; in-flight batches keep the
//! snapshot they already took.
//!
//! **Multi-tenant budget.** Under [`ServerCfg::merge_budget`] resident
//! merged weights are capped at an explicit byte budget
//! ([`MergedCache`]): cold adapters serve immediately on the composed
//! path while a builder thread merges them off the hot path; the
//! finished merge is promoted atomically into the entry's [`MergeSlot`]
//! (the same torn-weight-free exchange as hot-swap) after LRU/clock
//! eviction makes room, and adapters pinned by an in-flight decode
//! stream are evict-exempt (DESIGN.md §3.10). Without a budget every
//! merge is built eagerly at load time — the original behavior.
//!
//! The server runs over any [`BackendSpec`]: PJRT over an artifacts
//! directory, the native kernel-registry engine, or a scripted mock.
//! Pool workers reconnect the spec on their own threads (PJRT clients are
//! not `Send`); everything fallible is validated synchronously on a probe
//! connection plus the pool's startup handshake, so startup fails fast
//! instead of leaving clients to time out against a dead thread.
//!
//! Robustness contract: a worker never panics on malformed engine
//! output — a bad group fans an `Err` to each of its requests and the
//! pool keeps serving; and no metrics mutex is ever `unwrap()`ed, so a
//! panicking worker cannot poison later `metrics()` calls into panics.
//!
//! Next to the one-shot batcher, the server runs a second data path: a
//! streaming decode scheduler ([`super::scheduler`]) with continuous
//! batching, reached through [`Client::generate`]. Both paths share one
//! [`EnginePool`] and one adapter table; the scheduler has its own
//! bounded admission queue ([`ServerCfg::queue_depth`]) with typed
//! [`Overloaded`](super::scheduler::Overloaded) load-shedding and SLO
//! metrics (TTFT / per-token latency histograms, queue-depth gauges).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::models::forward;
use crate::runtime::ops::{
    AdapterParams, AdapterVariant, InferMergedReq, InferReq, InitReq, MergedParams, Precision,
    Variant,
};
use crate::runtime::{
    Adapter, AdapterStore, BackendSpec, CachePolicy, ConfigInfo, EnginePool, ExecBackend,
    MergeSlot, MergedCache, Tensor,
};
use crate::util::lock_unpoisoned;

use super::scheduler::{DecodeScheduler, DecodeShared, GenOptions, GenRequest, GenStream};

/// The adapter name single-adapter entrypoints register under, and the
/// route [`Client::infer`] takes when the caller names no adapter.
pub const DEFAULT_ADAPTER: &str = "default";

/// Which inference path serves steady-state requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FastPath {
    /// Precomputed merged weights (one matmul per layer). Per-adapter
    /// best-effort: adapters whose merge fails serve Composed, and
    /// backends without the merged artifact serve Composed globally.
    #[default]
    Merged,
    /// The full DoRA composition on every request.
    Composed,
}

impl FastPath {
    pub fn as_str(self) -> &'static str {
        match self {
            FastPath::Merged => "merged",
            FastPath::Composed => "composed",
        }
    }

    pub fn parse(s: &str) -> Result<FastPath> {
        match s {
            "merged" => Ok(FastPath::Merged),
            "composed" => Ok(FastPath::Composed),
            other => bail!("fast path must be merged|composed, got {other:?}"),
        }
    }
}

/// How merged weights are built, resolved at startup from the effective
/// fast path and [`ServerCfg::merge_budget`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MergeMode {
    /// Composed policy (or a backend without the merged artifact): no
    /// merges are ever built.
    Off,
    /// Merged policy, no budget: merge synchronously at load time, the
    /// original behavior.
    Eager,
    /// Merged policy under a byte budget: serve composed until the
    /// builder thread promotes an async merge into the cache.
    Lazy,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerCfg {
    /// Manifest config name (must have an `infer_<cfg>_fused` artifact).
    pub config: String,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Worker engines in the serving pool. 0 = auto: available
    /// parallelism, capped at the number of initially loaded adapters
    /// (affinity routing can't use more workers than adapters).
    pub workers: usize,
    /// Requested inference fast path (the effective path is recorded in
    /// [`ServerMetrics::fast_path`]).
    pub fast_path: FastPath,
    /// Bound on the streaming-decode admission queue: [`Client::generate`]
    /// calls beyond this many waiting requests are shed with a typed
    /// [`Overloaded`](super::scheduler::Overloaded) error instead of
    /// queueing unboundedly.
    pub queue_depth: usize,
    /// Byte budget for resident merged weights (`--merge-budget-mb`).
    /// `None` (the default) merges every adapter eagerly at load time —
    /// the unbudgeted legacy behavior. `Some(bytes)` serves cold
    /// adapters composed while merges build asynchronously and are
    /// promoted/evicted under the budget (only meaningful with the
    /// Merged fast path).
    pub merge_budget: Option<u64>,
    /// Eviction policy for the budgeted merged-weight cache
    /// (`--cache-policy`).
    pub cache_policy: CachePolicy,
    /// Serving precision: `Bf16` serves bf16-rounded weights and
    /// activations (merged replicas account HALF the f32 bytes under
    /// [`ServerCfg::merge_budget`], so the same budget fits ~2× the
    /// adapters); `F32` is the historical full-precision path.
    pub precision: Precision,
}

impl Default for ServerCfg {
    fn default() -> Self {
        ServerCfg {
            config: "small".into(),
            max_wait: Duration::from_millis(20),
            workers: 0,
            fast_path: FastPath::Merged,
            queue_depth: 32,
            merge_budget: None,
            cache_policy: CachePolicy::Lru,
            precision: Precision::F32,
        }
    }
}

/// One inference request: a prompt routed to a named adapter, answered
/// with next-token logits.
struct Request {
    adapter: String,
    prompt: Vec<i32>,
    enqueued: Instant,
    reply: SyncSender<Result<Reply>>,
}

/// Response: the full last-position logits row plus the argmax summary
/// and timing.
#[derive(Debug, Clone)]
pub struct Reply {
    pub next_token: i32,
    pub logit: f32,
    /// The request's full `[vocab]` logits row.
    pub logits: Vec<f32>,
    /// Which adapter served the request.
    pub adapter: String,
    pub latency: Duration,
    /// How many real requests shared the engine call.
    pub batch_occupancy: usize,
    /// Which path actually served this reply. Under a merge budget the
    /// same adapter answers [`FastPath::Composed`] while cold and
    /// [`FastPath::Merged`] once its merge is promoted.
    pub path: FastPath,
}

/// Per-adapter serving counters (one entry per adapter name routed to).
#[derive(Debug, Default, Clone)]
pub struct AdapterMetrics {
    pub completed: u64,
    pub failed: u64,
    /// Engine calls executed for this adapter.
    pub batches: u64,
    /// Engine calls served from the merged fast path.
    pub merged_batches: u64,
    /// Engine calls served from the composed path.
    pub composed_batches: u64,
    pub latencies_us: Vec<f64>,
    pub occupancies: Vec<f64>,
}

impl AdapterMetrics {
    pub fn p50_us(&self) -> f64 {
        crate::util::stats::percentile(&self.latencies_us, 50.0)
    }

    pub fn p95_us(&self) -> f64 {
        crate::util::stats::percentile(&self.latencies_us, 95.0)
    }

    pub fn mean_occupancy(&self) -> f64 {
        crate::util::stats::mean(&self.occupancies)
    }
}

/// Per-worker serving counters (indexed by pool worker).
#[derive(Debug, Default, Clone)]
pub struct WorkerMetrics {
    /// Engine calls this worker executed.
    pub batches: u64,
    pub completed: u64,
    pub failed: u64,
}

/// Aggregated serving metrics (global plus per-adapter and per-worker).
#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    pub completed: u64,
    /// Requests answered with an error (engine failure, malformed engine
    /// output, or unknown adapter). The pool stays up; this counts what
    /// it shed.
    pub failed: u64,
    /// Engine calls executed (one per adapter group per collected batch).
    pub batches: u64,
    /// Engine calls served from the merged fast path.
    pub merged_batches: u64,
    /// Engine calls served from the composed path.
    pub composed_batches: u64,
    pub latencies_us: Vec<f64>,
    pub occupancies: Vec<f64>,
    /// Per-adapter breakdown of the same counters.
    pub per_adapter: BTreeMap<String, AdapterMetrics>,
    /// Per-worker breakdown (length = pool size).
    pub per_worker: Vec<WorkerMetrics>,
    /// Adapters loaded or replaced while the server was running.
    pub hot_loads: u64,
    /// Adapters that requested the merged path but fell back to composed
    /// (merge failed on their leaves).
    pub merge_fallbacks: u64,
    /// Worker engines in the serving pool.
    pub workers: usize,
    /// Effective fast path ("merged" / "composed").
    pub fast_path: String,
    /// Compose backend the kernel registry selects for this config's
    /// inference shape (Tier-2 path), recorded at startup.
    pub compose_backend: String,
    /// Execution backend kind ("pjrt" / "native" / "mock").
    pub exec_backend: String,

    // --- Streaming-decode (scheduler) counters and SLO histograms ---
    /// Streaming requests admitted into a decode slot.
    pub decode_requests: u64,
    /// Streams that finished (EOS or max-tokens).
    pub decode_completed: u64,
    /// Streams answered with an engine/shutdown error.
    pub decode_failed: u64,
    /// Streams cancelled by a client dropping its [`GenStream`].
    pub decode_cancelled: u64,
    /// Tokens delivered to streaming clients.
    pub decode_tokens: u64,
    /// Batched decode-step engine calls executed.
    pub decode_steps: u64,
    /// Streaming requests rejected with `Overloaded` (gauge snapshot of
    /// the shed counter, filled by [`Server::metrics`]).
    pub shed_requests: u64,
    /// Streaming requests waiting for admission (gauge, filled by
    /// [`Server::metrics`]).
    pub decode_queue_depth: usize,
    /// Requests currently decoding in the continuous batch (gauge,
    /// filled by [`Server::metrics`]).
    pub decode_in_flight: usize,
    /// Per-request time-to-first-token samples (µs, submit -> first
    /// token event).
    pub ttft_us: Vec<f64>,
    /// Per-token decode latency samples (µs, step-to-step, first token
    /// excluded — that one is TTFT).
    pub token_latency_us: Vec<f64>,

    // --- Merged-weight cache (budgeted multi-tenant serving). All of
    // these are snapshots of the cache's own accounting, filled by
    // [`Server::metrics`]; in eager (unbudgeted) mode the gauges reflect
    // the unbounded cache (misses/evictions stay 0). ---
    /// Serves that found a resident merge (one per one-shot engine call
    /// or admitted stream).
    pub cache_hits: u64,
    /// Serves that found the slot cold and ran composed.
    pub cache_misses: u64,
    /// Merges evicted under budget pressure.
    pub cache_evictions: u64,
    /// Merges promoted to resident.
    pub cache_promotions: u64,
    /// Built merges rejected at promotion (did not fit the budget).
    pub cache_rejects: u64,
    /// Built merges discarded because a hot-swap outran the build.
    pub cache_stale_discards: u64,
    /// Accounted resident merged bytes (gauge, 512-byte rounded).
    pub cache_resident_bytes: u64,
    /// Peak accounted resident bytes over the server's lifetime.
    pub cache_high_water_bytes: u64,
    /// Configured merge budget in bytes (0 = unbounded).
    pub merge_budget_bytes: u64,
    /// Resident merge count (gauge).
    pub cache_resident: usize,
    /// Adapters currently pinned by in-flight decode streams (gauge).
    pub cache_pinned: usize,
    /// Names of the adapters whose merges are resident (gauge, sorted).
    pub resident_adapters: Vec<String>,
}

impl ServerMetrics {
    pub fn p50_us(&self) -> f64 {
        crate::util::stats::percentile(&self.latencies_us, 50.0)
    }

    pub fn p95_us(&self) -> f64 {
        crate::util::stats::percentile(&self.latencies_us, 95.0)
    }

    pub fn mean_occupancy(&self) -> f64 {
        crate::util::stats::mean(&self.occupancies)
    }

    /// Streaming SLO: median time-to-first-token (µs).
    pub fn ttft_p50_us(&self) -> f64 {
        crate::util::stats::percentile(&self.ttft_us, 50.0)
    }

    /// Streaming SLO: p99 time-to-first-token (µs).
    pub fn ttft_p99_us(&self) -> f64 {
        crate::util::stats::percentile(&self.ttft_us, 99.0)
    }

    /// Streaming SLO: median per-token latency (µs).
    pub fn token_p50_us(&self) -> f64 {
        crate::util::stats::percentile(&self.token_latency_us, 50.0)
    }

    /// Streaming SLO: p99 per-token latency (µs).
    pub fn token_p99_us(&self) -> f64 {
        crate::util::stats::percentile(&self.token_latency_us, 99.0)
    }
}

/// Monotonic generation counter for adapter entries. Each load/hot-swap
/// mints a fresh generation; the merged-weight cache keys residency and
/// build claims on it, so a merge built against a replaced entry is
/// recognized as stale and discarded instead of published.
static NEXT_ENTRY_GEN: AtomicU64 = AtomicU64::new(1);

/// One adapter's serving state: the parameter snapshot plus the
/// publication slot its merged weights appear in (filled at load time in
/// eager mode, or by the async builder after cache promotion in budgeted
/// mode; empty while cold/evicted — the composed fallback). The params
/// and variant are immutable once built — hot-loads swap the whole
/// entry. `pub(crate)` so the decode scheduler can pin a request's
/// snapshot at admission time.
pub(crate) struct AdapterEntry {
    pub(crate) params: Arc<AdapterParams>,
    /// Which compose math this adapter's requests (and its merge) use.
    pub(crate) variant: AdapterVariant,
    /// Cache generation this entry was registered under.
    pub(crate) gen: u64,
    pub(crate) merged: Arc<MergeSlot>,
}

/// The shared adapter table: name -> entry snapshot. Slots hold `Arc`s so
/// a worker snapshots a group's entry with one refcount bump, never a
/// deep copy under the lock — and a concurrent hot-load can never expose
/// a half-updated (torn) parameter/merge pair.
type SharedAdapters = Arc<Mutex<BTreeMap<String, Arc<AdapterEntry>>>>;

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    adapters: SharedAdapters,
    decode: Arc<DecodeShared>,
    default_adapter: String,
    seq: usize,
    vocab: usize,
}

impl Client {
    /// Blocking single-shot inference on the server's default adapter.
    pub fn infer(&self, prompt: &[i32]) -> Result<Reply> {
        self.infer_with(&self.default_adapter, prompt)
    }

    /// Blocking single-shot inference routed to a named adapter.
    pub fn infer_with(&self, adapter: &str, prompt: &[i32]) -> Result<Reply> {
        if prompt.is_empty() || prompt.len() > self.seq {
            bail!("prompt length {} outside 1..={}", prompt.len(), self.seq);
        }
        if let Some(&t) = prompt.iter().find(|&&t| t < 0 || t as usize >= self.vocab) {
            bail!("token {t} outside vocab 0..{}", self.vocab);
        }
        // Fail fast on an unknown adapter (the worker re-checks, so a
        // concurrent unload between here and execution is still safe).
        if !lock_unpoisoned(&self.adapters).contains_key(adapter) {
            bail!("adapter {adapter:?} is not loaded on this server");
        }
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request {
                adapter: adapter.to_string(),
                prompt: prompt.to_vec(),
                enqueued: Instant::now(),
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx.recv().context("server dropped request")?
    }

    /// Streaming autoregressive decode on the server's default adapter:
    /// returns a [`GenStream`] yielding one token event per decode step
    /// as the continuous-batching scheduler produces them.
    pub fn generate(&self, prompt: &[i32], opts: GenOptions) -> Result<GenStream> {
        self.generate_with(&self.default_adapter, prompt, opts)
    }

    /// [`Client::generate`] routed to a named adapter. Fails fast with a
    /// typed [`Overloaded`](super::scheduler::Overloaded) error when the
    /// admission queue is full (downcast to distinguish from validation
    /// errors). The adapter entry is snapshotted here, so the stream
    /// decodes against one consistent parameter set even across a
    /// concurrent hot-load.
    pub fn generate_with(
        &self,
        adapter: &str,
        prompt: &[i32],
        opts: GenOptions,
    ) -> Result<GenStream> {
        if prompt.is_empty() || prompt.len() > self.seq {
            bail!("prompt length {} outside 1..={}", prompt.len(), self.seq);
        }
        if let Some(&t) = prompt.iter().find(|&&t| t < 0 || t as usize >= self.vocab) {
            bail!("token {t} outside vocab 0..{}", self.vocab);
        }
        if opts.max_tokens == 0 {
            bail!("max_tokens must be >= 1");
        }
        if let Some(e) = opts.eos {
            if e < 0 || e as usize >= self.vocab {
                bail!("eos token {e} outside vocab 0..{}", self.vocab);
            }
        }
        let entry = lock_unpoisoned(&self.adapters).get(adapter).cloned();
        let Some(entry) = entry else {
            bail!("adapter {adapter:?} is not loaded on this server");
        };
        let (tx, rx) = mpsc::channel();
        self.decode.try_push(GenRequest {
            adapter: adapter.to_string(),
            entry,
            prompt: prompt.to_vec(),
            opts,
            tx,
            enqueued: Instant::now(),
        })?;
        Ok(GenStream::new(rx))
    }

    /// Blocking convenience: run [`Client::generate`] and collect the
    /// full decoded token sequence.
    pub fn generate_collect(&self, prompt: &[i32], opts: GenOptions) -> Result<Vec<i32>> {
        self.generate(prompt, opts)?.collect()
    }

    /// Blocking convenience: [`Client::generate_with`] + collect.
    pub fn generate_collect_with(
        &self,
        adapter: &str,
        prompt: &[i32],
        opts: GenOptions,
    ) -> Result<Vec<i32>> {
        self.generate_with(adapter, prompt, opts)?.collect()
    }

    /// Adapter names currently loaded (snapshot).
    pub fn adapters(&self) -> Vec<String> {
        lock_unpoisoned(&self.adapters).keys().cloned().collect()
    }
}

/// The running server: owns the batcher thread (which owns the engine
/// pool) and the adapter table.
pub struct Server {
    client_tx: Sender<Request>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServerMetrics>>,
    adapters: SharedAdapters,
    decode: Arc<DecodeShared>,
    cache: Arc<MergedCache>,
    /// Submit side of the async merge builder (budgeted mode only).
    merge_tx: Option<Sender<BuildReq>>,
    join: Option<std::thread::JoinHandle<()>>,
    sched_join: Option<std::thread::JoinHandle<()>>,
    merge_join: Option<std::thread::JoinHandle<()>>,
    info: ConfigInfo,
    default_adapter: String,
    /// Effective fast path (policy after backend-support resolution).
    fast_path: FastPath,
    merge_mode: MergeMode,
    /// Serving precision every entry is built and served under.
    precision: Precision,
}

impl Server {
    /// Start with one seed-0 initialized adapter registered under
    /// [`DEFAULT_ADAPTER`] (callers with trained adapters use
    /// [`Server::start_with_params`] or [`Server::start_with_adapters`]).
    /// Accepts anything that converts to a [`BackendSpec`]: an artifacts
    /// directory path (PJRT), `BackendSpec::Native`, `BackendSpec::auto()`,
    /// or a mock.
    pub fn start(spec: impl Into<BackendSpec>, cfg: ServerCfg) -> Result<Server> {
        let spec = spec.into();
        let backend = spec.connect()?;
        let init = backend.init(InitReq {
            config: cfg.config.clone(),
            seed: 0,
            precision: cfg.precision,
        })?;
        // Reuse the already-connected backend as the validation probe
        // (on PJRT a fresh connect would re-load the engine and
        // re-compile the infer executable for nothing).
        Self::start_with_probe(
            spec,
            backend,
            cfg,
            vec![(DEFAULT_ADAPTER.to_string(), init.params, AdapterVariant::Dora)],
        )
    }

    /// Start the server on the default backend (PJRT artifacts when
    /// usable, native otherwise).
    pub fn start_auto(cfg: ServerCfg) -> Result<Server> {
        Self::start(BackendSpec::auto(), cfg)
    }

    /// Start with one explicit parameter set (e.g. a Trainer's adapted
    /// weights), registered under [`DEFAULT_ADAPTER`].
    pub fn start_with_params(
        spec: impl Into<BackendSpec>,
        cfg: ServerCfg,
        frozen: Vec<Tensor>,
        trainable: Vec<Tensor>,
    ) -> Result<Server> {
        let spec = spec.into();
        let probe = spec.connect().context("connecting execution backend")?;
        Self::start_with_probe(
            spec,
            probe,
            cfg,
            vec![(
                DEFAULT_ADAPTER.to_string(),
                AdapterParams { frozen, trainable },
                AdapterVariant::Dora,
            )],
        )
    }

    /// Start hosting a set of named adapters. Every adapter must target
    /// `cfg.config`; the first becomes the default route for
    /// [`Client::infer`]. More adapters can be added (or replaced) later
    /// with [`Server::load_adapter`] / [`Server::hot_load`].
    pub fn start_with_adapters(
        spec: impl Into<BackendSpec>,
        cfg: ServerCfg,
        adapters: Vec<Adapter>,
    ) -> Result<Server> {
        if adapters.is_empty() {
            bail!("start_with_adapters needs at least one adapter");
        }
        for a in &adapters {
            if a.config != cfg.config {
                bail!(
                    "adapter {:?} targets config {:?}, server is configured for {:?}",
                    a.name,
                    a.config,
                    cfg.config
                );
            }
            // Serving a checkpoint at a different precision than it was
            // trained under silently changes its logits; reject up front
            // (pre-precision checkpoints decode as f32 and serve under
            // the default config unchanged).
            if a.precision != cfg.precision {
                bail!(
                    "adapter {:?} was trained at precision {:?}, server is configured for {:?}",
                    a.name,
                    a.precision.as_str(),
                    cfg.precision.as_str()
                );
            }
        }
        let spec = spec.into();
        let probe = spec.connect().context("connecting execution backend")?;
        Self::start_with_probe(
            spec,
            probe,
            cfg,
            adapters.into_iter().map(|a| (a.name, a.params, a.variant)).collect(),
        )
    }

    /// Shared startup tail: validate on `probe` (an engine already
    /// connected from `spec`), resolve the effective fast path, build the
    /// adapter entries (merging up front), start the worker pool, then
    /// spawn the batcher thread.
    ///
    /// All startup failure modes surface synchronously here: unknown
    /// config, per-adapter parameter mismatch, a missing/uncompilable
    /// `infer_<cfg>_fused` artifact, and a pool worker that cannot
    /// connect (previously a spawned thread died silently and clients
    /// hung).
    fn start_with_probe(
        spec: BackendSpec,
        probe: ExecBackend,
        cfg: ServerCfg,
        adapters: Vec<(String, AdapterParams, AdapterVariant)>,
    ) -> Result<Server> {
        let info = probe.config(&cfg.config)?;
        let default_adapter = adapters
            .first()
            .map(|(n, _, _)| n.clone())
            .context("no adapters to serve")?;
        let artifact =
            format!("infer_{}_fused{}", cfg.config, cfg.precision.token_suffix());
        probe
            .ensure_artifact(&artifact)
            .with_context(|| format!("validating serving artifact {artifact:?}"))?;
        // The merged policy engages only when the backend implements the
        // merged artifact (native and mock do; PJRT manifests don't).
        let fast_path = match cfg.fast_path {
            FastPath::Merged
                if probe
                    .ensure_artifact(&format!(
                        "infer_merged_{}{}",
                        cfg.config,
                        cfg.precision.token_suffix()
                    ))
                    .is_ok() =>
            {
                FastPath::Merged
            }
            _ => FastPath::Composed,
        };
        drop(probe);

        // Budgeted mode only engages when the merged path is effective;
        // eager mode runs the SAME cache unbounded, so the counters and
        // residency gauges are live in both.
        let merge_mode = match (fast_path, cfg.merge_budget) {
            (FastPath::Composed, _) => MergeMode::Off,
            (FastPath::Merged, None) => MergeMode::Eager,
            (FastPath::Merged, Some(_)) => MergeMode::Lazy,
        };
        let cache = Arc::new(match merge_mode {
            MergeMode::Lazy => {
                MergedCache::new(cfg.merge_budget.unwrap_or(u64::MAX), cfg.cache_policy)
            }
            _ => MergedCache::unbounded(cfg.cache_policy),
        });

        let mut merge_fallbacks = 0u64;
        let mut table = BTreeMap::new();
        for (name, params, variant) in adapters {
            validate_adapter_params(&info, &name, &params)?;
            let (entry, merged) = build_entry(
                &info,
                &name,
                params,
                variant,
                cfg.precision,
                merge_mode,
                &mut merge_fallbacks,
            );
            let entry = Arc::new(entry);
            // Register (and, eagerly-merged, promote) BEFORE the table
            // insert: a request can never observe the entry with its
            // merge still unpublished in eager mode.
            cache.register(&name, entry.gen);
            if let Some(m) = merged {
                cache.promote(&name, entry.gen, &entry.merged, m);
            }
            if table.insert(name.clone(), entry).is_some() {
                bail!("duplicate adapter name {name:?}");
            }
        }

        // The worker pool connects one engine per worker on its own
        // threads; a connect failure fails startup here, synchronously.
        // Auto sizing (workers = 0) caps at the initially loaded adapter
        // count: affinity routing can never use more workers than
        // adapters, so extra engines would only sit idle (hot-loaded
        // additional adapters share the pool; pass an explicit count to
        // provision for them up front).
        let workers = if cfg.workers == 0 {
            crate::dispatch::default_threads().min(table.len().max(1))
        } else {
            cfg.workers
        };
        // The pool is shared between the one-shot batcher and the decode
        // scheduler (both route by adapter affinity); it drains and joins
        // when the LAST holder drops.
        let pool = Arc::new(EnginePool::start(&spec, workers).context("starting serving pool")?);

        let (tx, rx): (Sender<Request>, Receiver<Request>) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(ServerMetrics {
            compose_backend: super::compose_plan(&info, false).backend.name().to_string(),
            exec_backend: spec.kind_name().to_string(),
            workers: pool.size(),
            fast_path: fast_path.as_str().to_string(),
            merge_fallbacks,
            per_worker: vec![WorkerMetrics::default(); pool.size()],
            ..ServerMetrics::default()
        }));
        let adapters: SharedAdapters = Arc::new(Mutex::new(table));

        // Budgeted mode: one builder thread merges cold adapters off the
        // serving hot path and offers the results for cache promotion.
        // It exits when the last BuildReq sender drops (batcher ctx,
        // scheduler, and the Server handle below).
        let (merge_tx, merge_join) = match merge_mode {
            MergeMode::Lazy => {
                let (btx, brx) = mpsc::channel::<BuildReq>();
                let (b_info, b_cache, b_metrics) =
                    (info.clone(), cache.clone(), metrics.clone());
                let b_precision = cfg.precision;
                let join = std::thread::Builder::new()
                    .name("merge-builder".into())
                    .spawn(move || {
                        run_merge_builder(brx, b_info, b_precision, b_cache, b_metrics)
                    })
                    .context("spawning merge builder")?;
                (Some(btx), Some(join))
            }
            _ => (None, None),
        };

        let ctx = Arc::new(GroupCtx {
            config: cfg.config.clone(),
            precision: cfg.precision,
            adapters: adapters.clone(),
            metrics: metrics.clone(),
            cache: cache.clone(),
            merge_tx: merge_tx.clone(),
            bs: info.train_batch,
            seq: info.seq,
            vocab: info.vocab,
        });
        let batcher = Batcher {
            ctx: ctx.clone(),
            stop: stop.clone(),
            max_wait: cfg.max_wait,
            pool: pool.clone(),
        };
        let join = std::thread::spawn(move || {
            batcher.run(rx);
            // Dropping the batcher releases its pool handle: queued jobs
            // drain and every in-flight reply is fanned before exit.
        });

        // The streaming-decode scheduler runs on its own thread, sharing
        // the pool and the metrics sink with the batcher.
        let decode = Arc::new(DecodeShared::new(cfg.queue_depth));
        let sched = DecodeScheduler {
            config: cfg.config.clone(),
            precision: cfg.precision,
            vocab: info.vocab,
            slots: info.train_batch,
            shared: decode.clone(),
            pool,
            metrics: metrics.clone(),
            cache: cache.clone(),
            merge_tx: merge_tx.clone(),
            stop: stop.clone(),
        };
        let sched_join = std::thread::spawn(move || sched.run());

        Ok(Server {
            client_tx: tx,
            stop,
            metrics,
            adapters,
            decode,
            cache,
            merge_tx,
            join: Some(join),
            sched_join: Some(sched_join),
            merge_join,
            info,
            default_adapter,
            fast_path,
            merge_mode,
            precision: cfg.precision,
        })
    }

    pub fn client(&self) -> Client {
        Client {
            tx: self.client_tx.clone(),
            adapters: self.adapters.clone(),
            decode: self.decode.clone(),
            default_adapter: self.default_adapter.clone(),
            seq: self.info.seq,
            vocab: self.info.vocab,
        }
    }

    /// Adapter names currently loaded, sorted.
    pub fn adapter_names(&self) -> Vec<String> {
        lock_unpoisoned(&self.adapters).keys().cloned().collect()
    }

    /// The adapter [`Client::infer`] routes to.
    pub fn default_adapter(&self) -> &str {
        &self.default_adapter
    }

    /// The effective fast path this server resolved at startup.
    pub fn fast_path(&self) -> FastPath {
        self.fast_path
    }

    /// Load or replace a named adapter **while serving**. FULLY validates
    /// the leaf set against the server's config (counts, per-leaf shapes,
    /// dtypes — a wrong-shaped hot-load is rejected here, synchronously,
    /// not discovered per request at the engine) and (under the merged
    /// policy) precomputes the merged weights BEFORE the slot swap; the
    /// table then exchanges the whole entry atomically, so in-flight
    /// batches keep the snapshot they already took and no request can
    /// ever see new parameters with stale merged weights (or vice versa).
    pub fn load_adapter(&self, name: &str, params: AdapterParams) -> Result<()> {
        self.load_adapter_variant(name, params, AdapterVariant::Dora)
    }

    /// [`Server::load_adapter`] with an explicit adapter variant (the
    /// checkpoint-carrying paths use this; bare parameter sets default to
    /// DoRA).
    pub fn load_adapter_variant(
        &self,
        name: &str,
        params: AdapterParams,
        variant: AdapterVariant,
    ) -> Result<()> {
        crate::runtime::adapters::validate_name(name)?;
        params.validate(&self.info, name)?;
        let mut fallbacks = 0u64;
        let (entry, merged) = build_entry(
            &self.info,
            name,
            params,
            variant,
            self.precision,
            self.merge_mode,
            &mut fallbacks,
        );
        let entry = Arc::new(entry);
        // Register the new generation first: the cache releases the old
        // entry's residency (in-flight snapshots of the OLD entry keep
        // serving its merge until they drain — see cache module docs)
        // and marks any still-running async build of it stale.
        self.cache.register(name, entry.gen);
        if let Some(m) = merged {
            self.cache.promote(name, entry.gen, &entry.merged, m);
        }
        lock_unpoisoned(&self.adapters).insert(name.to_string(), entry);
        let mut m = lock_unpoisoned(&self.metrics);
        m.hot_loads += 1;
        m.merge_fallbacks += fallbacks;
        Ok(())
    }

    /// Hot-load a named adapter from a checkpoint store (the trainer →
    /// store → server handoff without a restart).
    pub fn hot_load(&self, store: &AdapterStore, name: &str) -> Result<()> {
        let adapter = store.load(name)?;
        if adapter.config != self.info.name {
            bail!(
                "adapter {name:?} targets config {:?}, server is configured for {:?}",
                adapter.config,
                self.info.name
            );
        }
        if adapter.precision != self.precision {
            bail!(
                "adapter {name:?} was trained at precision {:?}, server is configured for {:?}",
                adapter.precision.as_str(),
                self.precision.as_str()
            );
        }
        self.load_adapter_variant(name, adapter.params, adapter.variant)
    }

    pub fn metrics(&self) -> ServerMetrics {
        let mut m = lock_unpoisoned(&self.metrics).clone();
        self.fill_gauges(&mut m);
        m
    }

    /// Copy the scheduler's live load gauges and the merged-weight
    /// cache's counters/gauges into a metrics snapshot.
    fn fill_gauges(&self, m: &mut ServerMetrics) {
        m.shed_requests = self.decode.shed.load(Ordering::Relaxed);
        m.decode_queue_depth = self.decode.queue_depth();
        m.decode_in_flight = self.decode.in_flight.load(Ordering::SeqCst);
        let cs = self.cache.stats();
        m.cache_hits = cs.hits;
        m.cache_misses = cs.misses;
        m.cache_evictions = cs.evictions;
        m.cache_promotions = cs.promotions;
        m.cache_rejects = cs.rejected;
        m.cache_stale_discards = cs.stale;
        m.cache_resident_bytes = cs.resident_bytes;
        m.cache_high_water_bytes = cs.high_water_bytes;
        m.merge_budget_bytes = cs.budget_bytes;
        m.cache_resident = cs.resident_count;
        m.cache_pinned = cs.pinned_count;
        m.resident_adapters = self.cache.resident().into_iter().map(|(n, _)| n).collect();
    }

    /// The cache's replayable residency event stream (one alloc per
    /// promotion, one free per eviction/replacement): replaying it on a
    /// fresh [`CachingAllocator`](crate::memsim::CachingAllocator)
    /// reconstructs [`ServerMetrics::cache_high_water_bytes`].
    pub fn mem_events(&self) -> Vec<crate::memsim::Event> {
        self.cache.events()
    }

    /// Join order on stop: the batcher and scheduler first (their exit
    /// drops the last pool handles, draining in-flight jobs and with
    /// them the GroupCtx/scheduler BuildReq senders), then our own
    /// sender, which lets the builder's `recv` disconnect and the
    /// builder thread exit.
    fn join_threads(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        if let Some(j) = self.sched_join.take() {
            let _ = j.join();
        }
        self.merge_tx.take();
        if let Some(j) = self.merge_join.take() {
            let _ = j.join();
        }
    }

    /// Stop the batcher, the decode scheduler (and their shared pool),
    /// and the merge builder, and join.
    pub fn shutdown(mut self) -> ServerMetrics {
        self.join_threads();
        let mut m = lock_unpoisoned(&self.metrics).clone();
        self.fill_gauges(&mut m);
        m
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.join_threads();
    }
}

/// Build one adapter's serving entry, plus — in eager mode — its merged
/// weights, ready for the caller to promote before publishing the entry.
/// The eager merge is best-effort: an adapter whose leaves cannot merge
/// (e.g. a scripted mock's placeholder tensors) serves the composed path
/// instead, counted in `fallbacks` — serving availability beats path
/// preference. In lazy (budgeted) mode no merge is built here; the first
/// cold serve schedules one on the builder thread.
fn build_entry(
    info: &ConfigInfo,
    name: &str,
    params: AdapterParams,
    variant: AdapterVariant,
    precision: Precision,
    mode: MergeMode,
    fallbacks: &mut u64,
) -> (AdapterEntry, Option<Arc<MergedParams>>) {
    let merged = match mode {
        MergeMode::Off | MergeMode::Lazy => None,
        MergeMode::Eager => match forward::merge_adapter_params(info, &params, variant, precision)
        {
            Ok(m) => Some(Arc::new(m)),
            Err(e) => {
                eprintln!(
                    "server: adapter {name:?}: merged fast path unavailable ({e:#}); \
                     serving composed"
                );
                *fallbacks += 1;
                None
            }
        },
    };
    let entry = AdapterEntry {
        params: Arc::new(params),
        variant,
        gen: NEXT_ENTRY_GEN.fetch_add(1, Ordering::Relaxed),
        merged: Arc::new(MergeSlot::empty()),
    };
    (entry, merged)
}

/// A queued async merge build (budgeted mode): the entry whose leaves
/// the builder thread should merge and offer for cache promotion.
pub(crate) struct BuildReq {
    pub(crate) name: String,
    pub(crate) entry: Arc<AdapterEntry>,
}

/// Builder-thread main loop (budgeted mode): merge each claimed entry's
/// leaves off the serving hot path and promote the result. A failed
/// merge is latched in the cache (no rebuild storm) and counted as a
/// fallback — the adapter keeps serving composed. Exits when every
/// sender is gone.
fn run_merge_builder(
    rx: Receiver<BuildReq>,
    info: ConfigInfo,
    precision: Precision,
    cache: Arc<MergedCache>,
    metrics: Arc<Mutex<ServerMetrics>>,
) {
    while let Ok(req) = rx.recv() {
        match forward::merge_adapter_params(&info, &req.entry.params, req.entry.variant, precision)
        {
            Ok(m) => {
                cache.promote(&req.name, req.entry.gen, &req.entry.merged, Arc::new(m));
            }
            Err(e) => {
                eprintln!(
                    "server: adapter {:?}: async merge failed ({e:#}); serving composed",
                    req.name
                );
                cache.build_failed(&req.name, req.entry.gen);
                lock_unpoisoned(&metrics).merge_fallbacks += 1;
            }
        }
    }
}

/// Leaf-count check for one adapter against the server config. Startup
/// deliberately validates counts only: scripted mock backends register
/// placeholder leaves the engine never reads (the robustness tests rely
/// on it). The hot-load path ([`Server::load_adapter`]) is strict — it
/// runs the full [`AdapterParams::validate`].
fn validate_adapter_params(info: &ConfigInfo, name: &str, params: &AdapterParams) -> Result<()> {
    if !params.matches(info) {
        bail!(
            "adapter {name:?}: param count mismatch — got {}+{}, config {} wants {}+{}",
            params.frozen.len(),
            params.trainable.len(),
            info.name,
            info.frozen.len(),
            info.trainable.len()
        );
    }
    Ok(())
}

/// NaN-safe argmax over one row of logits: NaN entries are skipped (a
/// `partial_cmp(..).unwrap()` here once panicked and killed the batcher
/// thread); ties keep the first index. A fully poisoned row degrades to a
/// deterministic `(0, NaN)` reply instead of a panic. Shared with the
/// decode scheduler's greedy sampling path.
pub(crate) fn argmax(row: &[f32]) -> (i32, f32) {
    let mut best: Option<usize> = None;
    for (i, v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some(b) if *v <= row[b] => {}
            _ => best = Some(i),
        }
    }
    match best {
        Some(b) => (b as i32, row[b]),
        None => (0, f32::NAN),
    }
}

/// State a group-serving job needs, shared between the batcher and every
/// pool worker.
struct GroupCtx {
    config: String,
    precision: Precision,
    adapters: SharedAdapters,
    metrics: Arc<Mutex<ServerMetrics>>,
    cache: Arc<MergedCache>,
    /// Builder-thread submit side; `None` outside budgeted mode.
    merge_tx: Option<Sender<BuildReq>>,
    bs: usize,
    seq: usize,
    vocab: usize,
}

/// The batcher thread's state: collects and groups requests, then
/// dispatches each adapter group to the pool.
struct Batcher {
    ctx: Arc<GroupCtx>,
    stop: Arc<AtomicBool>,
    max_wait: Duration,
    pool: Arc<EnginePool>,
}

impl Batcher {
    fn run(&self, rx: Receiver<Request>) {
        let bs = self.ctx.bs;
        while !self.stop.load(Ordering::SeqCst) {
            // Collect up to `bs` requests, waiting at most `max_wait`
            // after the first arrival (batch-or-timeout).
            let first = match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            };
            let mut batch = vec![first];
            let deadline = Instant::now() + self.max_wait;
            while batch.len() < bs {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => batch.push(r),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }

            // Group the collected batch by adapter and hand each group to
            // its affinity worker: one engine call per adapter present,
            // groups for different adapters executing concurrently. Same
            // adapter -> same worker -> per-adapter FIFO is preserved.
            let mut groups: BTreeMap<String, Vec<Request>> = BTreeMap::new();
            for req in batch {
                groups.entry(req.adapter.clone()).or_default().push(req);
            }
            for (adapter, group) in groups {
                let ctx = self.ctx.clone();
                let key = adapter.clone();
                self.pool.submit(
                    &key,
                    Box::new(move |worker, engine| {
                        serve_group(&ctx, engine, worker, &adapter, group);
                    }),
                );
            }
        }
    }
}

/// Execute one adapter's request group as a single engine call (merged
/// fast path when the entry carries merged weights, composed otherwise)
/// and fan the results (or the error) back to every request in it. Runs
/// on a pool worker's thread.
fn serve_group(
    ctx: &GroupCtx,
    engine: &ExecBackend,
    worker: usize,
    adapter: &str,
    group: Vec<Request>,
) {
    let (bs, seq, vocab) = (ctx.bs, ctx.seq, ctx.vocab);
    // Snapshot the adapter's entry (one Arc bump under the lock; a
    // concurrent hot-load swaps the slot without touching this
    // snapshot — parameters and merged weights stay consistent).
    let entry = lock_unpoisoned(&ctx.adapters).get(adapter).cloned();
    let Some(entry) = entry else {
        let n = group.len() as u64;
        let mut m = lock_unpoisoned(&ctx.metrics);
        m.failed += n;
        m.per_adapter.entry(adapter.to_string()).or_default().failed += n;
        if let Some(w) = m.per_worker.get_mut(worker) {
            w.failed += n;
        }
        drop(m);
        for req in group {
            let _ = req
                .reply
                .send(Err(anyhow::anyhow!("adapter {adapter:?} is not loaded")));
        }
        return;
    };

    // Pad into the fixed [bs, seq] shape: left-pad each prompt with
    // token 0, unused rows are zeros (their outputs are discarded).
    let mut tokens = vec![0i32; bs * seq];
    for (row, req) in group.iter().enumerate() {
        let p = &req.prompt;
        let start = seq - p.len();
        tokens[row * seq + start..(row + 1) * seq].copy_from_slice(p);
    }
    let tokens = Tensor::i32(vec![bs, seq], tokens);

    let occupancy = group.len();
    // Fast path: ONE snapshot of the entry's merge slot decides the
    // whole group's path — it either sees a promoted merge in full or
    // serves composed; a concurrent promote/evict cannot tear it. A cold
    // miss under budgeted mode schedules the async build (the claim
    // dedupes concurrent misses) and serves composed right now.
    let merged = entry.merged.snapshot();
    match &merged {
        Some(_) => ctx.cache.note_hit(adapter),
        None => {
            if let Some(btx) = &ctx.merge_tx {
                if ctx.cache.note_miss(adapter, entry.gen) {
                    let _ = btx.send(BuildReq {
                        name: adapter.to_string(),
                        entry: entry.clone(),
                    });
                }
            }
        }
    }
    let used_merged = merged.is_some();
    let result = match &merged {
        Some(merged) => engine.infer_merged(InferMergedReq {
            config: ctx.config.clone(),
            params: merged.clone(),
            tokens,
        }),
        None => engine.infer(InferReq {
            config: ctx.config.clone(),
            variant: Variant::Fused,
            adapter: entry.variant,
            precision: ctx.precision,
            params: entry.params.clone(),
            tokens,
        }),
    };

    // Fan results out first, then record metrics under ONE short lock
    // acquisition (no per-request map lookups while holding the mutex —
    // `metrics()` callers never wait on the reply fan-out).
    match result {
        Ok(resp) => {
            // `infer` validated shape/dtype/len; indexing is safe.
            let logits = resp.logits.as_f32().expect("validated f32 logits");
            let mut lats_us = Vec::with_capacity(occupancy);
            for (row, req) in group.into_iter().enumerate() {
                let row_logits = &logits[row * vocab..(row + 1) * vocab];
                let (next, logit) = argmax(row_logits);
                let latency = req.enqueued.elapsed();
                lats_us.push(latency.as_secs_f64() * 1e6);
                let _ = req.reply.send(Ok(Reply {
                    next_token: next,
                    logit,
                    logits: row_logits.to_vec(),
                    adapter: adapter.to_string(),
                    latency,
                    batch_occupancy: occupancy,
                    path: if used_merged { FastPath::Merged } else { FastPath::Composed },
                }));
            }
            let n = lats_us.len();
            let mut m = lock_unpoisoned(&ctx.metrics);
            m.batches += 1;
            m.completed += n as u64;
            if used_merged {
                m.merged_batches += 1;
            } else {
                m.composed_batches += 1;
            }
            m.latencies_us.extend_from_slice(&lats_us);
            m.occupancies.extend(std::iter::repeat(occupancy as f64).take(n));
            if let Some(w) = m.per_worker.get_mut(worker) {
                w.batches += 1;
                w.completed += n as u64;
            }
            let am = m.per_adapter.entry(adapter.to_string()).or_default();
            am.batches += 1;
            am.completed += n as u64;
            if used_merged {
                am.merged_batches += 1;
            } else {
                am.composed_batches += 1;
            }
            am.latencies_us.extend_from_slice(&lats_us);
            am.occupancies.extend(std::iter::repeat(occupancy as f64).take(n));
        }
        Err(e) => {
            // Fan the failure to every request in the group; the pool
            // itself keeps serving.
            let msg = format!("{e:#}");
            let n = group.len() as u64;
            for req in group {
                let _ = req.reply.send(Err(anyhow::anyhow!(msg.clone())));
            }
            let mut m = lock_unpoisoned(&ctx.metrics);
            m.batches += 1;
            m.failed += n;
            if used_merged {
                m.merged_batches += 1;
            } else {
                m.composed_batches += 1;
            }
            if let Some(w) = m.per_worker.get_mut(worker) {
                w.batches += 1;
                w.failed += n;
            }
            let am = m.per_adapter.entry(adapter.to_string()).or_default();
            am.batches += 1;
            am.failed += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::default_dir;
    use crate::runtime::{MockExec, NativeEngine};

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = default_dir();
        dir.join("manifest.json").exists().then_some(dir)
    }

    fn tiny_cfg() -> ServerCfg {
        ServerCfg {
            config: "tiny".into(),
            max_wait: Duration::from_millis(5),
            workers: 1,
            fast_path: FastPath::Merged,
            queue_depth: 8,
            merge_budget: None,
            cache_policy: CachePolicy::Lru,
            precision: Precision::F32,
        }
    }

    /// Accounted bytes of one tiny-config merge (embed [64,32] + two
    /// [32,32] layers = 4096 f32 = 16 KiB, already 512-aligned).
    const TINY_MERGE_BYTES: u64 = 16 * 1024;

    fn tiny_adapter(name: &str, seed: i32) -> Adapter {
        let be = ExecBackend::native();
        let info = be.config("tiny").unwrap();
        let init = be
            .init(InitReq { config: "tiny".into(), seed, precision: Precision::F32 })
            .unwrap();
        Adapter::new(name, &info, seed as u64, 0, init.params).unwrap()
    }

    // --- Native-engine tests: run unconditionally (no artifact gating) ---

    #[test]
    fn native_serves_single_request() {
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        assert_eq!(server.fast_path(), FastPath::Merged);
        let client = server.client();
        let reply = client.infer(&[1, 2, 3, 4]).unwrap();
        assert!(reply.next_token >= 0);
        assert!(reply.logit.is_finite());
        assert_eq!(reply.adapter, DEFAULT_ADAPTER);
        assert_eq!(reply.logits.len(), 64); // tiny vocab
        assert_eq!(reply.logits[reply.next_token as usize], reply.logit);
        assert_eq!(reply.path, FastPath::Merged);
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 0);
        assert_eq!(m.batches, 1);
        assert_eq!(m.exec_backend, "native");
        assert_eq!(m.fast_path, "merged");
        assert_eq!(m.merged_batches, 1);
        assert_eq!(m.composed_batches, 0);
        assert_eq!(m.merge_fallbacks, 0);
        assert_eq!(m.workers, 1);
        // The per-adapter and per-worker breakdowns mirror the globals.
        let am = &m.per_adapter[DEFAULT_ADAPTER];
        assert_eq!(am.completed, 1);
        assert_eq!(am.batches, 1);
        assert_eq!(am.merged_batches, 1);
        assert_eq!(m.per_worker.len(), 1);
        assert_eq!(m.per_worker[0].batches, 1);
        assert_eq!(m.per_worker[0].completed, 1);
    }

    #[test]
    fn native_composed_policy_serves_identically_shaped_replies() {
        let server = Server::start(
            BackendSpec::Native,
            ServerCfg { fast_path: FastPath::Composed, ..tiny_cfg() },
        )
        .unwrap();
        assert_eq!(server.fast_path(), FastPath::Composed);
        let reply = server.client().infer(&[1, 2, 3]).unwrap();
        assert_eq!(reply.logits.len(), 64);
        assert_eq!(reply.path, FastPath::Composed);
        let m = server.shutdown();
        assert_eq!(m.fast_path, "composed");
        assert_eq!(m.composed_batches, 1);
        assert_eq!(m.merged_batches, 0);
    }

    #[test]
    fn native_batches_concurrent_requests() {
        // The batch-occupancy criterion: with a wide window and 4
        // concurrent clients, batching packs >1 request per engine call.
        let server = Server::start(
            BackendSpec::Native,
            ServerCfg { max_wait: Duration::from_millis(200), ..tiny_cfg() },
        )
        .unwrap();
        let client = server.client();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || c.infer(&[i as i32 + 1, 2, 3]).unwrap())
            })
            .collect();
        let replies: Vec<Reply> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let m = server.shutdown();
        assert_eq!(m.completed, 4);
        assert!(m.batches < 4, "batches {}", m.batches);
        assert!(replies.iter().any(|r| r.batch_occupancy > 1));
        assert!(m.mean_occupancy() > 1.0, "occupancy {}", m.mean_occupancy());
    }

    #[test]
    fn native_streams_greedy_tokens_with_slo_metrics() {
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        let client = server.client();
        let opts = GenOptions { max_tokens: 8, ..GenOptions::default() };
        // First decode token == the one-shot infer's argmax (row-local
        // prefill: same last token, same logits row).
        let reply = client.infer(&[1, 2, 3]).unwrap();
        let stream = client.generate(&[1, 2, 3], opts).unwrap();
        let events: Vec<crate::coordinator::TokenEvent> =
            stream.map(|e| e.unwrap()).collect();
        assert_eq!(events.len(), 8);
        assert_eq!(events[0].token, reply.next_token);
        assert_eq!(events[0].logit, reply.logit);
        for (i, ev) in events.iter().enumerate() {
            assert_eq!(ev.index, i);
            assert!(ev.top.is_empty(), "streaming replies must not ship logits");
            assert_eq!(
                ev.finish,
                (i == 7).then_some(crate::coordinator::FinishReason::MaxTokens)
            );
        }
        // The collect path reproduces the stream bitwise.
        let again = client.generate_collect(&[1, 2, 3], opts).unwrap();
        assert_eq!(again, events.iter().map(|e| e.token).collect::<Vec<_>>());
        let m = server.shutdown();
        assert_eq!(m.decode_requests, 2);
        assert_eq!(m.decode_completed, 2);
        assert_eq!(m.decode_failed, 0);
        assert_eq!(m.decode_cancelled, 0);
        assert_eq!(m.decode_tokens, 16);
        assert_eq!(m.ttft_us.len(), 2);
        assert_eq!(m.token_latency_us.len(), 14);
        assert!(m.ttft_p99_us() >= m.ttft_p50_us());
        assert!(m.token_p99_us() > 0.0);
        assert_eq!(m.decode_in_flight, 0);
        assert_eq!(m.decode_queue_depth, 0);
        assert_eq!(m.shed_requests, 0);
    }

    #[test]
    fn generate_validates_prompt_options_and_adapter() {
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        let client = server.client();
        let opts = GenOptions::default();
        assert!(client.generate(&[], opts).is_err());
        assert!(client.generate(&[0; 10_000], opts).is_err());
        assert!(client.generate(&[-1], opts).is_err());
        assert!(client.generate(&[1_000_000], opts).is_err());
        assert!(client
            .generate(&[1], GenOptions { max_tokens: 0, ..opts })
            .is_err());
        assert!(client
            .generate(&[1], GenOptions { eos: Some(1_000_000), ..opts })
            .is_err());
        let err = client.generate_with("nope", &[1], opts).unwrap_err();
        assert!(format!("{err:#}").contains("nope"), "{err:#}");
        // None of those rejections are Overloaded sheds.
        assert!(err.downcast_ref::<crate::coordinator::Overloaded>().is_none());
        let m = server.shutdown();
        assert_eq!(m.decode_requests, 0);
        assert_eq!(m.shed_requests, 0);
    }

    #[test]
    fn temperature_streams_are_seed_reproducible_at_the_server() {
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        let client = server.client();
        let opts = GenOptions {
            max_tokens: 12,
            temperature: 0.9,
            top_k: 8,
            seed: 1234,
            ..GenOptions::default()
        };
        let a = client.generate_collect(&[3, 1, 4], opts).unwrap();
        let b = client.generate_collect(&[3, 1, 4], opts).unwrap();
        assert_eq!(a, b, "same seed must reproduce the stream bitwise");
        let c = client
            .generate_collect(&[3, 1, 4], GenOptions { seed: 99, ..opts })
            .unwrap();
        assert_ne!(a, c, "different seed should diverge at T=0.9");
        drop(server);
    }

    #[test]
    fn native_rejects_invalid_prompts_and_unknown_adapters() {
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        let client = server.client();
        assert!(client.infer(&[]).is_err());
        assert!(client.infer(&vec![0; 10_000]).is_err());
        assert!(client.infer(&[-1]).is_err());
        assert!(client.infer(&[1_000_000]).is_err());
        let err = client.infer_with("not-loaded", &[1, 2]).unwrap_err();
        assert!(format!("{err:#}").contains("not-loaded"), "{err:#}");
        assert_eq!(client.adapters(), vec![DEFAULT_ADAPTER.to_string()]);
        drop(server);
    }

    #[test]
    fn native_deterministic_given_params() {
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        let client = server.client();
        let a = client.infer(&[5, 6, 7]).unwrap();
        let b = client.infer(&[5, 6, 7]).unwrap();
        assert_eq!(a.next_token, b.next_token);
        assert_eq!(a.logits, b.logits);
        drop(server);
    }

    #[test]
    fn merged_and_composed_paths_agree_on_logits() {
        // The fast-path parity contract at the server level: identical
        // adapter, identical prompt, the two policies' logits agree to
        // 1e-5 (they differ only by float reassociation in the merge).
        let adapter = tiny_adapter("parity", 3);
        let run = |fp: FastPath| {
            let server = Server::start_with_adapters(
                BackendSpec::Native,
                ServerCfg { fast_path: fp, ..tiny_cfg() },
                vec![adapter.clone()],
            )
            .unwrap();
            let reply = server.client().infer_with("parity", &[2, 4, 6, 8]).unwrap();
            let m = server.shutdown();
            assert_eq!(m.fast_path, fp.as_str());
            reply
        };
        let merged = run(FastPath::Merged);
        let composed = run(FastPath::Composed);
        assert_eq!(merged.logits.len(), composed.logits.len());
        for (i, (&m, &c)) in merged.logits.iter().zip(&composed.logits).enumerate() {
            assert!(
                (m - c).abs() <= 1e-5 * c.abs().max(1.0),
                "logit {i}: merged {m} vs composed {c}"
            );
        }
    }

    #[test]
    fn variant_adapters_serve_on_both_paths_and_agree() {
        // rsLoRA and BoRA adapters (leaves nudged off init so the
        // variant math bites) serve through the merged fast path with no
        // fallback, and the merged logits match the composed path at
        // 1e-5 — the per-variant merge formula is what the worker serves.
        let mut base = tiny_adapter("v", 3);
        for t in base.params.trainable.iter_mut() {
            if let crate::runtime::TensorData::F32(v) = &mut t.data {
                for (i, x) in v.iter_mut().enumerate() {
                    *x += ((i % 7) as f32 - 3.0) * 0.01;
                }
            }
        }
        let prompt = [2, 4, 6, 8];
        let run = |variant: AdapterVariant, fp: FastPath| {
            let server = Server::start_with_adapters(
                BackendSpec::Native,
                ServerCfg { fast_path: fp, ..tiny_cfg() },
                vec![base.clone().with_variant(variant)],
            )
            .unwrap();
            let reply = server.client().infer_with("v", &prompt).unwrap();
            let m = server.shutdown();
            if fp == FastPath::Merged {
                assert_eq!(m.merge_fallbacks, 0, "{variant:?} failed to merge");
                assert_eq!(m.merged_batches, 1);
            } else {
                assert_eq!(m.composed_batches, 1);
            }
            reply.logits
        };
        let dora = run(AdapterVariant::Dora, FastPath::Merged);
        for variant in [AdapterVariant::RsLora, AdapterVariant::Bora] {
            let merged = run(variant, FastPath::Merged);
            let composed = run(variant, FastPath::Composed);
            for (i, (&m, &c)) in merged.iter().zip(&composed).enumerate() {
                assert!(
                    (m - c).abs() <= 1e-5 * c.abs().max(1.0),
                    "{variant:?} logit {i}: merged {m} vs composed {c}"
                );
            }
            // Off init the variant really is a different model.
            let diff =
                dora.iter().zip(&merged).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
            assert!(diff > 1e-4, "{variant:?} matches dora off init, max diff {diff}");
        }

        // Hot-loading a stored variant checkpoint carries its variant
        // into the serving entry (bitwise the same merge as startup).
        let dir = std::env::temp_dir()
            .join(format!("dora_server_variant_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::open(&dir).unwrap();
        store.save(&base.clone().with_variant(AdapterVariant::RsLora)).unwrap();
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        server.hot_load(&store, "v").unwrap();
        let reply = server.client().infer_with("v", &prompt).unwrap();
        let expect = run(AdapterVariant::RsLora, FastPath::Merged);
        assert_eq!(reply.logits, expect, "hot-loaded rslora serves different logits");
        let m = server.shutdown();
        assert_eq!(m.merge_fallbacks, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn native_train_then_serve_handoff() {
        use crate::coordinator::{Trainer, TrainerCfg};
        let mut tr = Trainer::new(
            NativeEngine::new(),
            TrainerCfg {
                config: "tiny".into(),
                variant: "fused".into(),
                seed: 11,
                branching: 3,
                eval_every: 0,
                train_workers: 0,
                grad_accum: 1,
                precision: Precision::F32,
            },
        )
        .unwrap();
        tr.train_steps(4).unwrap();
        let server = Server::start_with_params(
            BackendSpec::Native,
            tiny_cfg(),
            tr.frozen().to_vec(),
            tr.trainable().to_vec(),
        )
        .unwrap();
        let r = server.client().infer(&[1, 2, 3]).unwrap();
        assert!(r.logit.is_finite());
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        // Trained leaves merge cleanly: no fallback to composed.
        assert_eq!(m.merge_fallbacks, 0);
        assert_eq!(m.merged_batches, 1);
    }

    #[test]
    fn multi_adapter_routing_and_per_adapter_metrics() {
        let server = Server::start_with_adapters(
            BackendSpec::Native,
            tiny_cfg(),
            vec![tiny_adapter("alice", 1), tiny_adapter("bob", 2)],
        )
        .unwrap();
        assert_eq!(server.default_adapter(), "alice");
        assert_eq!(
            server.adapter_names(),
            vec!["alice".to_string(), "bob".to_string()]
        );
        let client = server.client();
        let a = client.infer_with("alice", &[3, 4, 5]).unwrap();
        let b = client.infer_with("bob", &[3, 4, 5]).unwrap();
        // Different seeds -> different parameters -> different logits.
        assert_ne!(a.logits, b.logits, "adapters share identical logits");
        assert_eq!(a.adapter, "alice");
        assert_eq!(b.adapter, "bob");
        // The default route is the first adapter.
        let d = client.infer(&[3, 4, 5]).unwrap();
        assert_eq!(d.adapter, "alice");
        assert_eq!(d.logits, a.logits);
        let m = server.shutdown();
        assert_eq!(m.completed, 3);
        assert_eq!(m.per_adapter["alice"].completed, 2);
        assert_eq!(m.per_adapter["bob"].completed, 1);
        assert_eq!(m.per_adapter["bob"].failed, 0);
    }

    #[test]
    fn pool_spreads_adapters_across_workers() {
        // Two adapters on a 2-worker pool: per-worker metrics show both
        // workers executed batches (affinity routing assigns first-seen
        // adapters round-robin).
        let server = Server::start_with_adapters(
            BackendSpec::Native,
            ServerCfg { workers: 2, ..tiny_cfg() },
            vec![tiny_adapter("alice", 1), tiny_adapter("bob", 2)],
        )
        .unwrap();
        let client = server.client();
        for i in 0..4 {
            client.infer_with("alice", &[i + 1]).unwrap();
            client.infer_with("bob", &[i + 1]).unwrap();
        }
        let m = server.shutdown();
        assert_eq!(m.workers, 2);
        assert_eq!(m.per_worker.len(), 2);
        assert_eq!(m.completed, 8);
        assert!(
            m.per_worker.iter().all(|w| w.batches > 0),
            "a worker sat idle: {:?}",
            m.per_worker
        );
        assert_eq!(
            m.per_worker.iter().map(|w| w.batches).sum::<u64>(),
            m.batches
        );
        assert_eq!(
            m.per_worker.iter().map(|w| w.completed).sum::<u64>(),
            m.completed
        );
    }

    #[test]
    fn hot_load_swaps_weights_while_serving() {
        let server = Server::start_with_adapters(
            BackendSpec::Native,
            tiny_cfg(),
            vec![tiny_adapter("live", 1)],
        )
        .unwrap();
        let client = server.client();
        let before = client.infer_with("live", &[2, 3, 4]).unwrap();
        // Replace "live" with different weights and add a new name.
        server
            .load_adapter("live", tiny_adapter("live", 9).params)
            .unwrap();
        server
            .load_adapter("fresh", tiny_adapter("fresh", 5).params)
            .unwrap();
        let after = client.infer_with("live", &[2, 3, 4]).unwrap();
        assert_ne!(before.logits, after.logits, "hot-load had no effect");
        assert!(client.infer_with("fresh", &[1]).is_ok());
        assert_eq!(server.adapter_names().len(), 2);
        let m = server.shutdown();
        assert_eq!(m.hot_loads, 2);
        assert_eq!(m.completed, 3);
        // Hot-loaded init leaves merge cleanly under the merged policy.
        assert_eq!(m.merge_fallbacks, 0);
        assert!(m.per_adapter.contains_key("fresh"));
    }

    #[test]
    fn load_adapter_validates_names_and_shapes() {
        let server = Server::start(BackendSpec::Native, tiny_cfg()).unwrap();
        assert!(server.load_adapter("../evil", AdapterParams::default()).is_err());
        let err = server
            .load_adapter("empty", AdapterParams::default())
            .unwrap_err();
        assert!(format!("{err:#}").contains("param count"), "{err:#}");
        // Right leaf COUNT but a wrong-shaped leaf: rejected synchronously
        // (not installed, not counted as a hot load or merge fallback).
        let mut bad = tiny_adapter("bad", 1).params;
        let n = bad.trainable[0].elems();
        let mut shape = bad.trainable[0].shape.clone();
        shape.reverse(); // [r, d] -> [d, r]
        bad.trainable[0] = Tensor::f32(shape, vec![0.0; n]);
        let err = server.load_adapter("bad", bad).unwrap_err();
        assert!(format!("{err:#}").contains("shape"), "{err:#}");
        assert!(!server.adapter_names().contains(&"bad".to_string()));
        let m = server.metrics();
        assert_eq!(m.hot_loads, 0);
        assert_eq!(m.merge_fallbacks, 0);
    }

    #[test]
    fn startup_validates_config_params_and_artifact() {
        // Unknown config fails synchronously.
        let err = Server::start(
            BackendSpec::Native,
            ServerCfg { config: "no_such_config".into(), ..tiny_cfg() },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("no_such_config"), "{err:#}");
        // Param-count mismatch fails synchronously.
        let err = Server::start_with_params(BackendSpec::Native, tiny_cfg(), vec![], vec![])
            .unwrap_err();
        assert!(format!("{err:#}").contains("param count"), "{err:#}");
        // Mismatched adapter config fails synchronously.
        let err = Server::start_with_adapters(
            BackendSpec::Native,
            ServerCfg { config: "small".into(), ..tiny_cfg() },
            vec![tiny_adapter("t", 0)],
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("config"), "{err:#}");
        // No adapters at all fails synchronously.
        assert!(
            Server::start_with_adapters(BackendSpec::Native, tiny_cfg(), vec![]).is_err()
        );
        // A PJRT spec over a directory with no artifacts fails
        // synchronously (this used to hang clients: the batcher thread
        // hit its "unreachable" return).
        let err = Server::start(
            BackendSpec::Pjrt(std::path::PathBuf::from("/nonexistent/artifacts")),
            tiny_cfg(),
        )
        .unwrap_err();
        assert!(!format!("{err:#}").is_empty());
    }

    #[test]
    fn malformed_engine_output_fans_errors_and_server_keeps_serving() {
        // The batcher-robustness criterion: a wrong-shaped output batch
        // answers every in-flight request with Err, and the NEXT batch
        // (well-formed) succeeds — the worker survives. The mock's
        // placeholder params can't merge, so this also covers the
        // per-adapter composed fallback under the merged policy.
        let info = ExecBackend::native().config("tiny").unwrap();
        let mock = MockExec::new(info.clone());
        // Batch 1: empty output vec (the old `outs[0]` panic).
        mock.push(Ok(vec![]));
        // Batch 2: wrong shape (the old slice-out-of-range panic).
        mock.push(Ok(vec![Tensor::f32(vec![1, 3], vec![0.0; 3])]));
        // Batch 3: wrong dtype (the old `unwrap_or(&[])` silent-empty).
        mock.push(Ok(vec![Tensor::i32(
            vec![info.train_batch, info.vocab],
            vec![0; info.train_batch * info.vocab],
        )]));
        // Batch 4+: script exhausted -> mock returns valid zero logits.
        let dummy_frozen: Vec<Tensor> =
            info.frozen.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let dummy_trainable: Vec<Tensor> =
            info.trainable.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let server = Server::start_with_params(
            mock,
            tiny_cfg(),
            dummy_frozen,
            dummy_trainable,
        )
        .unwrap();
        let client = server.client();
        for expect_err in [true, true, true, false] {
            let r = client.infer(&[1, 2, 3]);
            if expect_err {
                let e = format!("{:#}", r.unwrap_err());
                assert!(
                    e.contains("output") || e.contains("dtype") || e.contains("shape"),
                    "unexpected error: {e}"
                );
            } else {
                let reply = r.unwrap();
                assert_eq!(reply.next_token, 0); // zero logits -> argmax 0
            }
        }
        let m = server.shutdown();
        assert_eq!(m.batches, 4);
        assert_eq!(m.failed, 3);
        assert_eq!(m.completed, 1);
        assert_eq!(m.per_adapter[DEFAULT_ADAPTER].failed, 3);
        assert_eq!(m.per_adapter[DEFAULT_ADAPTER].completed, 1);
        // Placeholder leaves couldn't merge: composed fallback recorded.
        assert_eq!(m.merge_fallbacks, 1);
        assert_eq!(m.composed_batches, 4);
        assert_eq!(m.merged_batches, 0);
    }

    #[test]
    fn engine_error_fans_to_batch_and_serving_continues() {
        let info = ExecBackend::native().config("tiny").unwrap();
        let mock = MockExec::new(info.clone());
        mock.push(Err("transient device loss".into()));
        let dummy: Vec<Tensor> =
            info.frozen.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let dummy_t: Vec<Tensor> =
            info.trainable.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let server = Server::start_with_params(mock, tiny_cfg(), dummy, dummy_t).unwrap();
        let client = server.client();
        let e = format!("{:#}", client.infer(&[1]).unwrap_err());
        assert!(e.contains("transient device loss"), "{e}");
        assert!(client.infer(&[1]).is_ok());
        let m = server.shutdown();
        assert_eq!(m.failed, 1);
        assert_eq!(m.completed, 1);
        assert_eq!(m.per_worker[0].failed, 1);
        assert_eq!(m.per_worker[0].completed, 1);
    }

    #[test]
    fn nan_logits_do_not_panic_the_batcher() {
        let info = ExecBackend::native().config("tiny").unwrap();
        let mock = MockExec::new(info.clone());
        let mut logits = vec![f32::NAN; info.train_batch * info.vocab];
        // One finite value in row 0: the argmax must find it.
        logits[3] = 1.5;
        mock.push(Ok(vec![Tensor::f32(
            vec![info.train_batch, info.vocab],
            logits,
        )]));
        let dummy: Vec<Tensor> =
            info.frozen.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let dummy_t: Vec<Tensor> =
            info.trainable.iter().map(|_| Tensor::f32(vec![1], vec![0.0])).collect();
        let server = Server::start_with_params(mock, tiny_cfg(), dummy, dummy_t).unwrap();
        let reply = server.client().infer(&[1, 2]).unwrap();
        assert_eq!(reply.next_token, 3);
        assert_eq!(reply.logit, 1.5);
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn argmax_is_nan_safe_and_deterministic() {
        assert_eq!(argmax(&[0.0, 2.0, 1.0]), (1, 2.0));
        assert_eq!(argmax(&[f32::NAN, 1.0, f32::NAN]), (1, 1.0));
        let (i, v) = argmax(&[f32::NAN, f32::NAN]);
        assert_eq!(i, 0); // ties (incl. all-NaN) keep the first index
        assert!(v.is_nan());
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), (1, -1.0));
    }

    #[test]
    fn budgeted_cache_serves_composed_then_promotes_and_evicts() {
        // A budget holding exactly ONE tiny merge: cold adapters must
        // answer immediately on the composed path, promote asynchronously,
        // and squeeze each other out — with the accounting gauges never
        // exceeding the budget.
        let server = Server::start_with_adapters(
            BackendSpec::Native,
            ServerCfg { merge_budget: Some(TINY_MERGE_BYTES), ..tiny_cfg() },
            vec![tiny_adapter("a", 1), tiny_adapter("b", 2)],
        )
        .unwrap();
        let client = server.client();
        // The very first request finds the slot cold — it is served NOW,
        // composed, not blocked behind the merge build.
        let first = client.infer_with("a", &[1, 2, 3]).unwrap();
        assert_eq!(first.path, FastPath::Composed);
        // The async build promotes; poll until a merged-path reply lands.
        let deadline = Instant::now() + Duration::from_secs(30);
        let merged_reply = loop {
            let r = client.infer_with("a", &[1, 2, 3]).unwrap();
            if r.path == FastPath::Merged {
                break r;
            }
            assert!(Instant::now() < deadline, "merge was never promoted");
            std::thread::sleep(Duration::from_millis(2));
        };
        // Composed-fallback correctness: the two paths differ only by
        // float reassociation in the merge.
        for (i, (&m, &c)) in merged_reply.logits.iter().zip(&first.logits).enumerate() {
            assert!(
                (m - c).abs() <= 1e-5 * c.abs().max(1.0),
                "logit {i}: merged {m} vs composed {c}"
            );
        }
        let m = server.metrics();
        assert_eq!(m.merge_budget_bytes, TINY_MERGE_BYTES);
        assert_eq!(m.cache_promotions, 1);
        assert!(m.cache_misses >= 1);
        assert!(m.cache_hits >= 1);
        assert_eq!(m.cache_resident, 1);
        assert_eq!(m.resident_adapters, vec!["a".to_string()]);
        assert_eq!(m.cache_resident_bytes, TINY_MERGE_BYTES);
        assert_eq!(m.cache_evictions, 0);
        // "b" promoting must evict "a" — the budget holds one merge.
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let r = client.infer_with("b", &[1, 2, 3]).unwrap();
            if r.path == FastPath::Merged {
                break;
            }
            assert!(Instant::now() < deadline, "b's merge was never promoted");
            std::thread::sleep(Duration::from_millis(2));
        }
        // The event stream replays to the same high-water mark.
        let events = server.mem_events();
        let m = server.shutdown();
        assert_eq!(m.cache_evictions, 1);
        assert_eq!(m.cache_promotions, 2);
        assert_eq!(m.resident_adapters, vec!["b".to_string()]);
        assert_eq!(m.cache_resident_bytes, TINY_MERGE_BYTES);
        assert!(
            m.cache_high_water_bytes <= TINY_MERGE_BYTES,
            "budget overshoot: {} > {TINY_MERGE_BYTES}",
            m.cache_high_water_bytes
        );
        assert_eq!(crate::memsim::peak_of_events(&events), m.cache_high_water_bytes);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn eager_mode_reports_live_cache_gauges() {
        // No budget: merges are eager, the unbounded cache still keeps
        // the books (hits + residency), and nothing is ever evicted.
        let server = Server::start_with_adapters(
            BackendSpec::Native,
            tiny_cfg(),
            vec![tiny_adapter("a", 1), tiny_adapter("b", 2)],
        )
        .unwrap();
        let client = server.client();
        client.infer_with("a", &[1, 2]).unwrap();
        client.infer_with("b", &[1, 2]).unwrap();
        let m = server.shutdown();
        assert_eq!(m.merge_budget_bytes, 0, "0 encodes unbounded");
        assert_eq!(m.cache_resident, 2);
        assert_eq!(m.cache_resident_bytes, 2 * TINY_MERGE_BYTES);
        assert_eq!(m.cache_promotions, 2);
        assert_eq!(m.cache_hits, 2);
        assert_eq!(m.cache_misses, 0);
        assert_eq!(m.cache_evictions, 0);
        assert_eq!(
            m.resident_adapters,
            vec!["a".to_string(), "b".to_string()]
        );
    }

    #[test]
    fn hot_swap_under_budget_releases_residency_and_repromotes() {
        // A hot-swap while the old merge is resident: residency transfers
        // to the new generation only after ITS build promotes; the old
        // bytes are released immediately (no double accounting).
        let server = Server::start_with_adapters(
            BackendSpec::Native,
            ServerCfg { merge_budget: Some(TINY_MERGE_BYTES), ..tiny_cfg() },
            vec![tiny_adapter("live", 1)],
        )
        .unwrap();
        let client = server.client();
        let deadline = Instant::now() + Duration::from_secs(30);
        let before = loop {
            let r = client.infer_with("live", &[2, 3, 4]).unwrap();
            if r.path == FastPath::Merged {
                break r;
            }
            assert!(Instant::now() < deadline, "merge was never promoted");
            std::thread::sleep(Duration::from_millis(2));
        };
        server.load_adapter("live", tiny_adapter("live", 9).params).unwrap();
        // The swap itself frees the old residency (not an eviction).
        let m = server.metrics();
        assert_eq!(m.cache_resident, 0);
        assert_eq!(m.cache_resident_bytes, 0);
        assert_eq!(m.cache_evictions, 0);
        // New weights serve (composed at first), then re-promote.
        let deadline = Instant::now() + Duration::from_secs(30);
        let after = loop {
            let r = client.infer_with("live", &[2, 3, 4]).unwrap();
            if r.path == FastPath::Merged {
                break r;
            }
            assert!(Instant::now() < deadline, "swap was never re-promoted");
            std::thread::sleep(Duration::from_millis(2));
        };
        assert_ne!(before.logits, after.logits, "hot-swap had no effect");
        let m = server.shutdown();
        assert_eq!(m.cache_promotions, 2);
        assert_eq!(m.hot_loads, 1);
        assert!(m.cache_high_water_bytes <= TINY_MERGE_BYTES);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn fast_path_parse_roundtrip() {
        for fp in [FastPath::Merged, FastPath::Composed] {
            assert_eq!(FastPath::parse(fp.as_str()).unwrap(), fp);
        }
        assert!(FastPath::parse("warp").is_err());
        assert_eq!(FastPath::default(), FastPath::Merged);
    }

    // --- PJRT-gated variants (skip without `make artifacts`) ---

    #[test]
    fn serves_single_request() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(&dir, tiny_cfg()).unwrap();
        let client = server.client();
        let reply = client.infer(&[1, 2, 3, 4]).unwrap();
        assert!(reply.next_token >= 0);
        assert!(reply.logit.is_finite());
        let m = server.shutdown();
        assert_eq!(m.completed, 1);
        assert_eq!(m.batches, 1);
        // PJRT manifests carry no merged artifact: composed effective.
        assert_eq!(m.fast_path, "composed");
    }

    #[test]
    fn batches_concurrent_requests() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(
            &dir,
            ServerCfg { max_wait: Duration::from_millis(100), ..tiny_cfg() },
        )
        .unwrap();
        let client = server.client();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let c = client.clone();
                std::thread::spawn(move || c.infer(&[i as i32 + 1, 2, 3]).unwrap())
            })
            .collect();
        let replies: Vec<Reply> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let m = server.shutdown();
        assert_eq!(m.completed, 4);
        // With a 100 ms window and 4 concurrent clients, batching should
        // pack more than one request per executable call.
        assert!(m.batches < 4, "batches {}", m.batches);
        assert!(replies.iter().any(|r| r.batch_occupancy > 1));
    }

    #[test]
    fn deterministic_given_params() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(&dir, tiny_cfg()).unwrap();
        let client = server.client();
        let a = client.infer(&[5, 6, 7]).unwrap();
        let b = client.infer(&[5, 6, 7]).unwrap();
        assert_eq!(a.next_token, b.next_token);
        drop(server);
    }
}
