//! L3 coordinator: the deployable training/serving layer over the
//! execution backends (PJRT artifacts or the native kernel-registry
//! engine — see [`runtime::backend`](crate::runtime::backend) for the
//! fallback order).
//!
//! * `data`    — synthetic Markov corpus (the dataset substitute).
//! * `trainer` — training-run orchestration: seeded init, chunked typed
//!   train-step execution, loss/eval tracking, periodic adapter
//!   checkpointing, eager-vs-fused convergence comparison (paper §5.9).
//! * `server`  — batched multi-adapter inference serving over a pool of
//!   worker engines (batch-or-timeout policy with per-adapter request
//!   grouping and affinity routing, a precomputed merged-weight fast
//!   path with composed fallback, global + per-adapter + per-worker
//!   metrics, adapter hot-loading, malformed-output fan-out instead of
//!   batcher panics).
//! * `scheduler` — streaming autoregressive decode: a continuous-batching
//!   scheduler over the same engine pool (requests join and leave the
//!   running batch between decode steps), per-request seeded sampling
//!   (greedy / temperature / top-k), bounded admission with typed
//!   [`Overloaded`] load-shedding, and TTFT / per-token SLO histograms.
//!
//! Multi-tenant serving runs the merged fast path under an explicit byte
//! budget ([`ServerCfg::merge_budget`]): a
//! [`MergedCache`](crate::runtime::MergedCache) owns merged-weight
//! residency (LRU/clock eviction, async promotion, decode-stream
//! pinning), and both data paths fall back to the composed path while an
//! adapter is cold.

pub mod data;
pub mod scheduler;
pub mod server;
pub mod trainer;

pub use scheduler::{FinishReason, GenOptions, GenStream, Overloaded, TokenEvent};
pub use server::{
    AdapterMetrics, Client, FastPath, Reply, Server, ServerCfg, ServerMetrics, WorkerMetrics,
    DEFAULT_ADAPTER,
};
pub use trainer::{StepRecord, Trainer, TrainerCfg};

use crate::dispatch::{ComposeCtx, DispatchEnv};
use crate::dora::config::ActShape;
use crate::kernels::KernelChoice;
use crate::runtime::ConfigInfo;

/// Which compose backend the unified kernel layer selects for a model
/// config's full-batch activation shape (`[train_batch * seq, d_model]`).
///
/// The trainer and server record this at startup so operational logs and
/// metrics name the actual hot path (tier + backend) instead of leaving
/// the dispatch decision implicit in env vars.
pub fn compose_plan(info: &ConfigInfo, training: bool) -> KernelChoice {
    compose_plan_with(info, training, &DispatchEnv::from_env())
}

/// [`compose_plan`] with an explicit environment (no env-var reads of its
/// own, though it resolves backends through the process-wide registry;
/// the env-reading wrapper above is what the trainer/server call at
/// startup).
pub fn compose_plan_with(info: &ConfigInfo, training: bool, env: &DispatchEnv) -> KernelChoice {
    let act = ActShape::new(info.train_batch * info.seq, info.d_model);
    let ctx = if training { ComposeCtx::training(act) } else { ComposeCtx::inference(act) };
    crate::kernels::registry().select(env, &ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Tier;

    fn info(train_batch: usize, seq: usize, d_model: usize) -> ConfigInfo {
        ConfigInfo {
            name: "test".into(),
            vocab: 256,
            d_model,
            n_layers: 2,
            seq,
            rank: 8,
            scale: 2.0,
            n_params: 0,
            train_batch,
            chunk_steps: 4,
            frozen: vec![],
            trainable: vec![],
        }
    }

    // Tests use the explicit-env variant: another test in this binary
    // mutates the DORA_* process environment, so `from_env` would race.
    #[test]
    fn plan_routes_large_training_config_to_tier1() {
        // rows = 4 * 4096 = 16384, d_model = 4096: above the crossover.
        let c = compose_plan_with(&info(4, 4096, 4096), true, &DispatchEnv::default());
        assert_eq!(c.tier, Tier::FusedBackward);
        assert!(c.is_fused());
    }

    #[test]
    fn plan_routes_tiny_config_to_eager() {
        // The `tiny` scale: sub-crossover in training -> Tier 3.
        let c = compose_plan_with(&info(2, 64, 128), true, &DispatchEnv::default());
        assert_eq!(c.tier, Tier::Eager);
        assert_eq!(c.backend.kind(), crate::kernels::BackendKind::Eager);
    }

    #[test]
    fn plan_inference_is_tier2() {
        let c = compose_plan_with(&info(2, 64, 128), false, &DispatchEnv::default());
        assert_eq!(c.tier, Tier::FusedForward);
    }
}
