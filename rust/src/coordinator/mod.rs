//! L3 coordinator: the deployable training/serving layer over the PJRT
//! runtime.
//!
//! * `data`    — synthetic Markov corpus (the dataset substitute).
//! * `trainer` — training-run orchestration: seeded init, chunked
//!   train-step execution, loss/eval tracking, eager-vs-fused convergence
//!   comparison (paper §5.9).
//! * `server`  — batched inference serving over the Tier-2 fused-forward
//!   artifact (batch-or-timeout policy, latency metrics).

pub mod data;
pub mod server;
pub mod trainer;

pub use server::{Client, Reply, Server, ServerCfg, ServerMetrics};
pub use trainer::{StepRecord, Trainer, TrainerCfg};
