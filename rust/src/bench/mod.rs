//! Benchmark + report layer: the criterion-lite timing harness, the
//! standard shape sweeps, and the report generator that regenerates every
//! table and figure of the paper's evaluation (DESIGN.md §5).

pub mod ablation;
pub mod diff;
pub mod report;
pub mod shapes;
pub mod timing;
