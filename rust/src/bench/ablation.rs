//! Factorial ablation — the paper's §6.2 acknowledged gap: "Model-level
//! speedups reflect both contributions (factored norm + fused kernels)
//! jointly ... A fuller factorial ablation across additional model
//! families would strengthen the evidence."
//!
//! This unit crosses the two contributions independently on the cost
//! model: {dense, factored} norm × {eager, fused} compose, for every
//! model on H200, attributing the end-to-end gain to each axis.

use crate::dora::config::{ActShape, Config};
use crate::dora::gpu_cost;
use crate::dora::model_plan::Workload;
use crate::gpusim::device::{self, Device};
use crate::models::{ModelSpec, MODELS};
use crate::util::table::{fmt_speedup, Table};

/// Iteration time with the norm engine and compose engine chosen
/// INDEPENDENTLY (the four factorial cells; the paper's shipped configs
/// are the diagonal dense+eager = "Dense (B@A)" and factored+fused =
/// "Fused").
fn factorial_time(
    dev: &Device,
    spec: &ModelSpec,
    wl: &Workload,
    norm_cfg: Config,
    fused_compose: bool,
) -> f64 {
    let rows = wl.rows();
    let env = crate::dispatch::DispatchEnv::default();
    let mut t = 0.0;
    for (_, shape, count) in spec.inventory(wl.rank) {
        let act = ActShape::new(rows, shape.d_out);
        // Norm engine per `norm_cfg`; compose per `fused_compose` with
        // the real dispatch decision applied through the kernel registry.
        let choice = crate::dispatch::select_kernel(
            &env,
            &crate::dispatch::ComposeCtx::training(act),
        );
        let use_fused = fused_compose && choice.is_fused();
        let norm = gpu_cost::weight_norm(dev, shape, wl.dtype, norm_cfg);
        let base = gpu_cost::base_matmul(dev, shape, rows, wl.dtype);
        let lora = gpu_cost::lora_matmuls(dev, shape, rows, wl.dtype);
        let comp_f = gpu_cost::compose_forward(dev, act, wl.dtype, use_fused);
        let comp_b = gpu_cost::compose_backward(dev, act, wl.dtype, use_fused);
        let dmag = gpu_cost::dmag_reduction(dev, act, wl.dtype);
        // fwd + bwd(recompute fwd + grads approximated as in model_plan)
        let grads = gpu_cost::lora_matmuls(dev, shape, rows, wl.dtype)
            .add(gpu_cost::base_matmul(dev, shape, rows, wl.dtype));
        let module = 2.0 * (norm.time + base.time + lora.time + comp_f.time)
            + comp_b.time
            + dmag.time
            + grads.time;
        t += module * count as f64;
    }
    t * wl.grad_accum as f64
}

/// Render the factorial ablation table.
pub fn ablation() -> String {
    let dev = device::find("h200").unwrap();
    let wl = Workload::default();
    let mut t = Table::new(
        "Factorial ablation (H200, bf16, r=384): norm engine x compose engine, \
         speedup vs (dense norm + eager compose)",
        &[
            "Model",
            "dense+eager",
            "factored+eager",
            "dense+fused",
            "factored+fused",
            "norm share",
            "compose share",
        ],
    );
    for spec in MODELS.iter() {
        let de = factorial_time(dev, spec, &wl, Config::DenseBA, false);
        let fe = factorial_time(dev, spec, &wl, Config::Eager, false);
        let df = factorial_time(dev, spec, &wl, Config::DenseBA, true);
        let ff = factorial_time(dev, spec, &wl, Config::Fused, true);
        // Attribution: log-space share of the total gain per axis.
        let total = (de / ff).ln();
        let norm_share = ((de / fe).ln() / total * 100.0).round();
        let compose_share = ((de / df).ln() / total * 100.0).round();
        t.row(vec![
            spec.name.into(),
            "1.00x".into(),
            fmt_speedup(de / fe),
            fmt_speedup(de / df),
            fmt_speedup(de / ff),
            format!("{norm_share:.0}%"),
            format!("{compose_share:.0}%"),
        ]);
    }
    format!(
        "{}\nShares are log-space attributions of the factored+fused gain; \
         interaction terms make them not sum to exactly 100%.\n",
        t.to_markdown()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factorial_cells_ordered() {
        // Both axes help; the full system is the fastest cell.
        let dev = device::find("h200").unwrap();
        let wl = Workload::default();
        for spec in MODELS.iter() {
            let de = factorial_time(dev, spec, &wl, Config::DenseBA, false);
            let fe = factorial_time(dev, spec, &wl, Config::Eager, false);
            let df = factorial_time(dev, spec, &wl, Config::DenseBA, true);
            let ff = factorial_time(dev, spec, &wl, Config::Fused, true);
            assert!(fe < de, "{}: factored norm should help", spec.name);
            assert!(df < de, "{}: fused compose should help", spec.name);
            assert!(ff < fe && ff < df, "{}: full system fastest", spec.name);
        }
    }

    #[test]
    fn renders() {
        let s = ablation();
        assert!(s.contains("factored+fused"));
        assert!(s.lines().count() > 8);
    }
}
