//! The standard benchmark shape sweeps — the paper's "--shapes extended":
//! 20 unique activation shapes (rows x d_out) for the compose/backward
//! microbenchmarks, and the Table-7 weight-norm shape set.

use crate::dora::config::{ActShape, ModuleShape};

/// The 20 activation shapes of the paper's extended microbenchmark sweep
/// (rows = batch * seq). Spans launch-bound to bandwidth-bound regimes,
/// including the §4 crossover band around 2048 x 6144.
pub fn extended_act_shapes() -> Vec<ActShape> {
    let mut out = Vec::new();
    for &(rows, d_out) in &[
        (256, 1024),
        (256, 4096),
        (512, 2048),
        (512, 8192),
        (1024, 1024),
        (1024, 4096),
        (2048, 2048),
        (2048, 4096),
        (2048, 6144),
        (2048, 8192),
        (4096, 1024),
        (4096, 4096),
        (4096, 8192),
        (8192, 2048),
        (8192, 4096),
        (8192, 8192),
        (16384, 4096),
        (16384, 8192),
        (32768, 4096),
        (32768, 8192),
    ] {
        out.push(ActShape::new(rows, d_out));
    }
    out
}

/// Table 7's weight-norm shapes (d_out, d_in, rank).
pub fn norm_shapes() -> Vec<ModuleShape> {
    vec![
        ModuleShape::new(4096, 4096, 64),
        ModuleShape::new(4096, 4096, 384),
        ModuleShape::new(4096, 4096, 512),
        ModuleShape::new(8192, 8192, 384),
        ModuleShape::new(8192, 8192, 512),
        ModuleShape::new(8192, 8192, 768),
        ModuleShape::new(4096, 11008, 384),
        ModuleShape::new(8192, 28672, 384), // the MoE shape
    ]
}

/// CPU-scale activation shapes for the REAL-measurement benches (sized so
/// the eager chain's working set exceeds LLC but a trial stays sub-second).
pub fn cpu_act_shapes() -> Vec<ActShape> {
    vec![
        ActShape::new(256, 1024),
        ActShape::new(512, 2048),
        ActShape::new(1024, 4096),
        ActShape::new(2048, 4096),
        ActShape::new(4096, 4096),
        ActShape::new(4096, 8192),
    ]
}

/// CPU-scale norm shapes for real-measurement benches (naive matmul in
/// the dense baselines caps the size).
pub fn cpu_norm_shapes() -> Vec<ModuleShape> {
    vec![
        ModuleShape::new(256, 256, 16),
        ModuleShape::new(512, 512, 32),
        ModuleShape::new(512, 512, 128),
        ModuleShape::new(1024, 1024, 64),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twenty_unique_shapes() {
        let shapes = extended_act_shapes();
        assert_eq!(shapes.len(), 20);
        let mut set = std::collections::HashSet::new();
        for s in &shapes {
            assert!(set.insert((s.rows, s.d_out)), "duplicate {s:?}");
        }
    }

    #[test]
    fn norm_shapes_match_table7() {
        let shapes = norm_shapes();
        assert_eq!(shapes.len(), 8);
        assert!(shapes.contains(&ModuleShape::new(8192, 28672, 384)));
    }
}
