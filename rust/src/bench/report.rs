//! Report generator: regenerates every table and figure of the paper's
//! evaluation as markdown (DESIGN.md §5 experiment index).
//!
//! `dorafactors report <id>` prints one unit; `report all` prints the full
//! set (this is what EXPERIMENTS.md's simulated sections are built from).
//! Convergence (Table 10 / Figure 12) and the e2e run live in `examples/`
//! because they execute real PJRT training.

use crate::bench::shapes;
use crate::dora::config::{ActShape, Config, ModuleShape};
use crate::dora::model_plan::{self, Workload};
use crate::dora::{gpu_cost, mem_events};
use crate::gpusim::device::{self, Device, DEVICES};
use crate::memsim::allocator::peak_of_events;
use crate::models::{self, MODELS};
use crate::numerics::gdist;
use crate::numerics::stability::{self};
use crate::numerics::Dtype;
use crate::util::stats;
use crate::util::table::{fmt_bytes, fmt_secs, fmt_speedup, Table};

const MIB: f64 = (1u64 << 20) as f64;

fn model_devs() -> Vec<&'static Device> {
    device::model_devices()
}

// ---------------------------------------------------------------------------
// Tables
// ---------------------------------------------------------------------------

/// Table 1: norm memory, theory + measured allocator delta (fp32,
/// d=8192, r=512).
pub fn table1() -> String {
    let m = ModuleShape::new(8192, 8192, 512);
    let theory_dense = (m.dense_elems() * 4) as f64 / MIB;
    let theory_ug = (m.factored_elems() * 4) as f64 / MIB;
    let peft = peak_of_events(&mem_events::norm_events(m, Config::Peft, Dtype::F32, 256 << 20));
    let fact = peak_of_events(&mem_events::norm_events(m, Config::Eager, Dtype::F32, 256 << 20));
    let mut t = Table::new(
        "Table 1 — factored-norm memory (d_out=d_in=8192, r=512, fp32)",
        &["Quantity", "PEFT", "Factored (ours)"],
    );
    t.row(vec!["Theory: dense (B@A)".into(), format!("{theory_dense:.0} MB"), "N/A".into()]);
    t.row(vec!["Theory: U + G".into(), "N/A".into(), format!("{theory_ug:.1} MB")]);
    t.row(vec![
        "Theoretical reduction".into(),
        "".into(),
        format!("{:.1}x", m.theoretical_reduction()),
    ]);
    t.row(vec![
        "Measured: allocator delta".into(),
        format!("{:.0} MB", peft as f64 / MIB),
        format!("{:.0} MB", fact as f64 / MIB),
    ]);
    t.row(vec![
        "Measured reduction".into(),
        "".into(),
        format!("{:.1}x", peft as f64 / fact as f64),
    ]);
    t.to_markdown()
}

/// Table 3: benchmark hardware.
pub fn table3() -> String {
    let mut t = Table::new(
        "Table 3 — benchmark hardware (simulated testbed)",
        &["GPU", "Arch (SM)", "Memory", "BW (TB/s)", "Scope"],
    );
    for d in DEVICES.iter() {
        t.row(vec![
            d.name.into(),
            format!("{:?} (SM{})", d.arch, d.sm),
            format!("{:.0} GB", d.mem_gb),
            format!("{:.2}", d.peak_bw / 1e12),
            if d.model_scope { "Micro+Model".into() } else { "Micro".into() },
        ]);
    }
    t.to_markdown()
}

/// Tables 4 + 5: gradient-computation speedup and absolute times.
pub fn table4_5() -> String {
    let wl = Workload::default();
    let mut t4 = Table::new(
        "Table 4 — gradient-computation speedup (r=384, bf16, seq=4096, ga=8)",
        &[
            "Model",
            "vsPEFT RTX",
            "vsPEFT H200",
            "vsPEFT B200",
            "vsEager RTX",
            "vsEager H200",
            "vsEager B200",
        ],
    );
    let mut t5 = Table::new(
        "Table 5 — absolute gradient-computation time (s/iteration)",
        &[
            "Model",
            "Fused RTX",
            "Fused H200",
            "Fused B200",
            "Eager RTX",
            "Eager H200",
            "Eager B200",
            "PEFT RTX",
            "PEFT H200",
            "PEFT B200",
        ],
    );
    for spec in MODELS.iter() {
        let mut r4 = vec![spec.name.to_string()];
        let mut times: Vec<Vec<String>> = vec![vec![], vec![], vec![]]; // fused, eager, peft
        for base in [Config::Peft, Config::Eager] {
            for dev in model_devs() {
                if !model_plan::fits(dev, spec, &wl, Config::Fused) {
                    r4.push("OOM".into());
                    continue;
                }
                let tb = model_plan::grad_iteration_time(dev, spec, &wl, base);
                let tf = model_plan::grad_iteration_time(dev, spec, &wl, Config::Fused);
                r4.push(fmt_speedup(tb / tf));
            }
        }
        for (i, cfg) in [Config::Fused, Config::Eager, Config::Peft].iter().enumerate() {
            for dev in model_devs() {
                if !model_plan::fits(dev, spec, &wl, *cfg) {
                    times[i].push("OOM".into());
                } else {
                    times[i].push(format!(
                        "{:.1}",
                        model_plan::grad_iteration_time(dev, spec, &wl, *cfg)
                    ));
                }
            }
        }
        t4.row(r4);
        let mut r5 = vec![spec.name.to_string()];
        r5.extend(times.into_iter().flatten());
        t5.row(r5);
    }
    format!("{}\n{}", t4.to_markdown(), t5.to_markdown())
}

/// Table 6: rank scaling on H200.
pub fn table6() -> String {
    let dev = device::find("h200").unwrap();
    let mut t = Table::new(
        "Table 6 — speedup vs rank (H200, bf16, seq=4096)",
        &["Model", "Rank", "Grad vsPEFT", "Infer vsPEFT", "Grad vsEager", "Infer vsEager"],
    );
    for name in ["Qwen3.5-27B", "Qwen3-VL-32B"] {
        let spec = models::find(name).unwrap();
        for rank in [384usize, 512, 768] {
            let wl = Workload { rank, ..Workload::default() };
            let g = |c| model_plan::grad_iteration_time(dev, spec, &wl, c);
            let i = |c| model_plan::inference_time(dev, spec, &wl, c);
            t.row(vec![
                name.into(),
                rank.to_string(),
                fmt_speedup(g(Config::Peft) / g(Config::Fused)),
                fmt_speedup(i(Config::Peft) / i(Config::Fused)),
                fmt_speedup(g(Config::Eager) / g(Config::Fused)),
                fmt_speedup(i(Config::Eager) / i(Config::Fused)),
            ]);
        }
    }
    t.to_markdown()
}

/// Table 7 + Figure 9: norm memory across shapes.
pub fn table7() -> String {
    let mut t = Table::new(
        "Table 7 / Figure 9 — norm memory: measured delta + theoretical reduction (fp32)",
        &["Shape", "Rank", "PEFT", "Factored", "Meas. x", "Theory x"],
    );
    for m in shapes::norm_shapes() {
        let peft = peak_of_events(&mem_events::norm_events(m, Config::Peft, Dtype::F32, 256 << 20));
        let fact =
            peak_of_events(&mem_events::norm_events(m, Config::Eager, Dtype::F32, 256 << 20));
        t.row(vec![
            format!("{}x{}", m.d_out, m.d_in),
            m.rank.to_string(),
            format!("{:.0} MB", peft as f64 / MIB),
            format!("{:.0} MB", fact as f64 / MIB),
            format!("{:.1}x", peft as f64 / fact as f64),
            format!("{:.1}x", m.theoretical_reduction()),
        ]);
    }
    t.to_markdown()
}

/// Tables 8 + 13: model-level peak VRAM.
pub fn table8() -> String {
    let wl = Workload::default();
    let mut t = Table::new(
        "Table 8/13 — model-level peak VRAM (GB), all six models",
        &["Model", "Method", "RTX", "H200", "B200"],
    );
    for spec in MODELS.iter() {
        for cfg in [Config::Eager, Config::Fused, Config::DenseBA, Config::Peft] {
            let v = model_plan::peak_vram_bytes(spec, &wl, cfg) as f64 / 1e9;
            let cell = |dev: &Device| {
                if v * 1e9 > dev.mem_gb * 1e9 { "OOM".to_string() } else { format!("{v:.1}") }
            };
            let devs = model_devs();
            t.row(vec![
                spec.name.into(),
                cfg.name().into(),
                cell(devs[0]),
                cell(devs[1]),
                cell(devs[2]),
            ]);
        }
    }
    t.to_markdown()
}

/// Tables 9 + 14: geometric-mean microbenchmark speedups per GPU.
pub fn table9_14() -> String {
    let mut out = String::new();
    for dt in [Dtype::Bf16, Dtype::F32] {
        let mut t = Table::new(
            &format!(
                "Table {} — geo-mean microbenchmark speedups, {:?} (20 shapes)",
                if dt == Dtype::Bf16 { "9" } else { "14" },
                dt
            ),
            &["GPU", "Compose fwd", "Backward", "E2E", "Norm mem"],
        );
        for dev in DEVICES.iter() {
            let mut fwd = Vec::new();
            let mut bwd = Vec::new();
            let mut e2e = Vec::new();
            for act in shapes::extended_act_shapes() {
                let ef = gpu_cost::compose_forward(dev, act, dt, false).time;
                let ff = gpu_cost::compose_forward(dev, act, dt, true).time;
                fwd.push(ef / ff);
                let eb = gpu_cost::compose_backward(dev, act, dt, false).time;
                let fb = gpu_cost::compose_backward(dev, act, dt, true).time;
                bwd.push(eb / fb);
                e2e.push(single_layer_e2e_ratio(dev, act, dt));
            }
            // Norm memory ratio PEFT/factored over Table-7 shapes.
            let mut mem = Vec::new();
            for m in shapes::norm_shapes() {
                let p = peak_of_events(&mem_events::norm_events(m, Config::Peft, dt, 256 << 20));
                let f = peak_of_events(&mem_events::norm_events(m, Config::Eager, dt, 256 << 20));
                mem.push(p as f64 / f as f64);
            }
            t.row(vec![
                format!("{} {:?}", dev.name, dt),
                fmt_speedup(stats::geomean(&fwd)),
                fmt_speedup(stats::geomean(&bwd)),
                fmt_speedup(stats::geomean(&e2e)),
                format!("{:.1}x", stats::geomean(&mem)),
            ]);
        }
        out.push_str(&t.to_markdown());
        out.push('\n');
    }
    out
}

/// Single-layer E2E time ratio eager/fused (Figures 13-15's quantity):
/// one module's norm + base/lora matmuls + compose fwd+bwd.
fn single_layer_e2e_ratio(dev: &Device, act: ActShape, dt: Dtype) -> f64 {
    let m = ModuleShape::new(act.d_out, 4096, 384);
    let rows = act.rows;
    let e = gpu_cost::module_forward(dev, m, rows, dt, Config::Eager).time
        + gpu_cost::module_backward(dev, m, rows, dt, Config::Eager).time;
    let f = gpu_cost::module_forward(dev, m, rows, dt, Config::Fused).time
        + gpu_cost::module_backward(dev, m, rows, dt, Config::Fused).time;
    e / f
}

/// Appendix G: framework survey (static data from the paper).
pub fn table_g() -> String {
    let mut t = Table::new(
        "Appendix G — DoRA norm implementation in major frameworks (Feb 2026)",
        &["Framework", "Version", "Path", "Pattern"],
    );
    for (f, v, p, pat) in [
        ("HF PEFT", "20a9829", "peft/tuners/lora/dora.py", "torch.eye"),
        ("torchtune", "v0.5.0", "modules/peft/dora.py", "same algorithm"),
        ("Unsloth", "2026.3.7", "(disables custom kernels)", "falls back to PEFT"),
        ("SWIFT", "a807cb9", "(defers to PEFT/Unsloth)", "no custom code"),
        ("LLaMA-Factory", "v0.9.3", "(delegates to PEFT)", "no custom code"),
        ("Axolotl", "v0.6.0", "(delegates to PEFT)", "no custom code"),
    ] {
        t.row(vec![f.into(), v.into(), p.into(), pat.into()]);
    }
    t.to_markdown()
}

// ---------------------------------------------------------------------------
// Figures
// ---------------------------------------------------------------------------

/// Figure 1: numerical stability near g ~ 1 (bf16).
pub fn fig1() -> String {
    let pts = stability::sweep_g_offsets(Dtype::Bf16, 12, 2048, 42);
    let mut t = Table::new(
        "Figure 1 — compose error near g≈1 (bf16, fp64 reference)",
        &["|g-1|", "naive max err", "stable max err", "ratio"],
    );
    for p in &pts {
        t.row(vec![
            format!("{:.1e}", p.g_offset),
            format!("{:.2e}", p.err_naive),
            format!("{:.2e}", p.err_stable),
            format!("{:.1}x", p.err_naive / p.err_stable.max(1e-30)),
        ]);
    }
    let ratio = stability::peak_error_ratio(&pts);
    format!(
        "{}\nPeak-error ratio (naive/stable): {ratio:.1}x (paper: 3.0x)\n",
        t.to_markdown()
    )
}

/// Figure 4: inference speedup.
pub fn fig4() -> String {
    let wl = Workload::default();
    let mut t = Table::new(
        "Figure 4 — inference speedup vs PEFT (bf16, r=384)",
        &["Model", "RTX", "H200", "B200"],
    );
    for spec in MODELS.iter() {
        let mut row = vec![spec.name.to_string()];
        for dev in model_devs() {
            let p = model_plan::inference_time(dev, spec, &wl, Config::Peft);
            let f = model_plan::inference_time(dev, spec, &wl, Config::Fused);
            row.push(fmt_speedup(p / f));
        }
        t.row(row);
    }
    t.to_markdown()
}

/// Figure 5: dense (B@A) position in the eager-to-fused gap.
pub fn fig5() -> String {
    let wl = Workload::default();
    let mut t = Table::new(
        "Figure 5 — Dense (B@A) position (0% = eager, 100% = fused)",
        &["Model", "RTX", "H200", "B200"],
    );
    for spec in MODELS.iter() {
        let mut row = vec![spec.name.to_string()];
        for dev in model_devs() {
            let te = model_plan::grad_iteration_time(dev, spec, &wl, Config::Eager);
            let tb = model_plan::grad_iteration_time(dev, spec, &wl, Config::DenseBA);
            let tf = model_plan::grad_iteration_time(dev, spec, &wl, Config::Fused);
            let pos = 100.0 * (te - tb) / (te - tf);
            row.push(format!("{pos:.0}%"));
        }
        t.row(row);
    }
    format!(
        "{}\nNegative values mean dense (B@A) is slower than eager.\n",
        t.to_markdown()
    )
}

/// Figure 6: compose forward speedup vs activation size, all six GPUs.
pub fn fig6() -> String {
    let mut t = Table::new(
        "Figure 6a — compose forward speedup vs eager (bf16)",
        &["rows x d_out", "L40S", "A100", "RTX", "H200", "B200", "B300"],
    );
    for act in shapes::extended_act_shapes() {
        let mut row = vec![format!("{}x{}", act.rows, act.d_out)];
        for dev in DEVICES.iter() {
            let e = gpu_cost::compose_forward(dev, act, Dtype::Bf16, false).time;
            let f = gpu_cost::compose_forward(dev, act, Dtype::Bf16, true).time;
            row.push(fmt_speedup(e / f));
        }
        t.row(row);
    }
    t.to_markdown()
}

/// Figure 7: bandwidth utilization (fp32).
pub fn fig7() -> String {
    let act = ActShape::new(32768, 8192); // largest sweep shape
    let mut t = Table::new(
        "Figure 7 — bandwidth utilization at the largest shape (fp32)",
        &["GPU", "Fused GB/s", "Fused %peak", "Eager GB/s", "Eager %peak"],
    );
    for dev in DEVICES.iter() {
        let f = gpu_cost::compose_forward(dev, act, Dtype::F32, true);
        let e = gpu_cost::compose_forward(dev, act, Dtype::F32, false);
        t.row(vec![
            dev.name.into(),
            format!("{:.0}", f.achieved_bw() / 1e9),
            format!("{:.0}%", 100.0 * f.achieved_bw() / dev.peak_bw),
            format!("{:.0}", e.achieved_bw() / 1e9),
            format!("{:.0}%", 100.0 * e.achieved_bw() / dev.peak_bw),
        ]);
    }
    t.to_markdown()
}

/// Figure 8: backward speedup with the crossover.
pub fn fig8() -> String {
    let mut t = Table::new(
        "Figure 8 — backward speedup vs eager (bf16); <1 below the crossover",
        &["rows x d_out", "L40S", "A100", "RTX", "H200", "B200", "B300"],
    );
    for act in shapes::extended_act_shapes() {
        let mut row = vec![format!("{}x{}", act.rows, act.d_out)];
        for dev in DEVICES.iter() {
            let e = gpu_cost::compose_backward(dev, act, Dtype::Bf16, false).time;
            let f = gpu_cost::compose_backward(dev, act, Dtype::Bf16, true).time;
            row.push(fmt_speedup(e / f));
        }
        t.row(row);
    }
    t.to_markdown()
}

/// Figure 10: norm latency vs rank (RTX 6000 PRO, fp32).
pub fn fig10() -> String {
    let dev = device::find("rtx").unwrap();
    let m0 = ModuleShape::new(8192, 8192, 1);
    let mut t = Table::new(
        "Figure 10 — norm latency vs rank (RTX 6000 PRO, 8192x8192, fp32)",
        &["Rank", "PEFT", "Dense B@A", "Factored", "Fused chunk"],
    );
    for rank in [16usize, 64, 128, 256, 384, 512, 768] {
        let m = ModuleShape { rank, ..m0 };
        t.row(vec![
            rank.to_string(),
            fmt_secs(gpu_cost::weight_norm(dev, m, Dtype::F32, Config::Peft).time),
            fmt_secs(gpu_cost::weight_norm(dev, m, Dtype::F32, Config::DenseBA).time),
            fmt_secs(gpu_cost::weight_norm(dev, m, Dtype::F32, Config::Eager).time),
            fmt_secs(gpu_cost::weight_norm(dev, m, Dtype::F32, Config::Fused).time),
        ]);
    }
    t.to_markdown()
}

/// Figure 11: memory profile — forward/backward peaks, eager vs fused.
pub fn fig11() -> String {
    let mut t = Table::new(
        "Figure 11 — compose memory profile (bf16, d=4096)",
        &["batch x seq", "Eager fwd peak", "Fused fwd peak", "Saving", "Bwd peak (both)"],
    );
    for rows in [2048usize, 4096, 8192, 16384] {
        let act = ActShape::new(rows, 4096);
        let e = peak_of_events(&mem_events::compose_forward_events(
            act,
            Config::Eager,
            Dtype::Bf16,
            true,
        ));
        let f = peak_of_events(&mem_events::compose_forward_events(
            act,
            Config::Fused,
            Dtype::Bf16,
            true,
        ));
        let b = peak_of_events(&{
            let mut ev = mem_events::compose_forward_events(act, Config::Fused, Dtype::Bf16, true);
            ev.extend(mem_events::compose_backward_events(act, Config::Fused, Dtype::Bf16));
            ev
        });
        t.row(vec![
            format!("{rows}x4096"),
            fmt_bytes(e),
            fmt_bytes(f),
            fmt_bytes(e - f),
            fmt_bytes(b),
        ]);
    }
    t.to_markdown()
}

/// Figures 13-15: single-layer E2E speedups.
pub fn fig13_15() -> String {
    let mut out = String::new();
    // Fig 13: decomposition at d=4096, bs*seq=8192 on B200.
    let dev = device::find("b200").unwrap();
    let m = ModuleShape::new(4096, 4096, 384);
    let rows = 8192;
    let mut t = Table::new(
        "Figure 13 — single-layer overhead decomposition (B200, bf16)",
        &["Stage", "Eager", "Fused"],
    );
    let act = ActShape::new(rows, 4096);
    for (stage, e, f) in [
        (
            "norm",
            gpu_cost::weight_norm(dev, m, Dtype::Bf16, Config::Eager).time,
            gpu_cost::weight_norm(dev, m, Dtype::Bf16, Config::Fused).time,
        ),
        (
            "compose fwd",
            gpu_cost::compose_forward(dev, act, Dtype::Bf16, false).time,
            gpu_cost::compose_forward(dev, act, Dtype::Bf16, true).time,
        ),
        (
            "compose bwd",
            gpu_cost::compose_backward(dev, act, Dtype::Bf16, false).time,
            gpu_cost::compose_backward(dev, act, Dtype::Bf16, true).time,
        ),
        (
            "lora matmuls",
            gpu_cost::lora_matmuls(dev, m, rows, Dtype::Bf16).time,
            gpu_cost::lora_matmuls(dev, m, rows, Dtype::Bf16).time,
        ),
        (
            "base matmul",
            gpu_cost::base_matmul(dev, m, rows, Dtype::Bf16).time,
            gpu_cost::base_matmul(dev, m, rows, Dtype::Bf16).time,
        ),
    ] {
        t.row(vec![stage.into(), fmt_secs(e), fmt_secs(f)]);
    }
    out.push_str(&t.to_markdown());

    // Fig 14: E2E speedup vs rank across GPUs.
    let mut t = Table::new(
        "Figure 14 — single-layer E2E speedup vs rank (bf16, d=4096, rows=8192)",
        &["Rank", "L40S", "A100", "RTX", "H200", "B200", "B300"],
    );
    for rank in [64usize, 128, 256, 384, 512, 768] {
        let mut row = vec![rank.to_string()];
        for dev in DEVICES.iter() {
            let mm = ModuleShape::new(4096, 4096, rank);
            let e = gpu_cost::module_forward(dev, mm, rows, Dtype::Bf16, Config::Eager).time
                + gpu_cost::module_backward(dev, mm, rows, Dtype::Bf16, Config::Eager).time;
            let f = gpu_cost::module_forward(dev, mm, rows, Dtype::Bf16, Config::Fused).time
                + gpu_cost::module_backward(dev, mm, rows, Dtype::Bf16, Config::Fused).time;
            row.push(fmt_speedup(e / f));
        }
        t.row(row);
    }
    out.push('\n');
    out.push_str(&t.to_markdown());

    // Fig 15: E2E speedup vs hidden dim.
    let mut t = Table::new(
        "Figure 15 — single-layer E2E speedup vs hidden dim (bf16, r=384)",
        &["Hidden", "L40S", "A100", "RTX", "H200", "B200", "B300"],
    );
    for h in [1024usize, 2048, 3072, 4096, 6144, 8192] {
        let mut row = vec![h.to_string()];
        for dev in DEVICES.iter() {
            let mm = ModuleShape::new(h, h, 384);
            let e = gpu_cost::module_forward(dev, mm, rows, Dtype::Bf16, Config::Eager).time
                + gpu_cost::module_backward(dev, mm, rows, Dtype::Bf16, Config::Eager).time;
            let f = gpu_cost::module_forward(dev, mm, rows, Dtype::Bf16, Config::Fused).time
                + gpu_cost::module_backward(dev, mm, rows, Dtype::Bf16, Config::Fused).time;
            row.push(fmt_speedup(e / f));
        }
        t.row(row);
    }
    out.push('\n');
    out.push_str(&t.to_markdown());
    out
}

/// §3.1's g-distribution measurement + §4's dispatch statistics.
pub fn gdist_and_dispatch() -> String {
    let d = gdist::paper_population();
    let mut out = format!(
        "### g-distribution (synthetic trained adapter, 326 modules)\n\n\
         mean = {:.4}, std = {:.4}, bf16 collapse zone = {:.0}%, \
         fp16 zone = {:.0}% (paper: mean≈1.0, std≈0.0015, 100%, 20%)\n\n",
        d.mean,
        d.std,
        100.0 * d.frac_bf16_zone,
        100.0 * d.frac_f16_zone
    );
    let env = crate::dispatch::DispatchEnv::default();
    let mut t = Table::new(
        "Dispatch-tier statistics (training, bs=1, seq=4096, r=384)",
        &["Model", "Tier 1", "Tier 3", "Tier-1 %"],
    );
    for spec in MODELS.iter() {
        let stats = crate::dispatch::model_tier_stats(&env, spec, 384, 4096);
        t.row(vec![
            spec.name.into(),
            stats.tier1.to_string(),
            stats.tier3.to_string(),
            format!("{:.0}%", 100.0 * stats.frac_tier1()),
        ]);
    }
    out.push_str(&t.to_markdown());
    out
}

/// Kernel-backend registry: the unified dispatch surface (backends, their
/// execution strategy, and the tier -> backend mapping over the standard
/// shape sweep) plus a live cross-backend parity check.
pub fn kernel_backends() -> String {
    use crate::dispatch::{ComposeCtx, DispatchEnv};
    use crate::kernels::{registry, ComposeKernel};
    use crate::util::rng::Rng;

    let reg = registry();
    let mut t = Table::new(
        "Kernel registry — compose/norm backends behind the dispatch surface",
        &["Backend", "Kind", "Workers", "f32 parity", "bf16 parity"],
    );
    // Live parity check vs the fused reference on an uneven shape.
    let act = ActShape::new(37, 129);
    let mut rng = Rng::new(17);
    let base = rng.normal_vec_f32(act.elems(), 1.0);
    let lora = rng.normal_vec_f32(act.elems(), 0.3);
    let g: Vec<f32> = (0..act.d_out).map(|_| 1.0 + rng.normal() as f32 * 0.002).collect();
    let parity = |be: &dyn ComposeKernel, dt: Dtype| -> &'static str {
        let q = |v: &[f32]| v.iter().map(|&x| dt.quantize(x)).collect::<Vec<f32>>();
        let (bq, lq, gq) = (q(&base), q(&lora), q(&g));
        let reference = reg
            .compose(crate::kernels::BackendKind::Fused)
            .forward_alloc(&bq, &lq, &gq, 2.0, act, dt);
        let got = be.forward_alloc(&bq, &lq, &gq, 2.0, act, dt);
        if reference
            .iter()
            .zip(&got)
            .all(|(a, b)| a.to_bits() == b.to_bits())
        {
            "bitwise"
        } else {
            "DIVERGED"
        }
    };
    for be in reg.compose_backends() {
        t.row(vec![
            be.name().into(),
            format!("{:?}", be.kind()),
            be.parallelism().to_string(),
            parity(be.as_ref(), Dtype::F32).into(),
            parity(be.as_ref(), Dtype::Bf16).into(),
        ]);
    }

    let env = DispatchEnv::default();
    let mut map = Table::new(
        "Dispatch mapping (training ctx): tier and backend per shape",
        &["rows x d_out", "Working set", "Tier", "Backend"],
    );
    for act in shapes::cpu_act_shapes() {
        let choice = crate::dispatch::select_kernel(&env, &ComposeCtx::training(act));
        map.row(vec![
            format!("{}x{}", act.rows, act.d_out),
            fmt_bytes(crate::kernels::compose_working_set_bytes(act)),
            choice.tier.name().into(),
            choice.backend.name().into(),
        ]);
    }
    format!("{}\n{}", t.to_markdown(), map.to_markdown())
}

/// All report units in order, for `report all` / EXPERIMENTS.md.
pub fn all() -> String {
    let sections: Vec<(&str, String)> = vec![
        ("table1", table1()),
        ("table3", table3()),
        ("table4+5 / fig3", table4_5()),
        ("table6", table6()),
        ("table7 / fig9", table7()),
        ("table8+13", table8()),
        ("table9+14", table9_14()),
        ("tableG", table_g()),
        ("fig1", fig1()),
        ("fig4", fig4()),
        ("fig5", fig5()),
        ("fig6", fig6()),
        ("fig7", fig7()),
        ("fig8", fig8()),
        ("fig10", fig10()),
        ("fig11", fig11()),
        ("fig13-15", fig13_15()),
        ("gdist+dispatch", gdist_and_dispatch()),
        ("kernels", kernel_backends()),
        ("ablation", crate::bench::ablation::ablation()),
    ];
    let mut out = String::new();
    for (name, body) in sections {
        out.push_str(&format!("\n<!-- report unit: {name} -->\n\n{body}\n"));
    }
    out
}

/// Dispatch a report unit by id. Returns None for unknown ids.
pub fn by_name(id: &str) -> Option<String> {
    Some(match id {
        "all" => all(),
        "table1" => table1(),
        "table3" => table3(),
        "table4" | "table5" | "fig3" => table4_5(),
        "table6" => table6(),
        "table7" | "fig9" => table7(),
        "table8" | "table13" => table8(),
        "table9" | "table14" => table9_14(),
        "tableg" | "tableG" => table_g(),
        "fig1" => fig1(),
        "fig4" => fig4(),
        "fig5" => fig5(),
        "fig6" => fig6(),
        "fig7" => fig7(),
        "fig8" => fig8(),
        "fig10" => fig10(),
        "fig11" => fig11(),
        "fig13" | "fig14" | "fig15" => fig13_15(),
        "gdist" | "dispatch" => gdist_and_dispatch(),
        "kernels" | "backends" => kernel_backends(),
        "ablation" => crate::bench::ablation::ablation(),
        _ => return None,
    })
}

/// The ids `by_name` accepts (for the CLI help text).
pub const REPORT_IDS: &[&str] = &[
    "all", "table1", "table3", "table4", "table5", "table6", "table7", "table8", "table9",
    "table13", "table14", "tableG", "fig1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8",
    "fig9", "fig10", "fig11", "fig13", "fig14", "fig15", "gdist", "dispatch", "kernels",
    "ablation",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_unit_renders() {
        for id in REPORT_IDS {
            let body = by_name(id).unwrap_or_else(|| panic!("unknown id {id}"));
            assert!(body.len() > 50, "{id} too short");
            assert!(body.contains('|'), "{id} has no table");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn table1_numbers_in_paper_band() {
        let t = table1();
        assert!(t.contains("15.1x"), "theory reduction: {t}");
    }

    #[test]
    fn fig5_has_percentages() {
        let t = fig5();
        assert!(t.contains('%'));
    }

    #[test]
    fn fig7_fused_near_half_peak() {
        let t = fig7();
        // Every fused row should be ~50-55% of peak.
        let rows = t
            .lines()
            .filter(|l| !l.contains("GB/s") && l.matches('|').count() >= 5);
        for line in rows {
            let _ = line;
        }
        assert!(t.contains("53%") || t.contains("52%") || t.contains("54%"), "{t}");
    }

    #[test]
    fn kernel_backend_unit_lists_backends_and_parity_holds() {
        let t = kernel_backends();
        for name in ["eager-cpu", "fused-cpu", "parallel-tiled-cpu"] {
            assert!(t.contains(name), "missing backend {name}: {t}");
        }
        assert!(!t.contains("DIVERGED"), "backend parity violated: {t}");
        assert!(t.contains("tier3-eager"), "mapping table missing tiers: {t}");
    }

    #[test]
    fn table9_geomeans_in_band() {
        let t = table9_14();
        // bf16 compose-fwd geomeans should span roughly the paper's
        // 1.5-2.7x band; just assert presence of plausible values.
        assert!(t.contains("x"));
        assert!(t.lines().count() > 14);
    }
}
