//! Criterion-lite timing harness (no criterion in the vendored set).
//!
//! Mirrors the paper's microbenchmark methodology (§5.1): warmup
//! iterations followed by N timed trials, reporting the **median** (the
//! paper reports medians of 200 CUDA-event-timed trials) plus CV for the
//! stability criterion (paper: CV < 1.7%).
//!
//! `cargo bench` runs the `benches/*.rs` binaries (harness = false),
//! which use this module and print aligned result tables.

use std::time::Instant;

use crate::util::stats;

/// One benchmark measurement summary.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub median_s: f64,
    pub mean_s: f64,
    pub cv: f64,
    pub trials: usize,
}

impl Measurement {
    pub fn throughput_gbps(&self, bytes: u64) -> f64 {
        bytes as f64 / self.median_s / 1e9
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchCfg {
    pub warmup: usize,
    pub trials: usize,
    /// Abort a single benchmark after this many seconds (keeps `cargo
    /// bench` bounded on slow reference paths).
    pub time_cap_s: f64,
}

impl Default for BenchCfg {
    fn default() -> Self {
        BenchCfg { warmup: 3, trials: 20, time_cap_s: 10.0 }
    }
}

impl BenchCfg {
    /// Paper-faithful microbenchmark settings (10 warmup, 200 trials) —
    /// used for the fast CPU kernels.
    pub fn micro() -> Self {
        BenchCfg { warmup: 10, trials: 200, time_cap_s: 20.0 }
    }

    /// Quick settings for heavyweight end-to-end paths.
    pub fn quick() -> Self {
        BenchCfg { warmup: 1, trials: 5, time_cap_s: 30.0 }
    }
}

/// Time `f`, returning the median-of-trials measurement. `f` should
/// return something opaque to keep the optimizer honest (use
/// `std::hint::black_box` inside).
pub fn bench<F: FnMut()>(name: &str, cfg: BenchCfg, mut f: F) -> Measurement {
    let start = Instant::now();
    for _ in 0..cfg.warmup {
        f();
        if start.elapsed().as_secs_f64() > cfg.time_cap_s {
            break;
        }
    }
    let mut samples = Vec::with_capacity(cfg.trials);
    for _ in 0..cfg.trials {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if start.elapsed().as_secs_f64() > cfg.time_cap_s && samples.len() >= 3 {
            break;
        }
    }
    Measurement {
        name: name.to_string(),
        median_s: stats::median(&samples),
        mean_s: stats::mean(&samples),
        cv: stats::cv(&samples),
        trials: samples.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep() {
        let m = bench("sleep", BenchCfg { warmup: 0, trials: 5, time_cap_s: 5.0 }, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(m.median_s >= 0.002, "median {}", m.median_s);
        assert!(m.median_s < 0.05);
        assert_eq!(m.trials, 5);
    }

    #[test]
    fn time_cap_bounds_trials() {
        let m = bench("slow", BenchCfg { warmup: 0, trials: 1000, time_cap_s: 0.05 }, || {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        assert!(m.trials < 1000, "cap ignored: {} trials", m.trials);
        assert!(m.trials >= 3);
    }
}
