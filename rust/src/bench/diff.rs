//! Perf-trajectory diff: compare a fresh `perf_gate` BENCH JSON against
//! a committed baseline snapshot (`bench_baselines/BENCH_pr10.json`) and
//! render per-row deltas, so perf regressions show up as a reviewable
//! table instead of silently drifting (bench_results/ is gitignored —
//! the committed snapshot is the only history).
//!
//! Rows are matched by identity key — `kernel` name plus its shape
//! columns (`rows`/`d_out` for compose rows, `m`/`k`/`n` for GEMM rows)
//! plus the adapter `variant` when the row carries one,
//! `pool`+`fast_path` for serving and streaming-decode rows,
//! `adapters`+`mix`+`budget` for merged-cache rows — and compared on the
//! row's primary metric (ns_per_elem, ns_per_mac, or median_s). Rows
//! present on only one side are listed separately rather than dropped.
//!
//! [`BenchDiff::gate`] turns the comparison into a CI verdict: removed
//! rows always fail, and new rows fail unless the run opts in with
//! `--allow-new-keys` (so a PR that adds bench coverage can land without
//! first rewriting the committed baseline).

use crate::util::json::{Json, JsonError};
use crate::util::table::Table;

/// One matched row: metric values from both files.
#[derive(Debug, Clone)]
pub struct RowDelta {
    pub key: String,
    pub metric: &'static str,
    pub baseline: f64,
    pub fresh: f64,
}

impl RowDelta {
    /// Signed percent change, fresh vs baseline (+ = slower/regression
    /// for time-like metrics).
    pub fn delta_pct(&self) -> f64 {
        if self.baseline == 0.0 {
            return 0.0;
        }
        (self.fresh - self.baseline) / self.baseline * 100.0
    }
}

/// The structured comparison of two BENCH JSON documents.
#[derive(Debug, Default)]
pub struct BenchDiff {
    pub rows: Vec<RowDelta>,
    /// Keys present only in the baseline (removed rows).
    pub only_baseline: Vec<String>,
    /// Keys present only in the fresh run (new rows).
    pub only_fresh: Vec<String>,
}

impl BenchDiff {
    /// CI strictness verdict over row identity. Rows that vanished from
    /// the fresh run always fail (lost coverage); rows the baseline has
    /// never seen fail too unless `allow_new_keys` — the escape hatch a
    /// PR that *adds* bench coverage uses until the baseline snapshot is
    /// re-committed.
    pub fn gate(&self, allow_new_keys: bool) -> Result<(), String> {
        if !self.only_baseline.is_empty() {
            return Err(format!(
                "bench rows missing from fresh run: {}",
                self.only_baseline.join(", ")
            ));
        }
        if !allow_new_keys && !self.only_fresh.is_empty() {
            return Err(format!(
                "bench rows absent from baseline (pass --allow-new-keys to accept): {}",
                self.only_fresh.join(", ")
            ));
        }
        Ok(())
    }
}

/// Identity key of a `kernels` row. The adapter-variant column is part
/// of the identity only when the row carries one — committed baselines
/// that predate the variant axis keep matching their (implicitly DoRA)
/// fresh counterparts.
fn kernel_key(row: &Json) -> Result<String, JsonError> {
    let kernel = row.get("kernel")?.as_str()?.to_string();
    let variant = match row.opt("variant") {
        Some(v) => format!(" variant={}", v.as_str()?),
        None => String::new(),
    };
    if row.opt("m").is_some() {
        let (m, k, n) =
            (row.get("m")?.as_usize()?, row.get("k")?.as_usize()?, row.get("n")?.as_usize()?);
        Ok(format!("{kernel} {m}x{k}x{n}{variant}"))
    } else {
        let (rows, d_out) = (row.get("rows")?.as_usize()?, row.get("d_out")?.as_usize()?);
        Ok(format!("{kernel} {rows}x{d_out}{variant}"))
    }
}

/// Identity key of a `serving` row. Like the kernel variant column, the
/// precision column joins the identity only when the row carries one:
/// committed baselines that predate the precision axis keep matching
/// their (implicitly f32) fresh counterparts.
fn serving_key(row: &Json) -> Result<String, JsonError> {
    Ok(format!(
        "serve pool={} path={}{}",
        row.get("pool")?.as_usize()?,
        row.get("fast_path")?.as_str()?,
        precision_suffix(row)?
    ))
}

/// Identity key of a streaming `decode` row (tokens/sec trajectory).
fn decode_key(row: &Json) -> Result<String, JsonError> {
    Ok(format!(
        "decode pool={} path={}{}",
        row.get("pool")?.as_usize()?,
        row.get("fast_path")?.as_str()?,
        precision_suffix(row)?
    ))
}

/// ` precision=<p>` when the row carries the column, `""` otherwise.
fn precision_suffix(row: &Json) -> Result<String, JsonError> {
    Ok(match row.opt("precision") {
        Some(p) => format!(" precision={}", p.as_str()?),
        None => String::new(),
    })
}

/// Identity key of a merged-`cache` row (budgeted multi-tenant sweep).
fn cache_key(row: &Json) -> Result<String, JsonError> {
    Ok(format!(
        "cache adapters={} mix={} budget={}",
        row.get("adapters")?.as_usize()?,
        row.get("mix")?.as_str()?,
        row.get("budget")?.as_str()?
    ))
}

/// The row's primary metric: most specific time-per-work field present.
fn metric_of(row: &Json) -> Result<(&'static str, f64), JsonError> {
    for name in ["ns_per_elem", "ns_per_mac"] {
        if let Some(v) = row.opt(name) {
            return Ok((name, v.as_f64()?));
        }
    }
    Ok(("median_s", row.get("median_s")?.as_f64()?))
}

/// Collect `(key, metric, value)` triples from one BENCH document.
fn collect(doc: &Json) -> Result<Vec<(String, &'static str, f64)>, JsonError> {
    let mut out = Vec::new();
    if let Some(rows) = doc.opt("kernels") {
        for row in rows.as_arr()? {
            let (metric, v) = metric_of(row)?;
            out.push((kernel_key(row)?, metric, v));
        }
    }
    if let Some(rows) = doc.opt("serving") {
        for row in rows.as_arr()? {
            let (metric, v) = metric_of(row)?;
            out.push((serving_key(row)?, metric, v));
        }
    }
    if let Some(rows) = doc.opt("decode") {
        for row in rows.as_arr()? {
            let (metric, v) = metric_of(row)?;
            out.push((decode_key(row)?, metric, v));
        }
    }
    if let Some(rows) = doc.opt("cache") {
        for row in rows.as_arr()? {
            let (metric, v) = metric_of(row)?;
            out.push((cache_key(row)?, metric, v));
        }
    }
    Ok(out)
}

/// Structurally compare two BENCH documents.
pub fn diff(baseline: &Json, fresh: &Json) -> Result<BenchDiff, JsonError> {
    let base_rows = collect(baseline)?;
    let fresh_rows = collect(fresh)?;
    let mut out = BenchDiff::default();
    for (key, metric, bv) in &base_rows {
        match fresh_rows.iter().find(|(k, _, _)| k == key) {
            Some((_, _, fv)) => out.rows.push(RowDelta {
                key: key.clone(),
                metric,
                baseline: *bv,
                fresh: *fv,
            }),
            None => out.only_baseline.push(key.clone()),
        }
    }
    for (key, _, _) in &fresh_rows {
        if !base_rows.iter().any(|(k, _, _)| k == key) {
            out.only_fresh.push(key.clone());
        }
    }
    Ok(out)
}

/// Render the comparison as an aligned table plus summary lines.
pub fn render(baseline: &Json, fresh: &Json) -> Result<String, JsonError> {
    let d = diff(baseline, fresh)?;
    let mut out = String::new();
    if let Some(p) = baseline.opt("provenance") {
        out.push_str(&format!("baseline provenance: {}\n\n", p.as_str()?));
    }
    let mut table =
        Table::new("perf trajectory vs baseline", &["row", "metric", "baseline", "fresh", "delta"]);
    for row in &d.rows {
        table.row(vec![
            row.key.clone(),
            row.metric.to_string(),
            format!("{:.4}", row.baseline),
            format!("{:.4}", row.fresh),
            format!("{:+.1}%", row.delta_pct()),
        ]);
    }
    out.push_str(&table.to_markdown());
    for (label, keys) in
        [("only in baseline", &d.only_baseline), ("only in fresh run", &d.only_fresh)]
    {
        if !keys.is_empty() {
            out.push_str(&format!("\n{label}: {}\n", keys.join(", ")));
        }
    }
    for field in ["compose_geomean_speedup", "gemm_geomean_speedup"] {
        if let (Some(b), Some(f)) = (baseline.opt(field), fresh.opt(field)) {
            out.push_str(&format!("\n{field}: baseline {:.2}x, fresh {:.2}x", b.as_f64()?, f.as_f64()?));
        }
    }
    out.push('\n');
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn doc(extra_row: bool) -> Json {
        let mut kernels = vec![
            Json::obj(vec![
                ("kernel", Json::Str("compose_fused".into())),
                ("rows", Json::Num(512.0)),
                ("d_out", Json::Num(2048.0)),
                ("median_s", Json::Num(0.001)),
                ("ns_per_elem", Json::Num(if extra_row { 1.0 } else { 1.25 })),
            ]),
            Json::obj(vec![
                ("kernel", Json::Str("gemm_e2e_fwd_base_nt_blocked".into())),
                ("m", Json::Num(512.0)),
                ("k", Json::Num(128.0)),
                ("n", Json::Num(128.0)),
                ("median_s", Json::Num(0.002)),
                ("ns_per_mac", Json::Num(0.2)),
            ]),
        ];
        if extra_row {
            kernels.push(Json::obj(vec![
                ("kernel", Json::Str("gemm_ba_r8_smallk".into())),
                ("m", Json::Num(128.0)),
                ("k", Json::Num(8.0)),
                ("n", Json::Num(128.0)),
                ("median_s", Json::Num(0.0001)),
                ("ns_per_mac", Json::Num(0.1)),
            ]));
        }
        Json::obj(vec![
            ("bench", Json::Str("perf_gate".into())),
            ("kernels", Json::Arr(kernels)),
            (
                "serving",
                Json::Arr(vec![Json::obj(vec![
                    ("pool", Json::Num(1.0)),
                    ("fast_path", Json::Str("merged".into())),
                    ("median_s", Json::Num(0.0005)),
                    ("req_per_s", Json::Num(2000.0)),
                ])]),
            ),
            (
                "decode",
                Json::Arr(vec![Json::obj(vec![
                    ("pool", Json::Num(1.0)),
                    ("fast_path", Json::Str("merged".into())),
                    ("tokens", Json::Num(32.0)),
                    ("median_s", Json::Num(0.004)),
                    ("tok_per_s", Json::Num(8000.0)),
                ])]),
            ),
        ])
    }

    #[test]
    fn diff_matches_rows_and_flags_extras() {
        let base = doc(false);
        let fresh = doc(true);
        let d = diff(&base, &fresh).unwrap();
        assert_eq!(d.rows.len(), 4); // 2 kernel rows + 1 serving + 1 decode row
        assert!(d.only_baseline.is_empty());
        assert_eq!(d.only_fresh, vec!["gemm_ba_r8_smallk 128x8x128".to_string()]);
        let compose = d.rows.iter().find(|r| r.key.starts_with("compose_fused")).unwrap();
        assert_eq!(compose.metric, "ns_per_elem");
        assert!((compose.delta_pct() - (-20.0)).abs() < 1e-9);
        let serve = d.rows.iter().find(|r| r.key.starts_with("serve")).unwrap();
        assert_eq!(serve.metric, "median_s");
        assert_eq!(serve.delta_pct(), 0.0);
    }

    #[test]
    fn render_includes_table_and_geomeans() {
        let base = Json::obj(vec![
            ("kernels", doc(false).get("kernels").unwrap().clone()),
            ("compose_geomean_speedup", Json::Num(1.4)),
            ("provenance", Json::Str("test".into())),
        ]);
        let fresh = Json::obj(vec![
            ("kernels", doc(true).get("kernels").unwrap().clone()),
            ("compose_geomean_speedup", Json::Num(1.5)),
        ]);
        let text = render(&base, &fresh).unwrap();
        assert!(text.contains("perf trajectory"));
        assert!(text.contains("provenance: test"));
        assert!(text.contains("compose_geomean_speedup"));
        assert!(text.contains("-20.0%"));
    }

    #[test]
    fn variant_rows_key_separately_and_legacy_rows_keep_their_keys() {
        let legacy = Json::obj(vec![
            ("kernel", Json::Str("compose_fused".into())),
            ("rows", Json::Num(512.0)),
            ("d_out", Json::Num(2048.0)),
            ("median_s", Json::Num(0.001)),
        ]);
        // Pre-variant rows keep the exact key the committed baseline used.
        assert_eq!(kernel_key(&legacy).unwrap(), "compose_fused 512x2048");
        let mut rows = Vec::new();
        for v in ["rslora", "bora"] {
            rows.push(Json::obj(vec![
                ("kernel", Json::Str("compose_fused".into())),
                ("variant", Json::Str(v.into())),
                ("rows", Json::Num(512.0)),
                ("d_out", Json::Num(2048.0)),
                ("median_s", Json::Num(0.001)),
            ]));
        }
        assert_eq!(kernel_key(&rows[0]).unwrap(), "compose_fused 512x2048 variant=rslora");
        assert_eq!(kernel_key(&rows[1]).unwrap(), "compose_fused 512x2048 variant=bora");
        // Same kernel + shape, different variant: three distinct rows, so
        // a diff of {legacy} vs {legacy, rslora, bora} flags the variant
        // rows as new instead of colliding with the Dora row.
        let base = Json::obj(vec![("kernels", Json::Arr(vec![legacy.clone()]))]);
        rows.insert(0, legacy);
        let fresh = Json::obj(vec![("kernels", Json::Arr(rows))]);
        let d = diff(&base, &fresh).unwrap();
        assert_eq!(d.rows.len(), 1);
        assert!(d.only_baseline.is_empty());
        assert_eq!(
            d.only_fresh,
            vec![
                "compose_fused 512x2048 variant=rslora".to_string(),
                "compose_fused 512x2048 variant=bora".to_string(),
            ]
        );
    }

    #[test]
    fn precision_rows_key_separately_and_legacy_rows_keep_their_keys() {
        let legacy = Json::obj(vec![
            ("pool", Json::Num(1.0)),
            ("fast_path", Json::Str("merged".into())),
            ("median_s", Json::Num(0.001)),
        ]);
        // Pre-precision rows keep the exact key the committed baseline
        // used (implicitly f32).
        assert_eq!(serving_key(&legacy).unwrap(), "serve pool=1 path=merged");
        let bf16 = Json::obj(vec![
            ("pool", Json::Num(1.0)),
            ("fast_path", Json::Str("merged".into())),
            ("precision", Json::Str("bf16".into())),
            ("median_s", Json::Num(0.0011)),
        ]);
        assert_eq!(serving_key(&bf16).unwrap(), "serve pool=1 path=merged precision=bf16");
        assert_eq!(decode_key(&bf16).unwrap(), "decode pool=1 path=merged precision=bf16");
        // Same pool + path, different precision: two distinct rows, so a
        // diff of {legacy} vs {legacy, bf16} flags the bf16 row as new
        // instead of colliding with the f32 row.
        let base = Json::obj(vec![("serving", Json::Arr(vec![legacy.clone()]))]);
        let fresh = Json::obj(vec![("serving", Json::Arr(vec![legacy, bf16]))]);
        let d = diff(&base, &fresh).unwrap();
        assert_eq!(d.rows.len(), 1);
        assert!(d.only_baseline.is_empty());
        assert_eq!(d.only_fresh, vec!["serve pool=1 path=merged precision=bf16".to_string()]);
    }

    #[test]
    fn cache_rows_key_on_adapters_mix_and_budget() {
        let row = Json::obj(vec![
            ("adapters", Json::Num(1000.0)),
            ("mix", Json::Str("zipf".into())),
            ("budget", Json::Str("tight".into())),
            ("median_s", Json::Num(0.02)),
            ("hit_rate", Json::Num(0.9)),
        ]);
        assert_eq!(cache_key(&row).unwrap(), "cache adapters=1000 mix=zipf budget=tight");
        let base = doc(false);
        let mut fresh = doc(false);
        if let Json::Obj(map) = &mut fresh {
            map.insert("cache".to_string(), Json::Arr(vec![row]));
        }
        let d = diff(&base, &fresh).unwrap();
        assert_eq!(d.only_fresh, vec!["cache adapters=1000 mix=zipf budget=tight".to_string()]);
    }

    #[test]
    fn gate_fails_on_removed_rows_and_gates_new_rows_behind_the_flag() {
        // Identical docs pass under either strictness.
        let clean = diff(&doc(false), &doc(false)).unwrap();
        assert!(clean.gate(false).is_ok());

        // A fresh run with a new row fails strict mode but passes with
        // --allow-new-keys; legacy (matched) keys still diff normally.
        let grew = diff(&doc(false), &doc(true)).unwrap();
        let err = grew.gate(false).unwrap_err();
        assert!(err.contains("gemm_ba_r8_smallk 128x8x128"), "unexpected gate error: {err}");
        assert!(grew.gate(true).is_ok());
        assert_eq!(grew.rows.len(), 4);

        // A fresh run that *lost* a row fails even with the flag.
        let shrank = diff(&doc(true), &doc(false)).unwrap();
        assert!(shrank.gate(true).unwrap_err().contains("missing from fresh run"));
    }

    #[test]
    fn diff_round_trips_through_the_parser() {
        // The tool consumes files perf_gate wrote with `to_pretty`.
        let base = doc(false);
        let reparsed = json::parse(&base.to_pretty()).unwrap();
        let d = diff(&base, &reparsed).unwrap();
        assert!(d.only_baseline.is_empty() && d.only_fresh.is_empty());
        assert!(d.rows.iter().all(|r| r.delta_pct() == 0.0));
    }
}
