//! Caching-allocator simulator — the measurement substrate behind every
//! "measured allocator delta" and "reserved VRAM" number in the paper
//! (Tables 1, 7, 8/13; Appendix D's three-metric methodology).
//!
//! Models the behaviour of PyTorch's CUDA caching allocator that the
//! paper's methodology depends on:
//!
//! * allocations round up to 512-byte granularity and are served from
//!   size-bucketed free lists when a cached block fits (best-fit);
//! * freed blocks return to the cache, NOT the device — so `reserved`
//!   (what the GPU withholds from other processes) only grows until an
//!   explicit `empty_cache`;
//! * `allocated` tracks live bytes; `max_allocated` its peak — the
//!   microbenchmark metric; `reserved - baseline` captures fragmentation
//!   (the §6.1 concern: transient churn fragments the cache).
//!
//! Oversized-block reuse is bounded (a block may serve a request down to
//! half its size, like the CUDA allocator's split threshold) so churning
//! mismatched transient sizes grows `reserved` — the fragmentation the
//! paper's §6.1 deployment anecdote describes.

use std::collections::BTreeMap;

const GRANULARITY: u64 = 512;

/// One allocation event in a replayable stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    pub name: String,
    pub bytes: u64,
    pub kind: EventKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Alloc,
    Free,
}

impl Event {
    pub fn alloc(name: &str, bytes: u64) -> Event {
        Event { name: name.to_string(), bytes, kind: EventKind::Alloc }
    }

    pub fn free(name: &str) -> Event {
        Event { name: name.to_string(), bytes: 0, kind: EventKind::Free }
    }

    /// Indexed variants for per-chunk buffers.
    pub fn alloc_n(name: &str, i: u64, bytes: u64) -> Event {
        Event { name: format!("{name}.{i}"), bytes, kind: EventKind::Alloc }
    }

    pub fn free_n(name: &str, i: u64) -> Event {
        Event { name: format!("{name}.{i}"), bytes: 0, kind: EventKind::Free }
    }
}

/// Simulated caching allocator.
#[derive(Debug, Default)]
pub struct CachingAllocator {
    /// Live named allocations -> (requested rounded size, served block size).
    /// `allocated` counts the requested size (what torch's allocated stat
    /// reports); `reserved` counts whole blocks.
    live: BTreeMap<String, (u64, u64)>,
    /// Cached (freed but retained) blocks, keyed by size.
    cache: BTreeMap<u64, u32>,
    allocated: u64,
    max_allocated: u64,
    reserved: u64,
    max_reserved: u64,
    n_device_allocs: u64,
    n_cache_hits: u64,
}

impl CachingAllocator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Round a request up to the allocator's 512-byte granularity — the
    /// size `alloc` will account for it. Callers that enforce byte
    /// budgets against [`CachingAllocator::allocated`] (the merged-weight
    /// cache) use this so their arithmetic matches the accounting.
    pub fn round_up(bytes: u64) -> u64 {
        bytes.max(1).div_ceil(GRANULARITY) * GRANULARITY
    }

    /// Allocate a named tensor. Panics on duplicate names (stream bug).
    pub fn alloc(&mut self, name: &str, bytes: u64) {
        let size = Self::round_up(bytes);
        assert!(
            !self.live.contains_key(name),
            "double alloc of {name:?}"
        );
        // Best-fit from cache: smallest cached block >= size, but only if
        // it wastes less than half (split-threshold behaviour).
        let candidate = self
            .cache
            .range(size..)
            .next()
            .map(|(&s, _)| s)
            .filter(|&s| s <= size.saturating_mul(2));
        let block = match candidate {
            Some(s) => {
                let cnt = self.cache.get_mut(&s).unwrap();
                *cnt -= 1;
                if *cnt == 0 {
                    self.cache.remove(&s);
                }
                self.n_cache_hits += 1;
                s
            }
            None => {
                self.reserved += size;
                self.max_reserved = self.max_reserved.max(self.reserved);
                self.n_device_allocs += 1;
                size
            }
        };
        self.live.insert(name.to_string(), (size, block));
        self.allocated += size;
        self.max_allocated = self.max_allocated.max(self.allocated);
    }

    /// Free a named tensor back to the cache.
    pub fn free(&mut self, name: &str) {
        let (size, block) = self
            .live
            .remove(name)
            .unwrap_or_else(|| panic!("free of unknown tensor {name:?}"));
        self.allocated -= size;
        *self.cache.entry(block).or_insert(0) += 1;
    }

    /// Replay an event stream.
    pub fn replay(&mut self, events: &[Event]) {
        for ev in events {
            match ev.kind {
                EventKind::Alloc => self.alloc(&ev.name, ev.bytes),
                EventKind::Free => self.free(&ev.name),
            }
        }
    }

    /// torch.cuda.empty_cache(): release cached blocks to the device.
    pub fn empty_cache(&mut self) {
        let cached: u64 = self.cache.iter().map(|(&s, &c)| s * c as u64).sum();
        self.reserved -= cached;
        self.cache.clear();
    }

    /// reset_peak_memory_stats().
    pub fn reset_peak(&mut self) {
        self.max_allocated = self.allocated;
        self.max_reserved = self.reserved;
    }

    // ---- the three metrics of Appendix D ----------------------------------

    /// `torch.cuda.max_memory_allocated()` — microbenchmark deltas.
    pub fn max_allocated(&self) -> u64 {
        self.max_allocated
    }

    /// Live bytes right now.
    pub fn allocated(&self) -> u64 {
        self.allocated
    }

    /// `torch.cuda.memory_reserved()` — what the device withholds
    /// (includes cache + fragmentation).
    pub fn reserved(&self) -> u64 {
        self.reserved
    }

    pub fn max_reserved(&self) -> u64 {
        self.max_reserved
    }

    /// Cache effectiveness counters (fragmentation diagnostics).
    pub fn device_allocs(&self) -> u64 {
        self.n_device_allocs
    }

    pub fn cache_hits(&self) -> u64 {
        self.n_cache_hits
    }
}

/// Peak live bytes of an event stream replayed on a fresh allocator —
/// the "allocator delta after reset_peak + empty_cache" measurement.
pub fn peak_of_events(events: &[Event]) -> u64 {
    let mut alloc = CachingAllocator::new();
    alloc.replay(events);
    alloc.max_allocated()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(b: u64) -> u64 {
        CachingAllocator::round_up(b)
    }

    #[test]
    fn tracks_peak() {
        let mut a = CachingAllocator::new();
        a.alloc("x", 1000);
        a.alloc("y", 2000);
        a.free("x");
        a.alloc("z", 500);
        assert_eq!(a.max_allocated(), r(1000) + r(2000));
        // z is served from x's cached 1024-byte block, but the allocated
        // stat counts the requested (rounded) size, like torch's.
        assert_eq!(a.allocated(), r(2000) + r(500));
        assert_eq!(a.cache_hits(), 1);
    }

    #[test]
    fn cache_reuse_keeps_reserved_flat() {
        let mut a = CachingAllocator::new();
        for i in 0..100 {
            a.alloc(&format!("t{i}"), 1 << 20);
            a.free(&format!("t{i}"));
        }
        // One device block, reused 99 times.
        assert_eq!(a.device_allocs(), 1);
        assert_eq!(a.cache_hits(), 99);
        assert_eq!(a.reserved(), 1 << 20);
    }

    #[test]
    fn mismatched_sizes_fragment_reserved() {
        // Churning growing sizes defeats the cache (each block too small
        // for the next request): reserved grows — §6.1's fragmentation.
        let mut a = CachingAllocator::new();
        let mut total = 0u64;
        for i in 1..=10u64 {
            let sz = i * 3 << 20;
            a.alloc("t", sz);
            a.free("t");
            total += CachingAllocator::round_up(sz);
        }
        assert_eq!(a.reserved(), total, "no reuse possible");
    }

    #[test]
    fn half_size_reuse_allowed_but_not_tiny() {
        let mut a = CachingAllocator::new();
        a.alloc("big", 10 << 20);
        a.free("big");
        // 6 MiB fits in the cached 10 MiB block (>= half).
        a.alloc("med", 6 << 20);
        assert_eq!(a.device_allocs(), 1);
        a.free("med");
        // 1 MiB would waste > half of the 10 MiB block: new device alloc.
        a.alloc("small", 1 << 20);
        assert_eq!(a.device_allocs(), 2);
    }

    #[test]
    fn empty_cache_returns_reserved() {
        let mut a = CachingAllocator::new();
        a.alloc("x", 4 << 20);
        a.free("x");
        assert_eq!(a.reserved(), 4 << 20);
        a.empty_cache();
        assert_eq!(a.reserved(), 0);
    }

    #[test]
    #[should_panic(expected = "double alloc")]
    fn double_alloc_is_a_stream_bug() {
        let mut a = CachingAllocator::new();
        a.alloc("x", 10);
        a.alloc("x", 10);
    }

    #[test]
    #[should_panic(expected = "unknown tensor")]
    fn free_unknown_is_a_stream_bug() {
        let mut a = CachingAllocator::new();
        a.free("ghost");
    }

    #[test]
    fn replay_peak_helper() {
        let events = vec![
            Event::alloc("a", 1 << 20),
            Event::alloc("b", 1 << 20),
            Event::free("a"),
            Event::free("b"),
        ];
        assert_eq!(peak_of_events(&events), 2 << 20);
    }
}
