//! Memory simulator: a caching-allocator model (PyTorch-CUDA-style) that
//! replays allocation event streams to regenerate the paper's three memory
//! metrics (allocator peak, working-set delta, reserved VRAM — Appendix D).

pub mod allocator;

pub use allocator::{CachingAllocator, Event};
