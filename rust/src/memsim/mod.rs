//! Memory simulator: a caching-allocator model (PyTorch-CUDA-style) that
//! replays allocation event streams to regenerate the paper's three memory
//! metrics (allocator peak, working-set delta, reserved VRAM — Appendix D).
//!
//! Beyond offline replay, the allocator is the live bookkeeping spine of
//! the serving layer's budgeted merged-weight cache
//! ([`crate::runtime::cache`]): every merge promotion/eviction is an
//! alloc/free here, so resident bytes, the high-water mark, and the
//! replayable residency event stream all come from one accounting model.

pub mod allocator;

pub use allocator::{peak_of_events, CachingAllocator, Event, EventKind};
