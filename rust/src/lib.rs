//! dorafactors: factored norms and fused kernels for high-rank DoRA.
//!
//! Reproduction of "Scaling DoRA: High-Rank Adaptation via Factored Norms
//! and Fused Kernels" as a three-layer Rust + JAX + Pallas stack:
//!
//! * L1/L2 (build time): Pallas kernels + JAX model, AOT-lowered to HLO
//!   text under `artifacts/` (see `python/compile/`).
//! * L3 (this crate): the deployable runtime — PJRT execution of the AOT
//!   artifacts, the three-tier dispatch, a training/serving coordinator,
//!   real CPU kernels for the compose/norm hot paths, and the simulation
//!   substrates (GPU cost model, caching allocator) that regenerate every
//!   table and figure of the paper's evaluation.
//!
//! See DESIGN.md for the experiment index and substitution notes.

// Kernel hot loops use explicit indexed form on purpose (unit-stride
// addressing the optimizer vectorizes predictably), and kernel entry
// points take the full operand list by design — mirror of the CUDA
// signatures they model.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod bench;
pub mod coordinator;
pub mod dispatch;
pub mod dora;
pub mod kernels;
pub mod gpusim;
pub mod memsim;
pub mod models;
pub mod numerics;
pub mod runtime;
pub mod util;
