//! GPU-time cost of every DoRA operation, per configuration — the engine
//! behind the microbenchmark figures (6, 7, 8, 10) and the model-level
//! tables (4, 5, 6).
//!
//! Conventions (matching the paper's accounting):
//!
//! * Compose traffic is counted in *useful* bytes — the fused kernel's
//!   3 reads + 1 write — and each path's achieved bandwidth fraction
//!   absorbs its inefficiency (Figure 7 plots exactly this quantity, with
//!   "eager values are approximate lower bounds").
//! * The eager compose chain is 4 element-wise kernel launches plus the
//!   producer-consumer traffic; the fused kernel is a single launch.
//! * The fused *backward* writes two outputs (3 useful passes) with a
//!   dual-output efficiency penalty and a fixed custom-op overhead; the
//!   eager backward is 2 kernels of 2 passes each. This reproduces the
//!   paper's Figure-8 crossover: fused trails eager below ~2048x6144 and
//!   wins above ~8192x8192.

use crate::dora::config::{ActShape, Config, ModuleShape};
use crate::gpusim::device::Device;
use crate::gpusim::kernel::{self, BwClass, KernelCost};
use crate::numerics::Dtype;

/// Number of launches in the eager compose chain: t1 = s*lora,
/// t2 = g*t1, t3 = (g-1)*base, delta = t3 + t2 (paper §3.1: "four
/// sequential element-wise operations, each launching a separate kernel").
pub const EAGER_COMPOSE_LAUNCHES: u32 = 4;

/// Dual-output efficiency penalty for the fused backward (writing two
/// tensors halves per-output coalescing headroom; the Triton kernel
/// compensates with ROWS_PER_PROGRAM but still lands below the forward's
/// fraction — §3.2).
const FUSED_BWD_EFF: f64 = 0.72;

/// Eager backward chains only 2 kernels, so its cache behaviour is
/// better than the 4-kernel forward chain.
const EAGER_BWD_BOOST: f64 = 2.0;

/// Fixed overhead of the fused backward path (custom-op dispatch,
/// autograd bookkeeping) — the source of the sub-crossover losses.
const FUSED_BWD_OVERHEAD: f64 = 6.0e-6;

// ---------------------------------------------------------------------------
// Compose kernels (Figures 6, 7, 8).
// ---------------------------------------------------------------------------

/// Useful bytes of one compose: read base, lora (rows x d_out), g (d_out),
/// write delta.
fn compose_useful_bytes(act: ActShape, dt: Dtype) -> u64 {
    ((3 * act.elems() + act.d_out) * dt.size()) as u64
}

/// Forward compose cost.
pub fn compose_forward(dev: &Device, act: ActShape, dt: Dtype, fused: bool) -> KernelCost {
    let bytes = compose_useful_bytes(act, dt);
    if fused {
        kernel::stream(dev, bytes, BwClass::Fused)
    } else {
        let mut c = kernel::stream(dev, bytes, BwClass::EagerChain);
        // 4 launches instead of 1.
        c.time += dev.launch_latency * (EAGER_COMPOSE_LAUNCHES - 1) as f64;
        c.launches = EAGER_COMPOSE_LAUNCHES;
        c
    }
}

/// Tier-1 dual-output forward (delta + inner): one extra write.
pub fn compose_forward_dual(dev: &Device, act: ActShape, dt: Dtype) -> KernelCost {
    let bytes = ((4 * act.elems() + act.d_out) * dt.size()) as u64;
    let mut c = kernel::stream(dev, bytes, BwClass::Fused);
    c.time /= FUSED_BWD_EFF; // dual-output penalty
    c
}

/// Backward compose cost: d_lora and d_base from d_delta.
pub fn compose_backward(dev: &Device, act: ActShape, dt: Dtype, fused: bool) -> KernelCost {
    let elems = act.elems();
    if fused {
        // One kernel: read d (1), write d_lora + d_base (2).
        let bytes = ((3 * elems + act.d_out) * dt.size()) as u64;
        let bw = dev.fused_bw_frac * FUSED_BWD_EFF * dev.peak_bw;
        KernelCost {
            time: dev.launch_latency + FUSED_BWD_OVERHEAD + bytes as f64 / bw,
            bytes,
            flops: 0.0,
            launches: 1,
        }
    } else {
        // Two kernels, each read d + write out. The 2-op chain thrashes
        // less than the 4-op forward chain (boost), converging to the
        // fused fraction when the working set is L2-resident.
        let bytes = ((4 * elems + 2 * act.d_out) * dt.size()) as u64;
        let resid = (-(bytes as f64) / dev.l2_bytes).exp();
        let big = (dev.eager_bw_frac * EAGER_BWD_BOOST).min(dev.fused_bw_frac * 0.95);
        let frac = big + (dev.fused_bw_frac - big) * resid;
        KernelCost {
            time: 2.0 * dev.launch_latency + bytes as f64 / (frac * dev.peak_bw),
            bytes,
            flops: 0.0,
            launches: 2,
        }
    }
}

/// The d_mag reduction (sum of d_delta * inner over rows), shared by both
/// paths ("d_mag via PyTorch reduction", §3.2).
pub fn dmag_reduction(dev: &Device, act: ActShape, dt: Dtype) -> KernelCost {
    kernel::reduction(dev, 2 * act.elems(), act.d_out, dt.size())
}

// ---------------------------------------------------------------------------
// Weight-norm engines (Figure 10, Tables 1/7 timing side).
// ---------------------------------------------------------------------------

/// Norm computation cost for a module under the given configuration.
/// fp32 accumulation throughout (elt = 4) for the factored path; the dense
/// baselines run in the storage dtype then accumulate in fp32.
pub fn weight_norm(dev: &Device, m: ModuleShape, dt: Dtype, config: Config) -> KernelCost {
    let ModuleShape { d_out, d_in, rank: r } = m;
    match config {
        Config::Peft => {
            // x_eye = eye(d_in): one write of d_in^2.
            let eye = kernel::elementwise(dev, d_in * d_in, 0, 1, dt.size(), BwClass::EagerChain);
            // lora_A(x_eye): [d_in, d_in] @ [d_in, r]
            let mm1 = kernel::matmul(dev, d_in, r, d_in, dt.size());
            // lora_B(.): [d_in, r] @ [r, d_out]
            let mm2 = kernel::matmul(dev, d_in, d_out, r, dt.size());
            // composed = W + s * lora_weight: 2 reads + 1 write (plus the
            // scaling temp — part of the eager chain class).
            let comp = kernel::elementwise(dev, d_out * d_in, 2, 1, dt.size(), BwClass::EagerChain);
            // row norm: read composed once.
            let norm = kernel::reduction(dev, d_out * d_in, d_out, dt.size());
            kernel::total(&[eye, mm1, mm2, comp, norm])
        }
        Config::DenseBA => {
            // B @ A: [d_out, r] @ [r, d_in].
            let mm = kernel::matmul(dev, d_out, d_in, r, dt.size());
            let comp = kernel::elementwise(dev, d_out * d_in, 2, 1, dt.size(), BwClass::EagerChain);
            let norm = kernel::reduction(dev, d_out * d_in, d_out, dt.size());
            kernel::total(&[mm, comp, norm])
        }
        Config::Eager => {
            // Algorithm 1 in chunked eager ops (fp32 accumulation):
            // base_sq: read W once (fp32 copies of chunks), square+reduce.
            let base_sq = kernel::reduction(dev, d_out * d_in, d_out, 4);
            // U = W A^T chunks: flops 2*d_out*d_in*r, W read again.
            let u = kernel::matmul(dev, d_out, r, d_in, 4);
            // G = A A^T: 2*r^2*d_in.
            let g = kernel::matmul(dev, r, r, d_in, 4);
            // cross = sum(B * U): small. ba_sq = (B G * B): small.
            let cross = kernel::elementwise(dev, d_out * r, 2, 0, 4, BwClass::EagerChain);
            let bg = kernel::matmul(dev, d_out, r, r, 4);
            let assembly = kernel::elementwise(dev, d_out, 3, 1, 4, BwClass::EagerChain);
            kernel::total(&[base_sq, u, g, cross, bg, assembly])
        }
        Config::Fused => {
            // Pallas chunk kernel: W read ONCE, all three terms in-pass.
            // The dominant contraction is U = W A^T — same shape (and
            // therefore same MXU/TensorCore efficiency curve) as the eager
            // path's matmul, but with the base_sq pass and the A-read
            // folded into it, and no separate cross/elementwise launches.
            let u = kernel::matmul(dev, d_out, r, d_in, 4);
            let g = kernel::matmul(dev, r, r, d_in, 4);
            let chunk = KernelCost {
                time: u.time.max(g.time) + dev.launch_latency,
                bytes: u.bytes + (2 * d_out * r * 4) as u64,
                flops: u.flops + g.flops,
                launches: 1,
            };
            // BG matmul + fused assembly kernel.
            let bg = kernel::matmul(dev, d_out, r, r, 4);
            let assembly = kernel::stream(dev, (4 * d_out * 4) as u64, BwClass::Fused);
            kernel::total(&[chunk, bg, assembly])
        }
    }
}

// ---------------------------------------------------------------------------
// Whole-module costs (single-layer E2E, Figures 13-15; model-level §5.2).
// ---------------------------------------------------------------------------

/// Cost of the LoRA-path matmuls: (x @ A^T) [rows, r] then (. @ B^T)
/// [rows, d_out].
pub fn lora_matmuls(dev: &Device, m: ModuleShape, rows: usize, dt: Dtype) -> KernelCost {
    let a = kernel::matmul(dev, rows, m.rank, m.d_in, dt.size());
    let b = kernel::matmul(dev, rows, m.d_out, m.rank, dt.size());
    a.add(b)
}

/// Cost of the frozen base matmul x @ W^T.
pub fn base_matmul(dev: &Device, m: ModuleShape, rows: usize, dt: Dtype) -> KernelCost {
    kernel::matmul(dev, rows, m.d_out, m.d_in, dt.size())
}

/// Full forward cost of one adapted module under `config`.
pub fn module_forward(
    dev: &Device,
    m: ModuleShape,
    rows: usize,
    dt: Dtype,
    config: Config,
) -> KernelCost {
    let act = ActShape::new(rows, m.d_out);
    let norm = weight_norm(dev, m, dt, config);
    let base = base_matmul(dev, m, rows, dt);
    let lora = lora_matmuls(dev, m, rows, dt);
    let compose = compose_forward(dev, act, dt, config.fused_compose());
    // magnitude division: [d_out] elementwise, negligible but counted.
    let div = kernel::elementwise(dev, m.d_out, 2, 1, 4, BwClass::EagerChain);
    kernel::total(&[norm, base, lora, compose, div])
}

/// Full backward cost of one adapted module (d_x, d_A, d_B, d_m), with
/// gradient checkpointing recomputation of the forward included (the
/// paper's model benchmarks all run with checkpointing).
pub fn module_backward(
    dev: &Device,
    m: ModuleShape,
    rows: usize,
    dt: Dtype,
    config: Config,
) -> KernelCost {
    let act = ActShape::new(rows, m.d_out);
    // Checkpoint recompute: the forward runs again (including the norm).
    let recompute = module_forward(dev, m, rows, dt, config);
    // Compose backward.
    let cbwd = compose_backward(dev, act, dt, config.fused_compose());
    let dmag = dmag_reduction(dev, act, dt);
    // Matmul gradients: d_lora -> dB [d_out, r] and d(xa) [rows, r] -> dA;
    // base path dW skipped (frozen) but d_x needs W: [rows, d_out] @ W.
    let d_b = kernel::matmul(dev, m.d_out, m.rank, rows, dt.size());
    let d_xa = kernel::matmul(dev, rows, m.rank, m.d_out, dt.size());
    let d_a = kernel::matmul(dev, m.rank, m.d_in, rows, dt.size());
    let d_x_lora = kernel::matmul(dev, rows, m.d_in, m.rank, dt.size());
    let d_x_base = kernel::matmul(dev, rows, m.d_in, m.d_out, dt.size());
    kernel::total(&[recompute, cbwd, dmag, d_b, d_xa, d_a, d_x_lora, d_x_base])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::find;

    const BF16: Dtype = Dtype::Bf16;

    #[test]
    fn fused_compose_faster_at_large_shapes() {
        let dev = find("b200").unwrap();
        let act = ActShape::new(8192, 8192);
        let e = compose_forward(dev, act, BF16, false).time;
        let f = compose_forward(dev, act, BF16, true).time;
        let speedup = e / f;
        // Paper Figure 6: B200 reaches 3-4.5x at the largest shapes.
        assert!(speedup > 2.5 && speedup < 5.0, "speedup {speedup}");
    }

    #[test]
    fn compose_speedup_ordering_follows_bandwidth_class() {
        // B200's eager path is most launch/thrash-bound -> largest gain.
        let act = ActShape::new(8192, 8192);
        let s = |n: &str| {
            let d = find(n).unwrap();
            compose_forward(d, act, BF16, false).time / compose_forward(d, act, BF16, true).time
        };
        assert!(s("b200") > s("h200"), "b200 {} h200 {}", s("b200"), s("h200"));
        assert!(s("h200") > s("l40s"), "h200 {} l40s {}", s("h200"), s("l40s"));
    }

    #[test]
    fn backward_crossover_exists() {
        let dev = find("h200").unwrap();
        // Small activation: fused trails (launch/overhead bound).
        let small = ActShape::new(512, 1024);
        let e_s = compose_backward(dev, small, BF16, false).time;
        let f_s = compose_backward(dev, small, BF16, true).time;
        assert!(f_s > 0.85 * e_s, "fused should not dominate tiny shapes");
        // Large activation: fused wins.
        let large = ActShape::new(16384, 8192);
        let e_l = compose_backward(dev, large, BF16, false).time;
        let f_l = compose_backward(dev, large, BF16, true).time;
        assert!(e_l / f_l > 1.1, "large-shape bwd speedup {}", e_l / f_l);
    }

    #[test]
    fn peft_norm_time_constant_in_rank_factored_linear() {
        // Figure 10's shape: PEFT flat in r, factored ~linear in r.
        let dev = find("rtx").unwrap();
        let t = |cfg: Config, r: usize| {
            weight_norm(dev, ModuleShape::new(8192, 8192, r), Dtype::F32, cfg).time
        };
        let p16 = t(Config::Peft, 16);
        let p768 = t(Config::Peft, 768);
        assert!(p768 / p16 < 1.6, "PEFT should be ~flat in r: {}", p768 / p16);
        // Factored time grows with r (the U/G contractions), on top of a
        // rank-independent floor (the two W read passes) — Figure 10's
        // linear-plus-offset trace.
        let ranks = [64, 128, 256, 384, 512, 768];
        let times: Vec<f64> = ranks.iter().map(|&r| t(Config::Eager, r)).collect();
        assert!(times.windows(2).all(|w| w[1] > w[0]), "not monotone: {times:?}");
        let factored_growth = times[5] / times[0];
        let peft_growth = t(Config::Peft, 768) / t(Config::Peft, 64);
        assert!(factored_growth > 1.25, "factored should scale with r: {factored_growth}");
        assert!(
            factored_growth > peft_growth,
            "factored growth {factored_growth} should exceed PEFT growth {peft_growth}"
        );
    }

    #[test]
    fn factored_matches_peft_at_low_rank_on_rtx() {
        // Figure 10: at r <= 128 factored matches/beats the reference on
        // the bandwidth-constrained RTX 6000 PRO.
        let dev = find("rtx").unwrap();
        let m = ModuleShape::new(8192, 8192, 128);
        let peft = weight_norm(dev, m, Dtype::F32, Config::Peft).time;
        let fact = weight_norm(dev, m, Dtype::F32, Config::Eager).time;
        assert!(fact <= peft * 1.1, "factored {fact} vs peft {peft}");
    }

    #[test]
    fn fused_norm_cheaper_than_eager_norm() {
        let dev = find("h200").unwrap();
        let m = ModuleShape::new(4096, 4096, 384);
        let e = weight_norm(dev, m, BF16, Config::Eager).time;
        let f = weight_norm(dev, m, BF16, Config::Fused).time;
        assert!(f < e, "fused {f} eager {e}");
    }

    #[test]
    fn module_forward_ordering() {
        // Whole-module: Fused <= Eager <= DenseBA <= Peft on every device.
        let m = ModuleShape::new(4096, 4096, 384);
        for dev in crate::gpusim::device::DEVICES.iter() {
            let t = |c| module_forward(dev, m, 4096, BF16, c).time;
            assert!(t(Config::Fused) <= t(Config::Eager) * 1.001, "{}", dev.name);
            assert!(t(Config::Eager) <= t(Config::Peft), "{}", dev.name);
        }
    }
}
