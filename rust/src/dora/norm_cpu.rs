//! Real CPU implementations of the weight-norm engines with exact
//! allocation accounting — the measurable half of the factored-norm claim.
//!
//! Three engines mirror the paper's configurations:
//!
//! * [`peft_norm`]     — identity-matrix materialization (the upstream
//!   HF PEFT path): builds eye(d_in), pushes it through A then B, forms
//!   the dense composed weight, reduces.
//! * [`dense_ba_norm`] — direct B@A; still materializes [d_out, d_in].
//! * [`factored_norm`] — Algorithm 1: chunked base/cross/Gram accumulation
//!   through O(d_out*r + r^2) intermediates, fp32 throughout.
//!
//! Every transient allocation is reported through an [`AllocTracker`] so
//! the norm-memory tables (1, 7) can be regenerated from *real* peak
//! working sets, not just the cost model.

use crate::dora::config::ModuleShape;

/// Tracks live transient bytes and their peak — the CPU analogue of
/// `torch.cuda.max_memory_allocated()` deltas.
#[derive(Debug, Default, Clone)]
pub struct AllocTracker {
    live: u64,
    peak: u64,
    total_allocated: u64,
}

impl AllocTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&mut self, bytes: u64) {
        self.live += bytes;
        self.total_allocated += bytes;
        self.peak = self.peak.max(self.live);
    }

    pub fn free(&mut self, bytes: u64) {
        debug_assert!(self.live >= bytes, "free without alloc");
        self.live -= bytes;
    }

    /// Peak live transient bytes.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn total_allocated(&self) -> u64 {
        self.total_allocated
    }

    pub fn live(&self) -> u64 {
        self.live
    }
}

fn vec_f32(tracker: &mut AllocTracker, n: usize) -> Vec<f32> {
    tracker.alloc((n * 4) as u64);
    vec![0f32; n]
}

fn drop_vec(tracker: &mut AllocTracker, v: Vec<f32>) {
    tracker.free((v.len() * 4) as u64);
    drop(v);
}

/// Dense matmul C[m,n] = A[m,k] @ B[k,n] (row-major). Routed through the
/// blocked register-tiled cores (`kernels::gemm`); the old i-k-j loop's
/// `aik == 0.0` skip branch is gone — it defeated vectorization and made
/// throughput data-dependent, and a real PEFT dense GEMM does not skip
/// zeros either, so the eye-matmul baseline now costs what it claims.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    matmul_into(a, b, m, k, n, &mut c);
    c
}

pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    crate::kernels::gemm::nn_into(a, b, m, k, n, c);
}

/// Row-wise L2 norm of `w + s * delta`, materializing `scaled = s * delta`
/// and `composed = w + scaled` exactly like the PyTorch expression
/// `torch.linalg.norm(weight + scaling * lora_weight, dim=1)` does —
/// these two dense temporaries are part of the baselines' memory story
/// (Table 1: "3-4 dense [d_out, d_in] temporaries"). Accumulation in f64
/// (torch.linalg.norm's wide internal accumulation).
fn rowwise_norm_composed(
    w: &[f32],
    delta: &[f32],
    s: f32,
    d_out: usize,
    d_in: usize,
    tracker: &mut AllocTracker,
) -> Vec<f32> {
    let n = d_out * d_in;
    let mut scaled = vec_f32(tracker, n);
    for i in 0..n {
        scaled[i] = s * delta[i];
    }
    let mut composed = vec_f32(tracker, n);
    for i in 0..n {
        composed[i] = w[i] + scaled[i];
    }
    drop_vec(tracker, scaled);
    let mut out = vec![0f32; d_out];
    for i in 0..d_out {
        let row = &composed[i * d_in..(i + 1) * d_in];
        let mut acc = 0f64;
        for &v in row {
            acc += (v as f64) * (v as f64);
        }
        out[i] = acc.sqrt() as f32;
    }
    drop_vec(tracker, composed);
    out
}

/// HF PEFT's identity-matrix path (paper §1 listing), allocation-faithful:
/// eye [d_in, d_in] -> A(eye) [d_in, r] -> B(.) [d_in, d_out] -> transpose
/// [d_out, d_in] -> composed norm.
pub fn peft_norm(
    w: &[f32],
    a: &[f32],
    b: &[f32],
    s: f32,
    m: ModuleShape,
    tracker: &mut AllocTracker,
) -> Vec<f32> {
    let ModuleShape { d_out, d_in, rank: r } = m;
    // x_eye = eye(d_in)  [d_in, d_in]
    let mut eye = vec_f32(tracker, d_in * d_in);
    for i in 0..d_in {
        eye[i * d_in + i] = 1.0;
    }
    // lora_A(x_eye) = x_eye @ A^T  [d_in, r]
    let mut at = vec_f32(tracker, d_in * r); // A^T for the matmul layout
    for i in 0..r {
        for j in 0..d_in {
            at[j * r + i] = a[i * d_in + j];
        }
    }
    let mut h = vec_f32(tracker, d_in * r);
    matmul_into(&eye, &at, d_in, d_in, r, &mut h);
    drop_vec(tracker, eye);
    drop_vec(tracker, at);
    // lora_B(h) = h @ B^T  [d_in, d_out]
    let mut bt = vec_f32(tracker, r * d_out);
    for i in 0..d_out {
        for j in 0..r {
            bt[j * d_out + i] = b[i * r + j];
        }
    }
    let mut hb = vec_f32(tracker, d_in * d_out);
    matmul_into(&h, &bt, d_in, r, d_out, &mut hb);
    drop_vec(tracker, h);
    drop_vec(tracker, bt);
    // .T -> lora_weight [d_out, d_in] (PyTorch's .T is a view, but the
    // subsequent contiguous add materializes; we transpose explicitly).
    let mut lw = vec_f32(tracker, d_out * d_in);
    for i in 0..d_in {
        for j in 0..d_out {
            lw[j * d_in + i] = hb[i * d_out + j];
        }
    }
    drop_vec(tracker, hb);
    let norms = rowwise_norm_composed(w, &lw, s, d_out, d_in, tracker);
    drop_vec(tracker, lw);
    norms
}

/// Direct dense B@A (§5.3's straw-man): skips the identity matrix but
/// still forms [d_out, d_in].
pub fn dense_ba_norm(
    w: &[f32],
    a: &[f32],
    b: &[f32],
    s: f32,
    m: ModuleShape,
    tracker: &mut AllocTracker,
) -> Vec<f32> {
    let ModuleShape { d_out, d_in, rank: r } = m;
    let mut ba = vec_f32(tracker, d_out * d_in);
    matmul_into(b, a, d_out, r, d_in, &mut ba);
    let norms = rowwise_norm_composed(w, &ba, s, d_out, d_in, tracker);
    drop_vec(tracker, ba);
    norms
}

/// Dense column-wise baseline: materialize `B@A` and the composed weight
/// (the same two `[d_out, d_in]` temporaries as [`dense_ba_norm`]), then
/// reduce down columns with a per-column f64 accumulator. The eager
/// reference the factored column engines are verified against.
pub fn dense_ba_colnorm(
    w: &[f32],
    a: &[f32],
    b: &[f32],
    s: f32,
    m: ModuleShape,
    tracker: &mut AllocTracker,
) -> Vec<f32> {
    let ModuleShape { d_out, d_in, rank: r } = m;
    let n = d_out * d_in;
    let mut ba = vec_f32(tracker, n);
    matmul_into(b, a, d_out, r, d_in, &mut ba);
    let mut composed = vec_f32(tracker, n);
    for i in 0..n {
        composed[i] = w[i] + s * ba[i];
    }
    drop_vec(tracker, ba);
    tracker.alloc((d_in * 8) as u64);
    let mut acc = vec![0f64; d_in];
    for i in 0..d_out {
        let row = &composed[i * d_in..(i + 1) * d_in];
        for (k, &v) in row.iter().enumerate() {
            acc[k] += (v as f64) * (v as f64);
        }
    }
    drop_vec(tracker, composed);
    let out = acc.iter().map(|&x| x.sqrt() as f32).collect();
    tracker.free((d_in * 8) as u64);
    out
}

/// Default chunk budget (bytes), matching the paper's 256 MB knob.
pub const DEFAULT_CHUNK_BUDGET: u64 = 256 << 20;

/// Chunk size in elements for Algorithm 1:
/// `cs = min(d_in, budget / (d_out * 4))`, aligned down to 64.
pub fn chunk_size(m: ModuleShape, budget: u64) -> usize {
    let cs = (budget / (m.d_out as u64 * 4)) as usize;
    let cs = cs.min(m.d_in).max(1);
    if cs >= m.d_in {
        m.d_in
    } else {
        ((cs / 64) * 64).max(64.min(m.d_in))
    }
}

/// Algorithm 1: factored row-wise norm. fp32 accumulation (f32 here, with
/// the Gram/cross contractions in f32 — matching the paper's discipline;
/// the chunk working set is [d_out, cs] + U [d_out, r] + G [r, r]).
///
/// Thin f32 wrapper over the shared dtype-generic core
/// (`kernels::norm::factored_norm_seq`) — the same loops the registry's
/// `NormEngine` backends run, so results and tracked allocations are
/// unchanged. New call sites should go through the registry.
pub fn factored_norm(
    w: &[f32],
    a: &[f32],
    b: &[f32],
    s: f32,
    m: ModuleShape,
    budget: u64,
    tracker: &mut AllocTracker,
) -> Vec<f32> {
    crate::kernels::norm::factored_norm_seq::<crate::kernels::F32>(w, a, b, s, m, budget, tracker)
}

/// Magnitude division g = m / max(w_norm, eps) — Eq. 6, shared stage.
pub fn magnitude_divide(mag: &[f32], w_norm: &[f32], eps: f32) -> Vec<f32> {
    mag.iter()
        .zip(w_norm)
        .map(|(&m, &n)| m / n.max(eps))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, prop_close};
    use crate::util::rng::Rng;

    fn wab(seed: u64, m: ModuleShape) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec_f32(m.d_out * m.d_in, 0.05);
        let a = rng.normal_vec_f32(m.rank * m.d_in, 0.1);
        let b = rng.normal_vec_f32(m.d_out * m.rank, 0.1);
        (w, a, b)
    }

    #[test]
    fn three_engines_agree() {
        let m = ModuleShape::new(48, 96, 8);
        let (w, a, b) = wab(1, m);
        let mut t1 = AllocTracker::new();
        let mut t2 = AllocTracker::new();
        let mut t3 = AllocTracker::new();
        let n_peft = peft_norm(&w, &a, &b, 1.5, m, &mut t1);
        let n_ba = dense_ba_norm(&w, &a, &b, 1.5, m, &mut t2);
        let n_f = factored_norm(&w, &a, &b, 1.5, m, 1 << 14, &mut t3);
        for i in 0..m.d_out {
            assert!((n_peft[i] - n_ba[i]).abs() < 1e-4, "peft vs ba at {i}");
            assert!((n_ba[i] - n_f[i]).abs() < 1e-3, "ba vs factored at {i}");
        }
    }

    #[test]
    fn factored_peak_memory_much_smaller() {
        // The Table-1 claim, measured for real: at d=512, r=16 the dense
        // engines' transients dwarf the factored path's.
        let m = ModuleShape::new(512, 512, 16);
        let (w, a, b) = wab(2, m);
        let mut tp = AllocTracker::new();
        let mut tf = AllocTracker::new();
        peft_norm(&w, &a, &b, 1.0, m, &mut tp);
        factored_norm(&w, &a, &b, 1.0, m, DEFAULT_CHUNK_BUDGET, &mut tf);
        let reduction = tp.peak() as f64 / tf.peak() as f64;
        assert!(reduction > 10.0, "measured reduction only {reduction:.1}x");
    }

    #[test]
    fn chunk_size_formula() {
        // Paper Table 1 footnote: cs = min(d_in, budget/(d_out*4)),
        // 64-aligned; at 256 MB and d=8192, cs spans full d_in.
        let m = ModuleShape::new(8192, 8192, 512);
        assert_eq!(chunk_size(m, DEFAULT_CHUNK_BUDGET), 8192);
        // Tighter budget: 64 MB / (8192*4) = 2048.
        assert_eq!(chunk_size(m, 64 << 20), 2048);
        // Non-aligned budget rounds down to 64.
        assert_eq!(chunk_size(m, (64 << 20) + 123456), 2048);
    }

    #[test]
    fn chunking_is_invariant() {
        let m = ModuleShape::new(32, 320, 8);
        let (w, a, b) = wab(3, m);
        let mut t = AllocTracker::new();
        let full = factored_norm(&w, &a, &b, 0.8, m, u64::MAX, &mut t);
        for budget in [(32 * 64 * 4) as u64, (32 * 128 * 4) as u64] {
            let chunked = factored_norm(&w, &a, &b, 0.8, m, budget, &mut t);
            for i in 0..m.d_out {
                assert!(
                    (full[i] - chunked[i]).abs() < 1e-4,
                    "budget {budget}, row {i}: {} vs {}",
                    full[i],
                    chunked[i]
                );
            }
        }
    }

    #[test]
    fn scale_zero_fast_path() {
        let m = ModuleShape::new(16, 32, 4);
        let (w, a, b) = wab(4, m);
        let mut t = AllocTracker::new();
        let n = factored_norm(&w, &a, &b, 0.0, m, u64::MAX, &mut t);
        // Only base_sq allocated: d_out * 4 bytes.
        assert_eq!(t.peak(), (m.d_out * 4) as u64);
        for i in 0..m.d_out {
            let want: f64 = w[i * m.d_in..(i + 1) * m.d_in]
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum();
            assert!((n[i] as f64 - want.sqrt()).abs() < 1e-5);
        }
    }

    #[test]
    fn b_zero_gives_base_norm_and_unity_g() {
        let m = ModuleShape::new(16, 64, 4);
        let (w, a, _) = wab(5, m);
        let b = vec![0f32; m.d_out * m.rank];
        let mut t = AllocTracker::new();
        let n = factored_norm(&w, &a, &b, 2.0, m, u64::MAX, &mut t);
        let g = magnitude_divide(&n, &n, 1e-12);
        for gi in g {
            assert!((gi - 1.0).abs() < 1e-7);
        }
    }

    #[test]
    fn nan_propagates() {
        let m = ModuleShape::new(4, 8, 2);
        let (mut w, a, b) = wab(6, m);
        w[1 * m.d_in + 3] = f32::NAN;
        let mut t = AllocTracker::new();
        let n = factored_norm(&w, &a, &b, 1.0, m, u64::MAX, &mut t);
        assert!(n[1].is_nan());
        assert!(n[0].is_finite());
    }

    #[test]
    fn magnitude_divide_eps_floor() {
        let g = magnitude_divide(&[1.0, 1.0], &[0.0, 2.0], 1e-6);
        assert_eq!(g[0], 1e6);
        assert_eq!(g[1], 0.5);
    }

    #[test]
    fn property_factored_equals_dense() {
        check("factored == dense norm", 30, |gen| {
            let d_out = gen.usize_in(4, 40);
            let d_in = gen.usize_in(4, 80);
            let r = gen.usize_in(1, 12);
            let m = ModuleShape::new(d_out, d_in, r);
            let s = gen.f64_in(0.01, 4.0) as f32;
            let mut rng = Rng::new(gen.case as u64 + 1000);
            let w = rng.normal_vec_f32(d_out * d_in, 0.1);
            let a = rng.normal_vec_f32(r * d_in, 0.2);
            let b = rng.normal_vec_f32(d_out * r, 0.2);
            let mut t = AllocTracker::new();
            let dense = dense_ba_norm(&w, &a, &b, s, m, &mut t);
            let fact = factored_norm(&w, &a, &b, s, m, 4096, &mut t);
            for i in 0..d_out {
                prop_close(dense[i] as f64, fact[i] as f64, 1e-4, &format!("row {i}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn tracker_invariants() {
        let mut t = AllocTracker::new();
        t.alloc(100);
        t.alloc(50);
        t.free(100);
        t.alloc(30);
        assert_eq!(t.peak(), 150);
        assert_eq!(t.live(), 80);
        assert_eq!(t.total_allocated(), 180);
    }
}
