//! Model-level execution plans: per-iteration gradient-computation time,
//! inference time, and peak VRAM for the six VLMs under the four
//! configurations — the engine behind Tables 4, 5, 6, 8/13 and Figures
//! 3, 4, 5.
//!
//! An iteration is `grad_accum` micro-steps of `batch x seq` tokens
//! (paper §5.1: bs=1, ga=8, seq=4096, loss_tokens=1024, optimizer step
//! excluded). Per micro-step:
//!
//! * every adapted module contributes its forward + backward cost
//!   (`gpu_cost::module_*`), with the compose path chosen by the real
//!   three-tier dispatch — so KV projections fall back to eager exactly
//!   as in the paper (§4: ~71% Tier 1 / ~29% Tier 3);
//! * non-adapted work (attention scores/context, embedding + loss) is
//!   config-independent and added once.
//!
//! VRAM is assembled from persistent state (weights, adapter optimizer
//! state, checkpoint boundary activations, logits) plus each
//! configuration's transient high-water mark replayed through the caching
//! allocator (`memsim`), including gradient checkpointing's double
//! allocation of norm temporaries (§1).

use crate::dispatch::{self, ComposeCtx, DispatchEnv};
use crate::dora::config::{ActShape, Config};
use crate::dora::{gpu_cost, mem_events};
use crate::gpusim::device::Device;
use crate::gpusim::kernel::{self, KernelCost};
use crate::memsim::allocator::CachingAllocator;
use crate::models::ModelSpec;
use crate::numerics::Dtype;

/// Benchmark workload (paper §5.1 defaults).
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub rank: usize,
    pub batch: usize,
    pub seq: usize,
    pub grad_accum: usize,
    pub loss_tokens: usize,
    pub dtype: Dtype,
}

impl Default for Workload {
    fn default() -> Self {
        Workload {
            rank: 384,
            batch: 1,
            seq: 4096,
            grad_accum: 8,
            loss_tokens: 1024,
            dtype: Dtype::Bf16,
        }
    }
}

impl Workload {
    pub fn rows(&self) -> usize {
        self.batch * self.seq
    }
}

/// Compose path actually executed for a module, per config + dispatch —
/// resolved through the kernel registry so the model plan and the runtime
/// share one dispatch surface.
fn compose_is_fused(config: Config, act: ActShape, training: bool) -> bool {
    if !config.fused_compose() {
        return false;
    }
    let env = DispatchEnv::default();
    let ctx = if training {
        ComposeCtx::training(act)
    } else {
        ComposeCtx::inference(act)
    };
    dispatch::select_kernel(&env, &ctx).is_fused()
}

/// Config-independent per-micro-step work: attention + embedding/loss.
fn non_adapter_cost(dev: &Device, spec: &ModelSpec, wl: &Workload, training: bool) -> KernelCost {
    let tokens = wl.rows();
    let e = wl.dtype.size();
    // Attention scores + context per layer: 2 GEMM-ish ops of
    // 2*tokens*seq*q_dim flops each.
    let q_dim = spec.n_heads * spec.head_dim;
    let attn_flops = 4.0 * tokens as f64 * wl.seq as f64 * q_dim as f64 * spec.n_layers as f64;
    // Embedding gather is cheap; the loss head is
    // loss_tokens x hidden @ hidden x vocab.
    let head = kernel::matmul(dev, wl.loss_tokens, spec.vocab, spec.hidden, e);
    let attn = KernelCost {
        time: attn_flops / (0.35 * dev.peak_flops)
            + dev.launch_latency * 4.0 * spec.n_layers as f64,
        bytes: 0,
        flops: attn_flops,
        launches: 4 * spec.n_layers as u32,
    };
    let mut total = attn.add(head);
    if training {
        // backward (2x) + checkpoint recompute (1x).
        total.time *= 4.0;
        total.flops *= 4.0;
    }
    total
}

/// One gradient-computation iteration (ga micro-steps, optimizer excluded)
/// — the quantity of Tables 4/5.
pub fn grad_iteration_time(dev: &Device, spec: &ModelSpec, wl: &Workload, config: Config) -> f64 {
    let rows = wl.rows();
    let mut t = non_adapter_cost(dev, spec, wl, true).time;
    for (_, shape, count) in spec.inventory(wl.rank) {
        let act = ActShape::new(rows, shape.d_out);
        let fused = compose_is_fused(config, act, true);
        // Per-module config for the norm engine; compose fused-ness comes
        // from dispatch (sub-crossover modules run the eager compose even
        // under the Fused config).
        let eff_config = if config == Config::Fused && !fused { Config::Eager } else { config };
        let fwd = gpu_cost::module_forward(dev, shape, rows, wl.dtype, eff_config);
        let bwd = gpu_cost::module_backward(dev, shape, rows, wl.dtype, eff_config);
        t += (fwd.time + bwd.time) * count as f64;
    }
    t * wl.grad_accum as f64
}

/// One inference pass over the same batch (Figure 4's quantity).
pub fn inference_time(dev: &Device, spec: &ModelSpec, wl: &Workload, config: Config) -> f64 {
    let rows = wl.rows();
    let mut t = non_adapter_cost(dev, spec, wl, false).time;
    for (_, shape, count) in spec.inventory(wl.rank) {
        let act = ActShape::new(rows, shape.d_out);
        let fused = compose_is_fused(config, act, false);
        let eff_config = if config == Config::Fused && !fused { Config::Eager } else { config };
        t += gpu_cost::module_forward(dev, shape, rows, wl.dtype, eff_config).time * count as f64;
    }
    t * wl.grad_accum as f64
}

/// Does this workload fit the device? (Table 4's "32B models OOM on the
/// 96 GB RTX 6000 PRO under all configurations".)
pub fn fits(dev: &Device, spec: &ModelSpec, wl: &Workload, config: Config) -> bool {
    peak_vram_bytes(spec, wl, config) <= (dev.mem_gb * 1e9) as u64
}

/// Model-level peak VRAM (Table 8/13's reserved-VRAM quantity).
pub fn peak_vram_bytes(spec: &ModelSpec, wl: &Workload, config: Config) -> u64 {
    let e = wl.dtype.size() as u64;
    let rows = wl.rows() as u64;

    // ---- persistent state (config-independent) ---------------------------
    let weights = spec.weight_bytes();
    // Adapter params (A, B, m) in bf16 + fp32 AdamW (m1, m2) + fp32 grads.
    let adapter_params: u64 = spec
        .inventory(wl.rank)
        .iter()
        .map(|(_, s, n)| ((s.rank * s.d_in + s.d_out * s.rank + s.d_out) * n) as u64)
        .sum();
    let opt_state = adapter_params * (2 + 4 + 4 + 4);
    // Gradient checkpointing: one boundary activation per layer
    // [rows, hidden] + the live working set of one layer (~4 activations
    // of the widest projection).
    let boundary = spec.n_layers as u64 * rows * spec.hidden as u64 * e;
    let widest = spec.intermediate.max(spec.hidden) as u64;
    let layer_live = 6 * rows * widest * e;
    // Loss head: logits [loss_tokens, vocab] fp32 + softmax temp.
    let logits = 2 * wl.loss_tokens as u64 * spec.vocab as u64 * 4;

    // ---- config-dependent transients ---------------------------------------
    //
    // Norm transients run under no_grad and are freed before the layer's
    // activation peak, but the caching allocator RETAINS their blocks:
    // they contribute through `reserved`, which is what Table 8 measures
    // ("determines whether colocated workloads can share the device",
    // Appendix D). Replaying every module shape's norm stream through one
    // shared allocator captures both the block retention and the
    // fragmentation from mismatched shapes (§6.1).
    let mut norm_alloc = CachingAllocator::new();
    for (_, shape, _) in spec.inventory(wl.rank) {
        norm_alloc.replay(&mem_events::norm_events(shape, config, wl.dtype, 256 << 20));
    }
    let norm_reserved = norm_alloc.max_reserved();

    // Compose temporaries DO stack into the live working set at the
    // widest module (the eager chain's producer-consumer temps vs the
    // fused kernel's two outputs — Figure 11).
    let mut compose_peak = 0u64;
    for (_, shape, _) in spec.inventory(wl.rank) {
        let act = ActShape::new(wl.rows(), shape.d_out);
        let fused = compose_is_fused(config, act, true);
        let cfg_eff = if config == Config::Fused && !fused { Config::Eager } else { config };
        let mut a = CachingAllocator::new();
        a.replay(&mem_events::compose_forward_events(act, cfg_eff, wl.dtype, true));
        compose_peak = compose_peak.max(a.max_allocated());
    }

    weights + opt_state + boundary + layer_live + logits + norm_reserved + compose_peak
}

/// Speedup of `a` over `b` for Table 4's two columns.
pub fn speedup(t_baseline: f64, t_ours: f64) -> f64 {
    t_baseline / t_ours
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::find;
    use crate::models;

    fn wl() -> Workload {
        Workload::default()
    }

    #[test]
    fn table4_speedup_bands() {
        // Fused vs PEFT: 1.46-1.87x; fused vs eager: 1.18-1.24x on the
        // three model-scope GPUs. Allow a modestly wider envelope for the
        // simulator (±0.15 on each side).
        for dev in crate::gpusim::device::model_devices() {
            for spec in models::MODELS.iter() {
                let t_peft = grad_iteration_time(dev, spec, &wl(), Config::Peft);
                let t_eager = grad_iteration_time(dev, spec, &wl(), Config::Eager);
                let t_fused = grad_iteration_time(dev, spec, &wl(), Config::Fused);
                let vs_peft = t_peft / t_fused;
                let vs_eager = t_eager / t_fused;
                assert!(
                    (1.3..2.1).contains(&vs_peft),
                    "{} on {}: vs PEFT {vs_peft:.2}",
                    spec.name,
                    dev.name
                );
                assert!(
                    (1.05..1.45).contains(&vs_eager),
                    "{} on {}: vs eager {vs_eager:.2}",
                    spec.name,
                    dev.name
                );
            }
        }
    }

    #[test]
    fn inference_speedup_higher_than_training() {
        // §5.2: inference speedup (1.5-2.0x) exceeds gradient-computation
        // speedup because the forward concentrates the compose savings.
        let dev = find("h200").unwrap();
        for spec in models::MODELS.iter() {
            let inf = inference_time(dev, spec, &wl(), Config::Peft)
                / inference_time(dev, spec, &wl(), Config::Fused);
            let grad = grad_iteration_time(dev, spec, &wl(), Config::Peft)
                / grad_iteration_time(dev, spec, &wl(), Config::Fused);
            assert!(inf > grad, "{}: inf {inf:.2} <= grad {grad:.2}", spec.name);
        }
    }

    #[test]
    fn table6_rank_scaling_direction() {
        // vs PEFT grows with rank; vs eager decreases modestly.
        let dev = find("h200").unwrap();
        let spec = models::find("Qwen3-VL-32B").unwrap();
        let sp = |rank: usize, base: Config| {
            let w = Workload { rank, ..wl() };
            grad_iteration_time(dev, spec, &w, base)
                / grad_iteration_time(dev, spec, &w, Config::Fused)
        };
        let p384 = sp(384, Config::Peft);
        let p768 = sp(768, Config::Peft);
        assert!(p768 > p384, "vs PEFT should grow with rank: {p384:.2} -> {p768:.2}");
        // vs eager shrinks modestly (paper: 1.18 -> 1.14); in the cost
        // model the effect is weaker — assert non-increase within noise.
        let e384 = sp(384, Config::Eager);
        let e768 = sp(768, Config::Eager);
        assert!(e768 < e384 + 5e-3, "vs eager should not grow with rank: {e384:.3} -> {e768:.3}");
    }

    #[test]
    fn table8_vram_ordering() {
        // Fused < Eager < DenseBA < PEFT for every model.
        for spec in models::MODELS.iter() {
            let v = |c| peak_vram_bytes(spec, &wl(), c) as f64 / 1e9;
            assert!(v(Config::Fused) < v(Config::Eager), "{}", spec.name);
            assert!(v(Config::Eager) < v(Config::DenseBA), "{}", spec.name);
            assert!(v(Config::DenseBA) < v(Config::Peft), "{}", spec.name);
        }
    }

    #[test]
    fn rtx_oom_for_32b_training_but_not_inference_capacity() {
        // Table 4: 32B models OOM on the 96 GB RTX under ALL configs.
        let rtx = find("rtx").unwrap();
        let spec32 = models::find("Qwen2.5-VL-32B").unwrap();
        for c in crate::dora::ALL_CONFIGS {
            assert!(!fits(rtx, spec32, &wl(), c), "32B should OOM on RTX ({c})");
        }
        // The 8B model fits everywhere.
        let spec8 = models::find("Qwen3-VL-8B").unwrap();
        for c in crate::dora::ALL_CONFIGS {
            assert!(fits(rtx, spec8, &wl(), c), "8B should fit on RTX ({c})");
        }
        // The 24-27B models fit on H200/B200.
        let h200 = find("h200").unwrap();
        let mistral = models::find("mistral").unwrap();
        assert!(fits(h200, mistral, &wl(), Config::Peft));
    }

    #[test]
    fn dense_ba_between_eager_and_fused_or_worse() {
        // Figure 5: dense B@A is inconsistent — sometimes slower than
        // eager. It must never beat fused.
        for dev in crate::gpusim::device::model_devices() {
            for spec in models::MODELS.iter() {
                let t_ba = grad_iteration_time(dev, spec, &wl(), Config::DenseBA);
                let t_fused = grad_iteration_time(dev, spec, &wl(), Config::Fused);
                assert!(t_ba > t_fused, "{} on {}", spec.name, dev.name);
            }
        }
    }
}

#[cfg(test)]
mod probe {
    use super::*;
    use crate::gpusim::device::find;
    use crate::models;

    #[test]
    #[ignore]
    fn print_components() {
        let dev = find("h200").unwrap();
        let spec = models::find("Qwen3-VL-32B").unwrap();
        for rank in [384usize, 512, 768] {
            let w = Workload { rank, ..Workload::default() };
            let tp = grad_iteration_time(dev, spec, &w, Config::Peft);
            let te = grad_iteration_time(dev, spec, &w, Config::Eager);
            let tf = grad_iteration_time(dev, spec, &w, Config::Fused);
            println!(
                "r={rank} peft={tp:.2} eager={te:.2} fused={tf:.2} | vsP={:.3} vsE={:.3}",
                tp / tf,
                te / tf
            );
            let rows = w.rows();
            for (p, shape, _) in spec.inventory(rank) {
                let f = gpu_cost::module_forward(dev, shape, rows, w.dtype, Config::Peft);
                let ff = gpu_cost::module_forward(dev, shape, rows, w.dtype, Config::Fused);
                let n_p = gpu_cost::weight_norm(dev, shape, w.dtype, Config::Peft);
                let n_f = gpu_cost::weight_norm(dev, shape, w.dtype, Config::Fused);
                println!(
                    "  {p:?} {shape:?}: fwd peft {:.3}ms fused {:.3}ms | norm peft {:.3}ms fused {:.3}ms",
                    f.time * 1e3,
                    ff.time * 1e3,
                    n_p.time * 1e3,
                    n_f.time * 1e3
                );
            }
        }
        for c in crate::dora::ALL_CONFIGS {
            let w = Workload::default();
            println!("{c:?} vram: {:.1} GB", peak_vram_bytes(spec, &w, c) as f64 / 1e9);
        }
    }
}
