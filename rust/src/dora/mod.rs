//! The DoRA engine: the paper's four configurations as
//!
//! * real CPU kernels (`compose_cpu`, `norm_cpu`) — measurable
//!   implementations with exact allocation accounting;
//! * GPU cost plans (`gpu_cost`) — per-operation traffic/time models on
//!   the simulated testbed;
//! * allocation event streams (`mem_events`) — replayed through `memsim`
//!   for the memory tables.

pub mod compose_cpu;
pub mod config;
pub mod gpu_cost;
pub mod mem_events;
pub mod model_plan;
pub mod norm_cpu;
pub mod sharded_norm;

pub use config::{ActShape, Config, ModuleShape, ALL_CONFIGS};
