//! Allocation event streams for each configuration's norm + compose paths.
//!
//! These streams feed the caching-allocator simulator (`memsim`) to
//! regenerate the memory tables (1, 7, 8/13) and the memory-profile figure
//! (11). Each stream is the exact temporary lifecycle of the corresponding
//! engine — for the norm engines it matches the real CPU implementations
//! in `norm_cpu.rs` op for op (those use AllocTracker and agree by
//! construction; `tests::streams_match_real_trackers` pins this).

use crate::dora::config::{ActShape, Config, ModuleShape};
use crate::memsim::allocator::Event;
use crate::numerics::Dtype;

/// Norm-path allocation stream (Tables 1 and 7's "measured" column).
/// `dt` is the storage dtype; factored accumulators are always fp32.
pub fn norm_events(m: ModuleShape, config: Config, dt: Dtype, budget: u64) -> Vec<Event> {
    let ModuleShape { d_out, d_in, rank: r } = m;
    let e = dt.size() as u64;
    match config {
        Config::Peft => {
            // fp32 norm-accumulation cast of the composed weight when
            // storage is half precision (the §2.2 dtype discipline applies
            // to the dense engines' norm too).
            let f32_cast: u64 = if dt == Dtype::F32 { 0 } else { (d_out * d_in) as u64 * 4 };
            let mut ev = vec![
            // x_eye [d_in, d_in]
            Event::alloc("eye", (d_in * d_in) as u64 * e),
            // A^T layout copy + h = eye @ A^T [d_in, r]
            Event::alloc("a_t", (d_in * r) as u64 * e),
            Event::alloc("h", (d_in * r) as u64 * e),
            Event::free("eye"),
            Event::free("a_t"),
            // B^T + hb = h @ B^T [d_in, d_out]
            Event::alloc("b_t", (r * d_out) as u64 * e),
            Event::alloc("hb", (d_in * d_out) as u64 * e),
            Event::free("h"),
            Event::free("b_t"),
            // lora_weight = hb.T materialized [d_out, d_in]
            Event::alloc("lora_w", (d_out * d_in) as u64 * e),
            Event::free("hb"),
            // scaled = s * lora_weight ; composed = W + scaled
            Event::alloc("scaled", (d_out * d_in) as u64 * e),
            Event::alloc("composed", (d_out * d_in) as u64 * e),
            Event::free("scaled"),
            Event::alloc("norm", d_out as u64 * 4),
            Event::free("composed"),
            Event::free("lora_w"),
            Event::free("norm"),
            ];
            if f32_cast > 0 {
                let at = ev.len() - 4; // before the norm reduction
                ev.insert(at, Event::alloc("composed_f32", f32_cast));
                ev.push(Event::free("composed_f32"));
            }
            ev
        }
        Config::DenseBA => {
            let f32_cast: u64 = if dt == Dtype::F32 { 0 } else { (d_out * d_in) as u64 * 4 };
            let mut ev = vec![
                Event::alloc("ba", (d_out * d_in) as u64 * e),
                // scaled = s * ba; composed = W + scaled (two temps, like
                // the PEFT path's final expression).
                Event::alloc("scaled", (d_out * d_in) as u64 * e),
                Event::alloc("composed", (d_out * d_in) as u64 * e),
                Event::free("scaled"),
            ];
            if f32_cast > 0 {
                ev.push(Event::alloc("composed_f32", f32_cast));
            }
            ev.push(Event::alloc("norm", d_out as u64 * 4));
            if f32_cast > 0 {
                ev.push(Event::free("composed_f32"));
            }
            ev.extend([
                Event::free("composed"),
                Event::free("ba"),
                Event::free("norm"),
            ]);
            ev
        }
        Config::Fused => {
            // The Pallas chunk kernel (L1) reads W chunks HBM->VMEM and
            // computes base_sq/cross/Gram in-register: NO dense W-sized
            // transient exists at all. Only the accumulators and the
            // per-chunk U_c live in HBM.
            let cs = crate::dora::norm_cpu::chunk_size(m, budget) as u64;
            let n_chunks = (d_in as u64 + cs - 1) / cs;
            let mut ev = vec![
                Event::alloc("base_sq", d_out as u64 * 4),
                Event::alloc("cross", d_out as u64 * 4),
                Event::alloc("gram", (r * r) as u64 * 4),
            ];
            for c in 0..n_chunks {
                ev.push(Event::alloc_n("u_c", c, (d_out * r) as u64 * 4));
                ev.push(Event::free_n("u_c", c));
            }
            ev.push(Event::alloc("ba_sq", d_out as u64 * 4));
            ev.push(Event::alloc("norm", d_out as u64 * 4));
            for name in ["ba_sq", "gram", "cross", "base_sq", "norm"] {
                ev.push(Event::free(name));
            }
            ev
        }
        Config::Eager => {
            // Algorithm 1. The dominant transient is the fp32 chunk cast
            // [d_out, cs] (paper §2.3: exists when storage is not fp32 OR
            // when the framework's `.float()` copies; we model the paper's
            // measured behaviour: a [d_out, cs] fp32 buffer per chunk plus
            // the squared-W temp of the same size that the chunked
            // accumulation creates).
            let cs = crate::dora::norm_cpu::chunk_size(m, budget) as u64;
            let chunk_bytes = d_out as u64 * cs * 4;
            let mut ev = vec![
                Event::alloc("base_sq", d_out as u64 * 4),
                Event::alloc("cross", d_out as u64 * 4),
                Event::alloc("gram", (r * r) as u64 * 4),
            ];
            let n_chunks = (d_in as u64 + cs - 1) / cs;
            for c in 0..n_chunks {
                // fp32 cast copy of the W chunk exists only for non-fp32
                // storage (`.float()` on fp32 is a no-op) — this is why
                // the isolated-norm memory ratio inverts to 0.8x in bf16
                // (§2.3 "bf16 caveat") while fp32 sees the full benefit.
                if dt != Dtype::F32 {
                    ev.push(Event::alloc_n("w_c", c, chunk_bytes));
                }
                // (W_c ** 2) temp of the chunked base_sq accumulation —
                // the dominant rank-independent transient (§2.3).
                ev.push(Event::alloc_n("w_sq", c, chunk_bytes));
                ev.push(Event::free_n("w_sq", c));
                ev.push(Event::alloc_n("u_c", c, (d_out * r) as u64 * 4));
                ev.push(Event::free_n("u_c", c));
                if dt != Dtype::F32 {
                    ev.push(Event::free_n("w_c", c));
                }
            }
            ev.push(Event::alloc("ba_sq", d_out as u64 * 4));
            ev.push(Event::alloc("norm", d_out as u64 * 4));
            for name in ["ba_sq", "gram", "cross", "base_sq", "norm"] {
                ev.push(Event::free(name));
            }
            ev
        }
    }
}

/// Forward compose allocation stream (Figure 11's forward panel).
/// Training mode (autograd alive): temporaries of the eager chain stay
/// reachable until the output is produced.
pub fn compose_forward_events(
    act: ActShape,
    config: Config,
    dt: Dtype,
    training: bool,
) -> Vec<Event> {
    let n = act.elems() as u64 * dt.size() as u64;
    if config.fused_compose() {
        if training {
            // Tier-1 dual-output kernel: delta + saved inner, one pass —
            // no intermediate spike.
            vec![
                Event::alloc("delta", n),
                Event::alloc("inner", n),
                // both stay alive for backward
            ]
        } else {
            vec![Event::alloc("delta", n)]
        }
    } else {
        // Eager chain: t1 = s*lora; t2 = g*t1; t3 = (g-1)*base; out.
        let mut ev = vec![
            Event::alloc("t1", n),
            Event::alloc("t2", n),
            Event::free("t1"),
            Event::alloc("t3", n),
            Event::alloc("delta", n),
            Event::free("t2"),
            Event::free("t3"),
        ];
        if training {
            // autograd saves inner = s*lora + base for d_mag.
            ev.insert(0, Event::alloc("inner", n));
        }
        ev
    }
}

/// Backward compose stream (Figure 11's backward panel: peaks equal).
pub fn compose_backward_events(act: ActShape, _config: Config, dt: Dtype) -> Vec<Event> {
    let n = act.elems() as u64 * dt.size() as u64;
    vec![
        Event::alloc("d_lora", n),
        Event::alloc("d_base", n),
        Event::alloc("d_mag", act.d_out as u64 * 4),
        Event::free("inner"), // the saved tensor is consumed here
        Event::free("delta"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dora::norm_cpu::{self, AllocTracker};
    use crate::memsim::allocator::peak_of_events;
    use crate::util::rng::Rng;

    #[test]
    fn streams_match_real_trackers() {
        // The event stream's peak must equal the real implementation's
        // AllocTracker peak for the dense engines (the factored stream
        // additionally models the fp32-cast chunk the CPU engine avoids
        // by reading in place, so it is an upper bound there).
        let m = ModuleShape::new(24, 48, 4);
        let mut rng = Rng::new(0);
        let w = rng.normal_vec_f32(m.d_out * m.d_in, 0.1);
        let a = rng.normal_vec_f32(m.rank * m.d_in, 0.1);
        let b = rng.normal_vec_f32(m.d_out * m.rank, 0.1);

        // The allocator rounds to 512-byte granularity; allow that slack
        // (a handful of small vectors) but no structural drift.
        let close = |impl_peak: u64, stream_peak: u64, what: &str| {
            let diff = impl_peak.abs_diff(stream_peak);
            assert!(diff <= 8 * 512, "{what}: impl {impl_peak} vs stream {stream_peak}");
        };
        let mut t = AllocTracker::new();
        norm_cpu::peft_norm(&w, &a, &b, 1.0, m, &mut t);
        let stream_peak = peak_of_events(&norm_events(m, Config::Peft, Dtype::F32, u64::MAX));
        close(t.peak(), stream_peak, "peft");

        let mut t = AllocTracker::new();
        norm_cpu::dense_ba_norm(&w, &a, &b, 1.0, m, &mut t);
        let stream_peak = peak_of_events(&norm_events(m, Config::DenseBA, Dtype::F32, u64::MAX));
        close(t.peak(), stream_peak, "dense_ba");
    }

    #[test]
    fn table1_shape_peaks() {
        // d=8192, r=512, fp32: PEFT peak ~768 MiB (3 dense [d,d] buffers
        // alive at the norm stage); factored ~ chunk cast (256 MiB cap).
        let m = ModuleShape::new(8192, 8192, 512);
        let peft = peak_of_events(&norm_events(m, Config::Peft, Dtype::F32, 256 << 20));
        let fact = peak_of_events(&norm_events(m, Config::Eager, Dtype::F32, 256 << 20));
        let mib = 1u64 << 20;
        assert!(peft / mib >= 700 && peft / mib <= 850, "peft {} MiB", peft / mib);
        assert!(fact / mib >= 200 && fact / mib <= 300, "factored {} MiB", fact / mib);
        let reduction = peft as f64 / fact as f64;
        assert!((2.5..4.0).contains(&reduction), "measured reduction {reduction}");
    }

    #[test]
    fn moe_shape_reduction_is_much_larger() {
        // Table 7's 8192x28672 row: the budget caps the factored transient
        // while PEFT's dense buffers keep growing -> ~11x measured.
        let m = ModuleShape::new(8192, 28672, 384);
        let peft = peak_of_events(&norm_events(m, Config::Peft, Dtype::F32, 256 << 20));
        let fact = peak_of_events(&norm_events(m, Config::Eager, Dtype::F32, 256 << 20));
        let reduction = peft as f64 / fact as f64;
        assert!(reduction > 8.0, "MoE reduction {reduction}");
    }

    #[test]
    fn fused_forward_no_intermediate_spike() {
        let act = ActShape::new(8192, 4096);
        let fused = peak_of_events(&compose_forward_events(act, Config::Fused, Dtype::Bf16, true));
        let eager = peak_of_events(&compose_forward_events(act, Config::Eager, Dtype::Bf16, true));
        assert!(fused < eager, "fused {fused} vs eager {eager}");
        // Inference mode: fused is exactly one output tensor.
        let inf = peak_of_events(&compose_forward_events(act, Config::Fused, Dtype::Bf16, false));
        assert_eq!(inf, act.elems() as u64 * 2);
    }
}
