//! The four configurations compared throughout the paper (§1):
//!
//! | Config    | Norm              | Compose                  |
//! |-----------|-------------------|--------------------------|
//! | `Peft`    | identity-matrix   | 4-kernel eager chain     |
//! | `DenseBA` | direct B@A, dense | 4-kernel eager chain     |
//! | `Eager`   | factored (ours)   | eager chain, stable form |
//! | `Fused`   | factored (ours)   | single fused kernel      |

use std::fmt;

/// One of the paper's four benchmark configurations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Config {
    Peft,
    DenseBA,
    Eager,
    Fused,
}

pub const ALL_CONFIGS: [Config; 4] = [Config::Peft, Config::DenseBA, Config::Eager, Config::Fused];

impl Config {
    /// Display name matching the paper's tables.
    pub fn name(self) -> &'static str {
        match self {
            Config::Peft => "PEFT",
            Config::DenseBA => "Dense (B@A)",
            Config::Eager => "Eager",
            Config::Fused => "Fused",
        }
    }

    /// Does this configuration materialize the dense [d_out, d_in] product
    /// for the weight norm?
    pub fn dense_norm(self) -> bool {
        matches!(self, Config::Peft | Config::DenseBA)
    }

    /// Does this configuration use the single-pass fused compose kernel?
    pub fn fused_compose(self) -> bool {
        matches!(self, Config::Fused)
    }

    pub fn parse(s: &str) -> Option<Config> {
        match s.to_lowercase().replace(['(', ')', '@', ' ', '-', '_'], "").as_str() {
            "peft" => Some(Config::Peft),
            "denseba" | "dense" => Some(Config::DenseBA),
            "eager" => Some(Config::Eager),
            "fused" => Some(Config::Fused),
            _ => None,
        }
    }
}

impl fmt::Display for Config {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Shape of one adapted projection's weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModuleShape {
    pub d_out: usize,
    pub d_in: usize,
    pub rank: usize,
}

impl ModuleShape {
    pub fn new(d_out: usize, d_in: usize, rank: usize) -> Self {
        ModuleShape { d_out, d_in, rank }
    }

    /// Elements of the dense composed weight (the thing the factored norm
    /// never materializes).
    pub fn dense_elems(&self) -> usize {
        self.d_out * self.d_in
    }

    /// Elements of the rank-dependent intermediates U[d_out, r] + G[r, r]
    /// (paper Table 1).
    pub fn factored_elems(&self) -> usize {
        self.d_out * self.rank + self.rank * self.rank
    }

    /// Table 1/7's "theoretical reduction": dense / (U + G), both fp32.
    pub fn theoretical_reduction(&self) -> f64 {
        self.dense_elems() as f64 / self.factored_elems() as f64
    }
}

/// Shape of one compose invocation's activations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ActShape {
    /// batch * seq (collapsed leading dims).
    pub rows: usize,
    pub d_out: usize,
}

impl ActShape {
    pub fn new(rows: usize, d_out: usize) -> Self {
        ActShape { rows, d_out }
    }

    pub fn elems(&self) -> usize {
        self.rows * self.d_out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for c in ALL_CONFIGS {
            assert_eq!(Config::parse(c.name()), Some(c));
        }
        assert_eq!(Config::parse("dense (b@a)"), Some(Config::DenseBA));
        assert_eq!(Config::parse("unknown"), None);
    }

    #[test]
    fn norm_classification() {
        assert!(Config::Peft.dense_norm());
        assert!(Config::DenseBA.dense_norm());
        assert!(!Config::Eager.dense_norm());
        assert!(!Config::Fused.dense_norm());
        assert!(Config::Fused.fused_compose());
        assert!(!Config::Eager.fused_compose());
    }

    #[test]
    fn table1_theoretical_reduction() {
        // Paper Table 1: d=8192, r=512 -> 15.1x.
        let s = ModuleShape::new(8192, 8192, 512);
        let red = s.theoretical_reduction();
        assert!((red - 15.1).abs() < 0.2, "got {red}");
        // Table 7 spot checks.
        assert!((ModuleShape::new(4096, 4096, 64).theoretical_reduction() - 63.0).abs() < 1.5);
        assert!((ModuleShape::new(8192, 28672, 384).theoretical_reduction() - 71.3).abs() < 1.5);
    }
}
