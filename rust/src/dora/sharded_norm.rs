//! Sharded factored norm — the paper's §6.2 FSDP2 future work, built.
//!
//! The paper: "FSDP2/DTensor is not [supported]: the factored norm assumes
//! access to the full base weight W. Extending to FSDP2 would require
//! distributed accumulation of the chunk-wise partial sums followed by an
//! all-reduce over the shard dimension; the per-row output ([d_out]) is
//! small enough to replicate. We leave this for future work."
//!
//! That is exactly Algorithm 1's structure: every term is a sum over
//! d_in-chunks, and a d_in-shard IS a chunk assignment. Each worker holds
//! a contiguous `[d_out, shard_width]` slice of W and the matching
//! columns of A (B is replicated — it is `[d_out, r]`, rank-sized), and
//! computes partial `(base_sq, cross, G)`. One all-reduce (sum) of
//! `2·d_out + r²` floats — KILOBYTES, vs. the dense path's gigabytes —
//! then every worker assembles the identical `w_norm` locally.
//!
//! The "collective" here is an in-process simulation (workers are plain
//! shard structs; `all_reduce_sum` is the tree reduction a real NCCL/Gloo
//! ring would compute), which exercises the real numerical and layout
//! logic: uneven shards, fp32 accumulation, worker-count invariance.

use crate::dora::config::ModuleShape;
use crate::dora::norm_cpu::{chunk_size, AllocTracker};
use crate::kernels::norm::{accumulate_columns, ba_sq_row, sqrt_clamp_min0};
use crate::kernels::F32;

/// One worker's shard of the weight + A factor (d_in-sharded, like FSDP
/// parameter flattening along the input dimension).
#[derive(Debug, Clone)]
pub struct Shard {
    /// Column range [start, stop) of d_in owned by this worker.
    pub start: usize,
    pub stop: usize,
    /// W[:, start..stop], row-major [d_out, stop-start].
    pub w: Vec<f32>,
    /// A[:, start..stop], row-major [r, stop-start].
    pub a: Vec<f32>,
}

/// Partial sums produced by one worker (the all-reduce payload).
#[derive(Debug, Clone)]
pub struct Partials {
    pub base_sq: Vec<f32>, // [d_out]
    pub cross: Vec<f32>,   // [d_out]
    pub gram: Vec<f32>,    // [r, r]
}

impl Partials {
    fn zeros(d_out: usize, r: usize) -> Partials {
        Partials {
            base_sq: vec![0.0; d_out],
            cross: vec![0.0; d_out],
            gram: vec![0.0; r * r],
        }
    }

    /// Payload size in bytes — the paper's "small enough to replicate".
    pub fn payload_bytes(d_out: usize, r: usize) -> usize {
        (2 * d_out + r * r) * 4
    }
}

/// Split (W, A) into `n_workers` d_in-contiguous shards (uneven tails
/// allowed, like FSDP's last rank).
pub fn shard_inputs(w: &[f32], a: &[f32], m: ModuleShape, n_workers: usize) -> Vec<Shard> {
    assert!(n_workers >= 1);
    let per = m.d_in.div_ceil(n_workers);
    let mut shards = Vec::new();
    let mut start = 0;
    while start < m.d_in {
        let stop = (start + per).min(m.d_in);
        let width = stop - start;
        let mut ws = Vec::with_capacity(m.d_out * width);
        for i in 0..m.d_out {
            ws.extend_from_slice(&w[i * m.d_in + start..i * m.d_in + stop]);
        }
        let mut as_ = Vec::with_capacity(m.rank * width);
        for i in 0..m.rank {
            as_.extend_from_slice(&a[i * m.d_in + start..i * m.d_in + stop]);
        }
        shards.push(Shard { start, stop, w: ws, a: as_ });
        start = stop;
    }
    shards
}

/// One worker's local pass: Algorithm 1's loop body over ITS shard, with
/// the worker's own chunking (the 256 MB budget applies per worker).
pub fn worker_partials(
    shard: &Shard,
    b: &[f32],
    m: ModuleShape,
    budget: u64,
    tracker: &mut AllocTracker,
) -> Partials {
    let width = shard.stop - shard.start;
    let d_out = m.d_out;
    let r = m.rank;
    let mut p = Partials::zeros(d_out, r);
    tracker.alloc(((2 * d_out + r * r) * 4) as u64);

    let cs = chunk_size(ModuleShape::new(d_out, width.max(1), r), budget);
    let mut u_c = vec![0f32; d_out * r];
    tracker.alloc((d_out * r * 4) as u64);

    // Algorithm 1's chunk accumulator over THIS shard's columns — the
    // same core the sequential and parallel-tiled norm engines run, with
    // the shard width as the row stride.
    let mut start = 0;
    while start < width {
        let stop = (start + cs).min(width);
        accumulate_columns::<F32>(
            &shard.w,
            &shard.a,
            b,
            d_out,
            r,
            width,
            width,
            start,
            stop,
            &mut p.base_sq,
            &mut p.cross,
            &mut p.gram,
            &mut u_c,
        );
        start = stop;
    }
    tracker.free((d_out * r * 4) as u64);
    drop(u_c);
    p
}

/// Tree all-reduce (sum) over worker partials — the deterministic
/// reduction order a fixed-topology ring/tree gives, so every run of the
/// same world size is bitwise reproducible.
pub fn all_reduce_sum(mut parts: Vec<Partials>) -> Partials {
    assert!(!parts.is_empty());
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut iter = parts.into_iter();
        while let Some(mut a) = iter.next() {
            if let Some(b) = iter.next() {
                for (x, y) in a.base_sq.iter_mut().zip(&b.base_sq) {
                    *x += y;
                }
                for (x, y) in a.cross.iter_mut().zip(&b.cross) {
                    *x += y;
                }
                for (x, y) in a.gram.iter_mut().zip(&b.gram) {
                    *x += y;
                }
            }
            next.push(a);
        }
        parts = next;
    }
    parts.pop().unwrap()
}

/// Full sharded factored norm: shard → worker partials → all-reduce →
/// replicated assembly (Eq. 4 + Eq. 5 on every worker).
pub fn sharded_factored_norm(
    w: &[f32],
    a: &[f32],
    b: &[f32],
    s: f32,
    m: ModuleShape,
    n_workers: usize,
    budget: u64,
) -> Vec<f32> {
    let shards = shard_inputs(w, a, m, n_workers);
    let mut tracker = AllocTracker::new();
    let parts: Vec<Partials> = shards
        .iter()
        .map(|sh| worker_partials(sh, b, m, budget, &mut tracker))
        .collect();
    let total = all_reduce_sum(parts);

    // Replicated assembly: ba_sq via the global Gram, then Eq. 5.
    let (d_out, r) = (m.d_out, m.rank);
    let two_s = (2.0 * s as f64) as f32;
    let s2 = (s as f64 * s as f64) as f32;
    let mut out = vec![0f32; d_out];
    for i in 0..d_out {
        let ba = ba_sq_row::<F32>(&b[i * r..(i + 1) * r], &total.gram, r);
        let tot = total.base_sq[i] + two_s * total.cross[i] + s2 * ba;
        out[i] = sqrt_clamp_min0(tot);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dora::norm_cpu;
    use crate::util::prop::{check, prop_close};
    use crate::util::rng::Rng;

    fn wab(seed: u64, m: ModuleShape) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            rng.normal_vec_f32(m.d_out * m.d_in, 0.05),
            rng.normal_vec_f32(m.rank * m.d_in, 0.1),
            rng.normal_vec_f32(m.d_out * m.rank, 0.1),
        )
    }

    #[test]
    fn matches_unsharded_for_all_world_sizes() {
        let m = ModuleShape::new(48, 200, 8);
        let (w, a, b) = wab(1, m);
        let mut t = AllocTracker::new();
        let reference = norm_cpu::factored_norm(&w, &a, &b, 1.3, m, u64::MAX, &mut t);
        for workers in [1, 2, 3, 4, 7, 200] {
            let sharded = sharded_factored_norm(&w, &a, &b, 1.3, m, workers, u64::MAX);
            for i in 0..m.d_out {
                assert!(
                    (reference[i] - sharded[i]).abs() < 1e-4,
                    "workers={workers} row {i}: {} vs {}",
                    reference[i],
                    sharded[i]
                );
            }
        }
    }

    #[test]
    fn uneven_shards_cover_exactly() {
        let m = ModuleShape::new(4, 10, 2);
        let (w, a, _) = wab(2, m);
        let shards = shard_inputs(&w, &a, m, 3);
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[0].stop - shards[0].start, 4);
        assert_eq!(shards[2].stop - shards[2].start, 2); // uneven tail
        let covered: usize = shards.iter().map(|s| s.stop - s.start).sum();
        assert_eq!(covered, m.d_in);
    }

    #[test]
    fn payload_is_kilobytes_not_gigabytes() {
        // The point of the extension: at d_out=8192, r=512 the all-reduce
        // moves 2*8192*4 + 512^2*4 bytes ~= 1.1 MB, vs. the dense
        // product's 256 MB per module.
        let bytes = Partials::payload_bytes(8192, 512);
        assert!(bytes < 2 << 20, "{bytes}");
        let dense = 8192usize * 8192 * 4;
        assert!(dense / bytes > 200);
    }

    #[test]
    fn all_reduce_deterministic_tree() {
        let m = ModuleShape::new(8, 64, 4);
        let (w, a, b) = wab(3, m);
        let r1 = sharded_factored_norm(&w, &a, &b, 0.7, m, 4, u64::MAX);
        let r2 = sharded_factored_norm(&w, &a, &b, 0.7, m, 4, u64::MAX);
        assert_eq!(r1, r2, "same world size must be bitwise reproducible");
    }

    #[test]
    fn property_worker_count_invariance() {
        check("sharded norm ~ world size", 25, |g| {
            let m = ModuleShape::new(g.usize_in(4, 24), g.usize_in(8, 64), g.usize_in(1, 6));
            let s = g.f64_in(0.1, 3.0) as f32;
            let mut rng = Rng::new(g.case as u64 + 77);
            let w = rng.normal_vec_f32(m.d_out * m.d_in, 0.1);
            let a = rng.normal_vec_f32(m.rank * m.d_in, 0.2);
            let b = rng.normal_vec_f32(m.d_out * m.rank, 0.2);
            let w1 = sharded_factored_norm(&w, &a, &b, s, m, 1, u64::MAX);
            let wn = sharded_factored_norm(&w, &a, &b, s, m, g.usize_in(2, 8), u64::MAX);
            for i in 0..m.d_out {
                prop_close(w1[i] as f64, wn[i] as f64, 1e-4, &format!("row {i}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn per_worker_memory_shrinks_with_world_size() {
        // Each worker's transient is its own shard's chunk working set.
        let m = ModuleShape::new(64, 4096, 8);
        let (w, a, b) = wab(4, m);
        let peak_for = |workers: usize| {
            let shards = shard_inputs(&w, &a, m, workers);
            let mut worst = 0u64;
            for sh in &shards {
                let mut t = AllocTracker::new();
                worker_partials(sh, &b, m, u64::MAX, &mut t);
                worst = worst.max(t.peak());
            }
            worst
        };
        // The tracked transient (partials + U_c) is world-size constant,
        // but the shard data each worker must HOLD shrinks linearly.
        let shards4 = shard_inputs(&w, &a, m, 4);
        let shards1 = shard_inputs(&w, &a, m, 1);
        assert!(shards4[0].w.len() * 3 < shards1[0].w.len());
        let _ = peak_for(4);
    }
}
