//! Real CPU implementations of the DoRA compose — the measurable half of
//! the kernel-fusion claim.
//!
//! The paper's fused-vs-eager comparison is a *memory traffic* argument:
//! the eager path makes 4 sequential element-wise passes over
//! activation-sized arrays, the fused kernel one. On CPU the same regime
//! holds once the working set exceeds LLC, so `cargo bench compose_kernel`
//! reproduces the speedup *mechanism* with real wall-clock numbers (the
//! magnitude differs from GPU; the shape — fused wins, more at larger
//! sizes — is the reproduction target).
//!
//! Four entry points:
//! * [`compose_eager`]        — 4 separate passes with materialized
//!   temporaries, mirroring the PyTorch op-by-op chain.
//! * [`compose_fused`]        — single pass, stable form, fp32 compute.
//! * [`compose_fused_dual`]   — Tier-1 dual output (delta + inner).
//! * [`compose_backward_*`]   — the backward pair, eager and fused.
//!
//! All paths use the canonical evaluation order (`s*lora` first, then
//! `g*(.)`) so eager and fused agree bitwise in f32 (§3.1 "bitwise parity
//! across all PyTorch composition paths").
//!
//! Since the kernel-backend refactor these free functions are thin f32
//! wrappers over the shared dtype-generic cores in [`crate::kernels`] —
//! the same loops the `EagerCpu` / `FusedCpu` / `ParallelTiledCpu`
//! registry backends run, monomorphized with identity rounding, so f32
//! results are bitwise unchanged. New call sites should go through
//! [`crate::kernels::KernelRegistry`] instead.

use crate::dora::config::ActShape;
use crate::kernels::generic::{self, F32};

/// Eager compose: the 4-kernel chain with real temporaries.
///
/// t1 = s * lora; t2 = g * t1; t3 = (g-1) * base; delta = t3 + t2.
/// Each statement is a separate full pass (its own loop + allocation),
/// exactly like the separate CUDA kernels of the eager path.
pub fn compose_eager(base: &[f32], lora: &[f32], g: &[f32], s: f32, act: ActShape) -> Vec<f32> {
    let n = act.elems();
    let d = act.d_out;
    debug_assert_eq!(base.len(), n);
    debug_assert_eq!(lora.len(), n);
    debug_assert_eq!(g.len(), d);

    // Collect-style construction: exact-size iterators write each
    // temporary once with no zero-fill pass (cudaMalloc semantics — the
    // CUDA eager path's temporaries are not zeroed either).
    // Pass 1: t1 = s * lora.
    let t1: Vec<f32> = lora.iter().map(|&l| s * l).collect();
    // Pass 2: t2 = g * t1 (g broadcast along rows).
    let t2: Vec<f32> = t1
        .chunks_exact(d)
        .flat_map(|row| row.iter().zip(g).map(|(&v, &gj)| gj * v))
        .collect();
    drop(t1);
    // Pass 3: t3 = (g - 1) * base.
    let t3: Vec<f32> = base
        .chunks_exact(d)
        .flat_map(|row| row.iter().zip(g).map(|(&b, &gj)| (gj - 1.0) * b))
        .collect();
    // Pass 4: delta = t3 + t2.
    t3.iter().zip(&t2).map(|(&a, &b)| a + b).collect()
}

/// Fused compose: one pass, no temporaries. Identical arithmetic order.
pub fn compose_fused(base: &[f32], lora: &[f32], g: &[f32], s: f32, act: ActShape) -> Vec<f32> {
    let d = act.d_out;
    base.chunks_exact(d)
        .zip(lora.chunks_exact(d))
        .flat_map(|(brow, lrow)| {
            brow.iter().zip(lrow).zip(g).map(|((&b, &l), &gj)| {
                // Canonical order: s*lora first, then g*(.) — bitwise
                // identical to the eager chain (§3.1).
                let t1 = s * l;
                let t2 = gj * t1;
                let t3 = (gj - 1.0) * b;
                t3 + t2
            })
        })
        .collect()
}

/// Preallocated temporaries for the eager chain (the caching-allocator
/// regime: PyTorch's allocator serves these from its cache, so steady-state
/// benchmarking reuses buffers — `compose_eager_into` is the measurement-
/// grade eager path, isolating PASS COUNT from allocation effects).
#[derive(Debug, Clone)]
pub struct EagerTemps {
    t1: Vec<f32>,
    t2: Vec<f32>,
    t3: Vec<f32>,
}

impl EagerTemps {
    pub fn new(act: ActShape) -> Self {
        let n = act.elems();
        EagerTemps { t1: vec![0.0; n], t2: vec![0.0; n], t3: vec![0.0; n] }
    }
}

/// Eager compose into preallocated buffers: 4 separate indexed passes, the
/// steady-state form of the 4-kernel chain. Bitwise identical to
/// `compose_fused_into` (§3.1 canonical order).
pub fn compose_eager_into(
    base: &[f32],
    lora: &[f32],
    g: &[f32],
    s: f32,
    act: ActShape,
    temps: &mut EagerTemps,
    delta: &mut [f32],
) {
    debug_assert_eq!(temps.t1.len(), act.elems());
    generic::eager_chain::<F32>(
        base,
        lora,
        g,
        s,
        act.d_out,
        &mut temps.t1,
        &mut temps.t2,
        &mut temps.t3,
        delta,
    );
}

/// Fused compose writing into a caller-provided buffer (the hot-path form:
/// the coordinator reuses output buffers across calls).
pub fn compose_fused_into(
    base: &[f32],
    lora: &[f32],
    g: &[f32],
    s: f32,
    act: ActShape,
    delta: &mut [f32],
) {
    debug_assert_eq!(delta.len(), act.elems());
    generic::forward_rows::<F32>(base, lora, g, s, act.d_out, delta);
}

/// Tier-1 dual-output compose into caller buffers — one pass, two outputs.
pub fn compose_fused_dual_into(
    base: &[f32],
    lora: &[f32],
    g: &[f32],
    s: f32,
    act: ActShape,
    delta: &mut [f32],
    inner: &mut [f32],
) {
    generic::forward_dual_rows::<F32>(base, lora, g, s, act.d_out, delta, inner);
}

/// Tier-1 dual-output compose: (delta, inner = s*lora + base) in one pass.
pub fn compose_fused_dual(
    base: &[f32],
    lora: &[f32],
    g: &[f32],
    s: f32,
    act: ActShape,
) -> (Vec<f32>, Vec<f32>) {
    let n = act.elems();
    let mut delta = vec![0f32; n];
    let mut inner = vec![0f32; n];
    compose_fused_dual_into(base, lora, g, s, act, &mut delta, &mut inner);
    (delta, inner)
}

/// Eager backward: two separate passes (two kernels).
pub fn compose_backward_eager(
    d_delta: &[f32],
    g: &[f32],
    s: f32,
    act: ActShape,
) -> (Vec<f32>, Vec<f32>) {
    let n = act.elems();
    let mut d_lora = vec![0f32; n];
    let mut d_base = vec![0f32; n];
    generic::backward_eager_rows::<F32>(d_delta, g, s, act.d_out, &mut d_lora, &mut d_base);
    (d_lora, d_base)
}

/// Fused backward: one pass over d_delta, two outputs.
pub fn compose_backward_fused(
    d_delta: &[f32],
    g: &[f32],
    s: f32,
    act: ActShape,
) -> (Vec<f32>, Vec<f32>) {
    let n = act.elems();
    let mut d_lora = vec![0f32; n];
    let mut d_base = vec![0f32; n];
    generic::backward_rows::<F32>(d_delta, g, s, act.d_out, &mut d_lora, &mut d_base);
    (d_lora, d_base)
}

/// KernelAgent-style fully fused backward (paper §7 "LLM-guided
/// optimization"): one pass over d_delta AND inner producing d_lora,
/// d_base, and STAGE-1 partial d_mag sums per row-block; a cheap stage-2
/// pass reduces the partials. Deterministic (fixed block schedule, no
/// atomics) — the "two-stage partial-reduction strategy that fuses the
/// d_mag reduction" the paper credits with 3.58x over eager and leaves
/// for future integration. Here it eliminates the separate dmag pass
/// over d_delta + inner (2 of the 5 backward streams).
pub fn compose_backward_fused_dmag(
    d_delta: &[f32],
    inner: &[f32],
    g: &[f32],
    s: f32,
    act: ActShape,
    d_lora: &mut [f32],
    d_base: &mut [f32],
) -> Vec<f32> {
    use crate::kernels::ComposeKernel;
    crate::kernels::FusedCpu.backward_with_dmag(
        d_delta,
        inner,
        g,
        s,
        act,
        crate::numerics::half::Dtype::F32,
        d_lora,
        d_base,
    )
}

/// d_mag direction gradient: deterministic row reduction of
/// d_delta * inner (never atomics; §3.2).
pub fn dmag_reduction(d_delta: &[f32], inner: &[f32], act: ActShape) -> Vec<f32> {
    generic::dmag(d_delta, inner, act.rows, act.d_out)
}

/// Scalar reference (textbook form, fp64): the correctness oracle for the
/// property tests.
pub fn compose_reference_f64(
    base: &[f32],
    lora: &[f32],
    g: &[f32],
    s: f32,
    act: ActShape,
) -> Vec<f64> {
    let d = act.d_out;
    let mut out = vec![0f64; act.elems()];
    for row in 0..act.rows {
        let o = row * d;
        for j in 0..d {
            let gg = g[j] as f64;
            out[o + j] = (gg - 1.0) * base[o + j] as f64 + gg * s as f64 * lora[o + j] as f64;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, prop_assert, prop_close};
    use crate::util::rng::Rng;

    fn inputs(seed: u64, act: ActShape) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let base = rng.normal_vec_f32(act.elems(), 1.0);
        let lora = rng.normal_vec_f32(act.elems(), 0.3);
        let g: Vec<f32> = (0..act.d_out)
            .map(|_| 1.0 + rng.normal() as f32 * 0.002)
            .collect();
        (base, lora, g)
    }

    #[test]
    fn into_variants_bitwise_equal() {
        let act = ActShape::new(19, 130);
        let (base, lora, g) = inputs(9, act);
        let mut temps = EagerTemps::new(act);
        let mut d_eager = vec![0f32; act.elems()];
        let mut d_fused = vec![0f32; act.elems()];
        compose_eager_into(&base, &lora, &g, 1.3, act, &mut temps, &mut d_eager);
        compose_fused_into(&base, &lora, &g, 1.3, act, &mut d_fused);
        assert_eq!(d_eager, d_fused);
        assert_eq!(d_fused, compose_fused(&base, &lora, &g, 1.3, act));
    }

    #[test]
    fn fused_equals_eager_bitwise_f32() {
        // §3.1: canonical evaluation order makes all CPU composition paths
        // bitwise identical in f32.
        let act = ActShape::new(37, 129);
        let (base, lora, g) = inputs(1, act);
        let e = compose_eager(&base, &lora, &g, 1.7, act);
        let f = compose_fused(&base, &lora, &g, 1.7, act);
        assert_eq!(e, f, "bitwise parity violated");
    }

    #[test]
    fn matches_f64_reference() {
        let act = ActShape::new(16, 64);
        let (base, lora, g) = inputs(2, act);
        let f = compose_fused(&base, &lora, &g, 0.5, act);
        let r = compose_reference_f64(&base, &lora, &g, 0.5, act);
        for (a, b) in f.iter().zip(&r) {
            assert!((*a as f64 - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn dual_output_inner_correct() {
        let act = ActShape::new(8, 32);
        let (base, lora, g) = inputs(3, act);
        let (delta, inner) = compose_fused_dual(&base, &lora, &g, 2.0, act);
        let single = compose_fused(&base, &lora, &g, 2.0, act);
        assert_eq!(delta, single);
        for i in 0..act.elems() {
            let want = 2.0 * lora[i] + base[i];
            assert!((inner[i] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn backward_paths_agree() {
        let act = ActShape::new(24, 48);
        let (d_delta, _, g) = inputs(4, act);
        let (el, eb) = compose_backward_eager(&d_delta, &g, 1.3, act);
        let (fl, fb) = compose_backward_fused(&d_delta, &g, 1.3, act);
        assert_eq!(el, fl);
        assert_eq!(eb, fb);
    }

    #[test]
    fn fused_dmag_backward_matches_separate_paths() {
        let act = ActShape::new(100, 48); // odd block tail (100 = 3*32+4)
        let (d_delta, inner, g) = inputs(10, act);
        let mut dl = vec![0f32; act.elems()];
        let mut db = vec![0f32; act.elems()];
        let d_g = compose_backward_fused_dmag(&d_delta, &inner, &g, 1.7, act, &mut dl, &mut db);
        let (dl_ref, db_ref) = compose_backward_fused(&d_delta, &g, 1.7, act);
        assert_eq!(dl, dl_ref);
        assert_eq!(db, db_ref);
        let dg_ref = dmag_reduction(&d_delta, &inner, act);
        for (a, b) in d_g.iter().zip(&dg_ref) {
            // Both use f64 accumulation; block order may differ in last bits.
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn dmag_matches_naive_sum() {
        let act = ActShape::new(10, 16);
        let (d_delta, inner, _) = inputs(5, act);
        let got = dmag_reduction(&d_delta, &inner, act);
        for j in 0..act.d_out {
            let want: f64 = (0..act.rows)
                .map(|r| d_delta[r * 16 + j] as f64 * inner[r * 16 + j] as f64)
                .sum();
            assert!((got[j] as f64 - want).abs() < 1e-4);
        }
    }

    #[test]
    fn property_compose_linear_in_lora() {
        // delta(base, 2*lora) - delta(base, lora) == delta(0, lora).
        check("compose linear in lora", 50, |gen| {
            let rows = gen.usize_in(1, 12);
            let d = gen.usize_in(1, 64);
            let act = ActShape::new(rows, d);
            let base = gen.f32_normal_vec(act.elems(), 1.0);
            let lora = gen.f32_normal_vec(act.elems(), 1.0);
            let g: Vec<f32> = gen.f32_normal_vec(d, 0.01).iter().map(|x| 1.0 + x).collect();
            let s = gen.f64_in(0.0, 3.0) as f32;
            let lora2: Vec<f32> = lora.iter().map(|x| 2.0 * x).collect();
            let zeros = vec![0f32; act.elems()];
            let d1 = compose_fused(&base, &lora, &g, s, act);
            let d2 = compose_fused(&base, &lora2, &g, s, act);
            let dl = compose_fused(&zeros, &lora, &g, s, act);
            for i in 0..act.elems() {
                prop_close(
                    (d2[i] - d1[i]) as f64,
                    dl[i] as f64,
                    1e-4,
                    &format!("elem {i}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn property_g_equals_one_is_pure_lora() {
        // g == 1: delta = s * lora exactly.
        check("g=1 -> s*lora", 50, |gen| {
            let rows = gen.usize_in(1, 8);
            let d = gen.usize_in(1, 32);
            let act = ActShape::new(rows, d);
            let base = gen.f32_normal_vec(act.elems(), 10.0);
            let lora = gen.f32_normal_vec(act.elems(), 1.0);
            let g = vec![1.0f32; d];
            let s = 0.7f32;
            let delta = compose_fused(&base, &lora, &g, s, act);
            for i in 0..act.elems() {
                prop_assert(
                    (delta[i] - s * lora[i]).abs() < 1e-6,
                    format!("elem {i}: {} vs {}", delta[i], s * lora[i]),
                )?;
            }
            Ok(())
        });
    }
}
