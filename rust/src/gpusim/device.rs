//! Benchmark hardware registry (paper Table 3) and per-device calibration.
//!
//! Six NVIDIA GPUs spanning four architecture generations. The cost model
//! needs, per device:
//!
//! * peak HBM/GDDR bandwidth (Table 3),
//! * a kernel-launch latency (CUDA ~3-8 us; higher on consumer parts),
//! * the *achieved-fraction-of-peak* curves the paper measures in Figure 7
//!   (~50-55% for the fused kernel at large shapes, ~17-25% for the eager
//!   four-pass chain, which is additionally launch-gap bound).
//!
//! Calibration constants are taken from the paper's own measurements
//! (Figure 7's bandwidth table in §5.4), not tuned to match the speedup
//! tables — the speedups then *follow* from traffic ratios, which is the
//! paper's causal claim ("gains derive from reduced memory traffic").

/// Microarchitecture generation (Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    Ada,       // SM89
    Ampere,    // SM80
    Blackwell, // SM100/103/120
    Hopper,    // SM90
}

/// One GPU of the paper's testbed.
#[derive(Debug, Clone)]
pub struct Device {
    pub name: &'static str,
    pub arch: Arch,
    pub sm: u32,
    pub mem_gb: f64,
    /// Peak memory bandwidth, bytes/second.
    pub peak_bw: f64,
    /// Kernel launch + scheduling gap, seconds. Consumer/GDDR parts have
    /// higher effective gaps (smaller L2, driver overheads).
    pub launch_latency: f64,
    /// Achieved fraction of peak for a single streaming (fused) kernel at
    /// large shapes — paper Figure 7: 52-55% across all six GPUs.
    pub fused_bw_frac: f64,
    /// Achieved fraction of peak for the eager multi-kernel chain — paper:
    /// ~17% on B200, ~20-25% on older parts (launch gaps + cache thrash).
    pub eager_bw_frac: f64,
    /// L2 cache size, bytes. Below this working set the eager chain's
    /// producer-consumer intermediates stay cache-resident, so its
    /// effective bandwidth converges to the fused kernel's (Figure 6's
    /// small-shape regime where speedups shrink toward 1).
    pub l2_bytes: f64,
    /// Whether model-level benchmarks ran on this device (Table 3 "Scope").
    pub model_scope: bool,
    /// Dense-GEMM throughput, FLOP/s (bf16 tensor core, used for the
    /// matmul-dominated parts of model-level timing).
    pub peak_flops: f64,
}

const TBPS: f64 = 1e12;

/// The paper's six-GPU testbed (Table 3), with calibration from §5.4.
pub const DEVICES: [Device; 6] = [
    Device {
        name: "L40S",
        arch: Arch::Ada,
        sm: 89,
        mem_gb: 48.0,
        peak_bw: 0.86 * TBPS,
        launch_latency: 6.0e-6,
        fused_bw_frac: 0.54,
        eager_bw_frac: 0.25,
        l2_bytes: 96e6,
        model_scope: false,
        peak_flops: 362e12,
    },
    Device {
        name: "A100-SXM4",
        arch: Arch::Ampere,
        sm: 80,
        mem_gb: 80.0,
        peak_bw: 2.04 * TBPS,
        launch_latency: 4.5e-6,
        fused_bw_frac: 0.52,
        eager_bw_frac: 0.22,
        l2_bytes: 40e6,
        model_scope: false,
        peak_flops: 312e12,
    },
    Device {
        name: "RTX 6000 PRO",
        arch: Arch::Blackwell,
        sm: 120,
        mem_gb: 96.0,
        peak_bw: 1.60 * TBPS,
        launch_latency: 5.0e-6,
        fused_bw_frac: 0.55,
        eager_bw_frac: 0.21,
        l2_bytes: 128e6,
        model_scope: true,
        peak_flops: 503e12,
    },
    Device {
        name: "H200",
        arch: Arch::Hopper,
        sm: 90,
        mem_gb: 141.0,
        peak_bw: 4.80 * TBPS,
        launch_latency: 4.0e-6,
        fused_bw_frac: 0.53,
        eager_bw_frac: 0.20,
        l2_bytes: 50e6,
        model_scope: true,
        peak_flops: 990e12,
    },
    Device {
        name: "B200",
        arch: Arch::Blackwell,
        sm: 100,
        mem_gb: 192.0,
        peak_bw: 7.70 * TBPS,
        launch_latency: 4.0e-6,
        fused_bw_frac: 0.53,
        eager_bw_frac: 0.17,
        l2_bytes: 126e6,
        model_scope: true,
        peak_flops: 2250e12,
    },
    Device {
        name: "B300",
        arch: Arch::Blackwell,
        sm: 103,
        mem_gb: 268.0,
        peak_bw: 7.70 * TBPS,
        launch_latency: 4.0e-6,
        fused_bw_frac: 0.53,
        eager_bw_frac: 0.18,
        l2_bytes: 126e6,
        model_scope: false,
        peak_flops: 2250e12,
    },
];

/// Look up a device by (case-insensitive, prefix-tolerant) name.
pub fn find(name: &str) -> Option<&'static Device> {
    let needle = name.to_lowercase().replace([' ', '-', '_'], "");
    DEVICES.iter().find(|d| {
        d.name
            .to_lowercase()
            .replace([' ', '-', '_'], "")
            .starts_with(&needle)
    })
}

/// The three model-scope devices (Tables 4/5/8).
pub fn model_devices() -> Vec<&'static Device> {
    DEVICES.iter().filter(|d| d.model_scope).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_devices_four_generations() {
        assert_eq!(DEVICES.len(), 6);
        let mut archs: Vec<Arch> = DEVICES.iter().map(|d| d.arch).collect();
        archs.dedup();
        let unique: std::collections::HashSet<_> = DEVICES.iter().map(|d| d.arch).collect();
        assert_eq!(unique.len(), 4);
    }

    #[test]
    fn bandwidth_table_matches_paper() {
        assert_eq!(find("l40s").unwrap().peak_bw, 0.86e12);
        assert_eq!(find("h200").unwrap().peak_bw, 4.8e12);
        assert_eq!(find("b200").unwrap().peak_bw, 7.7e12);
        assert_eq!(find("rtx").unwrap().peak_bw, 1.6e12);
    }

    #[test]
    fn model_scope_is_three_gpus() {
        let names: Vec<_> = model_devices().iter().map(|d| d.name).collect();
        assert_eq!(names, vec!["RTX 6000 PRO", "H200", "B200"]);
    }

    #[test]
    fn lookup_variants() {
        assert!(find("B200").is_some());
        assert!(find("rtx 6000 pro").is_some());
        assert!(find("a100").is_some());
        assert!(find("mi300").is_none());
    }

    #[test]
    fn fused_fraction_in_paper_band() {
        for d in &DEVICES {
            assert!((0.50..=0.56).contains(&d.fused_bw_frac), "{}", d.name);
            assert!(d.eager_bw_frac < d.fused_bw_frac, "{}", d.name);
        }
    }
}
