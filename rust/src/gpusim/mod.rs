//! GPU cost-model simulator.
//!
//! Substitutes for the paper's six-GPU NVIDIA testbed (DESIGN.md §1):
//! devices carry peak bandwidth + achieved-fraction calibration from the
//! paper's own Figure-7 measurements, and kernels are timed as byte/FLOP
//! streams. The paper's speedup tables then *follow from traffic ratios*,
//! which is exactly the causal story the paper tells.

pub mod device;
pub mod kernel;

pub use device::{Device, DEVICES};
pub use kernel::KernelCost;
