//! Kernel-grain cost model: every GPU operation is a stream of bytes and
//! FLOPs through a device, timed as
//!
//! ```text
//! t = launch_latency + max(bytes / (frac * peak_bw), flops / eff_flops)
//! ```
//!
//! The paper's §5.4 measurement ("throughput scales nearly linearly with
//! peak bandwidth across the full 0.86-7.7 TB/s range, confirming these
//! kernels are memory-bandwidth-bound") is the license for this model:
//! for the compose/norm family the bytes term dominates, and the paper's
//! Figure-7 achieved-fraction calibration per path (fused ~53%, eager
//! ~17-25%) closes the loop. Matmul-heavy ops (the norm engines' GEMMs and
//! the model-level projections) use the FLOP term with a shape-dependent
//! MFU.

use super::device::Device;

/// A single modelled kernel invocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelCost {
    /// Wall-clock seconds.
    pub time: f64,
    /// Bytes moved through HBM.
    pub bytes: u64,
    /// Floating-point operations.
    pub flops: f64,
    /// Number of kernel launches.
    pub launches: u32,
}

impl KernelCost {
    pub const ZERO: KernelCost = KernelCost { time: 0.0, bytes: 0, flops: 0.0, launches: 0 };

    pub fn add(self, other: KernelCost) -> KernelCost {
        KernelCost {
            time: self.time + other.time,
            bytes: self.bytes + other.bytes,
            flops: self.flops + other.flops,
            launches: self.launches + other.launches,
        }
    }

    /// Achieved bandwidth (bytes/s) — Figure 7's y-axis.
    pub fn achieved_bw(&self) -> f64 {
        self.bytes as f64 / self.time.max(1e-30)
    }
}

/// Sum a sequence of kernel costs.
pub fn total(costs: &[KernelCost]) -> KernelCost {
    costs.iter().fold(KernelCost::ZERO, |acc, &c| acc.add(c))
}

/// Bandwidth-efficiency band selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BwClass {
    /// Single fused streaming kernel (the paper's Triton kernels).
    Fused,
    /// Element-wise op inside an eager multi-kernel chain: launch gaps,
    /// no producer-consumer reuse, L2 thrash between kernels.
    EagerChain,
}

impl BwClass {
    /// Effective fraction of peak bandwidth for a kernel whose useful
    /// working set is `bytes`. The fused class is size-independent; the
    /// eager chain blends from the fused fraction (fully L2-resident
    /// intermediates) to its large-shape fraction as the working set
    /// leaves L2 — this is why Figure 6's speedups grow with activation
    /// size instead of being launch-ratio-bound at the small end.
    fn frac(self, dev: &Device, bytes: u64) -> f64 {
        match self {
            BwClass::Fused => dev.fused_bw_frac,
            BwClass::EagerChain => {
                let resid = (-(bytes as f64) / dev.l2_bytes).exp();
                dev.eager_bw_frac + (dev.fused_bw_frac - dev.eager_bw_frac) * resid
            }
        }
    }
}

/// Time a pure streaming kernel moving `bytes` through HBM.
pub fn stream(dev: &Device, bytes: u64, class: BwClass) -> KernelCost {
    let bw = class.frac(dev, bytes) * dev.peak_bw;
    KernelCost {
        time: dev.launch_latency + bytes as f64 / bw,
        bytes,
        flops: 0.0,
        launches: 1,
    }
}

/// Matmul efficiency (fraction of peak FLOPs) by shape: large square GEMMs
/// approach ~60% MFU; skinny (small-k or small-n) GEMMs degrade toward the
/// bandwidth roofline, which the byte term below captures anyway.
fn matmul_mfu(m: usize, n: usize, k: usize) -> f64 {
    let min_dim = m.min(n).min(k) as f64;
    // Ramp saturating at 256: tall-skinny GEMMs with two large dims (the
    // adapter matmuls' regime) reach their efficiency plateau once the
    // small dim covers the tile width; beyond that, time scales ~linearly
    // with the small dim. Tiny dims bottom out at 0.08.
    (0.08 + 0.52 * (min_dim / 256.0).min(1.0)).min(0.60)
}

/// Time a GEMM C[m,n] = A[m,k] @ B[k,n] at element size `elt` bytes.
/// Roofline: max of FLOP time and the time to stream A, B, C once.
pub fn matmul(dev: &Device, m: usize, n: usize, k: usize, elt: usize) -> KernelCost {
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let bytes = ((m * k + k * n + m * n) * elt) as u64;
    let t_flops = flops / (matmul_mfu(m, n, k) * dev.peak_flops);
    let t_bytes = bytes as f64 / (dev.fused_bw_frac * dev.peak_bw);
    KernelCost {
        time: dev.launch_latency + t_flops.max(t_bytes),
        bytes,
        flops,
        launches: 1,
    }
}

/// An element-wise kernel reading `reads` arrays and writing `writes`
/// arrays of `n_elems` elements at `elt` bytes each.
pub fn elementwise(
    dev: &Device,
    n_elems: usize,
    reads: usize,
    writes: usize,
    elt: usize,
    class: BwClass,
) -> KernelCost {
    let bytes = (n_elems * (reads + writes) * elt) as u64;
    stream(dev, bytes, class)
}

/// A reduction kernel over `n_elems` inputs producing `n_out` outputs.
pub fn reduction(dev: &Device, n_elems: usize, n_out: usize, elt: usize) -> KernelCost {
    let bytes = ((n_elems + n_out) * elt) as u64;
    stream(dev, bytes, BwClass::Fused)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::find;

    #[test]
    fn stream_time_scales_with_bandwidth() {
        let l40s = find("l40s").unwrap();
        let b200 = find("b200").unwrap();
        let big = 1 << 30;
        let tl = stream(l40s, big, BwClass::Fused).time;
        let tb = stream(b200, big, BwClass::Fused).time;
        // ~9x bandwidth ratio -> ~9x time ratio at large sizes.
        let ratio = tl / tb;
        assert!((7.0..11.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn launch_latency_dominates_small_kernels() {
        let h200 = find("h200").unwrap();
        let c = stream(h200, 1024, BwClass::Fused);
        assert!(c.time < 1.2 * h200.launch_latency + 1e-6);
        assert!(c.time >= h200.launch_latency);
    }

    #[test]
    fn achieved_bw_below_fraction_of_peak() {
        let b200 = find("b200").unwrap();
        let c = stream(b200, 1 << 32, BwClass::Fused);
        let frac = c.achieved_bw() / b200.peak_bw;
        assert!(frac <= b200.fused_bw_frac + 1e-9);
        assert!(frac > 0.9 * b200.fused_bw_frac, "frac {frac}");
    }

    #[test]
    fn matmul_large_is_flop_bound() {
        let h200 = find("h200").unwrap();
        let c = matmul(h200, 4096, 4096, 4096, 2);
        let t_flops_ideal = c.flops / h200.peak_flops;
        assert!(c.time > t_flops_ideal, "must include MFU < 1");
        assert!(c.time < 10.0 * t_flops_ideal);
    }

    #[test]
    fn matmul_skinny_is_memory_bound() {
        let h200 = find("h200").unwrap();
        // [4096, 4096] @ [4096, 8]: tiny n -> streaming A dominates.
        let c = matmul(h200, 4096, 8, 4096, 4);
        let t_bytes = c.bytes as f64 / (h200.fused_bw_frac * h200.peak_bw);
        assert!(c.time >= t_bytes * 0.99);
    }

    #[test]
    fn cost_addition() {
        let h200 = find("h200").unwrap();
        let a = stream(h200, 1000, BwClass::Fused);
        let b = stream(h200, 2000, BwClass::EagerChain);
        let t = total(&[a, b]);
        assert_eq!(t.bytes, 3000);
        assert_eq!(t.launches, 2);
        assert!((t.time - (a.time + b.time)).abs() < 1e-15);
    }
}
