//! Cross-layer integration tests: the full stack (execution backend →
//! coordinator) plus the report generator.
//!
//! The coordinator/serving tests run UNCONDITIONALLY on the native
//! kernel-registry engine — a fresh checkout with no `artifacts/`
//! directory exercises Server batching and Trainer stepping for real.
//! PJRT-gated variants additionally run when `make artifacts` has been
//! built (CI without Python skips only those).

use std::time::Duration;

use dorafactors::bench::report;
use dorafactors::coordinator::{Server, ServerCfg, Trainer, TrainerCfg};
use dorafactors::dora::config::ActShape;
use dorafactors::numerics::stability;
use dorafactors::numerics::Dtype;
use dorafactors::runtime::{
    manifest, AdapterStore, BackendSpec, Engine, ExecBackend, NativeEngine, Tensor,
};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = manifest::default_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

/// Unique scratch directory for an adapter-store test, removed on drop.
struct ScratchDir(std::path::PathBuf);

impl ScratchDir {
    fn new(tag: &str) -> ScratchDir {
        let dir = std::env::temp_dir()
            .join(format!("dora_integration_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        ScratchDir(dir)
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn tiny_trainer_cfg(seed: u64) -> TrainerCfg {
    TrainerCfg {
        config: "tiny".into(),
        variant: "fused".into(),
        seed,
        branching: 3,
        eval_every: 0,
        train_workers: 0,
        grad_accum: 1,
        precision: dorafactors::runtime::Precision::F32,
    }
}

// --- Native-engine integration: unconditional ---------------------------

#[test]
fn native_train_then_serve_handoff_under_concurrent_load() {
    // The serve example's shape, in miniature: train on the native
    // engine, hand the adapted parameters to the batched server, fire
    // concurrent clients, and require every request answered.
    let mut tr = Trainer::new(
        NativeEngine::new(),
        TrainerCfg {
            config: "tiny".into(),
            variant: "fused".into(),
            seed: 13,
            branching: 3,
            eval_every: 0,
            train_workers: 0,
            grad_accum: 1,
            ..TrainerCfg::default()
        },
    )
    .unwrap();
    tr.train_steps(8).unwrap();
    let first = tr.history.first().unwrap().loss;
    let last = tr.history.last().unwrap().loss;
    assert!(first.is_finite() && last.is_finite());

    let server = Server::start_with_params(
        BackendSpec::Native,
        ServerCfg {
            config: "tiny".into(),
            max_wait: Duration::from_millis(50),
            ..ServerCfg::default()
        },
        tr.frozen().to_vec(),
        tr.trainable().to_vec(),
    )
    .unwrap();
    let client = server.client();
    let handles: Vec<_> = (0..3)
        .map(|cid| {
            let c = client.clone();
            std::thread::spawn(move || {
                (0..3)
                    .map(|i| c.infer(&[cid + 1, i + 1, 2]).unwrap())
                    .collect::<Vec<_>>()
            })
        })
        .collect();
    let replies: Vec<_> = handles
        .into_iter()
        .flat_map(|h| h.join().unwrap())
        .collect();
    let m = server.shutdown();
    assert_eq!(m.completed, 9);
    assert_eq!(m.failed, 0);
    assert!(replies.iter().all(|r| r.logit.is_finite()));
    // Batch-occupancy: concurrent clients must share at least one batch.
    assert!(m.batches < 9, "no batching happened: {} batches", m.batches);
    assert!(m.mean_occupancy() > 1.0);
    assert_eq!(m.exec_backend, "native");
}

#[test]
fn native_eager_vs_fused_convergence_parity_end_to_end() {
    // Paper §5.9 criterion on the native engine, through the full
    // Trainer surface: per-step losses within 1e-3 across numeric paths.
    let run = |variant: &str| {
        let mut tr = Trainer::new(
            NativeEngine::new(),
            TrainerCfg {
                config: "tiny".into(),
                variant: variant.into(),
                seed: 21,
                branching: 3,
                eval_every: 4,
                train_workers: 0,
                grad_accum: 1,
                ..TrainerCfg::default()
            },
        )
        .unwrap();
        tr.train_steps(12).unwrap();
        tr
    };
    let eager = run("eager");
    let fused = run("fused");
    let (mean, max) = Trainer::loss_delta(&eager, &fused);
    assert!(mean < 1e-3, "mean |dloss| {mean}");
    assert!(max < 1e-3, "max |dloss| {max}");
    assert!(!eager.eval_history.is_empty());
}

#[test]
fn auto_backend_runs_the_quickstart_artifact_surface() {
    // ExecBackend::auto() must serve the quickstart's artifact set on a
    // fresh checkout (native) and with real artifacts alike.
    let engine = ExecBackend::auto();
    let (bs, sq, d, r) = (2usize, 8usize, 32usize, 4usize);
    let mut rng = dorafactors::util::rng::Rng::new(4);
    let w = rng.normal_vec_f32(d * d, 0.05);
    let a = rng.normal_vec_f32(r * d, 0.06);
    let b = rng.normal_vec_f32(d * r, 0.06);
    let mut tracker = dorafactors::dora::norm_cpu::AllocTracker::new();
    let mag = dorafactors::dora::norm_cpu::factored_norm(
        &w,
        &a,
        &b,
        16.0 / (r as f32).sqrt(),
        dorafactors::dora::config::ModuleShape::new(d, d, r),
        u64::MAX,
        &mut tracker,
    );
    let inputs = [
        Tensor::f32(vec![bs, sq, d], rng.normal_vec_f32(bs * sq * d, 1.0)),
        Tensor::f32(vec![d, d], w),
        Tensor::f32(vec![r, d], a),
        Tensor::f32(vec![d, r], b),
        Tensor::f32(vec![d], mag),
    ];
    let mut reference: Option<Vec<f32>> = None;
    for variant in ["peft", "dense_ba", "eager", "fused"] {
        // PJRT's artifact set only carries dora_linear at its baked
        // shapes; the native engine takes any shape. Use native directly
        // when auto resolved to PJRT but the shape probe fails.
        let out = match engine.run(&format!("dora_linear_{variant}"), &inputs) {
            Ok(out) => out,
            Err(_) => ExecBackend::native()
                .run(&format!("dora_linear_{variant}"), &inputs)
                .unwrap(),
        };
        let y = out[0].as_f32().unwrap().to_vec();
        if let Some(r0) = &reference {
            let max_diff = y.iter().zip(r0).map(|(p, q)| (p - q).abs()).fold(0f32, f32::max);
            assert!(max_diff < 1e-3, "{variant}: {max_diff}");
        } else {
            reference = Some(y);
        }
    }
}

#[test]
fn checkpoint_roundtrip_is_bitwise_identical_after_training() {
    // Acceptance criterion: save -> load -> leaves bitwise equal, on a
    // REAL trained adapter (not just init noise).
    let scratch = ScratchDir::new("ckpt_roundtrip");
    let store = AdapterStore::open(&scratch.0).unwrap();
    let mut tr = Trainer::new(NativeEngine::new(), tiny_trainer_cfg(31)).unwrap();
    tr.train_steps(8).unwrap();
    let adapter = tr.to_adapter("trained").unwrap();
    store.save(&adapter).unwrap();
    let back = store.load("trained").unwrap();
    assert_eq!(back.config, "tiny");
    assert_eq!(back.step, 8);
    assert_eq!(back.seed, 31);
    assert_eq!(
        adapter.params.frozen.len() + adapter.params.trainable.len(),
        back.params.frozen.len() + back.params.trainable.len()
    );
    for (a, b) in adapter
        .params
        .frozen
        .iter()
        .chain(&adapter.params.trainable)
        .zip(back.params.frozen.iter().chain(&back.params.trainable))
    {
        assert!(a.bitwise_eq(b), "leaf {:?} changed across the round trip", a.shape);
    }
}

#[test]
fn multi_adapter_server_matches_single_adapter_logits() {
    // Acceptance criterion: a server hosting 2 adapters returns, for the
    // same prompt, exactly the logits each single-adapter server returns
    // — routing must not mix parameters — with per-adapter metrics.
    let mut tr_a = Trainer::new(NativeEngine::new(), tiny_trainer_cfg(41)).unwrap();
    tr_a.train_steps(8).unwrap();
    let mut tr_b = Trainer::new(NativeEngine::new(), tiny_trainer_cfg(42)).unwrap();
    tr_b.train_steps(8).unwrap();
    let adapter_a = tr_a.to_adapter("job-a").unwrap();
    let adapter_b = tr_b.to_adapter("job-b").unwrap();
    let cfg = || ServerCfg {
        config: "tiny".into(),
        max_wait: Duration::from_millis(5),
        ..ServerCfg::default()
    };
    let prompt = [3, 1, 4, 1, 5];

    // Single-adapter reference paths.
    let single = |adapter: &dorafactors::runtime::Adapter| {
        let server = Server::start_with_adapters(
            BackendSpec::Native,
            cfg(),
            vec![adapter.clone()],
        )
        .unwrap();
        let reply = server.client().infer(&prompt).unwrap();
        server.shutdown();
        reply
    };
    let ref_a = single(&adapter_a);
    let ref_b = single(&adapter_b);
    assert_ne!(ref_a.logits, ref_b.logits, "distinct adapters, distinct logits");

    // Multi-adapter path.
    let server = Server::start_with_adapters(
        BackendSpec::Native,
        cfg(),
        vec![adapter_a, adapter_b],
    )
    .unwrap();
    let client = server.client();
    let got_a = client.infer_with("job-a", &prompt).unwrap();
    let got_b = client.infer_with("job-b", &prompt).unwrap();
    assert_eq!(got_a.logits, ref_a.logits, "job-a logits diverge from single-adapter path");
    assert_eq!(got_b.logits, ref_b.logits, "job-b logits diverge from single-adapter path");
    assert_eq!(got_a.adapter, "job-a");
    assert_eq!(got_b.adapter, "job-b");
    let m = server.shutdown();
    assert_eq!(m.completed, 2);
    assert_eq!(m.per_adapter["job-a"].completed, 1);
    assert_eq!(m.per_adapter["job-b"].completed, 1);
    assert_eq!(m.per_adapter["job-a"].failed, 0);
}

#[test]
fn trainer_checkpoints_hot_load_into_a_running_server() {
    // The full hot-swap protocol: trainer writes periodic checkpoints to
    // the store, a RUNNING server reloads the name mid-serve, and the
    // served logits change to the refreshed weights.
    let scratch = ScratchDir::new("hot_swap");
    let store = AdapterStore::open(&scratch.0).unwrap();
    let mut tr = Trainer::new(NativeEngine::new(), tiny_trainer_cfg(51)).unwrap();
    tr.set_checkpointing(store.clone(), "live", 4).unwrap();
    tr.train_steps(4).unwrap();
    assert_eq!(tr.checkpoints_written, 1);

    let server = Server::start_with_adapters(
        BackendSpec::Native,
        ServerCfg {
            config: "tiny".into(),
            max_wait: Duration::from_millis(5),
            ..ServerCfg::default()
        },
        vec![store.load("live").unwrap()],
    )
    .unwrap();
    let client = server.client();
    let before = client.infer_with("live", &[2, 7, 1]).unwrap();

    // Train on; the next interval boundary writes checkpoint #2.
    tr.train_steps(8).unwrap();
    assert!(tr.checkpoints_written >= 2);
    server.hot_load(&store, "live").unwrap();
    let after = client.infer_with("live", &[2, 7, 1]).unwrap();
    assert_ne!(before.logits, after.logits, "hot-load served stale weights");

    // The refreshed weights match a cold server started from the same
    // checkpoint.
    let cold = Server::start_with_adapters(
        BackendSpec::Native,
        ServerCfg {
            config: "tiny".into(),
            max_wait: Duration::from_millis(5),
            ..ServerCfg::default()
        },
        vec![store.load("live").unwrap()],
    )
    .unwrap();
    let cold_reply = cold.client().infer_with("live", &[2, 7, 1]).unwrap();
    assert_eq!(after.logits, cold_reply.logits);
    cold.shutdown();

    let m = server.shutdown();
    assert_eq!(m.hot_loads, 1);
    assert_eq!(m.completed, 2);
    assert_eq!(m.per_adapter["live"].completed, 2);
}

#[test]
fn report_all_contains_every_unit() {
    let all = report::all();
    for marker in [
        "Table 1", "Table 3", "Table 4", "Table 6", "Table 7", "Table 8",
        "Table 9", "Figure 1", "Figure 4", "Figure 5", "Figure 6",
        "Figure 7", "Figure 8", "Figure 10", "Figure 11", "Figure 13",
        "Figure 14", "Figure 15", "g-distribution", "Dispatch-tier",
        "Appendix G",
    ] {
        assert!(all.contains(marker), "report all missing {marker:?}");
    }
    // Structural spot-checks of the reproduction targets.
    assert!(all.contains("15.1x"), "Table 1 theory reduction");
    assert!(all.contains("OOM"), "Table 4/8 RTX OOMs");
}

#[test]
fn train_then_serve_handoff() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    let mut tr = Trainer::new(
        engine,
        TrainerCfg {
            config: "tiny".into(),
            variant: "fused".into(),
            seed: 11,
            branching: 3,
            eval_every: 0,
            train_workers: 0,
            grad_accum: 1,
            precision: dorafactors::runtime::Precision::F32,
        },
    )
    .unwrap();
    tr.train_steps(4).unwrap();

    let server = Server::start_with_params(
        &dir,
        ServerCfg {
            config: "tiny".into(),
            max_wait: Duration::from_millis(5),
            ..ServerCfg::default()
        },
        tr.frozen().to_vec(),
        tr.trainable().to_vec(),
    )
    .unwrap();
    let client = server.client();
    let r = client.infer(&[1, 2, 3]).unwrap();
    assert!(r.logit.is_finite());
    let m = server.shutdown();
    assert_eq!(m.completed, 1);
}

#[test]
fn near_unity_artifact_matches_stability_model() {
    // The Figure-1 regime through the REAL artifact: g = 1 + 1e-3 on an
    // f32 compose must keep the base correction that bf16-naive loses.
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    let (rows, d_out) = (512usize, 2048usize);
    let base = vec![100.0f32; rows * d_out];
    let lora = vec![0.0f32; rows * d_out];
    let g = vec![1.0 + 1e-3f32; d_out];
    let out = engine
        .run(
            "compose_fused_512x2048",
            &[
                Tensor::f32(vec![rows, d_out], base),
                Tensor::f32(vec![rows, d_out], lora),
                Tensor::f32(vec![d_out], g),
            ],
        )
        .unwrap();
    let delta = out[0].as_f32().unwrap();
    // truth = (g-1) * 100 = 0.1 with s*lora = 0.
    for &v in delta.iter().step_by(499) {
        assert!((v - 0.1).abs() < 1e-4, "collapse through the artifact: {v}");
    }
    // And the software-rounding model agrees that bf16-naive would lose it.
    let naive_bf16 =
        stability::compose_naive_quantized(100.0, 0.0, 1.0 + 1e-3, 2.0, Dtype::Bf16);
    assert_eq!(naive_bf16, 0.0);
}

#[test]
fn trainer_rejects_bad_variant() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    let err = Trainer::new(
        engine,
        TrainerCfg { config: "tiny".into(), variant: "nope".into(), ..TrainerCfg::default() },
    );
    assert!(err.is_err());
}

#[test]
fn kernel_registry_backends_agree_with_flat_kernels() {
    // Cross-layer invariant of the backend refactor: every registry
    // backend reproduces the flat f32 kernels bitwise, and the registry's
    // dispatch surface agrees with the bare-enum tier decision.
    use dorafactors::dispatch::{ComposeCtx, DispatchEnv};
    use dorafactors::dora::compose_cpu;
    use dorafactors::kernels::registry;
    use dorafactors::numerics::Dtype;
    use dorafactors::util::rng::Rng;

    let act = ActShape::new(61, 193);
    let mut rng = Rng::new(99);
    let base = rng.normal_vec_f32(act.elems(), 1.0);
    let lora = rng.normal_vec_f32(act.elems(), 0.3);
    let g: Vec<f32> = (0..act.d_out).map(|_| 1.0 + rng.normal() as f32 * 0.002).collect();
    let want = compose_cpu::compose_fused(&base, &lora, &g, 1.7, act);
    for be in registry().compose_backends() {
        let got = be.forward_alloc(&base, &lora, &g, 1.7, act, Dtype::F32);
        assert_eq!(got, want, "backend {} diverged from the flat kernels", be.name());
    }
    let env = DispatchEnv::default();
    for rows in [16usize, 512, 8192] {
        for d_out in [256usize, 2048, 8192] {
            let ctx = ComposeCtx::training(ActShape::new(rows, d_out));
            let choice = registry().select(&env, &ctx);
            assert_eq!(choice.tier, dorafactors::dispatch::select_tier(&env, &ctx));
        }
    }
}

#[test]
fn dispatch_stats_consistent_with_model_plan_tiers() {
    // The dispatch module and the model plan must agree on which modules
    // run fused — the §4 "71% Tier 1" statistic is shared state.
    let env = dorafactors::dispatch::DispatchEnv::default();
    for spec in dorafactors::models::MODELS.iter() {
        let stats = dorafactors::dispatch::model_tier_stats(&env, spec, 384, 4096);
        let mut fused_modules = 0usize;
        for (_, shape, count) in spec.inventory(384) {
            let ctx = dorafactors::dispatch::ComposeCtx::training(ActShape::new(
                4096,
                shape.d_out,
            ));
            if dorafactors::dispatch::select_tier(&env, &ctx)
                != dorafactors::dispatch::Tier::Eager
            {
                fused_modules += count;
            }
        }
        assert_eq!(stats.tier1, fused_modules, "{}", spec.name);
    }
}
