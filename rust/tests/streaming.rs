//! Streaming-decode scheduler integration tests: the continuous-batching
//! determinism contract (a request that joins a running batch decodes
//! the same tokens as a solo run, bitwise), slot turnover, typed
//! overload shedding, and clean client-disconnect cancellation. All
//! tests run unconditionally on the native engine.

use std::time::{Duration, Instant};

use dorafactors::coordinator::{FastPath, GenOptions, Overloaded, Server, ServerCfg};
use dorafactors::runtime::ops::AdapterVariant;
use dorafactors::runtime::{Adapter, BackendSpec, ExecBackend, InitReq, Precision, TensorData};

fn cfg(workers: usize, fast_path: FastPath, queue_depth: usize) -> ServerCfg {
    ServerCfg {
        config: "tiny".into(),
        max_wait: Duration::from_millis(2),
        workers,
        fast_path,
        queue_depth,
        ..ServerCfg::default()
    }
}

/// A tiny-config adapter with leaves nudged off init so the variant math
/// bites (rsLoRA / BoRA differ from DoRA only off init).
fn perturbed_adapter(name: &str, variant: AdapterVariant) -> Adapter {
    let be = ExecBackend::native();
    let info = be.config("tiny").unwrap();
    let init = be
        .init(InitReq { config: "tiny".into(), seed: 3, precision: Precision::F32 })
        .unwrap();
    let mut adapter = Adapter::new(name, &info, 3, 0, init.params).unwrap();
    for t in adapter.params.trainable.iter_mut() {
        if let TensorData::F32(v) = &mut t.data {
            for (i, x) in v.iter_mut().enumerate() {
                *x += ((i % 7) as f32 - 3.0) * 0.01;
            }
        }
    }
    adapter.with_variant(variant)
}

/// Poll `probe` until it returns true or `what` times out (the scheduler
/// runs on its own thread; gauges lag submission by a step).
fn wait_for(what: &str, mut probe: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !probe() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn mid_batch_join_matches_solo_decode_bitwise() {
    // THE determinism acceptance criterion: for every adapter variant and
    // pool size, a request that joins a batch already mid-decode produces
    // a token sequence bitwise identical to the same request decoded on
    // an otherwise idle server. Works because the GEMM core accumulates
    // row-locally, so co-resident batch rows never perturb a request's
    // logits.
    let probe_prompt = [2, 7, 1, 8];
    let opts = GenOptions { max_tokens: 24, ..GenOptions::default() };
    let cases = [
        (AdapterVariant::Dora, FastPath::Merged),
        (AdapterVariant::Dora, FastPath::Composed),
        (AdapterVariant::RsLora, FastPath::Merged),
        (AdapterVariant::Bora, FastPath::Merged),
    ];
    for (variant, path) in cases {
        for workers in [1usize, 2] {
            let start = |adapters| {
                Server::start_with_adapters(
                    BackendSpec::Native,
                    cfg(workers, path, 16),
                    adapters,
                )
                .unwrap()
            };
            // Solo reference: the probe decodes alone.
            let server = start(vec![perturbed_adapter("v", variant)]);
            let solo = server
                .client()
                .generate_collect_with("v", &probe_prompt, opts)
                .unwrap();
            assert_eq!(solo.len(), 24);
            server.shutdown();

            // Busy run: two long fillers (one on a second adapter when the
            // pool has two workers) are mid-decode when the probe joins.
            let server = start(vec![
                perturbed_adapter("v", variant),
                perturbed_adapter("other", variant),
            ]);
            let client = server.client();
            // Long enough that the fillers are still decoding when the
            // probe joins AND finishes (they get cancelled at drop).
            let filler_opts = GenOptions { max_tokens: usize::MAX, seed: 9, ..opts };
            let f1 = client.generate_with("v", &[5, 5], filler_opts).unwrap();
            let f2 = client.generate_with("other", &[6, 6], filler_opts).unwrap();
            wait_for("fillers decoding", || server.metrics().decode_in_flight >= 2);
            let joined = client
                .generate_collect_with("v", &probe_prompt, opts)
                .unwrap();
            assert_eq!(
                joined, solo,
                "{variant:?}/{}/pool={workers}: mid-join decode diverged from solo",
                path.as_str()
            );
            drop(f1);
            drop(f2);
            let m = server.shutdown();
            assert_eq!(m.decode_failed, 0);
            assert!(m.decode_tokens >= 24);
        }
    }
}

#[test]
fn early_finish_frees_slot_within_one_step() {
    // tiny's train_batch is 4 decode slots. Five concurrent short streams
    // must ALL complete: the fifth can only run if a finished stream
    // frees its slot for the queued request (continuous batching, not
    // drain-then-refill).
    let server =
        Server::start(BackendSpec::Native, cfg(1, FastPath::Merged, 16)).unwrap();
    let client = server.client();
    let opts = GenOptions { max_tokens: 4, ..GenOptions::default() };
    let streams: Vec<_> = (0..5)
        .map(|_| client.generate(&[1, 2, 3], opts).unwrap())
        .collect();
    let collected: Vec<Vec<i32>> =
        streams.into_iter().map(|s| s.collect().unwrap()).collect();
    // Same adapter + greedy + same prompt: every stream decodes the same
    // sequence regardless of when its slot opened.
    for tokens in &collected {
        assert_eq!(tokens, &collected[0]);
        assert_eq!(tokens.len(), 4);
    }
    let m = server.shutdown();
    assert_eq!(m.decode_requests, 5);
    assert_eq!(m.decode_completed, 5);
    assert_eq!(m.decode_tokens, 20);
    assert_eq!(m.decode_failed, 0);
    assert_eq!(m.shed_requests, 0);
}

#[test]
fn queue_full_sheds_with_typed_overloaded() {
    // Saturate all 4 slots with effectively-infinite decodes, fill the
    // admission queue (cap 2), then confirm the next submit is rejected
    // with a typed, downcastable Overloaded — fail-fast, not a hang.
    let server =
        Server::start(BackendSpec::Native, cfg(1, FastPath::Merged, 2)).unwrap();
    let client = server.client();
    let long = GenOptions { max_tokens: usize::MAX, ..GenOptions::default() };
    // Admit the fillers one at a time: a burst could transiently
    // overflow the 2-deep queue and shed a filler instead of the probe.
    let mut fillers = Vec::new();
    for i in 0..4 {
        fillers.push(client.generate(&[1], long).unwrap());
        wait_for("filler admitted", || server.metrics().decode_in_flight == i + 1);
    }
    // No slot will free up, so these two sit in the queue...
    let q1 = client.generate(&[2], long).unwrap();
    let q2 = client.generate(&[3], long).unwrap();
    assert_eq!(server.metrics().decode_queue_depth, 2);
    // ...and the third is shed, immediately, with the typed error.
    let before = Instant::now();
    let err = client.generate(&[4], long).unwrap_err();
    assert!(before.elapsed() < Duration::from_secs(1), "shed was not fail-fast");
    let overloaded = err
        .downcast_ref::<Overloaded>()
        .unwrap_or_else(|| panic!("not a typed Overloaded: {err:#}"));
    assert_eq!(overloaded.queue_depth, 2);
    let m = server.metrics();
    assert_eq!(m.shed_requests, 1);
    assert_eq!(m.decode_in_flight, 4);
    drop(fillers);
    drop(q1);
    drop(q2);
    let m = server.shutdown();
    assert_eq!(m.shed_requests, 1);
    assert_eq!(m.decode_in_flight, 0);
}

#[test]
fn client_disconnect_mid_decode_cancels_cleanly() {
    // Dropping a GenStream mid-decode frees the slot (counted as
    // cancelled) without poisoning the scheduler: a follow-up request on
    // the same server decodes normally.
    let server =
        Server::start(BackendSpec::Native, cfg(1, FastPath::Merged, 8)).unwrap();
    let client = server.client();
    let stream = client
        .generate(&[1, 2], GenOptions { max_tokens: usize::MAX, ..GenOptions::default() })
        .unwrap();
    // Read a few events to prove it was really mid-decode, then hang up.
    for _ in 0..3 {
        stream.next_event().expect("stream died early").unwrap();
    }
    drop(stream);
    wait_for("cancellation", || server.metrics().decode_cancelled == 1);
    let tokens = client
        .generate_collect(&[1, 2], GenOptions { max_tokens: 6, ..GenOptions::default() })
        .unwrap();
    assert_eq!(tokens.len(), 6);
    let m = server.shutdown();
    assert_eq!(m.decode_cancelled, 1);
    assert_eq!(m.decode_completed, 1);
    assert_eq!(m.decode_failed, 0);
    assert_eq!(m.decode_in_flight, 0);
}

#[test]
fn shutdown_answers_queued_and_active_streams_with_errors() {
    // No request is left hanging at shutdown: active and queued streams
    // both receive an error event instead of a silent channel close.
    let server =
        Server::start(BackendSpec::Native, cfg(1, FastPath::Merged, 4)).unwrap();
    let client = server.client();
    let long = GenOptions { max_tokens: usize::MAX, ..GenOptions::default() };
    let active: Vec<_> = (0..4).map(|_| client.generate(&[1], long).unwrap()).collect();
    wait_for("slots busy", || server.metrics().decode_in_flight == 4);
    let queued = client.generate(&[2], long).unwrap();
    server.shutdown();
    // Drain every stream to its terminal state; each must end in Err.
    for s in active.into_iter().chain(std::iter::once(queued)) {
        let mut saw_err = false;
        for ev in s {
            if ev.is_err() {
                saw_err = true;
                break;
            }
        }
        assert!(saw_err, "a stream was dropped without a shutdown error");
    }
    // New submissions after shutdown fail fast too.
    let err = client.generate(&[1], long).unwrap_err();
    assert!(format!("{err:#}").contains("stopped"), "{err:#}");
}
